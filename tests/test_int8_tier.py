"""Quantization-loss regression suite for the int8 scoring tier.

The tier's contract (docs/quantized_tier.md): the int8 replica only decides
WHICH top-α·k candidates reach the exact fp32 rerank — returned scores are
always exact, predicates always evaluate on exact fp32 scalars, and the hot
tier of a tiered table never touches the replica at all. These tests pin the
recall cost of that candidate-selection perturbation against the pure-NumPy
float64 oracle (tests/oracle.py) and against the fp32 candidate-local path
on the SAME plans, across clause buckets C=1/2/4 and both metrics, plus the
tiered hot∪cold case proving hot rows stay exact-fp32-scored under an int8
cold plan.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from oracle import brute_force_topk, tie_aware_recall, tiered_brute_force_topk
from repro.core.query import ExecutionPlan, MHQ, SubqueryParams
from repro.serve.batch import BatchedHybridExecutor, CANDIDATE_LOCAL, CostModel
from repro.vectordb import ivf
from repro.vectordb.predicates import PredicateSet, Predicates
from repro.vectordb.table import ScalarCol, Table, TableSchema, VectorCol

N, D, M, K = 800, 24, 3, 10


def _make_table(metric: str, seed: int = 0):
    rng = np.random.default_rng(seed)
    schema = TableSchema(
        vector_cols=(VectorCol("v0", D), VectorCol("v1", D)),
        scalar_cols=tuple(ScalarCol(f"s{i}", "num") for i in range(M)),
        metric=metric)
    vecs = [rng.normal(size=(N, D)).astype(np.float32) for _ in range(2)]
    scal = rng.uniform(0.0, 1.0, (N, M)).astype(np.float32)
    t = Table.from_numpy(schema, vecs, scal)
    idx = [ivf.build(v, 8, seed=i, metric=metric) for i, v in enumerate(t.vectors)]
    return t, idx


def _clause(rng):
    col = int(rng.integers(0, M))
    lo = float(rng.uniform(0.0, 0.5))
    return {col: (lo, lo + 0.45)}


def _workload(t, n_queries: int, clauses: int, seed: int) -> list[MHQ]:
    rng = np.random.default_rng(seed)
    wl = []
    for _ in range(n_queries):
        w = rng.uniform(0.2, 1.0, 2)
        w = (w / w.sum()).astype(np.float32)
        qv = tuple(jnp.asarray(rng.normal(size=(D,)).astype(np.float32))
                   for _ in range(2))
        if clauses == 1:
            pred = Predicates.from_conditions(M, _clause(rng))
        else:
            pred = PredicateSet.from_clauses(
                M, [_clause(rng) for _ in range(clauses)])
        wl.append(MHQ(query_vectors=qv, weights=tuple(float(x) for x in w),
                      predicates=pred, k=K))
    return wl


def _plan(precision: str) -> ExecutionPlan:
    # nprobe = n_clusters: slot selection is exhaustive, so any recall gap
    # vs the oracle is attributable to the scoring tier, not probing
    return ExecutionPlan("index_scan", tuple(
        SubqueryParams(k_mult=2, nprobe=8, max_scan=2048, iterative=False)
        for _ in range(2)), precision=precision)


def test_cost_model_per_precision_crossover():
    """The calibrated per-precision constants
    (benchmarks/results/quantized_crossover.json) widen the int8 tier's
    candidate-local region: a (batch, scan, n_rows) point between the two
    crossovers dispatches dense under fp32 but candidate-local under int8."""
    from repro.serve.batch import DENSE, CostModel

    cm = CostModel()
    kw = dict(batch=8, scan=4096, n_rows=100_000)
    assert cm.choose(**kw, precision="fp32") == DENSE
    assert cm.choose(**kw, precision="int8") == CANDIDATE_LOCAL


@pytest.mark.parametrize("metric", ["dot", "l2"])
def test_int8_recall_vs_oracle_across_clause_buckets(metric):
    """int8-tier recall against the float64 oracle, per clause bucket, and
    the quantization loss vs the fp32 candidate-local path on identical
    plans — the α·k rerank must keep the tier within a small recall delta
    of exact scoring."""
    t, idx = _make_table(metric)
    bx = BatchedHybridExecutor(t, idx,
                               cost_model=CostModel(force=CANDIDATE_LOCAL))
    for clauses in (1, 2, 4):
        wl = _workload(t, 8, clauses, seed=100 + clauses)
        res8 = bx.execute_batch(wl, [_plan("int8")] * len(wl))
        res32 = bx.execute_batch(wl, [_plan("fp32")] * len(wl))
        r8, r32 = [], []
        for q, (i8, s8), (i32, _) in zip(wl, res8, res32):
            _, _, masked = brute_force_topk(
                t, q.query_vectors, q.weights, q.predicates, q.k)
            r8.append(tie_aware_recall(i8, masked, q.k))
            r32.append(tie_aware_recall(i32, masked, q.k))
            # exact-score contract: every returned int8-tier score is the
            # EXACT weighted fp32 score of its id (the rerank re-scored it)
            ids = np.asarray(i8)
            sc = np.asarray(s8)
            for pos in range(ids.shape[0]):
                if ids[pos] >= 0:
                    assert abs(sc[pos] - masked[ids[pos]]) <= \
                        1e-3 + 1e-4 * abs(masked[ids[pos]])
        assert np.mean(r8) >= 0.9, (metric, clauses, r8)
        assert min(r8) >= 0.7, (metric, clauses, r8)
        # quantization loss budget vs fp32 on the same candidate budget
        assert np.mean(r32) - np.mean(r8) <= 0.05, (metric, clauses, r8, r32)


def test_tiered_hot_rows_stay_exact_fp32_under_int8_cold_plan(monkeypatch):
    """Tiered parity: with the COLD tier forced onto int8 plans, the hot
    segment is still scored exactly in fp32 (``merge_hot_batch`` reads the
    full-precision hot vectors — there is no hot int8 replica), so every
    oracle top-k row living in the hot tier MUST be returned with its exact
    score; int8 selection noise is confined to cold candidates."""
    from repro.core.boomhq import BoomHQ, BoomHQConfig

    rng = np.random.default_rng(7)
    t, _ = _make_table("dot", seed=3)
    bq = BoomHQ(t, BoomHQConfig(use_de=False, n_clusters=8))
    bq.bind_cost_model(CostModel(force=CANDIDATE_LOCAL))
    bq.bind_tiered(hot_capacity=256)

    # queries first, then hot rows planted ON each query's weighted
    # direction — those hot rows dominate the global top-k by construction
    qrng = np.random.default_rng(55)
    wl = [MHQ(query_vectors=tuple(
                  jnp.asarray(qrng.normal(size=(D,)).astype(np.float32))
                  for _ in range(2)),
              weights=(0.7, 0.3), predicates=Predicates.none(M), k=K)
          for _ in range(4)]
    n_hot = 40
    hot_vecs = [rng.normal(size=(n_hot, D)).astype(np.float32) * 0.01
                for _ in range(2)]
    for j, q in enumerate(wl):
        for r in range(3):
            row = 3 * j + r
            for c in range(2):
                hot_vecs[c][row] = (8.0 - 0.1 * r) * \
                    np.asarray(q.query_vectors[c])
    hot_scal = rng.uniform(0.0, 1.0, (n_hot, M)).astype(np.float32)
    stats = bq.insert(list(hot_vecs), hot_scal)
    assert not stats["needs_compaction"]  # hot rows stay in the hot tier

    monkeypatch.setattr(
        bq, "optimize_batch",
        lambda qs, **kw: [_plan("int8")] * len(qs))
    res = bq.execute_batch(wl)

    segments = [(list(t.vectors), t.scalars), (hot_vecs, hot_scal)]
    for j, (q, (ids, scores)) in enumerate(zip(wl, res)):
        o_ids, _, masked = tiered_brute_force_topk(
            segments, "dot", q.query_vectors, q.weights, q.predicates, q.k)
        oracle_hot = {int(i) for i in o_ids if i >= N}
        assert oracle_hot, "fixture broke: no hot rows in the oracle top-k"
        got = {int(i) for i in np.asarray(ids) if i >= 0}
        missing = oracle_hot - got
        assert not missing, (
            f"query {j}: hot-tier oracle rows {sorted(missing)} lost — the "
            f"hot segment must be exact under an int8 cold plan")
        sc = np.asarray(scores)
        idn = np.asarray(ids)
        for pos in range(idn.shape[0]):
            if int(idn[pos]) in oracle_hot:
                exact = masked[int(idn[pos])]
                assert abs(sc[pos] - exact) <= 1e-3 + 1e-4 * abs(exact)
        assert tie_aware_recall(ids, masked, q.k) >= 0.9
