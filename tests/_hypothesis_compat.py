"""Optional-hypothesis shim: property tests skip (not error) when the
package is absent, while plain tests in the same module keep running.

    from tests._hypothesis_compat import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Placeholder so strategy expressions in decorators still evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
