"""Pallas kernels vs pure-jnp oracles — hypothesis sweeps over shapes/dtypes."""
import numpy as np

from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.kernels import ops, ref


def _case(n, d, m, seed, sel):
    rng = np.random.default_rng(seed)
    vecs = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    scal = jnp.asarray(rng.uniform(0, 10, (n, m)), jnp.float32)
    width = 10.0 * sel
    lo_v = rng.uniform(0, 10 - width)
    lo = jnp.asarray([lo_v] + [-np.inf] * (m - 1), jnp.float32)
    hi = jnp.asarray([lo_v + width] + [np.inf] * (m - 1), jnp.float32)
    act = jnp.asarray([True] + [False] * (m - 1))
    q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    return q, vecs, scal, lo, hi, act


@settings(max_examples=12, deadline=None)
@given(n=st.integers(10, 600), d=st.sampled_from([8, 32, 128]),
       m=st.integers(1, 4), k=st.sampled_from([1, 5, 10]),
       block=st.sampled_from([32, 128, 256]),
       metric=st.sampled_from(["dot", "l2"]),
       sel=st.floats(0.05, 1.0), seed=st.integers(0, 10_000))
def test_masked_topk_matches_oracle(n, d, m, k, block, metric, sel, seed):
    q, vecs, scal, lo, hi, act = _case(n, d, m, seed, sel)
    s1, i1 = ops.masked_topk(q, vecs, scal, lo, hi, act, k=k,
                             block_rows=block, metric=metric)
    s2, i2 = ref.masked_topk_ref(q, vecs, scal, lo, hi, act, n, k=k,
                                 metric=metric)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-3, rtol=1e-4)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


@settings(max_examples=8, deadline=None)
@given(n=st.integers(20, 500), d=st.sampled_from([16, 64]),
       k=st.sampled_from([5, 10]), block=st.sampled_from([64, 128]),
       seed=st.integers(0, 10_000))
def test_int8_scan_matches_oracle(n, d, k, block, seed):
    q, vecs, scal, lo, hi, act = _case(n, d, 2, seed, 0.5)
    qv, sc = ops.quantize_rows(vecs)
    s1, i1 = ops.int8_masked_topk(q, qv, sc, scal, lo, hi, act, k=k,
                                  block_rows=block)
    s2, i2 = ref.int8_topk_ref(q, qv, sc, scal, lo, hi, act, n, k=k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-3, rtol=1e-4)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


def test_int8_quantization_recall():
    """Quantized scan should recover ≥ 90% of the fp32 top-10 on real-ish data."""
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(5000, 64)), jnp.float32)
    scal = jnp.asarray(rng.uniform(0, 1, (5000, 1)), jnp.float32)
    lo = jnp.asarray([-np.inf], jnp.float32)
    hi = jnp.asarray([np.inf], jnp.float32)
    act = jnp.asarray([False])
    recs = []
    for s in range(5):
        q = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        qv, sc = ops.quantize_rows(vecs)
        _, i_q = ops.int8_masked_topk(q, qv, sc, scal, lo, hi, act, k=10)
        _, i_f = ref.masked_topk_ref(q, vecs, scal, lo, hi, act, 5000, k=10)
        recs.append(len(set(map(int, np.asarray(i_q)))
                        & set(map(int, np.asarray(i_f)))) / 10)
    assert np.mean(recs) >= 0.9


def test_empty_result_when_nothing_qualifies():
    q, vecs, scal, lo, hi, act = _case(100, 16, 2, 0, 0.5)
    lo = jnp.asarray([100.0, -np.inf], jnp.float32)  # impossible range
    hi = jnp.asarray([200.0, np.inf], jnp.float32)
    act = jnp.asarray([True, False])
    s, i = ops.masked_topk(q, vecs, scal, lo, hi, act, k=5)
    assert (np.asarray(i) == -1).all()
