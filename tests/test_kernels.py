"""Pallas kernels vs pure-jnp oracles — hypothesis sweeps over shapes/dtypes."""
import numpy as np

from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.kernels import ops, ref


def _case(n, d, m, seed, sel):
    rng = np.random.default_rng(seed)
    vecs = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    scal = jnp.asarray(rng.uniform(0, 10, (n, m)), jnp.float32)
    width = 10.0 * sel
    lo_v = rng.uniform(0, 10 - width)
    lo = jnp.asarray([lo_v] + [-np.inf] * (m - 1), jnp.float32)
    hi = jnp.asarray([lo_v + width] + [np.inf] * (m - 1), jnp.float32)
    act = jnp.asarray([True] + [False] * (m - 1))
    q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    return q, vecs, scal, lo, hi, act


@settings(max_examples=12, deadline=None)
@given(n=st.integers(10, 600), d=st.sampled_from([8, 32, 128]),
       m=st.integers(1, 4), k=st.sampled_from([1, 5, 10]),
       block=st.sampled_from([32, 128, 256]),
       metric=st.sampled_from(["dot", "l2"]),
       sel=st.floats(0.05, 1.0), seed=st.integers(0, 10_000))
def test_masked_topk_matches_oracle(n, d, m, k, block, metric, sel, seed):
    q, vecs, scal, lo, hi, act = _case(n, d, m, seed, sel)
    s1, i1, v1 = ops.masked_topk(q, vecs, scal, lo, hi, act, k=k,
                                 block_rows=block, metric=metric)
    s2, i2 = ref.masked_topk_ref(q, vecs, scal, lo, hi, act, n, k=k,
                                 metric=metric)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-3, rtol=1e-4)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))
    assert np.array_equal(np.asarray(v1), np.asarray(i2) >= 0)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(20, 500), d=st.sampled_from([16, 64]),
       k=st.sampled_from([5, 10]), block=st.sampled_from([64, 128]),
       seed=st.integers(0, 10_000))
def test_int8_scan_matches_oracle(n, d, k, block, seed):
    q, vecs, scal, lo, hi, act = _case(n, d, 2, seed, 0.5)
    qv, sc = ops.quantize_rows(vecs)
    s1, i1, _ = ops.int8_masked_topk(q, qv, sc, scal, lo, hi, act, k=k,
                                     block_rows=block)
    s2, i2 = ref.int8_topk_ref(q, qv, sc, scal, lo, hi, act, n, k=k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-3, rtol=1e-4)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


def test_int8_quantization_recall():
    """Quantized scan should recover ≥ 90% of the fp32 top-10 on real-ish data."""
    rng = np.random.default_rng(0)
    vecs = jnp.asarray(rng.normal(size=(5000, 64)), jnp.float32)
    scal = jnp.asarray(rng.uniform(0, 1, (5000, 1)), jnp.float32)
    lo = jnp.asarray([-np.inf], jnp.float32)
    hi = jnp.asarray([np.inf], jnp.float32)
    act = jnp.asarray([False])
    recs = []
    for s in range(5):
        q = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        qv, sc = ops.quantize_rows(vecs)
        _, i_q, _ = ops.int8_masked_topk(q, qv, sc, scal, lo, hi, act, k=10)
        _, i_f = ref.masked_topk_ref(q, vecs, scal, lo, hi, act, 5000, k=10)
        recs.append(len(set(map(int, np.asarray(i_q)))
                        & set(map(int, np.asarray(i_f)))) / 10)
    assert np.mean(recs) >= 0.9


def test_empty_result_when_nothing_qualifies():
    q, vecs, scal, lo, hi, act = _case(100, 16, 2, 0, 0.5)
    lo = jnp.asarray([100.0, -np.inf], jnp.float32)  # impossible range
    hi = jnp.asarray([200.0, np.inf], jnp.float32)
    act = jnp.asarray([True, False])
    s, i, v = ops.masked_topk(q, vecs, scal, lo, hi, act, k=5)
    assert (np.asarray(i) == -1).all()
    assert not np.asarray(v).any()


def test_underfilled_blocks_no_phantom_ids():
    """Fewer than k qualifying rows across MANY blocks: the cross-block
    merge sees (nb·k) pool slots of which only a handful are real, and its
    ``lax.top_k`` pulls NEG-score padding slots into the result. Those must
    surface as valid=False / id -1 / score NEG — never as phantom rows —
    and the real rows must all be present and flagged valid."""
    rng = np.random.default_rng(7)
    n, d, k = 400, 16, 8
    vecs = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    scal = jnp.asarray(rng.uniform(0, 10, (n, 1)), jnp.float32)
    # exactly 3 qualifying rows, spread across different 64-row blocks
    qual_rows = [5, 130, 333]
    scal = scal.at[jnp.asarray(qual_rows), 0].set(50.0)
    lo = jnp.asarray([49.0], jnp.float32)
    hi = jnp.asarray([51.0], jnp.float32)
    act = jnp.asarray([True])
    q = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    s, i, v = ops.masked_topk(q, vecs, scal, lo, hi, act, k=k, block_rows=64)
    s, i, v = np.asarray(s), np.asarray(i), np.asarray(v)
    assert v.sum() == len(qual_rows)
    assert set(i[v].tolist()) == set(qual_rows)
    assert (i[~v] == -1).all()
    assert (s[~v] <= ops.NEG / 2).all()
    # same contract on the quantized path
    qv, sc = ops.quantize_rows(vecs)
    s8, i8, v8 = ops.int8_masked_topk(q, qv, sc, scal, lo, hi, act, k=k,
                                      block_rows=64)
    assert np.asarray(v8).sum() == len(qual_rows)
    assert set(np.asarray(i8)[np.asarray(v8)].tolist()) == set(qual_rows)
