"""The scan-aware HLO analyzer: trip-count multiplication and dot flops."""

import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_plain_matmul_flops():
    def f(a, b):
        return a @ b

    comp = _compile(f, jnp.zeros((64, 128)), jnp.zeros((128, 32)))
    r = hlo_analysis.analyze(comp.as_text())
    expect = 2 * 64 * 128 * 32
    assert abs(r["flops"] - expect) / expect < 0.05


def test_scan_multiplies_body_flops():
    w = jnp.zeros((32, 32))

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    comp = _compile(f, jnp.zeros((8, 32)))
    r = hlo_analysis.analyze(comp.as_text())
    expect = 10 * 2 * 8 * 32 * 32  # 10 trips
    assert abs(r["flops"] - expect) / expect < 0.1, r["flops"]
    assert r["unknown_trip_whiles"] == 0


def test_nested_scan_multiplies_twice():
    w = jnp.zeros((16, 16))

    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None

            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None

        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    comp = _compile(f, jnp.zeros((4, 16)))
    r = hlo_analysis.analyze(comp.as_text())
    expect = 3 * 4 * 2 * 4 * 16 * 16
    assert abs(r["flops"] - expect) / expect < 0.1, r["flops"]


def test_bytes_nonzero_and_scaled_by_trips():
    def f1(x):
        return x + 1.0

    def f10(x):
        def body(c, _):
            return c + 1.0, None

        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    r1 = hlo_analysis.analyze(_compile(f1, jnp.zeros((1024,))).as_text())
    r10 = hlo_analysis.analyze(_compile(f10, jnp.zeros((1024,))).as_text())
    assert r1["bytes"] > 0
    assert r10["bytes"] > 5 * r1["bytes"]  # ~10x modulo loop overhead
