"""Predicate-aware proximity graph index: recall floors, kernel parity,
and the budget-matched hard-stratum acceptance (ISSUE 10 tentpole).

The hard stratum is built from a v->s dataset whose ``cluster_id`` scalar
IS the k-means cluster of the vector, so an equality predicate selects one
geometric region; placing the query near a DIFFERENT cluster makes every
IVF probe land on disqualified rows while the graph's split beam (raw-score
navigators + qualifying slots) routes through the disqualified region and
its predicate-qualifying entry seeds give the qualifying half of the beam
a foothold inside the selected region to climb from.
The acceptance pins graph recall >= IVF recall at EQUAL scan budget
(IVF ``max_scan`` = the graph's mean visited count).
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from oracle import NEG, brute_force_topk, similarity_np, tie_aware_recall
from repro.bench import datasets
from repro.bench.queries import gen_dnf_workload
from repro.core.query import ExecutionPlan, SubqueryParams
from repro.vectordb import graph, ivf
from repro.vectordb.predicates import Predicates, stack

K = 10


# ---------------------------------------------------------------------------
# shared small fixtures (sift = v->s: scalars derived from vector geometry)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=["dot", "l2"])
def sift_fixture(request):
    metric = request.param
    table = datasets.make("sift", rows=2000, seed=0, metric=metric)
    g = graph.build(table.vectors[0], 16, metric=metric)
    iv = ivf.build(table.vectors[0], n_clusters=16, metric=metric)
    return metric, table, g, iv


def _hard_stratum_cases(table, n_cases: int, seed: int):
    """(cluster_id, query) pairs with the query near a row of a DIFFERENT
    cluster than the one the predicate selects — the anti-correlated
    stratum where index-first probing finds only disqualified rows."""
    clu = np.asarray(table.scalars)[:, 0].astype(int)
    counts = np.bincount(clu)
    good = [c for c in range(counts.shape[0]) if counts[c] >= 2 * K]
    rng = np.random.default_rng(seed)
    vecs = np.asarray(table.vectors[0])
    cases = []
    for _ in range(n_cases):
        c = int(rng.choice(good))
        r = int(rng.choice(np.where(clu != c)[0]))
        q = (vecs[r] + rng.normal(0, 0.02, vecs.shape[1])).astype(np.float32)
        cases.append((c, q))
    return cases


def _masked_cluster_scores(table, q, c, metric):
    clu = np.asarray(table.scalars)[:, 0].astype(int)
    tot = similarity_np(q, np.asarray(table.vectors[0]), metric)
    return np.where(clu == c, tot, NEG)


# ---------------------------------------------------------------------------
# structure invariants
# ---------------------------------------------------------------------------

def test_build_structure(sift_fixture):
    metric, table, g, _ = sift_fixture
    n = int(np.asarray(table.vectors[0]).shape[0])
    assert g.neighbors.shape == (n, 16)
    assert g.metric == metric
    nb = np.asarray(g.neighbors)
    valid = nb >= 0
    assert valid.sum() > 0
    assert nb[valid].max() < n
    # no self-loops
    rows = np.broadcast_to(np.arange(n)[:, None], nb.shape)
    assert not np.any((nb == rows) & valid)
    ep = np.asarray(g.entry_points)
    assert ep.shape[0] == graph.GRAPH_ENTRY_POINTS
    assert ((ep >= 0) & (ep < n)).all()


def _reachable_from_entries(g) -> np.ndarray:
    nb = np.asarray(g.neighbors)
    reach = np.zeros(nb.shape[0], bool)
    reach[np.asarray(g.entry_points)] = True
    frontier = np.where(reach)[0]
    while frontier.size:
        nxt = nb[frontier].reshape(-1)
        nxt = np.unique(nxt[nxt >= 0])
        nxt = nxt[~reach[nxt]]
        reach[nxt] = True
        frontier = nxt
    return reach


def test_build_fully_reachable(sift_fixture):
    """The repair pass makes (almost) every row walkable from the entry
    points — without it the pure-kNN prune fragments clustered data into
    islands the beam can never leave."""
    _, table, g, _ = sift_fixture
    reach = _reachable_from_entries(g)
    assert reach.mean() >= 0.99, reach.sum()


def test_extend_appends_and_reaches_new_rows(sift_fixture):
    metric, table, g0, _ = sift_fixture
    vecs = np.asarray(table.vectors[0])
    n0 = 1700
    base = graph.build(jnp.asarray(vecs[:n0]), 16, metric=metric)
    ext = graph.extend(base, jnp.asarray(vecs), n0)
    assert ext.neighbors.shape == (vecs.shape[0], 16)
    # structural: appended rows got spliced into the sealed graph
    reach = _reachable_from_entries(ext)
    assert reach[n0:].mean() >= 0.95, reach[n0:].sum()
    # functional: querying WITH a new row's vector keeps oracle recall
    # (note: under dot the row itself need not be in its own top-k — a
    # higher-norm aligned vector can out-score |q|^2 — so recall against
    # the exact landscape is the right criterion, not a self-hit)
    pred = Predicates.none(table.scalars.shape[1])
    recs = []
    for r in range(n0, n0 + 12):
        ids, _, _, _ = graph.search(
            ext, jnp.asarray(vecs), table.scalars, pred,
            jnp.asarray(vecs[r]), beam_width=16, n_hops=8, k=K)
        m = similarity_np(vecs[r], vecs, metric)
        recs.append(tie_aware_recall(np.asarray(ids), m, K))
    assert np.mean(recs) >= 0.4, recs


# ---------------------------------------------------------------------------
# kernel parity: Pallas extraction (interpret mode) vs pure-jnp reference
# ---------------------------------------------------------------------------

def test_beam_search_kernel_parity(sift_fixture):
    metric, table, g, _ = sift_fixture
    rng = np.random.default_rng(3)
    vecs = np.asarray(table.vectors[0])
    q_b = jnp.asarray(vecs[rng.choice(vecs.shape[0], 4, replace=False)]
                      + rng.normal(0, 0.02, (4, vecs.shape[1])).astype(np.float32))
    preds = [
        Predicates.none(3),
        Predicates.from_conditions(3, {0: (0.0, 7.0)}),
        Predicates.from_conditions(3, {2: (0.0, float(np.median(np.asarray(table.scalars)[:, 2])))}),
        Predicates.from_conditions(3, {1: (0.0, 8.0)}),
    ]
    pred_b = stack(preds)
    ids_j, sc_j, nv_j, nq_j = graph.search_local_batch(
        g, table.vectors[0], table.scalars, pred_b, q_b,
        beam_width=8, n_hops=4, k=K, use_kernel=False)
    ids_k, sc_k, nv_k, nq_k = graph.search_local_batch(
        g, table.vectors[0], table.scalars, pred_b, q_b,
        beam_width=8, n_hops=4, k=K, use_kernel=True, interpret=True)
    assert np.array_equal(np.asarray(ids_j), np.asarray(ids_k))
    np.testing.assert_allclose(np.asarray(sc_j), np.asarray(sc_k),
                               rtol=1e-5, atol=1e-4)
    assert np.array_equal(np.asarray(nv_j), np.asarray(nv_k))
    assert np.array_equal(np.asarray(nq_j), np.asarray(nq_k))


# ---------------------------------------------------------------------------
# oracle recall floors
# ---------------------------------------------------------------------------

def test_single_column_filtered_recall(sift_fixture):
    """Moderate-selectivity range filter on the geometry-derived num column:
    graph search keeps tie-aware oracle recall on both metrics."""
    metric, table, g, _ = sift_fixture
    scal = np.asarray(table.scalars)
    lo, hi = np.quantile(scal[:, 2], [0.25, 0.75])
    pred = Predicates.from_conditions(3, {2: (float(lo), float(hi))})
    mask = (scal[:, 2] >= lo) & (scal[:, 2] <= hi)
    rng = np.random.default_rng(11)
    vecs = np.asarray(table.vectors[0])
    recs = []
    for r in rng.choice(vecs.shape[0], 10, replace=False):
        q = (vecs[r] + rng.normal(0, 0.02, vecs.shape[1])).astype(np.float32)
        ids, _, _, _ = graph.search(g, table.vectors[0], table.scalars, pred,
                                    jnp.asarray(q), beam_width=16, n_hops=8,
                                    k=K)
        masked = np.where(mask, similarity_np(q, vecs, metric), NEG)
        recs.append(tie_aware_recall(np.asarray(ids), masked, K))
    # dot floors lower: greedy max-inner-product routing is hub-prone
    # (the walk parks on high-norm rows), a known MIPS-graph gap — see
    # docs/graph_index.md
    floor = 0.45 if metric == "dot" else 0.7
    assert np.mean(recs) >= floor, recs


@pytest.mark.parametrize("n_clauses", [1, 2, 4])
def test_graph_plan_recall_floor_clause_buckets(fitted, n_clauses):
    """End-to-end forced-graph plans on the fitted fixture: weighted
    multi-column DNF recall per clause bucket stays above the floor."""
    bq, _ = fitted
    table = bq.table
    wl = gen_dnf_workload(table, 8, n_vec_used=2, seed=100 + n_clauses,
                          clause_counts=(n_clauses,))
    recs = []
    for q in wl:
        subs = tuple(SubqueryParams(k_mult=8, iterative=False)
                     for _ in range(q.n_vec))
        plan = bq.executor.legalize(
            ExecutionPlan("graph", subs, beam_width=16, n_hops=8))
        assert plan.strategy == "graph"
        ids, _ = bq.executor.execute(q, plan)
        _, _, masked = brute_force_topk(
            table, q.query_vectors, q.weights, q.predicates, q.k)
        recs.append(tie_aware_recall(np.asarray(ids), masked, q.k))
    assert np.mean(recs) >= 0.65, recs


# ---------------------------------------------------------------------------
# budget-matched hard-stratum acceptance
# ---------------------------------------------------------------------------

def test_hard_stratum_graph_beats_ivf_at_equal_budget(sift_fixture):
    metric, table, g, iv = sift_fixture
    cases = _hard_stratum_cases(table, 16, seed=5)
    n = np.asarray(table.vectors[0]).shape[0]
    g_rec, g_vis = [], []
    for c, q in cases:
        pred = Predicates.from_conditions(3, {0: (float(c), float(c))})
        ids, _, nvis, _ = graph.search(
            g, table.vectors[0], table.scalars, pred, jnp.asarray(q),
            beam_width=16, n_hops=8, k=K)
        g_rec.append(tie_aware_recall(
            np.asarray(ids), _masked_cluster_scores(table, q, c, metric), K))
        g_vis.append(int(nvis))
    budget = int(np.mean(g_vis))
    # IVF at the same scan budget, nprobe rounded UP so IVF is never
    # budget-starved relative to the graph
    npb = max(2, -(-budget // (n // 16)))
    i_rec = []
    for c, q in cases:
        pred = Predicates.from_conditions(3, {0: (float(c), float(c))})
        ids, _, _, _ = ivf.search(iv, table.vectors[0], table.scalars, pred,
                                  jnp.asarray(q), nprobe=npb,
                                  max_scan=budget, k=K)
        i_rec.append(tie_aware_recall(
            np.asarray(ids), _masked_cluster_scores(table, q, c, metric), K))
    g_mean, i_mean = float(np.mean(g_rec)), float(np.mean(i_rec))
    assert g_mean >= i_mean + 0.1, (g_mean, i_mean, budget)
    assert g_mean >= 0.15, (g_mean, budget)
