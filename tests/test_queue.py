"""Deadline-aware batch formation under an injected fake clock, plus the
asyncio serving engine end-to-end (real clock, tiny table)."""
import asyncio

import numpy as np

from repro.bench import datasets, queries
from repro.core.boomhq import BoomHQ, BoomHQConfig
from repro.core.rewriter import RewriterConfig
import pytest

from repro.serve.queue import (
    FAILED, OK, TIMED_OUT, AsyncServingEngine, BatchFormer, serve_stream,
)
from repro.vectordb import flat


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        self.now += dt
        return self.now


def _former(**kw) -> tuple[BatchFormer, FakeClock]:
    clock = FakeClock()
    kw.setdefault("batch_size", 4)
    kw.setdefault("max_wait", 1.0)
    return BatchFormer(clock=clock, **kw), clock


def test_cut_on_full_preserves_fifo():
    f, clock = _former()
    reqs = [f.submit(f"q{i}") for i in range(5)]
    batch, expired = f.poll()
    assert expired == []
    assert [r.seq for r in batch] == [0, 1, 2, 3]  # FIFO, oldest first
    assert [r.query for r in batch] == ["q0", "q1", "q2", "q3"]
    # the 5th request is not yet aged — no second cut at the same instant
    batch2, _ = f.poll()
    assert batch2 is None and len(f) == 1
    assert reqs[4].status == "pending"


def test_cut_on_age():
    f, clock = _former(batch_size=8, max_wait=0.5)
    f.submit("a")
    clock.advance(0.2)
    f.submit("b")
    assert f.poll()[0] is None  # oldest age 0.2 < 0.5, queue not full
    clock.advance(0.31)  # oldest now 0.51 >= max_wait
    batch, _ = f.poll()
    assert [r.query for r in batch] == ["a", "b"]  # underfull but aged out


def test_expired_reported_and_never_executed():
    f, clock = _former(batch_size=2, max_wait=10.0)
    doomed = f.submit("doomed", timeout=0.5)
    clock.advance(1.0)
    ok = f.submit("ok")  # arrives after the deadline passed
    batch, expired = f.poll()
    assert expired == [doomed]
    assert doomed.status == TIMED_OUT and doomed.result is None
    assert doomed.done == clock.now and doomed.latency == 1.0
    # the expired request freed its slot: no cut-on-full, no stale entry
    assert batch is None and len(f) == 1
    clock.advance(10.0)
    batch, expired = f.poll()
    assert expired == [] and [r.seq for r in batch] == [ok.seq]


def test_expiry_wins_over_formation():
    """A request whose deadline has passed never enters a batch, even when
    the queue is full enough to cut at the same poll."""
    f, clock = _former(batch_size=2, max_wait=10.0)
    a = f.submit("a", timeout=0.1)
    f.submit("b")
    f.submit("c")
    clock.advance(0.2)
    batch, expired = f.poll()
    assert expired == [a]
    assert [r.query for r in batch] == ["b", "c"]


def test_deadline_exactly_at_poll_still_serves():
    """now == deadline is NOT expired (strict >): a budget of exactly the
    queue wait still executes."""
    f, clock = _former(batch_size=8, max_wait=0.5)
    r = f.submit("edge", timeout=0.5)
    clock.advance(0.5)
    batch, expired = f.poll()
    assert expired == [] and batch == [r]


def test_next_event_schedules_earliest_of_age_and_deadline():
    f, clock = _former(batch_size=8, max_wait=1.0)
    assert f.next_event() is None
    f.submit("a")  # cut-on-age instant: 1.0
    assert f.next_event() == 1.0
    f.submit("b", timeout=0.25)  # deadline 0.25 is sooner
    assert f.next_event() == 0.25
    clock.advance(2.0)
    f.poll()
    assert f.next_event() is None  # drained


def test_flush_forces_underfull_unaged_batch():
    f, clock = _former(batch_size=8, max_wait=100.0)
    f.submit("a")
    f.submit("b")
    assert f.poll()[0] is None
    batch, _ = f.poll(flush=True)
    assert [r.query for r in batch] == ["a", "b"]


# ---------------------------------------------------------------------------
# asyncio engine end-to-end
# ---------------------------------------------------------------------------

def _tiny_bq():
    table = datasets.make("part", rows=900, seed=4)
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=8, use_de=False,
        rewriter=RewriterConfig(steps=10, refine_columns=False)))
    return table, bq


def test_async_engine_serves_stream():
    from repro.serve.batch import DENSE, CostModel

    table, bq = _tiny_bq()
    # pin the exact sharded scan: this test asserts ground-truth scores,
    # and the default cost model would route this tiny table's index
    # groups through the (approximate) single-device learned path
    bq.bind_shards(3).bind_cost_model(CostModel(force=DENSE))
    wl = queries.gen_workload(table, 8, n_vec_used=2, seed=11)

    async def main():
        eng = AsyncServingEngine(bq, batch_size=3, max_wait=0.01)
        reqs = await serve_stream(eng, wl)
        return eng, reqs

    eng, reqs = asyncio.run(main())
    assert [r.query for r in reqs] == wl  # submission order preserved
    assert all(r.status == OK for r in reqs)
    for r in reqs:
        q = r.query
        gt_ids, gt_s = flat.ground_truth(table, list(q.query_vectors),
                                         list(q.weights), q.predicates, q.k)
        ids, scores = r.result
        np.testing.assert_allclose(np.asarray(scores), np.asarray(gt_s),
                                   atol=1e-4, rtol=1e-5)
    rep = eng.report()
    assert rep.n_queries == len(wl) and rep.n_timed_out == 0
    assert rep.qps > 0 and rep.p50_ms is not None and rep.p99_ms >= rep.p50_ms
    assert "p50" in rep.describe()


def test_async_engine_survives_execution_failure():
    """A raising execute_batch fails ITS requests (submit re-raises) but
    must not kill the drainer — later requests still get served."""
    table, bq = _tiny_bq()
    wl = queries.gen_workload(table, 2, n_vec_used=2, seed=13)
    state = {"calls": 0}

    class Flaky:
        def execute_batch(self, qs):
            state["calls"] += 1
            if state["calls"] == 1:
                raise RuntimeError("boom")
            return bq.execute_batch(qs)

    async def main():
        eng = AsyncServingEngine(Flaky(), batch_size=1, max_wait=0.0)
        async with eng:
            with pytest.raises(RuntimeError, match="boom"):
                await eng.submit(wl[0])
            ok = await eng.submit(wl[1])
        return eng, ok

    eng, ok = asyncio.run(main())
    assert ok.status == OK and ok.result is not None
    served = sorted(eng._served, key=lambda r: r.seq)
    assert [r.status for r in served] == [FAILED, OK]
    assert eng.report().n_timed_out == 0


def test_async_engine_stop_noflush_fails_inflight():
    """stop(flush=False) mid-execution must not strand the in-flight
    batch's submit() callers — they resolve with a cancellation instead of
    hanging forever."""
    import time as _time

    class Slow:
        def execute_batch(self, qs):
            _time.sleep(0.4)
            return [(np.asarray([0]), np.asarray([0.0]))] * len(qs)

    async def main():
        eng = AsyncServingEngine(Slow(), batch_size=1, max_wait=0.0)
        await eng.start()
        task = asyncio.ensure_future(eng.submit("q"))
        await asyncio.sleep(0.1)  # batch formed and executing in the worker
        # a second request that never forms a batch (the drainer is busy
        # and stop() won't flush) must also resolve, not hang
        eng.former.batch_size = 99
        queued = asyncio.ensure_future(eng.submit("q2"))
        await asyncio.sleep(0)
        await eng.stop(flush=False)
        with pytest.raises(asyncio.CancelledError):
            await asyncio.wait_for(task, timeout=2.0)
        with pytest.raises(asyncio.CancelledError):
            await asyncio.wait_for(queued, timeout=2.0)
        return eng

    eng = asyncio.run(main())
    assert sorted(r.status for r in eng._served) == [FAILED, FAILED]


def test_deadline_between_cut_and_dispatch_times_out():
    """Regression: deadline enforcement must NOT stop at cut time. A request
    whose deadline lands between poll() (batch formed) and _execute
    (dispatch) resolves timed_out and is dropped from the executed batch —
    it used to execute anyway and report OK."""
    clock = FakeClock()
    executed = []

    class Recorder:
        def execute_batch(self, qs):
            executed.extend(qs)
            return [(np.asarray([0]), np.asarray([0.0]))] * len(qs)

    async def main():
        eng = AsyncServingEngine(Recorder(), batch_size=2, max_wait=0.0,
                                 clock=clock)
        await eng.start()
        eng.former.submit("doomed", timeout=0.5)
        eng.former.submit("survivor", timeout=5.0)
        batch, expired = eng.former.poll()  # cut at t=0: nothing expired
        assert expired == [] and len(batch) == 2
        # the deadline passes AFTER the cut, BEFORE dispatch (e.g. the
        # batch sat behind an in-flight one)
        clock.advance(1.0)
        await eng._execute(batch)
        await eng.stop(flush=False)
        return eng, batch

    eng, (doomed, survivor) = asyncio.run(main())
    assert doomed.status == TIMED_OUT and doomed.result is None
    assert doomed.done == 1.0
    assert survivor.status == OK and survivor.result is not None
    assert executed == ["survivor"]  # the expired request never executed
    rep = eng.report()
    assert rep.n_timed_out == 1 and rep.n_queries >= 2


def test_dispatch_expiry_keeps_exact_deadline_serving():
    """now == deadline at dispatch still executes (same strict > rule as
    queue-side expiry), and an all-expired batch executes nothing."""
    clock = FakeClock()
    executed = []

    class Recorder:
        def execute_batch(self, qs):
            executed.extend(qs)
            return [(np.asarray([0]), np.asarray([0.0]))] * len(qs)

    async def main():
        eng = AsyncServingEngine(Recorder(), batch_size=2, max_wait=0.0,
                                 clock=clock)
        await eng.start()
        edge = eng.former.submit("edge", timeout=1.0)
        batch, _ = eng.former.poll(flush=True)
        clock.advance(1.0)  # exactly at the deadline
        await eng._execute(batch)
        dead = eng.former.submit("dead", timeout=0.1)
        batch, _ = eng.former.poll(flush=True)
        clock.advance(1.0)
        await eng._execute(batch)  # whole batch expired: no executor call
        await eng.stop(flush=False)
        return edge, dead

    edge, dead = asyncio.run(main())
    assert edge.status == OK and executed == ["edge"]
    assert dead.status == TIMED_OUT


def test_async_engine_timeout_disposition():
    _, bq = _tiny_bq()

    async def main():
        eng = AsyncServingEngine(bq, batch_size=64, max_wait=0.2)
        async with eng:
            r = await eng.submit("never-executed-query", timeout=0.0)
        return eng, r

    eng, r = asyncio.run(main())
    # a zero budget expires before any batch cuts — and is never executed,
    # which is also why a non-MHQ placeholder query cannot crash the engine
    assert r.status == TIMED_OUT and r.result is None
    rep = eng.report()
    assert rep.n_timed_out == 1 and rep.p50_ms is None
