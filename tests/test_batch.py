"""Batched serving subsystem: sequential/batched/cross-shard parity, counter
semantics, linear IVF inserts, and the single rewriter decode path."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.bench import datasets, queries
from repro.core.boomhq import BoomHQ, BoomHQConfig
from repro.core.executor import HybridExecutor, plan_columns, recall_at_k
from repro.core.query import ExecutionPlan, SubqueryParams, default_plan
from repro.core.rewriter import MHQRewriter, RewriterConfig, candidate_plans
from repro.serve.batch import (
    BatchedHybridExecutor, ServingEngine, next_bucket, pow2_at_most,
)
from repro.vectordb import flat, ivf
from repro.vectordb.predicates import Predicates, clause_bucket


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_filter_first_qualified_count_uncapped(tiny_table):
    """n_qualified must be the TRUE qualifying-row count, not min(count,
    max_candidates) — escalation logic reads it."""
    t = tiny_table
    pred = Predicates.none(t.schema.n_scalar)  # everything qualifies
    cap = 64
    assert t.n_rows > cap
    w = jnp.asarray([1.0] + [0.0] * (t.schema.n_vec - 1), jnp.float32)
    _, _, n_scored, n_qual = flat.filter_first(
        tuple(t.vectors), t.scalars, pred,
        tuple(v[0] for v in t.vectors), w, t.schema.metric,
        k=5, max_candidates=cap, n_vec=t.schema.n_vec)
    assert int(n_scored) == cap  # scoring is capped by the gather width
    assert int(n_qual) == t.n_rows  # the true count is not


def _extend_reference(index, new_vectors, first_new_row):
    """The seed's per-row append semantics (quadratic), kept as the oracle."""
    d = (jnp.sum(index.centroids * index.centroids, axis=1)[None, :]
         - 2.0 * (new_vectors @ index.centroids.T))
    assign = np.asarray(jnp.argmin(d, axis=1))
    rows = np.arange(first_new_row, first_new_row + new_vectors.shape[0],
                     dtype=np.int32)
    old_rows = np.asarray(index.sorted_rows)
    old_off = np.asarray(index.offsets)
    buckets = [old_rows[old_off[c]: old_off[c + 1]]
               for c in range(index.n_clusters)]
    for r, a in zip(rows, assign):
        buckets[a] = np.append(buckets[a], r)
    counts = np.array([len(b) for b in buckets])
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return np.concatenate(buckets).astype(np.int32), offsets


def test_ivf_extend_matches_seed_semantics(rng):
    vecs = jnp.asarray(rng.normal(size=(800, 16)), jnp.float32)
    index = ivf.build(vecs, 12, seed=0)
    new = jnp.asarray(rng.normal(size=(137, 16)), jnp.float32)
    ref_rows, ref_off = _extend_reference(index, new, 800)
    ext = ivf.extend(index, new, 800)
    np.testing.assert_array_equal(np.asarray(ext.sorted_rows), ref_rows)
    np.testing.assert_array_equal(np.asarray(ext.offsets), ref_off)


def test_ivf_extend_empty_clusters(rng):
    """Regroup must survive clusters that own zero rows."""
    vecs = jnp.asarray(rng.normal(size=(30, 8)) + 5.0, jnp.float32)
    index = ivf.build(vecs, 8, seed=1)
    new = jnp.asarray(rng.normal(size=(4, 8)) + 5.0, jnp.float32)
    ext = ivf.extend(index, new, 30)
    ref_rows, ref_off = _extend_reference(index, new, 30)
    np.testing.assert_array_equal(np.asarray(ext.sorted_rows), ref_rows)
    np.testing.assert_array_equal(np.asarray(ext.offsets), ref_off)
    assert sorted(np.asarray(ext.sorted_rows).tolist()) == list(range(34))


def test_rrf_extras_fuses_and_excludes():
    """Unit semantics of the RRF fusion kernel: contributions of a row's
    occurrences across columns SUM (dedup), rows already inside a column's
    top-k_i block are excluded from the extras, and the output is ranked
    best-fused first with -1 padding."""
    from repro.core.executor import rrf_extras

    # col A ranking: [10 11 | 20 21 30]   (k_i = 2, tail after |)
    # col B ranking: [12 13 | 21 20 -1]
    a = jnp.asarray([[10, 11, 20, 21, 30]])
    b = jnp.asarray([[12, 13, 21, 20, -1]])
    ex = np.asarray(rrf_extras((a, b), kis=(2, 2), n_extra=4))
    # 20: 1/63 + 1/64;  21: 1/64 + 1/63  (tie, id-order breaks it)
    # 30: 1/65 single-column;  included rows 10..13 must not appear
    assert ex.tolist() == [[20, 21, 30, -1]]

    # a two-column row beats a better-single-rank row when combined:
    # 40 at tail ranks (3, 3) vs 50 at tail rank 3 in one column only
    a2 = jnp.asarray([[1, 2, 40, 50]])
    b2 = jnp.asarray([[3, 4, 40, -1]])
    ex2 = np.asarray(rrf_extras((a2, b2), kis=(2, 2), n_extra=2))
    assert ex2.tolist() == [[40, 50]]


def _skew_weight_fixture():
    """A fixture where the global weighted top-k provably needs rows that
    rank BELOW top-k_i in every column: 'generalist' rows sit at per-column
    ranks 11-14 (k_i = 10) in both columns, but their weighted score beats
    every single-column specialist."""
    from repro.vectordb.table import ScalarCol, Table, TableSchema, VectorCol

    rng = np.random.default_rng(17)
    n, d, m, k = 200, 8, 2, 10
    va = rng.normal(size=(n, d)).astype(np.float32) * 0.01
    vb = rng.normal(size=(n, d)).astype(np.float32) * 0.01
    for j in range(10):   # specialists: top-10 of exactly one column
        va[j, 0] = 10.0 - 0.05 * j
        vb[10 + j, 0] = 10.0 - 0.05 * j
    for j in range(4):    # generalists: rank 11-14 in BOTH columns
        va[20 + j, 0] = 8.5 - 0.01 * j
        vb[20 + j, 0] = 8.5 - 0.01 * j
    schema = TableSchema(
        vector_cols=(VectorCol("v0", d), VectorCol("v1", d)),
        scalar_cols=tuple(ScalarCol(f"s{i}", "num") for i in range(m)))
    t = Table.from_numpy(
        schema, [va, vb], rng.uniform(0, 1, (n, m)).astype(np.float32))
    qa = np.zeros(d, np.float32)
    qa[0] = 1.0
    from repro.core.query import MHQ

    q = MHQ(query_vectors=(jnp.asarray(qa), jnp.asarray(qa)),
            weights=(0.7, 0.3), predicates=Predicates.none(m), k=k)
    w_scores = 0.7 * (va @ qa) + 0.3 * (vb @ qa)
    oracle = set(np.argsort(-w_scores)[:k].tolist())
    # fixture validity: some oracle rows are outside BOTH per-column top-k_i
    top_a = set(np.argsort(-(va @ qa))[:k].tolist())
    top_b = set(np.argsort(-(vb @ qa))[:k].tolist())
    missed = oracle - top_a - top_b
    assert missed == {20, 21, 22, 23}
    return t, q, oracle


def test_rrf_fusion_skew_weight_oracle_floor():
    """Satellite regression: on weight-skewed queries a global top-k row can
    rank below top-k_i in every column, so the truncated per-column union
    loses it no matter how exact the rerank is (recall capped at 0.6 on this
    fixture). RRF (k=60) fusion over the probed tails must recover the
    full oracle top-k — in the batched index_scan path AND the sequential
    executor (parity: both build the same union)."""
    t, q, oracle = _skew_weight_fixture()
    idx = [ivf.build(v, 8, seed=i, metric=t.schema.metric)
           for i, v in enumerate(t.vectors)]
    plan = ExecutionPlan("index_scan", tuple(
        SubqueryParams(k_mult=1, nprobe=8, max_scan=256, iterative=False)
        for _ in range(2)))

    (ids_b, scores_b), = BatchedHybridExecutor(t, idx).execute_batch(
        [q], [plan])
    got_b = set(int(i) for i in np.asarray(ids_b) if i >= 0)
    assert len(got_b & oracle) == q.k, (
        f"batched union missed {sorted(oracle - got_b)} — RRF extras did "
        f"not recover the cross-column rows")

    ids_s, scores_s = HybridExecutor(t, idx).execute(q, plan)
    got_s = set(int(i) for i in np.asarray(ids_s) if i >= 0)
    assert len(got_s & oracle) == q.k
    assert_results_match(ids_s, scores_s, ids_b, scores_b)


def test_predict_delegates_to_plan_codes(rng):
    """predict() and plan_codes->plan_from_codes are one decode path: both
    must produce the same ExecutionPlan on random inputs."""
    in_dim, n_vec = 24, 2
    rew = MHQRewriter(in_dim, n_vec, RewriterConfig(seed=3))
    for i in range(8):
        x = rng.normal(size=(in_dim,)).astype(np.float32)
        via_predict = rew.predict(x)
        codes = np.asarray(rew.plan_codes(rew.params, jnp.asarray(x)))
        via_codes = rew.plan_from_codes(codes)
        assert via_predict == via_codes


# ---------------------------------------------------------------------------
# batched executor parity
# ---------------------------------------------------------------------------

def assert_results_match(ids_s, scores_s, ids_b, scores_b, *, atol=1e-4):
    """Per-query parity up to float reduction order: scores must agree to
    tolerance everywhere, and any position where the ids differ must be a
    float-tie (both candidates' scores equal within atol) — the batched
    path scores via GEMM, the sequential one via gathered matvec, so the
    last ulp may order near-exact ties differently."""
    ids_s, scores_s = np.asarray(ids_s), np.asarray(scores_s)
    ids_b, scores_b = np.asarray(ids_b), np.asarray(scores_b)
    np.testing.assert_allclose(scores_b, scores_s, atol=atol, rtol=1e-5)
    diff = ids_s != ids_b
    if np.any(diff):
        np.testing.assert_allclose(scores_b[diff], scores_s[diff], atol=atol,
                                   err_msg="ids differ on non-tied scores")


def test_bucket_helpers():
    assert [next_bucket(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert next_bucket(3, 16) == 16
    assert [pow2_at_most(n) for n in (1, 2, 3, 7, 8, 100)] == [1, 2, 2, 4, 8, 64]


@pytest.fixture(scope="module")
def exec_setup(tiny_table):
    t = tiny_table
    idx = [ivf.build(v, 16, seed=i, metric=t.schema.metric)
           for i, v in enumerate(t.vectors)]
    return t, HybridExecutor(t, idx), BatchedHybridExecutor(t, idx)


def test_batched_executor_parity_all_strategies(exec_setup):
    """Same workload through the sequential loop and the batched path ->
    identical ids and scores per query, across every strategy (incl. the
    iterative re-expansion path) and mixed group sizes."""
    t, seq, bx = exec_setup
    wl = queries.gen_workload(t, 10, n_vec_used=2, seed=3) + \
        queries.gen_workload(t, 5, n_vec_used=1, seed=4)
    grid = candidate_plans(2, weights=(0.9, 0.1)) + [default_plan(2)]
    plans = [grid[j % len(grid)] for j in range(len(wl))]
    batched = bx.execute_batch(wl, plans)
    for q, p, (ids_b, scores_b) in zip(wl, plans, batched):
        ids_s, scores_s = seq.execute(q, p)
        assert_results_match(ids_s, scores_s, ids_b, scores_b)


def test_batched_executor_filter_first_group(exec_setup):
    t, seq, bx = exec_setup
    wl = queries.gen_workload(t, 6, n_vec_used=2, seed=5)
    plan = ExecutionPlan("filter_first",
                         tuple(SubqueryParams() for _ in range(2)),
                         max_candidates=512)
    batched = bx.execute_batch(wl, [plan] * len(wl))
    for q, (ids_b, scores_b) in zip(wl, batched):
        ids_s, scores_s = seq.execute(q, plan)
        assert_results_match(ids_s, scores_s, ids_b, scores_b)


def test_batched_executor_parity_mixed_clause_counts(exec_setup):
    """Satellite: batched vs sequential on a batch mixing conjunctive (C=1)
    and DNF (C∈{2,4}) predicates — groups split per clause bucket, every
    query's result must still match the sequential executor."""
    t, seq, bx = exec_setup
    wl = queries.gen_dnf_workload(t, 8, n_vec_used=2, seed=11,
                                  clause_counts=(2, 3, 4)) + \
        queries.gen_workload(t, 4, n_vec_used=2, seed=12)
    buckets = {clause_bucket(q.predicates) for q in wl}
    assert len(buckets) >= 2  # genuinely mixed complexity
    grid = candidate_plans(2, weights=(0.7, 0.3)) + [default_plan(2)]
    plans = [grid[j % len(grid)] for j in range(len(wl))]
    batched = bx.execute_batch(wl, plans)
    for q, p, (ids_b, scores_b) in zip(wl, plans, batched):
        ids_s, scores_s = seq.execute(q, p)
        assert_results_match(ids_s, scores_s, ids_b, scores_b)


# ---------------------------------------------------------------------------
# three-way parity: sequential vs batched vs cross-shard
# ---------------------------------------------------------------------------

def _assert_three_way(t, seq, bx, wl, *, shard_counts=(2, 5)):
    """filter_first with an uncapped gather is the budget at which all three
    paths (sequential, batched, cross-shard exact scan) compute the same
    mathematical result — so parity is well-defined for ANY predicate."""
    plans = [ExecutionPlan("filter_first",
                           tuple(SubqueryParams() for _ in range(q.n_vec)),
                           max_candidates=t.n_rows) for q in wl]
    batched = bx.execute_batch(wl, plans)
    sharded = {s: BatchedHybridExecutor(t, bx.indexes, bx.engine, n_shards=s)
               .execute_batch_sharded(wl) for s in shard_counts}
    for j, (q, p) in enumerate(zip(wl, plans)):
        ids_s, scores_s = seq.execute(q, p)
        ids_b, scores_b = batched[j]
        assert_results_match(ids_s, scores_s, ids_b, scores_b)
        for s in shard_counts:
            ids_x, scores_x = sharded[s][j]
            assert_results_match(ids_s, scores_s, ids_x, scores_x)


def _mixed_wl(t, seed):
    return queries.gen_dnf_workload(t, 5, n_vec_used=2, seed=seed,
                                    clause_counts=(2, 3, 4)) + \
        queries.gen_workload(t, 3, n_vec_used=2, seed=seed + 1)


def test_three_way_parity_seed_corpus(exec_setup):
    """Deterministic sweep (always runs, hypothesis or not): sequential vs
    execute_batch vs cross-shard execute_batch agree (float-tie tolerant)
    on mixed clause-bucket batches, for a divisible (2) and a padded (7)
    shard split of the 1500-row table."""
    t, seq, bx = exec_setup
    for seed in (101, 202):
        wl = _mixed_wl(t, seed)
        assert len({clause_bucket(q.predicates) for q in wl}) >= 2
        _assert_three_way(t, seq, bx, wl, shard_counts=(2, 7))


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 50_000))
def test_three_way_parity_property(exec_setup, seed):
    """Hypothesis property sweep of the same three-way parity over random
    mixed clause-bucket workloads."""
    t, seq, bx = exec_setup
    _assert_three_way(t, seq, bx, _mixed_wl(t, seed), shard_counts=(4,))


def test_sharded_executor_mesh_wiring(exec_setup):
    """A bound 1-device mesh routes through the shard_map kernel and must
    reproduce the logical-shard reference bit-for-bit (the multi-device
    equivalence runs in tests/test_distributed.py's subprocess). The
    logical executor pins the dense path — the mesh side is always dense,
    and bit-parity is only defined against the same scoring path."""
    import jax
    from jax.sharding import Mesh

    from repro.serve.batch import DENSE, CostModel

    t, _, bx = exec_setup
    wl = _mixed_wl(t, 77)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    bx_mesh = BatchedHybridExecutor(t, bx.indexes, bx.engine, mesh=mesh)
    bx_log = BatchedHybridExecutor(t, bx.indexes, bx.engine, n_shards=1,
                                   cost_model=CostModel(force=DENSE))
    res_m = bx_mesh.execute_batch_sharded(wl)
    res_l = bx_log.execute_batch_sharded(wl)
    for (im, sm), (il, sl) in zip(res_m, res_l):
        np.testing.assert_array_equal(im, il)
        np.testing.assert_allclose(sm, sl, atol=1e-6)


def test_batched_executor_single_index_group(exec_setup):
    t, seq, bx = exec_setup
    wl = queries.gen_workload(t, 4, n_vec_used=2, seed=6)
    plan = ExecutionPlan(
        "single_index",
        tuple(SubqueryParams(k_mult=4, nprobe=8) for _ in range(2)),
        dominant=1)
    assert plan_columns(wl[0], plan) == (1,)
    batched = bx.execute_batch(wl, [plan] * len(wl))
    for q, (ids_b, scores_b) in zip(wl, batched):
        ids_s, scores_s = seq.execute(q, plan)
        assert_results_match(ids_s, scores_s, ids_b, scores_b)


# ---------------------------------------------------------------------------
# scoring dispatcher: cost-model routing, decision log, per-group crossover
# ---------------------------------------------------------------------------

def test_cost_model_choose():
    from repro.serve.batch import CANDIDATE_LOCAL, DENSE, CostModel

    cm = CostModel(crossover=1.0, overhead=0)
    assert cm.choose(batch=4, scan=100, n_rows=1000) == CANDIDATE_LOCAL
    assert cm.choose(batch=32, scan=100, n_rows=1000) == DENSE
    assert CostModel(crossover=4.0, overhead=0).choose(
        batch=32, scan=100, n_rows=1000) == CANDIDATE_LOCAL
    # the constant per-batch term adds to the candidate-local side
    assert CostModel(crossover=1.0, overhead=700).choose(
        batch=4, scan=100, n_rows=1000) == DENSE
    for force in (DENSE, CANDIDATE_LOCAL):
        assert CostModel(force=force).choose(
            batch=1, scan=1, n_rows=10**9) == force


def test_cost_model_small_batch_overhead_regression():
    """Satellite: the constant per-batch overhead term pins the dispatch
    decisions measured end-to-end on this container
    (``benchmarks/kernels_bench.py overhead_sweep`` + ``serving
    --crossover``): candidate-local serves the 500k suite at B=8 AND B=32
    (measured 1.47x / 4.39x — the stale 0.92x B=8 row did not reproduce),
    dense serves the 60k suite at both batch sizes, and near the crossover
    boundary a tiny batch now falls back to dense where the overhead-free
    model mispredicted candidate-local."""
    from repro.serve.batch import CANDIDATE_LOCAL, DENSE, CostModel

    cm = CostModel()  # the calibrated defaults
    assert cm.choose(batch=8, scan=2048, n_rows=500_000) == CANDIDATE_LOCAL
    assert cm.choose(batch=32, scan=2048, n_rows=500_000) == CANDIDATE_LOCAL
    assert cm.choose(batch=8, scan=2048, n_rows=60_000) == DENSE
    assert cm.choose(batch=32, scan=2048, n_rows=60_000) == DENSE
    # near-boundary tiny batch: the fixed per-batch cost flips it dense
    naive = CostModel(overhead=0)
    assert naive.choose(batch=1, scan=67_000,
                        n_rows=500_000) == CANDIDATE_LOCAL
    assert cm.choose(batch=1, scan=67_000, n_rows=500_000) == DENSE


def test_dispatcher_forced_paths_parity(exec_setup):
    """The two scoring paths forced via a fake cost model must produce the
    same results (float-tie tolerant) on the same workload, and every
    recorded decision must carry the forced path."""
    from repro.serve.batch import CANDIDATE_LOCAL, DENSE, CostModel

    t, seq, bx = exec_setup
    wl = queries.gen_workload(t, 8, n_vec_used=2, seed=91) + \
        queries.gen_dnf_workload(t, 4, n_vec_used=2, seed=92,
                                 clause_counts=(2, 4))
    grid = candidate_plans(2, weights=(0.8, 0.2)) + [default_plan(2)]
    plans = [grid[j % len(grid)] for j in range(len(wl))]
    results = {}
    for force in (DENSE, CANDIDATE_LOCAL):
        bxf = BatchedHybridExecutor(t, bx.indexes, bx.engine,
                                    cost_model=CostModel(force=force))
        results[force] = bxf.execute_batch(wl, plans)
        counts, decisions = bxf.dispatcher.take()
        assert set(counts) == {force}
        assert decisions and all(d["path"] == force for d in decisions)
    for (ids_d, s_d), (ids_l, s_l) in zip(results[DENSE],
                                          results[CANDIDATE_LOCAL]):
        assert_results_match(ids_d, s_d, ids_l, s_l)


def test_dispatcher_crossover_honored_per_group(exec_setup):
    """One batch, two groups with different candidate budgets: the small
    budget clears the crossover (candidate-local) while the full-table
    filter_first group does not (dense) — in the SAME execute_batch call.
    The threshold is per group, never batch-global."""
    from repro.serve.batch import CANDIDATE_LOCAL, DENSE, CostModel

    t, seq, bx = exec_setup
    wl = queries.gen_workload(t, 8, n_vec_used=2, seed=93)
    small = ExecutionPlan(
        "index_scan",
        tuple(SubqueryParams(k_mult=2, nprobe=8, max_scan=64,
                             iterative=False) for _ in range(2)))
    full = ExecutionPlan(
        "filter_first", tuple(SubqueryParams() for _ in range(2)),
        max_candidates=t.n_rows)
    plans = [small, small, small, small, full, full, full, full]
    cm = CostModel(crossover=1.0, overhead=0)
    # ix group budget is per active column ((64+64)/2): 4·64 <= 1500 ->
    # candidate-local; the full-table ff group: 4·1500 > 1500 -> dense
    assert cm.choose(batch=4, scan=64, n_rows=t.n_rows) == CANDIDATE_LOCAL
    assert cm.choose(batch=4, scan=t.n_rows, n_rows=t.n_rows) == DENSE
    bxc = BatchedHybridExecutor(t, bx.indexes, bx.engine, cost_model=cm)
    results = bxc.execute_batch(wl, plans)
    counts, decisions = bxc.dispatcher.take()
    by_group = {d["group"][0]: d["path"] for d in decisions}
    assert by_group == {"ix": CANDIDATE_LOCAL, "ff": DENSE}
    assert counts == {CANDIDATE_LOCAL: 1, DENSE: 1}
    # every decision re-derives from the cost model inputs it logged
    for d in decisions:
        assert d["path"] == cm.choose(batch=d["batch"], scan=d["scan"],
                                      n_rows=t.n_rows)
    # and both groups' results still match the sequential executor
    for q, p, (ids_b, scores_b) in zip(wl, plans, results):
        ids_s, scores_s = seq.execute(q, p)
        assert_results_match(ids_s, scores_s, ids_b, scores_b)


def test_dispatcher_sharded_chunks_route_and_match(exec_setup):
    """execute_batch_sharded routes through the dispatcher too: forcing
    each path must leave the decision log with that path and produce the
    same (exact) results."""
    from repro.serve.batch import CANDIDATE_LOCAL, DENSE, CostModel

    t, _, bx = exec_setup
    wl = _mixed_wl(t, 95)
    results = {}
    for force in (DENSE, CANDIDATE_LOCAL):
        bxf = BatchedHybridExecutor(t, bx.indexes, bx.engine, n_shards=3,
                                    cost_model=CostModel(force=force))
        results[force] = bxf.execute_batch_sharded(wl)
        counts, decisions = bxf.dispatcher.take()
        assert set(counts) == {force}
        assert all(d["group"][0] == "sharded" for d in decisions)
    for (ids_d, s_d), (ids_l, s_l) in zip(results[DENSE],
                                          results[CANDIDATE_LOCAL]):
        assert_results_match(ids_d, s_d, ids_l, s_l)


def test_serve_report_records_path_counts():
    """ServeReport surfaces the dispatcher's per-group path counts and
    describe() renders them; bind_cost_model forces the path end-to-end."""
    from repro.serve.batch import CANDIDATE_LOCAL, DENSE, CostModel

    table = datasets.make("part", rows=1200, seed=2)
    wl = queries.gen_workload(table, 8, n_vec_used=2, seed=21)
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=8, use_de=False,
        rewriter=RewriterConfig(steps=10, refine_columns=False)))
    try:
        for force in (CANDIDATE_LOCAL, DENSE):
            bq.bind_cost_model(CostModel(force=force))
            engine = ServingEngine(bq, batch_size=4)
            engine.warmup(wl)
            _, rep = engine.serve(wl)
            assert rep.path_counts and set(rep.path_counts) == {force}
            assert f"paths {force}" in rep.describe()
    finally:
        bq.bind_cost_model()


# ---------------------------------------------------------------------------
# end-to-end: batched optimizer + serving engine
# ---------------------------------------------------------------------------

def test_optimize_batch_matches_sequential(fitted):
    bq, test = fitted
    plans_seq = [bq.optimize(q) for q in test]
    plans_bat = bq.optimize_batch(test)
    assert plans_seq == plans_bat


def test_execute_batch_parity_and_recall(fitted):
    """Batched end-to-end serving returns the sequential path's exact ids
    and scores — hence zero recall regression by construction."""
    bq, test = fitted
    batched = bq.execute_batch(test)
    seq_recs, bat_recs = [], []
    for q, (ids_b, scores_b) in zip(test, batched):
        ids_s, scores_s = bq.execute(q)
        assert_results_match(ids_s, scores_s, ids_b, scores_b)
        gt, _ = flat.ground_truth(bq.table, list(q.query_vectors),
                                  list(q.weights), q.predicates, q.k)
        seq_recs.append(recall_at_k(ids_s, gt))
        bat_recs.append(recall_at_k(ids_b, gt))
    assert np.mean(bat_recs) >= np.mean(seq_recs) - 1e-3


def test_serving_engine_reports(fitted):
    bq, test = fitted
    gts = [np.asarray(flat.ground_truth(bq.table, list(q.query_vectors),
                                        list(q.weights), q.predicates,
                                        q.k)[0]) for q in test]
    engine = ServingEngine(bq, batch_size=4)
    engine.warmup(test)
    results, rep = engine.serve(test, gt_ids=gts)
    assert len(results) == len(test)
    assert rep.n_queries == len(test)
    assert rep.n_batches == (len(test) + 3) // 4
    assert rep.qps > 0
    assert rep.mean_recall is not None and 0.0 <= rep.mean_recall <= 1.0
    assert "QPS" in rep.describe()


def test_unfitted_execute_batch_uses_default_plans():
    table = datasets.make("part", rows=1200, seed=2)
    wl = queries.gen_workload(table, 3, n_vec_used=2, seed=7)
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=8, use_de=False,
        rewriter=RewriterConfig(steps=10, refine_columns=False)))
    plans = bq.optimize_batch(wl)
    assert all(p == default_plan(q.n_vec) for p, q in zip(plans, wl))
    results = bq.execute_batch(wl)
    for q, (ids, scores) in zip(wl, results):
        # parity with the sequential fallback (a query may legitimately
        # qualify fewer than k rows — e.g. an empty-selectivity predicate)
        ids_s, scores_s = bq.execute(q)
        assert_results_match(ids_s, scores_s, ids, scores)


def test_sharded_serving_engine_matches_ground_truth():
    """ServingEngine over a bind_shards-bound BoomHQ with the cost model
    pinned to the EXACT sharded scan: every served result is the exact
    filtered top-k, and bind_shards() restores single-shard serving. (The
    default cost model routes index groups three ways — per-shard IVF /
    exact scan / single-device — so exactness is only a contract of the
    dense-forced configuration; the learned routes are floored against the
    oracle in tests/test_oracle.py and tests/test_sharded_ivf.py.)"""
    from repro.serve.batch import DENSE, CostModel

    table = datasets.make("part", rows=1200, seed=2)
    wl = queries.gen_workload(table, 6, n_vec_used=2, seed=9)
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=8, use_de=False,
        rewriter=RewriterConfig(steps=10, refine_columns=False)))
    bq.bind_shards(3).bind_cost_model(CostModel(force=DENSE))
    assert bq._batched_executor().n_shards == 3
    engine = ServingEngine(bq, batch_size=4)
    results, rep = engine.serve(wl)
    assert rep.n_queries == len(wl) and rep.n_batches == 2
    for q, (ids, scores) in zip(wl, results):
        gt_ids, gt_s = flat.ground_truth(table, list(q.query_vectors),
                                         list(q.weights), q.predicates, q.k)
        assert_results_match(gt_ids, gt_s, ids, scores)
    bq.bind_shards().bind_cost_model()
    assert bq._batched_executor().n_shards == 1
