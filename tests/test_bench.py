"""Benchmark construction (§4): correlation properties + stratification."""
import numpy as np

import jax.numpy as jnp

from repro.bench import augment, datasets, queries
from repro.vectordb.predicates import eval_mask


def test_cluster_labels_are_vector_correlated():
    """Rows sharing a cluster label must be closer than random pairs."""
    rng = np.random.default_rng(0)
    vecs = datasets._mixture_vectors(2000, 32, n_comp=8, seed=1)
    labels = augment.cluster_labels(vecs, n_clusters=8, seed=0)
    d_same, d_diff = [], []
    for _ in range(300):
        i, j = rng.integers(0, 2000, 2)
        d = np.linalg.norm(vecs[i] - vecs[j])
        (d_same if labels[i] == labels[j] else d_diff).append(d)
    assert np.mean(d_same) < np.mean(d_diff)


def test_hyperplane_codes_binary_structure():
    vecs = datasets._mixture_vectors(500, 16, seed=2)
    codes = augment.hyperplane_codes(vecs, n_planes=4, seed=0)
    assert codes.min() >= 0 and codes.max() < 16
    assert len(np.unique(codes)) > 2


def test_refdist_is_continuous_and_smooth():
    vecs = datasets._mixture_vectors(500, 16, seed=3)
    d = augment.refpoint_distance_sum(vecs, n_refs=4, seed=0)
    assert d.std() > 0
    # neighbours in vector space have close ref-dist sums
    i = np.argsort(vecs[:, 0])
    assert abs(d[i[0]] - d[i[1]]) < d.std() * 3


def test_hash_embed_correlates_with_scalars():
    scal, _ = datasets._scalar_table(1500, seed=0)
    v = augment.hash_embed(scal, 64, seed=0)
    # same category rows more similar than different-category rows
    cats = scal[:, 0]
    c = cats[0]
    same = v[cats == c]
    diff = v[cats != c]
    sim_same = (same[:50] @ same[:50].T).mean()
    sim_diff = (same[:50] @ diff[:50].T).mean()
    assert sim_same > sim_diff


def test_lm_embed_runs_with_assigned_arch():
    scal, _ = datasets._scalar_table(64, seed=0)
    v = augment.lm_embed(scal, 32, arch="stablelm-1.6b", smoke=True, seq=8)
    assert v.shape == (64, 32)
    np.testing.assert_allclose(np.linalg.norm(v, axis=1), 1.0, atol=1e-3)


def test_all_dataset_specs_build():
    for name in datasets.SPECS:
        t = datasets.make(name, rows=300, seed=0)
        assert t.n_rows == 300
        assert t.schema.n_vec == len(datasets.SPECS[name].dims)
        for v, vc in zip(t.vectors, t.schema.vector_cols):
            assert v.shape == (300, vc.dim)
            assert np.isfinite(np.asarray(v)).all()


def test_workload_selectivity_stratified(tiny_table):
    wl = queries.gen_workload(tiny_table, 40, n_vec_used=2, seed=0)
    sels = queries.workload_selectivities(tiny_table, wl)
    # must cover both selective and permissive regimes
    assert (sels < 0.3).sum() >= 5
    assert (sels > 0.6).sum() >= 5
    # weights: w1 + w2 == 1
    for q in wl:
        assert abs(sum(q.weights) - 1.0) < 1e-6


def test_workload_predicates_valid(tiny_table):
    wl = queries.gen_workload(tiny_table, 10, seed=3)
    for q in wl:
        assert bool(q.predicates.active.any())
        mask = eval_mask(q.predicates, tiny_table.scalars)
        assert mask.dtype == jnp.bool_


def test_gen_dnf_workload_properties(tiny_table):
    from repro.vectordb.predicates import PredicateSet, n_clauses

    wl = queries.gen_dnf_workload(tiny_table, 16, n_vec_used=2, seed=5,
                                  clause_counts=(2, 3, 4))
    assert len(wl) == 16
    assert all(isinstance(q.predicates, PredicateSet) for q in wl)
    assert max(n_clauses(q.predicates) for q in wl) >= 2
    sels = queries.workload_selectivities(tiny_table, wl)
    # stratification must cover selective and permissive regimes
    assert (sels < 0.4).sum() >= 3
    assert (sels > 0.5).sum() >= 3
    for q in wl:
        mask = eval_mask(q.predicates, tiny_table.scalars)
        assert mask.dtype == jnp.bool_
