"""Checkpointing: atomic commit, checksums, resume, elastic restore."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt


@pytest.fixture()
def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,))},
            "step_count": jnp.asarray(7)}


def test_save_restore_roundtrip(tmp_path, tree):
    d = str(tmp_path / "ck")
    ckpt.save(d, 10, tree, meta={"note": "hi"})
    step, out, meta = ckpt.restore(d, like=tree)
    assert step == 10 and meta["note"] == "hi"
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_latest_step_ignores_uncommitted(tmp_path, tree):
    d = str(tmp_path / "ck")
    ckpt.save(d, 5, tree)
    ckpt.save(d, 9, tree)
    os.remove(os.path.join(d, "step_00000009", "COMMIT"))  # simulate crash
    assert ckpt.latest_step(d) == 5


def test_checksum_detects_corruption(tmp_path, tree):
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, tree)
    shard = os.path.join(d, "step_00000003", "shard_p0.npz")
    data = dict(np.load(shard))
    k = [k for k in data if "w" in k][0]
    data[k] = data[k] + 1.0
    np.savez(shard, **data)
    with pytest.raises(IOError, match="checksum"):
        ckpt.restore(d, like=tree)


def test_overwrite_same_step(tmp_path, tree):
    d = str(tmp_path / "ck")
    ckpt.save(d, 2, tree)
    tree2 = jax.tree.map(lambda x: x * 2, tree)
    ckpt.save(d, 2, tree2)
    _, out, _ = ckpt.restore(d, like=tree)
    np.testing.assert_array_equal(np.asarray(out["params"]["b"]),
                                  2 * np.ones(4))


def test_shape_mismatch_raises(tmp_path, tree):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, tree)
    bad = {"params": {"w": jnp.zeros((2, 2)), "b": jnp.ones((4,))},
           "step_count": jnp.asarray(0)}
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(d, like=bad)


def test_resume_reproduces_training(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint/restore + 3: identical loss."""
    from repro.launch import train as train_cli

    losses_full = train_cli.main([
        "--arch", "mamba2-370m", "--smoke", "--steps", "6",
        "--global-batch", "2", "--seq-len", "16", "--log-every", "100"])
    d2 = str(tmp_path / "b")
    train_cli.main([
        "--arch", "mamba2-370m", "--smoke", "--steps", "3",
        "--schedule-total", "6",
        "--global-batch", "2", "--seq-len", "16", "--ckpt", d2,
        "--ckpt-every", "3", "--log-every", "100"])
    losses_resumed = train_cli.main([
        "--arch", "mamba2-370m", "--smoke", "--steps", "6",
        "--global-batch", "2", "--seq-len", "16", "--ckpt", d2,
        "--ckpt-every", "3", "--log-every", "100"])
    np.testing.assert_allclose(losses_full[3:], losses_resumed, rtol=1e-4)
