"""Recompile regression: a warmed serving engine never recompiles.

The shape-bucketing discipline (CLAUSE_GRID / NPROBE_GRID / MAX_SCAN_GRID /
KMULT_GRID, pow-of-two k and candidate buckets — serve/batch.SHAPE_GRIDS)
exists so the jit cache is keyed on a FINITE set of shapes. These tests pin
that contract at runtime, complementing boomlint's static RC001 rule: push
a mixed 32-query batch (conjunctive + DNF, 1- and 2-vector) through
optimize_batch + execute_batch twice and count XLA compilations.

* pass 2 (same engine, same queries): exactly zero compiles;
* pass 1 (cold paths for a fresh workload): bounded by a grid-derived
  ceiling — un-bucketing any shape makes the count scale with the batch
  (32 novel keys × per-group pipeline jits) and blows through it.
"""
import numpy as np
import pytest

from repro.analysis.recompile import CompileCounter, supported

# measured ~126 cold compiles for this exact workload; the ceiling leaves
# ~60% headroom for jax-version drift while staying far below the
# per-query blowup an un-bucketed shape causes (32 × ~12 jits ≈ 380+)
FIRST_PASS_CEILING = 200


@pytest.fixture(scope="module")
def mixed_batch(fitted):
    from repro.bench import queries

    bq, _holdout = fitted
    conj = queries.gen_workload(bq.table, 20, n_vec_used=2, seed=7)
    dnf = queries.gen_dnf_workload(bq.table, 12, n_vec_used=2, seed=8)
    qs = conj + dnf
    assert len(qs) == 32
    return bq, qs


@pytest.mark.slow
def test_warm_engine_never_recompiles(mixed_batch):
    if not supported():
        pytest.skip("this jax version emits no countable compile logs")
    bq, qs = mixed_batch

    with CompileCounter() as first:
        bq.optimize_batch(qs)
        res1 = bq.execute_batch(qs)
    assert first.count <= FIRST_PASS_CEILING, (
        f"{first.count} compiles on the first pass — a shape escaped the "
        f"bucketing grids; last compiles: {first.names[-8:]}")

    with CompileCounter() as second:
        bq.optimize_batch(qs)
        res2 = bq.execute_batch(qs)
    assert second.count == 0, (
        f"{second.count} recompiles on a warmed engine: {second.names}")

    # determinism rides along: identical passes, identical results
    for (i1, s1), (i2, s2) in zip(res1, res2):
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2))


@pytest.mark.slow
def test_post_compaction_prewarm_no_recompiles():
    """Tiered ingest p99 pin: compaction grows the cold table, and the new
    row count is a new static shape for every serving jit — the first
    post-swap batch used to pay the whole compile ladder inside its own
    latency (benchmarks/results/data_updates.json: p99 ≈ 3× p50 with one
    compaction in the window). ``BoomHQ._prewarm_cold`` replays retained
    recent traffic against the new cold state on the compaction thread
    BEFORE the epoch publish, so the compiles land there: the first
    post-swap batch of a warmed engine must be compile-free."""
    if not supported():
        pytest.skip("this jax version emits no countable compile logs")
    from repro.bench import datasets, queries
    from repro.core.boomhq import BoomHQ, BoomHQConfig
    from repro.core.data_encoder import DataEncoderConfig
    from repro.core.rewriter import RewriterConfig

    table = datasets.make("part", rows=900, seed=2)
    wl = queries.gen_workload(table, 18, n_vec_used=2, seed=11)
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=8,
        encoder=DataEncoderConfig(frozen_steps=8, ae_steps=10, sample=256),
        rewriter=RewriterConfig(steps=25, refine_columns=False)))
    bq.fit(wl[:10])
    bq.bind_tiered(hot_capacity=96)
    serve = wl[10:]
    bq.execute_batch(serve)  # warm pre-swap shapes + retain in _recent
    bq.execute_batch(serve)

    extra = datasets.make("part", rows=96, seed=23)
    stats = bq.insert([np.asarray(v) for v in extra.vectors],
                      np.asarray(extra.scalars))
    assert stats["needs_compaction"]
    with CompileCounter() as during:
        out = bq.tiered.compact()  # finetune_cb runs _prewarm_cold inside
    assert out["compacted"] == 96
    # the new cold row count IS a new shape — the compile ladder must have
    # run somewhere, and pre-warm pulls it into the compaction itself
    assert during.count > 0, (
        "compaction compiled nothing — the post-swap shapes were never "
        "warmed, so the zero-count below would be vacuous")

    with CompileCounter() as first_post_swap:
        res = bq.execute_batch(serve)
    assert first_post_swap.count == 0, (
        f"{first_post_swap.count} compiles on the first post-swap batch — "
        f"pre-warm missed a serving shape: {first_post_swap.names[-8:]}")
    # sanity, not recall (a query whose predicate qualifies zero rows may
    # legitimately return all -1): the warmed batch still produced results
    assert len(res) == len(serve)
    assert any(np.sum(np.asarray(ids) >= 0) > 0 for ids, _ in res)


@pytest.mark.slow
def test_permuted_replay_converges(mixed_batch):
    """A PERMUTED replay may re-chunk the batch (chunk membership is
    order-dependent) and so touch a handful of new pad buckets — but the
    count must stay grid-bounded (not per-query), and replaying the same
    permutation must then be compile-free: the cache converges instead of
    thrashing."""
    if not supported():
        pytest.skip("this jax version emits no countable compile logs")
    bq, qs = mixed_batch
    bq.execute_batch(qs)  # ensure warm (module fixture order-independent)
    rng = np.random.default_rng(3)
    perm = [qs[i] for i in rng.permutation(len(qs))]
    with CompileCounter() as cc:
        bq.optimize_batch(perm)
        bq.execute_batch(perm)
    assert cc.count <= FIRST_PASS_CEILING // 4, (
        f"permuted replay compiled {cc.count}× — bucket keys are leaking "
        f"per-order shapes: {cc.names[-8:]}")
    with CompileCounter() as again:
        bq.optimize_batch(perm)
        bq.execute_batch(perm)
    assert again.count == 0, f"replay did not converge: {again.names}"
