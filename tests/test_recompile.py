"""Recompile regression: a warmed serving engine never recompiles.

The shape-bucketing discipline (CLAUSE_GRID / NPROBE_GRID / MAX_SCAN_GRID /
KMULT_GRID, pow-of-two k and candidate buckets — serve/batch.SHAPE_GRIDS)
exists so the jit cache is keyed on a FINITE set of shapes. These tests pin
that contract at runtime, complementing boomlint's static RC001 rule: push
a mixed 32-query batch (conjunctive + DNF, 1- and 2-vector) through
optimize_batch + execute_batch twice and count XLA compilations.

* pass 2 (same engine, same queries): exactly zero compiles;
* pass 1 (cold paths for a fresh workload): bounded by a grid-derived
  ceiling — un-bucketing any shape makes the count scale with the batch
  (32 novel keys × per-group pipeline jits) and blows through it.
"""
import numpy as np
import pytest

from repro.analysis.recompile import CompileCounter, supported

# measured ~126 cold compiles for this exact workload; the ceiling leaves
# ~60% headroom for jax-version drift while staying far below the
# per-query blowup an un-bucketed shape causes (32 × ~12 jits ≈ 380+)
FIRST_PASS_CEILING = 200


@pytest.fixture(scope="module")
def mixed_batch(fitted):
    from repro.bench import queries

    bq, _holdout = fitted
    conj = queries.gen_workload(bq.table, 20, n_vec_used=2, seed=7)
    dnf = queries.gen_dnf_workload(bq.table, 12, n_vec_used=2, seed=8)
    qs = conj + dnf
    assert len(qs) == 32
    return bq, qs


@pytest.mark.slow
def test_warm_engine_never_recompiles(mixed_batch):
    if not supported():
        pytest.skip("this jax version emits no countable compile logs")
    bq, qs = mixed_batch

    with CompileCounter() as first:
        bq.optimize_batch(qs)
        res1 = bq.execute_batch(qs)
    assert first.count <= FIRST_PASS_CEILING, (
        f"{first.count} compiles on the first pass — a shape escaped the "
        f"bucketing grids; last compiles: {first.names[-8:]}")

    with CompileCounter() as second:
        bq.optimize_batch(qs)
        res2 = bq.execute_batch(qs)
    assert second.count == 0, (
        f"{second.count} recompiles on a warmed engine: {second.names}")

    # determinism rides along: identical passes, identical results
    for (i1, s1), (i2, s2) in zip(res1, res2):
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2))


@pytest.mark.slow
def test_permuted_replay_converges(mixed_batch):
    """A PERMUTED replay may re-chunk the batch (chunk membership is
    order-dependent) and so touch a handful of new pad buckets — but the
    count must stay grid-bounded (not per-query), and replaying the same
    permutation must then be compile-free: the cache converges instead of
    thrashing."""
    if not supported():
        pytest.skip("this jax version emits no countable compile logs")
    bq, qs = mixed_batch
    bq.execute_batch(qs)  # ensure warm (module fixture order-independent)
    rng = np.random.default_rng(3)
    perm = [qs[i] for i in rng.permutation(len(qs))]
    with CompileCounter() as cc:
        bq.optimize_batch(perm)
        bq.execute_batch(perm)
    assert cc.count <= FIRST_PASS_CEILING // 4, (
        f"permuted replay compiled {cc.count}× — bucket keys are leaking "
        f"per-order shapes: {cc.names[-8:]}")
    with CompileCounter() as again:
        bq.optimize_batch(perm)
        bq.execute_batch(perm)
    assert again.count == 0, f"replay did not converge: {again.names}"
