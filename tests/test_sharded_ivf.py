"""Per-shard IVF probing path (`ShardedIVF` + plan-driven shard fan-out):
shard-count edge cases, histogram gather caps, escalation exactness and
mesh/logical parity.

The mesh cases run in-process when the host platform exposes >= 4 devices
— the dedicated `sharded-mesh` CI job forces
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` and runs ONLY this
file; under the plain tier-1 process (1 device) they skip and the
equivalent parity is covered by tests/test_distributed.py's subprocess.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from oracle import brute_force_topk, sharded_brute_force_topk, \
    tie_aware_recall

from repro.bench import datasets, queries
from repro.core.executor import legalize_for_shard
from repro.core.query import ExecutionPlan, SubqueryParams
from repro.serve.batch import (
    BatchedHybridExecutor, CANDIDATE_LOCAL, DENSE, SHARDED_LOCAL,
    SINGLE_DEVICE, CostModel,
)
from repro.vectordb import histogram, ivf
from repro.vectordb.table import ScalarCol, Table, TableSchema, VectorCol


def _indexes(t):
    return [ivf.build(v, 16, seed=i, metric=t.schema.metric)
            for i, v in enumerate(t.vectors)]


def _generous_plan(t, *, iterative=False):
    """Budgets at which per-shard probing degenerates to an exhaustive
    filtered scan — the regime where the path must be oracle-exact."""
    return ExecutionPlan("index_scan", tuple(
        SubqueryParams(k_mult=4, nprobe=64, max_scan=t.n_rows,
                       iterative=iterative) for _ in range(t.schema.n_vec)))


def _mixed_wl(t, seed):
    return queries.gen_workload(t, 5, n_vec_used=2, seed=seed) + \
        queries.gen_dnf_workload(t, 5, n_vec_used=2, seed=seed + 1,
                                 clause_counts=(2, 3, 4))


def _oracle_recall(t, q, ids):
    _, _, masked = brute_force_topk(
        t, list(q.query_vectors), list(q.weights), q.predicates, q.k)
    return tie_aware_recall(ids, masked, q.k)


# ---------------------------------------------------------------------------
# shard-count edge cases
# ---------------------------------------------------------------------------

def test_one_shard_is_single_device_bit_for_bit(tiny_table):
    """S=1 must degenerate to the single-device candidate-local path with
    IDENTICAL bits: the 1-shard ShardedIVF reuses the bound index verbatim
    and the probe/rerank kernels run unsharded, so ids AND scores match
    exactly (not just to float tolerance). Budgets are exhaustive so the
    probe cannot miss — at tighter budgets the sharded path's per-shard
    escalation may legitimately ADD rows the probe missed (checked below
    as a one-sided recall claim)."""
    t = tiny_table
    idx = _indexes(t)
    wl = _mixed_wl(t, 31)
    plans = [_generous_plan(t)] * len(wl)
    bx1 = BatchedHybridExecutor(t, idx, n_shards=1,
                                cost_model=CostModel(force=SHARDED_LOCAL))
    bx0 = BatchedHybridExecutor(t, idx,
                                cost_model=CostModel(force=CANDIDATE_LOCAL))
    res1 = bx1.execute_batch_sharded(wl, plans)
    res0 = bx0.execute_batch(wl, plans)
    for (i1, s1), (i0, s0) in zip(res1, res0):
        np.testing.assert_array_equal(i1, i0)
        np.testing.assert_array_equal(s1, s0)


def test_one_shard_tight_budget_never_below_single_device(tiny_table):
    """At tight budgets S=1 runs the same probes as the single-device
    candidate-local path plus per-shard escalation — so its oracle recall
    can only be >= per query."""
    t = tiny_table
    idx = _indexes(t)
    wl = _mixed_wl(t, 31)
    plan = ExecutionPlan("index_scan", tuple(
        SubqueryParams(k_mult=2, nprobe=2, max_scan=128, iterative=False)
        for _ in range(2)))
    plans = [plan] * len(wl)
    bx1 = BatchedHybridExecutor(t, idx, n_shards=1,
                                cost_model=CostModel(force=SHARDED_LOCAL))
    bx0 = BatchedHybridExecutor(t, idx,
                                cost_model=CostModel(force=CANDIDATE_LOCAL))
    res1 = bx1.execute_batch_sharded(wl, plans)
    res0 = bx0.execute_batch(wl, plans)
    for q, (i1, _), (i0, _) in zip(wl, res1, res0):
        assert _oracle_recall(t, q, i1) >= _oracle_recall(t, q, i0) - 1e-9


def test_non_divisible_row_count_pads_exactly(tiny_table):
    """1500 rows over 7 shards: the padded short shard must change nothing
    — generous budgets stay oracle-exact, every id is a real row, and the
    merge agrees with the pure-NumPy sharded oracle."""
    t = tiny_table
    assert t.n_rows % 7 != 0
    bx = BatchedHybridExecutor(t, _indexes(t), n_shards=7,
                               cost_model=CostModel(force=SHARDED_LOCAL))
    wl = _mixed_wl(t, 43)
    res = bx.execute_batch_sharded(wl, [_generous_plan(t)] * len(wl))
    for q, (ids, scores) in zip(wl, res):
        assert _oracle_recall(t, q, ids) == 1.0
        valid = ids[ids >= 0]
        assert valid.size == len(set(valid.tolist()))  # no duplicates
        assert np.all(valid < t.n_rows)  # no padded phantom rows
        o_ids, o_scores, _ = sharded_brute_force_topk(
            t, list(q.query_vectors), list(q.weights), q.predicates, q.k,
            n_shards=7)
        np.testing.assert_allclose(
            np.sort(scores[ids >= 0]), np.sort(o_scores[o_ids >= 0]),
            atol=1e-4, rtol=1e-5)


def test_all_filtered_shard_contributes_nothing():
    """A shard whose rows ALL fail the predicate must contribute zero
    candidates — and no phantom ids — while the other shards' results stay
    exact (the PR 4 validity-mask regression, at shard granularity)."""
    rng = np.random.default_rng(0)
    n, d, m, n_shards = 900, 16, 2, 3
    schema = TableSchema(
        vector_cols=(VectorCol("v0", d),),
        scalar_cols=tuple(ScalarCol(f"s{i}", "num") for i in range(m)))
    scal = rng.uniform(0.0, 1.0, (n, m)).astype(np.float32)
    # scalar 0 encodes the shard: rows of shard 0 can never satisfy >= 1.0
    scal[:, 0] = np.repeat(np.arange(n_shards), n // n_shards)
    t = Table.from_numpy(
        schema, [rng.normal(size=(n, d)).astype(np.float32)], scal)
    idx = [ivf.build(t.vectors[0], 8, seed=0)]
    from repro.vectordb.predicates import Predicates

    wl = []
    for j in range(4):
        qv = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
        from repro.core.query import MHQ
        wl.append(MHQ(query_vectors=(qv,), weights=(1.0,),
                      predicates=Predicates.from_conditions(
                          m, {0: (1.0, 2.0)}), k=10))
    bx = BatchedHybridExecutor(t, idx, n_shards=n_shards,
                               cost_model=CostModel(force=SHARDED_LOCAL))
    res = bx.execute_batch_sharded(wl, [_generous_plan(t)] * len(wl))
    shard_len = n // n_shards
    for q, (ids, scores) in zip(wl, res):
        assert _oracle_recall(t, q, ids) == 1.0
        valid = ids[ids >= 0]
        assert valid.size > 0
        assert np.all(valid >= shard_len)  # shard 0 contributed nothing
        assert np.all(scores[ids < 0] < -1e29)  # empty slots stay NEG


def test_selective_predicate_escalates_to_exact(tiny_table):
    """Tiny probing budgets + a predicate qualifying fewer than k rows:
    every shard underfills, the per-shard escalation exact-scans its own
    underfilled subset, and the merged result is the complete qualifying
    set — the recall contract survives the worst plan."""
    t = tiny_table
    idx = _indexes(t)
    scal = np.asarray(t.scalars)
    col = next(i for i, c in enumerate(t.schema.scalar_cols)
               if c.kind == "num")
    vals = np.sort(scal[:, col])
    lo, hi = float(vals[2]), float(vals[6])  # ~5 qualifying rows
    from repro.core.query import MHQ
    from repro.vectordb.predicates import Predicates

    rng = np.random.default_rng(3)
    q = MHQ(query_vectors=tuple(
        jnp.asarray(rng.normal(size=(v.shape[1],)).astype(np.float32))
        for v in t.vectors),
        weights=(0.6, 0.4),
        predicates=Predicates.from_conditions(
            t.schema.n_scalar, {col: (lo, hi)}), k=10)
    _, _, masked = brute_force_topk(
        t, list(q.query_vectors), list(q.weights), q.predicates, q.k)
    assert 0 < int(np.sum(masked > -1e29)) < q.k  # genuinely underfilled
    plan = ExecutionPlan("index_scan", tuple(
        SubqueryParams(k_mult=1, nprobe=1, max_scan=32, iterative=False)
        for _ in range(2)))
    bx = BatchedHybridExecutor(t, idx, n_shards=4,
                               cost_model=CostModel(force=SHARDED_LOCAL))
    (ids, scores), = bx.execute_batch_sharded([q], [plan])
    assert _oracle_recall(t, q, ids) == 1.0
    assert set(ids[ids >= 0].tolist()) == \
        set(np.flatnonzero(masked > -1e29).tolist())


def test_boundary_trigger_escalates_dominant_shard_only(monkeypatch):
    """The finer escalation trigger (merged-underfill almost never fires —
    other shards pad the merge out, so probe misses in a DOMINANT shard
    went unnoticed): a shard whose local top-k boundary score sits at the
    merged k-th cutoff was truncated while still globally competitive and
    re-runs exact — and ONLY that shard. Pins all three claims:
    the merged result is full (the old trigger stays silent), the exact
    retry rescans a strict shard-subset, and the retry restores the oracle
    top-k the probe missed."""
    from repro.core.query import MHQ
    from repro.vectordb.predicates import Predicates

    rng = np.random.default_rng(5)
    n, d, m, n_shards, k = 600, 16, 2, 3, 10
    shard_len = n // n_shards
    schema = TableSchema(
        vector_cols=(VectorCol("v0", d),),
        scalar_cols=tuple(ScalarCol(f"s{i}", "num") for i in range(m)))
    qdir = rng.normal(size=(d,)).astype(np.float32)
    qdir /= np.linalg.norm(qdir)
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    # shard 1 dominates: its rows carry a strong query-direction component
    # at varied magnitudes plus noise, so they spread over many clusters
    # and a tight nprobe provably misses some of the global top-k
    boost = np.linspace(4.0, 12.0, shard_len).astype(np.float32)
    vecs[shard_len: 2 * shard_len] += boost[:, None] * qdir[None, :]
    t = Table.from_numpy(
        schema, [vecs], rng.uniform(0, 1, (n, m)).astype(np.float32))
    idx = [ivf.build(t.vectors[0], 24, seed=0)]
    wl = [MHQ(query_vectors=(jnp.asarray(qdir),), weights=(1.0,),
              predicates=Predicates.none(m), k=k)]
    tight = ExecutionPlan("index_scan", (
        SubqueryParams(k_mult=2, nprobe=1, max_scan=96, iterative=False),))

    captured = {}
    orig = BatchedHybridExecutor._escalate_shards

    def spy(self, ids, scores, need, **kw):
        captured["need"] = need.copy()
        return orig(self, ids, scores, need, **kw)

    monkeypatch.setattr(BatchedHybridExecutor, "_escalate_shards", spy)
    bx = BatchedHybridExecutor(t, idx, n_shards=n_shards,
                               cost_model=CostModel(force=SHARDED_LOCAL))
    (ids, _), = bx.execute_batch_sharded(wl, [tight])
    q = wl[0]

    # the merged result was FULL — the old merged-underfill trigger would
    # never have escalated this query
    assert int(np.sum(ids >= 0)) == k
    # ... yet the boundary trigger fired, on the dominant shard ONLY
    assert bx.escalated == {0}
    need = captured["need"]
    assert need[0].tolist() == [False, True, False]
    assert not need[1:].any()  # padding queries never escalate
    # the strict-subset retry restores the exact top-k (all of which lives
    # in the dominant shard by construction)
    assert _oracle_recall(t, q, ids) == 1.0
    valid = ids[ids >= 0]
    assert np.all((valid >= shard_len) & (valid < 2 * shard_len))

    # counterfactual: with escalation disabled the same probe demonstrably
    # missed part of the top-k — the trigger is what closes the gap
    monkeypatch.setattr(BatchedHybridExecutor, "_escalate_shards",
                        lambda self, ids, scores, need, **kw: (ids, scores))
    bx2 = BatchedHybridExecutor(t, idx, n_shards=n_shards,
                                cost_model=CostModel(force=SHARDED_LOCAL))
    (ids2, _), = bx2.execute_batch_sharded(wl, [tight])
    assert _oracle_recall(t, q, ids2) < 1.0


def test_legalize_for_shard_budget_split():
    # global budget splits ceil-wise, floors at the per-shard k_i
    assert legalize_for_shard(40, 16, 2048, n_shards=4, shard_len=125_000,
                              n_clusters=16) == (40, 16, 512)
    # nprobe clamps to the per-shard cluster count
    assert legalize_for_shard(40, 16, 2048, n_shards=4, shard_len=125_000,
                              n_clusters=8) == (40, 8, 512)
    # shard smaller than the split budget: everything clamps to the shard
    assert legalize_for_shard(40, 16, 2048, n_shards=4, shard_len=100,
                              n_clusters=4) == (40, 4, 100)
    # 1 shard keeps the single-device budgets bit-for-bit
    assert legalize_for_shard(40, 8, 512, n_shards=1, shard_len=1500,
                              n_clusters=16) == (40, 8, 512)


# ---------------------------------------------------------------------------
# histogram-estimated gather caps (sharded candidate-local, no host sync)
# ---------------------------------------------------------------------------

def _exactness_over_wl(bx, t, wl):
    for q, (ids, _) in zip(wl, bx.execute_batch_sharded(wl)):
        assert _oracle_recall(t, q, ids) == 1.0


def test_histogram_cap_estimates_and_stays_exact(tiny_table):
    """With faithful histograms the sharded candidate-local gather sizes
    itself from the estimate (no mid-chunk host sync) and remains the
    exact filtered top-k."""
    t = tiny_table
    hists = histogram.build(t.scalars, 32)
    bx = BatchedHybridExecutor(t, _indexes(t), n_shards=3,
                               cost_model=CostModel(force=CANDIDATE_LOCAL),
                               hists=hists)
    _exactness_over_wl(bx, t, _mixed_wl(t, 61))


def test_histogram_cap_undershoot_escalates_exactly(tiny_table, monkeypatch):
    """A worst-case estimator (claims ZERO selectivity for everything)
    under-shoots every static cap — the overflow escalation must restore
    exactness: an under-shooting estimate may cost a retry, never rows."""
    import repro.serve.batch as sb

    t = tiny_table
    wl = _mixed_wl(t, 67)
    # the under-shoot must actually happen for this test to mean anything:
    # the workload qualifies far more rows than the floor-sized cap
    masks = np.stack([np.asarray(
        brute_force_topk(t, list(q.query_vectors), list(q.weights),
                         q.predicates, q.k)[2]) > -1e29 for q in wl])
    assert masks.sum(axis=1).max() > 64

    monkeypatch.setattr(
        sb, "_selectivity_batch",
        lambda hists, pred_b: jnp.zeros(
            (np.asarray(pred_b.active).shape[0],), jnp.float32))
    hists = histogram.build(t.scalars, 32)
    bx = BatchedHybridExecutor(t, _indexes(t), n_shards=3,
                               cost_model=CostModel(force=CANDIDATE_LOCAL),
                               hists=hists)
    _exactness_over_wl(bx, t, wl)


# ---------------------------------------------------------------------------
# mesh parity (runs under the sharded-mesh CI job; skips on 1 device)
# ---------------------------------------------------------------------------

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 host devices (sharded-mesh CI job)")


@needs_mesh
def test_sharded_ivf_mesh_matches_logical():
    """The shard_map execution of the per-shard probing path must equal the
    logical single-device reference bit-for-bit: same per-shard probes,
    same rerank, same merge order."""
    from jax.sharding import Mesh

    t = datasets.make("part", rows=1024, seed=1)
    idx = _indexes(t)
    wl = _mixed_wl(t, 71)
    plan = ExecutionPlan("index_scan", tuple(
        SubqueryParams(k_mult=4, nprobe=8, max_scan=256, iterative=False)
        for _ in range(2)))
    plans = [plan] * len(wl)
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    bx_m = BatchedHybridExecutor(t, idx, mesh=mesh,
                                 cost_model=CostModel(force=SHARDED_LOCAL))
    bx_l = BatchedHybridExecutor(t, idx, n_shards=4,
                                 cost_model=CostModel(force=SHARDED_LOCAL))
    res_m = bx_m.execute_batch_sharded(wl, plans)
    res_l = bx_l.execute_batch_sharded(wl, plans)
    for (im, sm), (il, sl) in zip(res_m, res_l):
        np.testing.assert_array_equal(im, il)
        np.testing.assert_allclose(sm, sl, atol=1e-6)


@needs_mesh
def test_sharded_ivf_mesh_oracle_floor():
    """End-to-end over a REAL 4-device mesh: the learned-path plumbing
    (BoomHQ.bind_shards -> sharded-IVF groups under shard_map) clears the
    exact-oracle floor at generous budgets."""
    from jax.sharding import Mesh

    t = datasets.make("part", rows=1024, seed=1)
    bx = BatchedHybridExecutor(
        t, _indexes(t), mesh=Mesh(np.array(jax.devices()[:4]), ("data",)),
        cost_model=CostModel(force=SHARDED_LOCAL))
    wl = _mixed_wl(t, 73)
    res = bx.execute_batch_sharded(wl, [_generous_plan(t)] * len(wl))
    for q, (ids, _) in zip(wl, res):
        assert _oracle_recall(t, q, ids) == 1.0


# ---------------------------------------------------------------------------
# dispatcher three-way routing
# ---------------------------------------------------------------------------

def test_choose_sharded_three_way():
    cm = CostModel(crossover=1.0, overhead=0, min_shard_rows=256)
    # big shards + budget under the crossover -> plan-driven probing
    assert cm.choose_sharded(batch=4, scan=64, n_rows=4096,
                             n_shards=4) == SHARDED_LOCAL
    # budget past the crossover -> exact per-shard dense scan
    assert cm.choose_sharded(batch=8, scan=4096, n_rows=4096,
                             n_shards=4) == DENSE
    # shards below the floor -> the fan-out is not worth the merge
    assert cm.choose_sharded(batch=4, scan=64, n_rows=512,
                             n_shards=4) == SINGLE_DEVICE
    # forces: local-flavored pins the probing path, dense stays exact
    for force, want in ((SHARDED_LOCAL, SHARDED_LOCAL),
                        (CANDIDATE_LOCAL, SHARDED_LOCAL), (DENSE, DENSE),
                        (SINGLE_DEVICE, SINGLE_DEVICE)):
        assert CostModel(force=force).choose_sharded(
            batch=1, scan=1, n_rows=10**9, n_shards=4) == want


def test_small_shards_route_single_device(tiny_table):
    """Default cost model on a tiny table: index groups skip the fan-out
    (SINGLE_DEVICE) and still produce learned-path results; the decision
    log records the route."""
    t = tiny_table
    bx = BatchedHybridExecutor(t, _indexes(t), n_shards=3)
    wl = _mixed_wl(t, 83)
    plans = [_generous_plan(t)] * len(wl)
    res = bx.execute_batch_sharded(wl, plans)
    counts, decisions = bx.dispatcher.take()
    assert counts.get(SINGLE_DEVICE, 0) >= 1
    routed = [d for d in decisions if d["group"][0] == "sharded-ivf"]
    assert routed and all(d["path"] == SINGLE_DEVICE for d in routed)
    # the delegated path is the plain single-device index_scan: held to the
    # usual mean-level floor (per-column candidate generation is the
    # ROADMAP's known structural gap, not an exactness bug)
    recs = [_oracle_recall(t, q, ids) for q, (ids, _) in zip(wl, res)]
    assert float(np.mean(recs)) >= 0.9 and min(recs) >= 0.5, recs
