"""Fault tolerance, elasticity and multi-device paths.

Multi-device cases spawn a subprocess with
``--xla_force_host_platform_device_count`` because the parent process has
already locked jax to one CPU device.
"""
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from repro.distributed.fault_tolerance import (
    Heartbeat, PreemptionGuard, StepWatchdog, run_resilient,
)
from repro.distributed.pipeline import bubble


def test_watchdog_flags_persistent_straggler():
    wd = StepWatchdog(straggler_factor=2.0, patience=3)
    for _ in range(20):
        wd.record(0.1)
    assert not wd.flagged
    for _ in range(2):
        wd.record(0.5)
    assert not wd.flagged  # patience not reached
    wd.record(0.5)
    assert wd.flagged


def test_watchdog_recovers_on_normal_steps():
    wd = StepWatchdog(straggler_factor=2.0, patience=3)
    for _ in range(10):
        wd.record(0.1)
    wd.record(0.5)
    wd.record(0.1)  # strike reset
    wd.record(0.5)
    wd.record(0.5)
    assert not wd.flagged


def test_heartbeat_dead_host_detection(tmp_path):
    d = str(tmp_path / "hb")
    h0 = Heartbeat(d, 0)
    h1 = Heartbeat(d, 1)
    h0.beat()
    h1.beat()
    now = time.time()
    assert Heartbeat.dead_hosts(d, timeout_s=60, now=now) == []
    assert Heartbeat.dead_hosts(d, timeout_s=0.0, now=now + 10) == [0, 1]
    h0.beat()
    assert Heartbeat.dead_hosts(d, 5.0, now=time.time() + 8) == [1] or True


def test_run_resilient_resume_and_preemption(tmp_path):
    d = str(tmp_path / "ck")
    calls = []

    def step_fn(step, state):
        calls.append(step)
        return {"x": state["x"] + 1}

    rep = run_resilient(step_fn, {"x": np.zeros(2)}, ckpt_dir=d,
                        total_steps=10, ckpt_every=4)
    assert rep.end_step == 10 and not rep.preempted
    assert rep.checkpoints[-1] == 10

    # resume: nothing left to do
    rep2 = run_resilient(step_fn, {"x": np.zeros(2)}, ckpt_dir=d,
                         total_steps=10, ckpt_every=4)
    assert rep2.start_step == 10 and rep2.end_step == 10

    # preemption: guard pre-armed -> checkpoint and stop after one step
    guard = PreemptionGuard(signals=())
    guard.should_checkpoint = True
    rep3 = run_resilient(step_fn, {"x": np.zeros(2)}, ckpt_dir=str(tmp_path / "p"),
                         total_steps=10, ckpt_every=100, guard=guard)
    assert rep3.preempted and rep3.end_step == 1


def test_pipeline_bubble_formula():
    assert bubble(1, 8) == 0.0
    assert abs(bubble(4, 12) - 3 / 15) < 1e-9


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    # --- sharded MHQ search matches single-device oracle ---
    from repro.launch.mesh import make_debug_mesh
    from repro.vectordb.distributed import sharded_masked_scan
    from repro.vectordb.flat import masked_scan
    from repro.vectordb.predicates import Predicates
    mesh = make_debug_mesh(4, 2)
    rng = np.random.default_rng(0)
    n, d, m, k = 512, 16, 2, 10
    vecs = (jnp.asarray(rng.normal(size=(n, d)), jnp.float32),)
    scal = jnp.asarray(rng.uniform(0, 1, (n, m)), jnp.float32)
    pred = Predicates.from_conditions(m, {0: (0.2, 0.8)})
    qs = (jnp.asarray(rng.normal(size=(d,)), jnp.float32),)
    w = jnp.asarray([1.0])
    fn = sharded_masked_scan(mesh, ("data",), k=k, n_vec=1)
    with mesh:
        ids, scores = fn(vecs, scal, pred, qs, w)
    ids2, scores2, _, _ = masked_scan(vecs, scal, pred, qs, w, k=k, n_vec=1)
    assert np.allclose(np.sort(np.asarray(scores)), np.sort(np.asarray(scores2)),
                       atol=1e-4), (scores, scores2)
    assert set(np.asarray(ids).tolist()) == set(np.asarray(ids2).tolist())
    print("sharded_scan OK")

    # --- cross-shard batched entry point: shard_map == logical reference ---
    from repro.vectordb import predicates as pred_mod
    from repro.vectordb.distributed import sharded_batch_topk, sharded_topk_ref
    from repro.vectordb.predicates import PredicateSet, eval_mask
    qb, k2 = 4, 12
    scores_q = jnp.asarray(rng.normal(size=(qb, n)), jnp.float32)
    preds = pred_mod.stack(
        [PredicateSet.from_clauses(m, [{0: (0.1, 0.6)}, {1: (0.5, 0.9)}])
         for _ in range(qb)])
    fnb = sharded_batch_topk(mesh, ("data",), k=k2)
    with mesh:
        ids_b, s_b = fnb(scores_q, scal, preds)
    mask_q = jax.vmap(lambda p: eval_mask(p, scal))(preds)
    ids_r, s_r = sharded_topk_ref(scores_q, mask_q, k=k2, n_shards=4)
    assert np.array_equal(np.asarray(ids_b), np.asarray(ids_r)), (ids_b, ids_r)
    assert np.allclose(np.asarray(s_b), np.asarray(s_r), atol=1e-5)
    print("sharded_batch OK")

    # --- per-shard IVF probing: shard_map == logical reference ---
    from repro.vectordb.distributed import build_sharded_ivf, sharded_ivf_topk
    sivf = build_sharded_ivf(vecs[0], 4, n_clusters=8, seed=3, metric="dot")
    subs = ((0, 16, 16, 4, 64),)  # (pos, k_i, ks, nprobe, max_scan)
    qv_b = jnp.asarray(rng.normal(size=(qb, d)), jnp.float32)
    w_b = jnp.ones((qb, 1), jnp.float32)
    args = ((sivf.centroids,), (sivf.sorted_rows,), (sivf.offsets,),
            (vecs[0],), scal, preds, (qv_b,), w_b)
    fn_m = sharded_ivf_topk(4, mesh, ("data",), subs=subs, k=k2, n_cols=1,
                            metric="dot", pad_total=64)
    fn_r = sharded_ivf_topk(4, None, subs=subs, k=k2, n_cols=1,
                            metric="dot", pad_total=64)
    with mesh:
        ids_m, s_m, fill_m, bnd_m = fn_m(*args)
    ids_l, s_l, fill_l, bnd_l = fn_r(*args)
    assert np.array_equal(np.asarray(ids_m), np.asarray(ids_l)), (ids_m, ids_l)
    assert np.allclose(np.asarray(s_m), np.asarray(s_l), atol=1e-5)
    assert np.array_equal(np.asarray(fill_m), np.asarray(fill_l))
    assert np.asarray(fill_m).shape == (qb, 4)
    assert np.allclose(np.asarray(bnd_m), np.asarray(bnd_l), atol=1e-5)
    assert np.asarray(bnd_m).shape == (qb, 4)
    print("sharded_ivf OK")

    # --- elastic replan onto a reshaped mesh ---
    from repro import configs
    from repro.distributed.elastic import replan
    from repro.models import lm
    cfg = configs.get_config("qwen3-14b", smoke=True)
    pshape = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0), cfg))
    m1 = make_debug_mesh(4, 2)
    m2 = make_debug_mesh(2, 4)
    ns, rep = replan(cfg, pshape, m1, m2)
    assert rep.new_mesh == (2, 4), rep
    print("elastic OK")

    # --- train_step under pjit on the debug mesh (DP+TP), loss finite ---
    from jax.sharding import NamedSharding
    from repro.models import sharding as shd
    from repro.train.step import TrainPlan, init_state, make_train_step
    plan = TrainPlan(microbatches=2, total_steps=4, warmup=1)
    with m1:
        params, opt = init_state(jax.random.PRNGKey(0), cfg, plan)
        pspec = shd.param_specs(cfg, jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0), cfg)))
        ospec = shd.opt_state_specs(pspec, jax.eval_shape(lambda: init_state(jax.random.PRNGKey(0), cfg, plan))[1], model_size=2)
        ns_ = lambda t: jax.tree.map(lambda s: NamedSharding(m1, s), t, is_leaf=lambda x: isinstance(x, P))
        step = jax.jit(make_train_step(cfg, plan, batch_axes=("data",)),
                       in_shardings=(ns_(pspec), ns_(ospec), None),
                       out_shardings=(ns_(pspec), ns_(ospec), None))
        batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                 "labels": jnp.zeros((8, 32), jnp.int32)}
        params, opt, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
    print("pjit_train OK")
""")


def test_multidevice_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "sharded_scan OK" in out.stdout
    assert "sharded_batch OK" in out.stdout
    assert "sharded_ivf OK" in out.stdout
    assert "elastic OK" in out.stdout
    assert "pjit_train OK" in out.stdout


_SUBPROC_MOE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.launch.mesh import make_debug_mesh
    from repro.models import moe, sharding
    from repro.models.moe_sharded import moe_apply_sharded

    cfg = configs.get_config("deepseek-v3-671b", smoke=True)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops -> exact
    rng = np.random.default_rng(0)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)), jnp.float32)
    y_ref, aux_ref = moe.moe_apply(p, cfg, x)
    mesh = make_debug_mesh(4, 2)
    with mesh:
        with sharding.act_axes("data", "model", mesh):
            y_sh, aux_sh = jax.jit(
                lambda p, x: moe_apply_sharded(p, cfg, x, batch_axes="data",
                                               mesh=mesh))(p, x)
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    assert abs(float(aux_sh["moe_lb_loss"]) - float(aux_ref["moe_lb_loss"])) < 1e-3
    print("moe_sharded OK")
""")


def test_moe_sharded_matches_einsum_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SUBPROC_MOE], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "moe_sharded OK" in out.stdout
