"""boomlint: golden fixtures, suppression/baseline round-trips, repo gate."""
import collections
import os

import pytest

from repro.analysis import cli
from repro.analysis.config import LintConfig, registered_shape_values
from repro.analysis.runner import run_paths
from repro.analysis.suppressions import Baseline, parse_suppressions

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "boomlint")
REPO_SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src",
                        "repro")

# the fixture hot_* functions opt into hot-host scanning via config
FIXTURE_CFG = LintConfig(
    trace=False,
    hot_functions=(("hs001_bad.py", "hot_*"), ("hs001_clean.py", "hot_*"),
                   ("ep001_bad.py", "hot_*"), ("ep001_clean.py", "hot_*"),
                   ("ep002_bad.py", "hot_*"), ("ep002_clean.py", "hot_*")),
)


def _scan(name, cfg=FIXTURE_CFG):
    return run_paths([os.path.join(FIXTURES, name)], cfg)


def _rules(findings):
    return collections.Counter(f.rule for f in findings)


# ---------------------------------------------------------------------------
# golden fixtures: every rule fires on its seeded twin, never on the clean one
# ---------------------------------------------------------------------------

def test_hs001_bad_fixture():
    active = _scan("hs001_bad.py")["active"]
    assert _rules(active) == {"HS001": 6}, [f.render() for f in active]
    lines = {f.line for f in active}
    by_msg = " | ".join(f.message for f in active)
    assert ".item()" in by_msg
    assert "float()" in by_msg
    assert "truthiness" in by_msg or "traced value" in by_msg
    assert "repeated host transfer" in by_msg
    assert all(f.path.endswith("hs001_bad.py") for f in active)
    assert all(f.line > 0 for f in active) and len(lines) == 6


def test_hs001_clean_fixture():
    active = _scan("hs001_clean.py")["active"]
    assert active == [], [f.render() for f in active]


def test_rc001_bad_fixture():
    active = _scan("rc001_bad.py")["active"]
    assert _rules(active) == {"RC001": 3}, [f.render() for f in active]
    msgs = " | ".join(f.message for f in active)
    assert "'kk' does not match" in msgs or "does not match" in msgs
    assert "48" in msgs  # the off-grid literal
    assert "unhashable" in msgs


def test_rc001_clean_fixture():
    active = _scan("rc001_clean.py")["active"]
    assert active == [], [f.render() for f in active]


def test_sm001_bad_fixture():
    active = _scan("sm001_bad.py")["active"]
    assert _rules(active) == {"SM001": 2}, [f.render() for f in active]
    names = " | ".join(f.message for f in active)
    assert "`table`" in names and "`vectors`" in names


def test_sm001_clean_fixture():
    active = _scan("sm001_clean.py")["active"]
    assert active == [], [f.render() for f in active]


def test_pl001_bad_fixture():
    active = _scan("pl001_bad.py")["active"]
    assert _rules(active) == {"PL001": 1}, [f.render() for f in active]
    assert "VMEM" in active[0].message


def test_pl001_clean_fixture():
    active = _scan("pl001_clean.py")["active"]
    assert active == [], [f.render() for f in active]


def test_ep001_bad_fixture():
    active = _scan("ep001_bad.py")["active"]
    assert _rules(active) == {"EP001": 5}, [f.render() for f in active]
    msgs = " | ".join(f.message for f in active)
    assert "snapshot()" in msgs and "epoch" in msgs
    # the non-hot function's identical reads stay exempt
    assert "cold_ingest_path" not in msgs
    fields = {f.message.split("`")[3].rsplit(".", 1)[-1] for f in active}
    assert fields == {"_hot", "_cold", "_epoch", "_sealing", "_compacting"}


def test_ep001_clean_fixture():
    active = _scan("ep001_clean.py")["active"]
    assert active == [], [f.render() for f in active]


def test_ep002_bad_fixture():
    active = _scan("ep002_bad.py")["active"]
    assert _rules(active) == {"EP002": 4}, [f.render() for f in active]
    msgs = " | ".join(f.message for f in active)
    assert "freshness check" in msgs
    assert "SemanticCache.lookup()" in msgs
    # the non-hot function's identical read stays exempt
    assert "cold_report_path" not in msgs
    fields = {f.message.split("`")[3].rsplit(".", 1)[-1] for f in active}
    assert fields == {"ids", "scores", "centroids"}


def test_ep002_clean_fixture():
    active = _scan("ep002_clean.py")["active"]
    assert active == [], [f.render() for f in active]


# ---------------------------------------------------------------------------
# suppressions & baseline
# ---------------------------------------------------------------------------

def test_suppression_round_trip():
    res = _scan("suppressed.py")
    # two ignores match their finding; the wrong-rule ignore does not
    assert _rules(res["active"]) == {"HS001": 1}
    assert _rules(res["suppressed"]) == {"HS001": 2}
    assert "item_not_suppressed" not in " ".join(
        f.message for f in res["suppressed"])


def test_parse_suppressions_forms():
    src = (
        "x = 1  # boomlint: ignore[HS001] inline\n"
        "# boomlint: ignore[RC001, SM001] standalone, multi-rule\n"
        "# continued explanation line\n"
        "y = 2\n"
    )
    sup = parse_suppressions(src)
    assert sup[1] == {"HS001"}
    assert sup[4] == {"RC001", "SM001"}


def test_ignore_suppressions_audit_mode():
    cfg = LintConfig(trace=False, ignore_suppressions=True,
                     hot_functions=FIXTURE_CFG.hot_functions)
    res = _scan("suppressed.py", cfg)
    assert _rules(res["active"]) == {"HS001": 3}


def test_baseline_round_trip(tmp_path):
    active = _scan("hs001_bad.py")["active"]
    path = str(tmp_path / "baseline.json")
    Baseline.from_findings(active).save(path)
    bl = Baseline.load(path)
    assert bl.filter(active) == []  # fully absorbed
    # an extra finding beyond the baseline stays active
    extra = _scan("rc001_bad.py")["active"]
    remaining = bl.filter(active + extra)
    assert len(remaining) == len(extra)
    assert {f.rule for f in remaining} == {"RC001"}


def test_baseline_is_line_number_stable(tmp_path):
    # baseline keys on (rule, path, source-line context), not line numbers:
    # inserting lines above a baselined finding must not resurrect it
    active = _scan("hs001_bad.py")["active"]
    bl = Baseline.from_findings(active)
    shifted = [type(f)(f.rule, f.path, f.line + 40, f.message, f.severity,
                       f.context) for f in active]
    assert bl.filter(shifted) == []


# ---------------------------------------------------------------------------
# the repo gate: src/repro carries zero unsuppressed AST findings
# ---------------------------------------------------------------------------

def test_repo_is_boomlint_clean_ast():
    res = run_paths([REPO_SRC], LintConfig(trace=False))
    assert res["active"] == [], [f.render() for f in res["active"]]


def test_repo_suppressions_carry_reasons():
    # every inline ignore in src/repro must say WHY
    import re
    for root, _dirs, names in os.walk(REPO_SRC):
        for n in names:
            if not n.endswith(".py"):
                continue
            with open(os.path.join(root, n), encoding="utf-8") as fh:
                for i, line in enumerate(fh, 1):
                    m = re.search(r"boomlint:\s*ignore\[[^\]]+\]\s*(.*)",
                                  line)
                    if m:
                        assert m.group(1).strip(), (
                            f"{n}:{i} suppression without a reason")


# ---------------------------------------------------------------------------
# config / estimator pins
# ---------------------------------------------------------------------------

def test_registered_shape_values_cover_grids():
    vals = registered_shape_values()
    for v in (1, 2, 4, 8, 16, 32, 2048, 8192, 32768, 131072, 1024, 256,
              64):
        assert v in vals, v


def test_vmem_envelope_fits_default_budget():
    from repro.analysis import tracepass
    assert tracepass.check_vmem_envelope(LintConfig()) == []


def test_vmem_envelope_detects_overflow():
    from repro.analysis import tracepass
    found = tracepass.check_vmem_envelope(LintConfig(vmem_budget=1024))
    # all five registered kernel envelopes (masked_topk, int8_scan,
    # gather_score, int8_gather_score, beam_search) blow a 1 KiB budget
    assert _rules(found) == {"PL001": 5}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_exit_codes(tmp_path, capsys):
    bad = os.path.join(FIXTURES, "rc001_bad.py")
    assert cli.main([bad, "--no-trace"]) == 1
    out = capsys.readouterr().out
    assert "RC001" in out
    clean = os.path.join(FIXTURES, "rc001_clean.py")
    assert cli.main([clean, "--no-trace"]) == 0


def test_cli_json_output(capsys):
    bad = os.path.join(FIXTURES, "rc001_bad.py")
    assert cli.main([bad, "--no-trace", "--json"]) == 1
    import json
    payload = json.loads(capsys.readouterr().out)
    assert {f["rule"] for f in payload} == {"RC001"}
    assert all({"rule", "path", "line", "message", "severity"} <= set(f)
               for f in payload)


def test_cli_baseline_workflow(tmp_path, capsys):
    bad = os.path.join(FIXTURES, "rc001_bad.py")
    bl = str(tmp_path / "bl.json")
    assert cli.main([bad, "--no-trace", "--write-baseline", bl]) == 0
    capsys.readouterr()
    assert cli.main([bad, "--no-trace", "--baseline", bl]) == 0


# the full level-2 gate (tracing real kernels) runs in CI via the boomlint
# step; here a marked smoke keeps it honest under plain pytest too
@pytest.mark.slow
def test_trace_checks_clean():
    from repro.analysis import tracepass
    assert tracepass.run_trace_checks(LintConfig()) == []
