"""End-to-end behaviour: the paper's pipeline on a small table, plus the
input-spec deliverable and engine personalities."""

import numpy as np

import jax

from repro.bench import datasets, queries
from repro.core.boomhq import BoomHQ, BoomHQConfig
from repro.core.data_encoder import DataEncoderConfig
from repro.core.executor import recall_at_k
from repro.core.rewriter import RewriterConfig
from repro.vectordb import flat


def _cfg():
    return BoomHQConfig(
        n_clusters=16,
        encoder=DataEncoderConfig(frozen_steps=30, ae_steps=50, sample=512),
        rewriter=RewriterConfig(steps=120, refine_columns=False))


def test_full_pipeline_meets_recall_targets():
    table = datasets.make("aka_title", rows=2500, seed=4)
    wl = queries.gen_workload(table, 26, n_vec_used=2, seed=5)
    bq = BoomHQ(table, _cfg())
    metrics = bq.fit(wl[:18])
    assert metrics["strategy_acc"] > 0.4
    recs = []
    for q in wl[18:]:
        gt, _ = flat.ground_truth(table, list(q.query_vectors),
                                  list(q.weights), q.predicates, q.k)
        ids, scores = bq.execute(q)
        recs.append(recall_at_k(ids, gt))
        # scores sorted descending among valid entries
        s = np.asarray(scores)
        valid = s > -1e29
        assert (np.diff(s[valid]) <= 1e-5).all()
    assert np.mean(recs) >= 0.7


def test_plans_adapt_across_queries():
    table = datasets.make("part", rows=2500, seed=6)
    wl = queries.gen_workload(table, 40, n_vec_used=2, seed=7)
    bq = BoomHQ(table, _cfg())
    bq.fit(wl[:30])
    plans = [bq.optimize(q) for q in wl[30:]]
    descs = {p.describe() for p in plans}
    assert len(descs) >= 2, descs  # per-query adaptation, not one static plan


def test_input_specs_cover_all_cells():
    from repro import configs
    from repro.configs.base import SHAPES
    from repro.launch.input_specs import input_specs

    n_cells = 0
    for arch in configs.ARCHS:
        for shape in SHAPES:
            specs = input_specs(arch, shape)
            assert isinstance(specs, dict) and specs
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
            n_cells += 1
    assert n_cells == 40  # the assigned 10 archs × 4 shapes


def test_engine_personalities_registered():
    from repro.core.executor import ENGINES

    assert set(ENGINES) == {"pgvector", "milvus", "opensearch"}
    assert ENGINES["pgvector"].iterative_scan
    assert not ENGINES["milvus"].iterative_scan
    assert not ENGINES["opensearch"].max_scan_tuples
