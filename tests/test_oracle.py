"""Recall floors against the pure-NumPy brute-force oracle (tests/oracle.py).

Every execution path — flat scans, IVF at generous budgets, the batched
executor, and the cross-shard fan-out — is measured against ground truth
that shares NO code with the kernels: previously the batched/distributed
paths were only checked against each other, so a shared bug was invisible.
Floors are recall >= 0.95 at generous budgets (the exact paths must hit
1.0 up to float ties).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from oracle import (
    brute_force_topk, eval_mask_np, sharded_brute_force_topk,
    tie_aware_recall,
)

from repro.bench import queries
from repro.core.executor import HybridExecutor
from repro.core.query import ExecutionPlan, SubqueryParams, default_plan
from repro.serve.batch import (
    BatchedHybridExecutor, SHARDED_LOCAL, CostModel, compute_batch_scores,
)
from repro.vectordb import flat, ivf
from repro.vectordb.predicates import clause_bucket, eval_mask

FLOOR = 0.95


def _mixed_workload(table, *, n_conj=5, n_dnf=5, seed=31):
    return queries.gen_workload(table, n_conj, n_vec_used=2, seed=seed) + \
        queries.gen_dnf_workload(table, n_dnf, n_vec_used=2, seed=seed + 1,
                                 clause_counts=(2, 3, 4))


def _oracle_recall(table, q, ids) -> float:
    _, _, masked = brute_force_topk(
        table, list(q.query_vectors), list(q.weights), q.predicates, q.k)
    return tie_aware_recall(ids, masked, q.k)


def test_oracle_mask_agrees_with_kernel(tiny_table):
    """The NumPy mask oracle and the jax evaluator must agree row-for-row —
    a disagreement means one of them mis-reads the DNF fields."""
    t = tiny_table
    for q in _mixed_workload(t, seed=37):
        a = eval_mask_np(q.predicates, np.asarray(t.scalars))
        b = np.asarray(eval_mask(q.predicates, t.scalars))
        np.testing.assert_array_equal(a, b)


def test_masked_scan_matches_oracle(tiny_table):
    """The flat masked scan is the repo's internal ground truth — the
    independent oracle must rate it 1.0 (up to float ties)."""
    t = tiny_table
    for q in _mixed_workload(t):
        ids, _, _, _ = flat.masked_scan(
            tuple(t.vectors), t.scalars, q.predicates,
            tuple(q.query_vectors), jnp.asarray(q.weights, jnp.float32),
            t.schema.metric, k=q.k, n_vec=t.schema.n_vec)
        assert _oracle_recall(t, q, np.asarray(ids)) == 1.0


def test_filter_first_generous_matches_oracle(tiny_table):
    """filter_first with an uncapped gather is exact."""
    t = tiny_table
    for q in _mixed_workload(t, seed=41):
        ids, _, _, _ = flat.filter_first(
            tuple(t.vectors), t.scalars, q.predicates,
            tuple(q.query_vectors), jnp.asarray(q.weights, jnp.float32),
            t.schema.metric, k=q.k, max_candidates=t.n_rows,
            n_vec=t.schema.n_vec)
        assert _oracle_recall(t, q, np.asarray(ids)) == 1.0


def test_ivf_generous_budget_recall_floor(tiny_table):
    """Single-column IVF probing every cluster with an uncapped scan must
    clear the floor (it degenerates to an exhaustive filtered scan)."""
    t = tiny_table
    idx = ivf.build(t.vectors[0], 16, seed=0, metric=t.schema.metric)
    rng = np.random.default_rng(5)
    for q in _mixed_workload(t, seed=43):
        qv = jnp.asarray(rng.normal(size=t.vectors[0].shape[1]).astype(np.float32))
        ids, _, _, _ = ivf.search(
            idx, t.vectors[0], t.scalars, q.predicates, qv,
            nprobe=idx.n_clusters, max_scan=t.n_rows, k=q.k)
        _, _, masked = brute_force_topk(
            t, [np.asarray(qv)] + [np.zeros_like(np.asarray(v[0]))
                                   for v in t.vectors[1:]],
            [1.0] + [0.0] * (t.schema.n_vec - 1), q.predicates, q.k)
        assert tie_aware_recall(np.asarray(ids), masked, q.k) >= FLOOR


@pytest.mark.slow
def test_batched_path_recall_floor(fitted):
    """The batched executor at generous budgets (the robust default plan:
    full probes, scan cap above the table) must clear the mean-recall floor
    on the fitted fixture, conjunctive and DNF alike.

    The floor is on the MEAN: index_scan generates candidates per column,
    so a balanced-weight query's global top-k row can rank below top-k_i in
    every individual column — a structural property of the paper's
    two-phase flow, not a kernel bug (the exact paths below are held to
    per-query 1.0)."""
    bq, test = fitted
    bx = BatchedHybridExecutor(bq.table, bq.indexes, bq.engine)
    plans = [default_plan(q.n_vec, bq.engine) for q in test]
    results = bx.execute_batch(test, plans)
    recs = [_oracle_recall(bq.table, q, ids)
            for q, (ids, _) in zip(test, results)]
    assert float(np.mean(recs)) >= FLOOR, recs
    assert min(recs) >= 0.5, recs


@pytest.mark.slow
def test_cross_shard_recall_floor_and_acceptance(fitted):
    """Acceptance: oracle-measured recall of the cross-shard EXACT scan
    (cost model pinned dense — the default router sends this tiny table's
    index groups single-device) matches (>=, up to float ties) the
    single-shard batched path on the fitted fixture, and both the 2- and
    4-shard meshes clear the exact-path floor of 1.0."""
    from repro.serve.batch import DENSE

    bq, test = fitted
    single = bq.execute_batch(test)  # learned plans + escalation
    recs_single = [_oracle_recall(bq.table, q, ids)
                   for q, (ids, _) in zip(test, single)]
    try:
        bq.bind_cost_model(CostModel(force=DENSE))
        for n_shards in (2, 4):
            assert bq.table.n_rows % n_shards == 0
            bq.bind_shards(n_shards)
            sharded = bq.execute_batch(test)
            recs_sh = [_oracle_recall(bq.table, q, ids)
                       for q, (ids, _) in zip(test, sharded)]
            # exact sharded scan: floor is 1.0 up to float ties
            assert min(recs_sh) >= FLOOR, (n_shards, recs_sh)
            for rs, r1 in zip(recs_sh, recs_single):
                assert rs >= r1 - 1e-9, (n_shards, rs, r1)
    finally:
        # restore the shared fixture to single-shard + calibrated model
        bq.bind_shards().bind_cost_model()


@pytest.mark.slow
def test_sharded_ivf_learned_acceptance(fitted):
    """Acceptance (satellite): the sharded-IVF LEARNED path — per-shard
    probing driven by the same learned plans, with per-shard escalation —
    reaches oracle recall no worse than the single-shard learned path on
    the fitted fixture (mean level; per-shard probing covers at least the
    single index's neighborhoods at generous fan-out)."""
    bq, test = fitted
    single = bq.execute_batch(test)
    mean_single = float(np.mean([_oracle_recall(bq.table, q, ids)
                                 for q, (ids, _) in zip(test, single)]))
    try:
        # the fixture's shards sit under min_shard_rows: pin the probing
        # path so the learned sharded route is what's measured
        bq.bind_cost_model(CostModel(force=SHARDED_LOCAL))
        for n_shards in (2, 4):
            bq.bind_shards(n_shards)
            sharded = bq.execute_batch(test)
            mean_sh = float(np.mean([_oracle_recall(bq.table, q, ids)
                                     for q, (ids, _) in zip(test, sharded)]))
            assert mean_sh >= mean_single - 1e-3, (n_shards, mean_sh,
                                                   mean_single)
    finally:
        bq.bind_shards().bind_cost_model()


def test_sharded_oracle_merge_matches_global(tiny_table):
    """The pure-NumPy sharded oracle (per-shard exact top-k + candidate
    merge) must agree with the global brute force score-for-score — pins
    that the merge semantics every sharded path is tested against loses
    nothing."""
    t = tiny_table
    for q in _mixed_workload(t, seed=71):
        g_ids, g_scores, _ = brute_force_topk(
            t, list(q.query_vectors), list(q.weights), q.predicates, q.k)
        for s in (2, 4, 7):
            s_ids, s_scores, _ = sharded_brute_force_topk(
                t, list(q.query_vectors), list(q.weights), q.predicates,
                q.k, n_shards=s)
            np.testing.assert_allclose(s_scores, g_scores, atol=1e-12)
            assert set(s_ids[s_ids >= 0]) == set(g_ids[g_ids >= 0]) or \
                np.allclose(np.sort(s_scores), np.sort(g_scores))


# ---------------------------------------------------------------------------
# three-way parity: sequential vs execute_batch vs sharded-IVF learned path
# ---------------------------------------------------------------------------

def _single_col_mixed_wl(t, *, n_conj=4, n_dnf=4, seed=31):
    """Mixed clause-bucket workload with ONE active vector column per
    query: single-column index_scan at exhaustive budgets (nprobe = all
    clusters, max_scan = table, k_i >= k) IS the exact filtered top-k —
    the candidates are the top-k_i QUALIFYING rows of the only scored
    column — so strict three-way parity is mathematically well-defined.
    (Multi-column index_scan is structurally approximate — the ROADMAP's
    per-column candidate gap — and the sharded union is a superset of the
    single-device one, so those are held to one-sided floors instead.)"""
    return queries.gen_workload(t, n_conj, n_vec_used=1, seed=seed) + \
        queries.gen_dnf_workload(t, n_dnf, n_vec_used=1, seed=seed + 1,
                                 clause_counts=(2, 3, 4))


def _three_way_sharded_ivf(t, wl, *, shard_counts=(2, 5)):
    idx = [ivf.build(v, 16, seed=i, metric=t.schema.metric)
           for i, v in enumerate(t.vectors)]
    seq = HybridExecutor(t, idx)
    bx = BatchedHybridExecutor(t, idx)
    plan = ExecutionPlan("index_scan", tuple(
        SubqueryParams(k_mult=4, nprobe=64, max_scan=t.n_rows,
                       iterative=False) for _ in range(t.schema.n_vec)))
    plans = [plan] * len(wl)
    batched = bx.execute_batch(wl, plans)
    sharded = {s: BatchedHybridExecutor(
        t, idx, n_shards=s, cost_model=CostModel(force=SHARDED_LOCAL)
    ).execute_batch_sharded(wl, plans) for s in shard_counts}
    for j, q in enumerate(wl):
        ids_s, scores_s = seq.execute(q, plan)
        assert _oracle_recall(t, q, np.asarray(ids_s)) == 1.0
        assert _oracle_recall(t, q, batched[j][0]) == 1.0
        valid = np.asarray(ids_s) >= 0
        for s in shard_counts:
            ids_x, scores_x = sharded[s][j]
            assert _oracle_recall(t, q, ids_x) == 1.0
            np.testing.assert_allclose(
                np.sort(np.asarray(scores_x)[np.asarray(ids_x) >= 0]),
                np.sort(np.asarray(scores_s)[valid]), atol=1e-4, rtol=1e-5)


def test_sharded_ivf_three_way_parity_corpus(tiny_table):
    """Deterministic corpus (always runs): mixed clause-bucket batches
    through the sequential executor, execute_batch, and the sharded-IVF
    learned path on a divisible (2) and a padded (7: 1500 % 7 != 0)
    shard split."""
    t = tiny_table
    assert t.n_rows % 7 != 0  # the 7-way split genuinely exercises padding
    for seed in (301, 402):
        wl = _single_col_mixed_wl(t, seed=seed)
        assert len({clause_bucket(q.predicates) for q in wl}) >= 2
        _three_way_sharded_ivf(t, wl, shard_counts=(2, 7))


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 50_000))
def test_sharded_ivf_three_way_parity_property(tiny_table, seed):
    """Hypothesis sweep of the same three-way parity over random mixed
    clause-bucket workloads."""
    t = tiny_table
    _three_way_sharded_ivf(
        t, _single_col_mixed_wl(t, n_conj=3, n_dnf=3, seed=seed),
        shard_counts=(4,))


def test_sharded_ivf_multicolumn_never_below_batched(tiny_table):
    """Multi-column index_scan: the per-shard candidate union is a
    SUPERSET of the single-device one, so at identical plans the
    sharded-IVF oracle recall can only be >= the batched path's,
    per query."""
    t = tiny_table
    idx = [ivf.build(v, 16, seed=i, metric=t.schema.metric)
           for i, v in enumerate(t.vectors)]
    bx = BatchedHybridExecutor(t, idx)
    wl = _mixed_workload(t, seed=83)
    plan = ExecutionPlan("index_scan", tuple(
        SubqueryParams(k_mult=4, nprobe=64, max_scan=t.n_rows,
                       iterative=False) for _ in range(t.schema.n_vec)))
    plans = [plan] * len(wl)
    batched = bx.execute_batch(wl, plans)
    for s in (2, 4):
        bxs = BatchedHybridExecutor(
            t, idx, n_shards=s, cost_model=CostModel(force=SHARDED_LOCAL))
        sharded = bxs.execute_batch_sharded(wl, plans)
        for q, (ids_b, _), (ids_x, _) in zip(wl, batched, sharded):
            assert _oracle_recall(t, q, ids_x) >= \
                _oracle_recall(t, q, ids_b) - 1e-9


@pytest.mark.slow
def test_cross_shard_executor_oracle_exactness(tiny_table):
    """execute_batch_sharded (logical shards, divisible and not) is the
    exact filtered top-k according to the independent oracle."""
    t = tiny_table
    idx = [ivf.build(v, 16, seed=i, metric=t.schema.metric)
           for i, v in enumerate(t.vectors)]
    wl = _mixed_workload(t, seed=47)
    scores_b = compute_batch_scores(t, wl)
    for n_shards in (2, 7):  # 1500 % 2 == 0; 7 exercises the pad path
        bx = BatchedHybridExecutor(t, idx, n_shards=n_shards)
        results = bx.execute_batch_sharded(wl, scores_b=scores_b)
        for q, (ids, _) in zip(wl, results):
            assert _oracle_recall(t, q, ids) == 1.0


@pytest.mark.slow
def test_both_scoring_paths_recall_floor(fitted):
    """Acceptance: BOTH dispatcher scoring paths — dense GEMM and the
    candidate-local fused gather+score — clear the oracle recall floor on
    the fitted fixture end-to-end (learned plans + escalation), and the
    candidate-local mean tracks the dense mean."""
    from repro.serve.batch import CANDIDATE_LOCAL, DENSE, CostModel

    bq, test = fitted
    means = {}
    try:
        for force in (DENSE, CANDIDATE_LOCAL):
            bq.bind_cost_model(CostModel(force=force))
            results = bq.execute_batch(test)
            recs = [_oracle_recall(bq.table, q, ids)
                    for q, (ids, _) in zip(test, results)]
            assert float(np.mean(recs)) >= FLOOR, (force, recs)
            means[force] = float(np.mean(recs))
    finally:
        bq.bind_cost_model()  # restore the shared fixture
    assert abs(means[CANDIDATE_LOCAL] - means[DENSE]) <= 0.02, means


def test_candidate_local_generous_budget_is_exact(tiny_table):
    """Candidate-local filter_first with an uncapped gather is the exact
    filtered top-k according to the independent oracle — the same bar the
    dense escalation plan is held to."""
    from repro.serve.batch import CANDIDATE_LOCAL, CostModel
    from repro.serve.batch import BatchedHybridExecutor as BX

    t = tiny_table
    idx = [ivf.build(v, 16, seed=i, metric=t.schema.metric)
           for i, v in enumerate(t.vectors)]
    bx = BX(t, idx, cost_model=CostModel(force=CANDIDATE_LOCAL))
    wl = _mixed_workload(t, n_conj=3, n_dnf=3, seed=59)
    plans = [ExecutionPlan(
        "filter_first", tuple(SubqueryParams() for _ in range(q.n_vec)),
        max_candidates=t.n_rows) for q in wl]
    for q, (ids, _) in zip(wl, bx.execute_batch(wl, plans)):
        assert _oracle_recall(t, q, ids) == 1.0


def test_escalation_plan_is_exact(tiny_table):
    """The sharded underfill-escalation cross-check (filter_first with an
    uncapped gather) must itself be oracle-exact."""
    t = tiny_table
    idx = [ivf.build(v, 16, seed=i, metric=t.schema.metric)
           for i, v in enumerate(t.vectors)]
    bx = BatchedHybridExecutor(t, idx)
    wl = _mixed_workload(t, n_conj=3, n_dnf=3, seed=53)
    plans = [ExecutionPlan(
        "filter_first", tuple(SubqueryParams() for _ in range(q.n_vec)),
        max_candidates=t.n_rows) for q in wl]
    for q, (ids, _) in zip(wl, bx.execute_batch(wl, plans)):
        assert _oracle_recall(t, q, ids) == 1.0
