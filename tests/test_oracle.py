"""Recall floors against the pure-NumPy brute-force oracle (tests/oracle.py).

Every execution path — flat scans, IVF at generous budgets, the batched
executor, and the cross-shard fan-out — is measured against ground truth
that shares NO code with the kernels: previously the batched/distributed
paths were only checked against each other, so a shared bug was invisible.
Floors are recall >= 0.95 at generous budgets (the exact paths must hit
1.0 up to float ties).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from oracle import brute_force_topk, eval_mask_np, tie_aware_recall

from repro.bench import queries
from repro.core.query import ExecutionPlan, SubqueryParams, default_plan
from repro.serve.batch import BatchedHybridExecutor, compute_batch_scores
from repro.vectordb import flat, ivf
from repro.vectordb.predicates import eval_mask

FLOOR = 0.95


def _mixed_workload(table, *, n_conj=5, n_dnf=5, seed=31):
    return queries.gen_workload(table, n_conj, n_vec_used=2, seed=seed) + \
        queries.gen_dnf_workload(table, n_dnf, n_vec_used=2, seed=seed + 1,
                                 clause_counts=(2, 3, 4))


def _oracle_recall(table, q, ids) -> float:
    _, _, masked = brute_force_topk(
        table, list(q.query_vectors), list(q.weights), q.predicates, q.k)
    return tie_aware_recall(ids, masked, q.k)


def test_oracle_mask_agrees_with_kernel(tiny_table):
    """The NumPy mask oracle and the jax evaluator must agree row-for-row —
    a disagreement means one of them mis-reads the DNF fields."""
    t = tiny_table
    for q in _mixed_workload(t, seed=37):
        a = eval_mask_np(q.predicates, np.asarray(t.scalars))
        b = np.asarray(eval_mask(q.predicates, t.scalars))
        np.testing.assert_array_equal(a, b)


def test_masked_scan_matches_oracle(tiny_table):
    """The flat masked scan is the repo's internal ground truth — the
    independent oracle must rate it 1.0 (up to float ties)."""
    t = tiny_table
    for q in _mixed_workload(t):
        ids, _, _, _ = flat.masked_scan(
            tuple(t.vectors), t.scalars, q.predicates,
            tuple(q.query_vectors), jnp.asarray(q.weights, jnp.float32),
            t.schema.metric, k=q.k, n_vec=t.schema.n_vec)
        assert _oracle_recall(t, q, np.asarray(ids)) == 1.0


def test_filter_first_generous_matches_oracle(tiny_table):
    """filter_first with an uncapped gather is exact."""
    t = tiny_table
    for q in _mixed_workload(t, seed=41):
        ids, _, _, _ = flat.filter_first(
            tuple(t.vectors), t.scalars, q.predicates,
            tuple(q.query_vectors), jnp.asarray(q.weights, jnp.float32),
            t.schema.metric, k=q.k, max_candidates=t.n_rows,
            n_vec=t.schema.n_vec)
        assert _oracle_recall(t, q, np.asarray(ids)) == 1.0


def test_ivf_generous_budget_recall_floor(tiny_table):
    """Single-column IVF probing every cluster with an uncapped scan must
    clear the floor (it degenerates to an exhaustive filtered scan)."""
    t = tiny_table
    idx = ivf.build(t.vectors[0], 16, seed=0, metric=t.schema.metric)
    rng = np.random.default_rng(5)
    for q in _mixed_workload(t, seed=43):
        qv = jnp.asarray(rng.normal(size=t.vectors[0].shape[1]).astype(np.float32))
        ids, _, _, _ = ivf.search(
            idx, t.vectors[0], t.scalars, q.predicates, qv,
            nprobe=idx.n_clusters, max_scan=t.n_rows, k=q.k)
        _, _, masked = brute_force_topk(
            t, [np.asarray(qv)] + [np.zeros_like(np.asarray(v[0]))
                                   for v in t.vectors[1:]],
            [1.0] + [0.0] * (t.schema.n_vec - 1), q.predicates, q.k)
        assert tie_aware_recall(np.asarray(ids), masked, q.k) >= FLOOR


@pytest.mark.slow
def test_batched_path_recall_floor(fitted):
    """The batched executor at generous budgets (the robust default plan:
    full probes, scan cap above the table) must clear the mean-recall floor
    on the fitted fixture, conjunctive and DNF alike.

    The floor is on the MEAN: index_scan generates candidates per column,
    so a balanced-weight query's global top-k row can rank below top-k_i in
    every individual column — a structural property of the paper's
    two-phase flow, not a kernel bug (the exact paths below are held to
    per-query 1.0)."""
    bq, test = fitted
    bx = BatchedHybridExecutor(bq.table, bq.indexes, bq.engine)
    plans = [default_plan(q.n_vec, bq.engine) for q in test]
    results = bx.execute_batch(test, plans)
    recs = [_oracle_recall(bq.table, q, ids)
            for q, (ids, _) in zip(test, results)]
    assert float(np.mean(recs)) >= FLOOR, recs
    assert min(recs) >= 0.5, recs


@pytest.mark.slow
def test_cross_shard_recall_floor_and_acceptance(fitted):
    """Acceptance: oracle-measured recall of the cross-shard batched path
    matches (>=, up to float ties) the single-shard batched path on the
    fitted fixture, and both the 2- and 4-shard meshes clear the exact-path
    floor of 1.0."""
    bq, test = fitted
    single = bq.execute_batch(test)  # learned plans + escalation
    recs_single = [_oracle_recall(bq.table, q, ids)
                   for q, (ids, _) in zip(test, single)]
    try:
        for n_shards in (2, 4):
            assert bq.table.n_rows % n_shards == 0
            bq.bind_shards(n_shards)
            sharded = bq.execute_batch(test)
            recs_sh = [_oracle_recall(bq.table, q, ids)
                       for q, (ids, _) in zip(test, sharded)]
            # exact sharded scan: floor is 1.0 up to float ties
            assert min(recs_sh) >= FLOOR, (n_shards, recs_sh)
            for rs, r1 in zip(recs_sh, recs_single):
                assert rs >= r1 - 1e-9, (n_shards, rs, r1)
    finally:
        bq.bind_shards()  # restore the shared fixture to single-shard


@pytest.mark.slow
def test_cross_shard_executor_oracle_exactness(tiny_table):
    """execute_batch_sharded (logical shards, divisible and not) is the
    exact filtered top-k according to the independent oracle."""
    t = tiny_table
    idx = [ivf.build(v, 16, seed=i, metric=t.schema.metric)
           for i, v in enumerate(t.vectors)]
    wl = _mixed_workload(t, seed=47)
    scores_b = compute_batch_scores(t, wl)
    for n_shards in (2, 7):  # 1500 % 2 == 0; 7 exercises the pad path
        bx = BatchedHybridExecutor(t, idx, n_shards=n_shards)
        results = bx.execute_batch_sharded(wl, scores_b=scores_b)
        for q, (ids, _) in zip(wl, results):
            assert _oracle_recall(t, q, ids) == 1.0


@pytest.mark.slow
def test_both_scoring_paths_recall_floor(fitted):
    """Acceptance: BOTH dispatcher scoring paths — dense GEMM and the
    candidate-local fused gather+score — clear the oracle recall floor on
    the fitted fixture end-to-end (learned plans + escalation), and the
    candidate-local mean tracks the dense mean."""
    from repro.serve.batch import CANDIDATE_LOCAL, DENSE, CostModel

    bq, test = fitted
    means = {}
    try:
        for force in (DENSE, CANDIDATE_LOCAL):
            bq.bind_cost_model(CostModel(force=force))
            results = bq.execute_batch(test)
            recs = [_oracle_recall(bq.table, q, ids)
                    for q, (ids, _) in zip(test, results)]
            assert float(np.mean(recs)) >= FLOOR, (force, recs)
            means[force] = float(np.mean(recs))
    finally:
        bq.bind_cost_model()  # restore the shared fixture
    assert abs(means[CANDIDATE_LOCAL] - means[DENSE]) <= 0.02, means


def test_candidate_local_generous_budget_is_exact(tiny_table):
    """Candidate-local filter_first with an uncapped gather is the exact
    filtered top-k according to the independent oracle — the same bar the
    dense escalation plan is held to."""
    from repro.serve.batch import CANDIDATE_LOCAL, CostModel
    from repro.serve.batch import BatchedHybridExecutor as BX

    t = tiny_table
    idx = [ivf.build(v, 16, seed=i, metric=t.schema.metric)
           for i, v in enumerate(t.vectors)]
    bx = BX(t, idx, cost_model=CostModel(force=CANDIDATE_LOCAL))
    wl = _mixed_workload(t, n_conj=3, n_dnf=3, seed=59)
    plans = [ExecutionPlan(
        "filter_first", tuple(SubqueryParams() for _ in range(q.n_vec)),
        max_candidates=t.n_rows) for q in wl]
    for q, (ids, _) in zip(wl, bx.execute_batch(wl, plans)):
        assert _oracle_recall(t, q, ids) == 1.0


def test_escalation_plan_is_exact(tiny_table):
    """The sharded underfill-escalation cross-check (filter_first with an
    uncapped gather) must itself be oracle-exact."""
    t = tiny_table
    idx = [ivf.build(v, 16, seed=i, metric=t.schema.metric)
           for i, v in enumerate(t.vectors)]
    bx = BatchedHybridExecutor(t, idx)
    wl = _mixed_workload(t, n_conj=3, n_dnf=3, seed=53)
    plans = [ExecutionPlan(
        "filter_first", tuple(SubqueryParams() for _ in range(q.n_vec)),
        max_candidates=t.n_rows) for q in wl]
    for q, (ids, _) in zip(wl, bx.execute_batch(wl, plans)):
        assert _oracle_recall(t, q, ids) == 1.0
