"""Seeded SM001 violation: shard_map body closing over the full table."""
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def sharded_scores(mesh, table, queries):
    def local(q):
        # SM001: `table` is captured, not passed through in_specs — it
        # replicates to every device instead of being sharded
        return q @ table.T

    return shard_map(local, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P("data"))(queries)


def sharded_gather(mesh, vectors, idx):
    def local(i):
        return vectors[i]  # SM001: captured array subscripted in the body

    return shard_map(local, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P("data"))(idx)
