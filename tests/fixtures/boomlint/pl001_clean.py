"""Clean twin of pl001_bad: tiles sized inside the VMEM budget."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, scratch):
    o_ref[...] = x_ref[...] * 2.0


def small_tile(x):
    # 1024×256 f32 tile + scratch = 2 MiB — well inside the budget
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec((1024, 256), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1024, 256), lambda i: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1024, 256), jnp.float32)],
    )(x)
