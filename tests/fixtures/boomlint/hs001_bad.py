"""Seeded HS001 violations: host syncs on traced values."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def item_in_jit(x):
    s = jnp.sum(x)
    return s.item()  # HS001: .item() on a traced value


@jax.jit
def coerce_in_jit(x):
    t = jnp.max(x)
    return x / float(t)  # HS001: float() of a traced value


@jax.jit
def branch_in_jit(x):
    m = jnp.mean(x)
    if m > 0:  # HS001: truthiness of a traced value
        return x - m
    return x


@jax.jit
def asarray_in_jit(x):
    y = x * 2
    return np.asarray(y)  # HS001: np call on a traced value


def hot_loop(batches):
    # qualname-matched hot function (configured in the test)
    out = []
    for b in batches:
        out.append(int(b.sum()))  # HS001: coercion inside a loop
    return out


def hot_duplicate(ids):
    a = np.asarray(ids)
    b = np.asarray(ids)  # HS001: repeated transfer of the same value
    return a, b
