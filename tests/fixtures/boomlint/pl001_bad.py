"""Seeded PL001 violation: literal Pallas tile shapes over the VMEM budget."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, scratch):
    o_ref[...] = x_ref[...] * 2.0


def big_tile(x):
    # PL001: 4096×1024 f32 tile + matching scratch = 32 MiB of VMEM
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec((4096, 1024), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4096, 1024), lambda i: (i, 0)),
        scratch_shapes=[pltpu.VMEM((4096, 1024), jnp.float32)],
    )(x)
