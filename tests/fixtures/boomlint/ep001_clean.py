"""EP001-clean twin: the same hot paths, reading tiered state only through
the snapshot accessor (and non-tiered private fields, which are exempt)."""


def hot_execute_batch(bq, queries):
    snap = bq.tiered.snapshot()  # ONE consistent (epoch, cold, hot) view
    return snap.cold, snap.hot_views, queries


def hot_merge(tiered, results):
    snap = tiered.snapshot()
    if snap.epoch > 0:  # epoch off the snapshot: immutable
        results.extend(snap.hot_views)
    return results


def hot_status(engine):
    # private fields of NON-tiered objects are not EP001's business
    return engine._pool, engine.bq.tiered.snapshot().epoch


def cold_ingest_path(bq, rows):
    return bq.tiered.snapshot(), rows
