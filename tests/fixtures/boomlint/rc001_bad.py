"""Seeded RC001 violations: recompile hazards at jitted entry points."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "mode"))
def topk_static(x, *, k, mode="dot"):
    return jax.lax.top_k(x, k)[0]


@functools.partial(jax.jit, static_argnames=("kk",))
def misnamed_static(x, *, k=4):  # RC001: 'kk' names no parameter
    return x * k


def caller(x):
    a = topk_static(x, k=48)  # RC001: 48 is no grid value / pow2 bucket
    b = topk_static(x, k=[4])  # RC001: unhashable list as static arg
    return a, b
