"""Suppression fixture: every violation carries a boomlint ignore."""
import jax
import jax.numpy as jnp


@jax.jit
def item_suppressed_inline(x):
    s = jnp.sum(x)
    return s.item()  # boomlint: ignore[HS001] fixture: intentional sync


@jax.jit
def item_suppressed_standalone(x):
    s = jnp.sum(x)
    # boomlint: ignore[HS001] fixture: standalone comment covers the
    # next code line even across continued comment lines
    return s.item()


@jax.jit
def item_not_suppressed(x):
    s = jnp.sum(x)
    # boomlint: ignore[RC001] wrong rule id — HS001 still fires here
    return s.item()
