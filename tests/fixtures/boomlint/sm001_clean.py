"""Clean twin of sm001_bad: arrays ride in_specs; scalars may close over."""
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def sharded_scores(mesh, table, queries, cfg=None):
    n, d = table.shape
    k = max(4, n // 128)  # host scalar — replication-free closure

    def local(t, q):
        scores = q @ t.T
        return jax.lax.top_k(scores, k)[0]  # closes over the scalar only

    return shard_map(local, mesh=mesh,
                     in_specs=(P("data"), P(None)),
                     out_specs=P(None))(table, queries)
