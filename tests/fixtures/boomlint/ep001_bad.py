"""Seeded EP001 violations: serving hot paths reading mutable tiered state
directly instead of through one batch-formation-time snapshot()."""


def hot_execute_batch(bq, queries):
    hot = bq.tiered._hot  # EP001: mutable hot buffer read in a hot path
    cold = bq.tiered._cold  # EP001: mutable cold pointer read
    return hot, cold, queries


def hot_merge(tiered, results):
    # EP001: epoch read races the background compaction's publish
    if tiered._epoch > 0:
        results.append(tiered._sealing)  # EP001: sealing generation read
    return results


def hot_status(engine):
    # _compacting is a progress flag, not part of any published snapshot
    return engine.bq.tiered._compacting  # EP001: compaction flag read


def cold_ingest_path(bq, rows):
    # NOT hot (qualname does not match the configured glob): same reads
    # are fine off the serving path — TieredTable's own methods and
    # offline tooling hold the lock or run single-threaded
    return bq.tiered._hot, rows
