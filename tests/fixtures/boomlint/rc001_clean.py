"""Clean twin of rc001_bad: static args drawn from registered buckets."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("k", "mode"))
def topk_static(x, *, k, mode="dot"):
    return jax.lax.top_k(x, k)[0]


def caller(x, k_runtime):
    a = topk_static(x, k=16)  # pow2 bucket — bounded compile set
    b = topk_static(x, k=32, mode="dot")  # registered grid value
    c = topk_static(x, k=k_runtime)  # a variable: bucketing happened
    return a, b, c
