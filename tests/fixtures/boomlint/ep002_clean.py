"""EP002-clean twin: the same hot paths, reading the cache only through
the token-checked lookup() or with an explicit freshness comparison."""


def hot_submit(engine, query):
    cached = engine.semcache.lookup(query, engine._cache_token())
    return cached, query  # lookup() enforces the (epoch, n_rows) token


def hot_serve_repeat(cache, key, k, token):
    entry = cache._index[key]
    if entry.token != token:  # explicit freshness check before the read
        return None
    return entry.ids[:k], entry.scores[:k]


def hot_rank(semcache, probe, token):
    out = []
    for entry in semcache._tenants[probe.tenant_id].values():
        if entry.token == token:  # fresh entries only
            out.append(entry.centroids)
    return out


def cold_report_path(cache, key):
    return cache._index[key].ids
