"""Seeded EP002 violations: serving hot paths reading semantic-cache entry
payloads without a freshness (token/epoch) check."""


def hot_submit(engine, query):
    entry = engine.semcache._tenants[query.tenant_id][0]
    return entry.ids, query  # EP002: raw payload read, no token check


def hot_serve_repeat(cache, key, k):
    entry = cache._index[key]
    ids = entry.ids[:k]  # EP002: stale entry can resurrect old epochs
    scores = entry.scores[:k]  # EP002: scores payload read
    return ids, scores


def hot_rank(semcache, probe):
    # EP002: centroid read drives a homegrown match loop that skips the
    # token discipline lookup() enforces
    return [entry.centroids  # EP002
            for entry in semcache._tenants[probe.tenant_id].values()]


def cold_report_path(cache, key):
    # NOT hot (qualname does not match the configured glob): offline
    # accounting may read entries directly
    return cache._index[key].ids
