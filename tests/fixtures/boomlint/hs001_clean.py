"""Clean twin of hs001_bad: the same shapes of code, no host syncs."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def sum_stays_on_device(x):
    return jnp.sum(x)


@jax.jit
def scale_on_device(x):
    t = jnp.max(x)
    return x / t  # stays traced — no coercion


@jax.jit
def branch_with_where(x):
    m = jnp.mean(x)
    return jnp.where(m > 0, x - m, x)  # lax-level select, no sync


@jax.jit
def shape_reads_are_static(x):
    n = x.shape[0]  # static metadata — never a transfer
    return x * float(n)  # float() of a static int is host arithmetic


def hot_loop_hoisted(batches):
    sums = np.asarray(jnp.stack([b.sum() for b in batches]))  # one transfer
    return [int(s) for s in sums]  # host-side ints after the sync


def hot_single_transfer(ids):
    a = np.asarray(ids)
    return a, a  # reuse the host value


def hot_rebound(run, ids):
    a = np.asarray(ids)
    ids = run(ids)  # rebound — the next transfer is a NEW value
    b = np.asarray(ids)
    return a, b


def hot_lazy_memo(mask, estimated):
    n_qual = None
    if not estimated:
        n_qual = np.asarray(jnp.sum(mask, axis=1))
    if n_qual is None:  # memo guard: at most one of the two sites runs
        n_qual = np.asarray(jnp.sum(mask, axis=1))
    return n_qual


def hot_exclusive_branches(mask, fast):
    if fast:
        n_qual = np.asarray(jnp.sum(mask, axis=1))
    else:
        n_qual = np.asarray(jnp.sum(mask, axis=1))  # other arm — one runs
    return n_qual
