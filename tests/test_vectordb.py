"""vectordb substrate: predicates, histograms, IVF, flat scans."""
import numpy as np

from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.vectordb import flat, histogram, ivf
from repro.vectordb.predicates import Predicates, eval_mask
from repro.vectordb.table import weighted_score


def test_eval_mask_conjunction(tiny_table):
    t = tiny_table
    pred = Predicates.from_conditions(
        t.schema.n_scalar, {2: (0.0, 2.0), 3: (100.0, np.inf)})
    mask = np.asarray(eval_mask(pred, t.scalars))
    scal = np.asarray(t.scalars)
    expect = (scal[:, 2] >= 0) & (scal[:, 2] <= 2) & (scal[:, 3] >= 100)
    assert np.array_equal(mask, expect)


def test_eval_mask_inactive_passes(tiny_table):
    t = tiny_table
    pred = Predicates.none(t.schema.n_scalar)
    assert np.asarray(eval_mask(pred, t.scalars)).all()


@settings(max_examples=20, deadline=None)
@given(lo=st.floats(0, 500), width=st.floats(0.1, 500), col=st.integers(2, 3))
def test_histogram_selectivity_close_to_exact(lo, width, col):
    rng = np.random.default_rng(42)
    scal = np.stack([rng.integers(0, 10, 4000).astype(np.float32),
                     rng.uniform(0, 1000, 4000).astype(np.float32),
                     rng.uniform(0, 1000, 4000).astype(np.float32),
                     rng.lognormal(3, 1, 4000).astype(np.float32)], axis=1)
    h = histogram.build(jnp.asarray(scal), n_bins=64)
    pred = Predicates.from_conditions(4, {col: (lo, lo + width)})
    est = float(histogram.estimate_selectivity(h, pred))
    exact = float(np.mean((scal[:, col] >= lo) & (scal[:, col] <= lo + width)))
    assert abs(est - exact) < 0.06  # histogram-resolution error bound


def test_dnf_selectivity_inclusion_exclusion_empirical():
    """Full inclusion–exclusion over C<=4 clauses (11 intersection terms at
    C=4) must track the empirical mask fraction of random DNF predicates on
    independent columns closely — and strictly beat the Bonferroni upper
    bound min(1, Σσ_c) it replaced."""
    from repro.vectordb.predicates import PredicateSet

    rng = np.random.default_rng(7)
    n, m = 20000, 4
    scal = rng.uniform(0, 1, (n, m)).astype(np.float32)
    h = histogram.build(jnp.asarray(scal), 64)
    err_ie, err_bon = [], []
    for _ in range(30):
        clauses = []
        for _ in range(int(rng.integers(2, 5))):
            cols = rng.choice(m, int(rng.integers(1, 3)), replace=False)
            clauses.append({int(c): tuple(sorted(rng.uniform(0, 1, 2)))
                            for c in cols})
        ps = PredicateSet.from_clauses(m, clauses)
        est = float(histogram.estimate_selectivity(h, ps))
        emp = float(np.mean(np.asarray(eval_mask(ps, jnp.asarray(scal)))))
        bon = min(1.0, sum(
            float(histogram._clause_selectivity(
                h, ps.lo[i], ps.hi[i], ps.active[i]))
            for i in range(len(clauses))))
        err_ie.append(abs(est - emp))
        err_bon.append(abs(bon - emp))
    assert float(np.max(err_ie)) < 0.05  # histogram-resolution error bound
    assert float(np.mean(err_ie)) < float(np.mean(err_bon))


def test_dnf_selectivity_union_identities():
    """Disjoint clauses sum; a nested clause adds nothing to the union."""
    from repro.vectordb.predicates import PredicateSet

    rng = np.random.default_rng(8)
    scal = rng.uniform(0, 1, (10000, 2)).astype(np.float32)
    h = histogram.build(jnp.asarray(scal), 64)
    disjoint = PredicateSet.from_clauses(
        2, [{0: (0.0, 0.2)}, {0: (0.5, 0.6)}, {0: (0.8, 0.9)}])
    est = float(histogram.estimate_selectivity(h, disjoint))
    assert abs(est - (0.2 + 0.1 + 0.1)) < 0.02
    nested = PredicateSet.from_clauses(
        2, [{0: (0.1, 0.9)}, {0: (0.3, 0.5)}])  # second ⊂ first
    est_n = float(histogram.estimate_selectivity(h, nested))
    assert abs(est_n - 0.8) < 0.02


def test_dnf_selectivity_ignores_inactive_bound_garbage():
    """Regression: the inclusion–exclusion intersection took max(lo)/min(hi)
    over ALL columns, so garbage bounds on INACTIVE columns (which eval_mask
    never reads — no producer is required to zero them) emptied real clause
    intersections and inflated the union estimate to ~1.0."""
    from repro.vectordb.predicates import PredicateSet

    rng = np.random.default_rng(11)
    n, m = 20000, 2
    scal = rng.uniform(0, 1, (n, m)).astype(np.float32)
    h = histogram.build(jnp.asarray(scal), 64)
    # clause 0 active on col0 only, clause 1 on col1 only; the inactive
    # column of each clause carries a garbage range disjoint from the
    # active one, which the broken intersection folded in
    active = jnp.asarray([[True, False], [False, True]])
    lo = jnp.asarray([[0.0, 0.9], [0.9, 0.0]], jnp.float32)
    hi = jnp.asarray([[0.5, 1.0], [1.0, 0.5]], jnp.float32)
    ps = PredicateSet(active=active, lo=lo, hi=hi,
                      clause_valid=jnp.asarray([True, True]))
    est = float(histogram.estimate_selectivity(h, ps))
    emp = float(np.mean(np.asarray(eval_mask(ps, jnp.asarray(scal)))))
    assert abs(emp - 0.75) < 0.02  # sanity: 0.5 + 0.5 - 0.25
    assert abs(est - emp) < 0.05  # broken code estimated ~1.0 here


def test_value_encode_bin_agrees_with_histogram_binning():
    """Regression: ``value_encode`` binned with searchsorted's default
    side="left" while histogram build/update/_prefix_at use side="right" —
    a scalar exactly ON an interior bin edge one-hotted into a different
    bin than the stats count it in. Pin bin agreement on boundary values."""
    from repro.vectordb.predicates import value_encode

    b = 16
    edges = jnp.linspace(0.0, 1.0, b + 1)[None, :]  # (1, B+1)
    h0 = histogram.Histograms(
        edges=edges, prefix=jnp.zeros((1, b + 1)), n_rows=jnp.asarray(0.0))
    interior = [float(edges[0, j]) for j in range(1, b)]
    off_edge = [0.03, 0.51, 0.999]
    for x in interior + off_edge:
        enc = np.asarray(value_encode(jnp.asarray([x]), edges))
        assert enc.shape == (1, b) and enc.sum() == 1.0
        h = histogram.update(h0, jnp.asarray([[x]]))
        counts = np.diff(np.asarray(h.prefix[0]))
        assert int(enc[0].argmax()) == int(counts.argmax()), x


def test_histogram_update_matches_rebuild():
    rng = np.random.default_rng(1)
    a = rng.uniform(0, 10, (2000, 2)).astype(np.float32)
    b = rng.uniform(0, 10, (500, 2)).astype(np.float32)  # same range
    h1 = histogram.update(histogram.build(jnp.asarray(a), 32), jnp.asarray(b))
    pred = Predicates.from_conditions(2, {0: (2.0, 5.0)})
    est1 = float(histogram.estimate_selectivity(h1, pred))
    exact = float(np.mean((np.concatenate([a, b])[:, 0] >= 2)
                          & (np.concatenate([a, b])[:, 0] <= 5)))
    assert abs(est1 - exact) < 0.05


def test_ivf_unfiltered_recall(tiny_table):
    t = tiny_table
    idx = ivf.build(t.vectors[0], 16, metric="dot")
    q = np.asarray(t.vectors[0][7])  # a data point: its NN is itself
    pred = Predicates.none(t.schema.n_scalar)
    ids, scores, n_scored, n_qual = ivf.search(
        idx, t.vectors[0], t.scalars, pred, jnp.asarray(q),
        nprobe=16, max_scan=t.n_rows, k=10)
    qs = [jnp.asarray(np.asarray(v[7])) for v in t.vectors]
    w = [1.0] + [0.0] * (t.schema.n_vec - 1)
    gt, _ = flat.ground_truth(t, qs, w, pred, 10)
    # full probe == exhaustive
    assert set(np.asarray(ids).tolist()) == set(np.asarray(gt).tolist())


def test_ivf_filtered_only_qualifying(tiny_table):
    t = tiny_table
    idx = ivf.build(t.vectors[0], 16)
    pred = Predicates.from_conditions(t.schema.n_scalar, {0: (3.0, 3.0)})
    q = jnp.asarray(np.asarray(t.vectors[0][3]))
    ids, _, _, _ = ivf.search(idx, t.vectors[0], t.scalars, pred, q,
                              nprobe=16, max_scan=t.n_rows, k=10)
    scal = np.asarray(t.scalars)
    for i in np.asarray(ids):
        if i >= 0:
            assert scal[i, 0] == 3.0


def test_ivf_extend_finds_new_rows(tiny_table):
    t = tiny_table
    idx = ivf.build(t.vectors[0], 16)
    new_vecs = np.asarray(t.vectors[0][:5]) + 1e-4
    idx2 = ivf.extend(idx, jnp.asarray(new_vecs), t.n_rows)
    assert idx2.sorted_rows.shape[0] == t.n_rows + 5
    t2 = t.append([new_vecs] + [np.asarray(v[:5]) for v in t.vectors[1:]],
                  np.asarray(t.scalars[:5]))
    pred = Predicates.none(t.schema.n_scalar)
    ids, _, _, _ = ivf.search(idx2, t2.vectors[0], t2.scalars, pred,
                              jnp.asarray(new_vecs[0]), nprobe=16,
                              max_scan=t2.n_rows, k=3)
    assert int(np.asarray(ids)[0]) in (t.n_rows, 0)  # the clone or original


def test_filter_first_matches_masked_scan(tiny_table):
    t = tiny_table
    pred = Predicates.from_conditions(t.schema.n_scalar, {3: (200.0, 800.0)})
    qs = tuple(jnp.asarray(np.asarray(v[11])) for v in t.vectors)
    w = jnp.asarray([0.6, 0.4])
    a_ids, a_s, _, _ = flat.filter_first(
        tuple(t.vectors), t.scalars, pred, qs, w, k=10,
        max_candidates=t.n_rows, n_vec=t.schema.n_vec)
    b_ids, b_s, _, _ = flat.masked_scan(
        tuple(t.vectors), t.scalars, pred, qs, w, k=10, n_vec=t.schema.n_vec)
    assert np.allclose(np.sort(np.asarray(a_s)), np.sort(np.asarray(b_s)),
                       atol=1e-4)


def test_weighted_score_definition(tiny_table):
    t = tiny_table
    qs = [jnp.asarray(np.asarray(v[0])) for v in t.vectors]
    w = jnp.asarray([0.3, 0.7])
    s = weighted_score(t, qs, w)
    manual = 0.3 * np.asarray(t.vectors[0]) @ np.asarray(qs[0]) \
        + 0.7 * np.asarray(t.vectors[1]) @ np.asarray(qs[1])
    assert np.allclose(np.asarray(s), manual, atol=1e-4)
