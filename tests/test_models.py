"""Model correctness: SSD-vs-naive oracle, RoPE, decode/prefill consistency,
MoE routing invariants, MLA absorbed-decode equivalence."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm, mamba2, moe, rotary
from repro.models.attention import chunked_causal_attention

ARCHS = configs.ARCHS


def test_ssd_matches_naive_recurrence():
    """The chunked SSD scan == the step-by-step SSM recurrence."""
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 24, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, h, n)), jnp.float32)
    y_ssd, st_ssd = mamba2.ssd_scan(x, dt, A, B, C, chunk=8)

    # naive: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t · h_t
    state = np.zeros((b, h, n, p), np.float32)
    ys = []
    for t in range(s):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])  # (b,h)
        state = state * decay[:, :, None, None] + np.einsum(
            "bh,bhn,bhp->bhnp", np.asarray(dt[:, t]), np.asarray(B[:, t]),
            np.asarray(x[:, t]))
        ys.append(np.einsum("bhn,bhnp->bhp", np.asarray(C[:, t]), state))
    y_naive = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_ssd), y_naive, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_ssd), state, atol=1e-3, rtol=1e-3)


def test_chunked_attention_matches_full():
    rng = np.random.default_rng(1)
    b, s, h, kv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    o1 = chunked_causal_attention(q, k, v, q_chunk=16, scale=0.25)
    o2 = chunked_causal_attention(q, k, v, q_chunk=64, scale=0.25)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    # causality: output at t must not depend on k/v after t
    k2 = k.at[:, s // 2:].set(0.0)
    v2 = v.at[:, s // 2:].set(0.0)
    o3 = chunked_causal_attention(q, k2, v2, q_chunk=16, scale=0.25)
    np.testing.assert_allclose(np.asarray(o1[:, : s // 2]),
                               np.asarray(o3[:, : s // 2]), atol=1e-4)


def test_rope_rotation_preserves_norm_and_relativity():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8)[None]
    y = rotary.apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               atol=1e-4)
    # partial rotary leaves the tail untouched
    y2 = rotary.apply_rope(x, pos, rotary_pct=0.25)
    np.testing.assert_allclose(np.asarray(y2[..., 4:]), np.asarray(x[..., 4:]))


def test_moe_capacity_and_combine():
    cfg = configs.get_config("kimi-k2-1t-a32b", smoke=True)
    rng = np.random.default_rng(3)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y, aux = moe.moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert 0.0 <= float(aux["moe_drop_frac"]) <= 1.0
    assert float(aux["moe_lb_loss"]) >= 0.99  # >= 1 at balance by Switch def


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """prefill + decode_step logits == full forward logits at that position."""
    cfg = configs.get_config(arch, smoke=True)
    if cfg.family == "moe":  # avoid capacity-drop mismatches between modes
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    rng = np.random.default_rng(5)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    b, p_len = 2, 12
    batch = {}
    if cfg.modality == "vlm":
        npre = cfg.n_prefix_embeds
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, npre, cfg.d_model)), jnp.float32)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, p_len + 1)), jnp.int32)
        batch["tokens"] = toks[:, :p_len]
        full = {"patch_embeds": batch["patch_embeds"], "tokens": toks}
        total_prompt = npre + p_len
    elif cfg.inputs_are_embeds:
        emb = jnp.asarray(rng.normal(size=(b, p_len + 1, cfg.d_model)), jnp.float32)
        batch["embeds"] = emb[:, :p_len]
        full = {"embeds": emb}
        total_prompt = p_len
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, p_len + 1)), jnp.int32)
        batch["tokens"] = toks[:, :p_len]
        full = {"tokens": toks}
        total_prompt = p_len

    logits_pre, cache = lm.prefill(params, cfg, batch, max_len=total_prompt + 4)
    if cfg.inputs_are_embeds:
        inp = {"embed": emb[:, p_len]}
    else:
        inp = {"token": toks[:, p_len]}
    logits_dec, _ = lm.decode_step(params, cfg, inp,
                                   jnp.asarray(total_prompt, jnp.int32), cache)
    h, _ = lm.hidden(params, cfg, full)
    logits_full = lm.unembed(params, cfg, h[:, -1])
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full),
                               atol=2e-2, rtol=2e-3)
    # and the prefill's own last-position logits match the forward at p_len-1
    logits_full_prev = lm.unembed(params, cfg, h[:, -2])
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full_prev), atol=2e-2, rtol=2e-3)


def test_mla_absorbed_decode_equals_expanded():
    """The compressed-cache decode must equal expanded-form attention."""
    cfg = configs.get_config("deepseek-v3-671b", smoke=True)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    rng = np.random.default_rng(7)
    params = lm.init(jax.random.PRNGKey(1), cfg)
    b, s = 2, 9
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)
    _, cache = lm.prefill(params, cfg, {"tokens": toks[:, :s]}, max_len=s + 2)
    logits_dec, _ = lm.decode_step(params, cfg, {"token": toks[:, s]},
                                   jnp.asarray(s, jnp.int32), cache)
    h, _ = lm.hidden(params, cfg, {"tokens": toks})
    logits_full = lm.unembed(params, cfg, h[:, -1])
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_full),
                               atol=2e-2, rtol=2e-3)


def test_vocab_padding_masks_pad_logits():
    cfg = configs.get_config("mamba2-370m", smoke=True)
    cfg = dataclasses.replace(cfg, vocab=250, vocab_pad_multiple=16)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    h = jnp.ones((1, 1, cfg.d_model), jnp.float32)
    logits = lm.unembed(params, cfg, h)
    assert logits.shape[-1] == cfg.vocab_padded == 256
    assert (np.asarray(logits[..., cfg.vocab:]) < -1e20).all()


def test_flash_attention_matches_naive():
    from repro.models.attention import flash_causal_attention
    rng = np.random.default_rng(4)
    b, s, h, kv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.float32)
    o_naive = chunked_causal_attention(q, k, v, q_chunk=16, scale=0.25)
    o_flash = flash_causal_attention(q, k, v, q_chunk=16, kv_chunk=8, scale=0.25)
    np.testing.assert_allclose(np.asarray(o_flash), np.asarray(o_naive),
                               atol=1e-4, rtol=1e-4)
    # with softcap and offset
    o1 = chunked_causal_attention(q, k, v, q_chunk=32, scale=0.25, softcap=20.0)
    o2 = flash_causal_attention(q, k, v, q_chunk=8, kv_chunk=16, scale=0.25,
                                softcap=20.0)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1), atol=1e-4,
                               rtol=1e-4)
