"""Semantic result cache: hit predicate, epoch-staleness contract, tenant
isolation — the oracle pins for docs/semantic_cache.md.

The load-bearing tests are the invalidation oracles: an entry cached at
epoch e is NEVER served at epoch e+1 (compaction moved rows the cached
result may depend on), and a hot-tier insert is visible to the very next
miss (any insert changes the ``(epoch, n_rows)`` token). Tenant isolation
is pinned both at the cache layer (hypothesis sweep) and end-to-end
through the predicate fold."""
import asyncio
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from oracle import brute_force_topk, eval_mask_np
from repro.bench import datasets, queries
from repro.core.boomhq import BoomHQ, BoomHQConfig
from repro.core.query import MHQ
from repro.core.rewriter import RewriterConfig
from repro.serve.queue import AsyncServingEngine
from repro.serve.semcache import (
    SemanticCache, k_bucket, predicate_signature, query_signature,
)
from repro.vectordb.algebra import col
from repro.vectordb.predicates import (
    Predicates, PredicateSet, fold_conjunct, pad_clauses,
)
from repro.vectordb.table import ScalarCol, Table

TENANTS = 3


def _mhq(vec, lo=0.0, hi=1.0, *, k=5, m=3, tenant=None) -> MHQ:
    return MHQ(
        query_vectors=(np.asarray(vec, np.float32),),
        weights=(1.0,),
        predicates=Predicates.from_conditions(m, {0: (lo, hi)}),
        k=k, tenant_id=tenant)


# -- signature canonicalization ----------------------------------------------

def test_signature_invariant_to_clause_order_and_padding():
    a = (col(0).between(0, 1) | col(1).between(2, 3)).compile(m=3)
    b = (col(1).between(2, 3) | col(0).between(0, 1)).compile(m=3)
    assert predicate_signature(a) == predicate_signature(b)
    padded = pad_clauses(a, 4)  # bigger legalized bucket, same DNF
    assert predicate_signature(padded) == predicate_signature(a)
    c = (col(0).between(0, 1) | col(1).between(2, 4)).compile(m=3)
    assert predicate_signature(c) != predicate_signature(a)


def test_signature_conjunctive_shim_matches_dnf_form():
    p = Predicates.from_conditions(3, {1: (2.0, 3.0)})
    ps = col(1).between(2, 3).compile(m=3)
    assert predicate_signature(p) == predicate_signature(ps)
    # inactive-column bound garbage is canonicalized away
    q = Predicates.from_conditions(3, {1: (2.0, 3.0)})
    q.lo = q.lo.at[0].set(-5.0)  # inactive column: semantically dead
    assert predicate_signature(q) == predicate_signature(p)


def test_signature_empty_clause_dropped():
    # folding an impossible range empties a clause; the signature must
    # treat it as absent from the union
    ps = (col(0).between(0, 1) | col(1).between(2, 3)).compile(m=3)
    emptied = fold_conjunct(ps, 1, 10.0, 20.0)  # kills the second clause
    only = fold_conjunct(col(0).between(0, 1).compile(m=3), 1, 10.0, 20.0)
    # the emptied clause contributes nothing to the union: both forms
    # denote the same DNF and must share one signature
    assert predicate_signature(only) == predicate_signature(emptied)
    false_ps = PredicateSet.from_clauses(3, [])
    assert predicate_signature(false_ps) == b"false"


def test_query_signature_splits_on_weights_and_recall_target():
    q = _mhq([0.0, 1.0])
    assert query_signature(q) == query_signature(_mhq([9.9, 9.9]))  # vec ≠ key
    assert query_signature(q) != query_signature(
        dataclasses.replace(q, weights=(0.5,)))
    assert query_signature(q) != query_signature(
        dataclasses.replace(q, recall_target=0.99))


# -- cache hit rules ----------------------------------------------------------

def test_k_bucket_compatibility():
    cache = SemanticCache()
    token = (0, 100)
    cache.insert(_mhq([0.0, 1.0], k=10), token, np.arange(10),
                 np.linspace(1, 0, 10))
    hit = cache.lookup(_mhq([0.0, 1.0], k=5), token)  # same bucket, k<=10
    assert hit is not None and len(hit[0]) == 5
    np.testing.assert_array_equal(hit[0], np.arange(5))
    assert cache.lookup(_mhq([0.0, 1.0], k=12), token) is None  # entry too small
    assert cache.lookup(_mhq([0.0, 1.0], k=20), token) is None  # other bucket
    assert k_bucket(5) == k_bucket(10) != k_bucket(20)


def test_eps_gates_near_duplicates():
    token = (0, 100)
    exact = SemanticCache(eps=0.0)
    exact.insert(_mhq([0.0, 1.0]), token, np.arange(5), np.zeros(5))
    assert exact.lookup(_mhq([0.0, 1.0 + 1e-4]), token) is None
    assert exact.lookup(_mhq([0.0, 1.0]), token) is not None
    fuzzy = SemanticCache(eps=1e-3)
    fuzzy.insert(_mhq([0.0, 1.0]), token, np.arange(5), np.zeros(5))
    assert fuzzy.lookup(_mhq([0.0, 1.0 + 1e-4]), token) is not None
    assert fuzzy.lookup(_mhq([0.0, 1.1]), token) is None
    # per-metric mapping form
    per = SemanticCache(eps={"dot": 1e-3, "l2": 0.0}, metric="l2")
    per.insert(_mhq([0.0, 1.0]), token, np.arange(5), np.zeros(5))
    assert per.lookup(_mhq([0.0, 1.0 + 1e-4]), token) is None  # l2 eps is 0


def test_token_staleness_epoch_and_rowcount():
    cache = SemanticCache()
    q = _mhq([0.0, 1.0])
    cache.insert(q, (3, 100), np.arange(5), np.zeros(5))
    assert cache.lookup(q, (3, 100)) is not None
    # epoch bump alone (same row count: compaction only MOVED rows) flushes
    assert cache.lookup(q, (4, 100)) is None
    assert cache.stats()["stale_drops"] == 1
    assert len(cache) == 0  # dropped on touch, not just skipped
    # row-count bump alone (hot insert, same epoch) flushes too
    cache.insert(q, (4, 100), np.arange(5), np.zeros(5))
    assert cache.lookup(q, (4, 101)) is None
    assert cache.stats()["stale_drops"] == 2


def test_per_tenant_lru_bound():
    cache = SemanticCache(capacity_per_tenant=2)
    token = (0, 100)
    for i in range(3):
        cache.insert(_mhq([float(i), 0.0], tenant=0), token,
                     np.arange(5), np.zeros(5))
    cache.insert(_mhq([9.0, 9.0], tenant=1), token, np.arange(5), np.zeros(5))
    assert cache.stats()["evictions"] == 1
    assert len(cache) == 3  # 2 for tenant 0, 1 for tenant 1
    assert cache.lookup(_mhq([0.0, 0.0], tenant=0), token) is None  # evicted
    assert cache.lookup(_mhq([2.0, 0.0], tenant=0), token) is not None
    assert cache.lookup(_mhq([9.0, 9.0], tenant=1), token) is not None
    assert cache.invalidate_tenant(0) == 2
    assert len(cache) == 1


def test_miss_storm_replaces_instead_of_appending():
    # regression: N concurrent misses for one identical query used to append
    # N duplicate entries under one key, churning the LRU and evicting an
    # UNRELATED warm entry. A storm must leave ONE entry for that key and
    # the warm entry untouched.
    cache = SemanticCache(capacity_per_tenant=3)
    token = (0, 100)
    warm = _mhq([7.0, 7.0])
    cache.insert(warm, token, np.arange(5), np.zeros(5))
    storm = _mhq([0.0, 1.0])
    for i in range(10):  # 10 duplicate miss results racing in
        cache.insert(storm, token, np.arange(5) + i, np.zeros(5))
    assert len(cache) == 2  # warm + ONE storm entry
    assert cache.stats()["evictions"] == 0
    assert cache.lookup(warm, token) is not None  # warm entry survived
    hit = cache.lookup(storm, token)
    assert hit is not None
    np.testing.assert_array_equal(hit[0], np.arange(5) + 9)  # freshest result
    # near-duplicates within eps coalesce too; outside eps they coexist
    fuzzy = SemanticCache(eps=1e-2, capacity_per_tenant=8)
    fuzzy.insert(_mhq([0.0, 1.0]), token, np.arange(5), np.zeros(5))
    fuzzy.insert(_mhq([0.0, 1.0 + 1e-4]), token, np.arange(5), np.zeros(5))
    assert len(fuzzy) == 1
    fuzzy.insert(_mhq([0.0, 2.0]), token, np.arange(5), np.zeros(5))
    assert len(fuzzy) == 2


def test_invalidate_tenant_drops_hit_counter():
    # regression: invalidate_tenant left the tenant's hit counter behind,
    # so per-tenant accounting reported hits for a tenant with no entries.
    cache = SemanticCache()
    token = (0, 100)
    cache.insert(_mhq([0.0, 1.0], tenant=0), token, np.arange(5), np.zeros(5))
    cache.insert(_mhq([0.0, 1.0], tenant=1), token, np.arange(5), np.zeros(5))
    assert cache.lookup(_mhq([0.0, 1.0], tenant=0), token) is not None
    assert cache.lookup(_mhq([0.0, 1.0], tenant=1), token) is not None
    assert cache.stats()["tenant_hits"] == {0: 1, 1: 1}
    cache.invalidate_tenant(0)
    assert cache.stats()["tenant_hits"] == {1: 1}


def test_tenant_isolation_unit():
    cache = SemanticCache()
    token = (0, 100)
    cache.insert(_mhq([0.0, 1.0], tenant=0), token, np.arange(5), np.zeros(5))
    assert cache.lookup(_mhq([0.0, 1.0], tenant=1), token) is None
    assert cache.lookup(_mhq([0.0, 1.0], tenant=None), token) is None
    assert cache.lookup(_mhq([0.0, 1.0], tenant=0), token) is not None


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_tenant_isolation_property(data):
    """Hypothesis sweep over tenant/predicate/vector mixes: a hit can only
    ever return an entry inserted under the SAME tenant. Entries encode
    their tenant in the cached ids, so any cross-tenant leak is visible in
    the returned payload."""
    cache = SemanticCache(eps=data.draw(st.sampled_from([0.0, 0.5])))
    token = (0, 100)
    n = data.draw(st.integers(min_value=2, max_value=8))
    probes = []
    for _ in range(n):
        tenant = data.draw(st.integers(min_value=0, max_value=3))
        vec = [data.draw(st.floats(-1, 1, width=32)) for _ in range(3)]
        lo = data.draw(st.floats(0, 4, width=32))
        hi = lo + data.draw(st.floats(0, 4, width=32))
        k = data.draw(st.sampled_from([3, 5, 10]))
        q = _mhq(vec, lo, hi, k=k, tenant=tenant)
        cache.insert(q, token, np.full(k, tenant), np.zeros(k))
        probes.append(q)
    for q in probes:
        for other in range(4):
            got = cache.lookup(
                dataclasses.replace(q, tenant_id=other), token)
            if got is not None:
                assert np.all(got[0] == other), \
                    f"tenant {other} got tenant {got[0][0]}'s entry"


# -- end-to-end: engine + tiered epochs + tenant fold -------------------------

@pytest.fixture(scope="module")
def tenant_bq():
    """Fitted BoomHQ over 'part' with an extra categorical tenant column,
    namespaces bound. Tests that bind_tiered must unbind before returning."""
    base = datasets.make("part", rows=900, seed=7)
    rng_ = np.random.default_rng(7)
    tcol = rng_.integers(0, TENANTS, base.n_rows).astype(np.float32)
    schema = dataclasses.replace(
        base.schema,
        scalar_cols=tuple(base.schema.scalar_cols)
        + (ScalarCol("tenant", "cat", TENANTS),))
    table = Table.from_numpy(
        schema, [np.asarray(v) for v in base.vectors],
        np.concatenate([np.asarray(base.scalars), tcol[:, None]], axis=1))
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=8, use_de=False,
        rewriter=RewriterConfig(steps=10, refine_columns=False)))
    wl = queries.gen_workload(table, 12, n_vec_used=2, k=5, seed=0)
    bq.fit(wl)
    bq.bind_tenants("tenant")
    held = queries.gen_workload(table, 6, n_vec_used=2, k=5, seed=1)
    return bq, held


def _fresh_rows(table, n: int, seed: int, tenant: float = 0.0):
    extra = datasets.make("part", rows=n, seed=seed)
    scal = np.concatenate(
        [np.asarray(extra.scalars),
         np.full((n, 1), tenant, np.float32)], axis=1)
    return [np.asarray(v) for v in extra.vectors], scal


def test_tenant_fold_scopes_results(tenant_bq):
    bq, held = tenant_bq
    tcol = np.asarray(bq.table.scalars)[:, -1]
    base_mask = eval_mask_np(held[0].predicates,
                             np.asarray(bq.table.scalars))
    scoped_any = 0
    for tenant in range(TENANTS):
        q = dataclasses.replace(held[0], tenant_id=tenant)
        ids = np.asarray(bq.execute(q)[0])
        got = ids[ids >= 0]
        if not (base_mask & (tcol == tenant)).any():
            assert got.size == 0  # no qualifying rows for this tenant
            continue
        scoped_any += 1
        assert got.size > 0
        assert np.all(tcol[got] == tenant), tenant
    assert scoped_any > 0  # at least one tenant actually had rows
    # the fold is an intersection with the query's own predicate
    folded = bq.resolve_tenant(
        dataclasses.replace(held[0], tenant_id=1)).predicates
    mask = eval_mask_np(folded, np.asarray(bq.table.scalars))
    base_mask = eval_mask_np(held[0].predicates, np.asarray(bq.table.scalars))
    assert np.array_equal(mask, base_mask & (tcol == 1))


def test_engine_isolates_tenants_through_cache(tenant_bq):
    bq, held = tenant_bq
    cache = SemanticCache(eps=0.0)
    eng = AsyncServingEngine(bq, batch_size=2, max_wait=0.005,
                             semcache=cache)
    q0 = dataclasses.replace(held[1], tenant_id=0)
    q1 = dataclasses.replace(held[1], tenant_id=1)

    async def main():
        async with eng:
            a = await eng.submit(q0)   # miss
            b = await eng.submit(q0)   # hit (same tenant, exact repeat)
            c = await eng.submit(q1)   # other tenant: MUST miss
            d = await eng.submit(q1)   # now cached for tenant 1
            return a, b, c, d

    a, b, c, d = asyncio.run(main())
    assert not a.cache_hit and b.cache_hit
    assert not c.cache_hit and d.cache_hit
    np.testing.assert_array_equal(np.asarray(a.result[0])[: q0.k],
                                  np.asarray(b.result[0]))
    tcol = np.asarray(bq.table.scalars)[:, -1]
    cids = np.asarray(c.result[0])
    assert np.all(tcol[cids[cids >= 0]] == 1)
    rep = eng.report()
    assert rep.n_cache_hits == 2
    assert rep.tenants[0]["n_cache_hits"] == 1
    assert rep.tenants[1]["n_cache_hits"] == 1
    assert rep.tenants[0]["n_queries"] == 2


def test_cache_entry_never_served_across_epoch(tenant_bq):
    """THE staleness oracle: an entry cached at epoch e is never served at
    epoch e+1, and the post-swap miss recomputes against the new state
    (matches the brute-force oracle over the compacted table)."""
    bq, held = tenant_bq
    bq.bind_tiered(hot_capacity=8)
    try:
        cache = SemanticCache(eps=0.0)
        eng = AsyncServingEngine(bq, batch_size=2, max_wait=0.005,
                                 semcache=cache)
        q = held[2]

        async def main():
            async with eng:
                r1 = await eng.submit(q)
                r2 = await eng.submit(q)
                epoch0 = bq.tiered.epoch
                vecs, scal = _fresh_rows(bq.table, 8, seed=31)
                bq.tiered.insert(vecs, scal)
                bq.tiered.compact()  # epoch e -> e+1
                assert bq.tiered.epoch == epoch0 + 1
                r3 = await eng.submit(q)
                r4 = await eng.submit(q)
                return r1, r2, r3, r4

        r1, r2, r3, r4 = asyncio.run(main())
        assert not r1.cache_hit and r2.cache_hit
        assert not r3.cache_hit  # pinned: epoch bump = implicit flush
        assert cache.stats()["stale_drops"] >= 1
        assert r4.cache_hit  # repopulated under the NEW token
        # the post-swap result is computed against the compacted table
        gt_ids, gt_s, _ = brute_force_topk(
            bq.tiered.logical_table(), list(q.query_vectors),
            list(q.weights), q.predicates, q.k)
        np.testing.assert_allclose(np.sort(np.asarray(r3.result[1])),
                                   np.sort(gt_s), atol=1e-3, rtol=1e-4)
    finally:
        bq.unbind_tiered()


def test_hot_insert_visible_to_next_miss(tenant_bq):
    """Any hot-tier insert changes the freshness token: the very next
    repeat MISSES and its re-execution sees the inserted row."""
    bq, held = tenant_bq
    bq.bind_tiered(hot_capacity=32)
    try:
        cache = SemanticCache(eps=0.0)
        eng = AsyncServingEngine(bq, batch_size=2, max_wait=0.005,
                                 semcache=cache)
        # a query whose predicate some cold row passes; give the inserted
        # row that row's scalars and an unbeatable vector
        q = held[3]
        mask = eval_mask_np(q.predicates, np.asarray(bq.table.scalars))
        assert mask.any()
        passing = int(np.argmax(mask))
        big = [100.0 * np.asarray(v, np.float32)[None]
               for v in q.query_vectors]
        new_scal = np.asarray(bq.table.scalars)[passing: passing + 1]

        async def main():
            async with eng:
                r1 = await eng.submit(q)
                r2 = await eng.submit(q)
                new_id = bq.tiered.snapshot().n_rows  # next global row id
                bq.tiered.insert(big, new_scal)
                r3 = await eng.submit(q)
                return r1, r2, r3, new_id

        r1, r2, r3, new_id = asyncio.run(main())
        assert r2.cache_hit
        assert not r3.cache_hit  # pinned: insert = token change = miss
        assert new_id in np.asarray(r3.result[0])  # and the miss SEES it
    finally:
        bq.unbind_tiered()
