"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see 1 CPU device; multi-device tests run in
subprocesses (tests/test_distributed.py)."""
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy end-to-end serving tests (lint job deselects with "
        "-m 'not slow'; tier-1 runs them)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_table():
    from repro.bench import datasets

    return datasets.make("part", rows=1500, seed=0)


@pytest.fixture(scope="session")
def fitted():
    """Fitted BoomHQ on a MIXED workload — conjunctive and DNF predicates —
    so the whole fit/optimize/execute(+batch) pipeline runs the clause
    algebra end-to-end. Shared by the batched-parity, oracle recall-floor
    and cross-shard suites (tests must leave the instance unsharded)."""
    from repro.bench import datasets, queries
    from repro.core.boomhq import BoomHQ, BoomHQConfig
    from repro.core.data_encoder import DataEncoderConfig
    from repro.core.rewriter import RewriterConfig
    from repro.vectordb.predicates import n_clauses

    table = datasets.make("part", rows=2000, seed=0)
    conj = queries.gen_workload(table, 22, n_vec_used=2, seed=1)
    dnf = queries.gen_dnf_workload(table, 10, n_vec_used=2, seed=2,
                                   clause_counts=(2, 3, 4))
    assert max(n_clauses(q.predicates) for q in dnf) >= 2
    wl = conj[:12] + dnf[:6] + conj[12:] + dnf[6:]
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=16,
        encoder=DataEncoderConfig(frozen_steps=25, ae_steps=40, sample=512),
        rewriter=RewriterConfig(steps=80, refine_columns=False)))
    bq.fit(wl[:18])
    return bq, wl[18:]
