"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see 1 CPU device; multi-device tests run in
subprocesses (tests/test_distributed.py)."""
import numpy as np
import pytest



@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_table():
    from repro.bench import datasets

    return datasets.make("part", rows=1500, seed=0)
