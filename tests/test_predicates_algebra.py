"""Predicate-algebra API: builder -> DNF compilation -> evaluation parity
with a pure-NumPy oracle, clause-grid legalization, union selectivity
estimates, clause-folded soft encodings, and the engine-aware default plan."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core.executor import MILVUS, PGVECTOR
from repro.core.query import default_plan
from repro.vectordb import algebra, histogram, ivf
from repro.vectordb.algebra import col
from repro.vectordb.predicates import (
    CLAUSE_GRID, MAX_CLAUSES, PredicateSet, Predicates, active_any, as_set,
    clause_bucket, eval_mask, soft_encode, stack, take,
)


# ---------------------------------------------------------------------------
# pure-NumPy oracle over expression trees
# ---------------------------------------------------------------------------

def np_eval(expr, scal: np.ndarray) -> np.ndarray:
    """Reference evaluator: interprets the expression tree directly."""
    if isinstance(expr, algebra.Cond):
        x = scal[:, int(expr.col)]
        return (x >= np.float32(expr.lo)) & (x <= np.float32(expr.hi))
    if isinstance(expr, algebra.And):
        out = np.ones(scal.shape[0], bool)
        for p in expr.parts:
            out &= np_eval(p, scal)
        return out
    if isinstance(expr, algebra.Or):
        out = np.zeros(scal.shape[0], bool)
        for p in expr.parts:
            out |= np_eval(p, scal)
        return out
    assert isinstance(expr, algebra.Not)
    return ~np_eval(expr.part, scal)


def random_expr(rng, scal: np.ndarray, depth: int = 0):
    """Random expression tree over the data's value ranges."""
    m = scal.shape[1]
    r = rng.random()
    if depth >= 3 or r < 0.45:
        c = int(rng.integers(0, m))
        lo, hi = float(scal[:, c].min()), float(scal[:, c].max())
        a, b = sorted(rng.uniform(lo, hi, 2))
        kind = rng.integers(0, 6)
        if kind == 0:
            return col(c).between(a, b)
        if kind == 1:
            return col(c) <= b
        if kind == 2:
            return col(c) > a
        if kind == 3:
            return col(c) == float(rng.choice(scal[:, c]))
        if kind == 4:
            return col(c).below(b)
        vals = rng.choice(np.unique(scal[:, c]),
                          size=min(3, len(np.unique(scal[:, c]))),
                          replace=False)
        return col(c).isin([float(v) for v in vals])
    a = random_expr(rng, scal, depth + 1)
    b = random_expr(rng, scal, depth + 1)
    if r < 0.7:
        return a & b
    if r < 0.9:
        return a | b
    return ~a


@pytest.fixture(scope="module")
def scal4():
    rng = np.random.default_rng(7)
    return np.stack([
        rng.integers(0, 10, 3000).astype(np.float32),
        rng.integers(0, 50, 3000).astype(np.float32),
        rng.lognormal(1.0, 0.6, 3000).astype(np.float32),
        rng.uniform(1.0, 1000.0, 3000).astype(np.float32)], axis=1)


def _check_tree(expr, scal):
    try:
        ps = expr.compile(m=scal.shape[1])
    except ValueError:
        return None  # DNF wider than the clause grid — a legal refusal
    got = np.asarray(eval_mask(ps, jnp.asarray(scal)))
    want = np_eval(expr, scal)
    np.testing.assert_array_equal(got, want)
    assert ps.n_clauses in CLAUSE_GRID
    return ps


def test_random_trees_match_numpy_oracle(scal4):
    """Deterministic sweep (always runs, hypothesis or not)."""
    rng = np.random.default_rng(0)
    compiled = 0
    for _ in range(120):
        if _check_tree(random_expr(rng, scal4), scal4) is not None:
            compiled += 1
    assert compiled > 60  # the clause grid must not be refusing everything


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_property_eval_matches_numpy_oracle(seed):
    rng = np.random.default_rng(seed)
    scal = np.stack([
        rng.integers(0, 8, 400).astype(np.float32),
        rng.uniform(-5.0, 5.0, 400).astype(np.float32),
        rng.lognormal(0.5, 1.0, 400).astype(np.float32)], axis=1)
    _check_tree(random_expr(rng, scal), scal)


# ---------------------------------------------------------------------------
# builder / compilation specifics
# ---------------------------------------------------------------------------

def test_builder_shapes_and_grid(scal4):
    ps = (col(3).between(10, 50) | (col(1) == 3)).compile(m=4)
    assert isinstance(ps, PredicateSet)
    assert ps.n_clauses == 2 and bool(ps.clause_valid.all())

    ps3 = col(1).isin([1, 2, 3]).compile(m=4)
    assert ps3.n_clauses == 4  # 3 clauses pad onto the (1, 2, 4) grid
    assert int(np.asarray(ps3.clause_valid).sum()) == 3
    assert clause_bucket(ps3) == 4

    with pytest.raises(ValueError):
        col(1).isin(range(MAX_CLAUSES + 1)).compile(m=4)


def test_compile_resolves_names(tiny_table):
    t = tiny_table
    ps = (col("price").between(10, 500) & (col("brand") == 2)).compile(t.schema)
    scal = np.asarray(t.scalars)
    want = (scal[:, 3] >= 10) & (scal[:, 3] <= 500) & (scal[:, 1] == 2)
    np.testing.assert_array_equal(np.asarray(eval_mask(ps, t.scalars)), want)
    with pytest.raises(KeyError):
        (col("no_such_column") == 1).compile(t.schema)
    with pytest.raises(TypeError):
        algebra.compile(col("price"), t.schema)


def test_unsatisfiable_compiles_to_empty_mask(scal4):
    ps = ((col(2) < 1.0) & (col(2) > 2.0)).compile(m=4)
    assert not np.asarray(eval_mask(ps, jnp.asarray(scal4))).any()


def test_negation_is_exact_complement(scal4):
    e = col(3).between(100.0, 500.0)
    m = np.asarray(eval_mask(e.compile(m=4), jnp.asarray(scal4)))
    mn = np.asarray(eval_mask((~e).compile(m=4), jnp.asarray(scal4)))
    assert np.array_equal(mn, ~m)


def test_predicates_compat_shim_is_c1(scal4):
    p = Predicates.from_conditions(4, {3: (100.0, 500.0)})
    ps = as_set(p)
    assert ps.n_clauses == 1 and bool(ps.clause_valid.all())
    np.testing.assert_array_equal(
        np.asarray(eval_mask(p, jnp.asarray(scal4))),
        np.asarray(eval_mask(ps, jnp.asarray(scal4))))
    assert np.array_equal(np.asarray(active_any(p)), np.asarray(p.active))


def test_stack_and_take_mixed_types(scal4):
    p1 = Predicates.from_conditions(4, {0: (3.0, 3.0)})
    ps = (col(3).between(10, 50) | (col(1) == 3)).compile(m=4)
    st_b = stack([p1, ps])
    assert isinstance(st_b, PredicateSet) and st_b.active.shape == (2, 2, 4)
    masks = np.asarray(jax.vmap(
        lambda p: eval_mask(p, jnp.asarray(scal4)))(st_b))
    np.testing.assert_array_equal(
        masks[0], np.asarray(eval_mask(p1, jnp.asarray(scal4))))
    np.testing.assert_array_equal(
        masks[1], np.asarray(eval_mask(ps, jnp.asarray(scal4))))
    sub = take(st_b, np.asarray([1]))
    assert sub.active.shape == (1, 2, 4)
    # all-conjunctive stacks stay on the cheap C=1 representation
    assert isinstance(stack([p1, p1]), Predicates)


# ---------------------------------------------------------------------------
# selectivity union estimates
# ---------------------------------------------------------------------------

def test_union_selectivity_inclusion_exclusion(scal4):
    h = histogram.build(jnp.asarray(scal4), 64)
    # overlapping ranges on one column: union < sum
    e = col(3).between(100, 500) | col(3).between(300, 700)
    est = float(histogram.estimate_selectivity(h, e.compile(m=4)))
    exact = float((((scal4[:, 3] >= 100) & (scal4[:, 3] <= 500))
                   | ((scal4[:, 3] >= 300) & (scal4[:, 3] <= 700))).mean())
    assert abs(est - exact) < 0.06


def test_union_selectivity_bonferroni_upper_bound(scal4):
    h = histogram.build(jnp.asarray(scal4), 64)
    e = col(1).isin([1, 2, 3])  # pads to C=4
    est = float(histogram.estimate_selectivity(h, e.compile(m=4)))
    exact = float(np.isin(scal4[:, 1], [1, 2, 3]).mean())
    assert est >= exact - 0.03  # upper bound (disjoint points: ~tight)
    assert est <= 1.0


# ---------------------------------------------------------------------------
# clause-folded soft encoding
# ---------------------------------------------------------------------------

def test_soft_encode_folds_clauses(scal4):
    edges = jnp.asarray(np.stack([
        np.linspace(scal4[:, i].min(), scal4[:, i].max() * 1.001, 9)
        for i in range(4)]))
    p1 = Predicates.from_conditions(4, {3: (100.0, 500.0)})
    np.testing.assert_allclose(
        np.asarray(soft_encode(as_set(p1), edges)),
        np.asarray(soft_encode(p1, edges)), atol=1e-6)  # C=1 == old rule
    ps = (col(3).between(100, 300) | col(3).between(600, 900)).compile(m=4)
    enc = np.asarray(soft_encode(ps, edges))
    assert enc.shape == (4, 8)
    np.testing.assert_allclose(enc.sum(axis=1), 1.0, atol=1e-5)
    # both lobes of the OR must carry mass
    bin_lo = np.asarray(edges[3])[:-1]
    lobe1 = enc[3][(bin_lo >= 50) & (bin_lo <= 350)].sum()
    lobe2 = enc[3][(bin_lo >= 550) & (bin_lo <= 950)].sum()
    assert lobe1 > 0.1 and lobe2 > 0.1


# ---------------------------------------------------------------------------
# DNF through the search substrate + engine-aware default plan
# ---------------------------------------------------------------------------

def test_ivf_search_respects_dnf(tiny_table):
    t = tiny_table
    idx = ivf.build(t.vectors[0], 16, metric=t.schema.metric)
    ps = ((col("category") == 3) | (col("category") == 5)).compile(t.schema)
    q = jnp.asarray(np.asarray(t.vectors[0][3]))
    ids, _, _, _ = ivf.search(idx, t.vectors[0], t.scalars, ps, q,
                              nprobe=16, max_scan=t.n_rows, k=10)
    scal = np.asarray(t.scalars)
    for i in np.asarray(ids):
        if i >= 0:
            assert scal[i, 0] in (3.0, 5.0)


def test_default_plan_respects_engine_caps():
    free = default_plan(2, PGVECTOR)
    assert free == default_plan(2)  # pgvector exposes everything
    clamped = default_plan(2, MILVUS)
    for s in clamped.subqueries:
        assert s.max_scan == MILVUS.default_max_scan
        assert not s.iterative
        assert s.nprobe <= MILVUS.nprobe_cap
