"""Tiered streaming ingest: epoch-swap protocol, hot∪cold recall, parity.

Ordered stateful progression over one fitted instance (tests run in
definition order and document the lifecycle: parity → insert → recall →
mid-compaction isolation → sharded parity → async background compaction),
plus standalone pins for the incremental ``ivf.extend`` path and the EP001
field registry."""
import asyncio

import numpy as np
import pytest

from oracle import (
    brute_force_topk, eval_mask_np, tie_aware_recall, tiered_brute_force_topk,
)
from repro.bench import datasets, queries
from repro.core.boomhq import BoomHQ, BoomHQConfig
from repro.core.data_encoder import DataEncoderConfig
from repro.core.rewriter import RewriterConfig

ROWS = 1200
_STATE: dict = {}  # cross-test measurements of the ordered progression


@pytest.fixture(scope="module")
def tiered_bq():
    table = datasets.make("part", rows=ROWS, seed=0)
    wl = queries.gen_workload(table, 36, n_vec_used=2, seed=1)
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=8,
        encoder=DataEncoderConfig(frozen_steps=10, ae_steps=15, sample=256),
        rewriter=RewriterConfig(steps=30, refine_columns=False)))
    bq.fit(wl[:12])
    return bq, wl[12:]


def _fresh_rows(n: int, seed: int):
    extra = datasets.make("part", rows=n, seed=seed)
    return [np.asarray(v) for v in extra.vectors], np.asarray(extra.scalars)


def _segments(snap):
    """Snapshot -> the (vectors_list, scalars) segments of the union oracle,
    in global row-id order (cold, then each hot view)."""
    segs = [(list(np.asarray(v) for v in snap.cold.table.vectors),
             np.asarray(snap.cold.table.scalars))]
    for view in snap.hot_views:
        segs.append(([np.asarray(b)[: view.count] for b in view.vectors],
                     np.asarray(view.scalars)[: view.count]))
    return segs


def _union_recall(bq, qs) -> tuple[float, list]:
    """Mean tie-aware recall of the tiered path against the hot∪cold
    brute-force oracle, all queries executed against ONE snapshot."""
    snap = bq.tiered.snapshot()
    segs = _segments(snap)
    metric = snap.cold.table.schema.metric
    results = bq.execute_batch(qs, snapshot=snap)
    recs = []
    for q, (ids, _) in zip(qs, results):
        _ids, _sc, masked = tiered_brute_force_topk(
            segs, metric, q.query_vectors, q.weights, q.predicates, q.k)
        recs.append(tie_aware_recall(np.asarray(ids), masked, q.k))
    return float(np.mean(recs)), results


# -- 1: binding with an empty hot segment changes NOTHING --------------------

def test_empty_hot_bitforbit_parity(tiered_bq):
    bq, held = tiered_bq
    base = bq.execute_batch(held[:8])
    bq.bind_tiered(hot_capacity=128)
    assert bq.tiered.snapshot().n_hot == 0
    got = bq.execute_batch(held[:8])
    for (bi, bs), (ti, ts) in zip(base, got):
        assert np.array_equal(np.asarray(bi), np.asarray(ti))
        assert np.array_equal(np.asarray(bs), np.asarray(ts))
    # pre-insert tiered recall baseline for the drift acceptance below
    recs = []
    for q, (ids, _) in zip(held[:16], bq.execute_batch(held[:16])):
        _i, _s, masked = brute_force_topk(
            bq.table, q.query_vectors, q.weights, q.predicates, q.k)
        recs.append(tie_aware_recall(np.asarray(ids), masked, q.k))
    _STATE["pre_insert_recall"] = float(np.mean(recs))


# -- 2: inserted rows are visible to the very next batch ---------------------

def test_insert_visible_before_compaction(tiered_bq):
    bq, held = tiered_bq
    vecs, scal = _fresh_rows(64, seed=7)
    stats = bq.insert(vecs, scal)
    assert stats["inserted"] == 64 and not stats["needs_compaction"]
    snap = bq.tiered.snapshot()
    assert snap.epoch == 0 and snap.n_hot == 64
    assert snap.n_rows == ROWS + 64
    mean_rec, _results = _union_recall(bq, held[8:16])
    assert mean_rec >= 0.9, mean_rec
    # sentinel visibility: insert one row built to dominate a query — a
    # large multiple of its query vectors with scalars copied from a cold
    # row that passes its predicate — and it must surface as top-1 from
    # the hot segment on the very next batch, no compaction involved
    q = held[8]
    sentinel_id = snap.n_rows  # next global id = current logical row count
    big = [100.0 * np.asarray(v, np.float32)[None] for v in q.query_vectors]
    mask = eval_mask_np(q.predicates, np.asarray(bq.table.scalars))
    passing = int(np.argmax(mask))
    assert mask[passing]
    bq.insert(big, np.asarray(bq.table.scalars)[passing: passing + 1])
    ids, _ = bq.execute_batch([q])[0]
    assert int(np.asarray(ids)[0]) == sentinel_id


# -- 3: acceptance — +10% rows, full-stream recall within 0.02 ---------------

def test_recall_drift_after_ten_percent_insert(tiered_bq):
    bq, held = tiered_bq
    vecs, scal = _fresh_rows(55, seed=8)  # 65 + 55 = 120 = 10% of 1200
    bq.insert(vecs, scal)
    assert bq.tiered.snapshot().n_rows == ROWS + 120
    mean_rec, _ = _union_recall(bq, held[:16])
    assert mean_rec >= _STATE["pre_insert_recall"] - 0.02, (
        mean_rec, _STATE["pre_insert_recall"])


# -- 4: epoch swap between batches loses nothing -----------------------------

def test_snapshot_isolation_across_compaction(tiered_bq):
    bq, held = tiered_bq
    snap_a = bq.tiered.snapshot()
    assert snap_a.hot_views  # 120 hot rows from the tests above
    r1 = bq.execute_batch(held[:6], snapshot=snap_a)
    bq.tiered.compact()  # seals the active generation and folds it cold
    assert bq.tiered.epoch == snap_a.epoch + 1
    # a batch formed BEFORE the swap replays bit-for-bit: its snapshot is
    # immutable, the swap published a new one without touching it
    r2 = bq.execute_batch(held[:6], snapshot=snap_a)
    for (i1, s1), (i2, s2) in zip(r1, r2):
        assert np.array_equal(np.asarray(i1), np.asarray(i2))
        assert np.array_equal(np.asarray(s1), np.asarray(s2))
    # a batch formed AFTER the swap sees the same logical rows (now cold);
    # recall against the unchanged union oracle does not degrade
    snap_b = bq.tiered.snapshot()
    assert snap_b.n_rows == snap_a.n_rows  # no rows lost in the swap
    segs = _segments(snap_a)
    metric = snap_a.cold.table.schema.metric
    pre, post = [], []
    for q, (i1, _), (i3, _) in zip(
            held[:6], r1, bq.execute_batch(held[:6], snapshot=snap_b)):
        _i, _s, masked = tiered_brute_force_topk(
            segs, metric, q.query_vectors, q.weights, q.predicates, q.k)
        pre.append(tie_aware_recall(np.asarray(i1), masked, q.k))
        post.append(tie_aware_recall(np.asarray(i3), masked, q.k))
    assert float(np.mean(post)) >= float(np.mean(pre)) - 0.02


# -- 5: parity holds under bind_shards too -----------------------------------

def test_sharded_empty_hot_parity(tiered_bq):
    bq, held = tiered_bq
    while bq.tiered.snapshot().n_hot:  # drain: one compact per generation
        bq.tiered.compact()
    bq.bind_shards(2)
    got = bq.execute_batch(held[:6])
    bq.unbind_tiered()
    base = bq.execute_batch(held[:6])
    for (bi, bs), (ti, ts) in zip(base, got):
        assert np.array_equal(np.asarray(bi), np.asarray(ti))
        assert np.array_equal(np.asarray(bs), np.asarray(ts))
    bq.bind_shards(1)


# -- 6: async engine — background compaction, zero serving failures ----------

def test_async_engine_background_compaction(tiered_bq):
    bq, held = tiered_bq
    bq.bind_tiered(hot_capacity=64)
    vecs, scal = _fresh_rows(100, seed=9)

    async def main():
        from repro.serve.queue import AsyncServingEngine
        eng = AsyncServingEngine(bq, batch_size=6, max_wait=0.01)
        async with eng:
            tasks = [asyncio.ensure_future(eng.submit(q)) for q in held]
            # ingest mid-stream: fills the 64-row hot segment, the engine's
            # CompactionScheduler folds it cold on its own worker
            bq.insert(vecs, scal)
            reqs = await asyncio.gather(*tasks)
        return eng, reqs

    eng, reqs = asyncio.run(main())
    assert all(r.status == "ok" for r in reqs)
    assert all(r.snapshot is not None for r in reqs)  # stamped at cut time
    rep = eng.report()
    assert rep.n_timed_out == 0
    assert rep.n_inserted >= 100 and rep.n_compactions >= 1
    assert rep.epoch == bq.tiered.epoch
    assert "inserted" in rep.describe()
    bq.unbind_tiered()


# -- standalone pins ---------------------------------------------------------

def test_ivf_extend_incremental_matches_regroup(rng):
    from repro.vectordb import ivf

    base = rng.standard_normal((400, 8)).astype(np.float32)
    idx = ivf.build(base, 8, seed=3)
    for m, seed in ((1, 0), (20, 1), (99, 2)):
        new = rng.standard_normal((m, 8)).astype(np.float32)
        assign = ivf._assign_to_centroids(idx, new)
        rows = np.arange(400, 400 + m, dtype=np.int32)
        inc = ivf._extend_incremental(idx, assign, rows)
        reg = ivf._extend_regroup(idx, assign, rows)
        assert np.array_equal(np.asarray(inc.sorted_rows),
                              np.asarray(reg.sorted_rows)), (m, seed)
        assert np.array_equal(np.asarray(inc.offsets),
                              np.asarray(reg.offsets))
        # public dispatch picks the incremental path for small batches and
        # the regroup for large ones — both byte-identical by the pin above
        via_extend = ivf.extend(idx, new, 400)
        assert np.array_equal(np.asarray(via_extend.sorted_rows),
                              np.asarray(inc.sorted_rows))
        assert np.array_equal(np.asarray(via_extend.centroids),
                              np.asarray(idx.centroids))


def test_ep001_registry_matches_tiered_fields(tiered_bq):
    # the lint rule's banned-field list must track the real mutable state
    from repro.analysis.config import DEFAULT_TIERED_MUTABLE_FIELDS
    from repro.vectordb.tiered import TieredTable

    bq, _ = tiered_bq
    t = TieredTable(bq.table, bq.indexes, bq.hists, hot_capacity=4)
    for field in DEFAULT_TIERED_MUTABLE_FIELDS:
        assert hasattr(t, field), field


def test_compact_rebuild_decision_locked_at_seal(tiered_bq, monkeypatch):
    """Regression: the ``rebuild_every`` decision used to read
    ``self._compactions`` OUTSIDE the lock during the heavy phase — a
    racing compaction bumping the counter mid-flight could skip (or
    double-fire) the every-Nth re-cluster. The sequence number is now
    captured under the lock at seal time; force the interleaving and pin
    the decision."""
    from repro.vectordb.table import Table
    from repro.vectordb.tiered import TieredTable

    bq, _ = tiered_bq
    t = TieredTable(bq.table, bq.indexes, bq.hists, hot_capacity=4,
                    rebuild_every=2)
    t.insert(*_fresh_rows(4, seed=21))
    r1 = t.compact()
    assert r1["compacted"] == 4 and r1["rebuild"] is False  # seq 1
    t.insert(*_fresh_rows(4, seed=22))

    orig = Table.append

    def racing_append(self, *a, **kw):
        # another compaction's counter bump landing while THIS compaction
        # is inside its unlocked heavy phase
        t._compactions += 9
        return orig(self, *a, **kw)

    monkeypatch.setattr(Table, "append", racing_append)
    r2 = t.compact()
    assert r2["compacted"] == 4
    assert r2["rebuild"] is True  # seq 2: the every-2nd re-cluster fires


def test_insert_publishes_without_device_transfers(tiered_bq):
    """Regression: ``_publish_locked`` used to re-materialize full-capacity
    DEVICE copies of the hot view on every insert. Views are now host-side
    tokens materialized lazily on first read: an insert-only window costs
    zero transfers, one snapshot read costs exactly one materialization
    (cached per view), and a late materialization still reads exactly the
    rows the view froze."""
    from repro.vectordb import tiered as T

    bq, _ = tiered_bq
    t = T.TieredTable(bq.table, bq.indexes, bq.hists, hot_capacity=64)
    vecs, scal = _fresh_rows(8, seed=23)
    base = T.hot_view_transfers()
    for i in range(8):
        t.insert([v[i: i + 1] for v in vecs], scal[i: i + 1])
    assert T.hot_view_transfers() - base == 0  # 8 publishes, 0 transfers
    view = t.snapshot().hot_views[0]
    _ = view.vectors
    _ = view.scalars
    per_view = len(vecs) + 1  # one copy per vector column + the scalars
    assert T.hot_view_transfers() - base == per_view
    _ = view.vectors  # cached: no second materialization
    assert T.hot_view_transfers() - base == per_view
    # late materialization: appends after the publish only touch rows
    # >= count, so the frozen prefix is unchanged
    view2 = t.snapshot().hot_views[0]
    assert view2.count == 8
    t.insert([v[:2] for v in vecs], scal[:2])
    np.testing.assert_array_equal(np.asarray(view2.scalars)[:8],
                                  scal[:8])


def test_hot_rows_filtered_exactly(tiered_bq):
    # a hot row failing the predicate must NEVER surface, even as the
    # nearest vector: hot scoring is exact-filtered, not probed
    bq, held = tiered_bq
    bq.bind_tiered(hot_capacity=32)
    # pick a query with a genuinely selective predicate and a cold row
    # that fails it; give that row an unbeatable vector
    mask = None
    for q in held:
        mask = eval_mask_np(q.predicates, np.asarray(bq.table.scalars))
        if not mask.all():
            break
    assert mask is not None and not mask.all()
    failing = int(np.argmin(mask))
    assert not mask[failing]
    first_hot = bq.table.n_rows  # id_offset of the fresh active generation
    big = [100.0 * np.asarray(v, np.float32)[None] for v in q.query_vectors]
    bq.insert(big, np.asarray(bq.table.scalars)[failing: failing + 1])
    ids, _ = bq.execute_batch([q])[0]
    assert first_hot not in np.asarray(ids)  # filtered despite top score
    bq.unbind_tiered()
