"""Optimizers, schedules, gradient compression, data pipeline determinism."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.data.pipeline import BatchSpec, SyntheticLM, PackedCorpus
from repro.train.grad_compress import compress_tree, decompress
from repro.train.optimizer import (
    AdafactorConfig, AdamWConfig, adafactor_init, adafactor_update,
    adamw_init, adamw_update, cosine_schedule,
)


def _quad_loss_descends(opt_init, opt_update, cfg, steps=60):
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (32, 16))
    params = {"w": jnp.zeros((32, 16)), "b": jnp.zeros((16,))}

    def loss(p):
        return jnp.mean(jnp.square(p["w"] + p["b"] - target))

    st = opt_init(params, cfg)
    l0 = float(loss(params))
    g = jax.jit(jax.grad(loss))
    for _ in range(steps):
        params, st = opt_update(g(params), st, params, cfg)
    return l0, float(loss(params))


def test_adamw_descends():
    l0, l1 = _quad_loss_descends(adamw_init, adamw_update, AdamWConfig(lr=5e-2))
    assert l1 < 0.1 * l0


def test_adamw_int8_state_close_to_fp32():
    l0a, l1a = _quad_loss_descends(adamw_init, adamw_update,
                                   AdamWConfig(lr=5e-2, state_dtype="float32"))
    l0b, l1b = _quad_loss_descends(adamw_init, adamw_update,
                                   AdamWConfig(lr=5e-2, state_dtype="int8"))
    assert l1b < 0.2 * l0b
    assert abs(l1a - l1b) < 0.1 * l0a + 1e-3


def test_adafactor_descends_with_tiny_state():
    cfg = AdafactorConfig(lr=5e-2)
    params = {"w": jnp.zeros((128, 128))}
    st = adafactor_init(params, cfg)
    # factored: state is O(rows+cols), not O(rows*cols)
    n_state = sum(np.prod(x.shape) for x in jax.tree.leaves(st["v"]))
    assert n_state == 128 + 128
    l0, l1 = _quad_loss_descends(adafactor_init, adafactor_update, cfg)
    assert l1 < 0.2 * l0


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, warmup=10, total=100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 0.11
    assert float(s(jnp.asarray(100))) < 0.2
    assert float(s(jnp.asarray(5))) < float(s(jnp.asarray(10)))


def test_grad_compress_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    res = {"a": jnp.zeros((64, 64))}
    qt, new_res = compress_tree(g, res)
    q, s = qt["a"]
    back = decompress(q, s)
    # quantization error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(back - g["a"]))) <= float(s) * 0.51 + 1e-6
    # error feedback: residual carries exactly the quantization error
    np.testing.assert_allclose(np.asarray(new_res["a"]),
                               np.asarray(g["a"] - back), atol=1e-6)
    # accumulated EF over repeated identical grads converges in mean
    total = jnp.zeros_like(back)
    res = {"a": jnp.zeros((64, 64))}
    for _ in range(16):
        qt, res = compress_tree(g, res)
        total = total + decompress(*qt["a"])
    np.testing.assert_allclose(np.asarray(total / 16), np.asarray(g["a"]),
                               atol=float(s) * 0.1)


def test_pipeline_determinism_and_host_split():
    spec = BatchSpec(global_batch=8, seq_len=16, vocab=100, num_hosts=2,
                     host_index=0)
    a = SyntheticLM(spec, seed=3).batch_at(5)
    b = SyntheticLM(spec, seed=3).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # resumable
    spec1 = BatchSpec(8, 16, 100, num_hosts=2, host_index=1)
    c = SyntheticLM(spec1, seed=3).batch_at(5)
    assert not np.array_equal(a["tokens"], c["tokens"])  # hosts differ
    assert a["tokens"].shape == (4, 16)  # per-host shard
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_packed_corpus_shapes():
    docs = [np.arange(50), np.arange(30)]
    spec = BatchSpec(global_batch=4, seq_len=16, vocab=100)
    pc = PackedCorpus(docs, spec, seed=0)
    b = pc.batch_at(0)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(pc.batch_at(3)["tokens"],
                                  pc.batch_at(3)["tokens"])
