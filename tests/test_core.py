"""BoomHQ core: data encoder anomaly signal, query encoder features,
executor strategies, rewriter training, end-to-end optimizer behaviour."""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.bench import datasets, queries
from repro.core.boomhq import BoomHQ, BoomHQConfig
from repro.core.data_encoder import DataEncoder, DataEncoderConfig
from repro.core.executor import HybridExecutor, MILVUS, PGVECTOR, recall_at_k
from repro.core.query import ExecutionPlan, MHQ, SubqueryParams
from repro.core.query_encoder import QueryEncoder
from repro.core.rewriter import RewriterConfig, candidate_plans
from repro.vectordb import flat, histogram, ivf
from repro.vectordb.predicates import Predicates


@pytest.fixture(scope="module")
def small_setup():
    table = datasets.make("part", rows=2000, seed=0)
    wl = queries.gen_workload(table, 20, n_vec_used=2, seed=1)
    return table, wl


def _fast_cfg(**over):
    return BoomHQConfig(
        n_clusters=16,
        encoder=DataEncoderConfig(frozen_steps=25, ae_steps=40, sample=512),
        rewriter=RewriterConfig(steps=80, refine_columns=False), **over)


def test_data_encoder_anomaly_signal(small_setup):
    """ε_recon must be higher for anomalous vector–scalar pairings than for
    pairings drawn from the data (the paper's core §3.2 claim)."""
    table, _ = small_setup
    de = DataEncoder([v.shape[1] for v in table.vectors], table.schema.n_scalar,
                     DataEncoderConfig(frozen_steps=80, ae_steps=150, sample=1024))
    de.fit(table)
    scal = np.asarray(table.scalars)
    m = table.schema.n_scalar
    normal_errs, anom_errs = [], []
    rng = np.random.default_rng(0)
    for i in rng.integers(0, table.n_rows, 24):
        qv = [jnp.asarray(np.asarray(v[i])) for v in table.vectors]
        # matched pairing: this row's own scalar values as point predicates
        pred_ok = Predicates.from_conditions(
            m, {j: (float(scal[i, j]), float(scal[i, j])) for j in range(2)})
        # anomalous: another random row's categories
        j = (i + 997) % table.n_rows
        pred_bad = Predicates.from_conditions(
            m, {0: (float(scal[j, 0]), float(scal[j, 0])),
                1: (float((scal[i, 1] + 13) % 50), float((scal[i, 1] + 13) % 50))})
        normal_errs.append(float(de.recon_errors(qv, pred_ok).mean()))
        anom_errs.append(float(de.recon_errors(qv, pred_bad).mean()))
    assert np.mean(anom_errs) > np.mean(normal_errs)


def test_local_probe_tracks_neighborhood_density(small_setup):
    table, _ = small_setup
    idxs = [ivf.build(v, 16, seed=i) for i, v in enumerate(table.vectors)]
    hists = histogram.build(table.scalars)
    qe = QueryEncoder(table, idxs, hists, None)
    m = table.schema.n_scalar
    row = 17
    qv = tuple(jnp.asarray(np.asarray(v[row])) for v in table.vectors)
    scal = np.asarray(table.scalars)
    # predicate satisfied by this row's own cluster -> high local rate
    pred_local = Predicates.from_conditions(
        m, {0: (float(scal[row, 0]), float(scal[row, 0]))})
    # impossible predicate -> zero local rate
    pred_none = Predicates.from_conditions(m, {2: (1e9, 2e9)})
    q1 = MHQ(qv, (1.0, 0.0), pred_local)
    q2 = MHQ(qv, (1.0, 0.0), pred_none)
    r1, _ = qe.local_probe(q1)
    r2, _ = qe.local_probe(q2)
    assert r1[0] > r2[0]
    assert r2[0] == 0.0


def test_executor_strategies_reach_target(small_setup):
    table, wl = small_setup
    idxs = [ivf.build(v, 16, seed=i) for i, v in enumerate(table.vectors)]
    ex = HybridExecutor(table, idxs, PGVECTOR)
    q = wl[0]
    gt, _ = flat.ground_truth(table, list(q.query_vectors), list(q.weights),
                              q.predicates, q.k)
    # exhaustive variants must hit recall 1.0
    ff = ExecutionPlan("filter_first",
                       tuple(SubqueryParams() for _ in range(q.n_vec)),
                       max_candidates=table.n_rows)
    ids, _ = ex.execute(q, ff)
    assert recall_at_k(ids, gt) == 1.0
    big = ExecutionPlan("index_scan", tuple(
        SubqueryParams(k_mult=8, nprobe=16, max_scan=table.n_rows,
                       iterative=True) for _ in range(q.n_vec)))
    ids, _ = ex.execute(q, big)
    assert recall_at_k(ids, gt) >= 0.9


def test_engine_legalization(small_setup):
    table, wl = small_setup
    idxs = [ivf.build(v, 16, seed=i) for i, v in enumerate(table.vectors)]
    ex = HybridExecutor(table, idxs, MILVUS)
    plan = ExecutionPlan("index_scan", (
        SubqueryParams(k_mult=8, nprobe=32, max_scan=128, iterative=True),
        SubqueryParams(k_mult=2, nprobe=4, max_scan=64, iterative=True)))
    legal = ex.legalize(plan)
    for s in legal.subqueries:
        assert not s.iterative  # milvus: no iterative_scan
        assert s.max_scan == MILVUS.default_max_scan  # no max_scan_tuples
    # per-column k_i / nprobe remain free (BoomHQ tunes them per column, §5.4)
    assert legal.subqueries[0].k_mult == 8
    assert legal.subqueries[1].k_mult == 2


def test_single_index_skew_guard(small_setup):
    table, wl = small_setup
    bq = BoomHQ(table, _fast_cfg())
    bq.fit(wl[:10])
    q = dataclasses.replace(wl[10], weights=(0.5, 0.5))
    plan = bq.optimize(q)
    assert plan.strategy != "single_index"  # balanced weights never single-index


def test_boomhq_end_to_end_recall(small_setup):
    table, wl = small_setup
    bq = BoomHQ(table, _fast_cfg())
    bq.fit(wl[:14])
    recs = []
    for q in wl[14:]:
        gt, _ = flat.ground_truth(table, list(q.query_vectors),
                                  list(q.weights), q.predicates, q.k)
        ids, _ = bq.execute(q)
        recs.append(recall_at_k(ids, gt))
    assert np.mean(recs) >= 0.75  # tiny training set; safeguards carry it


def test_boomhq_insert_keeps_working(small_setup):
    table, wl = small_setup
    bq = BoomHQ(table, _fast_cfg())
    bq.fit(wl[:10])
    n0 = bq.table.n_rows
    vecs = [np.asarray(v[:100]) + 0.01 for v in table.vectors]
    scal = np.asarray(table.scalars[:100])
    bq.insert(vecs, scal, finetune=True)
    assert bq.table.n_rows == n0 + 100
    q = wl[12]
    gt, _ = flat.ground_truth(bq.table, list(q.query_vectors), list(q.weights),
                              q.predicates, q.k)
    ids, _ = bq.execute(q)
    assert recall_at_k(ids, gt) >= 0.5


def test_candidate_plans_cover_strategies():
    plans = candidate_plans(2, weights=(0.95, 0.05))
    strategies = {p.strategy for p in plans}
    assert strategies == {"filter_first", "index_scan", "single_index"}
