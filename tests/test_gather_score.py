"""Candidate-local gather+score kernel parity harness.

Three implementations of the same contract are pinned against each other:

  * the Pallas kernel (``use_kernel=True, interpret=True`` — the exact
    program a TPU backend would tile through Mosaic, executed by the
    interpreter on CPU);
  * the pure-jnp reference (``kernels.ref.gather_score_ref``, the off-TPU
    serving path);
  * an independent float64 NumPy oracle built here from ``tests/oracle.py``
    primitives (mask + similarity share no code with repro kernels).

Sweeps cover every clause bucket (C=1/2/4 plus the conjunctive shim), both
metrics (ip/l2), non-power-of-two candidate counts, duplicate and -1-padded
candidate rows, S < k underfill, and all-filtered-out groups. The vectordb
entry points that wrap the kernel (``ivf.search_local_batch``,
``flat.filter_first_local_batch``) are oracle-pinned at the bottom.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from oracle import eval_mask_np, similarity_np, tie_tolerance

from repro.kernels.gather_score import (
    NEG, gather_score_topk, merge_topk_unique,
)
from repro.vectordb.predicates import PredicateSet, Predicates, stack


# ---------------------------------------------------------------------------
# case construction
# ---------------------------------------------------------------------------

def _table(rng, n, dims, m):
    vectors = tuple(jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
                    for d in dims)
    scalars = jnp.asarray(rng.uniform(0, 10, (n, m)), jnp.float32)
    return vectors, scalars


def _random_pred(rng, m, c, *, sel=0.5, conjunctive_shim=False):
    """One random DNF predicate with ``c`` clauses (``c=1`` optionally as the
    conjunctive ``Predicates`` shim — the kernel must accept both)."""
    if conjunctive_shim:
        assert c == 1
        lo = rng.uniform(0, 10 * (1 - sel))
        return Predicates.from_conditions(m, {0: (lo, lo + 10 * sel)})
    clauses = []
    for _ in range(c):
        col = int(rng.integers(0, m))
        lo = rng.uniform(0, 10 * (1 - sel))
        clauses.append({col: (lo, lo + 10 * sel)})
    return PredicateSet.from_clauses(m, clauses, n_clauses=c)


def _candidates(rng, b, s, n, *, dup_frac=0.3, pad_frac=0.2):
    """(b, s) candidate matrix with duplicate rows and -1 padding mixed in."""
    cand = rng.integers(0, n, size=(b, s))
    n_dup = int(s * dup_frac)
    if n_dup and s > 1:
        for row in cand:
            src = rng.integers(0, s, size=n_dup)
            dst = rng.integers(0, s, size=n_dup)
            row[dst] = row[src]
    pad = rng.random(size=(b, s)) < pad_frac
    cand[pad] = -1
    return cand.astype(np.int32)


def _oracle_topk(cand, vectors, qs, weights, scalars, preds, k, metric):
    """Independent float64 oracle over the candidate subset.

    Per query: dedup valid candidate rows, score them exactly, apply the
    NumPy DNF mask, select top-k by (-score, id). Returns (ids (B, k),
    scores (B, k), n_qual (B,)) — ``n_qual`` counts qualifying SLOTS
    (duplicates included), matching the kernel's counter contract."""
    cand = np.asarray(cand)
    scal_np = np.asarray(scalars)
    b, _ = cand.shape
    out_ids = np.full((b, k), -1, np.int64)
    out_scores = np.full((b, k), NEG, np.float64)
    n_qual = np.zeros((b,), np.int64)
    for j in range(b):
        total = np.zeros((scal_np.shape[0],), np.float64)
        for i, v in enumerate(vectors):
            w = float(np.asarray(weights)[j, i])
            if w != 0.0:
                total += w * similarity_np(np.asarray(qs[i])[j],
                                           np.asarray(v), metric)
        mask = eval_mask_np(preds[j], scal_np) if preds is not None \
            else np.ones((scal_np.shape[0],), bool)
        slots = cand[j][cand[j] >= 0]
        n_qual[j] = int(np.sum(mask[slots]))
        rows = np.unique(slots)
        rows = rows[mask[rows]]
        order = rows[np.lexsort((rows, -total[rows]))][:k]
        out_ids[j, : len(order)] = order
        out_scores[j, : len(order)] = total[order]
    return out_ids, out_scores, n_qual


def _assert_vs_oracle(ids, scores, o_ids, o_scores, *, atol=1e-3):
    """Float32-vs-float64 tolerant comparison: scores must agree to
    tolerance; a differing id is only acceptable on an oracle score tie."""
    ids, scores = np.asarray(ids), np.asarray(scores)
    filled = o_ids >= 0
    assert np.array_equal(ids >= 0, filled)
    np.testing.assert_allclose(scores[filled], o_scores[filled],
                               atol=atol, rtol=1e-4)
    for j in range(ids.shape[0]):
        for p in np.flatnonzero(ids[j] != o_ids[j]):
            tol = tie_tolerance(float(o_scores[j, p]))
            assert abs(scores[j, p] - o_scores[j, p]) <= tol, (
                j, p, ids[j, p], o_ids[j, p], scores[j, p], o_scores[j, p])


def _run_all_paths(cand, vectors, qs, weights, scalars, pred_b, *, k, metric,
                   block_s=32):
    kern = gather_score_topk(jnp.asarray(cand), vectors, qs, weights,
                             scalars, pred_b, k=k, metric=metric,
                             use_kernel=True, interpret=True, block_s=block_s)
    ref = gather_score_topk(jnp.asarray(cand), vectors, qs, weights,
                            scalars, pred_b, k=k, metric=metric,
                            use_kernel=False)
    return kern, ref


def _check_case(rng, *, n, dims, m, b, s, c, k, metric, sel=0.5,
                conjunctive_shim=False, block_s=32):
    vectors, scalars = _table(rng, n, dims, m)
    preds = [_random_pred(rng, m, c, sel=sel,
                          conjunctive_shim=conjunctive_shim)
             for _ in range(b)]
    pred_b = stack(preds)
    qs = tuple(jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
               for d in dims)
    weights = jnp.asarray(rng.uniform(0.1, 1.0, (b, len(dims))), jnp.float32)
    cand = _candidates(rng, b, s, n)

    (ids_k, s_k, q_k), (ids_r, s_r, q_r) = _run_all_paths(
        cand, vectors, qs, weights, scalars, pred_b, k=k, metric=metric,
        block_s=block_s)

    # kernel vs reference: identical ids and counters, scores to tolerance
    np.testing.assert_array_equal(np.asarray(ids_k), np.asarray(ids_r))
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               atol=1e-3, rtol=1e-4)

    # both vs the independent float64 oracle
    o_ids, o_scores, o_qual = _oracle_topk(
        cand, vectors, qs, weights, scalars, preds, k, metric)
    np.testing.assert_array_equal(np.asarray(q_r), o_qual)
    _assert_vs_oracle(ids_r, s_r, o_ids, o_scores)
    _assert_vs_oracle(ids_k, s_k, o_ids, o_scores)


# ---------------------------------------------------------------------------
# deterministic corpus
# ---------------------------------------------------------------------------

CORPUS = [
    # (seed, n, dims, m, b, s, c, k, metric)
    (0, 200, (16,), 2, 3, 64, 1, 5, "dot"),
    (1, 200, (16, 8), 3, 2, 33, 1, 5, "l2"),       # non-pow2 S, 2 columns
    (2, 300, (8,), 2, 4, 100, 2, 10, "dot"),       # C=2, non-pow2 S
    (3, 300, (8, 24), 2, 2, 57, 2, 7, "l2"),
    (4, 150, (32,), 4, 3, 48, 4, 10, "dot"),       # C=4 bucket
    (5, 150, (8,), 3, 2, 96, 4, 3, "l2"),
    (6, 120, (8,), 2, 2, 3, 1, 5, "dot"),          # S < k underfill
    (7, 250, (16,), 2, 1, 129, 2, 10, "dot"),      # S % block_s == 1
]


@pytest.mark.parametrize("seed,n,dims,m,b,s,c,k,metric", CORPUS)
def test_kernel_parity_corpus(seed, n, dims, m, b, s, c, k, metric):
    _check_case(np.random.default_rng(seed), n=n, dims=dims, m=m, b=b, s=s,
                c=c, k=k, metric=metric)


def test_kernel_parity_conjunctive_shim():
    """The C=1 conjunctive ``Predicates`` shim must hit the same path as a
    one-clause ``PredicateSet``."""
    _check_case(np.random.default_rng(11), n=180, dims=(16,), m=2, b=3, s=40,
                c=1, k=5, metric="dot", conjunctive_shim=True)


def test_all_filtered_out_group():
    """A group whose predicate matches nothing: all ids -1, scores NEG,
    n_qualified 0 — on both the kernel and the reference."""
    rng = np.random.default_rng(21)
    vectors, scalars = _table(rng, 120, (16,), 2)
    pred_b = stack([PredicateSet.from_clauses(
        2, [{0: (100.0, 200.0)}, {1: (-50.0, -40.0)}]) for _ in range(2)])
    qs = (jnp.asarray(rng.normal(size=(2, 16)), jnp.float32),)
    w = jnp.ones((2, 1), jnp.float32)
    cand = _candidates(rng, 2, 64, 120, pad_frac=0.0)
    for use_kernel in (True, False):
        ids, scores, n_qual = gather_score_topk(
            jnp.asarray(cand), vectors, qs, w, scalars, pred_b, k=5,
            metric="dot", use_kernel=use_kernel, interpret=True, block_s=32)
        assert (np.asarray(ids) == -1).all()
        assert (np.asarray(scores) <= NEG / 2).all()
        assert (np.asarray(n_qual) == 0).all()


def test_duplicates_never_crowd_out_distinct_rows():
    """A candidate list dominated by copies of one row must still surface k
    DISTINCT qualifying rows: duplicates are knocked out by row id inside
    each block and deduplicated again at the merge."""
    rng = np.random.default_rng(31)
    vectors, scalars = _table(rng, 100, (8,), 1)
    total = np.asarray(vectors[0] @ rng.normal(size=(8,)))  # just for rows
    best = int(np.argmax(total))
    k = 5
    others = [r for r in range(20) if r != best][: 2 * k]
    cand = np.asarray([[best] * 40 + others + [-1] * 6], np.int32)
    qs = (jnp.asarray(rng.normal(size=(1, 8)), jnp.float32),)
    w = jnp.ones((1, 1), jnp.float32)
    pred_b = stack([Predicates.none(1)])
    for use_kernel in (True, False):
        ids, scores, n_qual = gather_score_topk(
            jnp.asarray(cand), vectors, qs, w, scalars, pred_b, k=k,
            metric="dot", use_kernel=use_kernel, interpret=True, block_s=16)
        got = np.asarray(ids)[0]
        assert (got >= 0).all()
        assert len(set(got.tolist())) == k  # k distinct rows
        assert int(np.asarray(n_qual)[0]) == 40 + len(others)


def test_pred_none_skips_masking():
    """pred=None (pre-qualified candidates, the rerank-union path) must
    score every valid slot."""
    rng = np.random.default_rng(41)
    vectors, scalars = _table(rng, 90, (8,), 2)
    qs = (jnp.asarray(rng.normal(size=(2, 8)), jnp.float32),)
    w = jnp.ones((2, 1), jnp.float32)
    cand = _candidates(rng, 2, 48, 90, pad_frac=0.25)
    o_ids, o_scores, _ = _oracle_topk(cand, vectors, qs, w, scalars, None,
                                      5, "dot")
    for use_kernel in (True, False):
        ids, scores, n_qual = gather_score_topk(
            jnp.asarray(cand), vectors, qs, w, scalars, None, k=5,
            metric="dot", use_kernel=use_kernel, interpret=True, block_s=16)
        np.testing.assert_array_equal(
            np.asarray(n_qual), np.sum(cand >= 0, axis=1))
        _assert_vs_oracle(ids, scores, o_ids, o_scores)


def test_merge_topk_unique_underfill_and_ties():
    """The cross-block merge: duplicates keep one slot, padding never
    surfaces, ties break by smaller row id."""
    ids = jnp.asarray([[7, 3, 7, -1, 3, 9]], jnp.int32)
    scores = jnp.asarray([[1.0, 2.0, 1.0, NEG, 2.0, 2.0]], jnp.float32)
    out_ids, out_scores = merge_topk_unique(ids, scores, 5)
    # 3 and 9 tie at 2.0 -> smaller id first; 7 at 1.0; then empty slots
    np.testing.assert_array_equal(np.asarray(out_ids)[0],
                                  [3, 9, 7, -1, -1])
    assert np.asarray(out_scores)[0, 3] <= NEG / 2


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 100_000), n=st.integers(40, 300),
       d=st.sampled_from([8, 16]), m=st.integers(1, 4),
       b=st.integers(1, 4), s=st.integers(1, 80),
       c=st.sampled_from([1, 2, 4]), k=st.sampled_from([1, 5, 10]),
       metric=st.sampled_from(["dot", "l2"]),
       sel=st.floats(0.05, 1.0))
def test_kernel_parity_property(seed, n, d, m, b, s, c, k, metric, sel):
    """Hypothesis sweep of the same three-way parity over random shapes,
    clause buckets, metrics and selectivities."""
    _check_case(np.random.default_rng(seed), n=n, dims=(d,), m=m, b=b, s=s,
                c=c, k=k, metric=metric, sel=sel, block_s=16)


@pytest.mark.slow
def test_kernel_parity_large_shapes():
    """Interpreter-mode kernel on realistic block/candidate widths (the
    shapes a TPU run would tile) — slow under the interpreter, so marked
    for the tier-1 job only."""
    rng = np.random.default_rng(51)
    _check_case(rng, n=4000, dims=(64, 32), m=4, b=8, s=1024, c=2, k=10,
                metric="dot", block_s=256)
    _check_case(rng, n=4000, dims=(32,), m=3, b=4, s=777, c=4, k=10,
                metric="l2", block_s=256)


# ---------------------------------------------------------------------------
# vectordb candidate-local entry points vs the oracle
# ---------------------------------------------------------------------------

def test_search_local_batch_matches_scored_search(tiny_table):
    """ivf.search_local_batch (fused gather+score) against the dense-scored
    per-query search on the same probes: same probe slots, so the result
    sets agree up to float ties."""
    import jax

    from repro.vectordb import ivf
    from repro.vectordb.table import similarity

    t = tiny_table
    rng = np.random.default_rng(61)
    idx = ivf.build(t.vectors[0], 16, seed=0, metric=t.schema.metric)
    b, k, nprobe, max_scan = 4, 10, 8, 512
    q_b = jnp.asarray(rng.normal(size=(b, t.vectors[0].shape[1])),
                      jnp.float32)
    preds = [_random_pred(rng, t.schema.n_scalar, c, sel=0.6)
             for c in (1, 2, 4, 1)]
    pred_b = stack(preds)
    ids_l, s_l, n_sc, n_q = ivf.search_local_batch(
        idx, t.vectors[0], t.scalars, pred_b, q_b,
        nprobe=nprobe, max_scan=max_scan, k=k)
    rs_b = jax.vmap(
        lambda q: similarity(q, t.vectors[0], t.schema.metric))(q_b)
    for j in range(b):
        ids_s, s_s, _, n_qs = ivf.search_scored(
            idx, rs_b[j], t.scalars, preds[j], q_b[j],
            nprobe=nprobe, max_scan=max_scan, k=k)
        assert int(n_q[j]) == int(n_qs)
        # same candidate slots -> same top-k SET up to float ties
        np.testing.assert_allclose(
            np.sort(np.asarray(s_l[j])), np.sort(np.asarray(s_s)),
            atol=1e-3, rtol=1e-4)


def test_filter_first_local_batch_matches_sequential(tiny_table):
    """flat.filter_first_local_batch vs the sequential filter_first on the
    same cap: identical counters, score parity, tie-tolerant ids."""
    from repro.vectordb import flat

    t = tiny_table
    rng = np.random.default_rng(71)
    b, k, cap = 3, 10, 256
    preds = [_random_pred(rng, t.schema.n_scalar, c, sel=0.4)
             for c in (1, 2, 4)]
    pred_b = stack(preds)
    qs = [tuple(jnp.asarray(rng.normal(size=(v.shape[1],)), jnp.float32)
                for v in t.vectors) for _ in range(b)]
    q_b = tuple(jnp.stack([qs[j][i] for j in range(b)])
                for i in range(t.schema.n_vec))
    w = rng.uniform(0.2, 1.0, (b, t.schema.n_vec)).astype(np.float32)
    ids_l, s_l, n_sc, n_q = flat.filter_first_local_batch(
        tuple(t.vectors), t.scalars, pred_b, q_b, jnp.asarray(w),
        k=k, max_candidates=cap, n_vec=t.schema.n_vec,
        metric=t.schema.metric)
    for j in range(b):
        ids_s, s_s, n_sc_s, n_q_s = flat.filter_first(
            tuple(t.vectors), t.scalars, preds[j], qs[j],
            jnp.asarray(w[j]), t.schema.metric, k=k, max_candidates=cap,
            n_vec=t.schema.n_vec)
        assert int(n_q[j]) == int(n_q_s)
        assert int(n_sc[j]) == int(n_sc_s)
        np.testing.assert_allclose(np.asarray(s_l[j]), np.asarray(s_s),
                                   atol=1e-3, rtol=1e-4)
