"""Serving engine: greedy generation across families + determinism."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm
from repro.models.lm_serving import greedy_generate


@pytest.mark.parametrize("arch", ["qwen3-14b", "mamba2-370m", "zamba2-2.7b",
                                  "deepseek-v3-671b", "musicgen-large"])
def test_greedy_generate(arch):
    cfg = configs.get_config(arch, smoke=True)
    rng = np.random.default_rng(0)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    b, s, steps = 2, 16, 6
    if cfg.inputs_are_embeds:
        batch = {"embeds": jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                                       jnp.float32)}
    else:
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                       jnp.int32)}
    toks = greedy_generate(params, cfg, batch, steps=steps, max_len=s + steps + 2)
    assert toks.shape == (b, steps)
    assert (np.asarray(toks) >= 0).all() and (np.asarray(toks) < cfg.vocab).all()
    # deterministic
    toks2 = greedy_generate(params, cfg, batch, steps=steps, max_len=s + steps + 2)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))
