"""Pure-NumPy brute-force filtered top-k oracle.

Ground truth INDEPENDENT of every repro kernel: the DNF predicate mask, the
per-column similarities, the weighted combination and the top-k selection
are all re-derived here with plain NumPy in float64 — nothing is imported
from ``repro.vectordb`` or ``repro.serve``, so agreement between an
execution path and this oracle is evidence of correctness, not of two
kernels sharing a bug.

``tie_aware_recall`` is the float-tie-tolerant metric every recall-floor
assertion uses: a returned id counts as correct when its EXACT (float64)
score reaches the oracle's k-th score minus a tolerance scaled to the score
magnitude, so float32 reduction-order noise in the kernels cannot flip a
correct result into a miss.
"""
from __future__ import annotations

import numpy as np

NEG = -1e30


def eval_mask_np(pred, scalars: np.ndarray) -> np.ndarray:
    """(n,) bool DNF mask from the predicate's dense fields.

    Accepts the conjunctive ``Predicates`` shim ((M,) fields — lifted to one
    always-valid clause) or a ``PredicateSet`` ((C, M) fields + (C,)
    ``clause_valid``). OR over valid clauses of the AND over each clause's
    active columns; an inactive column always passes within its clause."""
    active = np.asarray(pred.active)
    lo = np.asarray(pred.lo, np.float64)
    hi = np.asarray(pred.hi, np.float64)
    if active.ndim == 1:  # conjunctive shim -> one valid clause
        active, lo, hi = active[None], lo[None], hi[None]
        valid = np.ones((1,), bool)
    else:
        valid = np.asarray(pred.clause_valid)
    s = np.asarray(scalars, np.float64)[:, None, :]  # (n, 1, M)
    ok = ((s >= lo[None]) & (s <= hi[None])) | ~active[None]
    return np.any(ok.all(axis=-1) & valid[None], axis=-1)


def similarity_np(q: np.ndarray, vecs: np.ndarray, metric: str) -> np.ndarray:
    """Row scores of ``vecs`` (n, d) against ``q`` (d,), float64. Matches
    the repo's metric conventions (higher = closer; l2 is the expanded
    negative squared distance)."""
    q = np.asarray(q, np.float64)
    vecs = np.asarray(vecs, np.float64)
    if metric == "dot":
        return vecs @ q
    if metric == "l2":
        return 2.0 * (vecs @ q) - np.sum(vecs * vecs, axis=-1) - float(q @ q)
    raise ValueError(f"unknown metric {metric!r}")


def exact_scores(table, query_vectors, weights) -> np.ndarray:
    """(n,) exact weighted similarity of every row, float64."""
    total = np.zeros((int(table.scalars.shape[0]),), np.float64)
    for i, q in enumerate(query_vectors):
        w = float(weights[i])
        if w != 0.0:
            total += w * similarity_np(
                np.asarray(q), np.asarray(table.vectors[i]),
                table.schema.metric)
    return total


def brute_force_topk(table, query_vectors, weights, pred, k: int):
    """Exact filtered top-k: (ids (k,), scores (k,), masked (n,)).

    ``masked`` holds every row's exact score with non-qualifying rows at
    NEG — the input ``tie_aware_recall`` needs. Unfilled result slots carry
    id -1 / score NEG, mirroring the kernels' conventions."""
    total = exact_scores(table, query_vectors, weights)
    mask = eval_mask_np(pred, np.asarray(table.scalars))
    masked = np.where(mask, total, NEG)
    order = np.argsort(-masked, kind="stable")[:k]
    found = masked[order] > NEG / 2
    ids = np.where(found, order, -1)
    scores = np.where(found, masked[order], NEG)
    return ids, scores, masked


def sharded_brute_force_topk(table, query_vectors, weights, pred, k: int,
                             n_shards: int):
    """Exact per-shard filtered top-k + candidate merge, pure NumPy.

    Mirrors the reference semantics of every sharded execution path: the
    table splits into ``n_shards`` contiguous ceil(n/S)-row shards, each
    shard keeps its local top-k over the exact masked scores, and the
    global result is the top-k of the S·k merged candidates (stable on
    score, shard-order on ties — the all-gather layout). Because each
    shard's local top-k is exact, the merge equals the global brute force
    up to float ties; this pins that the MERGE itself (not just the
    per-shard scans) loses nothing. Returns (ids, scores, masked) like
    ``brute_force_topk``."""
    total = exact_scores(table, query_vectors, weights)
    mask = eval_mask_np(pred, np.asarray(table.scalars))
    masked = np.where(mask, total, NEG)
    n = masked.shape[0]
    per = -(-n // n_shards)
    cand_ids, cand_scores = [], []
    for s in range(n_shards):
        seg = masked[s * per: min((s + 1) * per, n)]
        kk = min(k, seg.shape[0])
        order = np.argsort(-seg, kind="stable")[:kk]
        cand_ids.append(order + s * per)
        cand_scores.append(seg[order])
    cid = np.concatenate(cand_ids)
    cs = np.concatenate(cand_scores)
    order = np.argsort(-cs, kind="stable")[:k]
    found = cs[order] > NEG / 2
    ids = np.where(found, cid[order], -1)
    scores = np.where(found, cs[order], NEG)
    if ids.shape[0] < k:
        ids = np.pad(ids, (0, k - ids.shape[0]), constant_values=-1)
        scores = np.pad(scores, (0, k - scores.shape[0]),
                        constant_values=NEG)
    return ids, scores, masked


def tiered_brute_force_topk(segments, metric: str, query_vectors, weights,
                            pred, k: int):
    """Exact filtered top-k over a tiered table's hot ∪ cold union.

    ``segments`` is the logical table in GLOBAL ROW-ID ORDER: a list of
    ``(vectors_list, scalars)`` pairs — the cold table first, then each hot
    generation (sealing before active) — so row ids are positions in the
    concatenation, matching the tiered path's ``id_offset`` numbering.
    Returns (ids, scores, masked) like ``brute_force_topk``."""
    totals, masks = [], []
    for vectors_list, scalars in segments:
        scalars = np.asarray(scalars)
        total = np.zeros((int(scalars.shape[0]),), np.float64)
        for i, q in enumerate(query_vectors):
            w = float(weights[i])
            if w != 0.0:
                total += w * similarity_np(
                    np.asarray(q), np.asarray(vectors_list[i]), metric)
        totals.append(total)
        masks.append(eval_mask_np(pred, scalars))
    total = np.concatenate(totals)
    mask = np.concatenate(masks)
    masked = np.where(mask, total, NEG)
    order = np.argsort(-masked, kind="stable")[:k]
    found = masked[order] > NEG / 2
    ids = np.where(found, order, -1)
    scores = np.where(found, masked[order], NEG)
    if ids.shape[0] < k:
        ids = np.pad(ids, (0, k - ids.shape[0]), constant_values=-1)
        scores = np.pad(scores, (0, k - scores.shape[0]),
                        constant_values=NEG)
    return ids, scores, masked


def tie_tolerance(kth: float, atol: float = 1e-4, rtol: float = 1e-5) -> float:
    return atol + rtol * abs(kth)


def tie_aware_recall(ids, masked: np.ndarray, k: int, *,
                     atol: float = 1e-4, rtol: float = 1e-5) -> float:
    """Recall@k against the exact score landscape, tolerant of float ties.

    The budget is min(k, #qualifying rows); a returned id is correct when
    it qualifies and its exact score reaches the oracle's budget-th score
    minus a magnitude-scaled tolerance. Duplicates never double-count."""
    n_qual = int(np.sum(masked > NEG / 2))
    budget = min(k, n_qual)
    if budget == 0:
        return 1.0
    kth = np.sort(masked)[::-1][budget - 1]
    tol = tie_tolerance(float(kth), atol, rtol)
    got = {int(i) for i in np.asarray(ids).ravel() if i >= 0}
    correct = sum(1 for i in got if masked[i] >= kth - tol)
    return min(correct, budget) / budget
