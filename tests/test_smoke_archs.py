"""Per-arch REDUCED-config smoke (the assignment's required smoke tests):
one forward/train step on CPU asserting output shapes + no NaNs, plus a
two-step training-loss sanity for each family."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm
from repro.train.step import TrainPlan, init_state, make_train_step

B, S = 2, 32


def _batch(cfg, rng):
    b = {}
    s_tok = S
    if cfg.modality == "vlm":
        s_tok = S - cfg.n_prefix_embeds
        b["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_prefix_embeds, cfg.d_model)), jnp.float32)
    if cfg.inputs_are_embeds:
        b["embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        return b
    b["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, s_tok)), jnp.int32)
    b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, s_tok)), jnp.int32)
    return b


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_and_train_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)

    h, aux = lm.hidden(lm.init(jax.random.PRNGKey(0), cfg), cfg, batch)
    s_total = S if not (cfg.modality == "vlm") else S
    assert h.shape == (B, s_total, cfg.d_model) or cfg.modality == "vlm"
    assert np.isfinite(np.asarray(h, np.float32)).all(), f"{arch}: NaN hidden"

    plan = TrainPlan(microbatches=1, remat=True, total_steps=10, warmup=1)
    params, opt = init_state(jax.random.PRNGKey(0), cfg, plan)
    step = jax.jit(make_train_step(cfg, plan))
    l0 = None
    for i in range(2):
        params, opt, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"])), f"{arch}: loss NaN"
        l0 = l0 or float(metrics["loss"])
    assert float(metrics["loss"]) < l0 + 0.5  # sane (memorizing one batch)


@pytest.mark.parametrize("arch", ["gemma-7b", "deepseek-v3-671b", "mamba2-370m"])
def test_microbatched_step_close_to_single(arch):
    """Grad accumulation (mb=2) ends at ~the same loss as mb=1."""
    cfg = configs.get_config(arch, smoke=True)
    rng = np.random.default_rng(1)
    batch = _batch(cfg, rng)
    outs = {}
    for mb in (1, 2):
        plan = TrainPlan(microbatches=mb, total_steps=10, warmup=1)
        params, opt = init_state(jax.random.PRNGKey(0), cfg, plan)
        step = jax.jit(make_train_step(cfg, plan))
        params, opt, m = step(params, opt, batch)
        outs[mb] = float(m["loss"])
    # same data, same init: losses comparable (moe routing may differ slightly)
    assert abs(outs[1] - outs[2]) < 0.2


def test_param_counts_match_published_sizes():
    expect = {
        "gemma-7b": (8.5e9, 0.15),
        "qwen3-14b": (14.8e9, 0.15),
        "phi3-mini-3.8b": (3.8e9, 0.15),
        "stablelm-1.6b": (1.6e9, 0.15),
        "llava-next-mistral-7b": (7.2e9, 0.15),
        "musicgen-large": (1.8e9, 0.4),
        "zamba2-2.7b": (2.7e9, 0.25),
        "kimi-k2-1t-a32b": (1.03e12, 0.15),
        "deepseek-v3-671b": (6.71e11, 0.12),
        "mamba2-370m": (3.7e8, 0.25),
    }
    for arch, (target, tol) in expect.items():
        n = configs.get_config(arch).n_params()
        assert abs(n - target) / target < tol, (arch, n, target)


def test_active_params_moe():
    ds = configs.get_config("deepseek-v3-671b")
    act = ds.n_active_params()
    assert 2.5e10 < act < 4.5e10  # ~37B active
    kimi = configs.get_config("kimi-k2-1t-a32b")
    assert 2.0e10 < kimi.n_active_params() < 4.5e10  # ~32B active


def test_shape_applicability():
    from repro.configs.base import shape_applicable

    assert shape_applicable(configs.get_config("mamba2-370m"), "long_500k")[0]
    assert shape_applicable(configs.get_config("zamba2-2.7b"), "long_500k")[0]
    ok, why = shape_applicable(configs.get_config("gemma-7b"), "long_500k")
    assert not ok and "quadratic" in why
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        for arch in configs.ARCHS:
            assert shape_applicable(configs.get_config(arch), shape)[0]
