"""Batched MHQ execution: grouped, vmapped serving of many hybrid queries.

The sequential path (``HybridExecutor.execute``) pays one dispatch + host
sync per query, so throughput on small-to-mid tables is dominated by
per-query overhead rather than by scoring work. This module converts the hot
path into a batch-parallel one:

  * queries are grouped by (strategy, legalized per-column subquery params,
    k) — every query in a group runs the *same* static-shape kernel, so the
    group executes as one vmapped call over the query axis;
  * scoring is DENSE per chunk: one multithreaded GEMM computes every row's
    similarity for the whole batch, and search / filter-first / rerank
    kernels gather f32 *scores* instead of (max_scan, d) vector tensors —
    on CPU the vmapped vector gather is the dominant cost, and for wide
    columns it materializes hundreds of MB the single-query jit fuses away;
  * candidate counts, top-k widths and the batch axis are padded to
    power-of-two buckets, so the jit cache stays bounded instead of
    recompiling per distinct ``total`` / batch size;
  * pgvector-style ``iterative_scan`` re-expansion runs per *group*: one
    host sync reads the whole group's qualified counts, and only the
    still-underfilled subset re-selects slots at a doubled nprobe (the
    dense scores are reused, so re-expansion never re-scores vectors).

Per-query results match the sequential executor's exactly in structure and
up to float reduction order in values: the GEMM accumulates the same dots
as the gathered matvec but in a different blocking, so scores can differ in
the last ulp and near-exact ties may order differently. Bucketed top-k
widths are sliced back to the exact k (``lax.top_k`` is sorted, so the
prefix equals the narrower call), and padded candidate slots carry id -1,
which the dedupe/rerank masking already handles.

``ServingEngine`` is the deployment-shaped wrapper: it chops a request
stream into batches, drives ``BoomHQ.execute_batch`` (one fused optimizer
dispatch + one grouped execution pass per batch) and accounts QPS/recall.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import (
    CANDIDATE_PAD_FLOOR, EngineCaps, HybridExecutor, K_BUCKET_FLOOR, PGVECTOR,
    legalize_for_shard, next_bucket, plan_columns, pow2_at_most, recall_at_k,
    rerank_scored, rrf_extras, rrf_union_total, subquery_width,
)
from repro.core.query import (
    BEAM_GRID, ExecutionPlan, HOP_GRID, KMULT_GRID, MAX_SCAN_GRID, MHQ,
    NPROBE_GRID,
)
from repro.kernels.gather_score import gather_score_topk, merge_topk_unique
from repro.kernels.shapes import GRAPH_ENTRY_POINTS, GRAPH_SEED_FACTOR
from repro.vectordb import flat, graph, histogram, ivf, predicates
from repro.vectordb.distributed import (
    build_sharded_ivf, sharded_batch_topk, sharded_ivf_topk, sharded_topk_ref,
)
from repro.vectordb.predicates import eval_mask
from repro.vectordb.table import Table

# Dense-score budget: each chunk holds (batch, n_rows) f32 score matrices
# per active vector column; chunks are sized so batch · n_rows stays under
# this many slots (32 MB/column at the cap).
SLOT_BUDGET = 1 << 23
MAX_BATCH_KERNEL = 64  # widest vmapped execution kernel

# scoring paths the per-group dispatcher chooses between
DENSE = "dense"
CANDIDATE_LOCAL = "candidate_local"
# sharded-group routes: plan-driven per-shard IVF probing, or no fan-out at
# all (the group runs the plain single-device path when shards are too
# small to amortize the merge)
SHARDED_LOCAL = "sharded_local"
SINGLE_DEVICE = "single_device"

# histogram-estimated static gather caps (the sharded candidate-local path):
# cap = next_bucket(margin · estimated_max + slack), with overflow
# escalation re-running only the queries whose true count exceeds the cap
CAP_MARGIN = 1.5
CAP_SLACK = 32


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Scoring-path cost model: dense vs candidate-local, plus the sharded
    three-way route.

    The dense path runs one GEMM over ALL rows per vector column and group
    chunk — per-batch cost ∝ ``n_rows``, and (measured) essentially
    batch-size independent while B ≤ the chunk cap: the GEMM streams the
    table once either way. The candidate-local path gathers and scores
    only each query's legalized candidate budget, paying a FIXED per-batch
    overhead (probe slot selection dispatch, kernel launch, re-expansion
    host syncs) on top of the ``batch · scan`` gather work. Candidate-local
    wins when

        batch · scan + overhead  ≤  crossover · n_rows

    The constant term is what closes the ROADMAP's small-batch mispredict:
    without it the model sends every tiny batch candidate-local (B·scan
    shrinks with B but the fixed cost does not). Both constants are
    calibrated by ``benchmarks/kernels_bench.py`` (``crossover_sweep`` /
    ``overhead_sweep``) and the defaults are the values measured on this
    CPU container; a TPU backend with the Mosaic kernel should recalibrate
    ``crossover`` upward and ``overhead`` downward.

    ``choose_sharded`` adds the sharded three-way: groups over a sharded
    table run plan-driven per-shard IVF probing (``SHARDED_LOCAL``) when
    the same inequality holds at the global scale (the probe work is split
    across shards but the fixed overhead is paid once per batch), the
    exact per-shard dense scan otherwise — and skip the fan-out entirely
    (``SINGLE_DEVICE``) when shards are smaller than ``min_shard_rows``,
    where the O(shards·k) merge costs more than it saves.

    The crossover is PER PRECISION: the int8 candidate tier gathers 1-byte
    elements (4× less memory traffic in the heavy stage) but pays an extra
    fixed cost per batch — the exact fp32 rerank of the top-α·k survivors
    is a second kernel dispatch. So ``crossover_int8 > crossover``: the
    candidate-local region widens — int8 groups stay candidate-local at
    scan budgets that would have pushed fp32 groups dense. Both int8
    constants are measured by the same ``kernels_bench`` sweeps run
    against the quantized path
    (``benchmarks/results/quantized_crossover.json``); on this container
    the measured fixed intercept is LOWER than fp32's in gathered-row
    units (the rerank dispatch is small next to the cheaper per-row
    gather the intercept is normalized by).

    ``force`` pins every group to one path (benchmarks and dispatcher
    tests): dense-flavored forces pin dense, local-flavored forces pin the
    context's local path."""

    crossover: float = 0.136
    overhead: float = 2048.0  # per-batch fixed cost, in gathered-row units
    crossover_int8: float = 0.545  # measured: results/quantized_crossover.json
    overhead_int8: float = 3350.0  # measured, same calibration run
    # graph tier: graph_row_cost converts visited-row budgets into
    # probed-slot units so the three tiers compare on one axis;
    # overhead_graph is the per-batch fixed cost of the walk dispatch
    # (n_hops sequential hop steps, not amortizable over the batch).
    # Measured by benchmarks/serving.py --graph
    # (benchmarks/results/graph_index.json), unit-anchored on the dense
    # exact scan's per-batch wall time. A visited graph row comes out
    # CHEAPER than one gathered-row unit — the per-hop neighbor gathers
    # vectorize across the whole query batch — which is why, once a graph
    # tier is bound, the fitted surface leaves probing only the cases the
    # planner routes to it for recall (or when a column has no graph).
    graph_row_cost: float = 0.216
    overhead_graph: float = 328.3
    min_shard_rows: int = 4096
    force: Optional[str] = None

    def constants(self, precision: str = "fp32") -> tuple[float, float]:
        """(crossover, overhead) of one precision tier."""
        if precision == "int8":
            return self.crossover_int8, self.overhead_int8
        return self.crossover, self.overhead

    def choose_strategy(self, *, batch: int, graph_scan: int,
                        probe_scan: int, n_rows: int) -> str:
        """Measured graph-vs-probe-vs-exact crossover at the STRATEGY level
        (the scoring-path crossovers above route a group once its strategy
        is fixed; this compares the strategies themselves, in the same
        gathered-row cost units):

          exact  ≈ crossover · n_rows          (one dense GEMM per column)
          probe  ≈ batch · probe_scan + overhead
          graph  ≈ batch · graph_scan · graph_row_cost + overhead_graph

        Returns the cheapest of {"exact", "index_scan", "graph"}. The
        planner uses it as a guard: recall is the rewriter's job, so this
        only breaks ties the learned heads are indifferent about (e.g. the
        skew-guard fallback path)."""
        costs = {
            "exact": self.crossover * n_rows,
            "index_scan": batch * probe_scan + self.overhead,
            "graph": batch * graph_scan * self.graph_row_cost
            + self.overhead_graph,
        }
        return min(costs, key=costs.get)

    def choose(self, *, batch: int, scan: int, n_rows: int,
               precision: str = "fp32") -> str:
        if self.force is not None:
            return CANDIDATE_LOCAL \
                if self.force in (CANDIDATE_LOCAL, SHARDED_LOCAL) else DENSE
        xo, oh = self.constants(precision)
        if batch * scan + oh <= xo * n_rows:
            return CANDIDATE_LOCAL
        return DENSE

    def choose_sharded(self, *, batch: int, scan: int, n_rows: int,
                       n_shards: int) -> str:
        if self.force is not None:
            if self.force in (CANDIDATE_LOCAL, SHARDED_LOCAL):
                return SHARDED_LOCAL
            return self.force  # DENSE or SINGLE_DEVICE
        if n_rows // max(1, n_shards) < self.min_shard_rows:
            return SINGLE_DEVICE
        return SHARDED_LOCAL if self.choose(
            batch=batch, scan=scan, n_rows=n_rows) == CANDIDATE_LOCAL \
            else DENSE


class ScoringDispatcher:
    """Per-execution-group scoring-path dispatch + decision log.

    Every group chunk asks :meth:`choose` before executing; the decision
    (group label, batch, candidate budget, chosen path) is recorded so
    serving reports can surface which path served the traffic
    (``ServeReport.path_counts``) and tests can assert the crossover is
    honored per group."""

    # decision log ring size: long-running servers (AsyncServingEngine never
    # drains the log) keep only the most recent window; counts stay exact
    MAX_DECISIONS = 4096

    def __init__(self, n_rows: int, cost_model: Optional[CostModel] = None):
        self.n_rows = int(n_rows)
        self.cost_model = cost_model if cost_model is not None else CostModel()
        self.counts: dict = {}
        self.decisions: deque = deque(maxlen=self.MAX_DECISIONS)

    def pins_dense(self, prefer_dense: bool) -> bool:
        """The paid-for-GEMM rule, held in ONE place: when a chunk's dense
        score matrices were already computed (the planner wanted them),
        gathering rows from them is strictly cheaper than re-scoring
        candidates from raw vectors — pin the chunk dense unless the cost
        model explicitly forces a path."""
        return prefer_dense and self.cost_model.force is None

    def choose(self, *, batch: int, scan: int, group=None,
               force: Optional[str] = None,
               prefer_dense: bool = False,
               precision: str = "fp32") -> str:
        if force is None and self.pins_dense(prefer_dense):
            force = DENSE
        path = force if force is not None else self.cost_model.choose(
            batch=batch, scan=scan, n_rows=self.n_rows, precision=precision)
        self.decisions.append(
            {"group": group, "batch": batch, "scan": scan, "path": path,
             "precision": precision})
        self.counts[path] = self.counts.get(path, 0) + 1
        return path

    def choose_sharded(self, *, batch: int, scan: int, n_shards: int,
                       group=None, prefer_dense: bool = False) -> str:
        """Route one sharded plan-driven group: per-shard IVF probing,
        exact per-shard dense scan, or no fan-out (single-device). A
        ``SINGLE_DEVICE`` decision delegates to the plain chunk path,
        which records its own inner dense/candidate-local decision. The
        paid-for-GEMM rule applies here too: when the batch's dense score
        matrices already exist, the exact sharded scan over them is
        strictly cheaper than re-scoring candidates from raw vectors."""
        if self.pins_dense(prefer_dense):
            path = DENSE
        else:
            path = self.cost_model.choose_sharded(
                batch=batch, scan=scan, n_rows=self.n_rows,
                n_shards=n_shards)
        self.decisions.append(
            {"group": group, "batch": batch, "scan": scan, "path": path})
        self.counts[path] = self.counts.get(path, 0) + 1
        return path

    def take(self) -> tuple[dict, list]:
        """Return (counts, recent decisions) accumulated since the last
        take, and reset both."""
        counts, decisions = self.counts, list(self.decisions)
        self.counts = {}
        self.decisions.clear()
        return counts, decisions


# Registered static-shape vocabularies. Every shape-bearing static argument
# a serving-path jit is called with must come from one of these grids, a
# power-of-two ``next_bucket`` value, or one of the two floors (the floors,
# ``next_bucket``/``pow2_at_most`` and the candidate-union width formulas
# live in core/executor — plan semantics shared with the sequential path —
# and are re-exported here) — that bound on distinct shapes is what bounds
# compile count, and boomlint (repro.analysis, rule RC001) checks call
# sites against this registry.
SHAPE_GRIDS = {
    "clause": predicates.CLAUSE_GRID,
    "nprobe": NPROBE_GRID,
    "max_scan": MAX_SCAN_GRID,
    "kmult": KMULT_GRID,
    "beam": BEAM_GRID,
    "hops": HOP_GRID,
}


def pad_selection(sel: np.ndarray) -> np.ndarray:
    """Pad a (non-empty) query-index selection to its power-of-two bucket
    by repeating the first element — the shared scaffolding of every
    subset-retry path (escalation, overflow re-gather, re-expansion):
    padding lanes compute a duplicate result that callers slice away."""
    bb = next_bucket(len(sel))
    return np.concatenate([sel, np.full(bb - len(sel), sel[0])])


def warm_bucket_ladder(execute_batch, queries: list, batch_size: int) -> None:
    """Warm the jit caches across the batch-bucket ladder.

    Arrival-driven serving (serve/queue.py) cuts batches at many sizes and
    each padded bucket is a distinct static shape; one untimed pass per
    power-of-two bucket — through ``next_bucket(batch_size)``, so a
    non-power-of-two batch_size still warms its top bucket — keeps cold
    compiles out of measured (and deadline-bounded) serving."""
    b = 1
    while b <= next_bucket(batch_size) and queries:
        execute_batch(queries[: min(b, len(queries))])
        b <<= 1


# ---------------------------------------------------------------------------
# vmapped kernels (batch axis = queries; one compile per static bucket)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("metric",))
def _dense_scores(vectors, q_b, *, metric):
    """(B, n) similarities of every row against every query in the batch —
    ONE multithreaded GEMM instead of B (max_scan, d) vector gathers. All
    downstream kernels gather f32 scores, not d-dim vectors."""
    from repro.vectordb.table import similarity

    return jax.vmap(lambda q: similarity(q, vectors, metric))(q_b)


def compute_batch_scores(table: Table, queries: list[MHQ]) -> tuple:
    """Per-column (B_bucket, n) dense similarity matrices for a query batch
    (batch axis padded to a power-of-two bucket by repeating the first
    query). Computed ONCE per batch and shared by the batched optimizer
    (pre-probe features) and the batched executor (search / filter-first /
    rerank scoring)."""
    bb = next_bucket(len(queries))
    qpad = list(queries) + [queries[0]] * (bb - len(queries))
    return tuple(
        _dense_scores(table.vectors[i],
                      jnp.stack([q.query_vectors[i] for q in qpad]),
                      metric=table.schema.metric)
        for i in range(table.schema.n_vec))


@partial(jax.jit, static_argnames=("nprobe", "max_scan", "k"))
def _search_batch(index, scores_b, scalars, pred_b, q_b, *, nprobe, max_scan,
                  k):
    def one(rs, pred, qv):
        return ivf.search_scored(index, rs, scalars, pred, qv,
                                 nprobe=nprobe, max_scan=max_scan, k=k)

    return jax.vmap(one)(scores_b, pred_b, q_b)


@partial(jax.jit, static_argnames=("k", "max_candidates"))
def _filter_first_batch(w_scores_b, scalars, pred_b, *, k, max_candidates):
    def one(rs, pred):
        return flat.filter_first_scored(rs, scalars, pred, k=k,
                                        max_candidates=max_candidates)

    return jax.vmap(one)(w_scores_b, pred_b)


@partial(jax.jit, static_argnames=("k", "total"))
def _rerank_batch(w_scores_b, rows_b, *, k, total):
    def one(rs, rows):
        return rerank_scored(rs, rows, k=k, total=total)

    return jax.vmap(one)(w_scores_b, rows_b)


@jax.jit
def _eval_mask_batch(pred_b, scalars):
    """(B,) stacked predicates × (n, M) scalars -> (B, n) bool masks."""
    return jax.vmap(lambda p: eval_mask(p, scalars))(pred_b)


@jax.jit
def _selectivity_batch(hists, pred_b):
    """(B,) histogram selectivity estimates for a stacked predicate batch —
    a tiny pure-stats computation (no table reads), so syncing it to size a
    static gather cap costs microseconds, not a device round-trip through
    the (B, n) mask kernel."""
    return jax.vmap(
        lambda p: histogram.estimate_selectivity(hists, p))(pred_b)


@partial(jax.jit, static_argnames=("k", "metric"))
def _gather_rerank_batch(rows_b, vectors, q_b, w_b, scalars, *, k, metric):
    """Candidate-local weighted re-rank: fused gather+score+dedup+top-k over
    the candidate union — no (B, n) weighted score matrix."""
    return gather_score_topk(rows_b, vectors, q_b, w_b, scalars, None,
                             k=k, metric=metric)


@partial(jax.jit, static_argnames=("size",))
def _qualifying_rows_batch(mask_b, *, size):
    """(B, n) bool masks -> (B, size) qualifying row ids, -1 padded."""
    return jax.vmap(
        lambda m: jnp.nonzero(m, size=size, fill_value=-1)[0]
    )(mask_b).astype(jnp.int32)


NEG = -1e30


@partial(jax.jit, static_argnames=("shard_len", "k", "metric"))
def _sharded_exact_retry(vectors, scalars, pred_b, q_b, w_b, need_b, *,
                         shard_len, k, metric):
    """Exact weighted filtered top-k over each query's underfilled
    shard-subset: dense scores for the retry subset (one GEMM per column),
    the predicate mask ANDed with the per-query shard-allow mask (rows of
    well-filled shards contribute nothing — their probed top-k stands),
    then one top-k. Used when the escalated queries span most shards: one
    batched retry beats a per-shard dispatch loop."""
    from repro.vectordb.table import similarity

    n = scalars.shape[0]
    s_count = need_b.shape[1]
    ws = jnp.zeros((w_b.shape[0], n), jnp.float32)
    for i, v in enumerate(vectors):
        ws = ws + w_b[:, i, None] * jax.vmap(
            lambda q, vv=v: similarity(q, vv, metric))(q_b[i])
    shard_of = jnp.minimum(jnp.arange(n, dtype=jnp.int32) // shard_len,
                           s_count - 1)
    allow = need_b[:, shard_of]
    mask = jax.vmap(lambda p: eval_mask(p, scalars))(pred_b) & allow
    masked = jnp.where(mask, ws, NEG)
    top_s, top_i = jax.lax.top_k(masked, k)
    ids = jnp.where(top_s > NEG / 2, top_i, -1)
    return ids.astype(jnp.int32), top_s


# ---------------------------------------------------------------------------
# batched executor
# ---------------------------------------------------------------------------

class BatchedHybridExecutor:
    """Executes a list of (MHQ, ExecutionPlan) pairs with grouped vmapped
    kernels. Produces per-query results identical to ``HybridExecutor``.

    With ``n_shards > 1`` (or a bound ``mesh``) the executor additionally
    exposes the CROSS-SHARD paths (:meth:`execute_batch_sharded`): formed
    batches fan out over contiguous table shards. Without plans, every
    clause-bucket group runs the EXACT per-shard scan (mask + local top-k
    over the dense score matrices, one O(shards · k) merge). With learned
    plans, index-strategy groups are dispatcher-routed three ways: the
    plan-driven per-shard IVF probing path (``ShardedIVF`` — each shard
    probes its own index with the group's shard-legalized knobs and reranks
    candidate-locally inside the shard), the exact per-shard dense scan, or
    the plain single-device path when shards are too small to amortize the
    fan-out. A real mesh runs both sharded paths under ``shard_map``;
    without one the logical-shard reference kernels keep the identical
    semantics on a single device.
    """

    def __init__(self, table: Table, indexes: list,
                 engine: EngineCaps = PGVECTOR, *, n_shards: int = 1,
                 mesh=None, shard_axes=("data",),
                 cost_model: Optional[CostModel] = None, hists=None,
                 graphs=None):
        self.table = table
        self.indexes = indexes
        self.engine = engine
        self.graphs = tuple(graphs) if graphs is not None else None
        self.hists = hists  # selectivity stats for static gather caps
        self.dispatcher = ScoringDispatcher(table.n_rows, cost_model)
        self.mesh = mesh
        self.shard_axes = shard_axes if isinstance(shard_axes, tuple) \
            else (shard_axes,)
        if mesh is not None:
            n_shards = 1
            for a in self.shard_axes:
                n_shards *= mesh.shape[a]
            if table.n_rows % n_shards:
                raise ValueError(
                    f"table rows {table.n_rows} not divisible over "
                    f"{n_shards} mesh shards")
        self.n_shards = max(1, int(n_shards))
        self._shard_fns: dict = {}  # k -> jit'd shard_map kernel
        self._sivf: dict = {}  # col -> ShardedIVF (lazy, per shard config)
        self._sivf_fns: dict = {}  # (group key, act) -> jit'd probe kernel
        # query indices (positions in the last execute_batch_sharded call)
        # whose merged probe result underfilled and took the exact
        # shard-subset retry — benchmarks segment the probe-served tier
        # from the escalation tax with this; callers may clear it
        self.escalated: set = set()
        self._seq = HybridExecutor(table, indexes, engine, graphs=graphs)

    def legalize(self, plan: ExecutionPlan) -> ExecutionPlan:
        return self._seq.legalize(plan)

    # -- grouping ----------------------------------------------------------

    def _group_key(self, q: MHQ, plan: ExecutionPlan):
        """Everything that determines the static shape of the group kernel.

        filter_first groups on (k, max_candidates); index groups on the
        active columns and their effective (k_i, nprobe, max_scan,
        iterative) — all grid-valued, so the number of groups (and thus
        compiled kernels) stays small. The legalized DNF clause bucket
        (CLAUSE_GRID) joins both keys: every query in a group then stacks
        to one static (B, C, M) predicate shape, and mixed-complexity
        batches split into at most len(CLAUSE_GRID) extra groups. The
        plan's candidate-tier precision (PRECISION_GRID) joins the index
        key: int8 and fp32 groups compile different scoring kernels AND
        take different cost-model crossovers, so they must never share a
        chunk (legalization pins filter_first to fp32, so its key carries
        no precision component).
        """
        cb = predicates.clause_bucket(q.predicates)
        if plan.strategy == "filter_first":
            return ("ff", cb, q.k, plan.max_candidates)
        n = self.table.n_rows
        if plan.strategy == "graph":
            # graph groups key on the legalized (beam_width, n_hops) pair —
            # grid-valued (BEAM_GRID/HOP_GRID), they fix the static
            # candidate-pool shape of the routing trace — plus each active
            # column's k_i. Precision is pinned fp32 by legalization; it
            # rides in the key slot so _run_chunk_local unpacks uniformly.
            subs = tuple((i, min(plan.subqueries[i].k_mult * q.k, n),
                          plan.beam_width, plan.n_hops)
                         for i in plan_columns(q, plan))
            return ("gr", cb, q.k, subs, "fp32")
        subs = []
        for i in plan_columns(q, plan):
            sp = plan.subqueries[i]
            np0 = min(sp.nprobe, self.indexes[i].n_clusters,
                      self.engine.nprobe_cap)
            subs.append((i, min(sp.k_mult * q.k, n), np0,
                         min(sp.max_scan, n), sp.iterative))
        return ("ix", cb, q.k, tuple(subs), plan.precision)

    def _group_scan(self, key) -> int:
        """Per-query, per-active-column candidate budget of a group — the
        ``scan`` the cost model weighs against ``n_rows``.

        Both sides of the crossover scale with the group's active columns —
        dense runs one (B, n) GEMM per active column, candidate-local
        gathers each column's budget (and the rerank union gathers every
        active column per row) — so the comparison must be per column:
        filter_first's cap already is (every active column is gathered for
        each of the ``max_candidates`` rows), and index groups divide the
        summed per-column budgets by the column count. Legalization clamped
        every term (max_scan/max_candidates capped at the table size)."""
        if key[0] == "ff":
            return int(key[3])
        subs = key[3]
        if key[0] == "gr":
            # a graph subquery's budget is the rows its walk can visit:
            # entry points + qualifying seeds + hops · beam · degree
            tot = sum(GRAPH_ENTRY_POINTS + GRAPH_SEED_FACTOR * bw
                      + nh * bw * self.graphs[col].degree
                      for (col, _, bw, nh) in subs)
            return max(1, tot // max(1, len(subs)))
        return max(1, sum(s[3] for s in subs) // max(1, len(subs)))

    # -- execution ---------------------------------------------------------

    def execute_batch(self, queries: list[MHQ], plans: list[ExecutionPlan],
                      *, scores_b: Optional[tuple] = None
                      ) -> list[tuple[np.ndarray, np.ndarray]]:
        """-> one (ids (k,), scores (k,)) numpy pair per query, in order.

        ``scores_b``: optional per-column (B_bucket, n) dense similarity
        matrices from ``compute_batch_scores`` (row j = queries[j]); when
        given, chunks gather their rows from it instead of re-running the
        GEMMs."""
        assert len(queries) == len(plans)
        plans = [self.legalize(p) for p in plans]
        out: list = [None] * len(queries)
        groups: dict = {}
        for j, (q, p) in enumerate(zip(queries, plans)):
            groups.setdefault(self._group_key(q, p), []).append(j)
        chunk = pow2_at_most(max(1, min(
            MAX_BATCH_KERNEL, SLOT_BUDGET // max(self.table.n_rows, 1))))
        for key, idxs in groups.items():
            for s in range(0, len(idxs), chunk):
                part = idxs[s: s + chunk]
                self._run_chunk(key, [queries[j] for j in part], part, out,
                                bucket_cap=chunk, scores_b=scores_b)
        return out

    # -- cross-shard execution ---------------------------------------------

    def execute_batch_sharded(self, queries: list[MHQ],
                              plans: Optional[list[ExecutionPlan]] = None, *,
                              scores_b: Optional[tuple] = None
                              ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Cross-shard fan-out of a formed batch.

        Without ``plans`` (the exact mode): queries group by (legalized
        clause bucket, k) so every group stacks to one static (B, C, M)
        predicate shape, then each group runs as an EXACT sharded masked
        top-k — every shard masks + local-top-k's its slice of the dense
        score matrices and one O(shards · k) merge yields the global
        result. Underfill there can only mean fewer than k rows genuinely
        qualify.

        With learned ``plans``: groups form exactly like the single-device
        batched path (strategy + legalized grid params + clause bucket),
        and every index-strategy group is routed three ways by the cost
        model (``choose_sharded``): the PLAN-DRIVEN per-shard IVF probing
        path (each shard probes its own index with the group's
        shard-legalized knobs — the learned nprobe/max_scan finally
        operative at shard scale), the exact per-shard dense scan, or the
        plain single-device path when shards are too small to amortize the
        fan-out. filter_first groups keep the exact sharded scan (their
        plan IS the full filtered gather).
        """
        out: list = [None] * len(queries)
        chunk = pow2_at_most(max(1, min(
            MAX_BATCH_KERNEL, SLOT_BUDGET // max(self.table.n_rows, 1))))
        if plans is None:
            groups: dict = {}
            for j, q in enumerate(queries):
                groups.setdefault(
                    (predicates.clause_bucket(q.predicates), q.k),
                    []).append(j)
            for (_, k), idxs in groups.items():
                for s in range(0, len(idxs), chunk):
                    part = idxs[s: s + chunk]
                    self._run_chunk_sharded(
                        [queries[j] for j in part], part, out, k=k,
                        bucket_cap=chunk, scores_b=scores_b)
            return out
        assert len(plans) == len(queries)
        plans = [self.legalize(p) for p in plans]
        groups = {}
        for j, (q, p) in enumerate(zip(queries, plans)):
            groups.setdefault(self._group_key(q, p), []).append(j)
        for key, idxs in groups.items():
            for s in range(0, len(idxs), chunk):
                part = idxs[s: s + chunk]
                qs = [queries[j] for j in part]
                if key[0] == "ff":
                    self._run_chunk_sharded(qs, part, out, k=key[2],
                                            bucket_cap=chunk,
                                            scores_b=scores_b)
                    continue
                if key[0] == "gr":
                    # the sealed graph is one whole-table adjacency, not a
                    # per-shard structure — graph groups always run the
                    # single-device candidate-local walk, whose visited-row
                    # budget is tiny next to any sharded scan
                    self._run_chunk(key, qs, part, out, bucket_cap=chunk,
                                    scores_b=scores_b)
                    continue
                bb = min(next_bucket(len(part)), chunk)
                path = self.dispatcher.choose_sharded(
                    batch=bb, scan=self._group_scan(key),
                    n_shards=self.n_shards,
                    group=("sharded-ivf",) + key[:3],
                    prefer_dense=scores_b is not None)
                if path == SINGLE_DEVICE:
                    self._run_chunk(key, qs, part, out, bucket_cap=chunk,
                                    scores_b=scores_b)
                elif path == SHARDED_LOCAL:
                    self._run_chunk_sharded_ivf(key, qs, part, out,
                                                bucket_cap=chunk)
                else:
                    self._run_chunk_sharded(qs, part, out, k=key[2],
                                            bucket_cap=chunk,
                                            scores_b=scores_b)
        return out

    def _shard_fn(self, k: int):
        """shard_map kernel for this mesh, one jit per k."""
        if k not in self._shard_fns:
            self._shard_fns[k] = sharded_batch_topk(
                self.mesh, self.shard_axes, k=k)
        return self._shard_fns[k]

    # -- plan-driven per-shard IVF probing ----------------------------------

    def _sivf_col(self, col: int):
        """This shard config's per-shard IVF of one column (lazy). Each
        shard keeps the bound index's FULL cluster count — S× finer
        granularity relative to its rows — because the per-shard slot
        budget is the global ``max_scan`` split S ways, and finer clusters
        target those fewer slots much better (measured on the 500k suite:
        probe-tier recall 0.08 → 0.22 and +57% QPS vs dividing C by S).
        The 1-shard configuration reuses the bound index verbatim, so it
        is bit-for-bit the single-device candidate-local path."""
        if col not in self._sivf:
            base = self.indexes[col]
            self._sivf[col] = build_sharded_ivf(
                self.table.vectors[col], self.n_shards,
                n_clusters=base.n_clusters,
                seed=col, metric=self.table.schema.metric, base_index=base)
        return self._sivf[col]

    def _sivf_fn(self, key, act: tuple):
        """jit'd per-shard probing kernel for one (group key, active-column
        set) — all plan params are shard-legalized here, so the static
        grid stays as bounded as the single-device group keys."""
        fkey = (key, act)
        if fkey not in self._sivf_fns:
            k, subs = key[2], key[3]
            shard_subs, total = [], 0
            for (col, k_i, np0, ms, _it) in subs:
                sivf = self._sivf_col(col)
                k_s, np_s, ms_s = legalize_for_shard(
                    k_i, np0, ms, n_shards=self.n_shards,
                    shard_len=sivf.shard_len, n_clusters=sivf.n_clusters)
                ks = subquery_width(k_s, ms_s)
                shard_subs.append((act.index(col), k_s, ks, np_s, ms_s))
                total += k_s
            pad_total = (rrf_union_total(total) if len(shard_subs) > 1
                         else next_bucket(total, CANDIDATE_PAD_FLOOR))
            self._sivf_fns[fkey] = sharded_ivf_topk(
                self.n_shards, self.mesh, self.shard_axes,
                subs=tuple(shard_subs), k=k, n_cols=len(act),
                metric=self.table.schema.metric, pad_total=pad_total)
        return self._sivf_fns[fkey]

    def _run_chunk_sharded_ivf(self, key, qs: list[MHQ], part: list[int],
                               out: list, *, bucket_cap: int):
        """One plan-driven sharded group chunk: per-shard IVF probing with
        the group's shard-legalized knobs, candidate-local rerank inside
        each shard, one O(shards · k) merge — no dense score matrix is
        ever built. Per-shard BOUNDARY escalation afterwards: a shard that
        kept a full local top-k whose weakest kept score sits at-or-above
        the merged k-th (its truncated local k+1-th row may belong in the
        global top-k) re-runs as an exact masked top-k over ONLY that
        shard-subset's rows; merged underfill keeps the old escalate-all
        fallback. Shards whose boundary is strictly below the merged
        cutoff provably contributed everything relevant and are never
        rescanned."""
        t = self.table
        k, subs = key[2], key[3]  # per-shard probing scores fp32 — the
        # int8 tier targets the single-device candidate-local path, so an
        # int8-precision group routed here keeps the exact scoring
        bb = min(next_bucket(len(qs)), bucket_cap)
        pred_b, qv_b, w_b = self._stack_inputs(qs, bb)
        vecs, qsb, wsub, act = self._active_columns(qs, qv_b, w_b)
        sivfs = [self._sivf_col(col) for (col, *_r) in subs]
        fn = self._sivf_fn(key, act)
        ids, scores, fill, bnd = fn(
            tuple(s.centroids for s in sivfs),
            tuple(s.sorted_rows for s in sivfs),
            tuple(s.offsets for s in sivfs),
            vecs, t.scalars, pred_b, qsb, wsub)
        # fill/boundary and the merged ids ride along with the results in
        # one transfer — no mid-chunk host round-trip gates the kernels.
        # The finer trigger fixes "escalation never bites": the merged
        # result almost never underfills (other shards pad it out), so
        # probe losses inside a DOMINANT shard went unnoticed. A shard
        # whose weakest kept score reaches the merged cutoff had its
        # whole contribution rank globally — its probing budget, not the
        # data, bound what it surfaced (a full local top-k was truncated;
        # a shorter one means the probe itself starved) — and only that
        # shard-subset pays the exact retry. A shard strictly below the
        # cutoff provably surfaced everything relevant.
        fill_np = np.asarray(fill)
        bnd_np = np.asarray(bnd)
        ids_np0 = np.asarray(ids)
        sc_np0 = np.asarray(scores)
        under = (ids_np0 >= 0).sum(axis=1) < k  # (bb,) merged underfill
        kth = sc_np0[:, -1]  # merged k-th score (NEG when underfilled)
        need = under[:, None] & (fill_np < k)
        if fill_np.shape[1] > 1:
            # S=1 stays bit-for-bit the single-device candidate-local path:
            # the lone shard's local top-k IS the merge, so its boundary
            # always sits at the cutoff and carries no signal
            need |= ~under[:, None] & (bnd_np >= kth[:, None])
        need[len(qs):] = False  # padding queries never escalate
        self.escalated.update(part[j] for j in np.flatnonzero(
            need.any(axis=1)))
        if need.any():
            ids, scores = self._escalate_shards(
                ids, scores, need, k=k, pred_b=pred_b, vecs=vecs, qsb=qsb,
                wsub=wsub)
            ids_np = np.asarray(ids)
        else:
            ids_np = ids_np0  # already on host — don't transfer twice
        scores_np = np.asarray(scores)
        for pos, j in enumerate(part):
            out[j] = (ids_np[pos], scores_np[pos])

    def _escalate_shards(self, ids, scores, need: np.ndarray, *, k: int,
                         pred_b, vecs: tuple, qsb: tuple, wsub):
        """Exact retry on the underfilled shard-subset: the escalated
        queries re-run as one dense masked top-k restricted (allow mask)
        to the rows of their underfilled shards (``_sharded_exact_retry``
        — streaming the rows once beats gathering qualifying rows at
        arbitrary width), and a dedup-by-id merge folds the escalated
        candidates into the probed results. Probe-found rows keep the
        probe path's exact float scores through the merge (first
        occurrence wins), so escalation can only ADD rows, never perturb
        the well-filled shards' results."""
        t = self.table
        s_count = need.shape[1]
        shard_len = -(-t.n_rows // s_count)
        sel = np.flatnonzero(need.any(axis=1))
        sel_p = pad_selection(sel)
        cur_ids = ids[jnp.asarray(sel_p)]
        cur_sc = scores[jnp.asarray(sel_p)]
        # ONE batched dense retry for the whole subset, shard scope
        # enforced by the allow mask. Under the boundary trigger the mask
        # is genuinely strict: typically a single dominant shard per
        # escalated query, so only shard_len rows are rescanned — the
        # well-filled shards never pay the retry.
        rq_j = jnp.asarray(sel_p)
        need_p = np.array(need[sel_p])
        need_p[len(sel):] = False  # padding rows draw nothing
        e_ids, e_sc = _sharded_exact_retry(
            vecs, t.scalars, predicates.take(pred_b, sel_p),
            tuple(q[rq_j] for q in qsb), wsub[rq_j],
            jnp.asarray(need_p),
            shard_len=min(shard_len, t.n_rows), k=k,
            metric=t.schema.metric)
        cur_ids, cur_sc = merge_topk_unique(
            jnp.concatenate([cur_ids, e_ids], axis=1),
            jnp.concatenate([cur_sc, e_sc], axis=1), k)
        sel_j = jnp.asarray(sel)
        ids = ids.at[sel_j].set(cur_ids[: len(sel)])
        scores = scores.at[sel_j].set(cur_sc[: len(sel)])
        return ids, scores

    def _run_chunk_sharded(self, qs: list[MHQ], part: list[int], out: list,
                           *, k: int, bucket_cap: int,
                           scores_b: Optional[tuple] = None):
        """One sharded group chunk, dispatcher-routed.

        The sharded scan is EXACT, so its candidate-local variant must be
        too: the per-query qualifying-row count (from the predicate masks,
        which cost no GEMM) is the group's candidate budget — when it
        clears the crossover, the chunk runs as an exact fused gather+score
        over only the qualifying rows instead of the dense (bb, n)
        weighted-score scan. The gather width is a STATIC cap estimated
        from the selectivity histograms (margin + slack over the largest
        per-query estimate), so no host sync gates the kernels; the true
        counts ride back with the results, and any query whose count
        overflowed the cap re-runs at the exact width (overflow
        escalation) — under-shooting estimates cost one retry, never
        exactness. Without histograms the old one-sync-per-chunk sizing
        remains. A bound device mesh pins the group to the dense shard_map
        kernel (the fan-out IS the point there); the decision is still
        recorded."""
        t = self.table
        bb = min(next_bucket(len(qs)), bucket_cap)
        pred_b, qv_b, w_b = self._stack_inputs(qs, bb)
        if self.mesh is not None:
            self.dispatcher.choose(batch=bb, scan=t.n_rows,
                                   group=("sharded-mesh", k), force=DENSE)
            _, weighted_scores = self._chunk_scores(
                qs, part, bb, qv_b, w_b, scores_b)
            out_ids, out_scores = self._shard_fn(k)(
                weighted_scores(), t.scalars, pred_b)
        else:
            mask = _eval_mask_batch(pred_b, t.scalars)
            prefer_dense = scores_b is not None
            n_qual = None
            estimated = False
            if self.dispatcher.pins_dense(prefer_dense):
                mc = t.n_rows  # candidate-local impossible: skip the sync
            elif self.hists is not None:
                # histogram-estimated static cap — stats only, no (bb, n)
                # mask reduction blocks the host before the gather launches
                est = float(np.max(np.asarray(
                    _selectivity_batch(self.hists, pred_b)))) * t.n_rows
                mc = min(next_bucket(max(
                    int(np.ceil(est * CAP_MARGIN)) + CAP_SLACK, k, 1)),
                    next_bucket(t.n_rows))
                estimated = mc < next_bucket(t.n_rows)
            else:
                # one host sync per chunk sizes the candidate-local gather
                n_qual = np.asarray(jnp.sum(mask, axis=1))
                mc = min(next_bucket(max(int(n_qual.max()), k, 1)),
                         next_bucket(t.n_rows))
            path = self.dispatcher.choose(batch=bb, scan=mc,
                                          group=("sharded", k),
                                          prefer_dense=prefer_dense)
            if path == CANDIDATE_LOCAL:
                vecs, qsb, wsub, _ = self._active_columns(qs, qv_b, w_b)
                rows_b = _qualifying_rows_batch(mask, size=mc)
                out_ids, out_scores, _ = _gather_rerank_batch(
                    rows_b, vecs, qsb, wsub, t.scalars,
                    k=k, metric=t.schema.metric)
                if estimated:
                    # true counts ride back with the result transfer
                    if n_qual is None:
                        n_qual = np.asarray(jnp.sum(mask, axis=1))
                    over = np.flatnonzero(n_qual[: len(qs)] > mc)
                    if over.size:
                        out_ids, out_scores = self._regather_overflow(
                            mask, n_qual, over, out_ids, out_scores,
                            vecs, qsb, wsub, k=k)
            else:
                _, weighted_scores = self._chunk_scores(
                    qs, part, bb, qv_b, w_b, scores_b)
                out_ids, out_scores = sharded_topk_ref(
                    weighted_scores(), mask, k=k, n_shards=self.n_shards)
        ids_np, scores_np = np.asarray(out_ids), np.asarray(out_scores)
        for pos, j in enumerate(part):
            out[j] = (ids_np[pos], scores_np[pos])

    def _regather_overflow(self, mask, n_qual: np.ndarray, over: np.ndarray,
                           out_ids, out_scores, vecs, qsb, wsub, *, k: int):
        """Overflow escalation of the histogram-capped exact gather: the
        queries whose true qualifying count exceeded the static cap re-run
        at their exact width, so an under-shooting estimate can never drop
        qualifying rows."""
        t = self.table
        sel_p = pad_selection(over)
        sel_j = jnp.asarray(sel_p)
        mc2 = min(next_bucket(max(int(n_qual[over].max()), k, 1)),
                  next_bucket(t.n_rows))
        rows2 = _qualifying_rows_batch(
            jnp.asarray(mask)[sel_j], size=mc2)
        ids2, sc2, _ = _gather_rerank_batch(
            rows2, vecs, tuple(q[sel_j] for q in qsb), wsub[sel_j],
            t.scalars, k=k, metric=t.schema.metric)
        sel = jnp.asarray(over)
        out_ids = jnp.asarray(out_ids).at[sel].set(ids2[: len(over)])
        out_scores = jnp.asarray(out_scores).at[sel].set(sc2[: len(over)])
        return out_ids, out_scores

    def _stack_inputs(self, qs: list[MHQ], bb: int):
        """Batch inputs padded (by repeating the first query) to bucket bb."""
        qpad = qs + [qs[0]] * (bb - len(qs))
        pred_b = predicates.stack([q.predicates for q in qpad])
        qv_b = tuple(jnp.stack([q.query_vectors[i] for q in qpad])
                     for i in range(self.table.schema.n_vec))
        w_b = jnp.asarray([q.weights for q in qpad], jnp.float32)
        return pred_b, qv_b, w_b

    def _chunk_scores(self, qs: list[MHQ], part: list[int], bb: int,
                      qv_b: tuple, w_b, scores_b: Optional[tuple]):
        """(col_scores, weighted_scores) closures for one chunk, gathering
        rows of the whole-batch dense matrices when ``scores_b`` is given."""
        t = self.table
        n_vec = t.schema.n_vec
        w_np = np.asarray([q.weights for q in qs], np.float32)
        scores_cache: dict = {}
        rows_idx = jnp.asarray(
            part + [part[0]] * (bb - len(part))) if scores_b is not None \
            else None

        def col_scores(i):
            if i not in scores_cache:
                scores_cache[i] = scores_b[i][rows_idx] \
                    if scores_b is not None else \
                    _dense_scores(t.vectors[i], qv_b[i],
                                  metric=t.schema.metric)
            return scores_cache[i]

        def weighted_scores():
            """Σ_i w_i · sim_i over every column some query weights."""
            ws = None
            for i in range(n_vec):
                if not np.any(np.abs(w_np[:, i]) > 0):
                    continue  # exact: a zero weight contributes exactly 0
                s = w_b[:, i, None] * col_scores(i)
                ws = s if ws is None else ws + s
            return ws if ws is not None \
                else jnp.zeros((bb, t.n_rows), jnp.float32)

        return col_scores, weighted_scores

    def _run_chunk(self, key, qs: list[MHQ], part: list[int], out: list,
                   *, bucket_cap: int, scores_b: Optional[tuple] = None):
        t = self.table
        bb = min(next_bucket(len(qs)), bucket_cap)
        precision = key[4] if key[0] in ("ix", "gr") else "fp32"
        # graph groups have no dense variant: the walk's whole point is to
        # touch O(hops·beam·degree) rows, so a (B, n) score matrix buys
        # nothing — they pin candidate-local (the decision is still logged)
        force = CANDIDATE_LOCAL if key[0] == "gr" else None
        path = self.dispatcher.choose(batch=bb, scan=self._group_scan(key),
                                      group=key[:3], force=force,
                                      prefer_dense=scores_b is not None,
                                      precision=precision)
        pred_b, qv_b, w_b = self._stack_inputs(qs, bb)

        if path == CANDIDATE_LOCAL:
            out_ids, out_scores = self._run_chunk_local(
                key, qs, pred_b, qv_b, w_b)
        else:
            col_scores, weighted_scores = self._chunk_scores(
                qs, part, bb, qv_b, w_b, scores_b)
            if key[0] == "ff":
                _, _, k, mc = key
                out_ids, out_scores, _, _ = _filter_first_batch(
                    weighted_scores(), t.scalars, pred_b,
                    k=k, max_candidates=mc)
            else:
                k, subs = key[2], key[3]
                cand = [self._batched_subquery(col, col_scores(col), pred_b,
                                               qv_b[col], k_i, np0, ms, it)
                        for (col, k_i, np0, ms, it) in subs]
                rows_b = self._union_candidates(cand, subs)
                out_ids, out_scores = _rerank_batch(
                    weighted_scores(), rows_b, k=k, total=rows_b.shape[1])
        ids_np, scores_np = np.asarray(out_ids), np.asarray(out_scores)
        for pos, j in enumerate(part):
            out[j] = (ids_np[pos], scores_np[pos])

    def _run_chunk_local(self, key, qs: list[MHQ], pred_b, qv_b, w_b):
        """Candidate-local execution of one group chunk: only the legalized
        candidate budget is ever gathered/scored — no (bb, n) score matrix.
        Subqueries run through ``ivf.search_local_batch`` (or its int8
        two-stage variant when the group's plan precision says so — the
        candidate union that reaches the final weighted rerank below is
        then already fp32-exact per column) and the re-rank / filter-first
        through the fused gather+score kernel path."""
        t = self.table
        if key[0] == "ff":
            _, _, k, mc = key
            out_ids, out_scores, _, _ = flat.filter_first_local_batch(
                tuple(t.vectors), t.scalars, pred_b, qv_b, w_b, k=k,
                max_candidates=mc, n_vec=t.schema.n_vec,
                metric=t.schema.metric)
            return out_ids, out_scores
        k, subs, precision = key[2], key[3], key[4]
        if key[0] == "gr":
            cand = [self._graph_subquery(col, pred_b, qv_b[col], k_i, bw, nh)
                    for (col, k_i, bw, nh) in subs]
        else:
            cand = [self._batched_subquery(col, None, pred_b, qv_b[col], k_i,
                                           np0, ms, it, local=True,
                                           precision=precision)
                    for (col, k_i, np0, ms, it) in subs]
        rows_b = self._union_candidates(cand, subs)
        vecs, qsb, wsub, _ = self._active_columns(qs, qv_b, w_b)
        out_ids, out_scores, _ = _gather_rerank_batch(
            rows_b.astype(jnp.int32), vecs, qsb, wsub, t.scalars,
            k=k, metric=t.schema.metric)
        return out_ids, out_scores

    def _active_columns(self, qs: list[MHQ], qv_b: tuple, w_b):
        """Restrict (vectors, queries, weights) to columns some query in the
        chunk actually weights — a zero weight contributes exactly 0, so the
        candidate-local re-rank need not gather those columns at all.
        Returns (vectors, queries, weights, active column ids)."""
        w_np = np.asarray([q.weights for q in qs], np.float32)
        act = tuple(i for i in range(self.table.schema.n_vec)
                    if np.any(np.abs(w_np[:, i]) > 0))
        vecs = tuple(self.table.vectors[i] for i in act)
        qsb = tuple(qv_b[i] for i in act)
        wsub = w_b[:, jnp.asarray(act, jnp.int32)] if act else w_b[:, :0]
        return vecs, qsb, wsub, act

    @staticmethod
    def _pad_candidates(cand: list):
        """Concat per-column candidate ids and pad the union to a
        power-of-two bucket (-1 = empty slot)."""
        rows_b = jnp.concatenate(cand, axis=1)
        total = next_bucket(rows_b.shape[1], CANDIDATE_PAD_FLOOR)
        if total > rows_b.shape[1]:
            rows_b = jnp.pad(rows_b, ((0, 0), (0, total - rows_b.shape[1])),
                             constant_values=-1)
        return rows_b

    def _union_candidates(self, cand_wide: list, subs):
        """Candidate union of one ix-group chunk from the columns' WIDE
        ranked lists: each column's exact top-k_i block (the engine
        contract — those rows are always reranked), then, for multi-column
        groups, RRF-fused extras drawn from the probed tails filling the
        padded bucket (``executor.rrf_extras``). A global top-k row can
        rank below top-k_i in every column on weight-skewed queries; the
        fused extras recover it when its COMBINED ranks are strong, at
        zero extra probing cost — the tails were already ranked. Widths
        are all derived from the static group key, so the jit cache stays
        bounded; single-column groups keep the plain truncate-and-pad
        union (fusion of one ranking is that ranking)."""
        kis = tuple(s[1] for s in subs)
        cand = [cw[:, :ki] for cw, ki in zip(cand_wide, kis)]
        if len(cand_wide) < 2:
            return self._pad_candidates(cand)
        base = jnp.concatenate(cand, axis=1)
        sum_ki = base.shape[1]
        extras = rrf_extras(tuple(cand_wide), kis=kis,
                            n_extra=rrf_union_total(sum_ki) - sum_ki)
        return jnp.concatenate([base, extras], axis=1)

    def _graph_subquery(self, col: int, pred_b, q_b, k_i: int,
                        beam_width: int, n_hops: int):
        """One column's predicate-aware graph walk for the whole chunk.
        Returns ranked candidate ids at the padded probe width (bb, ks),
        ks ≥ k_i — the same contract as ``_batched_subquery``, so the RRF
        union and rerank downstream are strategy-agnostic. No re-expansion
        ladder: the walk's budget is fixed by (beam_width, n_hops) and
        underfill escalation happens at the plan level (default_plan)."""
        t = self.table
        ks = subquery_width(k_i, t.n_rows)
        ids, _, _, _ = graph.search_local_batch(
            self.graphs[col], t.vectors[col], t.scalars, pred_b, q_b,
            beam_width=beam_width, n_hops=n_hops, k=ks)
        return ids

    def _batched_subquery(self, col: int, rs_b, pred_b, q_b, k_i: int,
                          nprobe: int, max_scan: int, iterative: bool,
                          *, local: bool = False, precision: str = "fp32"):
        """One column's filtered subquery for the whole chunk, with grouped
        iterative re-expansion. Returns ranked candidate ids at the FULL
        padded probe width (bb, ks), ks ≥ k_i: callers take the top-k_i
        prefix as the exact union block and feed the tail to RRF fusion
        (``_union_candidates``).

        Dense mode (``local=False``): ``rs_b`` (bb, n) holds the column's
        dense scores, so re-expansion rounds never re-score vectors — only
        re-select slots. Candidate-local mode gathers and scores only the
        probed slots (``ivf.search_local_batch``); re-expansion re-gathers
        the underfilled subset at the doubled nprobe. Each round narrows to
        the still-underfilled SUBSET (padded to its own power-of-two
        bucket), so — like the sequential doubling loop — the extra probing
        work scales with how many queries underfill, not with the group
        size."""
        t, index = self.table, self.indexes[col]
        cap = min(index.n_clusters, self.engine.nprobe_cap)
        ks = subquery_width(k_i, max_scan)

        def probe(np_, pred, qb, rs):
            if local and precision == "int8":
                vq, sc = t.quantized(col)
                ids_, _, _, nq = ivf.search_local_batch_int8(
                    index, t.vectors[col], vq, sc, t.scalars, pred, qb,
                    nprobe=np_, max_scan=max_scan, k=ks)
            elif local:
                ids_, _, _, nq = ivf.search_local_batch(
                    index, t.vectors[col], t.scalars, pred, qb,
                    nprobe=np_, max_scan=max_scan, k=ks)
            else:
                ids_, _, _, nq = _search_batch(
                    index, rs, t.scalars, pred, qb,
                    nprobe=np_, max_scan=max_scan, k=ks)
            return ids_, nq

        ids, n_qual = probe(nprobe, pred_b, q_b, rs_b)
        if not iterative:
            return ids
        done = np.asarray(n_qual) >= k_i  # ONE host sync per group round
        # boomlint: ignore[HS001] `done` is already a host-side numpy mask
        # (transferred once above) — this bool() costs no device sync
        while not bool(done.all()) and nprobe < cap:
            nprobe = min(2 * nprobe, cap)
            sel = np.flatnonzero(~done)
            sel_p = pad_selection(sel)
            pred_sub = predicates.take(pred_b, sel_p)
            ids2, nq2 = probe(nprobe, pred_sub, q_b[sel_p],
                              rs_b[sel_p] if rs_b is not None else None)
            ids = ids.at[jnp.asarray(sel)].set(ids2[: len(sel)])
            # boomlint: ignore[HS001] one sync per re-expansion round is
            # the iterative contract (the round count is the doubling
            # ladder, not the batch size — same shape as
            # HybridExecutor._subquery)
            done[sel] = np.asarray(nq2)[: len(sel)] >= k_i
        return ids


# ---------------------------------------------------------------------------
# serving front-end
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeReport:
    n_queries: int
    n_batches: int
    seconds: float
    qps: float
    mean_recall: Optional[float] = None
    recalls: Optional[list] = None
    # async deadline-aware serving (serve/queue.py) dispositions/latency
    n_timed_out: int = 0
    p50_ms: Optional[float] = None
    p99_ms: Optional[float] = None
    # per-group scoring-path dispatch counts ({"dense": .., "candidate_local": ..})
    path_counts: Optional[dict] = None
    # tiered streaming ingest (vectordb/tiered.py): rows inserted, background
    # hot→cold compactions completed, and the cold epoch at report time
    n_inserted: int = 0
    n_compactions: int = 0
    epoch: int = 0
    # semantic result cache (serve/semcache.py): requests resolved at submit
    # time without execution
    n_cache_hits: int = 0
    # multi-tenant serving: {tenant_id: {n_queries, n_ok, n_timed_out,
    # n_cache_hits, mean_recall, qps}} — None key is untenanted traffic
    tenants: Optional[dict] = None

    def describe(self) -> str:
        rec = f", mean recall {self.mean_recall:.3f}" \
            if self.mean_recall is not None else ""
        lat = f", p50 {self.p50_ms:.1f}ms / p99 {self.p99_ms:.1f}ms" \
            if self.p50_ms is not None and self.p99_ms is not None else ""
        to = f", {self.n_timed_out} timed out" if self.n_timed_out else ""
        paths = ""
        if self.path_counts:
            paths = ", paths " + "/".join(
                f"{name}×{cnt}" for name, cnt in sorted(self.path_counts.items()))
        ingest = f", {self.n_inserted} inserted over {self.n_compactions} " \
            f"compactions (epoch {self.epoch})" if self.n_inserted else ""
        cache = f", {self.n_cache_hits} cache hits" if self.n_cache_hits else ""
        tnt = f", {len(self.tenants)} tenants" \
            if self.tenants and len(self.tenants) > 1 else ""
        return (f"{self.n_queries} queries in {self.seconds:.2f}s over "
                f"{self.n_batches} batches ({self.qps:.1f} QPS{rec}{lat}{to}"
                f"{paths}{ingest}{cache}{tnt})")


class ServingEngine:
    """Deployment-shaped batched serving over a fitted ``BoomHQ``.

    Each batch costs ONE fused optimizer dispatch (vmapped features + heads
    + argmax) and one grouped execution pass — versus 2·B host round-trips
    for the per-query loop.
    """

    def __init__(self, boomhq, *, batch_size: int = 32):
        self.bq = boomhq
        self.batch_size = batch_size

    def warmup(self, queries: list[MHQ]) -> None:
        """Populate the jit caches so served batches measure steady state."""
        if queries:
            self.bq.execute_batch(list(queries[: self.batch_size]))

    def serve(self, queries: list[MHQ], *, gt_ids=None
              ) -> tuple[list, ServeReport]:
        """Run the stream in batches. ``gt_ids`` (optional, one ground-truth
        id array per query) enables recall accounting."""
        dispatcher = self._dispatcher()
        if dispatcher is not None:
            dispatcher.take()  # drop warmup decisions from the report
        results: list = []
        n_batches = 0
        t0 = time.perf_counter()
        for s in range(0, len(queries), self.batch_size):
            results.extend(self.bq.execute_batch(
                queries[s: s + self.batch_size]))
            n_batches += 1
        seconds = time.perf_counter() - t0
        recalls = None
        if gt_ids is not None:
            recalls = [recall_at_k(ids, gt)
                       for (ids, _), gt in zip(results, gt_ids)]
        counts = dispatcher.take()[0] if dispatcher is not None else None
        report = ServeReport(
            n_queries=len(queries), n_batches=n_batches, seconds=seconds,
            qps=len(queries) / max(seconds, 1e-9),
            mean_recall=float(np.mean(recalls)) if recalls is not None else None,
            recalls=recalls, path_counts=counts or None)
        return results, report

    def _dispatcher(self) -> Optional[ScoringDispatcher]:
        return getattr(self.bq._batched_executor(), "dispatcher", None)
