"""Semantic result cache: repeated/near-duplicate MHQs short-circuit the
engine at submit time (ROADMAP open item 1, docs/semantic_cache.md).

Hybrid-query traffic at scale is dominated by repeats and near-duplicates
of a small prevailing set. The cache sits in FRONT of ``BatchFormer``: a
hit returns a previously computed ``(ids, scores)`` at zero scan cost; a
miss executes normally and populates. The hit predicate is deliberately
conservative — every clause must hold:

* **same canonicalized predicate signature** — the DNF is normalized
  (inactive bounds forced to ±inf, invalid/empty clauses dropped, clauses
  sorted) so the signature is invariant to clause order, padding bucket and
  inactive-column garbage; predicates that merely *render* differently but
  denote the same DNF share a signature, while any semantic difference
  splits it.
* **same tenant** — the tenant id is part of the key, never the fuzzy
  match, so one tenant's results can never leak to another (the engine also
  folds the tenant conjunct into the predicate BEFORE lookup, which lands
  the tenant in the signature as well — defense in depth).
* **compatible k bucket** — the entry must have been computed for the same
  padded top-k bucket with ``entry.k >= q.k``; the cached prefix
  ``ids[:q.k]`` is then exactly the query's top-k.
* **query vectors within ε of the entry's centroid** — per vector column,
  Euclidean distance ``||q_i - c_i||_2 <= eps`` (per-metric ε; see
  docs/semantic_cache.md for the score-error bound). ``eps=0`` degenerates
  to exact-repeat caching with bit-for-bit replay parity.
* **fresh token** — every entry is stamped with the freshness token of the
  state it was computed under: ``(TieredSnapshot.epoch, n_rows)`` for
  tiered serving, ``(0, table.n_rows)`` otherwise. A hit requires token
  equality with the CURRENT token, so an epoch bump (compaction moved rows
  the entry's result may depend on) or any hot-tier insert (new rows the
  entry has never seen) implicitly flushes: stale entries are lazily
  dropped on first touch and counted in ``stale_drops``. Cached results
  can never resurrect pre-compaction state — pinned by
  tests/test_semcache.py, enforced in serving code by boomlint rule EP002.

Storage is a bounded per-tenant LRU (``capacity_per_tenant``) so one noisy
tenant can never evict another's working set. All methods are thread-safe.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.core.query import MHQ
from repro.vectordb.predicates import PredicateLike, as_set

DEFAULT_CAPACITY_PER_TENANT = 256


def predicate_signature(pred: PredicateLike) -> bytes:
    """Canonical byte signature of a DNF predicate.

    Normalization: promote to ``PredicateSet``; force inactive columns'
    bounds to ±inf (their stored values are semantically dead); drop
    invalid (padding) clauses and clauses emptied by intersection
    (``lo > hi`` on an active column matches nothing); sort the surviving
    clauses' byte encodings. Two predicates share a signature iff their
    normalized clause SETS coincide — invariant to clause order and the
    legalized padding bucket."""
    ps = as_set(pred)
    active = np.asarray(ps.active, bool)
    lo = np.asarray(ps.lo, np.float32).copy()
    hi = np.asarray(ps.hi, np.float32).copy()
    valid = np.asarray(ps.clause_valid, bool)
    lo[~active] = -np.inf
    hi[~active] = np.inf
    clauses = []
    for c in range(active.shape[0]):
        if not valid[c]:
            continue
        if np.any(active[c] & (lo[c] > hi[c])):
            continue  # empty clause: contributes nothing to the union
        clauses.append(active[c].tobytes() + lo[c].tobytes() + hi[c].tobytes())
    if not clauses:
        return b"false"
    return b"|".join(sorted(clauses))


def query_signature(q: MHQ) -> bytes:
    """Exact-match part of the cache key: predicate signature + weights +
    recall target (plans — and therefore approximate results — may differ
    across recall targets, so they never share entries)."""
    w = np.asarray(q.weights, np.float32).tobytes()
    rt = np.float32(q.recall_target).tobytes()
    return predicate_signature(q.predicates) + b"#" + w + rt


def k_bucket(k: int) -> int:
    from repro.core.executor import K_BUCKET_FLOOR, next_bucket
    return next_bucket(k, K_BUCKET_FLOOR)


@dataclasses.dataclass
class CacheEntry:
    centroids: tuple  # per vector column, (d_i,) np.float32
    k: int  # the k the result was computed for
    ids: np.ndarray  # (k,) int — cached result rows
    scores: np.ndarray  # (k,) f32 — cached result scores
    token: tuple  # (epoch, n_rows) freshness stamp


class SemanticCache:
    """Bounded per-tenant semantic result cache (see module doc).

    ``eps`` is a float (both metrics) or a ``{"dot": e, "l2": e}`` mapping;
    0.0 caches exact repeats only. ``lookup``/``insert`` take the CURRENT
    freshness token — the engine derives it from the serving snapshot
    (``AsyncServingEngine._cache_token``)."""

    def __init__(self, *, capacity_per_tenant: int = DEFAULT_CAPACITY_PER_TENANT,
                 eps: float | dict = 0.0, metric: str = "dot"):
        assert capacity_per_tenant >= 1
        self.capacity_per_tenant = capacity_per_tenant
        self._eps = eps
        self.metric = metric
        self._lock = threading.Lock()
        # tenant -> OrderedDict[entry_id, CacheEntry]  (LRU order)
        self._tenants: dict = {}
        # (tenant, sig, k_bucket) -> [entry_id, ...]
        self._index: dict = {}
        self._next_id = 0
        self.hits = 0
        self.misses = 0
        self.stale_drops = 0
        self.evictions = 0
        self.tenant_hits: dict = {}

    @property
    def eps(self) -> float:
        e = self._eps
        return float(e[self.metric]) if isinstance(e, dict) else float(e)

    # -- internals (call with lock held) ------------------------------------

    def _drop_locked(self, tenant, eid: int) -> None:
        lru = self._tenants.get(tenant)
        if lru is None or eid not in lru:
            return
        del lru[eid]
        for key, eids in list(self._index.items()):
            if key[0] == tenant and eid in eids:
                eids.remove(eid)
                if not eids:
                    del self._index[key]

    def _within_eps_locked(self, entry: CacheEntry, q: MHQ) -> bool:
        eps = self.eps
        for qv, c in zip(q.query_vectors, entry.centroids):
            d = float(np.linalg.norm(np.asarray(qv, np.float32) - c))
            if d > eps:
                return False
        return True

    # -- public API ----------------------------------------------------------

    def lookup(self, q: MHQ, token: tuple) -> Optional[tuple]:
        """Return cached ``(ids, scores)`` (length ``q.k``) on a hit, else
        None. ``token`` is the CURRENT freshness token; entries stamped with
        any other token are stale, dropped on touch, and never served."""
        tenant = q.tenant_id
        key = (tenant, query_signature(q), k_bucket(q.k))
        with self._lock:
            eids = self._index.get(key, ())
            for eid in list(eids):
                entry = self._tenants[tenant][eid]
                if entry.token != token:
                    self._drop_locked(tenant, eid)
                    self.stale_drops += 1
                    continue
                if entry.k < q.k or not self._within_eps_locked(entry, q):
                    continue
                self._tenants[tenant].move_to_end(eid)
                self.hits += 1
                self.tenant_hits[tenant] = self.tenant_hits.get(tenant, 0) + 1
                return (entry.ids[: q.k].copy(), entry.scores[: q.k].copy())
            self.misses += 1
            return None

    def insert(self, q: MHQ, token: tuple, ids, scores) -> None:
        """Populate after a miss executed: stamp the result with the token
        of the state it was computed under (the batch's snapshot, NOT the
        current one — the table may have moved while the batch ran)."""
        tenant = q.tenant_id
        entry = CacheEntry(
            centroids=tuple(np.asarray(v, np.float32).copy()
                            for v in q.query_vectors),
            k=int(q.k),
            ids=np.asarray(ids).copy(),
            scores=np.asarray(scores, np.float32).copy(),
            token=tuple(token),
        )
        key = (tenant, query_signature(q), k_bucket(q.k))
        with self._lock:
            lru = self._tenants.setdefault(tenant, OrderedDict())
            # N concurrent misses for one (near-)identical query must not
            # append N duplicates under one key — that churns the LRU and
            # evicts DISTINCT working-set entries. Replace an existing
            # same-key entry whose centroid is within ε in place instead.
            for eid in self._index.get(key, ()):
                old = lru.get(eid)
                if old is not None and self._within_eps_locked(old, q):
                    lru[eid] = entry
                    lru.move_to_end(eid)
                    return
            eid = self._next_id
            self._next_id += 1
            lru[eid] = entry
            self._index.setdefault(key, []).append(eid)
            while len(lru) > self.capacity_per_tenant:
                old_eid = next(iter(lru))
                self._drop_locked(tenant, old_eid)
                self.evictions += 1

    def invalidate_tenant(self, tenant) -> int:
        """Drop every entry of one tenant; returns the count dropped."""
        with self._lock:
            self.tenant_hits.pop(tenant, None)
            lru = self._tenants.pop(tenant, None)
            if not lru:
                return 0
            n = len(lru)
            self._index = {k: v for k, v in self._index.items()
                           if k[0] != tenant}
            return n

    def __len__(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._tenants.values())

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "stale_drops": self.stale_drops,
                "evictions": self.evictions,
                "entries": sum(len(v) for v in self._tenants.values()),
                "tenant_hits": dict(self.tenant_hits),
            }
