from repro.serve.engine import (  # noqa: F401
    make_prefill_step, make_decode_step, greedy_generate,
)
from repro.serve.batch import (  # noqa: F401
    BatchedHybridExecutor, ServeReport, ServingEngine,
)
