"""Hybrid-query serving: the full request pipeline, batched + async.

    request queue → deadline-aware batch formation → shard fan-out → merge

  * ``serve.queue`` — the live-traffic front half: ``BatchFormer`` cuts a
    batch when full OR when the oldest request ages past ``max_wait``;
    per-request deadlines expire queued requests with a ``timed_out``
    disposition (never executed); ``AsyncServingEngine`` drives it under
    asyncio with execution in a worker thread.
  * ``serve.semcache`` — the semantic result cache in FRONT of the queue:
    repeated/near-duplicate queries (same canonicalized predicate
    signature + tenant + k bucket, query vector within ε of a cached
    centroid, fresh ``(epoch, n_rows)`` token) resolve at submit time with
    zero scan cost; per-tenant bounded LRU (docs/semantic_cache.md).
  * ``serve.batch`` — the execution back half: ``BatchedHybridExecutor``
    groups a formed batch by (strategy, legalized params, clause bucket, k)
    and runs grouped vmapped kernels over shared dense score matrices; with
    shards bound (``n_shards``/``mesh``) each clause-bucket group instead
    fans out over contiguous table shards — per-shard mask + local top-k on
    the shard's slice of the dense scores, one O(shards·k) merge
    (``vectordb.distributed.sharded_batch_topk``). ``ServingEngine`` is the
    synchronous batch-chopping wrapper; ``ServeReport`` carries QPS/recall
    plus the async dispositions (``n_timed_out``, p50/p99 latency).

(The LM prefill/decode helpers formerly re-exported here moved to
``repro.models.lm_serving``; ``repro.serve.engine`` remains as a deprecated
alias for one release.)
"""
from repro.serve.batch import (  # noqa: F401
    BatchedHybridExecutor, ServeReport, ServingEngine,
)
from repro.serve.queue import (  # noqa: F401
    AsyncServingEngine, BatchFormer, ServeRequest, serve_stream,
)
from repro.serve.semcache import (  # noqa: F401
    CacheEntry, SemanticCache, predicate_signature, query_signature,
)
