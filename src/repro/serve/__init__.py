"""Hybrid-query serving: batched execution + the deployment front-end.

(The LM prefill/decode helpers formerly re-exported here moved to
``repro.models.lm_serving``; ``repro.serve.engine`` remains as a deprecated
alias for one release.)
"""
from repro.serve.batch import (  # noqa: F401
    BatchedHybridExecutor, ServeReport, ServingEngine,
)
