"""DEPRECATED alias — the LM prefill/decode serving helpers moved to
:mod:`repro.models.lm_serving`.

The ``repro.serve`` package hosts the hybrid-query serving stack
(``ServingEngine``, ``BatchedHybridExecutor`` in ``serve.batch``); keeping
the unrelated LM engine under the same roof made ``from repro.serve import
engine`` a landmine. This shim re-exports the old names for one release and
warns; import from ``repro.models.lm_serving`` instead.
"""
from __future__ import annotations

import warnings

from repro.models.lm_serving import (  # noqa: F401
    greedy_generate, make_decode_step, make_prefill_step,
)

warnings.warn(
    "repro.serve.engine is deprecated; import the LM prefill/decode helpers "
    "from repro.models.lm_serving (the serve package now hosts the "
    "hybrid-query ServingEngine)",
    DeprecationWarning,
    stacklevel=2,
)
