"""Async deadline-aware MHQ serving: queue → batch formation → fan-out.

The synchronous ``ServingEngine`` chops a PRE-COLLECTED query list into
fixed batches — fine for benchmarks, wrong for live traffic, where requests
arrive one at a time and each carries a latency budget. This module adds the
missing front half of the serving pipeline:

  request queue  →  deadline-aware batch formation  →  batched execution
                                                        (shard fan-out + merge)

  * ``BatchFormer`` is the pure-synchronous policy core (injectable clock,
    so tests drive it under a fake clock): a batch CUTS when ``batch_size``
    requests are pending (cut-on-full) OR when the oldest pending request
    has aged past ``max_wait`` seconds (cut-on-age). Requests whose
    per-request deadline passes while still queued are expired — reported
    with a ``timed_out`` disposition and NEVER executed. FIFO arrival
    order is preserved within every formed batch.
  * ``AsyncServingEngine`` is the asyncio front-end: concurrent
    ``submit()`` callers share formed batches; one drainer task cuts
    batches and executes them through ``BoomHQ.execute_batch`` — which
    fans each batch out over the table shards when the instance is
    ``bind_shards``-bound — in a worker thread, so the event loop keeps
    accepting arrivals mid-execution.

Dispositions and latency percentiles land in the shared ``ServeReport``
(``n_timed_out``, ``p50_ms``/``p99_ms``).
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import dataclasses
import functools
import time
from typing import Callable, Optional

import numpy as np

from repro.core.executor import recall_at_k
from repro.core.query import MHQ
from repro.serve.batch import ServeReport

PENDING = "pending"
OK = "ok"
TIMED_OUT = "timed_out"
FAILED = "failed"  # execution raised; the exception propagates to submit()

_DEFAULT = object()  # sentinel: "use the engine's default timeout"


@dataclasses.dataclass
class ServeRequest:
    """One enqueued query: arrival instant, optional ABSOLUTE deadline, and
    (once the engine resolves it) disposition + result."""

    query: MHQ
    seq: int
    arrival: float
    deadline: Optional[float] = None  # clock instant; None = no deadline
    status: str = PENDING  # PENDING | OK | TIMED_OUT | FAILED
    result: Optional[tuple] = None  # (ids, scores) when status == OK
    done: float = 0.0
    cache_hit: bool = False  # resolved by the semantic cache, zero scan cost
    # tiered serving: the immutable (epoch, hot, cold) snapshot stamped on
    # the whole batch at CUT time — every request in a batch shares one, so
    # an epoch swap between formation and execution can never mix states
    snapshot: Optional[object] = None

    @property
    def latency(self) -> float:
        """Queue wait + execution for OK; time-to-expiry for TIMED_OUT."""
        return self.done - self.arrival


class BatchFormer:
    """Deadline-aware batch formation over a FIFO request queue.

    Synchronous policy core with an injectable ``clock`` — the async engine
    drives it with wall time, tests with a fake clock. See the module
    docstring for the cut/expire policy.
    """

    def __init__(self, *, batch_size: int = 32, max_wait: float = 0.05,
                 clock: Callable[[], float] = time.monotonic,
                 snapshot_fn: Optional[Callable[[], object]] = None):
        assert batch_size >= 1 and max_wait >= 0.0
        self.batch_size = batch_size
        self.max_wait = max_wait
        self.clock = clock
        # tiered serving: called ONCE per cut; the returned snapshot is
        # stamped on every request of the formed batch (snapshot-at-cut)
        self.snapshot_fn = snapshot_fn
        self._pending: list[ServeRequest] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._pending)

    def admit(self, query: MHQ, *, timeout: Optional[float] = None,
              now: Optional[float] = None) -> ServeRequest:
        """Stamp (but do NOT enqueue) the next request — sequence number,
        arrival instant and absolute deadline. Front-ends that resolve a
        request without ever forming it into a batch (a semantic-cache hit)
        use this directly so cached requests still occupy their slot in the
        serve order."""
        now = self.clock() if now is None else now
        r = ServeRequest(
            query=query, seq=self._seq, arrival=now,
            deadline=None if timeout is None else now + timeout)
        self._seq += 1
        return r

    def submit(self, query: MHQ, *, timeout: Optional[float] = None,
               now: Optional[float] = None) -> ServeRequest:
        """Enqueue one request; ``timeout`` (seconds from now) sets its
        absolute deadline."""
        r = self.admit(query, timeout=timeout, now=now)
        self._pending.append(r)
        return r

    def expire(self, now: Optional[float] = None) -> list[ServeRequest]:
        """Remove (and mark ``timed_out``) every pending request whose
        deadline has passed — they will never be executed."""
        now = self.clock() if now is None else now
        dead = [r for r in self._pending
                if r.deadline is not None and now > r.deadline]
        if dead:
            gone = {r.seq for r in dead}
            self._pending = [r for r in self._pending if r.seq not in gone]
            for r in dead:
                r.status = TIMED_OUT
                r.done = now
        return dead

    def poll(self, now: Optional[float] = None, *, flush: bool = False
             ) -> tuple[Optional[list[ServeRequest]], list[ServeRequest]]:
        """-> (batch | None, expired).

        Expiry runs first (expired requests never enter a batch); then a
        batch of the OLDEST ≤ ``batch_size`` requests cuts when the queue
        is full, the oldest request aged past ``max_wait``, or ``flush``
        forces the remainder out."""
        now = self.clock() if now is None else now
        expired = self.expire(now)
        batch = None
        if self._pending and (
                len(self._pending) >= self.batch_size
                or now - self._pending[0].arrival >= self.max_wait
                or flush):
            batch = self._pending[: self.batch_size]
            self._pending = self._pending[self.batch_size:]
            if self.snapshot_fn is not None:
                snap = self.snapshot_fn()  # snapshot-at-cut: one per batch
                for r in batch:
                    r.snapshot = snap
        return batch, expired

    def drain(self) -> list[ServeRequest]:
        """Remove and return every pending request (engine shutdown)."""
        out, self._pending = self._pending, []
        return out

    def next_event(self, now: Optional[float] = None) -> Optional[float]:
        """Earliest future instant a poll could act — the oldest request's
        cut-on-age instant or the soonest deadline — or None when idle."""
        if not self._pending:
            return None
        t = self._pending[0].arrival + self.max_wait
        for r in self._pending:
            if r.deadline is not None:
                t = min(t, r.deadline)
        return t


class CompactionScheduler:
    """Background hot→cold compaction — the same single-worker-thread
    pattern ``AsyncServingEngine`` executes batches with, on its OWN pool
    so a compaction can never delay a batch (and vice versa). At most one
    compaction runs at a time; ``maybe_schedule()`` is cheap and safe to
    call from any thread (the ingest path calls it on every insert that
    fills the hot segment, the drainer nudges it between batches)."""

    def __init__(self, tiered):
        self.tiered = tiered
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._inflight: Optional[concurrent.futures.Future] = None
        self.n_scheduled = 0

    def maybe_schedule(self) -> bool:
        """Submit one compaction if the hot segment needs it and none is
        already in flight. Returns True when one was submitted."""
        if self._inflight is not None and not self._inflight.done():
            return False
        if not self.tiered.needs_compaction():
            return False
        self._inflight = self._pool.submit(self.tiered.compact)
        self.n_scheduled += 1
        return True

    def drain(self) -> None:
        """Wait out the in-flight compaction and stop the worker."""
        if self._inflight is not None:
            self._inflight.result()
            self._inflight = None
        self._pool.shutdown(wait=True)


class AsyncServingEngine:
    """Asyncio deployment front-end over a fitted ``BoomHQ``.

    ``submit()`` coroutines from any number of concurrent callers enqueue
    into one ``BatchFormer``; a single drainer task cuts batches
    (cut-on-full / cut-on-age) and executes each through
    ``BoomHQ.execute_batch`` — one fused optimizer dispatch + grouped
    (possibly cross-shard) execution per batch — inside a worker thread so
    new arrivals keep landing while a batch runs. Expired requests resolve
    with ``status == "timed_out"`` and are never executed.
    """

    def __init__(self, boomhq, *, batch_size: int = 32,
                 max_wait: float = 0.05,
                 default_timeout: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic,
                 semcache=None):
        self.bq = boomhq
        self.former = BatchFormer(batch_size=batch_size, max_wait=max_wait,
                                  clock=clock)
        # optional serve.semcache.SemanticCache consulted at submit time:
        # hits resolve immediately (zero scan cost), misses populate after
        # their batch executes, stamped with the batch snapshot's token
        self.semcache = semcache
        self.default_timeout = default_timeout
        self.clock = clock
        self._futures: dict[int, asyncio.Future] = {}
        self._event: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._served: list[ServeRequest] = []
        self._n_batches = 0
        self._t0: Optional[float] = None
        self._pool: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._compactor: Optional[CompactionScheduler] = None

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> "AsyncServingEngine":
        if self._task is None:
            self._event = asyncio.Event()
            # ONE worker thread: batches execute strictly in formation
            # order, and a late stop() flush can never race the drainer
            # into two concurrent execute_batch calls
            self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
            if getattr(self.bq, "tiered", None) is not None:
                # snapshot-at-cut: every batch executes against one
                # immutable (epoch, hot, cold) view, and compaction runs
                # on its own worker so serving never pauses for it
                self.former.snapshot_fn = self.bq.tiered.snapshot
                self._compactor = CompactionScheduler(self.bq.tiered)
                self.bq._compactor = self._compactor
            self._task = asyncio.get_running_loop().create_task(self._drain())
        return self

    async def stop(self, *, flush: bool = True) -> None:
        """Serve (or expire) everything still queued, then stop the drainer
        and tear down the worker thread."""
        if self._task is None:
            return
        while flush and (len(self.former) or not self._all_resolved()):
            self._event.set()
            await asyncio.sleep(1e-3)
            batch, expired = self.former.poll(flush=True)
            self._resolve_expired(expired)
            if batch:
                await self._execute(batch)
        self._task.cancel()
        try:
            await self._task
        except asyncio.CancelledError:
            pass
        self._task = None
        # flush=False: fail everything never formed into a batch (and any
        # straggler future) so no submit() caller is left hanging — the
        # in-flight batch's futures were already failed by _execute's
        # cancellation branch
        for r in self.former.drain():
            r.status = FAILED
            r.done = self.clock()
            self._finish(r, exc=asyncio.CancelledError("engine stopped"))
        for seq in list(self._futures):
            fut = self._futures.pop(seq)
            if not fut.done():
                fut.set_exception(asyncio.CancelledError("engine stopped"))
        # wait=False: do not block the event loop on a discarded batch
        self._pool.shutdown(wait=False, cancel_futures=True)
        self._pool = None
        if self._compactor is not None:
            # let the in-flight compaction land (it owns published state)
            await asyncio.get_running_loop().run_in_executor(
                None, self._compactor.drain)
            if getattr(self.bq, "_compactor", None) is self._compactor:
                self.bq._compactor = None
            self._compactor = None

    async def __aenter__(self) -> "AsyncServingEngine":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def _all_resolved(self) -> bool:
        return not self._futures

    # -- request path ------------------------------------------------------

    def _cache_token(self) -> tuple:
        """CURRENT freshness token for semantic-cache admission:
        ``(epoch, n_rows)`` of the tiered snapshot (an epoch bump OR any
        hot-tier insert changes it), or ``(0, table.n_rows)`` untiered
        (eager inserts grow the table). One snapshot pointer read — never
        the mutable tiering fields (EP001)."""
        tiered = getattr(self.bq, "tiered", None)
        if tiered is not None:
            snap = tiered.snapshot()
            return (snap.epoch, snap.n_rows)
        return (0, self.bq.table.n_rows)

    async def submit(self, query: MHQ, *, timeout=_DEFAULT) -> ServeRequest:
        """Enqueue one query and await its disposition. Returns the resolved
        ``ServeRequest`` (``status`` is ``"ok"`` with ``result`` set, or
        ``"timed_out"`` with ``result`` None). With a semantic cache bound,
        a fresh-enough repeat resolves HERE — never queued, never executed,
        ``cache_hit`` set."""
        await self.start()
        tmo = self.default_timeout if timeout is _DEFAULT else timeout
        # fold the tenant namespace BEFORE the cache key is computed, so
        # the implicit conjunct is part of the predicate signature
        if getattr(query, "tenant_id", None) is not None and \
                hasattr(self.bq, "resolve_tenant"):
            query = self.bq.resolve_tenant(query)
        if self.semcache is not None:
            cached = self.semcache.lookup(query, self._cache_token())
            if cached is not None:
                r = self.former.admit(query, timeout=tmo)
                if self._t0 is None:
                    self._t0 = r.arrival
                r.status = OK
                r.result = cached
                r.cache_hit = True
                r.done = self.clock()
                self._served.append(r)
                return r
        r = self.former.submit(query, timeout=tmo)
        if self._t0 is None:
            self._t0 = r.arrival
        fut = asyncio.get_running_loop().create_future()
        self._futures[r.seq] = fut
        self._event.set()
        await fut
        return r

    async def _drain(self) -> None:
        while True:
            if self._compactor is not None:
                self._compactor.maybe_schedule()
            batch, expired = self.former.poll()
            self._resolve_expired(expired)
            if batch:
                await self._execute(batch)
                continue  # queue may already hold the next full batch
            nxt = self.former.next_event()
            try:
                wait = None if nxt is None \
                    else max(1e-4, nxt - self.clock())
                await asyncio.wait_for(self._event.wait(), wait)
            except asyncio.TimeoutError:
                pass
            self._event.clear()

    async def _execute(self, batch: list[ServeRequest]) -> None:
        # deadline enforcement does NOT stop at cut time: a request whose
        # deadline passed while its batch sat behind an in-flight one must
        # resolve timed_out here, not execute and report OK (same strict
        # `now > deadline` rule as BatchFormer.expire)
        now = self.clock()
        late = [r for r in batch
                if r.deadline is not None and now > r.deadline]
        if late:
            for r in late:
                r.status = TIMED_OUT
                r.done = now
                self._finish(r)
            batch = [r for r in batch if r.status == PENDING]
            if not batch:
                return
        loop = asyncio.get_running_loop()
        queries = [r.query for r in batch]
        if batch[0].snapshot is not None:
            # the whole batch shares the snapshot stamped at cut time —
            # an epoch swap landing mid-flight cannot change what it sees
            run = functools.partial(
                self.bq.execute_batch, queries, snapshot=batch[0].snapshot)
        else:
            run = functools.partial(self.bq.execute_batch, queries)
        exec_fut = loop.run_in_executor(self._pool, run)
        try:
            results = await asyncio.shield(exec_fut)
        except asyncio.CancelledError:
            # stop(flush=False) cancelled the drainer mid-batch: fail the
            # in-flight batch's futures so no submit() caller is stranded,
            # swallow the worker's eventual outcome, finish cancelling
            exec_fut.add_done_callback(
                lambda f: f.cancelled() or f.exception())
            now = self.clock()
            for r in batch:
                r.status = FAILED
                r.done = now
                self._finish(r, exc=asyncio.CancelledError("engine stopped"))
            raise
        except Exception as exc:  # noqa: BLE001 — a failed batch must fail
            # ITS requests (submit() re-raises), never kill the drainer:
            # a dead drainer would strand every later future forever
            now = self.clock()
            self._n_batches += 1
            for r in batch:
                r.status = FAILED
                r.done = now
                self._finish(r, exc=exc)
            return
        now = self.clock()
        self._n_batches += 1
        token = None
        if self.semcache is not None:
            snap = batch[0].snapshot
            # stamp entries with the token of the state the batch actually
            # executed under (its cut-time snapshot), not the current one —
            # an epoch swap mid-flight must leave these entries born stale
            token = (snap.epoch, snap.n_rows) if snap is not None \
                else (0, self.bq.table.n_rows)
        for r, res in zip(batch, results):
            r.status = OK
            r.result = res
            r.done = now
            if token is not None:
                self.semcache.insert(r.query, token, res[0], res[1])
            self._finish(r)

    def _resolve_expired(self, expired: list[ServeRequest]) -> None:
        for r in expired:
            self._finish(r)

    def _finish(self, r: ServeRequest, *, exc: Optional[Exception] = None
                ) -> None:
        self._served.append(r)
        fut = self._futures.pop(r.seq, None)
        if fut is not None and not fut.done():
            if exc is not None:
                fut.set_exception(exc)
            else:
                fut.set_result(r)

    # -- accounting --------------------------------------------------------

    def report(self, *, gt_ids: Optional[dict] = None) -> ServeReport:
        """Aggregate dispositions/latency over everything served so far.
        ``gt_ids``: optional ``{seq: ground-truth id array}`` for recall
        accounting over the OK requests."""
        served = sorted(self._served, key=lambda r: r.seq)
        ok = [r for r in served if r.status == OK]
        lats = np.asarray([r.latency for r in ok], np.float64)
        t_end = max((r.done for r in served), default=0.0)
        seconds = max(t_end - (self._t0 or 0.0), 1e-9) if served else 0.0
        recalls = None
        if gt_ids is not None:
            recalls = [recall_at_k(r.result[0], gt_ids[r.seq])
                       for r in ok if r.seq in gt_ids]
        tiered = getattr(self.bq, "tiered", None)
        tenants: dict = {}
        for r in served:
            t = getattr(r.query, "tenant_id", None)
            d = tenants.setdefault(t, {
                "n_queries": 0, "n_ok": 0, "n_timed_out": 0,
                "n_cache_hits": 0, "recalls": []})
            d["n_queries"] += 1
            d["n_ok"] += r.status == OK
            d["n_timed_out"] += r.status == TIMED_OUT
            d["n_cache_hits"] += r.cache_hit
            if r.status == OK and gt_ids is not None and r.seq in gt_ids:
                d["recalls"].append(recall_at_k(r.result[0], gt_ids[r.seq]))
        for d in tenants.values():
            rs = d.pop("recalls")  # host floats from recall_at_k
            d["mean_recall"] = sum(rs) / len(rs) if rs else None
            d["qps"] = d["n_ok"] / seconds if served else 0.0
        return ServeReport(
            n_queries=len(served),
            n_batches=self._n_batches,
            seconds=seconds,
            qps=len(ok) / seconds if served else 0.0,
            mean_recall=float(np.mean(recalls)) if recalls else None,
            recalls=recalls,
            n_timed_out=sum(r.status == TIMED_OUT for r in served),
            p50_ms=float(np.percentile(lats, 50) * 1e3) if len(lats) else None,
            p99_ms=float(np.percentile(lats, 99) * 1e3) if len(lats) else None,
            n_inserted=0 if tiered is None else tiered.n_inserted,
            n_compactions=0 if tiered is None else tiered.n_compactions,
            epoch=0 if tiered is None else tiered.epoch,
            n_cache_hits=sum(r.cache_hit for r in served),
            tenants=tenants or None,
        )


async def serve_stream(engine: AsyncServingEngine, queries: list[MHQ], *,
                       arrival_gaps: Optional[list[float]] = None,
                       timeout=_DEFAULT) -> list[ServeRequest]:
    """Submit a query stream with the given inter-arrival gaps (seconds;
    None = all-at-once) and await every disposition. Returns the resolved
    requests in submission order — the open-loop driver benchmarks and
    examples use for Poisson traffic."""
    async with engine:
        tasks = []
        for i, q in enumerate(queries):
            if arrival_gaps is not None and i > 0:
                await asyncio.sleep(arrival_gaps[i - 1])
            tasks.append(asyncio.ensure_future(
                engine.submit(q, timeout=timeout)))
        return list(await asyncio.gather(*tasks))
