"""Per-architecture execution plans (microbatching, optimizer, FSDP, SP).

Sizing rationale (16 GB HBM v5e chips, DESIGN.md §5 / EXPERIMENTS.md §Dry-run):
  * dense 1.6B–14B   — AdamW with int8 moments; activations bounded by
    microbatching the 1M-token train_4k batch down to ~1–2 GB of layer
    carries per device.
  * MoE giants       — Adafactor (factored second moment), bf16 params,
    FSDP over `data` (XLA all-gathers each layer's experts inside the
    scan), sequence-sharded residual carries (SP), 4 microbatches.
  * SSM/hybrid       — small models; modest microbatching.
"""
from __future__ import annotations

from repro.train.step import TrainPlan

TRAIN_PLANS: dict[str, TrainPlan] = {
    "gemma-7b": TrainPlan(microbatches=8, state_dtype="int8"),
    "qwen3-14b": TrainPlan(microbatches=16, state_dtype="int8"),
    "phi3-mini-3.8b": TrainPlan(microbatches=8, state_dtype="int8"),
    "stablelm-1.6b": TrainPlan(microbatches=4, state_dtype="int8"),
    "llava-next-mistral-7b": TrainPlan(microbatches=8, state_dtype="int8"),
    "musicgen-large": TrainPlan(microbatches=8, state_dtype="int8"),
    "zamba2-2.7b": TrainPlan(microbatches=16, state_dtype="int8"),
    # giants: mb=2 after §Perf iteration B5 (FSDP weight re-gathers scale
    # with the microbatch count; SP-sharded carries keep activations bounded)
    "kimi-k2-1t-a32b": TrainPlan(
        microbatches=2, optimizer="adafactor", param_dtype="bfloat16",
        fsdp=True, seq_shard_acts=True, grad_accum_dtype="bfloat16"),
    "deepseek-v3-671b": TrainPlan(
        microbatches=2, optimizer="adafactor", param_dtype="bfloat16",
        fsdp=True, seq_shard_acts=True, grad_accum_dtype="bfloat16"),
    "mamba2-370m": TrainPlan(microbatches=2, state_dtype="int8"),
}

# serving always runs bf16 params / bf16 caches
SERVE_PARAM_DTYPE = "bfloat16"

# §Perf-derived per-step config overrides (see EXPERIMENTS.md §Perf):
#   * prefill: flash attention (iteration A2) — online softmax kills the
#     (B,H,cq,S) score traffic; NOT used for training (the scan-of-scan
#     backward re-saves per-iteration carries without a custom VJP);
#   * MoE: sequence sub-groups shrink the GShard dispatch tensors (A1/B2).
TRAIN_CFG_OVERRIDES: dict[str, dict] = {
    # scatter-based expert parallelism (§Perf B7): −24% collectives,
    # useful 0.47→0.52 vs the grouped-einsum dispatch on deepseek train
    "deepseek-v3-671b": {"moe_impl": "sharded"},
    "kimi-k2-1t-a32b": {"moe_impl": "sharded"},
}
# Flash helps when the score matrix dwarfs K/V traffic (many heads per
# device, large batch — the MoE giants: −69..82% on the dominant term);
# on the small-head dense cells its per-kv-block carry traffic REGRESSED
# the counted bytes 80-230% (final-sweep A/B), so it is opt-in per arch.
PREFILL_CFG_OVERRIDES_COMMON: dict = {}
PREFILL_CFG_OVERRIDES: dict[str, dict] = {
    "deepseek-v3-671b": {"flash_attention": True, "moe_group_tokens": 2048},
    "kimi-k2-1t-a32b": {"flash_attention": True, "moe_group_tokens": 2048},
}


def train_plan(arch: str) -> TrainPlan:
    return TRAIN_PLANS[arch]


def train_cfg_overrides(arch: str) -> dict:
    return TRAIN_CFG_OVERRIDES.get(arch, {})


def prefill_cfg_overrides(arch: str) -> dict:
    return PREFILL_CFG_OVERRIDES.get(arch, dict(PREFILL_CFG_OVERRIDES_COMMON))
