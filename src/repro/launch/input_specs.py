"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(arch, shape_name)`` returns the abstract inputs for the step
that shape lowers (train_* -> train_step batch; prefill_* -> prefill batch;
decode_*/long_* -> (inputs, pos, cache)). Weak-type-correct and shardable —
the dry-run lowers against these exclusively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec, get_config
from repro.models import lm

I32 = jnp.int32
BF16 = jnp.bfloat16


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, shape: ShapeSpec, *, with_labels: bool) -> dict:
    """Train/prefill batch: tokens/labels (+ modality stub embeddings)."""
    b, s = shape.global_batch, shape.seq_len
    out: dict = {}
    if cfg.modality == "vlm":
        s_tok = s - cfg.n_prefix_embeds
        out["patch_embeds"] = _sds((b, cfg.n_prefix_embeds, cfg.d_model), BF16)
        out["tokens"] = _sds((b, s_tok), I32)
        if with_labels:
            out["labels"] = _sds((b, s_tok), I32)
    elif cfg.inputs_are_embeds:
        out["embeds"] = _sds((b, s, cfg.d_model), BF16)
        if with_labels:
            out["labels"] = _sds((b, s), I32)
    else:
        out["tokens"] = _sds((b, s), I32)
        if with_labels:
            out["labels"] = _sds((b, s), I32)
    return out


def decode_structs(cfg: ModelConfig, shape: ShapeSpec):
    """-> (inputs, pos, cache) abstract values for one decode step."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.inputs_are_embeds:
        inputs = {"embed": _sds((b, cfg.d_model), BF16)}
    else:
        inputs = {"token": _sds((b,), I32)}
    pos = _sds((), I32)
    cache = jax.eval_shape(lambda: lm.make_cache(cfg, b, s, dtype="bfloat16"))
    return inputs, pos, cache


def serve_params_struct(cfg: ModelConfig, dtype: str = "bfloat16"):
    """Abstract parameter tree with float leaves cast to the serving dtype."""
    shape = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0), cfg))
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, dt if jnp.issubdtype(l.dtype, jnp.floating) else l.dtype),
        shape)


def input_specs(arch: str, shape_name: str, *, smoke: bool = False):
    """The assigned deliverable: abstract inputs for (arch × shape)."""
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    if shape.step == "train":
        return {"batch": batch_struct(cfg, shape, with_labels=True)}
    if shape.step == "prefill":
        return {"batch": batch_struct(cfg, shape, with_labels=False)}
    inputs, pos, cache = decode_structs(cfg, shape)
    return {"inputs": inputs, "pos": pos, "cache": cache}
