"""Serving driver: batched prefill + decode for any registered arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --batch 4 --prompt-len 32 --steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm
from repro.models.lm_serving import greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    b, s = args.batch, args.prompt_len
    batch = {}
    if cfg.modality == "vlm":
        npre = min(cfg.n_prefix_embeds, s // 2)
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, npre, cfg.d_model)), jnp.float32)
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s - npre)),
                                      jnp.int32)
    elif cfg.inputs_are_embeds:
        batch["embeds"] = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                                      jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                      jnp.int32)

    t0 = time.perf_counter()
    toks = greedy_generate(params, cfg, batch, steps=args.steps,
                           max_len=s + args.steps + 1)
    dt = time.perf_counter() - t0
    n_tok = toks.size
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    print("first sequence:", np.asarray(toks[0])[:16].tolist())
    return toks


if __name__ == "__main__":
    main()
