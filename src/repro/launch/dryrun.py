import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

# NOTE: the two lines above MUST run before any jax import — jax locks the
# device count on first init. No `from __future__` here for that reason.
DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this AOT-compiles the real step function (train / prefill /
decode) against ShapeDtypeStruct inputs on the production mesh, then records:
  * memory_analysis()  — per-device argument/temp/output bytes (fits proof)
  * cost_analysis()    — per-device HLO FLOPs & bytes accessed
  * collective bytes   — parsed from the optimized per-device HLO, summed by
    opcode (all-gather / all-reduce / reduce-scatter / all-to-all / permute)
  * the derived roofline terms (TPU v5e constants; see §Roofline)

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] --out results.jsonl
"""

import argparse
import json
import re
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, get_config, shape_applicable
from repro.launch.input_specs import batch_struct, decode_structs, serve_params_struct
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.plans import (prefill_cfg_overrides, train_cfg_overrides,
                                train_plan)
from repro.models import lm, sharding
from repro.models.lm_serving import make_decode_step, make_prefill_step
from repro.train.step import init_state, make_train_step

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective opcode, from optimized HLO text.

    Uses result sizes (≈ bytes received per device); reduce-scatter results
    are scaled back up by the group size to count operand bytes."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls.startswith("%") and not ls.startswith("ROOT"):
            continue
        m = re.match(r"(?:ROOT )?%[\w.\-]+ = (.*?) ([\w\-]+)\(", ls)
        if not m:
            continue
        type_str, opcode = m.group(1), m.group(2)
        for coll in _COLLECTIVES:
            if opcode == coll or opcode == coll + "-start":
                b = _type_bytes(type_str)
                if coll == "reduce-scatter":
                    g = re.search(r"replica_groups=\[\d+,(\d+)\]", ls)
                    if g:
                        b *= int(g.group(1))
                out[coll] += b
                counts[coll] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape_name: str, mesh, cfg_overrides=None,
               plan_overrides=None):
    """-> (fn, args, in_shardings, out_shardings, meta)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    # production defaults derived in §Perf (overridable via --set / --baseline)
    if cfg_overrides is None or "baseline" not in (cfg_overrides or {}):
        auto = (train_cfg_overrides(arch) if shape.step == "train"
                else prefill_cfg_overrides(arch) if shape.step == "prefill"
                else {})
        cfg = _dc.replace(cfg, **auto)
    if cfg_overrides:
        cfg_overrides = {k: v for k, v in cfg_overrides.items() if k != "baseline"}
        if cfg_overrides:
            cfg = _dc.replace(cfg, **cfg_overrides)
    daxes = data_axes(mesh)
    bax = daxes if len(daxes) > 1 else daxes[0]

    if shape.step == "train":
        plan = train_plan(arch)
        if plan_overrides:
            plan = _dc.replace(plan, **plan_overrides)
        act = sharding.activation_spec(daxes, seq_shard=plan.seq_shard_acts) \
            if plan.seq_shard_acts else None
        state_shape = jax.eval_shape(
            lambda: init_state(jax.random.PRNGKey(0), cfg, plan))
        pspec = sharding.param_specs(cfg, state_shape[0], fsdp=plan.fsdp)
        step = sharding.with_act_axes(
            make_train_step(cfg, plan, act_spec=act, batch_axes=daxes,
                            grad_specs=pspec), bax, mesh=mesh)
        ospec = sharding.opt_state_specs(pspec, state_shape[1])
        bspec = sharding.batch_specs(cfg, batch_struct(cfg, shape, with_labels=True),
                                     daxes)
        args = (state_shape[0], state_shape[1],
                batch_struct(cfg, shape, with_labels=True))
        in_sh = (_ns(mesh, pspec), _ns(mesh, ospec), _ns(mesh, bspec))
        out_sh = (_ns(mesh, pspec), _ns(mesh, ospec), NamedSharding(mesh, P()))
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * cfg.n_active_params() * tokens
        return step, args, in_sh, out_sh, {"tokens": tokens,
                                           "model_flops": model_flops,
                                           "donate": (0, 1)}

    params = serve_params_struct(cfg)
    pshape = jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0), cfg))
    # serving shards weights over data too (weight-stationary; required to
    # fit the MoE giants' 1-2 TB of bf16 expert weights on 16 GB chips)
    pspec = sharding.param_specs(cfg, pshape, fsdp=cfg.family == "moe",
                                 moe_shard_ffn_dim=True)

    if shape.step == "prefill":
        step = sharding.with_act_axes(
            make_prefill_step(cfg, max_len=shape.seq_len), bax, mesh=mesh)
        batch = batch_struct(cfg, shape, with_labels=False)
        bspec = sharding.batch_specs(cfg, batch, daxes)
        cache_shape = jax.eval_shape(
            lambda: lm.make_cache(cfg, shape.global_batch, shape.seq_len,
                                  dtype="bfloat16"))
        cspec = sharding.cache_specs(cfg, cache_shape, daxes)
        args = (params, batch)
        in_sh = (_ns(mesh, pspec), _ns(mesh, bspec))
        out_sh = (NamedSharding(mesh, P(bax, "model")), _ns(mesh, cspec))
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * cfg.n_active_params() * tokens
        return step, args, in_sh, out_sh, {"tokens": tokens,
                                           "model_flops": model_flops}

    # decode
    shard_b = shape.global_batch > 1
    step = sharding.with_act_axes(make_decode_step(cfg), bax if shard_b else None,
                                  mesh=mesh)
    inputs, pos, cache = decode_structs(cfg, shape)
    shard_batch = shape.global_batch > 1
    cspec = sharding.cache_specs(cfg, cache, daxes, shard_batch=shard_batch)
    ispec = jax.tree.map(
        lambda l: P(bax, *([None] * (len(l.shape) - 1))) if shard_batch else P(),
        inputs)
    args = (params, inputs, pos, cache)
    in_sh = (_ns(mesh, pspec), _ns(mesh, ispec), NamedSharding(mesh, P()),
             _ns(mesh, cspec))
    lspec = P(bax, "model") if shard_batch else P(None, "model")
    out_sh = (NamedSharding(mesh, lspec), _ns(mesh, cspec))
    tokens = shape.global_batch
    model_flops = 2.0 * cfg.n_active_params() * tokens
    return step, args, in_sh, out_sh, {"tokens": tokens,
                                       "model_flops": model_flops,
                                       "donate": (3,)}  # cache aliases in/out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, cfg_overrides=None,
             plan_overrides=None, tag: str = "") -> dict:
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.perf_counter()
    with mesh:
        fn, args, in_sh, out_sh, meta = build_cell(
            arch, shape_name, mesh, cfg_overrides, plan_overrides)
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=meta.get("donate", ())).lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        from repro.launch import hlo_analysis
        hlo = hlo_analysis.analyze(compiled.as_text())
        coll = {**hlo["collectives"], "counts": hlo["collective_counts"],
                "unknown_trip_whiles": hlo["unknown_trip_whiles"]}

    # scan-aware per-device costs (hlo_analysis multiplies while-loop bodies
    # by their trip counts; raw cost_analysis counts each body once)
    flops_dev = float(hlo["flops"])
    bytes_dev = float(hlo["bytes"])
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    model_flops_dev = meta["model_flops"] / n_dev
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll["total"] / LINK_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "n_dev": n_dev,
        "tag": tag,
        "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
        "raw_cost_flops": raw_flops, "raw_cost_bytes": raw_bytes,
        "collectives": coll,
        "arg_bytes_per_dev": mem.argument_size_in_bytes,
        "temp_bytes_per_dev": mem.temp_size_in_bytes,
        "out_bytes_per_dev": mem.output_size_in_bytes,
        "alias_bytes_per_dev": mem.alias_size_in_bytes,
        "peak_hbm_gib": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes
                         - mem.alias_size_in_bytes) / 2**30,
        "model_flops_per_dev": model_flops_dev,
        "useful_flop_ratio": model_flops_dev / flops_dev if flops_dev else 0.0,
        **terms,
        "dominant": dom,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] "
              f"flops/dev={flops_dev:.3e} bytes/dev={bytes_dev:.3e} "
              f"coll/dev={coll['total']:.3e}B peak_hbm={rec['peak_hbm_gib']:.2f}GiB "
              f"| compute={t_comp*1e3:.1f}ms memory={t_mem*1e3:.1f}ms "
              f"coll={t_coll*1e3:.1f}ms -> {dom} "
              f"| useful={rec['useful_flop_ratio']:.2f} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (perf iterations)")
    ap.add_argument("--plan-set", action="append", default=[],
                    help="train-plan override key=value")
    ap.add_argument("--tag", default="", help="label for the record")
    args = ap.parse_args()

    def _parse_over(items):
        out = {}
        for it in items:
            k, v = it.split("=", 1)
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = {"true": True, "false": False}.get(v.lower(), v)
        return out

    cfg_over = _parse_over(args.set)
    plan_over = _parse_over(args.plan_set)

    from repro import configs  # populate registry

    cells = []
    if args.all:
        for arch in configs.ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                rec = run_cell(arch, shape, multi_pod=mp,
                               cfg_overrides=cfg_over or None,
                               plan_overrides=plan_over or None, tag=args.tag)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "error": f"{type(e).__name__}: {e}"}
                failures.append(rec)
                print(f"[{arch} × {shape}] FAILED: {rec['error']}")
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    if failures:
        raise SystemExit(f"{len(failures)} cells failed")


if __name__ == "__main__":
    main()
