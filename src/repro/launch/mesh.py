"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16×16 = 256 chips (data, model).
Multi-pod: 2×16×16 = 512 chips (pod, data, model) — the `pod` axis composes
with `data` for data parallelism; gradient reduction crosses pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple[str, ...]:
    """Every mesh axis that carries batch/data parallelism (all but `model`)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (requires host device override)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
