"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16×16 = 256 chips (data, model).
Multi-pod: 2×16×16 = 512 chips (pod, data, model) — the `pod` axis composes
with `data` for data parallelism; gradient reduction crosses pods.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there,
    # so older jax just omits the kwarg.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Every mesh axis that carries batch/data parallelism (all but `model`)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (requires host device override)."""
    return _make_mesh((n_data, n_model), ("data", "model"))
