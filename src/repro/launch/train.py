"""Production training driver.

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m --smoke \
      --steps 50 --ckpt /tmp/run1 --resume auto

Wires together: config registry, data pipeline, train-step builder, sharded
checkpointing (auto-resume from the newest valid manifest), the preemption
guard and the straggler watchdog. On a real pod the same entry point runs
under `jax.distributed.initialize()`; on this CPU container use --smoke.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import ckpt
from repro.data.pipeline import BatchSpec, make_source
from repro.distributed.fault_tolerance import PreemptionGuard, StepWatchdog
from repro.launch.plans import TRAIN_PLANS
from repro.train.step import TrainPlan, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--schedule-total", type=int, default=None,
                    help="LR-schedule horizon (defaults to --steps); pass the full-run horizon when training in resumable legs")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    base = TRAIN_PLANS.get(args.arch, TrainPlan())
    total = args.schedule_total or args.steps
    plan = TrainPlan(
        microbatches=args.microbatches, remat=base.remat,
        optimizer=base.optimizer, state_dtype=base.state_dtype,
        lr=args.lr, warmup=max(1, total // 10), total_steps=total)

    params, opt_state = init_state(jax.random.PRNGKey(args.seed), cfg, plan)
    step_fn = jax.jit(make_train_step(cfg, plan))

    spec = BatchSpec(args.global_batch, args.seq_len, cfg.vocab)
    src = make_source("synthetic", spec, seed=args.seed)

    start = 0
    if args.ckpt and args.resume == "auto":
        latest = ckpt.latest_step(args.ckpt)
        if latest is not None:
            start, tree, meta = ckpt.restore(
                args.ckpt, like={"params": params, "opt": opt_state})
            params, opt_state = tree["params"], tree["opt"]
            print(f"resumed from step {start}")

    guard = PreemptionGuard()
    watchdog = StepWatchdog()
    losses = []
    for step in range(start, args.steps):
        batch = src.batch_at(step)
        feed = _adapt_batch(cfg, batch)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, feed)
        loss = float(metrics["loss"])
        watchdog.record(time.perf_counter() - t0)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"p50 {watchdog.p50()*1e3:.0f}ms"
                  + (" [STRAGGLER]" if watchdog.flagged else ""))
        do_ckpt = args.ckpt and (
            (step + 1) % args.ckpt_every == 0 or step == args.steps - 1
            or guard.should_checkpoint)
        if do_ckpt:
            ckpt.save(args.ckpt, step + 1, {"params": params, "opt": opt_state},
                      meta={"arch": args.arch, "loss": loss})
        if guard.should_checkpoint:
            print(f"preempted at step {step + 1}; checkpointed; exiting")
            break
    guard.restore()
    if len(losses) >= 10:
        print(f"loss: first10={np.mean(losses[:10]):.4f} "
              f"last10={np.mean(losses[-10:]):.4f}")
    return losses


def _adapt_batch(cfg, batch):
    """Token batch -> the arch's input dict (modality stubs)."""
    tokens = jnp.asarray(batch["tokens"])
    labels = jnp.asarray(batch["labels"])
    b, s = tokens.shape
    if cfg.modality == "vlm":
        npre = min(cfg.n_prefix_embeds, s // 2)
        rng = np.random.default_rng(int(tokens[0, 0]))
        patches = jnp.asarray(rng.normal(size=(b, npre, cfg.d_model)), jnp.float32)
        return {"tokens": tokens[:, npre:], "labels": labels[:, npre:],
                "patch_embeds": patches}
    if cfg.inputs_are_embeds:
        emb = jax.nn.one_hot(tokens % cfg.d_model, cfg.d_model, dtype=jnp.float32)
        return {"embeds": emb, "labels": labels % cfg.vocab}
    return {"tokens": tokens % cfg.vocab, "labels": labels % cfg.vocab}


if __name__ == "__main__":
    main()
