"""Scan-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
program built on ``lax.scan`` (our layer stacks, microbatch accumulation,
q-chunked attention) under-reports FLOPs/bytes/collectives by the trip
count. This module re-derives per-device costs from ``compiled.as_text()``:

  * builds the computation call graph (fusions, calls, while bodies),
  * multiplies while-body costs by ``known_trip_count`` (CPU/TPU backends
    emit it in backend_config; missing counts fall back to 1 and are
    reported in ``unknown_trips``),
  * FLOPs: 2·out·contract for every ``dot``, window flops for convolutions,
  * bytes: Σ (operand + result buffer sizes) of top-level (post-fusion)
    instructions — the same convention as XLA's "bytes accessed",
  * collective bytes by opcode (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), result-size based; reduce-scatter
    scaled up by group size to count operand bytes.

Everything is per-device (the SPMD-partitioned module is per-device).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# opcodes that move data across the device/host boundary: explicit
# transfers (outfeed/infeed), point-to-point sends (host or cross-replica),
# and host-offloaded custom calls
HOST_TRANSFER_OPS = ("outfeed", "infeed", "send", "recv", "send-done",
                     "recv-done")
# S(5) is XLA's host memory space annotation (memory offloading / host
# layouts); a copy to/from it is a device<->host transfer
_HOST_SPACE_RE = re.compile(r"S\(5\)")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt in _DTYPE_BYTES:
            total += _shape_elems(m.group(2)) * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    # (callee, multiplier, is_fusion)
    calls: list = dataclasses.field(default_factory=list)
    is_fusion_callee: bool = False


def _split_computations(text: str) -> dict[str, tuple[bool, list[str]]]:
    comps: dict[str, tuple[bool, list[str]]] = {}
    cur, lines, entry = None, [], False
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            entry = bool(m.group(1))
            lines = []
            comps[cur] = (entry, lines)
        elif cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                lines.append(line)
    return comps


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota"}


def analyze(text: str) -> dict:
    raw = _split_computations(text)
    comps: dict[str, _Comp] = {}
    result_types: dict[str, str] = {}
    entry_name = None

    # first pass: result types of every instruction (for operand byte lookups)
    for name, (entry, lines) in raw.items():
        if entry:
            entry_name = name
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                result_types[m.group(1)] = m.group(2)

    unknown_trips = 0
    fusion_callees = set()

    for name, (entry, lines) in raw.items():
        c = _Comp(name)
        comps[name] = c
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            iname, type_str, opcode = m.groups()

            # --- flops ---
            if opcode == "dot":
                out_elems = sum(_shape_elems(s.group(2))
                                for s in _SHAPE_RE.finditer(type_str))
                # lhs operand: either typed inline ("dot(f32[64,128]{1,0}
                # %arg, ...)" — newer dumps) or a bare name whose type we
                # look up from its defining instruction
                lhs = re.search(
                    r"dot\((?:(\w+\[[\d,]*\](?:\{[^}]*\})?)\s+)?%?([\w.\-]+)",
                    line)
                cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                contract = 1
                if lhs and cd:
                    lhs_type = lhs.group(1) or \
                        result_types.get(lhs.group(2), "")
                    sm = _SHAPE_RE.search(lhs_type)
                    if sm:
                        dims = [int(d) for d in sm.group(2).split(",") if d]
                        for idx in cd.group(1).split(","):
                            if idx and int(idx) < len(dims):
                                contract *= dims[int(idx)]
                c.flops += 2.0 * out_elems * contract
            elif opcode == "convolution":
                out_elems = sum(_shape_elems(s.group(2))
                                for s in _SHAPE_RE.finditer(type_str))
                win = re.search(r"window=\{size=([\dx]+)", line)
                k = 1
                if win:
                    for d in win.group(1).split("x"):
                        k *= int(d)
                c.flops += 2.0 * out_elems * k

            # --- collectives ---
            base = opcode[:-6] if opcode.endswith("-start") else opcode
            if base in COLLECTIVES:
                b = _type_bytes(type_str)
                if base == "reduce-scatter":
                    g = re.search(r"replica_groups=\[\d+,(\d+)\]", line)
                    if g:
                        b *= int(g.group(1))
                c.coll[base] += b
                c.coll_counts[base] += 1

            # --- bytes accessed (top-level ops only; fusions counted whole) ---
            if opcode not in _SKIP_BYTES_OPS and not opcode.endswith("-done"):
                b = _type_bytes(type_str)
                for op in re.finditer(r"%([\w.\-]+)", line.split("(", 1)[1]
                                      if "(" in line else ""):
                    t = result_types.get(op.group(1))
                    if t:
                        b += _type_bytes(t)
                c.bytes += b

            # --- call graph ---
            if opcode == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", line)
                if fm:
                    c.calls.append((fm.group(1), 1.0, True))
                    fusion_callees.add(fm.group(1))
            elif opcode in ("call", "custom-call", "map", "reduce",
                            "reduce-window", "sort", "scatter", "select-and-scatter"):
                fm = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", line)
                if fm and opcode in ("call", "custom-call"):
                    c.calls.append((fm.group(1), 1.0, True))
                    fusion_callees.add(fm.group(1))
            elif opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", line)
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                tm = re.search(r'known_trip_count[\\"=:{]+n[\\":]+(\d+)', line)
                trip = float(tm.group(1)) if tm else 1.0
                if not tm:
                    unknown_trips += 1
                if bm:
                    c.calls.append((bm.group(1), trip, False))
                if cm:
                    c.calls.append((cm.group(1), trip, False))
            elif opcode == "conditional":
                bm = re.search(r"branch_computations=\{([^}]*)\}", line)
                if bm:
                    for b in bm.group(1).split(","):
                        c.calls.append((b.strip().lstrip("%"), 1.0, False))

    # fusion callees contribute flops/collectives but NOT byte counts
    # (their traffic is the fusion op's operands/results, already counted)
    memo: dict[str, tuple[float, float, dict, dict]] = {}

    def total(name: str, as_fusion: bool):
        key = name
        if key in memo:
            f, b, co, cc = memo[key]
        else:
            c = comps.get(name)
            if c is None:
                memo[key] = (0.0, 0.0, {}, {})
                f, b, co, cc = memo[key]
            else:
                f, b = c.flops, c.bytes
                co = dict(c.coll)
                cc = dict(c.coll_counts)
                for callee, mult, is_fused in c.calls:
                    cf, cb, cco, ccc = total(callee, is_fused)
                    f += mult * cf
                    b += mult * cb
                    for k, v in cco.items():
                        co[k] = co.get(k, 0) + mult * v
                    for k, v in ccc.items():
                        cc[k] = cc.get(k, 0) + mult * v
                memo[key] = (f, b, co, cc)
        if as_fusion:
            return f, 0.0, co, cc  # drop byte counts for fused interiors
        return f, b, co, cc

    # callees of fusions: byte counts suppressed at the call edge above; but a
    # computation reachable both ways is rare — acceptable approximation.
    f, b, co, cc = total(entry_name, False)
    co = {k: co.get(k, 0.0) for k in COLLECTIVES}
    co["total"] = sum(co.values())
    return {
        "flops": f,
        "bytes": b,
        "collectives": co,
        "collective_counts": {k: cc.get(k, 0) for k in COLLECTIVES},
        "unknown_trip_whiles": unknown_trips,
    }


def host_transfers(text: str) -> dict:
    """Count device<->host transfer instructions in optimized HLO text.

    Returns ``{"count": n, "ops": {opcode: n}, "host_space_copies": n}`` —
    serving kernels must report zero (boomlint CM001 gates on it): a
    transfer in compiled serving HLO means some value round-trips the host
    *inside* the kernel, the hazard class HS001 catches at the AST level.
    ``custom-call`` targets naming host callbacks count too (that is how
    ``pure_callback``/``io_callback`` lower)."""
    ops: dict = {}
    host_copies = 0
    for line in text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        opcode = m.group(3)
        if opcode in HOST_TRANSFER_OPS:
            ops[opcode] = ops.get(opcode, 0) + 1
        elif opcode == "custom-call" and "callback" in line:
            ops["host-callback"] = ops.get("host-callback", 0) + 1
        elif opcode in ("copy", "copy-start") and _HOST_SPACE_RE.search(m.group(2)):
            host_copies += 1
    return {"count": sum(ops.values()) + host_copies, "ops": ops,
            "host_space_copies": host_copies}


def comm_report(text: str, *, max_all_gathers: int | None = None) -> dict:
    """Collective-budget view of ``analyze``: per-opcode counts/bytes plus
    an over-budget verdict for the O(shards·k) merge contract (at most
    ``max_all_gathers`` all-gathers, no other collectives)."""
    a = analyze(text)
    counts = a["collective_counts"]
    others = {k: v for k, v in counts.items()
              if k != "all-gather" and v > 0}
    over = None
    if max_all_gathers is not None:
        over = counts.get("all-gather", 0) > max_all_gathers or bool(others)
    return {"counts": counts, "bytes": a["collectives"],
            "unexpected": others, "over_budget": over,
            "host": host_transfers(text)}
