import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

# (device-count override must precede any jax import; see dryrun.py)
DOC = """BoomHQ-technique dry-run: the distributed MHQ full-scan path at
production scale (the §Perf 'most representative of the paper's technique'
cell).

DB: 2 vector columns × 268M rows × 768 dims, 4 scalar columns, sharded over
the data axis of the 16×16 mesh (1M rows/device). Variants:
  C0  f32 DB, one query per step      (paper-faithful baseline)
  C1  f32 DB, 64-query batch          (amortize the DB read over queries)
  C2  int8 DB + per-row scales, 64-q  (4× less HBM per pass; kernels/int8_scan)

Usage: python -m repro.launch.dryrun_boomhq [--rows-per-dev 1048576] [--out f]
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis
from repro.launch.dryrun import HBM_BW, LINK_BW, PEAK_FLOPS
from repro.launch.mesh import data_axes, make_production_mesh
from repro.vectordb.distributed import sharded_masked_scan_batched
from repro.vectordb.predicates import Predicates


def _stacked_preds(q_batch: int, m: int):
    return Predicates(
        active=jax.ShapeDtypeStruct((q_batch, m), jnp.bool_),
        lo=jax.ShapeDtypeStruct((q_batch, m), jnp.float32),
        hi=jax.ShapeDtypeStruct((q_batch, m), jnp.float32),
    )


def run_variant(name: str, *, q_batch: int, int8: bool, rows_per_dev: int,
                d: int = 768, n_vec: int = 2, m: int = 4, k: int = 10,
                multi_pod: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    daxes = data_axes(mesh)
    n_data = 1
    for a in daxes:
        n_data *= mesh.shape[a]
    n = rows_per_dev * n_data
    vt = jnp.int8 if int8 else jnp.float32
    vectors = tuple(jax.ShapeDtypeStruct((n, d), vt) for _ in range(n_vec))
    scales = tuple(jax.ShapeDtypeStruct((n,), jnp.float32) for _ in range(n_vec))
    scalars = jax.ShapeDtypeStruct((n, m), jnp.float32)
    qs = tuple(jax.ShapeDtypeStruct((q_batch, d), jnp.float32)
               for _ in range(n_vec))
    w = jax.ShapeDtypeStruct((q_batch, n_vec), jnp.float32)
    preds = _stacked_preds(q_batch, m)

    fn = sharded_masked_scan_batched(mesh, daxes, k=k, n_vec=n_vec, int8=int8)
    t0 = time.perf_counter()
    dummy = jax.ShapeDtypeStruct((), jnp.float32)
    with mesh:
        lowered = fn.lower(vectors, scales if int8 else dummy, scalars,
                           preds, qs, w)
        compiled = lowered.compile()
    hlo = hlo_analysis.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    dt = time.perf_counter() - t0
    # per-QUERY roofline terms
    flops_q = hlo["flops"] / q_batch
    bytes_q = hlo["bytes"] / q_batch
    coll_q = hlo["collectives"]["total"] / q_batch
    model_flops_q = 2.0 * n * d * n_vec / (n_data)  # useful scoring flops/dev
    rec = {
        "variant": name, "q_batch": q_batch, "int8": int8,
        "rows": n, "rows_per_dev": rows_per_dev,
        "flops_per_dev_per_q": flops_q, "bytes_per_dev_per_q": bytes_q,
        "coll_bytes_per_dev_per_q": coll_q,
        "compute_s": flops_q / PEAK_FLOPS,
        "memory_s": bytes_q / HBM_BW,
        "collective_s": coll_q / LINK_BW,
        "useful_flop_ratio": model_flops_q / flops_q if flops_q else 0.0,
        "db_gib_per_dev": (rows_per_dev * d * n_vec * (1 if int8 else 4)
                           + rows_per_dev * m * 4) / 2**30,
        "arg_gib_per_dev": mem.argument_size_in_bytes / 2**30,
        "compile_s": round(dt, 1),
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda t: rec[t])
    rec["dominant"] = dom
    print(f"[boomhq-scan {name}] per-query/dev: flops={flops_q:.3e} "
          f"bytes={bytes_q:.3e} coll={coll_q:.3e}B | "
          f"compute={rec['compute_s']*1e3:.3f}ms memory={rec['memory_s']*1e3:.3f}ms "
          f"coll={rec['collective_s']*1e3:.4f}ms -> {dom} "
          f"| db/dev={rec['db_gib_per_dev']:.2f}GiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows-per-dev", type=int, default=1_048_576)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    variants = [
        ("C0_f32_q1", dict(q_batch=1, int8=False)),
        ("C1_f32_q64", dict(q_batch=64, int8=False)),
        ("C2_int8_q64", dict(q_batch=64, int8=True)),
    ]
    recs = []
    for name, kw in variants:
        recs.append(run_variant(name, rows_per_dev=args.rows_per_dev,
                                multi_pod=args.multi_pod, **kw))
    if args.out:
        with open(args.out, "a") as f:
            for r in recs:
                f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
