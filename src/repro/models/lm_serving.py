"""LM serving steps: prefill (prompt -> cache) and decode (one token/step).

(Relocated from ``repro.serve.engine`` — the ``serve`` package now hosts the
hybrid-query ``ServingEngine``/``BatchedHybridExecutor``; these LM-side
prefill/decode helpers live with the models they drive.)

The decode step is the unit lowered by the ``decode_32k`` / ``long_500k``
dry-run shapes: one new token for every sequence in the batch against a
seq_len-deep cache. ``greedy_generate`` is the host-side loop used by the
examples and integration tests (prefill once, then N decode steps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, max_len=max_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, inputs, pos, cache):
        return lm.decode_step(params, cfg, inputs, pos, cache)

    return decode_step


def greedy_generate(params, cfg: ModelConfig, batch: dict, *, steps: int,
                    max_len: int):
    """Prefill on ``batch`` then greedily decode ``steps`` tokens.

    Returns (tokens (B, steps) i32). Works for text archs; audio archs
    decode from embeddings so greedy id selection feeds the embed table stub.
    """
    prefill = jax.jit(make_prefill_step(cfg, max_len))
    decode = jax.jit(make_decode_step(cfg))
    logits, cache = prefill(params, batch)
    if cfg.modality == "vlm":
        prompt_len = batch["tokens"].shape[1] + cfg.n_prefix_embeds
    elif cfg.inputs_are_embeds:
        prompt_len = batch["embeds"].shape[1]
    else:
        prompt_len = batch["tokens"].shape[1]
    outs = []
    tok = jnp.argmax(logits[..., : cfg.vocab], axis=-1).astype(jnp.int32)
    for i in range(steps):
        outs.append(tok)
        pos = jnp.asarray(prompt_len + i, jnp.int32)
        if cfg.inputs_are_embeds:
            # audio stub: embed the sampled codec id through a fixed table
            emb = jax.nn.one_hot(tok % cfg.d_model, cfg.d_model, dtype=jnp.float32)
            logits, cache = decode(params, {"embed": emb}, pos, cache)
        else:
            logits, cache = decode(params, {"token": tok}, pos, cache)
        tok = jnp.argmax(logits[..., : cfg.vocab], axis=-1).astype(jnp.int32)
    return jnp.stack(outs, axis=1)
