"""Mamba2 (SSD — state-space duality) layer: chunked scan + one-step decode.

The forward pass is the SSD chunked algorithm (Dao & Gu 2024, §6): the
sequence splits into chunks of length L; within a chunk the recurrence is
evaluated as a (masked, decay-weighted) attention-like matmul — MXU-friendly
— and chunk-final states are carried through a ``lax.scan``, so memory is
O(B·H·L²) per step instead of O(B·H·S²).

Tensor-parallel layout (the Mamba2 paper's own §7 TP design): the z / x / dt
projections are head-structured and shard over the `model` axis; the group
(B, C) stream is replicated (n_groups < TP degree). The depthwise conv is
per-channel, so splitting it into an x-conv (sharded) and a bc-conv
(replicated) is mathematically identical to the fused conv.

Decode carries (conv_x, conv_bc, ssm_state) and costs O(1) per token — this
is what makes the ``long_500k`` shape runnable (DESIGN.md §4).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common import nn
from repro.configs.base import ModelConfig


def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, di, h = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    gn2 = 2 * cfg.ssm_n_groups * cfg.ssm_state
    ks = jax.random.split(key, 7)
    # dt bias: inverse-softplus of dt ~ U[1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[0], (h,)) * (math.log(0.1) - math.log(1e-3))
                 + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_z": nn.lecun_normal(ks[1], (d, di), dtype=dtype),
        "in_x": nn.lecun_normal(ks[2], (d, di), dtype=dtype),
        "in_bc": nn.lecun_normal(ks[3], (d, gn2), dtype=dtype),
        "in_dt": nn.lecun_normal(ks[4], (d, h), dtype=dtype),
        "conv_x_w": nn.trunc_normal(ks[5], (cfg.ssm_conv, di),
                                    1.0 / math.sqrt(cfg.ssm_conv), dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_w": nn.trunc_normal(ks[6], (cfg.ssm_conv, gn2),
                                     1.0 / math.sqrt(cfg.ssm_conv), dtype),
        "conv_bc_b": jnp.zeros((gn2,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": nn.rmsnorm_init(di, dtype),
        "out_proj": nn.lecun_normal(ks[0], (di, d), fan_in=di, dtype=dtype),
    }


def _causal_conv(w, b, x: jax.Array, width: int) -> jax.Array:
    """Depthwise causal conv along S. x (B,S,C), w (width,C)."""
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + x.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def _heads_from_groups(t: jax.Array, h: int, g: int):
    """(B,S,G,N) -> (B,S,H,N) by repeating each group across its heads."""
    b, s, _, n = t.shape
    rep = h // g
    return jnp.broadcast_to(t[:, :, :, None], (b, s, g, rep, n)).reshape(b, s, h, n)


def ssd_scan(x, dt, A, B_, C_, *, chunk: int, state_in=None):
    """The SSD chunked recurrence.

    x (B,S,H,P); dt (B,S,H) post-softplus; A (H,) negative; B_,C_ (B,S,H,N).
    Returns (y (B,S,H,P), final_state (B,H,N,P)). All math f32.
    """
    b, s, h, p_dim = x.shape
    n = B_.shape[-1]
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L
    f32 = jnp.float32
    xc = x.astype(f32).reshape(b, nc, L, h, p_dim)
    dtc = dt.astype(f32).reshape(b, nc, L, h)
    Bc = B_.astype(f32).reshape(b, nc, L, h, n)
    Cc = C_.astype(f32).reshape(b, nc, L, h, n)
    dA = dtc * A[None, None, None, :]  # (B,nc,L,H) log-decay, <= 0

    if state_in is None:
        state_in = jnp.zeros((b, h, n, p_dim), f32)

    idx = jnp.arange(L)
    causal = idx[:, None] >= idx[None, :]  # (L, L) l >= m

    def step(state, inputs):
        x_c, dt_c, dA_c, b_c, c_c = inputs  # leading dim B
        seg = jnp.cumsum(dA_c, axis=1)  # (B,L,H)
        lam = jnp.exp(seg[:, -1])  # (B,H) whole-chunk decay
        # intra-chunk: M[l,m] = C[l]·B[m] · exp(seg l - seg m) · dt[m], m <= l
        cb = jnp.einsum("blhn,bmhn->bhlm", c_c, b_c)
        decay = jnp.exp(seg.transpose(0, 2, 1)[:, :, :, None]
                        - seg.transpose(0, 2, 1)[:, :, None, :])  # (B,H,L,L)
        m_mat = cb * jnp.where(causal[None, None], decay, 0.0) \
            * dt_c.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhlm,bmhp->blhp", m_mat, x_c)
        # inter-chunk: contribution of the incoming state
        y_inter = jnp.einsum("blhn,bhnp,blh->blhp", c_c, state, jnp.exp(seg))
        # chunk-final state
        w_st = jnp.exp(seg[:, -1:, :] - seg) * dt_c  # (B,L,H)
        state = lam[:, :, None, None] * state \
            + jnp.einsum("blh,blhn,blhp->bhnp", w_st, b_c, x_c)
        return state, y_intra + y_inter

    xs = (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
          dA.transpose(1, 0, 2, 3), Bc.transpose(1, 0, 2, 3, 4),
          Cc.transpose(1, 0, 2, 3, 4))
    final_state, ys = jax.lax.scan(step, state_in, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p_dim)
    return y, final_state


def mamba_apply(p, cfg: ModelConfig, x: jax.Array, *, return_state: bool = False):
    """Full-sequence Mamba2. x (B,S,D) -> y (B,S,D) [, (conv_x, conv_bc, ssm_state)]."""
    b, s, _ = x.shape
    h, p_dim, g, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_n_groups, cfg.ssm_state
    di, w = cfg.d_inner, cfg.ssm_conv
    z = x @ p["in_z"]
    x_raw = x @ p["in_x"]
    bc_raw = x @ p["in_bc"]
    dt_raw = x @ p["in_dt"]
    xs = _causal_conv(p["conv_x_w"], p["conv_x_b"], x_raw, w).reshape(b, s, h, p_dim)
    bc = _causal_conv(p["conv_bc_w"], p["conv_bc_b"], bc_raw, w)
    B_ = _heads_from_groups(bc[..., : g * n].reshape(b, s, g, n), h, g)
    C_ = _heads_from_groups(bc[..., g * n:].reshape(b, s, g, n), h, g)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, final_state = ssd_scan(xs, dt, A, B_, C_, chunk=cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    # gated RMS norm (mamba2's RMSNormGated): gate, then normalize
    y = nn.rmsnorm_apply(p["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    out = y @ p["out_proj"]
    if not return_state:
        return out
    state = {
        "conv_x": x_raw[:, s - (w - 1):, :],
        "conv_bc": bc_raw[:, s - (w - 1):, :],
        "state": final_state,
    }
    return out, state


def mamba_make_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    gn2 = 2 * cfg.ssm_n_groups * cfg.ssm_state
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, gn2), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                           jnp.float32),
    }


def mamba_decode(p, cfg: ModelConfig, x: jax.Array, cache: dict):
    """One-token step. x (B,1,D) -> (y (B,1,D), cache)."""
    b = x.shape[0]
    h, p_dim, g, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_n_groups, cfg.ssm_state
    di, w = cfg.d_inner, cfg.ssm_conv
    z, x_new, bc_new, dt_raw = (x[:, 0] @ p["in_z"], x[:, 0] @ p["in_x"],
                                x[:, 0] @ p["in_bc"], x[:, 0] @ p["in_dt"])
    win_x = jnp.concatenate(
        [cache["conv_x"], x_new[:, None].astype(cache["conv_x"].dtype)], axis=1)
    win_bc = jnp.concatenate(
        [cache["conv_bc"], bc_new[:, None].astype(cache["conv_bc"].dtype)], axis=1)
    xs = jax.nn.silu(jnp.einsum("bwc,wc->bc", win_x, p["conv_x_w"]) + p["conv_x_b"])
    bc = jax.nn.silu(jnp.einsum("bwc,wc->bc", win_bc, p["conv_bc_w"]) + p["conv_bc_b"])
    xs = xs.reshape(b, h, p_dim).astype(jnp.float32)
    rep = h // g
    B_ = bc[..., : g * n].reshape(b, g, n)
    C_ = bc[..., g * n:].reshape(b, g, n)
    B_h = jnp.broadcast_to(B_[:, :, None], (b, g, rep, n)).reshape(b, h, n).astype(jnp.float32)
    C_h = jnp.broadcast_to(C_[:, :, None], (b, g, rep, n)).reshape(b, h, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None, :])  # (B,H)
    state = cache["state"] * decay[:, :, None, None] \
        + jnp.einsum("bh,bhn,bhp->bhnp", dt, B_h, xs)
    y = jnp.einsum("bhn,bhnp->bhp", C_h, state) + p["D"][None, :, None] * xs
    y = y.reshape(b, di).astype(x.dtype)
    y = nn.rmsnorm_apply(p["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None]
    return out, {"conv_x": win_x[:, 1:], "conv_bc": win_bc[:, 1:], "state": state}
