"""Feed-forward variants: gated (SwiGLU/GeGLU) and plain MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import nn


def ffn_init(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": nn.lecun_normal(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": nn.lecun_normal(ks[1], (d_ff, d_model), fan_in=d_ff, dtype=dtype),
    }
    if act in ("silu", "gelu"):
        p["w_gate"] = nn.lecun_normal(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def ffn_apply(p, x: jax.Array, act: str) -> jax.Array:
    up = x @ p["w_up"]
    if "w_gate" in p:
        up = nn.act_fn(act)(x @ p["w_gate"]) * up
    else:
        up = nn.act_fn("gelu" if act == "gelu_mlp" else act)(up)
    return up @ p["w_down"]
