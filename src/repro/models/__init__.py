from repro.models import attention, ffn, lm, mamba2, moe, rotary  # noqa: F401
