"""Rotary and sinusoidal position embeddings.

``apply_rope`` supports partial rotary (stablelm rotates only the first 25%
of head_dim) and interleaved vs half-split layouts (we use the half-split
"neox" layout used by every assigned arch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, rotary_pct: float, theta: float) -> jax.Array:
    """Inverse frequencies for the rotated sub-dimension. (rot_dim/2,) f32."""
    rot = rotary_dims(head_dim, rotary_pct)
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def rotary_dims(head_dim: int, rotary_pct: float) -> int:
    rot = int(head_dim * rotary_pct)
    return rot - (rot % 2)


def apply_rope(x: jax.Array, positions: jax.Array, *, rotary_pct: float = 1.0,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, head_dim) or (..., S, head_dim); positions: (..., S)."""
    head_dim = x.shape[-1]
    rot = rotary_dims(head_dim, rotary_pct)
    if rot == 0:
        return x
    freqs = rope_freqs(head_dim, rotary_pct, theta)  # (rot/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions.ndim + 2:  # insert head axis
        cos, sin = cos[..., None, :], sin[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if rot < head_dim else out


def sinusoidal(positions: jax.Array, dim: int, max_scale: float = 10000.0) -> jax.Array:
    """Classic sin/cos absolute position table. positions (..., S) -> (..., S, dim)."""
    half = dim // 2
    freq = jnp.exp(-jnp.log(max_scale) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
