"""Scatter-based expert-parallel MoE (shard_map) — the §Perf B7 dispatch.

The einsum (GShard) dispatch in moe.py builds (B,S,E,C) one-hot tensors and
pays O(T·E·C·D) FLOPs — 1-3× the expert compute itself. This version uses
the device-local formulation instead:

  * tokens are data-sharded and REPLICATED across `model` (the TP layout the
    rest of the block already uses), so every model rank sees its data
    shard's tokens and computes identical routing;
  * each model rank owns E/|model| experts (weights FSDP-sharded over
    `data`, all-gathered per layer inside the map — ZeRO-3);
  * rank-local scatter-add builds (E_loc, C, D) expert inputs in O(T·k·D);
  * expert FFN; gather back per assignment; psum over `model` combines the
    per-rank partial outputs.

Collectives per layer: the FSDP weight gather + one psum of (T_local, D) —
no dispatch-tensor resharding at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import compat

from repro.common import nn
from repro.configs.base import ModelConfig
from repro.models.ffn import ffn_apply
from repro.models.moe import group_capacity, router_topk


def moe_apply_sharded(p, cfg: ModelConfig, x: jax.Array, *, batch_axes,
                      model_axis: str = "model", mesh=None):
    """Drop-in for moe.moe_apply under an active mesh. x (B, S, D)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    if mesh is None:
        mesh = jax.sharding.get_abstract_mesh()
    n_model = mesh.shape[model_axis]
    assert e % n_model == 0, (e, n_model)
    e_loc = e // n_model
    # per-shard token count decides capacity: tokens of one data shard
    n_data = 1
    for a in (batch_axes if isinstance(batch_axes, tuple) else (batch_axes,)):
        n_data *= mesh.shape[a]
    t_shard = max(1, (b // max(1, n_data)) * s)
    cap = max(4, group_capacity(t_shard, cfg))  # per expert, per data shard

    from jax.sharding import PartitionSpec as P
    bax = batch_axes if isinstance(batch_axes, tuple) else (batch_axes,)
    bspec = bax if len(bax) > 1 else bax[0]

    def local(xl, router, w_up, w_gate, w_down):
        # xl: (B_l, S, D) — identical across model ranks of a data shard
        bl = xl.shape[0]
        t = bl * s
        xt = xl.reshape(t, d)
        logits = xt.astype(jnp.float32) @ router  # (T, E) — replicated compute
        top_w, top_i, probs = router_topk(logits, k)  # (T, k)

        # FSDP: assemble this rank's experts' full weights
        w_up = jax.lax.all_gather(w_up, bax, axis=1, tiled=True)
        w_gate = jax.lax.all_gather(w_gate, bax, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, bax, axis=2, tiled=True)

        # positions within each expert (consistent across ranks)
        pos_list, keep_list = [], []
        counts = jnp.zeros((e,), jnp.int32)
        for j in range(k):
            onehot_j = jax.nn.one_hot(top_i[:, j], e, dtype=jnp.int32)
            pos_j = jnp.cumsum(onehot_j, axis=0) - 1 + counts[None, :]
            counts = counts + jnp.sum(onehot_j, axis=0)
            pos_list.append(jnp.sum(pos_j * onehot_j, axis=1))
            keep_list.append(pos_list[-1] < cap)
        pos = jnp.stack(pos_list, 1)  # (T, k)
        keep = jnp.stack(keep_list, 1)

        rank = jax.lax.axis_index(model_axis)
        e0 = rank * e_loc
        mine = (top_i >= e0) & (top_i < e0 + e_loc) & keep  # (T, k)
        e_local = jnp.where(mine, top_i - e0, e_loc)  # e_loc = drop bucket
        pos_c = jnp.where(mine, pos, cap)  # cap = drop bucket

        # scatter-add tokens into (E_loc+1, C+1, D); last slices are drop bins
        buf = jnp.zeros((e_loc + 1, cap + 1, d), xl.dtype)
        tok_rep = jnp.repeat(xt[:, None, :], k, axis=1).reshape(t * k, d)
        idx = jnp.stack([e_local.reshape(-1), pos_c.reshape(-1)], axis=-1)
        buf = buf.at[idx[:, 0], idx[:, 1]].add(tok_rep)
        expert_in = buf[:e_loc, :cap]

        up = jnp.einsum("ecd,edf->ecf", expert_in, w_up)
        gate = nn.act_fn(cfg.ffn_act)(jnp.einsum("ecd,edf->ecf", expert_in,
                                                 w_gate))
        expert_out = jnp.einsum("ecf,efd->ecd", gate * up, w_down)
        expert_out = jnp.pad(expert_out, ((0, 1), (0, 1), (0, 0)))

        # gather each assignment's output, weight, and sum over k
        out_rows = expert_out[e_local.reshape(-1), pos_c.reshape(-1)]
        out_rows = out_rows.reshape(t, k, d)
        w_eff = (top_w * mine.astype(jnp.float32)).astype(xl.dtype)
        y = jnp.einsum("tkd,tk->td", out_rows, w_eff)
        y = jax.lax.psum(y, model_axis)  # combine across expert ranks
        drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
        return y.reshape(bl, s, d), probs, top_i, drop_frac

    y, probs, top_i, drop = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, None, None), P(), P(model_axis, bspec, None),
                  P(model_axis, bspec, None), P(model_axis, None, bspec)),
        out_specs=(P(bspec, None, None), P(bspec, None), P(bspec, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_up"], p["w_gate"], p["w_down"])

    if cfg.n_shared_experts:
        y = y + ffn_apply(p["shared"], x, cfg.ffn_act)
    e_arr = probs.shape[-1]
    me = jnp.mean(probs, axis=0)
    assign = jax.nn.one_hot(top_i[:, 0], e_arr, dtype=jnp.float32)
    ce = jnp.mean(assign, axis=0)
    aux = {"moe_lb_loss": e_arr * jnp.sum(me * ce),
           "moe_z_loss": jnp.mean(jnp.square(jax.nn.logsumexp(
               jnp.log(jnp.maximum(probs, 1e-20)), axis=-1))),
           "moe_drop_frac": drop}
    return y, aux
