"""LM assembly: blocks, parameter init, train loss, prefill and decode.

One module covers all four assigned families:

  dense   — [norm → attn → +res, norm → ffn → +res] × L     (scan, stacked)
  moe     — dense prefix (first_k_dense) + MoE blocks        (two scans)
  ssm     — [norm → mamba2 → +res] × L                       (scan)
  hybrid  — mamba2 × L with a SHARED attn+ffn block applied
            after every ``hybrid_attn_every``-th layer        (group scan)

Layer stacks are scanned (``jax.lax.scan`` over stacked params) so the HLO
is O(1) in depth; ``remat=True`` checkpoints each block for training. The
loss computes cross-entropy in sequence chunks so the (T, vocab) logits
matrix never materializes (gemma's 256k vocab would be 128 GB otherwise).

Modality stubs (DESIGN.md §4): vlm prepends projected patch embeddings,
audio feeds precomputed frame embeddings straight to the stack.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common import nn
from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2
from repro.models.ffn import ffn_init, ffn_apply
from repro.models.moe import moe_init, moe_apply
from repro.models.rotary import sinusoidal

MTP_COEF = 0.1
AUX_KEYS = ("moe_lb_loss", "moe_z_loss", "moe_drop_frac")


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, dtype=jnp.float32):
    if cfg.norm == "layernorm":
        return nn.layernorm_init(cfg.d_model, dtype)
    p = nn.rmsnorm_init(cfg.d_model, dtype)
    if cfg.zero_centered_norm:
        p = {"scale": jnp.zeros((cfg.d_model,), dtype)}
    return p


def norm_apply(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return nn.layernorm_apply(p, x, eps=cfg.norm_eps)
    return nn.rmsnorm_apply(p, x, eps=cfg.norm_eps,
                            zero_centered=cfg.zero_centered_norm)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

_KEEP_F32 = ("router", "A_log", "D", "dt_bias")  # numerics-sensitive leaves


def _cast_block(p, cfg: ModelConfig):
    """Mixed precision: cast block params to the compute dtype at use."""
    dt = jnp.dtype(cfg.dtype)

    def one(path, leaf):
        if path.split("/")[-1] in _KEEP_F32:
            return leaf
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf.astype(dt)
        return leaf

    from repro.common import pytree as _pt
    return _pt.tree_map_with_path(one, p)


def _attn_block_init(key, cfg: ModelConfig, d_ff: int, *, moe: bool, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "norm1": norm_init(cfg, dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "norm2": norm_init(cfg, dtype),
    }
    if moe:
        p["moe"] = moe_init(k2, cfg, dtype)
    else:
        p["ffn"] = ffn_init(k2, cfg.d_model, d_ff, cfg.ffn_act, dtype)
    return p


def _moe_dispatch(p, cfg: ModelConfig, h):
    """Select the MoE implementation (einsum baseline vs sharded scatter)."""
    from repro.models import sharding as _shd

    ctx = _shd.act_ctx()
    if cfg.moe_impl == "sharded" and ctx is not None:
        from repro.models.moe_sharded import moe_apply_sharded

        return moe_apply_sharded(p, cfg, h, batch_axes=ctx["batch"],
                                 model_axis=ctx["model"], mesh=ctx.get("mesh"))
    return moe_apply(p, cfg, h)


def _attn_block_apply(p, cfg: ModelConfig, x, positions, *, moe: bool):
    """Train/prefill-without-cache path. Returns (x, aux)."""
    p = _cast_block(p, cfg)
    y = attn.attn_apply(p["attn"], cfg, norm_apply(cfg, p["norm1"], x), positions)
    x = x + y
    h = norm_apply(cfg, p["norm2"], x)
    if moe:
        y, aux = _moe_dispatch(p["moe"], cfg, h)
    else:
        y, aux = ffn_apply(p["ffn"], h, cfg.ffn_act), {}
    return x + y, aux


def _attn_block_prefill(p, cfg: ModelConfig, x, positions, cache, *, moe: bool):
    p = _cast_block(p, cfg)
    y, cache = attn.attn_prefill(p["attn"], cfg, norm_apply(cfg, p["norm1"], x),
                                 positions, cache)
    x = x + y
    h = norm_apply(cfg, p["norm2"], x)
    if moe:
        y, _ = _moe_dispatch(p["moe"], cfg, h)
    else:
        y = ffn_apply(p["ffn"], h, cfg.ffn_act)
    return x + y, cache


def _attn_block_decode(p, cfg: ModelConfig, x, pos, cache, *, moe: bool):
    p = _cast_block(p, cfg)
    y, cache = attn.attn_decode(p["attn"], cfg, norm_apply(cfg, p["norm1"], x),
                                pos, cache)
    x = x + y
    h = norm_apply(cfg, p["norm2"], x)
    if moe:
        y, _ = _moe_dispatch(p["moe"], cfg, h)
    else:
        y = ffn_apply(p["ffn"], h, cfg.ffn_act)
    return x + y, cache


def _mamba_block_init(key, cfg: ModelConfig, dtype):
    return {"norm1": norm_init(cfg, dtype), "mamba": mamba2.mamba_init(key, cfg, dtype)}


def _mamba_block_apply(p, cfg: ModelConfig, x, *, return_state=False):
    p = _cast_block(p, cfg)
    h = norm_apply(cfg, p["norm1"], x)
    if return_state:
        y, st = mamba2.mamba_apply(p["mamba"], cfg, h, return_state=True)
        return x + y, st
    return x + mamba2.mamba_apply(p["mamba"], cfg, h)


def _mamba_block_decode(p, cfg: ModelConfig, x, cache):
    p = _cast_block(p, cfg)
    h = norm_apply(cfg, p["norm1"], x)
    y, cache = mamba2.mamba_decode(p["mamba"], cfg, h, cache)
    return x + y, cache


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stacked_init(key, n: int, one_init):
    """Initialize n blocks and stack their params along axis 0."""
    keys = jax.random.split(key, max(n, 1))
    ps = [one_init(keys[i]) for i in range(n)]
    if not ps:
        return None
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def init(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    params: dict = {}
    if not cfg.inputs_are_embeds:
        params["embed"] = {
            "table": nn.trunc_normal(ks[0], (cfg.vocab_padded, cfg.d_model),
                                     1.0 / math.sqrt(cfg.d_model), dtype)
        }
    if cfg.modality == "vlm":
        params["patch_proj"] = nn.linear_init(ks[1], cfg.d_model, cfg.d_model,
                                              bias=True, dtype=dtype)

    if cfg.family == "dense":
        params["blocks"] = _stacked_init(
            ks[2], cfg.n_layers,
            lambda k: _attn_block_init(k, cfg, cfg.d_ff, moe=False, dtype=dtype))
    elif cfg.family == "moe":
        kd, km = jax.random.split(ks[2])
        if cfg.first_k_dense:
            params["dense_blocks"] = _stacked_init(
                kd, cfg.first_k_dense,
                lambda k: _attn_block_init(k, cfg, cfg.dense_d_ff, moe=False, dtype=dtype))
        params["moe_blocks"] = _stacked_init(
            km, cfg.n_layers - cfg.first_k_dense,
            lambda k: _attn_block_init(k, cfg, 0, moe=True, dtype=dtype))
    elif cfg.family == "ssm":
        params["blocks"] = _stacked_init(
            ks[2], cfg.n_layers, lambda k: _mamba_block_init(k, cfg, dtype))
    elif cfg.family == "hybrid":
        params["blocks"] = _stacked_init(
            ks[2], cfg.n_layers, lambda k: _mamba_block_init(k, cfg, dtype))
        params["shared_attn"] = _attn_block_init(ks[3], cfg, cfg.d_ff, moe=False,
                                                 dtype=dtype)
    else:
        raise ValueError(cfg.family)

    params["final_norm"] = norm_init(cfg, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": nn.trunc_normal(ks[4], (cfg.d_model, cfg.vocab_padded),
                                 1.0 / math.sqrt(cfg.d_model), dtype)
        }
    if cfg.mtp_depth:
        params["mtp"] = {
            "norm_h": norm_init(cfg, dtype),
            "norm_e": norm_init(cfg, dtype),
            "proj": nn.linear_init(ks[5], 2 * cfg.d_model, cfg.d_model, dtype=dtype),
            "block": _attn_block_init(ks[6], cfg,
                                      cfg.dense_d_ff or cfg.d_ff, moe=False, dtype=dtype),
            "norm_f": norm_init(cfg, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# inputs
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, batch: dict):
    """-> (x (B,S,D) in cfg.dtype, positions (B,S))."""
    dt = jnp.dtype(cfg.dtype)
    parts = []
    if cfg.modality == "vlm":
        patches = batch["patch_embeds"].astype(dt)
        parts.append(nn.linear_apply(params["patch_proj"], patches).astype(dt))
    if cfg.inputs_are_embeds:
        parts.append(batch["embeds"].astype(dt))
    elif "tokens" in batch:
        tok = params["embed"]["table"][batch["tokens"]].astype(dt)
        parts.append(tok)
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.pos_embed == "sinusoidal":
        x = x + sinusoidal(positions, cfg.d_model).astype(dt)
    return x, positions


def unembed(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    """hidden (..., D) -> logits (..., vocab_padded), f32. Pad cols masked."""
    h = h.astype(jnp.float32)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["table"].astype(jnp.float32).T
    else:
        logits = h @ params["lm_head"]["w"].astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# stacked forward (train / no-cache)
# ---------------------------------------------------------------------------

def _scan_blocks(stacked, fn, x, *, remat: bool, collect_aux: bool, act_spec=None):
    """Scan ``fn(block_params, x) -> (x, aux)`` over stacked block params.

    ``act_spec`` (a PartitionSpec) constrains the residual-stream carry —
    used to shard the saved activations over the sequence dim (Megatron-style
    sequence parallelism for the remat footprint). Requires a mesh context.
    """
    if act_spec is not None:
        inner = fn

        def fn(bp, y):  # noqa: F811 — deliberate wrap
            y = jax.lax.with_sharding_constraint(y, act_spec)
            out, aux = inner(bp, y)
            return jax.lax.with_sharding_constraint(out, act_spec), aux

    if remat:
        fn = jax.checkpoint(fn, prevent_cse=False)

    def body(carry, bp):
        y, aux = fn(bp, carry)
        return y, aux

    x, auxs = jax.lax.scan(body, x, stacked)
    if collect_aux and auxs:
        auxs = {k: jnp.sum(v) for k, v in auxs.items()}
    return x, auxs


def hidden(params, cfg: ModelConfig, batch: dict, *, remat: bool = False,
           act_spec=None):
    """Full forward to final-norm hidden states. Returns (h, aux)."""
    x, positions = embed_inputs(params, cfg, batch)
    aux: dict = {}

    if cfg.family == "dense":
        x, _ = _scan_blocks(
            params["blocks"],
            lambda bp, y: (_attn_block_apply(bp, cfg, y, positions, moe=False)[0], {}),
            x, remat=remat, collect_aux=False, act_spec=act_spec)
    elif cfg.family == "moe":
        if cfg.first_k_dense:
            x, _ = _scan_blocks(
                params["dense_blocks"],
                lambda bp, y: (_attn_block_apply(bp, cfg, y, positions, moe=False)[0], {}),
                x, remat=remat, collect_aux=False, act_spec=act_spec)
        x, aux = _scan_blocks(
            params["moe_blocks"],
            lambda bp, y: _attn_block_apply(bp, cfg, y, positions, moe=True),
            x, remat=remat, collect_aux=True, act_spec=act_spec)
    elif cfg.family == "ssm":
        x, _ = _scan_blocks(
            params["blocks"],
            lambda bp, y: (_mamba_block_apply(bp, cfg, y), {}),
            x, remat=remat, collect_aux=False, act_spec=act_spec)
    elif cfg.family == "hybrid":
        x = _hybrid_forward(params, cfg, x, positions, remat=remat,
                            act_spec=act_spec)

    return norm_apply(cfg, params["final_norm"], x), aux


def _hybrid_forward(params, cfg: ModelConfig, x, positions, *, remat: bool,
                    act_spec=None):
    """zamba2: groups of ``hybrid_attn_every`` mamba layers + one SHARED attn block."""
    k = cfg.hybrid_attn_every
    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
    n_groups = cfg.n_layers // k
    grouped = jax.tree.map(
        lambda a: a.reshape(n_groups, k, *a.shape[1:]), params["blocks"])
    shared = params["shared_attn"]

    def group_fn(bp_group, y):
        y, _ = _scan_blocks(
            bp_group, lambda bp, z: (_mamba_block_apply(bp, cfg, z), {}),
            y, remat=False, collect_aux=False)
        y, _ = _attn_block_apply(shared, cfg, y, positions, moe=False)
        return y, {}

    x, _ = _scan_blocks(grouped, group_fn, x, remat=remat, collect_aux=False,
                        act_spec=act_spec)
    return x


# ---------------------------------------------------------------------------
# loss (chunked cross-entropy)
# ---------------------------------------------------------------------------

def _pick_chunk(s: int, target: int = 2048) -> int:
    for c in (target, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if c <= s and s % c == 0:
            return c
    return s


def chunked_ce(params, cfg: ModelConfig, h: jax.Array, labels: jax.Array,
               *, chunk: int = 0):
    """Mean next-token CE without materializing (T, V). labels < 0 ignored."""
    b, s, d = h.shape
    chunk = chunk or _pick_chunk(s)
    nc = s // chunk
    hc = h.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def one(args):
        hh, ll = args
        logits = unembed(params, cfg, hh)  # (B, c, V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(ll, 0)[..., None],
                                   axis=-1)[..., 0]
        valid = (ll >= 0).astype(jnp.float32)
        nll = (logz - gold) * valid
        return jnp.sum(nll), jnp.sum(valid), jnp.sum(jnp.square(logz) * valid)

    nll, cnt, zsq = jax.lax.map(one, (hc, lc))
    total_cnt = jnp.maximum(jnp.sum(cnt), 1.0)
    return jnp.sum(nll) / total_cnt, jnp.sum(zsq) / total_cnt


def loss_fn(params, cfg: ModelConfig, batch: dict, *, remat: bool = False,
            z_loss_coef: float = 1e-4, act_spec=None):
    """Scalar training loss + metrics. batch carries 'labels' (B, S_lab)."""
    h, aux = hidden(params, cfg, batch, remat=remat, act_spec=act_spec)
    labels = batch["labels"]
    s_lab = labels.shape[1]
    h_lab = h[:, h.shape[1] - s_lab:]
    ce, zsq = chunked_ce(params, cfg, h_lab, labels)
    loss = ce + z_loss_coef * zsq
    metrics = {"ce": ce, "z_sq": zsq}
    if aux:
        loss = loss + cfg.router_aux_coef * aux["moe_lb_loss"] \
            + 1e-4 * aux["moe_z_loss"]
        metrics.update(aux)
    if cfg.mtp_depth and "tokens" in batch:
        mtp_ce = _mtp_loss(params, cfg, h, batch)
        loss = loss + MTP_COEF * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(params, cfg: ModelConfig, h, batch):
    """DeepSeek-V3 multi-token prediction (depth 1): predict token t+2 from
    [h_t ; embed(token_{t+1})] through one extra block, shared unembedding."""
    p = params["mtp"]
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    h_in = norm_apply(cfg, p["norm_h"], h[:, : s - 1])
    e_in = norm_apply(
        cfg, p["norm_e"],
        params["embed"]["table"][tokens[:, 1:]].astype(h.dtype))
    x = nn.linear_apply(p["proj"], jnp.concatenate([h_in, e_in], axis=-1))
    positions = jnp.broadcast_to(jnp.arange(s - 1, dtype=jnp.int32), (b, s - 1))
    x, _ = _attn_block_apply(p["block"], cfg, x, positions, moe=False)
    x = norm_apply(cfg, p["norm_f"], x)
    # target for position t is labels[t+1] (= token t+2); t ranges 0..s-2
    tgt = labels[:, 1:]
    ce, _ = chunked_ce(params, cfg, x, tgt)
    return ce


# ---------------------------------------------------------------------------
# caches / prefill / decode
# ---------------------------------------------------------------------------

def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = jnp.dtype(dtype or cfg.dtype)
    L = cfg.n_layers

    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.zeros((n, *x.shape), x.dtype), tree)

    if cfg.family == "dense":
        return {"attn": stack(attn.attn_make_cache(cfg, batch, max_len, dt), L)}
    if cfg.family == "moe":
        c = {}
        if cfg.first_k_dense:
            c["dense"] = stack(attn.attn_make_cache(cfg, batch, max_len, dt),
                               cfg.first_k_dense)
        c["moe"] = stack(attn.attn_make_cache(cfg, batch, max_len, dt),
                         cfg.n_layers - cfg.first_k_dense)
        return c
    if cfg.family == "ssm":
        return {"mamba": stack(mamba2.mamba_make_cache(cfg, batch, dt), L)}
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.hybrid_attn_every
        return {
            "mamba": stack(mamba2.mamba_make_cache(cfg, batch, dt), L),
            "attn": stack(attn.attn_make_cache(cfg, batch, max_len, dt), n_groups),
        }
    raise ValueError(cfg.family)


def _scan_with_cache(stacked, cache, fn, x):
    """Scan blocks threading per-layer cache. fn(bp, cache_l, x) -> (x, cache_l)."""

    def body(carry, xs):
        bp, cl = xs
        y, new_cl = fn(bp, cl, carry)
        return y, new_cl

    x, new_cache = jax.lax.scan(body, x, (stacked, cache))
    return x, new_cache


def prefill(params, cfg: ModelConfig, batch: dict, max_len: int):
    """Run the prompt, fill caches. Returns (last_token_logits (B,V), cache)."""
    x, positions = embed_inputs(params, cfg, batch)
    b = x.shape[0]
    cache = make_cache(cfg, b, max_len)

    if cfg.family == "dense":
        x, c = _scan_with_cache(
            params["blocks"], cache["attn"],
            lambda bp, cl, y: _attn_block_prefill(bp, cfg, y, positions, cl, moe=False),
            x)
        cache = {"attn": c}
    elif cfg.family == "moe":
        new = {}
        if cfg.first_k_dense:
            x, new["dense"] = _scan_with_cache(
                params["dense_blocks"], cache["dense"],
                lambda bp, cl, y: _attn_block_prefill(bp, cfg, y, positions, cl, moe=False),
                x)
        x, new["moe"] = _scan_with_cache(
            params["moe_blocks"], cache["moe"],
            lambda bp, cl, y: _attn_block_prefill(bp, cfg, y, positions, cl, moe=True),
            x)
        cache = new
    elif cfg.family == "ssm":
        def fn(bp, cl, y):
            h = norm_apply(cfg, bp["norm1"], y)
            out, st = mamba2.mamba_apply(bp["mamba"], cfg, h, return_state=True)
            return y + out, jax.tree.map(lambda a, b: a.astype(b.dtype), st, cl)

        x, c = _scan_with_cache(params["blocks"], cache["mamba"], fn, x)
        cache = {"mamba": c}
    elif cfg.family == "hybrid":
        x, cache = _hybrid_prefill(params, cfg, x, positions, cache)

    h = norm_apply(cfg, params["final_norm"], x)
    logits = unembed(params, cfg, h[:, -1])
    return logits, cache


def _hybrid_prefill(params, cfg: ModelConfig, x, positions, cache):
    k = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // k
    grouped = jax.tree.map(lambda a: a.reshape(n_groups, k, *a.shape[1:]),
                           params["blocks"])
    mcache = jax.tree.map(lambda a: a.reshape(n_groups, k, *a.shape[1:]),
                          cache["mamba"])
    shared = params["shared_attn"]

    def body(carry, xs):
        gp, mcl, acl = xs

        def fn(bp, cl, z):
            h = norm_apply(cfg, bp["norm1"], z)
            out, st = mamba2.mamba_apply(bp["mamba"], cfg, h, return_state=True)
            return z + out, jax.tree.map(lambda a, b: a.astype(b.dtype), st, cl)

        y, nm = _scan_with_cache(gp, mcl, fn, carry)
        y, na = _attn_block_prefill(shared, cfg, y, positions, acl, moe=False)
        return y, (nm, na)

    x, (new_m, new_a) = jax.lax.scan(body, x, (grouped, mcache, cache["attn"]))
    new_m = jax.tree.map(lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), new_m)
    return x, {"mamba": new_m, "attn": new_a}


def decode_step(params, cfg: ModelConfig, inputs: dict, pos: jax.Array, cache: dict):
    """One token for every sequence in the batch.

    inputs: {"token": (B,)} or {"embed": (B, D)} (audio). pos: () int32 —
    the cache slot to write (same for the whole batch). Returns
    (logits (B, V) f32, new_cache).
    """
    dt = jnp.dtype(cfg.dtype)
    if cfg.inputs_are_embeds:
        x = inputs["embed"][:, None].astype(dt)
    else:
        x = params["embed"]["table"][inputs["token"]][:, None].astype(dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    if cfg.pos_embed == "sinusoidal":
        b = x.shape[0]
        ppos = jnp.full((b, 1), pos, jnp.int32)
        x = x + sinusoidal(ppos, cfg.d_model).astype(dt)

    if cfg.family == "dense":
        x, c = _scan_with_cache(
            params["blocks"], cache["attn"],
            lambda bp, cl, y: _attn_block_decode(bp, cfg, y, pos, cl, moe=False), x)
        cache = {"attn": c}
    elif cfg.family == "moe":
        new = {}
        if cfg.first_k_dense:
            x, new["dense"] = _scan_with_cache(
                params["dense_blocks"], cache["dense"],
                lambda bp, cl, y: _attn_block_decode(bp, cfg, y, pos, cl, moe=False), x)
        x, new["moe"] = _scan_with_cache(
            params["moe_blocks"], cache["moe"],
            lambda bp, cl, y: _attn_block_decode(bp, cfg, y, pos, cl, moe=True), x)
        cache = new
    elif cfg.family == "ssm":
        x, c = _scan_with_cache(
            params["blocks"], cache["mamba"],
            lambda bp, cl, y: _mamba_block_decode(bp, cfg, y, cl), x)
        cache = {"mamba": c}
    elif cfg.family == "hybrid":
        x, cache = _hybrid_decode(params, cfg, x, pos, cache)

    h = norm_apply(cfg, params["final_norm"], x)
    return unembed(params, cfg, h[:, 0]), cache


def _hybrid_decode(params, cfg: ModelConfig, x, pos, cache):
    k = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // k
    grouped = jax.tree.map(lambda a: a.reshape(n_groups, k, *a.shape[1:]),
                           params["blocks"])
    mcache = jax.tree.map(lambda a: a.reshape(n_groups, k, *a.shape[1:]),
                          cache["mamba"])
    shared = params["shared_attn"]

    def body(carry, xs):
        gp, mcl, acl = xs
        y, new_m = _scan_with_cache(
            gp, mcl, lambda bp, cl, z: _mamba_block_decode(bp, cfg, z, cl), carry)
        y, new_a = _attn_block_decode(shared, cfg, y, pos, acl, moe=False)
        return y, (new_m, new_a)

    x, (new_m, new_a) = jax.lax.scan(body, x, (grouped, mcache, cache["attn"]))
    new_m = jax.tree.map(lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), new_m)
    return x, {"mamba": new_m, "attn": new_a}
