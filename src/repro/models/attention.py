"""Attention variants: GQA (with qk-norm, partial rotary, softcap) and MLA.

Full-sequence attention (train / prefill) is **q-chunked** (flash-style
online computation is unnecessary when K/V stay resident: we scan over query
blocks so the score matrix never exceeds (B, H, q_chunk, S) — this is what
keeps prefill_32k inside HBM; see EXPERIMENTS.md §Dry-run).

Decode attends one new token against a (B, S_max, ...) cache updated in
place with ``dynamic_update_slice``.

MLA (deepseek-v3) implements the **absorbed** decode path: the cache stores
only the compressed (c_kv, k_rope) stream — 576 f-elements/token instead of
n_heads·(192+128) — and W_uk/W_uv are folded into the query/output einsums.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common import nn
from repro.configs.base import ModelConfig
from repro.models.rotary import apply_rope


def _cb(x, dim: int = 0):
    from repro.models.sharding import constrain_batch
    return constrain_batch(x, dim)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(scores / cap)
    return scores


def _qk_norm_apply(p, x, eps):
    # per-head RMS norm over head_dim (qwen3 style)
    return nn.rmsnorm_apply(p, x, eps=eps)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": nn.lecun_normal(ks[0], (d, h * hd), dtype=dtype),
        "wk": nn.lecun_normal(ks[1], (d, kv * hd), dtype=dtype),
        "wv": nn.lecun_normal(ks[2], (d, kv * hd), dtype=dtype),
        "wo": nn.lecun_normal(ks[3], (h * hd, d), fan_in=h * hd, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = nn.rmsnorm_init(hd, dtype)
        p["k_norm"] = nn.rmsnorm_init(hd, dtype)
    return p


def _gqa_qkv(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    """x (B,S,D) -> q (B,S,H,hd), k/v (B,S,KV,hd), rope + qk-norm applied."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, kv, hd)
    v = (x @ p["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = _qk_norm_apply(p["q_norm"], q, cfg.norm_eps)
        k = _qk_norm_apply(p["k_norm"], k, cfg.norm_eps)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
        k = apply_rope(k, positions, rotary_pct=cfg.rotary_pct, theta=cfg.rope_theta)
    return _cb(q), _cb(k), _cb(v)


def chunked_causal_attention(q, k, v, *, q_chunk: int, scale: float,
                             softcap: float = 0.0, q_offset=0):
    """Grouped causal attention, scanning over query blocks.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). H % KV == 0. q position i
    attends to k positions <= q_offset + i. Returns (B, Sq, H, hd_v).
    """
    b, sq, h, hd = q.shape
    _, sk, kvh, hdv = v.shape
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    k_pos = jnp.arange(sk)

    n_chunks = max(1, sq // q_chunk)
    assert sq % n_chunks == 0, (sq, q_chunk)
    cq = sq // n_chunks
    qg = _cb(qg.reshape(b, n_chunks, cq, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5), 1)

    def one_chunk(ci, qc):
        # qc: (B, cq, KV, G, hd)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qc, k,
                            preferred_element_type=jnp.float32) * scale
        scores = _softcap(scores, softcap)
        q_pos = q_offset + ci * cq + jnp.arange(cq)
        causal = k_pos[None, :] <= q_pos[:, None]  # (cq, sk)
        scores = jnp.where(causal[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return _cb(jnp.einsum("bkgqs,bskh->bqkgh", probs, v))

    if n_chunks == 1:
        out = one_chunk(0, qg[0])[None]
    else:
        out = jax.lax.map(lambda args: one_chunk(*args),
                          (jnp.arange(n_chunks), qg))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hdv)
    return out


def flash_causal_attention(q, k, v, *, q_chunk: int, kv_chunk: int,
                           scale: float, softcap: float = 0.0, q_offset=0):
    """Online-softmax (flash) causal attention: the running (m, l, acc)
    carry means no (B, H, cq, S) score matrix ever materializes — HBM
    traffic is O(S·ckv) per query block instead of O(S²).

    Shapes as chunked_causal_attention. Returns (B, Sq, H, hd_v)."""
    b, sq, h, hd = q.shape
    _, sk, kvh, hdv = v.shape
    g = h // kvh
    nq = max(1, sq // q_chunk)
    assert sq % nq == 0
    cq = sq // nq
    nk = max(1, sk // kv_chunk)
    assert sk % nk == 0
    ck = sk // nk
    qg = _cb(q.reshape(b, nq, cq, kvh, g, hd).transpose(1, 0, 3, 4, 2, 5), 1)
    kc = _cb(k.reshape(b, nk, ck, kvh, hd).transpose(1, 0, 3, 2, 4), 1)
    vc = _cb(v.reshape(b, nk, ck, kvh, hdv).transpose(1, 0, 3, 2, 4), 1)

    def one_q_chunk(args):
        qi, qc = args  # qc: (B, KV, G, cq, hd)
        q_pos = q_offset + qi * cq + jnp.arange(cq)

        def kv_step(carry, inputs):
            m, l, acc = carry
            kj, kb, vb = inputs  # kb: (B, KV, ck, hd)
            s = jnp.einsum("bkgqh,bksh->bkgqs", qc, kb,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            k_pos = kj * ck + jnp.arange(ck)
            causal = k_pos[None, :] <= q_pos[:, None]
            s = jnp.where(causal[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bksh->bkgqh", p.astype(vb.dtype), vb)
            return (m_new, l, acc), None

        m0 = jnp.full((b, kvh, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, cq, hdv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        return acc / jnp.maximum(l, 1e-20)[..., None]

    out = jax.lax.map(one_q_chunk, (jnp.arange(nq), qg))  # (nq,B,KV,G,cq,hdv)
    out = _cb(out, 1).transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, hdv)
    return _cb(out.astype(v.dtype))


def full_attention(cfg: ModelConfig, q, k, v, *, scale, softcap=0.0, q_offset=0):
    if cfg.flash_attention:
        return flash_causal_attention(
            q, k, v, q_chunk=cfg.attn_chunk_q, kv_chunk=cfg.attn_chunk_kv,
            scale=scale, softcap=softcap, q_offset=q_offset)
    return chunked_causal_attention(q, k, v, q_chunk=cfg.attn_chunk_q,
                                    scale=scale, softcap=softcap,
                                    q_offset=q_offset)


def gqa_apply(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Full-sequence causal self-attention (train / prefill). x (B,S,D)."""
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    out = full_attention(cfg, q, k, v, scale=scale,
                         softcap=cfg.attn_logit_softcap)
    b, s = x.shape[:2]
    return out.reshape(b, s, -1) @ p["wo"]


def gqa_make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def gqa_prefill(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array, cache: dict):
    """Run full attention AND fill the cache with k/v. Returns (y, cache)."""
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    out = full_attention(cfg, q, k, v, scale=scale,
                         softcap=cfg.attn_logit_softcap)
    b, s = x.shape[:2]
    y = out.reshape(b, s, -1) @ p["wo"]
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }
    return y, cache


def gqa_decode(p, cfg: ModelConfig, x: jax.Array, pos: jax.Array, cache: dict):
    """One-token decode. x (B,1,D); pos () current position. Returns (y, cache)."""
    b = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)  # squeeze S=1
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, ck,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    scores = _softcap(scores, cfg.attn_logit_softcap)
    valid = jnp.arange(ck.shape[1]) <= pos
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, cv).reshape(b, 1, h * hd)
    return out @ p["wo"], {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (deepseek-v3)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq_a": nn.lecun_normal(ks[0], (d, qr), dtype=dtype),
        "q_norm": nn.rmsnorm_init(qr, dtype),
        "wq_b": nn.lecun_normal(ks[1], (qr, h * (nope + rope_d)), fan_in=qr, dtype=dtype),
        "wkv_a": nn.lecun_normal(ks[2], (d, kvr + rope_d), dtype=dtype),
        "kv_norm": nn.rmsnorm_init(kvr, dtype),
        "wkv_b": nn.lecun_normal(ks[3], (kvr, h * (nope + vd)), fan_in=kvr, dtype=dtype),
        "wo": nn.lecun_normal(ks[4], (h * vd, d), fan_in=h * vd, dtype=dtype),
    }


def _mla_q(p, cfg: ModelConfig, x, positions):
    """-> q_nope (B,S,H,nope), q_rope (B,S,H,rope) with rope applied."""
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = nn.rmsnorm_apply(p["q_norm"], x @ p["wq_a"], eps=cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)
    return _cb(q_nope), _cb(q_rope)


def _mla_kv_compressed(p, cfg: ModelConfig, x, positions):
    """-> c_kv (B,S,kvr) normalized, k_rope (B,S,rope) rope applied (shared)."""
    kvr, rope_d = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = x @ p["wkv_a"]
    c_kv = nn.rmsnorm_apply(p["kv_norm"], kv[..., :kvr], eps=cfg.norm_eps)
    k_rope = apply_rope(kv[..., kvr:], positions, theta=cfg.rope_theta)
    return _cb(c_kv), _cb(k_rope)


def mla_apply(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Full-sequence MLA (train / prefill), expanded (non-absorbed) form."""
    b, s, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_kv_compressed(p, cfg, x, positions)
    kvb = (c_kv @ p["wkv_b"]).reshape(b, s, h, nope + vd)
    k_nope, v = kvb[..., :nope], kvb[..., nope:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, s, h, rope_d))],
                        axis=-1)
    scale = 1.0 / math.sqrt(nope + rope_d)
    out = full_attention(cfg, q, k, v, scale=scale)
    return out.reshape(b, s, h * vd) @ p["wo"]


def mla_make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_prefill(p, cfg: ModelConfig, x, positions, cache: dict):
    y = mla_apply(p, cfg, x, positions)
    c_kv, k_rope = _mla_kv_compressed(p, cfg, x, positions)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, 0, 0)),
    }
    return y, cache


def mla_decode(p, cfg: ModelConfig, x: jax.Array, pos: jax.Array, cache: dict):
    """Absorbed one-token MLA decode against the compressed cache.

    W_uk is folded into the query (q_c = q_nope·W_uk) and W_uv into the
    output, so attention runs entirely in the kv_lora_rank space.
    """
    b = x.shape[0]
    h = cfg.n_heads
    nope, rope_d, vd, kvr = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                             cfg.v_head_dim, cfg.kv_lora_rank)
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)  # (B,1,H,·)
    c_kv_new, k_rope_new = _mla_kv_compressed(p, cfg, x, positions)
    ck = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    cr = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    wkv_b = p["wkv_b"].reshape(kvr, h, nope + vd)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
    # absorb: q_c (B,H,kvr)
    q_c = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)
    scale = 1.0 / math.sqrt(nope + rope_d)
    scores = (jnp.einsum("bhr,bsr->bhs", q_c, ck, preferred_element_type=jnp.float32)
              + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0], cr,
                           preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(ck.shape[1]) <= pos
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(ck.dtype)
    ctx_c = jnp.einsum("bhs,bsr->bhr", probs, ck)  # (B,H,kvr)
    out = jnp.einsum("bhr,rhv->bhv", ctx_c, w_uv).reshape(b, 1, h * vd)
    return out @ p["wo"], {"c_kv": ck, "k_rope": cr}


# ---------------------------------------------------------------------------
# family dispatch
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    return mla_init(key, cfg, dtype) if cfg.use_mla else gqa_init(key, cfg, dtype)


def attn_apply(p, cfg: ModelConfig, x, positions):
    return mla_apply(p, cfg, x, positions) if cfg.use_mla else gqa_apply(p, cfg, x, positions)


def attn_make_cache(cfg: ModelConfig, batch, max_len, dtype):
    return (mla_make_cache(cfg, batch, max_len, dtype) if cfg.use_mla
            else gqa_make_cache(cfg, batch, max_len, dtype))


def attn_prefill(p, cfg: ModelConfig, x, positions, cache):
    return (mla_prefill(p, cfg, x, positions, cache) if cfg.use_mla
            else gqa_prefill(p, cfg, x, positions, cache))


def attn_decode(p, cfg: ModelConfig, x, pos, cache):
    return (mla_decode(p, cfg, x, pos, cache) if cfg.use_mla
            else gqa_decode(p, cfg, x, pos, cache))
