"""Mixture-of-Experts layer: top-k router + capacity-bounded einsum dispatch.

GShard-style **grouped** dispatch: each batch row is a group with its own
expert capacity C = ceil(S·k/E · capacity_factor), so the dispatch/combine
tensors are (B, S, E, C) — B shards over `data`, E over `model`, and the
tensors stay O(S·k·cf·D) per device regardless of global token count.

This einsum dispatch is the *baseline* (paper-faithful GShard); the
scatter-based ``moe_sharded`` path (see moe_sharded.py) removes the
dispatch-einsum FLOP overhead and is the §Perf hillclimb implementation.

Dropped tokens (beyond per-expert capacity) fall through on the residual
path. Aux losses: Switch load-balance (top-1 occupancy × mean prob) and a
router z-loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common import nn
from repro.configs.base import ModelConfig
from repro.models.ffn import ffn_init, ffn_apply


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)
    p = {
        "router": nn.trunc_normal(ks[0], (d, e), std, jnp.float32),  # router kept f32
        "w_up": nn.trunc_normal(ks[1], (e, d, f), std, dtype),
        "w_gate": nn.trunc_normal(ks[2], (e, d, f), std, dtype),
        "w_down": nn.trunc_normal(ks[3], (e, f, d), 1.0 / math.sqrt(f), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = ffn_init(ks[4], d, cfg.moe_d_ff * cfg.n_shared_experts,
                               cfg.ffn_act, dtype)
    return p


def router_topk(logits: jax.Array, k: int):
    """logits (..., E) -> (weights (..., k), idx (..., k), probs); renormalized."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    return top_p, top_i, probs


def group_capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(tokens_per_group * cfg.n_experts_per_tok / cfg.n_experts
                      * cfg.capacity_factor))
    return max(1, -(-c // 4) * 4) if c > 4 else max(1, c)


def routing_tensors(top_w, top_i, keep_dtype, e: int, cap: int):
    """Build grouped dispatch/combine. top_w/top_i: (B, S, k).

    Returns dispatch (B,S,E,C) in keep_dtype, combine (B,S,E,C) f32-cast,
    keep mask (B,S,k)."""
    b, s, k = top_i.shape
    pos_list, keep_list = [], []
    counts = jnp.zeros((b, e), jnp.int32)
    for j in range(k):  # priority order: choice 0 wins capacity ties
        onehot_j = jax.nn.one_hot(top_i[:, :, j], e, dtype=jnp.int32)  # (B,S,E)
        pos_j = jnp.cumsum(onehot_j, axis=1) - 1 + counts[:, None, :]
        counts = counts + jnp.sum(onehot_j, axis=1)
        pos_in_e = jnp.sum(pos_j * onehot_j, axis=-1)  # (B,S)
        keep_list.append(pos_in_e < cap)
        pos_list.append(pos_in_e)
    pos = jnp.stack(pos_list, -1)  # (B,S,k)
    keep = jnp.stack(keep_list, -1)
    e_onehot = jax.nn.one_hot(top_i, e, dtype=keep_dtype)  # (B,S,k,E)
    c_onehot = jax.nn.one_hot(pos, cap, dtype=keep_dtype)  # (B,S,k,C)
    kw = top_w.astype(keep_dtype) * keep.astype(keep_dtype)
    combine = jnp.einsum("bsk,bske,bskc->bsec", kw, e_onehot, c_onehot)
    dispatch = jnp.einsum("bske,bskc->bsec",
                          e_onehot * keep.astype(keep_dtype)[..., None], c_onehot)
    return dispatch, combine, keep


def experts_ffn(p, cfg: ModelConfig, expert_in: jax.Array) -> jax.Array:
    """(..., E, C, D) -> (..., E, C, D) through each expert's gated FFN."""
    up = jnp.einsum("...ecd,edf->...ecf", expert_in, p["w_up"])
    gate = nn.act_fn(cfg.ffn_act)(jnp.einsum("...ecd,edf->...ecf", expert_in,
                                             p["w_gate"]))
    return jnp.einsum("...ecf,efd->...ecd", gate * up, p["w_down"])


def aux_losses(probs, top_i, keep) -> dict:
    """probs (B,S,E), top_i (B,S,k), keep (B,S,k)."""
    e = probs.shape[-1]
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    assign = jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32)
    ce = jnp.mean(assign, axis=(0, 1))
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(
        jnp.log(jnp.maximum(probs, 1e-20)), axis=-1)))
    return {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
            "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}


def moe_apply(p, cfg: ModelConfig, x: jax.Array):
    """x (B, S, D) -> (y (B, S, D), aux dict). Grouped GShard dispatch.

    With ``moe_group_tokens`` set, each batch row splits into sequence
    sub-groups of that many tokens: capacity C scales with the group size,
    so the dispatch tensors and the dispatch-einsum FLOPs shrink linearly
    (at the cost of slightly higher drop variance; bump capacity_factor)."""
    b0, s0, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    g = cfg.moe_group_tokens
    if g and g < s0 and s0 % g == 0:
        x = x.reshape(b0 * (s0 // g), g, d)
    from repro.models.sharding import constrain_batch
    x = constrain_batch(x)
    b, s, _ = x.shape

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    top_w, top_i, probs = router_topk(logits, k)
    cap = group_capacity(s, cfg)
    dispatch, combine, keep = routing_tensors(top_w, top_i, x.dtype, e, cap)

    expert_in = constrain_batch(jnp.einsum("bsec,bsd->becd", dispatch, x))
    expert_out = constrain_batch(experts_ffn(p, cfg, expert_in))
    y = constrain_batch(jnp.einsum("bsec,becd->bsd", combine, expert_out))

    y = y.reshape(b0, s0, d)
    if cfg.n_shared_experts:
        y = y + ffn_apply(p["shared"], x.reshape(b0, s0, d), cfg.ffn_act)

    return y, aux_losses(probs, top_i, keep)
