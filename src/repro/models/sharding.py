"""Partition-spec rules: DP / TP / EP / FSDP / SP for every arch family.

Layout on the production mesh (DESIGN.md §5):
  * batch dims            -> data axes ("data", or ("pod","data") multi-pod)
  * attention heads / ffn -> "model" (Megatron column/row parallel)
  * MoE experts           -> "model" (expert parallel; all-to-all dispatch)
  * FSDP (giants only)    -> the non-model dim of each large weight also
                             shards over "data" (ZeRO-3; XLA all-gathers
                             per layer inside the scan)
  * Mamba2 heads          -> "model" (the z/x/dt streams; B,C replicated)
  * decode KV caches      -> sequence dim over "model" (context parallelism)

``param_specs`` maps a params pytree (from jax.eval_shape) to PartitionSpec
by path pattern; stacked layer dims (leading L) are detected by rank.
"""
from __future__ import annotations

import re

import jax
from jax.sharding import PartitionSpec as P

from repro.common import pytree
from repro.configs.base import ModelConfig

MODEL = "model"


def _rule_table(fsdp_axis, moe_shard_ffn_dim: bool = False):
    """Ordered (regex, base-spec) table. First match wins. ``F`` marks the
    FSDP axis slot (None when FSDP is off). ``moe_shard_ffn_dim`` places the
    experts' second shard axis on the FFN dim instead of d_model — keeps the
    up/gate contraction dim unsharded (the weight-stationary serving layout)."""
    F = fsdp_axis
    if moe_shard_ffn_dim:
        moe_rules = [
            (r"moe/router$", (F, None)),
            (r"moe/shared/w_(up|gate)$", (F, MODEL)),
            (r"moe/shared/w_down$", (MODEL, F)),
            (r"moe/w_(up|gate)$", (MODEL, None, F)),
            (r"moe/w_down$", (MODEL, F, None)),
        ]
    else:
        moe_rules = [
            (r"moe/router$", (F, None)),
            (r"moe/shared/w_(up|gate)$", (F, MODEL)),
            (r"moe/shared/w_down$", (MODEL, F)),
            (r"moe/w_(up|gate)$", (MODEL, F, None)),
            (r"moe/w_down$", (MODEL, None, F)),
        ]
    return moe_rules + [
        # embedding / head
        (r"embed/table$", (MODEL, None)),
        (r"lm_head/w$", (None, MODEL)),
        (r"patch_proj/w$", (None, None)),
        (r"patch_proj/b$", (None,)),
        # MLA
        (r"attn/wq_a$", (F, MODEL)),
        (r"attn/wq_b$", (None, MODEL)),
        (r"attn/wkv_a$", (MODEL, None)),   # row-parallel; 576-wide output
        (r"attn/wkv_b$", (None, MODEL)),
        (r"attn/(q_norm|kv_norm|k_norm)/scale$", (None,)),
        # GQA
        (r"attn/w[qkv]$", (F, MODEL)),
        (r"attn/wo$", (MODEL, F)),
        # dense FFN
        (r"ffn/w_(up|gate)$", (F, MODEL)),
        (r"ffn/w_down$", (MODEL, F)),
        # Mamba2 (heads on model; B/C replicated)
        (r"mamba/in_z$", (F, MODEL)),
        (r"mamba/in_x$", (F, MODEL)),
        (r"mamba/in_bc$", (F, None)),
        (r"mamba/in_dt$", (F, None)),
        (r"mamba/conv_x_w$", (None, MODEL)),
        (r"mamba/conv_x_b$", (MODEL,)),
        (r"mamba/conv_bc_(w|b)$", None),  # replicate
        (r"mamba/(A_log|D|dt_bias)$", (None,)),
        (r"mamba/norm/scale$", (MODEL,)),
        (r"mamba/out_proj$", (MODEL, F)),
        # mtp glue
        (r"mtp/proj/w$", (None, None)),
        (r"mtp/proj/b$", (None,)),
        # norms (catch-all)
        (r"(norm1|norm2|final_norm|norm_h|norm_e|norm_f|norm)/(scale|bias)$", None),
    ]


def spec_for_path(path: str, ndim: int, fsdp_axis=None,
                  moe_shard_ffn_dim: bool = False) -> P:
    for pat, base in _rule_table(fsdp_axis, moe_shard_ffn_dim):
        if re.search(pat, path):
            if base is None:
                return P()
            base = tuple(base)
            if ndim == len(base) + 1:  # stacked layer dim
                return P(None, *base)
            if ndim == len(base):
                return P(*base)
            # rank mismatch (e.g. scalar leaf) — replicate
            return P()
    return P()


def param_specs(cfg: ModelConfig, params_shape, *, fsdp: bool = False,
                fsdp_axis="data", moe_shard_ffn_dim: bool = False):
    """PartitionSpec pytree matching ``params_shape`` (a ShapeDtypeStruct tree)."""
    F = fsdp_axis if fsdp else None
    return pytree.tree_map_with_path(
        lambda path, leaf: spec_for_path(path, len(leaf.shape), F,
                                         moe_shard_ffn_dim), params_shape)


def opt_state_specs(param_spec_tree, opt_state_shape, *, model_size: int = 16):
    """Optimizer-state specs: float moments inherit their parameter's spec;
    int8 moments ({q, scale} blocks, shape (n_blocks, 256)/(n_blocks, 1))
    shard their block dim over `model` when divisible; the Adafactor row/col
    stats drop the reduced dim; scalars replicate."""
    flat_p = {path: spec for path, spec in pytree.tree_paths(param_spec_tree)}

    def one(path: str, leaf):
        # paths look like  m/<param_path>, v/<param_path>[/vr|/vc|/v|/q|/scale]
        parts = path.split("/")
        if parts[0] in ("m", "v"):
            tail = parts[-1]
            core = "/".join(parts[1:-1] if tail in ("vr", "vc", "v", "q", "scale")
                            else parts[1:])
            base = flat_p.get(core) or flat_p.get("/".join(parts[1:]))
            if base is None:
                return P()
            bs = tuple(base)
            if tail == "vr":  # reduced over last dim
                return P(*bs[:-1]) if len(bs) == len(leaf.shape) + 1 else P()
            if tail == "vc":  # reduced over second-to-last dim
                return P(*(bs[:-2] + bs[-1:])) if len(bs) == len(leaf.shape) + 1 else P()
            if tail == "scale" and len(bs) == len(leaf.shape):
                return P(*bs[:-1], None)  # per-row scale: (..., 1)
            if len(bs) == len(leaf.shape):
                return P(*bs)
            return P()
        return P()

    return pytree.tree_map_with_path(one, opt_state_shape)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, batch_shape: dict, data_axes=("data",)) -> dict:
    """Inputs: batch dim over the data axes, everything else unsharded."""
    d = tuple(data_axes)
    ax = d if len(d) > 1 else d[0]
    return {k: P(ax, *([None] * (len(v.shape) - 1)))
            for k, v in batch_shape.items()}


def cache_specs(cfg: ModelConfig, cache_shape, data_axes=("data",),
                *, shard_batch: bool = True):
    """Decode caches: (L, B, S, heads, hd) — B over data, S over model for
    attention caches (context parallelism); mamba states shard heads/channels
    over model. With batch=1 (long_500k) ``shard_batch=False`` keeps B whole."""
    d = tuple(data_axes)
    bax = (d if len(d) > 1 else d[0]) if shard_batch else None

    def one(path: str, leaf):
        nd = len(leaf.shape)
        if "conv_x" in path:  # (L, B, w-1, di)
            return P(None, bax, None, MODEL)
        if "conv_bc" in path:  # (L, B, w-1, 2gn)
            return P(None, bax, None, None)
        if path.endswith("state"):  # (L, B, H, N, P)
            return P(None, bax, MODEL, None, None)
        if "c_kv" in path or "k_rope" in path:  # MLA: (L, B, S, r)
            return P(None, bax, MODEL, None)
        if nd == 5:  # GQA k/v: (L, B, S, KV, hd)
            return P(None, bax, MODEL, None, None)
        return P()

    return pytree.tree_map_with_path(one, cache_shape)


def activation_spec(data_axes=("data",), *, seq_shard: bool = False) -> P:
    """Residual-stream (B, S, D) constraint for the layer-scan carry."""
    d = tuple(data_axes)
    bax = d if len(d) > 1 else d[0]
    return P(bax, MODEL if seq_shard else None, None)


# ---------------------------------------------------------------------------
# activation-sharding context: batch-dim constraints inside the model
# ---------------------------------------------------------------------------
# XLA's sharding propagation can lose the batch dim through the
# reshape/transpose-heavy attention and MoE interiors and silently REPLICATE
# the batch across `data` (observed: 16x redundant attention compute on
# deepseek prefill). The fix is a hard constraint on the batch dim only,
# with every other dim left UNCONSTRAINED so head/ffn sharding stays free.

import contextlib as _contextlib
import contextvars as _contextvars

_ACT_CTX = _contextvars.ContextVar("repro_act_ctx", default=None)


@_contextlib.contextmanager
def act_axes(batch_axis, model_axis: str = MODEL, mesh=None):
    """Enable batch-dim constraints (+ mesh-aware layers) during tracing."""
    tok = _ACT_CTX.set({"batch": batch_axis, "model": model_axis, "mesh": mesh}
                       if batch_axis is not None else None)
    try:
        yield
    finally:
        _ACT_CTX.reset(tok)


def with_act_axes(fn, batch_axis, model_axis: str = MODEL, mesh=None):
    def wrapped(*a, **kw):
        with act_axes(batch_axis, model_axis, mesh):
            return fn(*a, **kw)

    return wrapped


def act_ctx():
    return _ACT_CTX.get()


def constrain_batch(x, batch_dim: int = 0):
    """Pin x's batch dim to the data axes; other dims unconstrained."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    U = P.UNCONSTRAINED
    dims = [U] * x.ndim
    dims[batch_dim] = ctx["batch"]
    return jax.lax.with_sharding_constraint(x, P(*dims))
