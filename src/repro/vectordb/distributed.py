"""Mesh-sharded MHQ search (beyond-paper: the technique as a distributed,
first-class feature — DESIGN.md §2 'Distribution').

DB rows are sharded over the mesh's data axes; each device scores its local
shard and keeps a local top-k; the global top-k merges the per-device
candidates with one all-gather of O(devices · k) elements — independent of
DB size, so the collective term stays negligible (see EXPERIMENTS.md
§Roofline boomhq rows).

Two families of sharded search live here:

  * the EXACT scans (``sharded_masked_scan*``, ``sharded_batch_topk``) mask
    + local-top-k precomputed dense scores per shard — optimal while the
    dense GEMM is cheap relative to the table;
  * the PLAN-DRIVEN path (``ShardedIVF`` + ``sharded_ivf_topk``): each
    shard holds its slice's own IVF index and probes it with the learned
    plan's legalized knobs (nprobe / max_scan / k_i split across shards),
    reranking the candidate union with the fused candidate-local
    gather+score kernel INSIDE the shard — so the learned knobs stay
    operative at the scale tier where the dense GEMM becomes the wall.

Implemented with ``shard_map`` so the collective schedule is explicit; a
logical single-device variant keeps identical merge semantics for tests
and mesh-less serving.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.common import compat

from repro.vectordb.predicates import eval_mask
from repro.vectordb.table import similarity

NEG = -1e30


def sharded_masked_scan(mesh: Mesh, data_axes=("data",), *, k: int, n_vec: int,
                        metric: str = "dot"):
    """Build a jit'd sharded filtered top-k: rows sharded over ``data_axes``.

    Returned fn signature:
      fn(vectors: tuple[(n, d_i)], scalars (n, M), pred, qs tuple[(d_i,)], w (N,))
        -> (ids (k,), scores (k,))
    Row ids are global.
    """
    axes = data_axes if isinstance(data_axes, tuple) else (data_axes,)

    def local(vectors, scalars, pred, qs, w, row0):
        n_local = scalars.shape[0]
        total = jnp.zeros((n_local,), jnp.float32)
        for i in range(n_vec):
            total = total + w[i] * similarity(qs[i], vectors[i], metric)
        mask = eval_mask(pred, scalars)
        masked = jnp.where(mask, total, NEG)
        kk = min(k, n_local)
        s, idx = jax.lax.top_k(masked, kk)
        gids = row0 + idx  # globalize
        # gather candidates from every shard, then merge
        s_all = jax.lax.all_gather(s, axes, tiled=True)
        g_all = jax.lax.all_gather(gids, axes, tiled=True)
        ms, mi = jax.lax.top_k(s_all, k)
        out_ids = jnp.where(ms > NEG / 2, g_all[mi], -1)
        return out_ids, ms

    vec_specs = tuple(P(axes, None) for _ in range(n_vec))
    fn = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(vec_specs, P(axes, None), P(), tuple(P() for _ in range(n_vec)), P(), P(axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def run(vectors, scalars, pred, qs, w):
        n = scalars.shape[0]
        n_dev = 1
        for a in axes:
            n_dev *= mesh.shape[a]
        assert n % n_dev == 0, (n, n_dev)
        row0 = jnp.arange(n_dev, dtype=jnp.int32) * (n // n_dev)
        return fn(tuple(vectors), scalars, pred, tuple(qs), w, row0)

    return jax.jit(run)


def sharded_masked_scan_batched(mesh: Mesh, data_axes=("data",), *, k: int,
                                n_vec: int, metric: str = "dot",
                                int8: bool = False):
    """Beyond-paper optimized distributed scan: QUERY BATCHING (one pass over
    the DB shard serves Q queries — turns the memory-bound matvec into an
    MXU matmul) and optional INT8 DB storage (per-row absmax scales; 4× less
    HBM traffic on the scan — the Pallas int8_scan kernel's layout).

    Returned fn:
      fn(vectors, [scales,] scalars, preds (stacked Q), qs tuple[(Q, d_i)],
         w (Q, N)) -> (ids (Q, k), scores (Q, k))
    """
    axes = data_axes if isinstance(data_axes, tuple) else (data_axes,)

    def local(vectors, scales, scalars, preds, qs, w, row0):
        n_local = scalars.shape[0]
        q_batch = qs[0].shape[0]
        total = jnp.zeros((q_batch, n_local), jnp.float32)
        for i in range(n_vec):
            v = vectors[i]
            if int8:
                # true int8 path: quantize the queries too and run the dot
                # on the MXU's int8×int8→int32 — the DB is read as int8
                qsc = jnp.maximum(jnp.max(jnp.abs(qs[i]), axis=-1), 1e-12) / 127.0
                q8 = jnp.clip(jnp.round(qs[i] / qsc[:, None]), -127, 127
                              ).astype(jnp.int8)
                acc = jnp.einsum("nd,qd->qn", v, q8,
                                 preferred_element_type=jnp.int32)
                s = acc.astype(jnp.float32) * scales[i][None, :] * qsc[:, None]
            else:
                s = jnp.einsum("nd,qd->qn", v, qs[i])
                if metric == "l2":
                    s = 2.0 * s - jnp.sum(v * v, axis=-1)[None] \
                        - jnp.sum(qs[i] * qs[i], axis=-1)[:, None]
            total = total + w[:, i][:, None] * s
        # per-query DNF predicate masks: preds fields stacked over Q, the
        # shared OR-over-clauses evaluator vmapped over the query axis
        mask = jax.vmap(lambda p: eval_mask(p, scalars))(preds)  # (Q, n_local)
        masked = jnp.where(mask, total, NEG)
        kk = min(k, n_local)
        s_loc, idx = jax.lax.top_k(masked, kk)  # (Q, kk)
        gids = row0 + idx
        s_all = jax.lax.all_gather(s_loc, axes, axis=1, tiled=True)
        g_all = jax.lax.all_gather(gids, axes, axis=1, tiled=True)
        ms, mi = jax.lax.top_k(s_all, k)
        out_ids = jnp.where(ms > NEG / 2, jnp.take_along_axis(g_all, mi, 1), -1)
        return out_ids, ms

    vec_specs = tuple(P(axes, None) for _ in range(n_vec))
    scale_specs = tuple(P(axes) for _ in range(n_vec)) if int8 else P()
    fn = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(vec_specs, scale_specs, P(axes, None), P(),
                  tuple(P() for _ in range(n_vec)), P(), P(axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def run(vectors, scales, scalars, preds, qs, w):
        n = scalars.shape[0]
        n_dev = 1
        for a in axes:
            n_dev *= mesh.shape[a]
        assert n % n_dev == 0, (n, n_dev)
        row0 = jnp.arange(n_dev, dtype=jnp.int32) * (n // n_dev)
        scales = tuple(scales) if int8 else jnp.zeros(())
        return fn(tuple(vectors), scales, scalars, preds, tuple(qs), w, row0)

    return jax.jit(run)


# ---------------------------------------------------------------------------
# cross-shard batched serving entry point (serve/batch.py fans out here)
# ---------------------------------------------------------------------------
#
# The batched serving layer already computes dense per-column score matrices
# for the whole batch (serve.batch.compute_batch_scores); the cross-shard
# path must not re-score. Both functions below therefore take the WEIGHTED
# (Q, n) score matrix as input and only do per-shard mask + local top-k +
# one O(shards · k) merge:
#
#   * ``sharded_batch_topk`` builds the shard_map version: rows (score
#     columns + scalar rows) are sharded over the mesh's data axes, each
#     device reads only its local (Q, n_local) block of the dense matrix,
#     and the merge is one all-gather of O(shards · k) candidates.
#   * ``sharded_topk_ref`` is the single-device logical-shard reference
#     with IDENTICAL merge semantics (same local top-k widths, same shard
#     concatenation order, same tie-breaking) — the executor uses it when
#     no multi-device mesh is bound, and tests use it as the shard_map
#     oracle.


def _merge_shard_candidates(s_all, g_all, *, k):
    """Top-k over the concatenated per-shard candidates (Q, S·kk); output
    padded to width k with id -1 / score NEG when fewer candidates exist."""
    kf = min(k, s_all.shape[1])
    ms, mi = jax.lax.top_k(s_all, kf)
    ids = jnp.where(ms > NEG / 2, jnp.take_along_axis(g_all, mi, 1), -1)
    if kf < k:
        pad = ((0, 0), (0, k - kf))
        ids = jnp.pad(ids, pad, constant_values=-1)
        ms = jnp.pad(ms, pad, constant_values=NEG)
    return ids, ms


@partial(jax.jit, static_argnames=("k", "n_shards"))
def sharded_topk_ref(w_scores, mask, *, k, n_shards):
    """Logical-shard filtered top-k over precomputed weighted scores.

    ``w_scores``/``mask``: (Q, n). Rows split into ``n_shards`` contiguous
    shards (right-padded with non-qualifying rows when n % n_shards != 0);
    each shard keeps a local top-min(k, shard_len), then one merge over the
    (Q, shards·kk) candidates. Runs on a single device — the semantics (and
    tie-breaking) match ``sharded_batch_topk`` exactly.
    """
    q, n = w_scores.shape
    per = -(-n // n_shards)  # ceil-div shard length
    masked = jnp.where(mask, w_scores, NEG)
    masked = jnp.pad(masked, ((0, 0), (0, per * n_shards - n)),
                     constant_values=NEG)
    local = masked.reshape(q, n_shards, per)
    kk = min(k, per)
    s_loc, idx = jax.lax.top_k(local, kk)  # (Q, S, kk)
    gids = jnp.arange(n_shards, dtype=jnp.int32)[None, :, None] * per + idx
    return _merge_shard_candidates(s_loc.reshape(q, n_shards * kk),
                                   gids.reshape(q, n_shards * kk), k=k)


def sharded_batch_topk(mesh: Mesh, data_axes=("data",), *, k: int):
    """Build the jit'd cross-shard batched filtered top-k.

    Returned fn signature:
      fn(w_scores (Q, n), scalars (n, M), preds (stacked over Q))
        -> (ids (Q, k), scores (Q, k))

    ``w_scores`` is the whole-batch weighted score matrix assembled from the
    serving layer's per-column GEMMs; the shard_map in_spec slices its row
    axis so each device reads only its local (Q, n_local) block — the scan
    reuses the dense matrices instead of re-scoring, and the collective is
    one all-gather of O(shards · k) candidates per query.
    """
    axes = data_axes if isinstance(data_axes, tuple) else (data_axes,)

    def local(w_scores, scalars, preds, row0):
        n_local = scalars.shape[0]
        mask = jax.vmap(lambda p: eval_mask(p, scalars))(preds)  # (Q, n_local)
        masked = jnp.where(mask, w_scores, NEG)
        kk = min(k, n_local)
        s_loc, idx = jax.lax.top_k(masked, kk)  # (Q, kk)
        gids = row0 + idx  # globalize
        s_all = jax.lax.all_gather(s_loc, axes, axis=1, tiled=True)
        g_all = jax.lax.all_gather(gids, axes, axis=1, tiled=True)
        return _merge_shard_candidates(s_all, g_all, k=k)

    fn = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, axes), P(axes, None), P(), P(axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def run(w_scores, scalars, preds):
        n = scalars.shape[0]
        n_dev = 1
        for a in axes:
            n_dev *= mesh.shape[a]
        assert n % n_dev == 0, (n, n_dev)
        row0 = jnp.arange(n_dev, dtype=jnp.int32) * (n // n_dev)
        return fn(w_scores, scalars, preds, row0)

    return jax.jit(run)


# ---------------------------------------------------------------------------
# per-shard IVF indexing + plan-driven probing (the learned knobs at scale)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedIVF:
    """Per-shard IVF indexes of ONE vector column, stacked on a leading
    shard axis so a single structure serves both execution modes: under
    ``shard_map`` axis 0 shards across the mesh's data axes (each device
    reads only its own shard's index), and the logical single-device path
    vmaps over it with identical semantics.

    Rows are the table's contiguous ``shard_len``-sized slices.
    ``sorted_rows`` holds LOCAL row ids (0 .. shard_rows-1); callers
    globalize with ``shard * shard_len``. The last shard of a non-divisible
    table is short: its ``sorted_rows`` tail is zero-padded, and because
    ``offsets`` only ever counts the shard's real rows, padded slots can
    never be selected as probe candidates.
    """

    centroids: jax.Array    # (S, C, d)
    sorted_rows: jax.Array  # (S, shard_len) i32 local row ids, zero-padded
    offsets: jax.Array      # (S, C+1) i32

    metric: str

    def tree_flatten(self):
        return (self.centroids, self.sorted_rows, self.offsets), self.metric

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, metric=aux)

    @property
    def n_shards(self) -> int:
        return int(self.centroids.shape[0])

    @property
    def n_clusters(self) -> int:
        return int(self.centroids.shape[1])

    @property
    def shard_len(self) -> int:
        return int(self.sorted_rows.shape[1])

    def local_index(self, s: int):
        """Shard ``s``'s index as a plain ``ivf.IVFIndex`` (tests, probes)."""
        from repro.vectordb import ivf as _ivf

        return _ivf.IVFIndex(self.centroids[s], self.sorted_rows[s],
                             self.offsets[s], self.metric)


def build_sharded_ivf(vectors: jax.Array, n_shards: int, *,
                      n_clusters: int, seed: int = 0, metric: str = "dot",
                      base_index=None) -> ShardedIVF:
    """Build one per-shard IVF index per contiguous table slice.

    ``n_clusters`` is the PER-SHARD cluster count; every shard gets the
    same count (clamped to the shortest shard) so the stacked arrays stay
    static-shape. With ``n_shards == 1`` and a ``base_index`` the existing
    single-device index is reused verbatim — the degenerate configuration
    is then bit-for-bit the single-device candidate-local path."""
    from repro.vectordb import ivf as _ivf

    n = int(vectors.shape[0])
    s = max(1, int(n_shards))
    if s == 1 and base_index is not None:
        return ShardedIVF(base_index.centroids[None],
                          base_index.sorted_rows[None],
                          base_index.offsets[None], base_index.metric)
    shard_len = -(-n // s)
    n_last = n - (s - 1) * shard_len
    c = max(1, min(int(n_clusters), n_last))
    cents, rows, offs = [], [], []
    for i in range(s):
        v = vectors[i * shard_len: min((i + 1) * shard_len, n)]
        idx = _ivf.build(v, c, seed=seed + 7919 * i, metric=metric)
        r = idx.sorted_rows
        if int(r.shape[0]) < shard_len:
            r = jnp.pad(r, (0, shard_len - int(r.shape[0])))
        cents.append(idx.centroids)
        rows.append(r)
        offs.append(idx.offsets)
    return ShardedIVF(jnp.stack(cents), jnp.stack(rows), jnp.stack(offs),
                      metric)


def sharded_ivf_topk(n_shards: int, mesh: Mesh | None = None,
                     data_axes=("data",), *, subs: tuple, k: int,
                     n_cols: int, metric: str, pad_total: int):
    """Build the jit'd plan-driven per-shard probing search.

    ``subs``: one entry per probed column, carrying the SHARD-LEGALIZED
    static plan params ``(pos, k_i, ks, nprobe, max_scan)`` — ``pos``
    indexes the column tuples passed at call time (the chunk's weighted
    columns), ``ks`` the bucketed local top-k width, and
    ``nprobe``/``max_scan`` the per-shard probing budget
    (``executor.legalize_for_shard``). Each shard probes its own IVF index
    (``ivf.search_local_batch``), reranks the per-shard candidate union by
    the full weighted score with the fused candidate-local gather+score
    kernel — the PR 4 path, now running INSIDE each shard — and keeps a
    local top-k; the global result is one O(shards · k) candidate merge.

    Returned fn signature:
      fn(cent_t, rows_t, offs_t  — per-probed-column ``ShardedIVF`` arrays,
         vectors tuple[(n, d_i)], scalars (n, M), pred_b (stacked over B),
         qv_t tuple[(B, d_i)], w_b (B, n_cols))
        -> (ids (B, k), scores (B, k), fill (B, S), boundary (B, S))

    ``fill[:, s]`` is how many candidates shard ``s`` contributed per query
    and ``boundary[:, s]`` is shard ``s``'s weakest VALID kept local score
    (its k-th when the local top-k filled; NEG when it kept nothing) — the
    executor's per-shard escalation reads both: a shard whose boundary
    reaches the merged k-th score had its ENTIRE contribution land at or
    above the global cutoff, so its probing budget (local truncation or a
    starved probe), not the data, was the binding constraint and rows it
    never surfaced may belong in the global top-k — the loss mode the old
    merged-underfill trigger could never see. Without a mesh the shard
    axis is vmapped on one device (a non-divisible table is zero-padded;
    padded rows are unreachable by construction); with a mesh the identical
    body runs under ``shard_map`` and the merge is one all-gather, in the
    same shard order.
    """
    from repro.core.executor import rrf_extras
    from repro.kernels.gather_score import gather_score_topk
    from repro.vectordb import ivf as _ivf

    s = max(1, int(n_shards))
    axes = data_axes if isinstance(data_axes, tuple) else (data_axes,)

    def body(cent_t, rows_t, offs_t, vecs_t, scal, row0, pred_b, qv_t, w_b):
        """One shard: probe each planned column, rerank the union, local
        top-k. All ids are shard-local until the final globalization."""
        wide, cands = [], []
        for j, (pos, k_i, ks, np_s, ms_s) in enumerate(subs):
            idx = _ivf.IVFIndex(cent_t[j], rows_t[j], offs_t[j], metric)
            ids_j, _, _, _ = _ivf.search_local_batch(
                idx, vecs_t[pos], scal, pred_b, qv_t[pos],
                nprobe=np_s, max_scan=ms_s, k=ks)
            wide.append(ids_j)
            cands.append(ids_j[:, :k_i])
        rows_b = jnp.concatenate(cands, axis=1)
        # multi-column unions fill the pad slots with RRF-fused extras from
        # the wide probe tails — the SAME composition the single-device
        # executors build (`_union_candidates`), so S=1 stays bit-for-bit
        # and every shard recovers rows ranking below top-k_i in all of its
        # per-column lists at zero extra probing cost
        if len(subs) > 1 and pad_total > rows_b.shape[1]:
            extras = rrf_extras(
                tuple(wide), kis=tuple(s[1] for s in subs),
                n_extra=pad_total - rows_b.shape[1])
            rows_b = jnp.concatenate([rows_b, extras], axis=1)
        elif pad_total > rows_b.shape[1]:
            rows_b = jnp.pad(rows_b,
                             ((0, 0), (0, pad_total - rows_b.shape[1])),
                             constant_values=-1)
        ids_l, scores_l, _ = gather_score_topk(
            rows_b.astype(jnp.int32), vecs_t, qv_t, w_b, scal, None,
            k=k, metric=metric)
        fill = jnp.sum(ids_l >= 0, axis=1).astype(jnp.int32)
        ids_g = jnp.where(ids_l >= 0, ids_l + row0, -1)
        # weakest VALID kept local score (scores are sorted descending, so
        # that is slot fill-1, the k-th when the shard kept a full top-k):
        # the boundary the escalation trigger compares against the merged
        # k-th. NEG when the shard contributed nothing.
        last = jnp.maximum(fill - 1, 0)[:, None]
        boundary = jnp.where(
            fill > 0, jnp.take_along_axis(scores_l, last, axis=1)[:, 0],
            jnp.float32(NEG))
        return ids_g, scores_l, fill, boundary

    if mesh is None:
        def run(cent_t, rows_t, offs_t, vectors, scalars, pred_b, qv_t, w_b):
            n = scalars.shape[0]
            shard_len = -(-n // s)
            if s == 1:
                # degenerate configuration: EXACTLY the single-device
                # candidate-local chunk (no vmap, no pad, identity merge)
                ids, sc, fill, bnd = body(
                    tuple(c[0] for c in cent_t), tuple(r[0] for r in rows_t),
                    tuple(o[0] for o in offs_t), vectors, scalars,
                    jnp.asarray(0, jnp.int32), pred_b, qv_t, w_b)
                return ids, sc, fill[:, None], bnd[:, None]
            pad = s * shard_len - n
            if pad:
                vectors = tuple(jnp.pad(v, ((0, pad), (0, 0)))
                                for v in vectors)
                scalars = jnp.pad(scalars, ((0, pad), (0, 0)))
            vecs_sh = tuple(v.reshape(s, shard_len, v.shape[1])
                            for v in vectors)
            scal_sh = scalars.reshape(s, shard_len, scalars.shape[1])
            row0 = jnp.arange(s, dtype=jnp.int32) * shard_len
            ids, sc, fill, bnd = jax.vmap(
                body, in_axes=(0, 0, 0, 0, 0, 0, None, None, None))(
                cent_t, rows_t, offs_t, vecs_sh, scal_sh, row0,
                pred_b, qv_t, w_b)
            b = sc.shape[1]
            # (S, B, k) -> (B, S·k) in shard order — the all_gather layout
            s_all = jnp.swapaxes(sc, 0, 1).reshape(b, s * k)
            g_all = jnp.swapaxes(ids, 0, 1).reshape(b, s * k)
            mi, ms = _merge_shard_candidates(s_all, g_all, k=k)
            return mi, ms, jnp.swapaxes(fill, 0, 1), jnp.swapaxes(bnd, 0, 1)

        return jax.jit(run)

    sub_specs3 = tuple(P(axes, None, None) for _ in subs)
    sub_specs2 = tuple(P(axes, None) for _ in subs)
    vec_specs = tuple(P(axes, None) for _ in range(n_cols))

    def local(cent_t, rows_t, offs_t, vectors, scalars, pred_b, qv_t, w_b,
              row0):
        ids_g, sc, fill, bnd = body(
            tuple(c[0] for c in cent_t), tuple(r[0] for r in rows_t),
            tuple(o[0] for o in offs_t), vectors, scalars, row0[0],
            pred_b, qv_t, w_b)
        s_all = jax.lax.all_gather(sc, axes, axis=1, tiled=True)
        g_all = jax.lax.all_gather(ids_g, axes, axis=1, tiled=True)
        mi, ms = _merge_shard_candidates(s_all, g_all, k=k)
        return mi, ms, fill[None, :], bnd[None, :]

    fn = compat.shard_map(
        local, mesh=mesh,
        in_specs=(sub_specs3, sub_specs2, sub_specs2, vec_specs,
                  P(axes, None), P(), tuple(P() for _ in range(n_cols)),
                  P(), P(axes)),
        out_specs=(P(), P(), P(axes, None), P(axes, None)),
        check_vma=False)

    def run(cent_t, rows_t, offs_t, vectors, scalars, pred_b, qv_t, w_b):
        n = scalars.shape[0]
        assert n % s == 0, (n, s)
        row0 = jnp.arange(s, dtype=jnp.int32) * (n // s)
        mi, ms, fill, bnd = fn(cent_t, rows_t, offs_t, vectors, scalars,
                               pred_b, qv_t, w_b, row0)
        return mi, ms, jnp.swapaxes(fill, 0, 1), jnp.swapaxes(bnd, 0, 1)

    return jax.jit(run)
