"""Mesh-sharded MHQ search (beyond-paper: the technique as a distributed,
first-class feature — DESIGN.md §2 'Distribution').

DB rows are sharded over the mesh's data axes; each device scores its local
shard and keeps a local top-k; the global top-k merges the per-device
candidates with one all-gather of O(devices · k) elements — independent of
DB size, so the collective term stays negligible (see EXPERIMENTS.md
§Roofline boomhq rows).

Implemented with ``shard_map`` so the collective schedule is explicit.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.common import compat

from repro.vectordb.predicates import eval_mask
from repro.vectordb.table import similarity

NEG = -1e30


def sharded_masked_scan(mesh: Mesh, data_axes=("data",), *, k: int, n_vec: int,
                        metric: str = "dot"):
    """Build a jit'd sharded filtered top-k: rows sharded over ``data_axes``.

    Returned fn signature:
      fn(vectors: tuple[(n, d_i)], scalars (n, M), pred, qs tuple[(d_i,)], w (N,))
        -> (ids (k,), scores (k,))
    Row ids are global.
    """
    axes = data_axes if isinstance(data_axes, tuple) else (data_axes,)

    def local(vectors, scalars, pred, qs, w, row0):
        n_local = scalars.shape[0]
        total = jnp.zeros((n_local,), jnp.float32)
        for i in range(n_vec):
            total = total + w[i] * similarity(qs[i], vectors[i], metric)
        mask = eval_mask(pred, scalars)
        masked = jnp.where(mask, total, NEG)
        kk = min(k, n_local)
        s, idx = jax.lax.top_k(masked, kk)
        gids = row0 + idx  # globalize
        # gather candidates from every shard, then merge
        s_all = jax.lax.all_gather(s, axes, tiled=True)
        g_all = jax.lax.all_gather(gids, axes, tiled=True)
        ms, mi = jax.lax.top_k(s_all, k)
        out_ids = jnp.where(ms > NEG / 2, g_all[mi], -1)
        return out_ids, ms

    vec_specs = tuple(P(axes, None) for _ in range(n_vec))
    fn = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(vec_specs, P(axes, None), P(), tuple(P() for _ in range(n_vec)), P(), P(axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def run(vectors, scalars, pred, qs, w):
        n = scalars.shape[0]
        n_dev = 1
        for a in axes:
            n_dev *= mesh.shape[a]
        assert n % n_dev == 0, (n, n_dev)
        row0 = jnp.arange(n_dev, dtype=jnp.int32) * (n // n_dev)
        return fn(tuple(vectors), scalars, pred, tuple(qs), w, row0)

    return jax.jit(run)


def sharded_masked_scan_batched(mesh: Mesh, data_axes=("data",), *, k: int,
                                n_vec: int, metric: str = "dot",
                                int8: bool = False):
    """Beyond-paper optimized distributed scan: QUERY BATCHING (one pass over
    the DB shard serves Q queries — turns the memory-bound matvec into an
    MXU matmul) and optional INT8 DB storage (per-row absmax scales; 4× less
    HBM traffic on the scan — the Pallas int8_scan kernel's layout).

    Returned fn:
      fn(vectors, [scales,] scalars, preds (stacked Q), qs tuple[(Q, d_i)],
         w (Q, N)) -> (ids (Q, k), scores (Q, k))
    """
    axes = data_axes if isinstance(data_axes, tuple) else (data_axes,)

    def local(vectors, scales, scalars, preds, qs, w, row0):
        n_local = scalars.shape[0]
        q_batch = qs[0].shape[0]
        total = jnp.zeros((q_batch, n_local), jnp.float32)
        for i in range(n_vec):
            v = vectors[i]
            if int8:
                # true int8 path: quantize the queries too and run the dot
                # on the MXU's int8×int8→int32 — the DB is read as int8
                qsc = jnp.maximum(jnp.max(jnp.abs(qs[i]), axis=-1), 1e-12) / 127.0
                q8 = jnp.clip(jnp.round(qs[i] / qsc[:, None]), -127, 127
                              ).astype(jnp.int8)
                acc = jnp.einsum("nd,qd->qn", v, q8,
                                 preferred_element_type=jnp.int32)
                s = acc.astype(jnp.float32) * scales[i][None, :] * qsc[:, None]
            else:
                s = jnp.einsum("nd,qd->qn", v, qs[i])
                if metric == "l2":
                    s = 2.0 * s - jnp.sum(v * v, axis=-1)[None] \
                        - jnp.sum(qs[i] * qs[i], axis=-1)[:, None]
            total = total + w[:, i][:, None] * s
        # per-query DNF predicate masks: preds fields stacked over Q, the
        # shared OR-over-clauses evaluator vmapped over the query axis
        mask = jax.vmap(lambda p: eval_mask(p, scalars))(preds)  # (Q, n_local)
        masked = jnp.where(mask, total, NEG)
        kk = min(k, n_local)
        s_loc, idx = jax.lax.top_k(masked, kk)  # (Q, kk)
        gids = row0 + idx
        s_all = jax.lax.all_gather(s_loc, axes, axis=1, tiled=True)
        g_all = jax.lax.all_gather(gids, axes, axis=1, tiled=True)
        ms, mi = jax.lax.top_k(s_all, k)
        out_ids = jnp.where(ms > NEG / 2, jnp.take_along_axis(g_all, mi, 1), -1)
        return out_ids, ms

    vec_specs = tuple(P(axes, None) for _ in range(n_vec))
    scale_specs = tuple(P(axes) for _ in range(n_vec)) if int8 else P()
    fn = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(vec_specs, scale_specs, P(axes, None), P(),
                  tuple(P() for _ in range(n_vec)), P(), P(axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def run(vectors, scales, scalars, preds, qs, w):
        n = scalars.shape[0]
        n_dev = 1
        for a in axes:
            n_dev *= mesh.shape[a]
        assert n % n_dev == 0, (n, n_dev)
        row0 = jnp.arange(n_dev, dtype=jnp.int32) * (n // n_dev)
        scales = tuple(scales) if int8 else jnp.zeros(())
        return fn(tuple(vectors), scales, scalars, preds, tuple(qs), w, row0)

    return jax.jit(run)


# ---------------------------------------------------------------------------
# cross-shard batched serving entry point (serve/batch.py fans out here)
# ---------------------------------------------------------------------------
#
# The batched serving layer already computes dense per-column score matrices
# for the whole batch (serve.batch.compute_batch_scores); the cross-shard
# path must not re-score. Both functions below therefore take the WEIGHTED
# (Q, n) score matrix as input and only do per-shard mask + local top-k +
# one O(shards · k) merge:
#
#   * ``sharded_batch_topk`` builds the shard_map version: rows (score
#     columns + scalar rows) are sharded over the mesh's data axes, each
#     device reads only its local (Q, n_local) block of the dense matrix,
#     and the merge is one all-gather of O(shards · k) candidates.
#   * ``sharded_topk_ref`` is the single-device logical-shard reference
#     with IDENTICAL merge semantics (same local top-k widths, same shard
#     concatenation order, same tie-breaking) — the executor uses it when
#     no multi-device mesh is bound, and tests use it as the shard_map
#     oracle.


def _merge_shard_candidates(s_all, g_all, *, k):
    """Top-k over the concatenated per-shard candidates (Q, S·kk); output
    padded to width k with id -1 / score NEG when fewer candidates exist."""
    kf = min(k, s_all.shape[1])
    ms, mi = jax.lax.top_k(s_all, kf)
    ids = jnp.where(ms > NEG / 2, jnp.take_along_axis(g_all, mi, 1), -1)
    if kf < k:
        pad = ((0, 0), (0, k - kf))
        ids = jnp.pad(ids, pad, constant_values=-1)
        ms = jnp.pad(ms, pad, constant_values=NEG)
    return ids, ms


@partial(jax.jit, static_argnames=("k", "n_shards"))
def sharded_topk_ref(w_scores, mask, *, k, n_shards):
    """Logical-shard filtered top-k over precomputed weighted scores.

    ``w_scores``/``mask``: (Q, n). Rows split into ``n_shards`` contiguous
    shards (right-padded with non-qualifying rows when n % n_shards != 0);
    each shard keeps a local top-min(k, shard_len), then one merge over the
    (Q, shards·kk) candidates. Runs on a single device — the semantics (and
    tie-breaking) match ``sharded_batch_topk`` exactly.
    """
    q, n = w_scores.shape
    per = -(-n // n_shards)  # ceil-div shard length
    masked = jnp.where(mask, w_scores, NEG)
    masked = jnp.pad(masked, ((0, 0), (0, per * n_shards - n)),
                     constant_values=NEG)
    local = masked.reshape(q, n_shards, per)
    kk = min(k, per)
    s_loc, idx = jax.lax.top_k(local, kk)  # (Q, S, kk)
    gids = jnp.arange(n_shards, dtype=jnp.int32)[None, :, None] * per + idx
    return _merge_shard_candidates(s_loc.reshape(q, n_shards * kk),
                                   gids.reshape(q, n_shards * kk), k=k)


def sharded_batch_topk(mesh: Mesh, data_axes=("data",), *, k: int):
    """Build the jit'd cross-shard batched filtered top-k.

    Returned fn signature:
      fn(w_scores (Q, n), scalars (n, M), preds (stacked over Q))
        -> (ids (Q, k), scores (Q, k))

    ``w_scores`` is the whole-batch weighted score matrix assembled from the
    serving layer's per-column GEMMs; the shard_map in_spec slices its row
    axis so each device reads only its local (Q, n_local) block — the scan
    reuses the dense matrices instead of re-scoring, and the collective is
    one all-gather of O(shards · k) candidates per query.
    """
    axes = data_axes if isinstance(data_axes, tuple) else (data_axes,)

    def local(w_scores, scalars, preds, row0):
        n_local = scalars.shape[0]
        mask = jax.vmap(lambda p: eval_mask(p, scalars))(preds)  # (Q, n_local)
        masked = jnp.where(mask, w_scores, NEG)
        kk = min(k, n_local)
        s_loc, idx = jax.lax.top_k(masked, kk)  # (Q, kk)
        gids = row0 + idx  # globalize
        s_all = jax.lax.all_gather(s_loc, axes, axis=1, tiled=True)
        g_all = jax.lax.all_gather(gids, axes, axis=1, tiled=True)
        return _merge_shard_candidates(s_all, g_all, k=k)

    fn = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, axes), P(axes, None), P(), P(axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def run(w_scores, scalars, preds):
        n = scalars.shape[0]
        n_dev = 1
        for a in axes:
            n_dev *= mesh.shape[a]
        assert n % n_dev == 0, (n, n_dev)
        row0 = jnp.arange(n_dev, dtype=jnp.int32) * (n // n_dev)
        return fn(w_scores, scalars, preds, row0)

    return jax.jit(run)
