from repro.vectordb.table import Table, TableSchema, ScalarCol, VectorCol, similarity, weighted_score  # noqa: F401
from repro.vectordb.predicates import (  # noqa: F401
    CLAUSE_GRID, PredicateLike, Predicates, PredicateSet, as_set,
    clause_bucket, eval_mask, soft_encode, value_encode,
)
from repro.vectordb.algebra import col  # noqa: F401
from repro.vectordb import histogram, ivf, flat  # noqa: F401
