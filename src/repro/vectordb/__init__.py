from repro.vectordb.table import Table, TableSchema, ScalarCol, VectorCol, similarity, weighted_score  # noqa: F401
from repro.vectordb.predicates import Predicates, eval_mask, soft_encode, value_encode  # noqa: F401
from repro.vectordb import histogram, ivf, flat  # noqa: F401
