"""Fixed-degree proximity graph index (Vamana-style) + beam-search entry.

The third index strategy next to ``flat`` (filter_first) and ``ivf``
(index_scan): a degree-``R`` navigable graph built OFFLINE from the cold
table, searched by the fixed-trip-count predicate-aware beam search in
``kernels.beam_search``. Where IVF's probe list commits the whole scan
budget to the clusters nearest the query — exactly the region a
correlated predicate empties — the graph walk spends its budget hop by
hop, routing THROUGH non-qualifying rows toward the qualifying shell.

Build (numpy/offline, mirrors the DiskANN/Vamana recipe under this
repo's static-shape constraints):

  1. blocked exact kNN — each row's top-``4R`` candidates by one chunked
     GEMM per block (no index bootstrap; the cold table is sealed and
     bounded, and build runs in the compaction/seal path, off the serving
     hot loop);
  2. α-occlusion prune — candidates in similarity order; a candidate is
     dropped when it is (α-adjustedly) closer to an already-kept neighbor
     than to the node, which diversifies edges across directions instead
     of wasting degree on one tight cluster;
  3. reverse-edge fill — each kept edge (i→j) is mirrored into j's free
     slots (vectorized grouped scatter), making the graph navigable from
     sparse regions.

The degree sits on ``DEGREE_GRID`` so adjacency shapes — and therefore
the beam-search jit cache — stay bounded exactly like every other
legalized knob. ``extend`` appends rows for the compaction path (blocked
top-``R`` connect + reverse fill, no re-prune) — the cheap maintenance
step matching ``ivf.extend``; the sealing rebuild is ``build``, matching
``TieredTable.rebuild_every``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.shapes import GRAPH_ENTRY_POINTS, NEG
from repro.vectordb.predicates import PredicateLike, stack

# Legalized out-degrees, the graph analogue of NPROBE_GRID: every
# adjacency launched at serving time has one of these static widths.
DEGREE_GRID = (8, 16, 32)
DEFAULT_DEGREE = 16
# α > 1 keeps a candidate unless it is α-times closer to a kept neighbor
# than to the node — the Vamana densification that keeps long-range edges.
DEFAULT_ALPHA = 1.2
# candidate pool width for the prune, as a multiple of the degree
BUILD_CANDIDATE_MULT = 4
_KNN_CHUNK = 1024
_PRUNE_CHUNK = 512


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphIndex:
    neighbors: jax.Array  # (n, R) i32 adjacency, -1 = free slot
    entry_points: jax.Array  # (E,) i32 — medoid + strided seeds
    metric: str

    def tree_flatten(self):
        return (self.neighbors, self.entry_points), self.metric

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, metric=aux)

    @property
    def degree(self) -> int:
        return int(self.neighbors.shape[1])

    @property
    def n_rows(self) -> int:
        return int(self.neighbors.shape[0])


def legal_degree(degree: int) -> int:
    """Smallest grid degree >= the request (largest grid entry if none)."""
    for d in DEGREE_GRID:
        if d >= degree:
            return d
    return DEGREE_GRID[-1]


# ---------------------------------------------------------------------------
# build
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("c", "metric"))
def _chunk_topk(vectors, chunk, row0, *, c: int, metric: str):
    """Exact top-c neighbors of ``chunk`` rows (table rows row0..) against
    the whole column, self-similarity masked out."""
    g = chunk @ vectors.T
    if metric == "l2":
        sims = (2.0 * g
                - jnp.sum(vectors * vectors, axis=1)[None, :]
                - jnp.sum(chunk * chunk, axis=1)[:, None])
    else:
        sims = g
    b = chunk.shape[0]
    sims = sims.at[jnp.arange(b), row0 + jnp.arange(b)].set(NEG)
    top_s, top_i = jax.lax.top_k(sims, c)
    return jnp.where(top_s > NEG / 2, top_i, -1).astype(jnp.int32), top_s


@partial(jax.jit, static_argnames=("r", "metric"))
def _prune_chunk(cand_ids, cand_sims, cand_vecs, alpha, *, r: int,
                 metric: str):
    """α-occlusion prune of (B, C) similarity-ordered candidate lists down
    to degree r. Candidate t is occluded when some already-kept l has
    sim(t, l) beating the α-adjusted sim(node, t): for l2 (sims = -dist²)
    that is dist(t,l)·α < dist(node,t); for dot the α margin scales the
    node similarity directly."""
    g = jnp.einsum("bcd,bed->bce", cand_vecs, cand_vecs)
    if metric == "l2":
        nrm = jnp.sum(cand_vecs * cand_vecs, axis=-1)  # (B, C)
        pair = 2.0 * g - nrm[:, :, None] - nrm[:, None, :]
        thresh = cand_sims / (alpha * alpha)
    else:
        pair = g
        thresh = jnp.where(cand_sims >= 0.0, cand_sims * alpha,
                           cand_sims / alpha)
    c = cand_ids.shape[1]

    def one(ids, pr, th):
        def step(t, carry):
            sel, cnt = carry
            occ = jnp.any(sel & (pr[t] > th[t]))
            take = (ids[t] >= 0) & ~occ & (cnt < r)
            return sel.at[t].set(take), cnt + take.astype(jnp.int32)

        sel, _ = jax.lax.fori_loop(
            0, c, step, (jnp.zeros((c,), bool), jnp.asarray(0, jnp.int32)))
        pos = jnp.cumsum(sel.astype(jnp.int32)) - 1
        return jnp.full((r,), -1, jnp.int32).at[
            jnp.where(sel, pos, r)].set(
            jnp.where(sel, ids, -1), mode="drop")

    return jax.vmap(one)(cand_ids, pair, thresh)


def _reverse_fill(neigh: np.ndarray, src_rows: np.ndarray | None = None):
    """Mirror forward edges (i→j) into j's free adjacency slots, in place.

    One vectorized grouped scatter: edges sort by destination, each
    destination accepts reverse edges up to its free degree in source
    order. ``src_rows`` restricts the mirrored edges to those sources
    (the extend path mirrors only the new rows' edges). A mirrored edge
    may duplicate an existing forward edge — harmless, the search-side
    visited bitmask drops the second occurrence."""
    n, r = neigh.shape
    deg = (neigh >= 0).sum(1)
    if src_rows is None:
        src = np.repeat(np.arange(n, dtype=np.int32), r)
        dst = neigh.reshape(-1)
    else:
        src = np.repeat(np.asarray(src_rows, np.int32), r)
        dst = neigh[src_rows].reshape(-1)
    ok = dst >= 0
    src, dst = src[ok], dst[ok]
    order = np.argsort(dst, kind="stable")
    dsts, srcs = dst[order], src[order]
    rank = np.arange(dsts.size) - np.searchsorted(dsts, dsts, side="left")
    keep = rank < (r - deg[dsts])
    neigh[dsts[keep], deg[dsts[keep]] + rank[keep]] = srcs[keep]


def _entry_points(vectors: jax.Array, metric: str,
                  n_entry: int = GRAPH_ENTRY_POINTS) -> np.ndarray:
    """Medoid (closest row to the column mean) + strided seeds: the medoid
    anchors the dense core, the strided rows cover disconnected or sparse
    regions the prune may have isolated."""
    n = int(vectors.shape[0])
    mu = jnp.mean(vectors, axis=0)
    g = vectors @ mu
    if metric == "l2":
        g = 2.0 * g - jnp.sum(vectors * vectors, axis=1) - jnp.sum(mu * mu)
    pts = ((np.arange(n_entry, dtype=np.int64) * n) // n_entry).astype(
        np.int32)
    pts[0] = int(jnp.argmax(g))
    return pts


def build(vectors: jax.Array, degree: int = DEFAULT_DEGREE, *,
          alpha: float = DEFAULT_ALPHA, metric: str = "dot") -> GraphIndex:
    """Offline graph build from a sealed column (module doc). ``degree``
    legalizes onto ``DEGREE_GRID``."""
    r = legal_degree(degree)
    n = int(vectors.shape[0])
    c = min(BUILD_CANDIDATE_MULT * r, max(1, n - 1))
    # prune forward edges to HALF degree, reserving the rest for reverse
    # fill: under dot the α-occlusion rule rarely triggers, so a full-
    # degree prune leaves zero free slots, the reverse fill becomes a
    # no-op, and the purely-forward kNN digraph collapses into per-row
    # islands nothing can route into
    r_fwd = max(1, r // 2)
    neigh = np.full((n, r), -1, np.int32)
    alpha_j = jnp.asarray(alpha, jnp.float32)
    for lo in range(0, n, _KNN_CHUNK):
        hi = min(lo + _KNN_CHUNK, n)
        ids, sims = _chunk_topk(vectors, vectors[lo:hi], lo, c=c,
                                metric=metric)
        for plo in range(0, hi - lo, _PRUNE_CHUNK):
            phi = min(plo + _PRUNE_CHUNK, hi - lo)
            cand_vecs = vectors[jnp.clip(ids[plo:phi], 0, n - 1)]
            neigh[lo + plo:lo + phi, :r_fwd] = np.asarray(_prune_chunk(
                ids[plo:phi], sims[plo:phi], cand_vecs, alpha_j,
                r=r_fwd, metric=metric))
    _reverse_fill(neigh)
    entries = _entry_points(vectors, metric)
    _repair_reachability(neigh, np.asarray(vectors), entries, metric)
    return GraphIndex(neighbors=jnp.asarray(neigh),
                      entry_points=jnp.asarray(entries),
                      metric=metric)


def _repair_reachability(neigh: np.ndarray, vec: np.ndarray,
                         entries: np.ndarray, metric: str,
                         links_per_round: int = 32,
                         max_rounds: int = 64) -> None:
    """Make every row reachable from the entry points, in place.

    The build's candidate pool is pure kNN, so on well-separated data the
    pruned graph fragments into cluster islands and the walk can never
    leave the components the entries land in (true Vamana avoids this via
    search-seeded candidate pools, which carry long-range edges). Repair:
    directed BFS from the entries, then for the nearest unreached rows
    splice one edge reachable→unreached (evicting the donor's weakest
    slot), re-flood, repeat. Each spliced edge floods the target's whole
    local component on the next BFS, so rounds ~ #islands, not #rows."""
    n, r = neigh.shape
    seed = np.zeros(n, bool)
    seed[np.asarray(entries)] = True

    def flood():
        reach = seed.copy()
        frontier = np.where(reach)[0]
        while frontier.size:
            nxt = neigh[frontier].reshape(-1)
            nxt = np.unique(nxt[nxt >= 0])
            nxt = nxt[~reach[nxt]]
            reach[nxt] = True
            frontier = nxt
        return reach

    forced = np.zeros((n, r), bool)  # spliced edges are never evicted
    indeg = np.bincount(neigh[neigh >= 0], minlength=n)
    stall = 0
    prev_un = n + 1
    for _ in range(max_rounds):
        # full re-flood every round: an eviction can disconnect rows
        # counted reachable in an earlier round, so an incrementally-grown
        # reach mask would drift optimistic
        reach = flood()
        un = np.where(~reach)[0]
        if un.size == 0:
            return
        stall = stall + 1 if un.size >= prev_un else 0
        if stall >= 3:
            return
        prev_un = un.size
        rs = np.where(reach)[0]
        # nearest reachable donor for each unreached row (blocked GEMM)
        sims = vec[un] @ vec[rs].T
        if metric == "l2":
            sims = (2.0 * sims
                    - (vec[rs] * vec[rs]).sum(1)[None, :]
                    - (vec[un] * vec[un]).sum(1)[:, None])
        best_sim = sims.max(1)
        take = np.argsort(-best_sim)[:max(links_per_round, n // 256)]
        for t in take:
            u = int(un[t])
            # donors in similarity order — fall past any donor whose every
            # slot already holds a forced splice
            for d in np.argsort(-sims[t])[:64]:
                v = int(rs[d])
                free = np.where(neigh[v] < 0)[0]
                if free.size:
                    slot = int(free[0])
                else:
                    # evict the edge whose target is most redundantly
                    # referenced elsewhere — evicting the geometrically
                    # weakest edge instead tends to cut long-range bridges
                    # and disconnect more rows than the splice recovers
                    cand = np.where(~forced[v])[0]
                    if cand.size == 0:
                        continue
                    slot = int(cand[int(np.argmax(indeg[neigh[v, cand]]))])
                    indeg[neigh[v, slot]] -= 1
                neigh[v, slot] = u
                forced[v, slot] = True
                indeg[u] += 1
                break


def extend(index: GraphIndex, vectors: jax.Array,
           first_new_row: int) -> GraphIndex:
    """Append rows ``vectors[first_new_row:]`` (``vectors`` is the FULL
    post-append column) — the cheap compaction-path maintenance step.
    New rows get exact top-R forward edges into the whole grown column
    (no re-prune: the sealed prefix's diversity is preserved, and the
    sealing rebuild re-prunes everything) and mirror into existing rows'
    free slots, which keeps them reachable from the old graph."""
    n = int(vectors.shape[0])
    r = index.degree
    assert first_new_row == index.n_rows, (first_new_row, index.n_rows)
    c = min(r, max(1, n - 1))
    lists = []
    for lo in range(first_new_row, n, _KNN_CHUNK):
        hi = min(lo + _KNN_CHUNK, n)
        ids, _ = _chunk_topk(vectors, vectors[lo:hi], lo, c=c, metric=index.metric)
        lists.append(np.asarray(ids))
    new = np.full((n - first_new_row, r), -1, np.int32)
    if lists:
        got = np.concatenate(lists)
        new[:, :got.shape[1]] = got
    neigh = np.concatenate([np.asarray(index.neighbors), new])
    new_ids = np.arange(first_new_row, n, dtype=np.int32)
    _reverse_fill(neigh, new_ids)
    # _reverse_fill only consumes FREE slots and a sealed graph's slots
    # are mostly saturated by its own build-time fill, so appended rows
    # can end up referenced by nobody — the repair pass splices them (and
    # anything else the eviction churn disconnects) back in
    _repair_reachability(neigh, np.asarray(vectors),
                         np.asarray(index.entry_points), index.metric)
    return GraphIndex(neighbors=jnp.asarray(neigh),
                      entry_points=index.entry_points, metric=index.metric)


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

def search_local_batch(
    index: GraphIndex,
    vectors: jax.Array,  # (n, d) the indexed column
    scalars: jax.Array,  # (n, M)
    pred_b: PredicateLike,  # stacked, leading axis B
    q_b: jax.Array,  # (B, d)
    *,
    beam_width: int,
    n_hops: int,
    k: int,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """Candidate-local batched graph search — the same contract as
    ``ivf.search_local_batch``: (ids (B, k), scores (B, k), n_scored (B,),
    n_qualified (B,)), ties by smaller row id, -1/NEG empty slots.
    ``n_scored`` counts visited rows (the walk's actual scan budget)."""
    from repro.kernels.beam_search import beam_search_topk

    return beam_search_topk(
        index.neighbors, index.entry_points, vectors, scalars, pred_b, q_b,
        k=k, beam_width=beam_width, n_hops=n_hops, metric=index.metric,
        use_kernel=use_kernel, interpret=interpret)


def search(
    index: GraphIndex,
    vectors: jax.Array,
    scalars: jax.Array,
    pred: PredicateLike,
    q: jax.Array,  # (d,)
    *,
    beam_width: int,
    n_hops: int,
    k: int,
):
    """Single-query convenience wrapper mirroring ``ivf.search``:
    (ids (k,), scores (k,), n_scored (), n_qualified ())."""
    ids, scores, n_scored, n_qual = search_local_batch(
        index, vectors, scalars, stack([pred]), q[None], k=k,
        beam_width=beam_width, n_hops=n_hops)
    return ids[0], scores[0], n_scored[0], n_qual[0]
