"""Flat (sequential-scan) search paths.

``filter_first``: evaluate the predicate over all rows, gather up to
``max_candidates`` qualifying rows, score only those — cost ∝ selectivity·n,
the TPU analogue of 'scalar-index assisted sequential scan'.

``masked_scan``: score every row with the predicate as a mask — the exact
oracle (ground truth) and the fallback when selectivity is high. On TPU the
inner loop is the fused Pallas ``masked_topk`` kernel (kernels/); the jnp
path here is its oracle and the CPU execution path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.shapes import GATHER_BLOCK_S, NEG
from repro.vectordb.predicates import PredicateLike, eval_mask
from repro.vectordb.table import Table


@partial(jax.jit, static_argnames=("k", "max_candidates", "n_vec", "metric"))
def filter_first(
    vectors: tuple,  # tuple of (n, d_i)
    scalars: jax.Array,
    pred: PredicateLike,
    query_vectors: tuple,  # tuple of (d_i,)
    weights: jax.Array,
    metric: str = "dot",
    *,
    k: int,
    max_candidates: int,
    n_vec: int,
):
    """Filter-first execution. Returns (ids, scores, n_scored, n_qualified)."""
    mask = eval_mask(pred, scalars)
    n = scalars.shape[0]
    rows = jnp.nonzero(mask, size=max_candidates, fill_value=n)[0]
    valid = rows < n
    rows_c = jnp.clip(rows, 0, n - 1)
    from repro.vectordb.table import similarity

    total = jnp.zeros((max_candidates,), jnp.float32)
    for i in range(n_vec):
        total = total + weights[i] * similarity(query_vectors[i], vectors[i][rows_c], metric)
    masked = jnp.where(valid, total, NEG)
    top_scores, top_idx = jax.lax.top_k(masked, k)
    ids = jnp.where(top_scores > NEG / 2, rows_c[top_idx], -1)
    # n_scored is capped by the gather width; n_qualified is the true
    # qualifying-row count (underfill/escalation logic reads it).
    return ids, top_scores, jnp.sum(valid), jnp.sum(mask)


@partial(jax.jit, static_argnames=("k", "max_candidates"))
def filter_first_scored(
    row_scores: jax.Array,  # (n,) precomputed weighted scores for ONE query
    scalars: jax.Array,
    pred: PredicateLike,
    *,
    k: int,
    max_candidates: int,
):
    """``filter_first`` with the weighted row scores precomputed — the
    batched serving path computes Σ_i w_i·(V_i @ q_i) for a whole batch via
    per-column GEMMs and then runs this per query (matching ``filter_first``
    up to float reduction order)."""
    mask = eval_mask(pred, scalars)
    n = scalars.shape[0]
    rows = jnp.nonzero(mask, size=max_candidates, fill_value=n)[0]
    valid = rows < n
    rows_c = jnp.clip(rows, 0, n - 1)
    masked = jnp.where(valid, row_scores[rows_c], NEG)
    top_scores, top_idx = jax.lax.top_k(masked, k)
    ids = jnp.where(top_scores > NEG / 2, rows_c[top_idx], -1)
    return ids, top_scores, jnp.sum(valid), jnp.sum(mask)


@partial(jax.jit, static_argnames=("k", "max_candidates", "n_vec", "metric",
                                   "use_kernel", "interpret", "block_s"))
def filter_first_local_batch(
    vectors: tuple,  # tuple of (n, d_i)
    scalars: jax.Array,
    pred_b: PredicateLike,  # stacked, leading axis B
    query_vectors_b: tuple,  # tuple of (B, d_i)
    weights_b: jax.Array,  # (B, n_vec)
    *,
    k: int,
    max_candidates: int,
    n_vec: int,
    metric: str = "dot",
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    block_s: int = GATHER_BLOCK_S,
):
    """Candidate-local batched ``filter_first``: evaluate the predicate over
    all rows per query, then ONE fused gather+score+top-k
    (``kernels.gather_score``) over only the ≤ ``max_candidates`` qualifying
    rows — no dense (B, n) score matrix. Returns (ids (B, k), scores (B, k),
    n_scored (B,), n_qualified (B,)); the candidates are pre-qualified, so
    the fused kernel skips re-masking."""
    from repro.kernels.gather_score import gather_score_topk

    mask_b = jax.vmap(lambda p: eval_mask(p, scalars))(pred_b)  # (B, n)
    rows_b = jax.vmap(
        lambda m: jnp.nonzero(m, size=max_candidates, fill_value=-1)[0]
    )(mask_b)
    cand = rows_b.astype(jnp.int32)
    ids, scores, _ = gather_score_topk(
        cand, tuple(vectors[:n_vec]), tuple(query_vectors_b[:n_vec]),
        weights_b, scalars, None, k=k, metric=metric, use_kernel=use_kernel,
        interpret=interpret, block_s=block_s)
    return ids, scores, jnp.sum(cand >= 0, axis=1), jnp.sum(mask_b, axis=1)


@partial(jax.jit, static_argnames=("k", "n_vec", "metric"))
def masked_scan(
    vectors: tuple,
    scalars: jax.Array,
    pred: PredicateLike,
    query_vectors: tuple,
    weights: jax.Array,
    metric: str = "dot",
    *,
    k: int,
    n_vec: int,
):
    """Exact filtered top-k over the full table (also the recall oracle)."""
    from repro.vectordb.table import similarity

    n = scalars.shape[0]
    total = jnp.zeros((n,), jnp.float32)
    for i in range(n_vec):
        total = total + weights[i] * similarity(query_vectors[i], vectors[i], metric)
    mask = eval_mask(pred, scalars)
    masked = jnp.where(mask, total, NEG)
    top_scores, top_idx = jax.lax.top_k(masked, k)
    ids = jnp.where(top_scores > NEG / 2, top_idx, -1)
    return ids, top_scores, jnp.asarray(n), jnp.sum(mask)


def ground_truth(table: Table, query_vectors, weights, pred: PredicateLike, k: int):
    ids, scores, _, _ = masked_scan(
        tuple(table.vectors),
        table.scalars,
        pred,
        tuple(query_vectors),
        jnp.asarray(weights),
        table.schema.metric,
        k=k,
        n_vec=table.schema.n_vec,
    )
    return ids, scores
