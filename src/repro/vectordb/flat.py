"""Flat (sequential-scan) search paths.

``filter_first``: evaluate the predicate over all rows, gather up to
``max_candidates`` qualifying rows, score only those — cost ∝ selectivity·n,
the TPU analogue of 'scalar-index assisted sequential scan'.

``masked_scan``: score every row with the predicate as a mask — the exact
oracle (ground truth) and the fallback when selectivity is high. On TPU the
inner loop is the fused Pallas ``masked_topk`` kernel (kernels/); the jnp
path here is its oracle and the CPU execution path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.vectordb.predicates import PredicateLike, eval_mask
from repro.vectordb.table import Table

NEG = -1e30


@partial(jax.jit, static_argnames=("k", "max_candidates", "n_vec", "metric"))
def filter_first(
    vectors: tuple,  # tuple of (n, d_i)
    scalars: jax.Array,
    pred: PredicateLike,
    query_vectors: tuple,  # tuple of (d_i,)
    weights: jax.Array,
    metric: str = "dot",
    *,
    k: int,
    max_candidates: int,
    n_vec: int,
):
    """Filter-first execution. Returns (ids, scores, n_scored, n_qualified)."""
    mask = eval_mask(pred, scalars)
    n = scalars.shape[0]
    rows = jnp.nonzero(mask, size=max_candidates, fill_value=n)[0]
    valid = rows < n
    rows_c = jnp.clip(rows, 0, n - 1)
    from repro.vectordb.table import similarity

    total = jnp.zeros((max_candidates,), jnp.float32)
    for i in range(n_vec):
        total = total + weights[i] * similarity(query_vectors[i], vectors[i][rows_c], metric)
    masked = jnp.where(valid, total, NEG)
    top_scores, top_idx = jax.lax.top_k(masked, k)
    ids = jnp.where(top_scores > NEG / 2, rows_c[top_idx], -1)
    # n_scored is capped by the gather width; n_qualified is the true
    # qualifying-row count (underfill/escalation logic reads it).
    return ids, top_scores, jnp.sum(valid), jnp.sum(mask)


@partial(jax.jit, static_argnames=("k", "max_candidates"))
def filter_first_scored(
    row_scores: jax.Array,  # (n,) precomputed weighted scores for ONE query
    scalars: jax.Array,
    pred: PredicateLike,
    *,
    k: int,
    max_candidates: int,
):
    """``filter_first`` with the weighted row scores precomputed — the
    batched serving path computes Σ_i w_i·(V_i @ q_i) for a whole batch via
    per-column GEMMs and then runs this per query (matching ``filter_first``
    up to float reduction order)."""
    mask = eval_mask(pred, scalars)
    n = scalars.shape[0]
    rows = jnp.nonzero(mask, size=max_candidates, fill_value=n)[0]
    valid = rows < n
    rows_c = jnp.clip(rows, 0, n - 1)
    masked = jnp.where(valid, row_scores[rows_c], NEG)
    top_scores, top_idx = jax.lax.top_k(masked, k)
    ids = jnp.where(top_scores > NEG / 2, rows_c[top_idx], -1)
    return ids, top_scores, jnp.sum(valid), jnp.sum(mask)


@partial(jax.jit, static_argnames=("k", "n_vec", "metric"))
def masked_scan(
    vectors: tuple,
    scalars: jax.Array,
    pred: PredicateLike,
    query_vectors: tuple,
    weights: jax.Array,
    metric: str = "dot",
    *,
    k: int,
    n_vec: int,
):
    """Exact filtered top-k over the full table (also the recall oracle)."""
    from repro.vectordb.table import similarity

    n = scalars.shape[0]
    total = jnp.zeros((n,), jnp.float32)
    for i in range(n_vec):
        total = total + weights[i] * similarity(query_vectors[i], vectors[i], metric)
    mask = eval_mask(pred, scalars)
    masked = jnp.where(mask, total, NEG)
    top_scores, top_idx = jax.lax.top_k(masked, k)
    ids = jnp.where(top_scores > NEG / 2, top_idx, -1)
    return ids, top_scores, jnp.asarray(n), jnp.sum(mask)


def ground_truth(table: Table, query_vectors, weights, pred: PredicateLike, k: int):
    ids, scores, _, _ = masked_scan(
        tuple(table.vectors),
        table.scalars,
        pred,
        tuple(query_vectors),
        jnp.asarray(weights),
        table.schema.metric,
        k=k,
        n_vec=table.schema.n_vec,
    )
    return ids, scores
