"""Histogram-based global selectivity estimation (paper §3.3).

Per scalar column we keep equi-width bin edges and a **prefix-sum** count
array, exactly as the paper prescribes: a range predicate is answered by two
interpolated prefix lookups; conjunctions multiply per-column selectivities
under the independence assumption.

DNF predicate sets estimate the clause *union*: exact inclusion–exclusion
for C <= 2 (the pairwise clause intersection is itself a conjunction of
intersected ranges, estimated under the same independence assumption), and
the Bonferroni upper bound min(1, Σ_c σ_c) beyond.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.vectordb.predicates import PredicateLike, as_set


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Histograms:
    edges: jax.Array  # (M, B+1)
    prefix: jax.Array  # (M, B+1) cumulative counts, prefix[:,0] = 0
    n_rows: jax.Array  # ()

    def tree_flatten(self):
        return (self.edges, self.prefix, self.n_rows), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def build(scalars: jax.Array, n_bins: int = 64) -> Histograms:
    """scalars: (n, M). Equi-width per column with a tiny epsilon pad so the
    max value falls inside the last bin."""
    n, m = scalars.shape
    lo = jnp.min(scalars, axis=0)
    hi = jnp.max(scalars, axis=0)
    span = jnp.maximum(hi - lo, 1e-9)
    edges = lo[:, None] + span[:, None] * jnp.linspace(0.0, 1.0 + 1e-6, n_bins + 1)[None, :]

    def per_col(col, e):
        idx = jnp.clip(jnp.searchsorted(e, col, side="right") - 1, 0, n_bins - 1)
        counts = jnp.zeros((n_bins,), jnp.float32).at[idx].add(1.0)
        return jnp.concatenate([jnp.zeros((1,)), jnp.cumsum(counts)])

    prefix = jax.vmap(per_col, in_axes=(1, 0))(scalars, edges)
    return Histograms(edges=edges, prefix=prefix, n_rows=jnp.asarray(float(n)))


def update(h: Histograms, scalars_new: jax.Array) -> Histograms:
    """Incremental maintenance on insert: re-bin new rows into existing edges
    (edges are kept — consistent with paper's 'offline background' stats)."""
    n_bins = h.prefix.shape[1] - 1

    def per_col(col, e, pref):
        idx = jnp.clip(jnp.searchsorted(e, col, side="right") - 1, 0, n_bins - 1)
        counts = jnp.zeros((n_bins,), jnp.float32).at[idx].add(1.0)
        return pref + jnp.concatenate([jnp.zeros((1,)), jnp.cumsum(counts)])

    prefix = jax.vmap(per_col, in_axes=(1, 0, 0))(scalars_new, h.edges, h.prefix)
    return Histograms(h.edges, prefix, h.n_rows + scalars_new.shape[0])


def _prefix_at(edges_c: jax.Array, prefix_c: jax.Array, x: jax.Array) -> jax.Array:
    """Interpolated cumulative count of values <= x for one column."""
    b = prefix_c.shape[0] - 1
    idx = jnp.clip(jnp.searchsorted(edges_c, x, side="right") - 1, 0, b - 1)
    left, right = edges_c[idx], edges_c[idx + 1]
    frac = jnp.clip((x - left) / jnp.maximum(right - left, 1e-12), 0.0, 1.0)
    below = prefix_c[idx] + frac * (prefix_c[idx + 1] - prefix_c[idx])
    below = jnp.where(x < edges_c[0], 0.0, below)
    below = jnp.where(x >= edges_c[-1], prefix_c[-1], below)
    return below


def _clause_selectivity(h: Histograms, lo, hi, active) -> jax.Array:
    """Independence-product selectivity of ONE conjunctive clause.

    lo/hi/active: (M,). An empty range (hi < lo — e.g. a vacuous pairwise
    clause intersection) contributes selectivity 0."""
    def per_col(e, p, lo, hi, act):
        b = p.shape[0] - 1
        cnt = _prefix_at(e, p, hi) - _prefix_at(e, p, lo - 1e-9)
        # point predicates (equality): interpolation of discrete mass is ~0;
        # answer with the containing bin's full count instead.
        is_point = ((hi - lo) <= 1e-12) & (hi >= lo)
        idx = jnp.clip(jnp.searchsorted(e, lo, side="right") - 1, 0, b - 1)
        bin_cnt = p[idx + 1] - p[idx]
        cnt = jnp.where(is_point, bin_cnt, cnt)
        sel = jnp.clip(cnt / jnp.maximum(p[-1], 1.0), 0.0, 1.0)
        sel = jnp.where(hi < lo, 0.0, sel)
        return jnp.where(act, sel, 1.0)

    return jnp.prod(jax.vmap(per_col)(h.edges, h.prefix, lo, hi, active))


@jax.jit
def estimate_selectivity(h: Histograms, pred: PredicateLike) -> jax.Array:
    """σ_est ∈ [0, 1] for a predicate set (conjunctive or DNF).

    C=1: the classic independence product. C=2: inclusion–exclusion, with
    the clause intersection estimated as a conjunction of intersected
    ranges. C>2: the Bonferroni upper bound min(1, Σ_c σ_c)."""
    ps = as_set(pred)
    sels = jax.vmap(lambda lo, hi, act: _clause_selectivity(h, lo, hi, act))(
        ps.lo, ps.hi, ps.active)
    sels = jnp.where(ps.clause_valid, sels, 0.0)  # padding clauses: no mass
    c = ps.n_clauses  # static — picks the estimator at trace time
    if c == 1:
        return sels[0]
    if c == 2:
        inter = _clause_selectivity(
            h,
            jnp.maximum(ps.lo[0], ps.lo[1]),
            jnp.minimum(ps.hi[0], ps.hi[1]),
            ps.active[0] | ps.active[1],
        ) * (ps.clause_valid[0] & ps.clause_valid[1])
        return jnp.clip(sels[0] + sels[1] - inter, 0.0, 1.0)
    return jnp.clip(jnp.sum(sels), 0.0, 1.0)
