"""Histogram-based global selectivity estimation (paper §3.3).

Per scalar column we keep equi-width bin edges and a **prefix-sum** count
array, exactly as the paper prescribes: a range predicate is answered by two
interpolated prefix lookups; conjunctions multiply per-column selectivities
under the independence assumption.

DNF predicate sets estimate the clause *union* by FULL inclusion–exclusion
over the clause grid (C <= 4): every intersection of clauses is itself a
conjunction of per-column intersected ranges, estimated under the same
independence assumption — 11 intersection terms at C=4 (6 pairs + 4 triples
+ 1 quadruple), unrolled statically at trace time.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp

from repro.vectordb.predicates import PredicateLike, as_set


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Histograms:
    edges: jax.Array  # (M, B+1)
    prefix: jax.Array  # (M, B+1) cumulative counts, prefix[:,0] = 0
    n_rows: jax.Array  # ()

    def tree_flatten(self):
        return (self.edges, self.prefix, self.n_rows), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def build(scalars: jax.Array, n_bins: int = 64) -> Histograms:
    """scalars: (n, M). Equi-width per column with a tiny epsilon pad so the
    max value falls inside the last bin."""
    n, m = scalars.shape
    lo = jnp.min(scalars, axis=0)
    hi = jnp.max(scalars, axis=0)
    span = jnp.maximum(hi - lo, 1e-9)
    edges = lo[:, None] + span[:, None] * jnp.linspace(0.0, 1.0 + 1e-6, n_bins + 1)[None, :]

    def per_col(col, e):
        idx = jnp.clip(jnp.searchsorted(e, col, side="right") - 1, 0, n_bins - 1)
        counts = jnp.zeros((n_bins,), jnp.float32).at[idx].add(1.0)
        return jnp.concatenate([jnp.zeros((1,)), jnp.cumsum(counts)])

    prefix = jax.vmap(per_col, in_axes=(1, 0))(scalars, edges)
    return Histograms(edges=edges, prefix=prefix, n_rows=jnp.asarray(float(n)))


def update(h: Histograms, scalars_new: jax.Array) -> Histograms:
    """Incremental maintenance on insert: re-bin new rows into existing edges
    (edges are kept — consistent with paper's 'offline background' stats)."""
    n_bins = h.prefix.shape[1] - 1

    def per_col(col, e, pref):
        idx = jnp.clip(jnp.searchsorted(e, col, side="right") - 1, 0, n_bins - 1)
        counts = jnp.zeros((n_bins,), jnp.float32).at[idx].add(1.0)
        return pref + jnp.concatenate([jnp.zeros((1,)), jnp.cumsum(counts)])

    prefix = jax.vmap(per_col, in_axes=(1, 0, 0))(scalars_new, h.edges, h.prefix)
    return Histograms(h.edges, prefix, h.n_rows + scalars_new.shape[0])


def _prefix_at(edges_c: jax.Array, prefix_c: jax.Array, x: jax.Array) -> jax.Array:
    """Interpolated cumulative count of values <= x for one column."""
    b = prefix_c.shape[0] - 1
    idx = jnp.clip(jnp.searchsorted(edges_c, x, side="right") - 1, 0, b - 1)
    left, right = edges_c[idx], edges_c[idx + 1]
    frac = jnp.clip((x - left) / jnp.maximum(right - left, 1e-12), 0.0, 1.0)
    below = prefix_c[idx] + frac * (prefix_c[idx + 1] - prefix_c[idx])
    below = jnp.where(x < edges_c[0], 0.0, below)
    below = jnp.where(x >= edges_c[-1], prefix_c[-1], below)
    return below


def _clause_selectivity(h: Histograms, lo, hi, active) -> jax.Array:
    """Independence-product selectivity of ONE conjunctive clause.

    lo/hi/active: (M,). An empty range (hi < lo — e.g. a vacuous pairwise
    clause intersection) contributes selectivity 0."""
    def per_col(e, p, lo, hi, act):
        b = p.shape[0] - 1
        cnt = _prefix_at(e, p, hi) - _prefix_at(e, p, lo - 1e-9)
        # point predicates (equality): interpolation of discrete mass is ~0;
        # answer with the containing bin's full count instead.
        is_point = ((hi - lo) <= 1e-12) & (hi >= lo)
        idx = jnp.clip(jnp.searchsorted(e, lo, side="right") - 1, 0, b - 1)
        bin_cnt = p[idx + 1] - p[idx]
        cnt = jnp.where(is_point, bin_cnt, cnt)
        sel = jnp.clip(cnt / jnp.maximum(p[-1], 1.0), 0.0, 1.0)
        sel = jnp.where(hi < lo, 0.0, sel)
        return jnp.where(act, sel, 1.0)

    return jnp.prod(jax.vmap(per_col)(h.edges, h.prefix, lo, hi, active))


@jax.jit
def estimate_selectivity(h: Histograms, pred: PredicateLike) -> jax.Array:
    """σ_est ∈ [0, 1] for a predicate set (conjunctive or DNF).

    C=1: the classic independence product. C>=2: FULL inclusion–exclusion
    over the clause union — σ(∪A_c) = Σ|A_c| − Σ|A_c∩A_c'| + … — where
    every r-way clause intersection is the conjunction of its per-column
    intersected ranges (max lo / min hi, union of actives) estimated under
    the same independence assumption. The clause grid caps C at 4, so the
    unroll is at most 11 intersection terms (6 pairs + 4 triples + 1
    quadruple); a term with any padding clause contributes 0."""
    ps = as_set(pred)
    sels = jax.vmap(lambda lo, hi, act: _clause_selectivity(h, lo, hi, act))(
        ps.lo, ps.hi, ps.active)
    sels = jnp.where(ps.clause_valid, sels, 0.0)  # padding clauses: no mass
    c = ps.n_clauses  # static — the unroll specializes at trace time
    if c == 1:
        return sels[0]
    total = jnp.sum(sels)
    # Intersections must ignore inactive columns' lo/hi: eval_mask never
    # reads them, so producers may leave garbage there. Mask them to ±inf
    # (the neutral elements of max/min) before folding clause bounds.
    ilo = jnp.where(ps.active, ps.lo, -jnp.inf)
    ihi = jnp.where(ps.active, ps.hi, jnp.inf)
    for r in range(2, c + 1):
        sign = -1.0 if r % 2 == 0 else 1.0
        for combo in itertools.combinations(range(c), r):
            lo = ilo[combo[0]]
            hi = ihi[combo[0]]
            act = ps.active[combo[0]]
            valid = ps.clause_valid[combo[0]]
            for ci in combo[1:]:
                lo = jnp.maximum(lo, ilo[ci])
                hi = jnp.minimum(hi, ihi[ci])
                act = act | ps.active[ci]
                valid = valid & ps.clause_valid[ci]
            total = total + sign * _clause_selectivity(h, lo, hi, act) * valid
    return jnp.clip(total, 0.0, 1.0)
