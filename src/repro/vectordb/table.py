"""Columnar vector+scalar table — the storage substrate for MHQ.

A table holds N vector columns and M scalar columns (paper Fig. 1). All
scalar columns are stored as a dense ``(n, M)`` float32 matrix; categorical
columns carry integer category codes (their cardinality lives in the schema),
so every predicate is expressible as a closed range ``[lo, hi]`` (equality is
``[c, c]``). This keeps predicate evaluation a single fused compare-reduce on
TPU, and the encoder re-expands categoricals to one-hot from the codes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ScalarCol:
    name: str
    kind: str  # "num" | "cat"
    n_categories: int = 0  # for "cat"


@dataclasses.dataclass(frozen=True)
class VectorCol:
    name: str
    dim: int


@dataclasses.dataclass(frozen=True)
class TableSchema:
    vector_cols: tuple[VectorCol, ...]
    scalar_cols: tuple[ScalarCol, ...]
    metric: str = "dot"  # "dot" (higher=closer) | "l2" (lower=closer)

    @property
    def n_vec(self) -> int:
        return len(self.vector_cols)

    @property
    def n_scalar(self) -> int:
        return len(self.scalar_cols)

    def vec_index(self, name: str) -> int:
        return [v.name for v in self.vector_cols].index(name)


@dataclasses.dataclass
class Table:
    schema: TableSchema
    vectors: list[jax.Array]  # one (n, d_i) per vector column
    scalars: jax.Array  # (n, M) float32
    # per-column symmetric int8 replica (the quantized scoring tier):
    # vectors_i8[i] is (n, d_i) int8, scales[i] the (n,) f32 per-row absmax
    # scale (zero-point is 0 by symmetry). Built lazily per column and
    # maintained through append, so TieredTable compaction inherits it.
    vectors_i8: Optional[list] = None
    scales: Optional[list] = None

    @property
    def n_rows(self) -> int:
        return int(self.scalars.shape[0])

    def quantized(self, i: int) -> tuple[jax.Array, jax.Array]:
        """The column's int8 replica, built on first use and cached.
        -> ((n, d_i) int8, (n,) f32 per-row scales)."""
        if self.vectors_i8 is None:
            self.vectors_i8 = [None] * self.schema.n_vec
            self.scales = [None] * self.schema.n_vec
        if self.vectors_i8[i] is None:
            from repro.kernels.int8_scan import quantize_rows

            self.vectors_i8[i], self.scales[i] = quantize_rows(self.vectors[i])
        return self.vectors_i8[i], self.scales[i]

    @staticmethod
    def from_numpy(schema: TableSchema, vectors: list[np.ndarray], scalars: np.ndarray) -> "Table":
        assert len(vectors) == schema.n_vec
        n = scalars.shape[0]
        for v, col in zip(vectors, schema.vector_cols):
            assert v.shape == (n, col.dim), (v.shape, col)
        assert scalars.shape == (n, schema.n_scalar)
        return Table(
            schema=schema,
            vectors=[jnp.asarray(v, jnp.float32) for v in vectors],
            scalars=jnp.asarray(scalars, jnp.float32),
        )

    def append(self, vectors: list[np.ndarray], scalars: np.ndarray) -> "Table":
        """Immutable append (used by the data-update experiments).

        The scale is per ROW, so an append never re-quantizes old rows: any
        already-built int8 replica carries over as (old replica ‖ quantized
        new rows) — compaction keeps the quantized tier warm for free."""
        new = Table(
            schema=self.schema,
            vectors=[jnp.concatenate([a, jnp.asarray(b, jnp.float32)]) for a, b in zip(self.vectors, vectors)],
            scalars=jnp.concatenate([self.scalars, jnp.asarray(scalars, jnp.float32)]),
        )
        if self.vectors_i8 is not None and any(
                q is not None for q in self.vectors_i8):
            from repro.kernels.int8_scan import quantize_rows

            new.vectors_i8 = [None] * self.schema.n_vec
            new.scales = [None] * self.schema.n_vec
            for i, nv in enumerate(vectors):
                if self.vectors_i8[i] is None:
                    continue
                qn, sn = quantize_rows(jnp.asarray(nv, jnp.float32))
                new.vectors_i8[i] = jnp.concatenate([self.vectors_i8[i], qn])
                new.scales[i] = jnp.concatenate([self.scales[i], sn])
        return new


def similarity(q: jax.Array, vecs: jax.Array, metric: str) -> jax.Array:
    """Score rows of ``vecs`` (n, d) against ``q`` (d,). Higher = better."""
    if metric == "dot":
        return vecs @ q
    if metric == "l2":
        # -||v - q||^2 expanded — keeps it a single matmul + row norms
        return 2.0 * (vecs @ q) - jnp.sum(vecs * vecs, axis=-1) - jnp.sum(q * q)
    raise ValueError(f"unknown metric {metric!r}")


def weighted_score(
    table: Table, query_vectors: list[jax.Array], weights: jax.Array, rows: Optional[jax.Array] = None
) -> jax.Array:
    """Composite score Σ_i w_i · sim(q_i, o.v_i) (paper §1 definition)."""
    total = None
    for i, q in enumerate(query_vectors):
        vecs = table.vectors[i] if rows is None else table.vectors[i][rows]
        s = weights[i] * similarity(q, vecs, table.schema.metric)
        total = s if total is None else total + s
    return total
