"""Scalar predicates: DNF representation, evaluation and soft encodings.

Two dense, jit-friendly predicate types:

``Predicates`` — the original single-conjunction form: ``active`` marks which
of the M scalar columns carry a condition; each condition is the closed range
``[lo, hi]`` (equality for categoricals is ``[code, code]``). Kept as the
C=1 compatibility shim; every consumer accepts it unchanged.

``PredicateSet`` — the general form: a disjunction of C conjunctive clauses
(DNF), stored densely as ``(C, M)`` active/lo/hi fields plus a ``(C,)``
``clause_valid`` mask (padding clauses are invalid and match nothing). C is
legalized onto the small grid ``CLAUSE_GRID`` so the jit cache stays bounded:
kernels specialize on the clause *bucket*, not the exact clause count.

Build ``PredicateSet``s with the builder algebra in
:mod:`repro.vectordb.algebra`::

    from repro.vectordb.algebra import col
    expr = col("price").between(10, 50) | (col("brand") == 3) \
        & ~col("size").below(5)
    pred = expr.compile(table.schema)

Evaluation is OR-over-clauses of AND-over-columns; an inactive column always
passes within its clause, an invalid (padding) clause never matches. With
C=1 this degenerates to exactly the old conjunctive semantics.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# Legal clause counts: compiled DNFs pad up to the nearest bucket so the
# number of distinct kernel specializations stays bounded.
CLAUSE_GRID = (1, 2, 4)
MAX_CLAUSES = CLAUSE_GRID[-1]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Predicates:
    """Single conjunction over the M scalar columns (the C=1 compat shim)."""

    active: jax.Array  # (M,) bool
    lo: jax.Array  # (M,) f32
    hi: jax.Array  # (M,) f32

    def tree_flatten(self):
        return (self.active, self.lo, self.hi), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @staticmethod
    def none(m: int) -> "Predicates":
        return Predicates(
            active=jnp.zeros((m,), bool),
            lo=jnp.full((m,), -jnp.inf),
            hi=jnp.full((m,), jnp.inf),
        )

    @staticmethod
    def from_conditions(m: int, conds: dict[int, tuple[float, float]]) -> "Predicates":
        active = np.zeros((m,), bool)
        lo = np.full((m,), -np.inf, np.float32)
        hi = np.full((m,), np.inf, np.float32)
        for idx, (l, h) in conds.items():
            active[idx] = True
            lo[idx] = l
            hi[idx] = h
        return Predicates(jnp.asarray(active), jnp.asarray(lo), jnp.asarray(hi))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PredicateSet:
    """DNF predicate set: OR over C conjunctive clauses, fields ``(C, M)``.

    ``clause_valid`` masks padding clauses (False = clause matches nothing);
    real clauses that carry no active column match everything, exactly like
    an empty conjunction.
    """

    active: jax.Array  # (..., C, M) bool
    lo: jax.Array  # (..., C, M) f32
    hi: jax.Array  # (..., C, M) f32
    clause_valid: jax.Array  # (..., C) bool

    def tree_flatten(self):
        return (self.active, self.lo, self.hi, self.clause_valid), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_clauses(self) -> int:
        return int(self.active.shape[-2])

    @staticmethod
    def none(m: int, clauses: int = 1) -> "PredicateSet":
        """Matches every row (one valid clause with no conditions)."""
        c = legalize_clause_count(clauses)
        return PredicateSet(
            active=jnp.zeros((c, m), bool),
            lo=jnp.full((c, m), -jnp.inf),
            hi=jnp.full((c, m), jnp.inf),
            clause_valid=jnp.arange(c) < 1,
        )

    @staticmethod
    def from_clauses(m: int, clauses: list[dict[int, tuple[float, float]]],
                     *, n_clauses: int | None = None) -> "PredicateSet":
        """Build from per-clause ``{col: (lo, hi)}`` dicts, padded onto the
        clause grid. An empty ``clauses`` list matches nothing."""
        c_real = len(clauses)
        c = legalize_clause_count(max(c_real, 1) if n_clauses is None
                                  else n_clauses)
        if c_real > c:
            raise ValueError(f"{c_real} clauses exceed requested bucket {c}")
        active = np.zeros((c, m), bool)
        lo = np.full((c, m), -np.inf, np.float32)
        hi = np.full((c, m), np.inf, np.float32)
        for ci, conds in enumerate(clauses):
            for idx, (l, h) in conds.items():
                active[ci, idx] = True
                lo[ci, idx] = l
                hi[ci, idx] = h
        valid = np.arange(c) < c_real
        return PredicateSet(jnp.asarray(active), jnp.asarray(lo),
                            jnp.asarray(hi), jnp.asarray(valid))


PredicateLike = Predicates | PredicateSet


def legalize_clause_count(c: int) -> int:
    """Smallest clause-grid bucket >= c."""
    for b in CLAUSE_GRID:
        if b >= c:
            return b
    raise ValueError(
        f"{c} clauses exceed the clause grid cap {MAX_CLAUSES}; simplify the "
        f"predicate or raise CLAUSE_GRID")


def as_set(pred: PredicateLike) -> PredicateSet:
    """Promote to the DNF form. ``Predicates`` lifts to one valid clause
    (a new clause axis at -2); a ``PredicateSet`` passes through."""
    if isinstance(pred, PredicateSet):
        return pred
    active = pred.active[..., None, :]
    return PredicateSet(
        active=active,
        lo=pred.lo[..., None, :],
        hi=pred.hi[..., None, :],
        clause_valid=jnp.ones(active.shape[:-1], bool),
    )


def n_clauses(pred: PredicateLike) -> int:
    """Static clause count (1 for the conjunctive shim)."""
    return pred.n_clauses if isinstance(pred, PredicateSet) else 1


def clause_bucket(pred: PredicateLike) -> int:
    """The legalized clause bucket — part of batched group keys so every
    query in a vmapped group shares one static clause shape."""
    return legalize_clause_count(n_clauses(pred))


def pad_clauses(ps: PredicateSet, c: int) -> PredicateSet:
    """Pad the clause axis (-2) to ``c`` with invalid clauses."""
    cur = ps.active.shape[-2]
    if cur == c:
        return ps
    if cur > c:
        raise ValueError(f"cannot shrink clause axis {cur} -> {c}")
    extra = c - cur
    pad2 = [(0, 0)] * (ps.active.ndim - 2) + [(0, extra), (0, 0)]
    pad1 = [(0, 0)] * (ps.clause_valid.ndim - 1) + [(0, extra)]
    return PredicateSet(
        active=jnp.pad(ps.active, pad2, constant_values=False),
        lo=jnp.pad(ps.lo, pad2, constant_values=-jnp.inf),
        hi=jnp.pad(ps.hi, pad2, constant_values=jnp.inf),
        clause_valid=jnp.pad(ps.clause_valid, pad1, constant_values=False),
    )


def active_any(pred: PredicateLike) -> jax.Array:
    """(..., M) bool — columns constrained in ANY valid clause (the
    clause-folded replacement for the old ``pred.active`` feature)."""
    if isinstance(pred, PredicateSet):
        return jnp.any(pred.active & pred.clause_valid[..., None], axis=-2)
    return pred.active


def stack(preds: list[PredicateLike]) -> PredicateLike:
    """Stack per-query predicate sets along a new leading batch axis — the
    batched pytree fed to vmapped search kernels.

    All-conjunctive lists stack as ``Predicates`` ((B, M) per field, the
    cheap C=1 path). If any entry is a ``PredicateSet``, every entry is
    promoted and clause-padded to the list's common bucket, giving
    ``(B, C, M)`` fields + ``(B, C)`` validity."""
    if all(isinstance(p, Predicates) for p in preds):
        return Predicates(
            active=jnp.stack([p.active for p in preds]),
            lo=jnp.stack([p.lo for p in preds]),
            hi=jnp.stack([p.hi for p in preds]),
        )
    c = legalize_clause_count(max(n_clauses(p) for p in preds))
    sets = [pad_clauses(as_set(p), c) for p in preds]
    return PredicateSet(
        active=jnp.stack([p.active for p in sets]),
        lo=jnp.stack([p.lo for p in sets]),
        hi=jnp.stack([p.hi for p in sets]),
        clause_valid=jnp.stack([p.clause_valid for p in sets]),
    )


def take(pred: PredicateLike, idx) -> PredicateLike:
    """Gather along the leading (batch) axis of a stacked predicate pytree."""
    return jax.tree_util.tree_map(lambda x: x[idx], pred)


def fold_conjunct(pred: PredicateLike, col_idx: int, lo: float,
                  hi: float) -> PredicateLike:
    """Intersect ``[lo, hi]`` on column ``col_idx`` into EVERY clause.

    This is how an implicit constraint (e.g. a tenant namespace) compiles
    into an existing predicate with zero new kernel surface: the clause
    count, bucket and ``clause_valid`` mask are untouched, so C-grid
    legalization and batched group keys are unchanged. A clause whose
    intersection with the range is empty ends up with ``lo > hi`` on an
    active column, which :func:`eval_mask` already evaluates as matching
    nothing. Idempotent: folding the same range twice is a no-op."""
    if isinstance(pred, PredicateSet):
        active = np.array(pred.active)
        los = np.array(pred.lo, np.float32)
        his = np.array(pred.hi, np.float32)
        active[..., col_idx] = True
        los[..., col_idx] = np.maximum(los[..., col_idx], np.float32(lo))
        his[..., col_idx] = np.minimum(his[..., col_idx], np.float32(hi))
        return PredicateSet(jnp.asarray(active), jnp.asarray(los),
                            jnp.asarray(his), pred.clause_valid)
    active = np.array(pred.active)
    los = np.array(pred.lo, np.float32)
    his = np.array(pred.hi, np.float32)
    active[..., col_idx] = True
    los[..., col_idx] = np.maximum(los[..., col_idx], np.float32(lo))
    his[..., col_idx] = np.minimum(his[..., col_idx], np.float32(hi))
    return Predicates(jnp.asarray(active), jnp.asarray(los), jnp.asarray(his))


def eval_mask(pred: PredicateLike, scalars: jax.Array) -> jax.Array:
    """(n, M) scalars -> (n,) bool DNF mask: OR over clauses of the AND over
    that clause's active columns. C=1 reproduces the old conjunction."""
    ps = as_set(pred)
    s = scalars[..., None, :]  # (n, 1, M) against (C, M) fields
    ok = (s >= ps.lo) & (s <= ps.hi)
    ok = ok | ~ps.active  # inactive columns always pass within a clause
    clause = jnp.all(ok, axis=-1) & ps.clause_valid  # (n, C)
    return jnp.any(clause, axis=-1)


def _encode_clause(active, lo, hi, edges):
    """Per-clause scalar encoding — the paper's §3.2 rule on one conjunction.

    ``edges``: (M, B+1) per-column bin edges. A point value one-hots into its
    bin; a range spreads unit mass over the bins it overlaps; an inactive
    column is maximum-entropy (uniform). Returns (M, B)."""
    clo = jnp.maximum(lo[:, None], edges[:, :-1])
    chi = jnp.minimum(hi[:, None], edges[:, 1:])
    width = jnp.maximum(edges[:, 1:] - edges[:, :-1], 1e-12)
    overlap = jnp.clip(chi - clo, 0.0, None) / width
    # point predicates (lo == hi) get an indicator on the containing bin
    point = (lo >= edges[:, :-1].T).T & (lo <= edges[:, 1:].T).T
    is_point = ((hi - lo) <= 1e-12) & (hi >= lo)
    mass = jnp.where(is_point[:, None], point.astype(jnp.float32), overlap)
    mass_sum = jnp.sum(mass, axis=-1, keepdims=True)
    uniform = jnp.full_like(mass, 1.0 / mass.shape[-1])
    enc = jnp.where(mass_sum > 0, mass / jnp.maximum(mass_sum, 1e-12), uniform)
    return jnp.where(active[:, None], enc, uniform)


def clause_weights(ps: PredicateSet, edges: jax.Array) -> jax.Array:
    """(C,) normalized per-clause masses under the bin-uniform measure.

    A clause's mass is the product over its active columns of the fraction
    of the column's edge span the clause's range covers (a point condition
    counts one bin). Invalid clauses weigh zero; if every clause has zero
    mass the weights fall back to uniform over the valid clauses."""
    span = jnp.maximum(edges[:, -1] - edges[:, 0], 1e-12)  # (M,)
    b = edges.shape[1] - 1

    def one(active, lo, hi):
        cov_lo = jnp.maximum(lo, edges[:, 0])
        cov_hi = jnp.minimum(hi, edges[:, -1])
        cov = jnp.clip(cov_hi - cov_lo, 0.0, None) / span
        is_point = ((hi - lo) <= 1e-12) & (hi >= lo)
        cov = jnp.where(is_point, 1.0 / b, cov)
        cov = jnp.where(active, cov, 1.0)
        return jnp.prod(cov)

    mass = jax.vmap(one)(ps.active, ps.lo, ps.hi)  # (C,)
    mass = jnp.where(ps.clause_valid, mass, 0.0)
    total = jnp.sum(mass)
    uniform = ps.clause_valid / jnp.maximum(jnp.sum(ps.clause_valid), 1)
    return jnp.where(total > 0, mass / jnp.maximum(total, 1e-12), uniform)


def soft_encode(pred: PredicateLike, edges: jax.Array) -> jax.Array:
    """Paper §3.2 'Scalar Encoding' generalized to DNF predicate sets.

    Each clause is encoded with the conjunctive rule (:func:`_encode_clause`)
    and the per-clause (M, B) masses are folded with the normalized clause
    weights — so the output keeps the (M, B) shape every consumer (S_enc,
    data-encoder input) already expects, and C=1 reproduces the old
    encoding exactly."""
    ps = as_set(pred)
    enc_c = jax.vmap(lambda a, l, h: _encode_clause(a, l, h, edges))(
        ps.active, ps.lo, ps.hi)  # (C, M, B)
    w = clause_weights(ps, edges)  # (C,)
    return jnp.einsum("c,cmb->mb", w, enc_c)


def value_encode(values: jax.Array, edges: jax.Array) -> jax.Array:
    """One-hot bin encoding of concrete scalar values. values: (M,) -> (M, B).

    ``side="right"`` matches the binning rule in ``histogram.build`` /
    ``update`` / ``_prefix_at`` exactly, so a value sitting on an interior
    bin edge one-hots into the SAME bin the selectivity stats count it in.
    """
    b = edges.shape[1] - 1
    idx = jnp.clip(
        jax.vmap(lambda e, v: jnp.searchsorted(e, v, side="right"))(
            edges, values) - 1, 0, b - 1
    )
    return jax.nn.one_hot(idx, b)
