"""Scalar predicates: representation, evaluation and soft encodings.

A conjunctive predicate set Q_S is stored densely over all M scalar columns:
``active`` marks which columns carry a condition; each condition is the
closed range ``[lo, hi]`` (equality for categoricals is ``[code, code]``).
Dense representation keeps the structure static under jit — an inactive
column is simply the full range.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Predicates:
    active: jax.Array  # (M,) bool
    lo: jax.Array  # (M,) f32
    hi: jax.Array  # (M,) f32

    def tree_flatten(self):
        return (self.active, self.lo, self.hi), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @staticmethod
    def none(m: int) -> "Predicates":
        return Predicates(
            active=jnp.zeros((m,), bool),
            lo=jnp.full((m,), -jnp.inf),
            hi=jnp.full((m,), jnp.inf),
        )

    @staticmethod
    def from_conditions(m: int, conds: dict[int, tuple[float, float]]) -> "Predicates":
        active = np.zeros((m,), bool)
        lo = np.full((m,), -np.inf, np.float32)
        hi = np.full((m,), np.inf, np.float32)
        for idx, (l, h) in conds.items():
            active[idx] = True
            lo[idx] = l
            hi[idx] = h
        return Predicates(jnp.asarray(active), jnp.asarray(lo), jnp.asarray(hi))


def stack(preds: list["Predicates"]) -> "Predicates":
    """Stack per-query predicate sets along a new leading batch axis — the
    batched pytree fed to vmapped search kernels ((B, M) per field)."""
    return Predicates(
        active=jnp.stack([p.active for p in preds]),
        lo=jnp.stack([p.lo for p in preds]),
        hi=jnp.stack([p.hi for p in preds]),
    )


def eval_mask(pred: Predicates, scalars: jax.Array) -> jax.Array:
    """(n, M) scalars -> (n,) bool conjunction mask."""
    ok = (scalars >= pred.lo) & (scalars <= pred.hi)
    ok = ok | ~pred.active  # inactive columns always pass
    return jnp.all(ok, axis=-1)


def soft_encode(
    pred: Predicates, edges: jax.Array
) -> jax.Array:
    """Paper §3.2 'Scalar Encoding' generalized to predicates.

    ``edges``: (M, B+1) per-column bin edges. A point value one-hots into its
    bin; a range spreads unit mass over the bins it overlaps; an inactive
    column is maximum-entropy (uniform). Returns (M, B).
    """
    lo = jnp.maximum(pred.lo[:, None], edges[:, :-1])
    hi = jnp.minimum(pred.hi[:, None], edges[:, 1:])
    width = jnp.maximum(edges[:, 1:] - edges[:, :-1], 1e-12)
    overlap = jnp.clip(hi - lo, 0.0, None) / width
    # point predicates (lo == hi) get an indicator on the containing bin
    point = (pred.lo >= edges[:, :-1].T).T & (pred.lo <= edges[:, 1:].T).T
    is_point = (pred.hi - pred.lo)[:, None] <= 1e-12
    mass = jnp.where(is_point, point.astype(jnp.float32), overlap)
    mass_sum = jnp.sum(mass, axis=-1, keepdims=True)
    uniform = jnp.full_like(mass, 1.0 / mass.shape[-1])
    enc = jnp.where(mass_sum > 0, mass / jnp.maximum(mass_sum, 1e-12), uniform)
    return jnp.where(pred.active[:, None], enc, uniform)


def value_encode(values: jax.Array, edges: jax.Array) -> jax.Array:
    """One-hot bin encoding of concrete scalar values. values: (M,) -> (M, B)."""
    b = edges.shape[1] - 1
    idx = jnp.clip(
        jax.vmap(jnp.searchsorted)(edges, values) - 1, 0, b - 1
    )
    return jax.nn.one_hot(idx, b)
