"""Predicate builder algebra: composable AND/OR/NOT/IN expressions that
``compile()`` to the static-shape DNF :class:`~repro.vectordb.predicates.PredicateSet`.

Usage::

    from repro.vectordb.algebra import col

    expr = col("price").between(10, 50) | (col("brand") == 3) \
        & ~col("size").below(5)
    pred = expr.compile(table.schema)          # names need a schema
    pred = (col(2) >= 4.0).compile(m=4)        # integer columns need only M

Columns are referenced by name (resolved against ``TableSchema.scalar_cols``
at compile time) or by integer index. Atoms are closed ranges ``[lo, hi]``
over the float32 scalar storage; strict bounds (``<``, ``>``, NOT of a
range) are exact via ``nextafter`` in float32, so the compiled closed-range
form evaluates identically to the strict comparison on float32 data.

Compilation pipeline:
  1. push NOT down to the atoms (De Morgan; a negated range splits into at
     most two complement ranges),
  2. expand to DNF (OR of conjunctive clauses; AND distributes as the cross
     product of its operands' clause lists),
  3. per clause, intersect conditions that share a column; drop clauses made
     empty by the intersection; dedupe identical clauses,
  4. pad the clause count onto ``CLAUSE_GRID`` (invalid padding clauses
     match nothing) — the jit cache specializes per bucket, not per count.

A predicate that simplifies to *false* (e.g. ``c < 1 & c > 2``) compiles to
a set whose single clause is invalid: it evaluates to an all-False mask.
Expressions whose DNF exceeds ``MAX_CLAUSES`` raise — the grid is the API's
complexity budget.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.vectordb.predicates import (
    MAX_CLAUSES, PredicateSet, legalize_clause_count,
)

# intermediate-expansion guard: DNF cross products may transiently exceed
# the final clause count before intersection/dedup collapses them
_EXPANSION_CAP = 256


def _f32(v) -> float:
    return float(np.float32(v))


def _next_below(v: float) -> float:
    return float(np.nextafter(np.float32(v), np.float32(-np.inf)))


def _next_above(v: float) -> float:
    return float(np.nextafter(np.float32(v), np.float32(np.inf)))


class Expr:
    """Base class: boolean composition plus compilation."""

    def __and__(self, other: "Expr") -> "Expr":
        return And((self, other))

    def __or__(self, other: "Expr") -> "Expr":
        return Or((self, other))

    def __invert__(self) -> "Expr":
        return Not(self)

    def compile(self, schema=None, *, m: int | None = None,
                n_clauses: int | None = None) -> PredicateSet:
        """Compile to a clause-grid-legalized ``PredicateSet``.

        ``schema``: a ``TableSchema`` (resolves column names and provides M).
        ``m``: the scalar column count when every column is an integer index.
        ``n_clauses``: optional explicit bucket (grid-legalized)."""
        return compile(self, schema, m=m, n_clauses=n_clauses)


@dataclasses.dataclass(frozen=True)
class Cond(Expr):
    """Atomic closed-range condition ``col ∈ [lo, hi]``."""

    col: int | str
    lo: float
    hi: float


@dataclasses.dataclass(frozen=True)
class And(Expr):
    parts: tuple


@dataclasses.dataclass(frozen=True)
class Or(Expr):
    parts: tuple


@dataclasses.dataclass(frozen=True)
class Not(Expr):
    part: Expr


class ColumnRef:
    """Named/indexed column handle producing atomic conditions."""

    __slots__ = ("_col",)

    def __init__(self, column: int | str):
        self._col = column

    def between(self, lo, hi) -> Cond:
        """Closed range ``lo <= x <= hi``."""
        return Cond(self._col, _f32(lo), _f32(hi))

    def isin(self, values) -> Expr:
        """IN-list: equality with any of ``values`` (one clause each)."""
        vals = [_f32(v) for v in values]
        if not vals:
            return Or(())  # empty IN-list is false
        return Or(tuple(Cond(self._col, v, v) for v in vals))

    def below(self, v) -> Cond:
        """Strict ``x < v``."""
        return Cond(self._col, -np.inf, _next_below(v))

    def above(self, v) -> Cond:
        """Strict ``x > v``."""
        return Cond(self._col, _next_above(v), np.inf)

    def __eq__(self, v) -> Cond:  # type: ignore[override]
        return Cond(self._col, _f32(v), _f32(v))

    def __ne__(self, v) -> Expr:  # type: ignore[override]
        return Not(Cond(self._col, _f32(v), _f32(v)))

    def __le__(self, v) -> Cond:
        return Cond(self._col, -np.inf, _f32(v))

    def __lt__(self, v) -> Cond:
        return self.below(v)

    def __ge__(self, v) -> Cond:
        return Cond(self._col, _f32(v), np.inf)

    def __gt__(self, v) -> Cond:
        return self.above(v)

    __hash__ = None  # rich __eq__ builds conditions; refs are not hashable


def col(column: int | str) -> ColumnRef:
    """Entry point of the builder: ``col("price")`` or ``col(3)``."""
    return ColumnRef(column)


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

def _negate(e: Expr) -> Expr:
    """Push one NOT through ``e`` (De Morgan down to the atoms)."""
    if isinstance(e, Not):
        return e.part
    if isinstance(e, And):
        return Or(tuple(_negate(p) for p in e.parts))
    if isinstance(e, Or):
        return And(tuple(_negate(p) for p in e.parts))
    assert isinstance(e, Cond)
    parts = []
    if np.isfinite(e.lo):
        parts.append(Cond(e.col, -np.inf, _next_below(e.lo)))
    if np.isfinite(e.hi):
        parts.append(Cond(e.col, _next_above(e.hi), np.inf))
    return Or(tuple(parts))  # empty (full-range atom) -> false


def _intersect(clause: dict, cond: Cond) -> dict | None:
    """Merge an atom into a conjunctive clause; None = empty clause."""
    lo, hi = clause.get(cond.col, (-np.inf, np.inf))
    lo, hi = max(lo, cond.lo), min(hi, cond.hi)
    if lo > hi:
        return None
    out = dict(clause)
    out[cond.col] = (lo, hi)
    return out


def _dnf(e: Expr) -> list[dict]:
    """-> clauses as {col: (lo, hi)} dicts (empty list = false)."""
    if isinstance(e, Not):
        return _dnf(_negate(e.part))
    if isinstance(e, Cond):
        return [{e.col: (e.lo, e.hi)}]
    if isinstance(e, Or):
        out = []
        for p in e.parts:
            out.extend(_dnf(p))
            if len(out) > _EXPANSION_CAP:
                raise ValueError("predicate DNF expansion too large")
        return _dedupe(out)
    assert isinstance(e, And)
    clauses: list[dict] = [{}]
    for p in e.parts:
        nxt = []
        for pc in _dnf(p):
            for c in clauses:
                merged = c
                for ccol, (lo, hi) in pc.items():
                    merged = _intersect(merged, Cond(ccol, lo, hi))
                    if merged is None:
                        break
                if merged is not None:
                    nxt.append(merged)
            if len(nxt) > _EXPANSION_CAP:
                raise ValueError("predicate DNF expansion too large")
        clauses = nxt
        if not clauses:
            return []
    return _dedupe(clauses)


def _dedupe(clauses: list[dict]) -> list[dict]:
    seen, out = set(), []
    for c in clauses:
        key = tuple(sorted(c.items()))
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


def _resolve(clauses: list[dict], schema, m: int | None) -> tuple[list[dict], int]:
    names = {}
    if schema is not None:
        names = {sc.name: i for i, sc in enumerate(schema.scalar_cols)}
        m = len(schema.scalar_cols)
    if m is None:
        raise ValueError("compile() needs a schema or m=<n_scalar_columns>")
    out = []
    for c in clauses:
        rc = {}
        for key, rng in c.items():
            if isinstance(key, str):
                if key not in names:
                    raise KeyError(f"unknown scalar column {key!r}")
                idx = names[key]
            else:
                idx = int(key)
            if not 0 <= idx < m:
                raise IndexError(f"scalar column {idx} out of range [0, {m})")
            # two names may alias one index only through a schema bug; merge
            if idx in rc:
                lo, hi = rc[idx]
                rng = (max(lo, rng[0]), min(hi, rng[1]))
            rc[idx] = rng
        out.append(rc)
    return out, m


def constrain(pred, cond: Cond, schema=None, *, m: int | None = None):
    """Fold an atomic condition conjunctively into EVERY clause of an
    already-compiled predicate (``Predicates`` or ``PredicateSet``).

    This is the compile step for implicit constraints — tenant namespaces
    fold ``tenant == t`` into an existing DNF without changing its clause
    bucket or touching kernels. The column is resolved exactly like
    :func:`compile` (by name against ``schema.scalar_cols`` or by index
    against ``m``)."""
    from repro.vectordb.predicates import fold_conjunct

    resolved, _ = _resolve([{cond.col: (cond.lo, cond.hi)}], schema,
                           m if m is not None else pred.active.shape[-1])
    ((idx, (lo, hi)),) = resolved[0].items()
    return fold_conjunct(pred, idx, lo, hi)


def compile(expr: Expr, schema=None, *, m: int | None = None,
            n_clauses: int | None = None) -> PredicateSet:
    """Compile an expression tree to a ``PredicateSet`` (see module doc)."""
    if isinstance(expr, ColumnRef):
        raise TypeError("a bare col(...) is not a predicate; add a condition")
    clauses = _dnf(expr)
    clauses, m = _resolve(clauses, schema, m)
    if len(clauses) > MAX_CLAUSES:
        raise ValueError(
            f"predicate compiles to {len(clauses)} DNF clauses, more than the "
            f"clause-grid cap {MAX_CLAUSES}; simplify the expression")
    if n_clauses is not None:
        n_clauses = legalize_clause_count(max(n_clauses, len(clauses)))
    return PredicateSet.from_clauses(m, clauses, n_clauses=n_clauses)
