"""Tiered hot/cold table: LSM-style streaming ingest over the IVF tier.

The serving stack was build-once: ``ivf.extend`` regrouped buckets eagerly
and every insert rebuilt the executor, so live traffic had no path from an
insert to the probing tier. This module adds the two-tier table that closes
that gap (ROADMAP open item 1):

* a small writable **hot segment** — flat, bounded capacity, append-only.
  Queries always score it candidate-locally with the fused
  ``kernels.gather_score`` kernel and filter the predicate EXACTLY, so hot
  rows never cost recall; the segment is bounded, so the extra scan is
  O(capacity) per batch regardless of table size.
* sealed **cold state** — the existing ``Table`` + per-column IVF indexes
  (and, under ``bind_shards``, the ``ShardedIVF`` tier built from them),
  searched through the unchanged plan-driven probing paths.

Row ids are GLOBAL: cold rows keep ``[0, n_cold)`` and hot rows are numbered
``id_offset + local_slot`` where ``id_offset`` is the cold row count when
their hot generation opened. Compaction appends a generation's rows to the
cold table at exactly those positions, so ids are stable across the
hot→cold transition and the existing O(shards·k) dedup merge, the underfill
escalation and the recall contracts all survive unchanged.

Concurrency model — the **epoch-swap protocol**:

* All mutable state (``_hot``/``_sealing``/``_cold``/``_epoch``) lives
  behind one condition lock and is NEVER read by serving code. Queries call
  ``snapshot()`` once at batch-formation time and execute the whole batch
  against that immutable ``TieredSnapshot`` — boomlint rule EP001 enforces
  this repo-wide (docs/analysis.md).
* Inserts append in place into the active generation's buffers. Appends
  only ever touch rows at-or-beyond every published snapshot's ``count``,
  which the candidate mask excludes, so in-flight queries are isolated
  without copying.
* When the active generation fills it is **sealed** (frozen view published
  alongside a fresh empty generation) and **compaction** — normally on a
  background worker thread (``serve.queue.CompactionScheduler``, the
  ``AsyncServingEngine`` worker-pool pattern) — folds the sealed rows into
  the cold table/indexes via the incremental ``ivf.extend`` path, then
  publishes the new cold state by swapping the snapshot pointer and
  bumping the **epoch**. Serving never pauses: batches formed before the
  swap keep their old snapshot, batches formed after read the new one.
* Only the INGEST side ever blocks (backpressure): an insert that outruns
  both generations waits for the in-flight compaction, or runs one inline
  on the caller's thread.

The epoch also drives the accounting that keeps plans honest as data
drifts: compaction re-bins the sealed rows into the selectivity histograms
(planning sees them once they are cold; hot rows are exact-filtered so they
need no estimate) and ``rows_since_finetune`` tracks encoder staleness
until the owner's finetune callback clears it.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.vectordb import graph as graphmod
from repro.vectordb import histogram, ivf
from repro.vectordb.table import Table

DEFAULT_HOT_CAPACITY = 1024


# host->device materializations of hot views, for the per-insert transfer
# accounting (one "transfer" = one column buffer moved); see hot_view_transfers
_transfer_lock = threading.Lock()
_hot_view_transfers = 0


def hot_view_transfers() -> int:
    """Cumulative count of hot-view column buffers copied host->device.

    Publishing a view is free — the device copies are built lazily on the
    first reader — so the delta across an insert-only window (no query
    snapshots consumed) must be 0."""
    with _transfer_lock:
        return _hot_view_transfers


class HotView:
    """Immutable logical view of one hot generation at a published instant.

    Construction is a host-side token: it captures REFERENCES to the
    generation's full-capacity host buffers plus ``count``/``id_offset``.
    The device copies (static shapes keep the jit cache bounded) are built
    LAZILY — on the first ``vectors``/``scalars`` read, i.e. the first query
    snapshot that actually scores this view — and cached per view, so
    insert-heavy windows with no interleaved reads publish versions at zero
    transfer cost (``hot_view_transfers`` counts the copies).

    Only rows ``< count`` are valid: the candidate mask in the hot top-k
    excludes the rest, and appends only ever write rows at-or-beyond every
    published view's ``count``, so a late materialization still reads
    exactly the rows the view logically froze."""

    __slots__ = ("np_vectors", "np_scalars", "count", "id_offset",
                 "_device", "_lock")

    def __init__(self, np_vectors: tuple, np_scalars: np.ndarray,
                 count: int, id_offset: int):
        self.np_vectors = tuple(np_vectors)  # per-column (capacity, d_i) f32
        self.np_scalars = np_scalars  # (capacity, M) f32
        self.count = count  # valid rows
        self.id_offset = id_offset  # global row id of local slot 0
        self._device = None
        self._lock = threading.Lock()

    def _materialize(self):
        if self._device is None:
            with self._lock:
                if self._device is None:
                    global _hot_view_transfers
                    dev = (tuple(jnp.asarray(b) for b in self.np_vectors),
                           jnp.asarray(self.np_scalars))
                    with _transfer_lock:
                        _hot_view_transfers += len(self.np_vectors) + 1
                    self._device = dev
        return self._device

    @property
    def vectors(self) -> tuple:
        return self._materialize()[0]

    @property
    def scalars(self) -> jax.Array:
        return self._materialize()[1]

    @property
    def capacity(self) -> int:
        return int(self.np_scalars.shape[0])


@dataclasses.dataclass(frozen=True)
class ColdState:
    """One sealed cold epoch: table + per-column IVF + histograms, plus the
    optional per-column proximity graphs (the third-strategy tier — sealed
    exactly like the IVF state, extended on compaction, ``None`` when the
    deployment has no graph tier)."""

    table: Table
    indexes: tuple
    hists: histogram.Histograms
    graphs: tuple | None = None


@dataclasses.dataclass(frozen=True)
class TieredSnapshot:
    """The consistent ``(epoch, hot_view, cold_shards)`` unit every batch
    executes against. Immutable — a swap publishes a NEW snapshot; nothing
    a formed batch holds ever mutates."""

    epoch: int
    cold: ColdState
    hot_views: tuple  # 0..2 HotView (active [+ sealing during compaction])

    @property
    def n_hot(self) -> int:
        return sum(v.count for v in self.hot_views)

    @property
    def n_rows(self) -> int:
        """Logical row count (cold + every hot view)."""
        return self.cold.table.n_rows + self.n_hot


class _HotBuffer:
    """Mutable append-only host-side backing of one hot generation."""

    def __init__(self, schema, capacity: int, id_offset: int):
        self.vectors = [np.zeros((capacity, vc.dim), np.float32)
                        for vc in schema.vector_cols]
        self.scalars = np.zeros((capacity, schema.n_scalar), np.float32)
        self.count = 0
        self.id_offset = id_offset
        self.capacity = capacity

    def write(self, vecs: list, scal: np.ndarray, pos: int, take: int) -> None:
        lo = self.count
        for buf, v in zip(self.vectors, vecs):
            buf[lo: lo + take] = v[pos: pos + take]
        self.scalars[lo: lo + take] = scal[pos: pos + take]
        self.count += take

    def view(self) -> HotView:
        # a host-side token over the live buffers: rows >= count are stale
        # garbage (or rows appended after this publish) and masked out by
        # every consumer; device copies happen on first read (lazy)
        return HotView(
            np_vectors=tuple(self.vectors),
            np_scalars=self.scalars,
            count=self.count,
            id_offset=self.id_offset,
        )


class TieredTable:
    """Writable hot segment in front of sealed cold IVF state.

    Owns ALL mutable tiering state. Serving code must read through
    ``snapshot()`` (EP001); ingest goes through ``insert()``; compaction
    through ``compact()`` — safe from any thread.
    """

    def __init__(self, table: Table, indexes, hists, *,
                 hot_capacity: int = DEFAULT_HOT_CAPACITY,
                 rebuild_every: int = 0,
                 finetune_cb: Optional[Callable] = None,
                 graphs=None):
        assert hot_capacity >= 1
        self.schema = table.schema
        self.hot_capacity = hot_capacity
        # sealing step: every Nth compaction re-clusters the whole column
        # (full k-means rebuild) instead of the incremental centroid-assign
        # extend; 0 = incremental only
        self.rebuild_every = rebuild_every
        self.finetune_cb = finetune_cb
        self._cond = threading.Condition()
        self._cold = ColdState(
            table, tuple(indexes), hists,
            tuple(graphs) if graphs is not None else None)
        self._hot = _HotBuffer(table.schema, hot_capacity,
                               id_offset=table.n_rows)
        self._sealing: Optional[HotView] = None
        self._compacting = False
        self._epoch = 0
        self._compactions = 0
        self._inserted = 0
        self._rows_since_finetune = 0
        self._snap = self._build_snapshot()

    # -- the one sanctioned read path --------------------------------------

    def snapshot(self) -> TieredSnapshot:
        """The current published ``(epoch, hot_view, cold_shards)`` —
        ONE atomic pointer read. Take it once at batch formation and use it
        for the whole batch; never read the mutable fields (EP001)."""
        return self._snap

    # -- bookkeeping (host-side, locked) ------------------------------------

    def _build_snapshot(self) -> TieredSnapshot:
        views = []
        if self._sealing is not None and self._sealing.count > 0:
            views.append(self._sealing)
        if self._hot.count > 0:
            views.append(self._hot.view())
        return TieredSnapshot(epoch=self._epoch, cold=self._cold,
                              hot_views=tuple(views))

    def _publish_locked(self) -> None:
        self._snap = self._build_snapshot()

    def _seal_locked(self) -> None:
        """Freeze the (full) active generation and open a fresh one whose
        id space starts right behind it."""
        assert self._sealing is None
        self._sealing = self._hot.view()
        self._hot = _HotBuffer(
            self.schema, self.hot_capacity,
            id_offset=self._sealing.id_offset + self._sealing.count)
        self._publish_locked()

    # -- ingest -------------------------------------------------------------

    def insert(self, vectors: list, scalars) -> dict:
        """Append rows to the hot segment; global ids are assigned in
        arrival order. Never blocks serving — only the INGEST caller waits
        (or compacts inline) when both generations are full."""
        vecs = [np.asarray(v, np.float32) for v in vectors]
        scal = np.asarray(scalars, np.float32)
        m = int(scal.shape[0])
        pos = 0
        while pos < m:
            run_inline = False
            with self._cond:
                free = self._hot.capacity - self._hot.count
                if free > 0:
                    take = min(free, m - pos)
                    self._hot.write(vecs, scal, pos, take)
                    pos += take
                    self._publish_locked()
                    if pos == m:
                        break
                    continue
                # active generation full and rows remain: make room
                if self._compacting:
                    # backpressure: the in-flight compaction publishes soon
                    self._cond.wait(timeout=30.0)
                    continue
                if self._sealing is None:
                    self._seal_locked()
                    continue
                run_inline = True  # sealed segment pending, no worker
            if run_inline:
                self.compact()
        with self._cond:
            self._inserted += m
            self._rows_since_finetune += m
            return {"inserted": m, "hot_fill": self._hot.count,
                    "hot_capacity": self.hot_capacity,
                    "needs_compaction": self._needs_compaction_locked(),
                    "epoch": self._epoch}

    def _needs_compaction_locked(self) -> bool:
        return self._sealing is not None or \
            self._hot.count >= self.hot_capacity

    def needs_compaction(self) -> bool:
        with self._cond:
            return self._needs_compaction_locked() and not self._compacting

    # -- compaction ---------------------------------------------------------

    def compact(self) -> dict:
        """Fold the sealed hot generation into the cold state and publish
        under a new epoch. Heavy work (cluster assignment, bucket insert,
        histogram re-bin, optional encoder finetune) runs OUTSIDE the lock;
        in-flight batches keep their pre-swap snapshot throughout."""
        t0 = time.perf_counter()
        with self._cond:
            if self._compacting:
                return {"compacted": 0, "epoch": self._epoch}
            if self._sealing is None:
                if self._hot.count == 0:
                    return {"compacted": 0, "epoch": self._epoch}
                self._seal_locked()
            frozen = self._sealing
            cold = self._cold
            # the rebuild_every decision is a function of WHICH compaction
            # this is — capture the sequence number under the lock at seal
            # time (reading self._compactions in the unlocked section below
            # raced concurrent compactions and could skip or double-fire
            # the re-cluster)
            seq = self._compactions + 1
            self._compacting = True
        rebuild = self.rebuild_every > 0 and seq % self.rebuild_every == 0
        try:
            n = frozen.count
            first_new = cold.table.n_rows
            assert first_new == frozen.id_offset  # global ids stay stable
            new_vecs = [b[:n] for b in frozen.np_vectors]
            new_scal = frozen.np_scalars[:n]
            table = cold.table.append(new_vecs, new_scal)
            if rebuild:  # sealing step: full re-cluster of every column
                indexes = tuple(
                    ivf.build(v, idx.n_clusters, seed=i, metric=idx.metric)
                    for i, (idx, v) in enumerate(
                        zip(cold.indexes, table.vectors)))
            else:  # steady state: nearest-centroid incremental insert
                indexes = tuple(
                    ivf.extend(idx, jnp.asarray(v), first_new)
                    for idx, v in zip(cold.indexes, new_vecs))
            hists = histogram.update(cold.hists, jnp.asarray(new_scal))
            # the graph tier seals alongside the IVF state: new rows get
            # forward edges against the full post-append column plus
            # reverse-edge splices into their neighbors' free slots
            # (graph.extend keeps the incremental path even on rebuild
            # compactions — re-running the full kNN+prune build per sealing
            # step would dominate the compaction)
            graphs = None if cold.graphs is None else tuple(
                graphmod.extend(g, jnp.asarray(v), first_new)
                for g, v in zip(cold.graphs, table.vectors))
            new_cold = ColdState(table, indexes, hists, graphs)
            if self.finetune_cb is not None:
                self.finetune_cb(new_cold, first_new, n)
                with self._cond:
                    self._rows_since_finetune = max(
                        0, self._rows_since_finetune - n)
        except BaseException:
            with self._cond:  # leave the sealed segment intact for a retry
                self._compacting = False
                self._cond.notify_all()
            raise
        with self._cond:
            self._cold = new_cold
            self._sealing = None
            self._epoch += 1
            self._compactions += 1
            self._compacting = False
            self._publish_locked()
            self._cond.notify_all()
        return {"compacted": n, "epoch": self._epoch, "rebuild": rebuild,
                "seconds": time.perf_counter() - t0}

    # -- accounting ---------------------------------------------------------

    @property
    def epoch(self) -> int:
        with self._cond:
            return self._epoch

    @property
    def n_compactions(self) -> int:
        with self._cond:
            return self._compactions

    @property
    def n_inserted(self) -> int:
        with self._cond:
            return self._inserted

    def encoder_staleness(self) -> float:
        """Fraction of logical rows the data encoder has never seen —
        epoch-fed drift accounting for the owner's finetune policy."""
        with self._cond:
            snap = self._snap
            return self._rows_since_finetune / max(1, snap.n_rows)

    def logical_table(self) -> Table:
        """Materialize the concatenated logical table (cold ‖ hot views) —
        for oracles, ground truth and offline use, NOT the serving path."""
        snap = self.snapshot()
        t = snap.cold.table
        if not snap.hot_views:
            return t
        vecs = [np.asarray(v) for v in t.vectors]
        scal = np.asarray(t.scalars)
        for view in snap.hot_views:
            vecs = [np.concatenate([a, b[: view.count]])
                    for a, b in zip(vecs, view.np_vectors)]
            scal = np.concatenate([scal, view.np_scalars[: view.count]])
        return Table.from_numpy(t.schema, vecs, scal)


# ---------------------------------------------------------------------------
# hot-segment scoring + merge (the query-side half)
# ---------------------------------------------------------------------------

def _hot_topk(view_args, qs, weights, pred_b, *, k: int, metric: str):
    """Exact filtered top-k over one hot view for a padded query batch.

    Candidate slots are the full static capacity masked down to ``count``,
    scored with the fused candidate-local kernel — the hot segment is just
    one more candidate source. Local slot ids map to global ids via the
    view's offset."""
    from repro.kernels.gather_score import gather_score_topk

    vectors, scalars, count, id_offset = view_args
    cap = scalars.shape[0]
    b = weights.shape[0]
    slots = jnp.arange(cap, dtype=jnp.int32)
    cand = jnp.where(slots[None, :] < count, slots[None, :], -1)
    cand = jnp.broadcast_to(cand, (b, cap)).astype(jnp.int32)
    ids, scores, n_qual = gather_score_topk(
        cand, vectors, qs, weights, scalars, pred_b, k=k, metric=metric)
    ids = jnp.where(ids >= 0, ids + id_offset, -1).astype(jnp.int32)
    return ids, scores, n_qual


@partial(jax.jit, static_argnames=("k", "metric"))
def merge_hot_batch(cold_ids, cold_scores, views, qs, weights, pred_b, *,
                    k: int, metric: str):
    """Fold every hot view's exact candidates into the cold results through
    the existing O(shards·k) dedup merge (``merge_topk_unique``): the hot
    segment rides the same contract as one more shard. Hot and cold id
    spaces are disjoint by construction, so dedup is a no-op and ties break
    by smaller global id exactly like the sharded merge.

    ``views``: tuple of (vectors, scalars, count, id_offset) pytrees —
    count/id_offset ride as traced scalars so inserts never recompile;
    only the view COUNT (1 vs 2, during compaction) and the static shapes
    key the jit cache."""
    from repro.kernels.gather_score import merge_topk_unique

    all_ids, all_scores = [cold_ids], [cold_scores]
    for view_args in views:
        ids, scores, _ = _hot_topk(view_args, qs, weights, pred_b,
                                   k=k, metric=metric)
        all_ids.append(ids)
        all_scores.append(scores)
    return merge_topk_unique(jnp.concatenate(all_ids, axis=1),
                             jnp.concatenate(all_scores, axis=1), k)


def view_args(view: HotView):
    """HotView -> the traced pytree ``merge_hot_batch`` consumes."""
    return (view.vectors, view.scalars,
            jnp.asarray(view.count, jnp.int32),
            jnp.asarray(view.id_offset, jnp.int32))
