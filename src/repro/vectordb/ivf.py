"""IVF-Flat index: k-means clustering + probe-based search.

TPU adaptation of the paper's HNSW substrate (DESIGN.md §2): the navigable
graph becomes a cluster decomposition; ``ef_search`` becomes ``nprobe``;
``max_scan_tuples`` caps the gathered candidate count; ``iterative_scan``
becomes nprobe re-expansion when the filtered result underfills k.

Everything is static-shape jit-able: the probed clusters' rows are mapped to
a fixed ``max_scan`` slot array via a prefix-sum + searchsorted trick, so a
single fused gather/score/mask/top-k runs on device regardless of how many
rows each cluster holds.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.shapes import GATHER_BLOCK_S, NEG
from repro.vectordb.predicates import PredicateLike, eval_mask
from repro.vectordb.table import similarity


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IVFIndex:
    centroids: jax.Array  # (C, d)
    sorted_rows: jax.Array  # (n,) i32 — row ids grouped by cluster
    offsets: jax.Array  # (C+1,) i32 — cluster c owns sorted_rows[offsets[c]:offsets[c+1]]
    metric: str

    def tree_flatten(self):
        return (self.centroids, self.sorted_rows, self.offsets), self.metric

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, metric=aux)

    @property
    def n_clusters(self) -> int:
        return int(self.centroids.shape[0])


# ---------------------------------------------------------------------------
# k-means
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_clusters", "iters"))
def _kmeans(vectors: jax.Array, key: jax.Array, n_clusters: int, iters: int = 12):
    n = vectors.shape[0]
    idx = jax.random.choice(key, n, (n_clusters,), replace=False)
    cent = vectors[idx]

    def step(cent, _):
        d = (
            jnp.sum(cent * cent, axis=1)[None, :]
            - 2.0 * (vectors @ cent.T)
        )  # (n, C) up to +||v||² const
        assign = jnp.argmin(d, axis=1)
        one = jax.nn.one_hot(assign, n_clusters, dtype=jnp.float32)
        counts = one.sum(0)
        sums = one.T @ vectors
        newc = sums / jnp.maximum(counts[:, None], 1.0)
        # dead centroids keep their old position
        newc = jnp.where(counts[:, None] > 0, newc, cent)
        return newc, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    d = jnp.sum(cent * cent, axis=1)[None, :] - 2.0 * (vectors @ cent.T)
    assign = jnp.argmin(d, axis=1)
    return cent, assign


def build(vectors: jax.Array, n_clusters: int, seed: int = 0, iters: int = 12,
          metric: str = "dot") -> IVFIndex:
    cent, assign = _kmeans(vectors, jax.random.PRNGKey(seed), n_clusters, iters)
    assign_np = np.asarray(assign)
    order = np.argsort(assign_np, kind="stable").astype(np.int32)
    counts = np.bincount(assign_np, minlength=n_clusters)
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return IVFIndex(
        centroids=cent,
        sorted_rows=jnp.asarray(order),
        offsets=jnp.asarray(offsets),
        metric=metric,
    )


# Below this fraction of the existing rows an insert takes the incremental
# splice (one O(n + m) np.insert, no sort) instead of the full regroup —
# the compaction path's steady state folds one bounded hot segment at a
# time, always far under this.
EXTEND_INCREMENTAL_FRACTION = 0.25


def _assign_to_centroids(index: IVFIndex, new_vectors: jax.Array) -> np.ndarray:
    d = (
        jnp.sum(index.centroids * index.centroids, axis=1)[None, :]
        - 2.0 * (new_vectors @ index.centroids.T)
    )
    return np.asarray(jnp.argmin(d, axis=1))


def _extend_regroup(index: IVFIndex, assign: np.ndarray,
                    rows: np.ndarray) -> IVFIndex:
    old_rows = np.asarray(index.sorted_rows)
    old_off = np.asarray(index.offsets)
    C = index.n_clusters
    # One vectorized regroup pass, O((n + inserts) log): a stable sort of
    # [old assignments ‖ new assignments] keeps each cluster's existing rows
    # in order and appends the new rows in insertion order behind them.
    old_assign = np.repeat(np.arange(C), np.diff(old_off))
    all_assign = np.concatenate([old_assign, assign])
    all_rows = np.concatenate([old_rows, rows]).astype(np.int32)
    order = np.argsort(all_assign, kind="stable")
    counts = np.bincount(all_assign, minlength=C)
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return IVFIndex(
        centroids=index.centroids,
        sorted_rows=jnp.asarray(all_rows[order]),
        offsets=jnp.asarray(offsets),
        metric=index.metric,
    )


def _extend_incremental(index: IVFIndex, assign: np.ndarray,
                        rows: np.ndarray) -> IVFIndex:
    """Splice the new rows into their clusters without re-sorting the whole
    layout: every new row lands at the END of its cluster's segment
    (``np.insert`` is stable at equal positions, so rows sharing a cluster
    keep insertion order) — byte-identical to the regroup semantics at
    O(n + m) instead of O((n + m) log (n + m))."""
    old_rows = np.asarray(index.sorted_rows)
    old_off = np.asarray(index.offsets)
    C = index.n_clusters
    pos = old_off[assign + 1]  # insert just before the next cluster's rows
    sorted_rows = np.insert(old_rows, pos, rows).astype(np.int32)
    counts = np.bincount(assign, minlength=C)
    offsets = (old_off + np.concatenate(
        [[0], np.cumsum(counts)])).astype(np.int32)
    return IVFIndex(
        centroids=index.centroids,
        sorted_rows=jnp.asarray(sorted_rows),
        offsets=jnp.asarray(offsets),
        metric=index.metric,
    )


def extend(index: IVFIndex, new_vectors: jax.Array, first_new_row: int) -> IVFIndex:
    """Insert rows into existing clusters (centroids unchanged) — the cheap
    maintenance path that matches the paper's buffer-then-integrate updates
    and the tiered compaction's hot→cold fold. Small inserts (the steady
    compaction case) take the incremental splice; large ones the vectorized
    regroup — both produce identical layouts. The full re-cluster
    (``build``) stays the sealing step (``TieredTable.rebuild_every``)."""
    assign = _assign_to_centroids(index, new_vectors)
    rows = np.arange(first_new_row, first_new_row + new_vectors.shape[0],
                     dtype=np.int32)
    n_old = int(index.sorted_rows.shape[0])
    if rows.shape[0] <= max(1, int(n_old * EXTEND_INCREMENTAL_FRACTION)):
        return _extend_incremental(index, assign, rows)
    return _extend_regroup(index, assign, rows)


# ---------------------------------------------------------------------------
# probing search
# ---------------------------------------------------------------------------

def _candidate_slots(index: IVFIndex, probe_clusters: jax.Array, max_scan: int):
    """Map ``max_scan`` static slots onto the rows of the probed clusters.

    Returns (row_ids (max_scan,), valid (max_scan,)).
    """
    starts = index.offsets[probe_clusters]
    sizes = index.offsets[probe_clusters + 1] - starts
    cum = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes)])
    total = cum[-1]
    slots = jnp.arange(max_scan, dtype=jnp.int32)
    which = jnp.clip(jnp.searchsorted(cum, slots, side="right") - 1, 0, sizes.shape[0] - 1)
    within = slots - cum[which]
    valid = slots < jnp.minimum(total, max_scan)
    gather_pos = jnp.clip(starts[which] + within, 0, index.sorted_rows.shape[0] - 1)
    return index.sorted_rows[gather_pos], valid


def probe_scan_budget(n_clusters: int, n_rows: int, *, nprobe: int,
                      probe_k: int) -> int:
    """Candidate budget of one neighborhood pre-probe: ``nprobe`` clusters
    at ~4× the mean cluster size, floored at ``4·probe_k`` and capped at
    the table. Shared by ``preprobe``/``preprobe_scored`` and the planner's
    scan-cost estimate (``BoomHQ._plan_local``), so the dense-vs-local
    planning decision can never drift from what the probe gathers."""
    return min(n_rows,
               max(probe_k * 4, (nprobe * 4 * n_rows) // max(1, n_clusters)))


def probe_slots(index: IVFIndex, q: jax.Array, *, nprobe: int, max_scan: int):
    """Probe the ``nprobe`` closest clusters and map their rows onto
    ``max_scan`` static candidate slots. -> (rows (max_scan,), valid
    (max_scan,)) — the shared slot selection of every search variant."""
    csim = similarity(q, index.centroids, index.metric)
    _, probe_clusters = jax.lax.top_k(csim, nprobe)
    return _candidate_slots(index, probe_clusters, max_scan)


@partial(jax.jit, static_argnames=("nprobe", "max_scan", "k"))
def search(
    index: IVFIndex,
    vectors: jax.Array,  # (n, d) the indexed column
    scalars: jax.Array,  # (n, M)
    pred: PredicateLike,
    q: jax.Array,  # (d,)
    *,
    nprobe: int,
    max_scan: int,
    k: int,
):
    """Index-first filtered search on one vector column.

    Returns (ids (k,), scores (k,), n_scored (), n_qualified ()). Unfilled
    result slots carry id -1 / score NEG.
    """
    rows, valid = probe_slots(index, q, nprobe=nprobe, max_scan=max_scan)
    cand_vecs = vectors[rows]
    cand_scal = scalars[rows]
    scores = similarity(q, cand_vecs, index.metric)
    qual = eval_mask(pred, cand_scal) & valid
    masked = jnp.where(qual, scores, NEG)
    top_scores, top_idx = jax.lax.top_k(masked, k)
    ids = jnp.where(top_scores > NEG / 2, rows[top_idx], -1)
    return ids, top_scores, jnp.sum(valid), jnp.sum(qual)


@partial(jax.jit, static_argnames=("nprobe", "max_scan", "k"))
def search_scored(
    index: IVFIndex,
    row_scores: jax.Array,  # (n,) this column's precomputed query similarities
    scalars: jax.Array,
    pred: PredicateLike,
    q: jax.Array,
    *,
    nprobe: int,
    max_scan: int,
    k: int,
):
    """``search`` with the row similarities precomputed.

    The batched serving path scores ALL rows for a whole query batch in one
    multithreaded GEMM, then runs this cheap slot-select + score-gather per
    query — gathering f32 scores instead of (max_scan, d) vectors. Results
    match ``search`` up to float reduction order (GEMM vs gathered matvec).
    Re-probing at a larger nprobe reuses the same ``row_scores``.
    """
    rows, valid = probe_slots(index, q, nprobe=nprobe, max_scan=max_scan)
    scores = row_scores[rows]
    qual = eval_mask(pred, scalars[rows]) & valid
    masked = jnp.where(qual, scores, NEG)
    top_scores, top_idx = jax.lax.top_k(masked, k)
    ids = jnp.where(top_scores > NEG / 2, rows[top_idx], -1)
    return ids, top_scores, jnp.sum(valid), jnp.sum(qual)


@partial(jax.jit, static_argnames=("nprobe", "probe_k"))
def preprobe(
    index: IVFIndex,
    vectors: jax.Array,
    scalars: jax.Array,
    pred: PredicateLike,
    q: jax.Array,
    *,
    nprobe: int = 1,
    probe_k: int = 32,
):
    """Paper §3.3 neighborhood pre-probing: a cheap *unfiltered* ANN probe,
    then the local satisfaction rate of the predicates among those neighbors.

    Returns (rate (), mean_top_score ()).
    """
    csim = similarity(q, index.centroids, index.metric)
    _, probe_clusters = jax.lax.top_k(csim, nprobe)
    n = vectors.shape[0]
    max_scan = probe_scan_budget(index.n_clusters, n, nprobe=nprobe,
                                 probe_k=probe_k)
    rows, valid = _candidate_slots(index, probe_clusters, max_scan)
    scores = jnp.where(valid, similarity(q, vectors[rows], index.metric), NEG)
    return _probe_stats(scores, rows, scalars, pred, probe_k)


def _probe_stats(scores, rows, scalars, pred, probe_k):
    top_scores, top_idx = jax.lax.top_k(scores, probe_k)
    neigh_rows = rows[top_idx]
    ok = eval_mask(pred, scalars[neigh_rows])
    found = top_scores > NEG / 2
    rate = jnp.sum(ok & found) / jnp.maximum(jnp.sum(found), 1)
    mean_s = jnp.sum(jnp.where(found, top_scores, 0.0)) / jnp.maximum(jnp.sum(found), 1)
    return rate, mean_s


@partial(jax.jit, static_argnames=("nprobe", "probe_k"))
def preprobe_scored(
    index: IVFIndex,
    row_scores: jax.Array,  # (n,) this column's precomputed similarities
    scalars: jax.Array,
    pred: PredicateLike,
    q: jax.Array,
    *,
    nprobe: int = 1,
    probe_k: int = 32,
):
    """``preprobe`` with the row similarities precomputed — the batched
    optimizer path scores every row for the whole batch in one GEMM (shared
    with the batched executor) and gathers f32 scores here instead of
    materializing (batch, max_scan, d) vector tensors under vmap."""
    csim = similarity(q, index.centroids, index.metric)
    _, probe_clusters = jax.lax.top_k(csim, nprobe)
    n = row_scores.shape[0]
    max_scan = probe_scan_budget(index.n_clusters, n, nprobe=nprobe,
                                 probe_k=probe_k)
    rows, valid = _candidate_slots(index, probe_clusters, max_scan)
    scores = jnp.where(valid, row_scores[rows], NEG)
    return _probe_stats(scores, rows, scalars, pred, probe_k)


# ---------------------------------------------------------------------------
# candidate-local batched search (no dense score matrix)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("nprobe", "max_scan", "k", "use_kernel",
                                   "interpret", "block_s"))
def search_local_batch(
    index: IVFIndex,
    vectors: jax.Array,  # (n, d) the indexed column
    scalars: jax.Array,  # (n, M)
    pred_b: PredicateLike,  # stacked, leading axis B
    q_b: jax.Array,  # (B, d)
    *,
    nprobe: int,
    max_scan: int,
    k: int,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    block_s: int = GATHER_BLOCK_S,
):
    """Candidate-local batched variant of ``search_scored``: no dense (B, n)
    score matrix is ever built. Candidate slots are selected per query (the
    cheap part) and ONE fused gather+score+mask+top-k
    (``kernels.gather_score``) touches only those ``B·max_scan`` rows —
    the path the dispatcher picks once ``B·max_scan / n_rows`` drops below
    the crossover. Returns (ids (B, k), scores (B, k), n_scored (B,),
    n_qualified (B,)); ties break by smaller row id (``search`` breaks by
    candidate-slot order, so near-exact ties may order differently)."""
    from repro.kernels.gather_score import gather_score_topk

    rows_b, valid_b = jax.vmap(
        lambda q: probe_slots(index, q, nprobe=nprobe, max_scan=max_scan))(q_b)
    cand = jnp.where(valid_b, rows_b, -1).astype(jnp.int32)
    w = jnp.ones((q_b.shape[0], 1), jnp.float32)
    ids, scores, n_qual = gather_score_topk(
        cand, (vectors,), (q_b,), w, scalars, pred_b, k=k,
        metric=index.metric, use_kernel=use_kernel, interpret=interpret,
        block_s=block_s)
    return ids, scores, jnp.sum(valid_b, axis=1), n_qual


@partial(jax.jit, static_argnames=("nprobe", "max_scan", "k", "rerank_mult",
                                   "use_kernel", "interpret", "block_s"))
def search_local_batch_int8(
    index: IVFIndex,
    vectors: jax.Array,  # (n, d) exact fp32 column (the rerank tier)
    vectors_i8: jax.Array,  # (n, d) int8 replica (the scoring tier)
    scales: jax.Array,  # (n,) f32 per-row dequant scales
    scalars: jax.Array,  # (n, M) — exact fp32, shared by both tiers
    pred_b: PredicateLike,  # stacked, leading axis B
    q_b: jax.Array,  # (B, d)
    *,
    nprobe: int,
    max_scan: int,
    k: int,
    rerank_mult: int | None = None,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    block_s: int = GATHER_BLOCK_S,
):
    """Quantized-tier ``search_local_batch``: identical slot selection, but
    the probed candidates are scored from the int8 replica (predicate
    filtering stays on the exact fp32 scalars) and only the top-α·k
    quantized survivors are re-scored exactly in fp32
    (``kernels.gather_score.gather_score_topk_int8``). Returned scores are
    exact fp32; quantization can only perturb WHICH α·k candidates reach
    the rerank, never their final scores or the qualified counts that
    drive iterative re-expansion."""
    from repro.kernels.gather_score import gather_score_topk_int8

    rows_b, valid_b = jax.vmap(
        lambda q: probe_slots(index, q, nprobe=nprobe, max_scan=max_scan))(q_b)
    cand = jnp.where(valid_b, rows_b, -1).astype(jnp.int32)
    w = jnp.ones((q_b.shape[0], 1), jnp.float32)
    kwargs = {} if rerank_mult is None else {"rerank_mult": rerank_mult}
    ids, scores, n_qual = gather_score_topk_int8(
        cand, (vectors,), (vectors_i8,), (scales,), (q_b,), w, scalars,
        pred_b, k=k, metric=index.metric, use_kernel=use_kernel,
        interpret=interpret, block_s=block_s, **kwargs)
    return ids, scores, jnp.sum(valid_b, axis=1), n_qual
