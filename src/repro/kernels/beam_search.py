"""Predicate-aware graph beam search: fixed-trip-count routing + fused
candidate extraction (the third index strategy, ROADMAP open item 1).

IVF probing pays for selectivity twice on predicate-correlated data: the
clusters nearest the query are exactly the clusters the predicate empties,
so the probe budget scans rows the DNF mask then throws away. A proximity
graph routes AROUND the emptied region instead — each hop moves the
frontier along similarity gradients, and qualifying rows a few edges past
the non-qualifying shell are reachable at a scan budget no probe list can
match. This module is the search half of that trade (the graph itself is
built by ``vectordb.graph``); everything is static-shape and jit-able:

  * **fixed trip count** — exactly ``n_hops`` hops of exactly
    ``beam_width`` expansions of exactly ``degree`` neighbors, so one
    trace serves every query and the batched executor's jit cache is
    keyed only by the legalized plan knobs;
  * **visited set as a row bitmask** — a packed ``(ceil(n/32),)`` uint32
    word array; membership is a shift-and-mask gather, insertion is a
    scatter-add of one bit per first-seen row (batch-deduplicated first,
    so each (word, bit) pair is touched at most once per hop);
  * **predicate folded into ROUTING, not reachability** — the DNF mask
    never prunes edges (filtered-out rows still relay the walk through
    non-qualifying regions); instead the beam is split: half the frontier
    slots go to the best unexpanded candidates by raw similarity (the
    navigators), half to the best *qualifying* unexpanded candidates (the
    result magnets). Non-qualifying rows can route but can never crowd
    qualifying ones out of their half of the beam;
  * **predicate-qualifying entry seeds** — besides the graph's global
    entry points, each query's walk is seeded with
    ``GRAPH_SEED_FACTOR·beam_width`` qualifying rows under the query's
    own DNF mask (the filtered-ANN "teleport" that NPG-style native
    hybrid search uses for anti-correlated predicates): on the correlated
    hard stratum the global entries sit in regions the predicate empties,
    and without a foothold inside the qualifying region the result
    magnets have nothing to climb from. Seeds are chosen by hashed row id
    (deterministic pseudo-random spread), so a LARGE qualifying region is
    sampled everywhere instead of at its lowest row ids and the walk
    hill-climbs from the best of the sample. The seed mask is one vmapped
    scalar pass — O(n·M) compare work, the same pre-pass filter_first
    pays, NOT a vector-column scan — and seeds count toward ``n_scored``
    like every other visited row;
  * **one fused extraction** — every row the walk ever visited is
    accumulated into a static ``(entry + n_hops·beam_width·degree)``-slot
    candidate pool, and the result set is ONE ``gather_score_topk`` call
    (the PR 4 Pallas kernel) over that pool with the full DNF predicate:
    dedup, masking, weighted scoring and top-k selection all follow the
    kernel's exact contract, so filtered-out rows used for routing can
    never enter the result set.

Routing similarities are computed with plain-jnp gathers inside the loop
(XLA fuses the per-hop gather+matvec); the Pallas kernel handles the one
heavy candidate-pool scoring pass. ``use_kernel``/``interpret`` pass
through to it with the same defaults as ``gather_score_topk`` — tests pin
kernel-vs-reference parity of the WHOLE search with
``use_kernel=True, interpret=True``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.gather_score import gather_score_topk
from repro.kernels.shapes import GATHER_BLOCK_S, GRAPH_SEED_FACTOR, NEG
from repro.vectordb.predicates import PredicateLike, eval_mask
from repro.vectordb.table import similarity


def _mark_fresh(visited: jax.Array, ids: jax.Array, n_words: int):
    """Batch-insert ``ids`` (i32, -1 = padding, duplicates allowed) into the
    packed uint32 visited bitmask. Returns (visited', fresh) where ``fresh``
    flags the FIRST occurrence of each not-yet-visited row — exactly the
    slots whose bits were set. Within-batch duplicates are resolved by a
    sort pass first, so the scatter-add touches every (word, bit) pair at
    most once and the add is an exact bitwise OR."""
    order = jnp.argsort(ids)
    sorted_ids = ids[order]
    first_sorted = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
    first = jnp.zeros_like(first_sorted).at[order].set(first_sorted)
    idc = jnp.clip(ids, 0, n_words * 32 - 1)
    word = idc >> 5
    bit = (idc & 31).astype(jnp.uint32)
    seen = (visited[word] >> bit) & jnp.uint32(1)
    fresh = first & (ids >= 0) & (seen == 0)
    bitval = jnp.where(fresh, jnp.uint32(1) << bit, jnp.uint32(0))
    visited = visited.at[jnp.where(fresh, word, n_words)].add(
        bitval, mode="drop")
    return visited, fresh


def _beam_one(neighbors, vectors, scalars, entry, pred, q, *,
              beam_width: int, n_hops: int, metric: str):
    """Single-query routing walk. Returns (cand (S,), n_visited ()) with
    S = entry + n_hops·beam_width·degree; cand carries every first-visited
    row id, -1 in never-filled slots."""
    n, r = neighbors.shape
    e = entry.shape[0]
    expand = beam_width * r
    s_total = e + n_hops * expand
    p = e + expand  # frontier pool slots
    n_words = (n + 31) // 32
    w_qual = beam_width // 2
    w_raw = beam_width - w_qual

    def score_rows(ids, fresh):
        idc = jnp.clip(ids, 0, n - 1)
        sc = jnp.where(fresh, similarity(q, vectors[idc], metric), NEG)
        qual = eval_mask(pred, scalars[idc]) & fresh
        return sc, qual

    visited = jnp.zeros((n_words,), jnp.uint32)
    visited, fresh0 = _mark_fresh(visited, entry.astype(jnp.int32), n_words)
    seed_ids = jnp.where(fresh0, entry, -1).astype(jnp.int32)
    seed_sc, seed_qual = score_rows(seed_ids, fresh0)

    pool_ids = jnp.full((p,), -1, jnp.int32).at[:e].set(seed_ids)
    pool_sc = jnp.full((p,), NEG, jnp.float32).at[:e].set(seed_sc)
    pool_qual = jnp.zeros((p,), bool).at[:e].set(seed_qual)
    pool_exp = jnp.zeros((p,), bool)
    out = jnp.full((s_total,), -1, jnp.int32).at[:e].set(seed_ids)

    def hop(h, carry):
        pool_ids, pool_sc, pool_qual, pool_exp, visited, out = carry
        # split beam: w_raw navigator slots by raw similarity, w_qual
        # result-magnet slots by qualifying-only similarity — the
        # predicate shapes WHERE the walk lingers, never what it may
        # traverse
        selectable = (pool_ids >= 0) & ~pool_exp
        raw = jnp.where(selectable, pool_sc, NEG)
        _, i_raw = jax.lax.top_k(raw, w_raw)
        taken = jnp.zeros((p,), bool).at[i_raw].set(True)
        qual_sc = jnp.where(selectable & pool_qual & ~taken, pool_sc, NEG)
        _, i_qual = jax.lax.top_k(qual_sc, w_qual)
        fr_idx = jnp.concatenate([i_raw, i_qual])
        fr_ok = jnp.concatenate([raw[i_raw], qual_sc[i_qual]]) > NEG / 2
        # mark expanded only where the pick was real — top_k on an
        # all-NEG lane returns arbitrary indices
        pool_exp = pool_exp.at[jnp.where(fr_idx >= 0, fr_idx, p)].set(
            fr_ok, mode="drop") | pool_exp

        fr_ids = jnp.where(fr_ok, pool_ids[fr_idx], -1)
        nb = neighbors[jnp.clip(fr_ids, 0, n - 1)]  # (beam_width, r)
        nb = jnp.where(fr_ok[:, None], nb, -1).reshape(expand)
        visited2, fresh = _mark_fresh(visited, nb, n_words)
        new_ids = jnp.where(fresh, nb, -1).astype(jnp.int32)
        new_sc, new_qual = score_rows(new_ids, fresh)
        out = jax.lax.dynamic_update_slice(out, new_ids, (e + h * expand,))

        # frontier merge: best p slots by routing score survive; expanded
        # entries keep their flag (the bitmask blocks re-insertion, the
        # flag blocks re-expansion)
        all_ids = jnp.concatenate([pool_ids, new_ids])
        all_sc = jnp.concatenate([pool_sc, new_sc])
        all_qual = jnp.concatenate([pool_qual, new_qual])
        all_exp = jnp.concatenate([pool_exp, jnp.zeros((expand,), bool)])
        top_sc, sel = jax.lax.top_k(all_sc, p)
        return (all_ids[sel], top_sc, all_qual[sel], all_exp[sel],
                visited2, out)

    carry = (pool_ids, pool_sc, pool_qual, pool_exp, visited, out)
    *_, out = jax.lax.fori_loop(0, n_hops, hop, carry)
    return out, jnp.sum(out >= 0)


@partial(jax.jit, static_argnames=("beam_width", "n_hops", "metric"))
def beam_candidates_batch(neighbors, vectors, scalars, entry, pred_b, q_b, *,
                          beam_width: int, n_hops: int, metric: str = "dot"):
    """vmapped routing for a query batch. -> (cand (B, S) i32, -1 padded;
    n_visited (B,)) — the candidate matrix feeds ``gather_score_topk``
    directly (its contract allows -1 pads and duplicates, though the
    bitmask guarantees per-query uniqueness already). ``entry`` is either
    a shared (E,) row set or per-query (B, E) rows (how the qualifying
    seeds ride in); -1 entries are ignored."""
    walk = partial(_beam_one, neighbors, vectors, scalars,
                   beam_width=beam_width, n_hops=n_hops, metric=metric)
    if entry.ndim == 2:
        return jax.vmap(walk)(entry, pred_b, q_b)
    return jax.vmap(partial(walk, entry))(pred_b, q_b)


@partial(jax.jit, static_argnames=("k", "beam_width", "n_hops", "metric",
                                   "use_kernel", "interpret", "block_s"))
def beam_search_topk(
    neighbors: jax.Array,  # (n, r) i32 adjacency, -1 = free slot
    entry: jax.Array,  # (E,) i32 entry points
    vectors: jax.Array,  # (n, d) the indexed column
    scalars: jax.Array,  # (n, M)
    pred_b: PredicateLike,  # stacked, leading axis B
    q_b: jax.Array,  # (B, d)
    *,
    k: int,
    beam_width: int,
    n_hops: int,
    metric: str = "dot",
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    block_s: int = GATHER_BLOCK_S,
):
    """Filtered top-k over the graph for a query batch.

    Routing walks the graph predicate-aware (module doc); the result set
    is ONE fused gather+score+mask+top-k over every visited row. Returns
    (ids (B, k), scores (B, k), n_scored (B,), n_qualified (B,)) —
    the same contract as ``ivf.search_local_batch``, so the executor's
    subquery plumbing (RRF union, rerank, iterative accounting) is
    strategy-agnostic. ``n_scored`` is the visited-row count: the scan
    budget the walk actually spent, comparable with IVF's probed-slot
    count in the cost model's crossover fit.

    Each query's entry set is the graph's global entry points plus
    ``SEED_FACTOR·beam_width`` predicate-qualifying seed rows (module
    doc) — found by one vmapped DNF-mask pass over the scalar columns, so
    an anti-correlated predicate still hands the result magnets a
    foothold inside the qualifying region. Seeds are one row per row-id
    segment, picked by hashed row id (a Knuth multiplicative key), not
    first-by-row-id: a deterministic pseudo-random SPREAD over the
    qualifying set, so a large qualifying region is sampled everywhere
    rather than at its lowest row ids — the walk then hill-climbs from
    the best of them. Empty segments pad with -1 and are ignored by the
    walk."""
    n = scalars.shape[0]
    n_seeds = GRAPH_SEED_FACTOR * beam_width
    seg = -(-n // n_seeds)
    pad = seg * n_seeds - n
    mask_b = jax.vmap(lambda p: eval_mask(p, scalars))(pred_b)
    # one seed per row-id segment, the qualifying row with the largest
    # hashed id (Knuth multiplicative key): a deterministic uniform
    # sample of the qualifying set at O(n) compare work — no sort, no
    # top_k over the table
    key = (jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761)) >> 12
    key_seg = jnp.pad(key.astype(jnp.int32), (0, pad),
                      constant_values=-1).reshape(n_seeds, seg)
    def pick(m):
        kk = jnp.where(jnp.pad(m, (0, pad)).reshape(n_seeds, seg),
                       key_seg, -1)
        j = jnp.argmax(kk, axis=1)
        ok = jnp.take_along_axis(kk, j[:, None], 1)[:, 0] >= 0
        rows = j.astype(jnp.int32) + jnp.arange(n_seeds, dtype=jnp.int32) * seg
        return jnp.where(ok, rows, -1)
    seeds = jax.vmap(pick)(mask_b)
    entry_b = jnp.concatenate([
        jnp.broadcast_to(entry[None, :],
                         (q_b.shape[0], entry.shape[0])).astype(jnp.int32),
        seeds], axis=1)
    cand, n_visited = beam_candidates_batch(
        neighbors, vectors, scalars, entry_b, pred_b, q_b,
        beam_width=beam_width, n_hops=n_hops, metric=metric)
    w = jnp.ones((q_b.shape[0], 1), jnp.float32)
    ids, scores, n_qual = gather_score_topk(
        cand, (vectors,), (q_b,), w, scalars, pred_b, k=k, metric=metric,
        use_kernel=use_kernel, interpret=interpret, block_s=block_s)
    return ids, scores, n_visited, n_qual
