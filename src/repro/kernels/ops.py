"""jit'd wrappers: padding, kernel dispatch and the cross-block merge.

``interpret`` defaults to True off-TPU (the kernels execute in Python via
the Pallas interpreter for correctness validation); on a TPU backend the
same calls lower through Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.int8_scan import int8_topk_blocks, quantize_rows  # noqa: F401
from repro.kernels.masked_topk import masked_topk_blocks
from repro.kernels.shapes import NEG, SCAN_BLOCK_ROWS


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x, block_rows, value=0):
    n = x.shape[0]
    pad = (-n) % block_rows
    if pad == 0:
        return x
    width = ((0, pad),) + tuple((0, 0) for _ in range(x.ndim - 1))
    return jnp.pad(x, width, constant_values=value)


def _merge(block_s, block_i, k):
    """Cross-block merge of per-block top-k pools.

    Underfilled blocks pad their pools with (NEG, -1) slots; those slots
    flow through ``lax.top_k`` whenever fewer than k rows qualify overall,
    so the merge must report which result slots are real — callers that
    consume ids (or scores) without checking would otherwise see phantom
    rows. -> (scores (k,), ids (k,), valid (k,) bool); invalid slots carry
    score NEG / id -1."""
    flat_s = block_s.reshape(-1)
    flat_i = block_i.reshape(-1)
    top_s, pos = jax.lax.top_k(flat_s, k)
    valid = (top_s > NEG / 2) & (flat_i[pos] >= 0)
    ids = jnp.where(valid, flat_i[pos], -1)
    return jnp.where(valid, top_s, NEG), ids, valid


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "metric",
                                             "interpret"))
def masked_topk(q, vectors, scalars, lo, hi, active, *, k: int,
                block_rows: int = SCAN_BLOCK_ROWS, metric: str = "dot",
                interpret: bool | None = None):
    """Fused filtered top-k over the whole table.
    -> (scores (k,), ids (k,), valid (k,))."""
    if interpret is None:
        interpret = _default_interpret()
    n = vectors.shape[0]
    block_rows = min(block_rows, max(8, n))
    v = _pad_rows(vectors, block_rows)
    s = _pad_rows(scalars, block_rows)
    bs, bi = masked_topk_blocks(q, v, s, lo, hi, active, n, k=k,
                                block_rows=block_rows, metric=metric,
                                interpret=interpret)
    return _merge(bs, bi, k)


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def int8_masked_topk(q, vec_i8, scales, scalars, lo, hi, active, *, k: int,
                     block_rows: int = SCAN_BLOCK_ROWS,
                     interpret: bool | None = None):
    """Quantized fused filtered top-k.
    -> (scores (k,), ids (k,), valid (k,))."""
    if interpret is None:
        interpret = _default_interpret()
    n = vec_i8.shape[0]
    block_rows = min(block_rows, max(8, n))
    v = _pad_rows(vec_i8, block_rows)
    sc = _pad_rows(scales, block_rows)
    s = _pad_rows(scalars, block_rows)
    bs, bi = int8_topk_blocks(q, v, sc, s, lo, hi, active, n, k=k,
                              block_rows=block_rows, interpret=interpret)
    return _merge(bs, bi, k)
