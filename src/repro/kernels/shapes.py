"""Single source of truth for kernel tile/block shapes and sentinels.

Every Pallas kernel in this package *and* the boomlint PL001 VMEM
estimator (``repro.analysis``) read these constants, so the static
analyzer can never disagree with what the kernels actually launch. If a
tile shape changes here, the estimator budget check moves with it; if a
kernel grows a new scratch buffer, add it to the matching ``*_tile_bytes``
function in the same commit.

The byte estimators model resident VMEM per grid step: the row/candidate
tile plus the operands whose index_map pins them to block 0 (query,
predicate bounds). They deliberately ignore compiler-managed double
buffering — the budget (``DEFAULT_VMEM_BUDGET``) leaves headroom for it.
"""
from __future__ import annotations

# Score sentinel for masked-out / padded rows and the id sentinel used by
# the k-round knockout select (any value > max row count works; 2**30
# keeps int32 arithmetic safe).
NEG = -1e30
ID_SENTINEL = 2**30

# Row tile for the full-scan kernels (masked_topk, int8_scan). 1024 rows ×
# 768 dims × 4 B ≈ 3.2 MB resident — comfortable inside 16 MB VMEM with
# dims aligned to the 128-lane MXU.
SCAN_BLOCK_ROWS = 1024

# Candidate tile for the gather+score kernel (gather_score). 256 gathered
# rows per step bounds the per-column scratch to block_s·d·4 B.
GATHER_BLOCK_S = 256

# Declared support envelope — the largest shapes the serving kernels are
# expected to launch with. The PL001 trace-level check evaluates the
# estimators at this envelope against the budget.
MAX_COL_DIM = 768  # widest single vector column
MAX_VEC_COLS = 4  # most vector columns per table
MAX_SCALARS = 16  # most scalar predicate columns
MAX_TOPK = 128  # largest static k a kernel is launched with

# Graph-index beam search envelope (kernels/beam_search.py): the largest
# legalized knobs a plan may launch with. The per-hop expansion working
# set is beam_width·degree gathered rows; the visited-candidate pool the
# final gather+score extraction runs over is
# (GRAPH_ENTRY_POINTS + GRAPH_SEED_FACTOR·beam_width) +
# n_hops·beam_width·degree slots — the walk is seeded with the global
# entries PLUS GRAPH_SEED_FACTOR·beam_width predicate-qualifying rows per
# query (hashed-id spread over the qualifying set).
MAX_BEAM_WIDTH = 16  # widest legalized beam (BEAM_GRID max)
MAX_BEAM_HOPS = 8  # most legalized hops (HOP_GRID max)
MAX_GRAPH_DEGREE = 32  # largest graph out-degree (DEGREE_GRID max)
GRAPH_ENTRY_POINTS = 8  # static entry-point count (medoid + strided)
GRAPH_SEED_FACTOR = 4  # qualifying seed rows per beam slot

# Conservative per-step budget: 16 MB physical VMEM minus headroom for
# Mosaic double buffering and spills.
DEFAULT_VMEM_BUDGET = 12 * 2**20

_F32 = 4


def scan_tile_bytes(dim: int, n_scalars: int, *, k: int = MAX_TOPK,
                    block_rows: int = SCAN_BLOCK_ROWS) -> int:
    """Resident bytes per grid step of ``masked_topk_blocks``:
    (block_rows, dim) f32 vector tile + (block_rows, n_scalars) f32 scalar
    tile + pinned query/lo/hi/active rows + (1, k) output pools."""
    tile = block_rows * (dim + n_scalars) * _F32
    pinned = (dim + 3 * n_scalars + 1) * _F32
    out = 2 * k * _F32
    return tile + pinned + out


def int8_scan_tile_bytes(dim: int, n_scalars: int, *, k: int = MAX_TOPK,
                         block_rows: int = SCAN_BLOCK_ROWS) -> int:
    """Like ``scan_tile_bytes`` but the vector tile is int8 with a per-row
    f32 dequant scale column."""
    tile = block_rows * (dim + (1 + n_scalars) * _F32)
    pinned = (dim + 3 * n_scalars + 1) * _F32
    out = 2 * k * _F32
    return tile + pinned + out


def gather_tile_bytes(dims: tuple, n_scalars: int, n_clauses: int, *,
                      k: int = MAX_TOPK,
                      block_s: int = GATHER_BLOCK_S) -> int:
    """Resident bytes per grid step of ``gather_score_blocks``: one
    (block_s, d_i) f32 VMEM scratch per vector column + the gathered
    (block_s, n_scalars) scalar tile + pinned per-query operands."""
    scratch = block_s * sum(dims) * _F32
    scal = block_s * n_scalars * _F32
    pinned = (sum(dims) + n_clauses * (2 * n_scalars + 1) + block_s) * _F32
    out = 2 * k * _F32
    return scratch + scal + pinned + out


def beam_tile_bytes(dim: int, n_scalars: int, n_clauses: int = 4, *,
                    k: int = MAX_TOPK,
                    beam_width: int = MAX_BEAM_WIDTH,
                    n_hops: int = MAX_BEAM_HOPS,
                    degree: int = MAX_GRAPH_DEGREE,
                    block_s: int = GATHER_BLOCK_S) -> int:
    """Resident bytes per query of the graph beam search
    (``kernels.beam_search``): the max of the XLA routing loop's per-hop
    working set and the final Pallas extraction's per-grid-step tile.

    Per hop the routing loop gathers ``beam_width·degree`` neighbor rows
    ((expand, dim) f32 vectors + (expand, n_scalars) f32 scalars + id /
    score / qual lanes) and merges them into the
    (entry + expand)-slot frontier pool (ids, scores, qual, expanded).
    The visited bitmask is table-sized HBM state (n/32 B), never tiled
    into VMEM, so it is deliberately outside this estimate. Result
    extraction is one ``gather_score`` launch over the accumulated
    visited-candidate pool, so its tile is exactly
    ``gather_tile_bytes((dim,), ...)``."""
    expand = beam_width * degree
    hop = expand * (dim + n_scalars + 3) * _F32
    pool = (GRAPH_ENTRY_POINTS + GRAPH_SEED_FACTOR * beam_width
            + expand) * 4 * _F32
    extract = gather_tile_bytes((dim,), n_scalars, n_clauses,
                                k=k, block_s=block_s)
    return max(hop + pool, extract)


def int8_gather_tile_bytes(dims: tuple, n_scalars: int, n_clauses: int, *,
                           k: int = MAX_TOPK,
                           block_s: int = GATHER_BLOCK_S) -> int:
    """``gather_tile_bytes`` for the quantized tier: each column's gathered
    tile is int8 (1 B/elem) plus a (block_s, 1) f32 per-row dequant scale
    tile; everything else (scalar tile, pinned query/predicate operands,
    output pools) is unchanged."""
    scratch = block_s * sum(d + _F32 for d in dims)  # int8 tile + scale col
    scal = block_s * n_scalars * _F32
    pinned = (sum(dims) + n_clauses * (2 * n_scalars + 1) + block_s) * _F32
    out = 2 * k * _F32
    return scratch + scal + pinned + out
