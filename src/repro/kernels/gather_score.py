"""Fused candidate-local gather+score kernel: the executor hot path past the
dense-GEMM crossover.

The batched executor scores DENSELY — one GEMM over all rows per vector
column per batch — which is optimal while ``B·max_scan / n_rows`` is large
but becomes the wall past ~10⁵-row shards: the GEMM touches every row even
though the learned plans only ever look at ``max_scan`` candidates per
query. This kernel closes that gap. Given a ``(B, S)`` candidate-row matrix
(padded with -1), each grid step (query b, candidate block j):

  * gathers the block's candidate rows — vectors of every weighted column
    plus the scalar row — into VMEM scratch tiles via per-row HBM→VMEM
    async copies (the table refs stay in ``pl.ANY``/HBM, so table size is
    bounded by HBM, not the ~16 MB VMEM; the rows are arbitrary, so there
    is no contiguous BlockSpec for them);
  * scores the tile with one MXU dot per column and combines with the
    query's column weights (l2 keeps the -||v||² and -||q||² terms so score
    VALUES match ``table.similarity``, not just the ranking);
  * evaluates the DNF predicate on the gathered scalars (OR over valid
    clauses of AND over active columns) and masks;
  * selects the block-local top-k by k rounds of max+knockout, where the
    knockout removes every slot carrying the winning ROW ID — duplicate
    candidates (the rerank union) can never crowd distinct rows out of a
    block's k slots.

Per-block candidates merge in the caller (``merge_topk_unique``): one
dedup-by-id pass plus a (-score, id) lexsort, so ties break by smaller row
id — the same rule the pure-jnp reference (``ref.gather_score_ref``) and the
NumPy test oracle use, which keeps kernel-vs-reference id parity exact on
tie-free data.

Off-TPU the public entry ``gather_score_topk`` runs the reference path by
default (the interpreter would execute the Pallas kernel in Python, row by
row); on a TPU backend the same call tiles through Mosaic. ``use_kernel``
forces either path (tests pin kernel-vs-reference parity with
``use_kernel=True, interpret=True``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.shapes import GATHER_BLOCK_S, ID_SENTINEL, NEG


def _pred_fields(pred):
    """Dense (B, C, M) lo/hi/active + (B, C) clause_valid f32 fields from a
    batched PredicateLike (the conjunctive shim lifts to one valid clause)."""
    from repro.vectordb.predicates import as_set

    ps = as_set(pred)
    return (ps.lo.astype(jnp.float32), ps.hi.astype(jnp.float32),
            ps.active.astype(jnp.float32), ps.clause_valid.astype(jnp.float32))


def merge_topk_unique(ids, scores, k: int):
    """(B, P) candidate pools -> (B, k) top-k with duplicate row ids
    suppressed and ties broken by smaller row id.

    Padded slots carry id -1 / score NEG. Duplicate ids score identically
    (same row, same per-row dot), so keeping the first occurrence is exact.
    """

    def one(cid, s):
        order = jnp.argsort(cid)
        sc = cid[order]
        first = jnp.concatenate(
            [jnp.ones((1,), bool), sc[1:] != sc[:-1]])
        keep = jnp.zeros_like(first).at[order].set(first) & (cid >= 0)
        s2 = jnp.where(keep, s, NEG)
        key = jnp.where(cid >= 0, cid, ID_SENTINEL)
        sel = jnp.lexsort((key, -s2))[:k]
        top = s2[sel]
        out_ids = jnp.where(top > NEG / 2, cid[sel], -1)
        return out_ids.astype(jnp.int32), top

    return jax.vmap(one)(ids, scores)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _kernel(cand_ref, scal_ref, w_ref, lo_ref, hi_ref, act_ref, cval_ref,
            *refs, k: int, block_s: int, n_vec: int, metric: str,
            apply_pred: bool, int8: bool = False):
    vec_refs = refs[:n_vec]  # pl.ANY (HBM) — full table columns
    pos = n_vec
    if int8:
        scale_refs = refs[pos:pos + n_vec]  # pl.ANY (HBM) — (n, 1) f32
        pos += n_vec
    q_refs = refs[pos:pos + n_vec]
    out_s_ref, out_i_ref, out_q_ref = refs[pos + n_vec: pos + n_vec + 3]
    scratch = refs[pos + n_vec + 3:]
    vec_tiles = scratch[:n_vec]  # VMEM (BS, d_i) per column (f32 or int8)
    pos = n_vec
    if int8:
        scale_tiles = scratch[pos:pos + n_vec]  # VMEM (BS, 1) f32
        pos += n_vec
    scal_tile = scratch[pos]  # VMEM (BS, M)
    sem = scratch[pos + 1]  # DMA completion semaphore

    cid = cand_ref[...].reshape(block_s, 1)  # (BS, 1) i32, -1 = padding
    n = scal_ref.shape[0]
    idc = jnp.clip(cid[:, 0], 0, n - 1)  # clamp padding for safe gathers

    def gather(src_ref, tile_ref):
        # arbitrary-row gather: one HBM→VMEM async copy per candidate row
        # into the block's scratch tile — the table itself never enters
        # VMEM, so table size is bounded by HBM
        def body(t, _):
            dma = pltpu.make_async_copy(src_ref.at[pl.ds(idc[t], 1), :],
                                        tile_ref.at[pl.ds(t, 1), :], sem)
            dma.start()
            dma.wait()
            return 0

        jax.lax.fori_loop(0, block_s, body, 0)

    total = jnp.zeros((block_s, 1), jnp.float32)
    for i in range(n_vec):
        gather(vec_refs[i], vec_tiles[i])
        q = q_refs[i][...]  # (1, d)
        if int8:
            # quantized tier: the gathered tile is int8 (4× fewer HBM
            # bytes per row) — one dot on the cast tile, then the per-row
            # absmax dequant scale (score(v·s) = s·score(v); l2 norms
            # rescale by s²)
            gather(scale_refs[i], scale_tiles[i])
            tile = vec_tiles[i][...].astype(jnp.float32)  # (BS, d)
            sc = scale_tiles[i][...]  # (BS, 1)
            s = jnp.dot(tile, q.T,
                        preferred_element_type=jnp.float32) * sc
            if metric == "l2":
                s = (2.0 * s
                     - jnp.sum(tile * tile, axis=1, keepdims=True) * sc * sc
                     - jnp.sum(q * q))
        else:
            tile = vec_tiles[i][...]  # (BS, d)
            s = jnp.dot(tile, q.T,
                        preferred_element_type=jnp.float32)  # (BS, 1)
            if metric == "l2":
                s = (2.0 * s - jnp.sum(tile * tile, axis=1, keepdims=True)
                     - jnp.sum(q * q))
        total = total + w_ref[0, i] * s

    if apply_pred:
        gather(scal_ref, scal_tile)
        st = scal_tile[...]  # (BS, M)
        lo, hi, act = lo_ref[...][0], hi_ref[...][0], act_ref[...][0]  # (C, M)
        ok_cm = ((st[:, None, :] >= lo) & (st[:, None, :] <= hi)) \
            | (act < 0.5)  # (BS, C, M)
        clause = jnp.all(ok_cm, axis=-1) & (cval_ref[...][0] > 0.5)  # (BS, C)
        ok = jnp.any(clause, axis=-1)[:, None]
    else:
        ok = jnp.ones((block_s, 1), bool)
    qual = ok & (cid >= 0)
    out_q_ref[0, 0] = jnp.sum(qual.astype(jnp.int32))

    s = jnp.where(qual, total, NEG)
    for j in range(k):
        m = jnp.max(s)
        is_max = (s >= m) & (s > NEG / 2)
        first = jnp.min(jnp.where(is_max, cid, jnp.int32(ID_SENTINEL)))
        out_s_ref[0, 0, j] = m
        out_i_ref[0, 0, j] = jnp.where(m > NEG / 2, first, -1)
        # knock out every slot carrying this ROW ID, not just one slot —
        # duplicates must not occupy multiple of the block's k slots
        s = jnp.where(cid == first, NEG, s)


@functools.partial(jax.jit, static_argnames=("k", "block_s", "metric",
                                             "apply_pred", "interpret"))
def gather_score_blocks(cand, vectors, qs, weights, scalars, lo, hi, active,
                        clause_valid, scales=None, *, k: int, block_s: int,
                        metric: str = "dot", apply_pred: bool = True,
                        interpret: bool = True):
    """-> (block_scores (B, nb, k), block_ids (B, nb, k), block_qual (B, nb)).

    ``cand`` (B, S) i32 candidate rows (-1 = padding), S a multiple of
    ``block_s``; block ids are ROW ids (block-locally deduplicated).

    With ``scales`` (tuple of (n, 1) f32 per-row dequant scales) the
    ``vectors`` are the int8 replicas: tiles gather as int8 and dequantize
    per row in VMEM — the quantized scoring tier."""
    b, s_tot = cand.shape
    assert s_tot % block_s == 0, (s_tot, block_s)
    nb = s_tot // block_s
    n, m = scalars.shape
    n_vec = len(vectors)
    c = lo.shape[1]
    int8 = scales is not None
    kern = functools.partial(_kernel, k=k, block_s=block_s, n_vec=n_vec,
                             metric=metric, apply_pred=apply_pred, int8=int8)
    in_specs = [
        pl.BlockSpec((1, block_s), lambda b_, j: (b_, j)),  # candidates
        pl.BlockSpec(memory_space=pl.ANY),  # scalars — stay in HBM
        pl.BlockSpec((1, n_vec), lambda b_, j: (b_, 0)),  # weights
        pl.BlockSpec((1, c, m), lambda b_, j: (b_, 0, 0)),  # lo
        pl.BlockSpec((1, c, m), lambda b_, j: (b_, 0, 0)),  # hi
        pl.BlockSpec((1, c, m), lambda b_, j: (b_, 0, 0)),  # active
        pl.BlockSpec((1, c), lambda b_, j: (b_, 0)),  # clause_valid
    ]
    for _ in vectors:
        in_specs.append(pl.BlockSpec(memory_space=pl.ANY))  # columns — HBM
    if int8:
        for _ in vectors:
            in_specs.append(pl.BlockSpec(memory_space=pl.ANY))  # scales
    for v in vectors:
        in_specs.append(
            pl.BlockSpec((1, v.shape[1]), lambda b_, j: (b_, 0)))
    tile_dtype = jnp.int8 if int8 else jnp.float32
    scratch_shapes = [pltpu.VMEM((block_s, v.shape[1]), tile_dtype)
                      for v in vectors]
    if int8:
        scratch_shapes += [pltpu.VMEM((block_s, 1), jnp.float32)
                           for _ in vectors]
    scratch_shapes += [pltpu.VMEM((block_s, m), jnp.float32),
                       pltpu.SemaphoreType.DMA(())]
    operands = [cand, scalars, weights, lo, hi, active, clause_valid,
                *[v for v in vectors]]
    if int8:
        operands += [s for s in scales]
    operands += [q for q in qs]
    out_s, out_i, out_q = pl.pallas_call(
        kern,
        grid=(b, nb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, k), lambda b_, j: (b_, j, 0)),
            pl.BlockSpec((1, 1, k), lambda b_, j: (b_, j, 0)),
            pl.BlockSpec((1, 1), lambda b_, j: (b_, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nb, k), jnp.float32),
            jax.ShapeDtypeStruct((b, nb, k), jnp.int32),
            jax.ShapeDtypeStruct((b, nb), jnp.int32),
        ],
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*operands)
    return out_s, out_i, out_q


# ---------------------------------------------------------------------------
# public entry — kernel on TPU, pure-jnp reference elsewhere
# ---------------------------------------------------------------------------

def _default_use_kernel() -> bool:
    return jax.default_backend() == "tpu"


def gather_score_topk(cand, vectors, qs, weights, scalars, pred=None, *,
                      k: int, metric: str = "dot",
                      block_s: int = GATHER_BLOCK_S,
                      use_kernel: bool | None = None,
                      interpret: bool | None = None,
                      scales=None):
    """Fused candidate-local filtered top-k for a query batch.

    cand:    (B, S) i32 candidate row ids, -1 = padded/empty slot (duplicates
             allowed — they are deduplicated before selection).
    vectors: tuple of (n, d_i) table columns; qs: tuple of (B, d_i) queries;
    weights: (B, n_vec) per-column weights; scalars: (n, M).
    pred:    batched PredicateLike (leading axis B) or None to skip masking
             (candidates already qualified, e.g. the rerank union).
    scales:  tuple of (n,) f32 per-row dequant scales — when given,
             ``vectors`` are the int8 replicas and scoring runs the
             quantized tier (4× fewer gathered HBM bytes; the DNF mask
             still evaluates on the exact fp32 scalars).

    -> (ids (B, k), scores (B, k), n_qualified (B,)). Empty slots carry
    id -1 / score NEG; ties break by smaller row id. Traceable — callers
    jit it into their own graphs."""
    if use_kernel is None:
        use_kernel = _default_use_kernel()
    b, s_tot = cand.shape
    apply_pred = pred is not None
    if apply_pred:
        lo, hi, act, cval = _pred_fields(pred)
    else:
        m = scalars.shape[1]
        lo = jnp.full((b, 1, m), -jnp.inf, jnp.float32)
        hi = jnp.full((b, 1, m), jnp.inf, jnp.float32)
        act = jnp.zeros((b, 1, m), jnp.float32)
        cval = jnp.ones((b, 1), jnp.float32)

    if not use_kernel:
        from repro.kernels.ref import gather_score_ref

        return gather_score_ref(cand, vectors, qs, weights, scalars,
                                lo, hi, act, cval, k=k, metric=metric,
                                apply_pred=apply_pred, scales=scales)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bs = min(block_s, _next_pow2(max(s_tot, k, 8)))
    pad = (-s_tot) % bs
    if s_tot + pad < k:  # the merge pool (nb·k) must hold at least k slots
        pad += ((k - (s_tot + pad)) + bs - 1) // bs * bs
    if pad:
        cand = jnp.pad(cand, ((0, 0), (0, pad)), constant_values=-1)
    scales2 = None if scales is None else tuple(
        s.reshape(-1, 1).astype(jnp.float32) for s in scales)
    out_s, out_i, out_q = gather_score_blocks(
        cand, tuple(vectors), tuple(qs), weights, scalars, lo, hi, act, cval,
        scales2, k=k, block_s=bs, metric=metric, apply_pred=apply_pred,
        interpret=interpret)
    nb = cand.shape[1] // bs
    ids, scores = merge_topk_unique(
        out_i.reshape(b, nb * k), out_s.reshape(b, nb * k), k)
    return ids, scores, jnp.sum(out_q, axis=1)


# α of the two-stage quantized scan: the int8 pass keeps α·k candidates for
# the exact fp32 rerank. Measured on the quantization-loss suite: α=4 holds
# the int8-tier recall within 0.01 of fp32 candidate-local on every clause
# bucket; the rerank pool is capped at MAX_TOPK (the largest static k).
RERANK_MULT = 4


def gather_score_topk_int8(cand, vectors, vectors_i8, scales, qs, weights,
                           scalars, pred=None, *, k: int,
                           metric: str = "dot",
                           rerank_mult: int = RERANK_MULT,
                           block_s: int = GATHER_BLOCK_S,
                           use_kernel: bool | None = None,
                           interpret: bool | None = None):
    """Two-stage quantized candidate-local top-k: int8 gather→score→DNF-mask
    keeps the top ``rerank_mult·k`` candidates (predicates evaluate on the
    EXACT scalars, so filtering is bit-identical to fp32), then the fp32
    kernel reranks exactly those rows — returned scores are exact fp32 and
    the quantization can only affect which near-boundary rows reach the
    rerank pool.

    Same contract as ``gather_score_topk``; ``n_qualified`` counts the
    original candidate list's qualifying slots (stage-1 semantics)."""
    from repro.kernels.shapes import MAX_TOPK

    kq = max(k, min(rerank_mult * k, MAX_TOPK))
    ids_q, _, n_qual = gather_score_topk(
        cand, vectors_i8, qs, weights, scalars, pred, k=kq, metric=metric,
        block_s=block_s, use_kernel=use_kernel, interpret=interpret,
        scales=scales)
    # survivors are already predicate-qualified and deduplicated (-1 pads)
    ids, scores, _ = gather_score_topk(
        ids_q, vectors, qs, weights, scalars, None, k=k, metric=metric,
        block_s=block_s, use_kernel=use_kernel, interpret=interpret)
    return ids, scores, n_qual


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p
