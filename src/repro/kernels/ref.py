"""Pure-jnp oracles for the Pallas kernels (the correctness contract)."""
from __future__ import annotations

import jax.numpy as jnp

NEG = -1e30


def masked_topk_ref(q, vectors, scalars, lo, hi, active, n_rows, *, k: int,
                    metric: str = "dot"):
    """Exact filtered top-k. Tie-break: smaller row id first (kernel parity).

    Returns (scores (k,), ids (k,)); empty slots score NEG / id -1."""
    n = vectors.shape[0]
    scores = vectors @ q
    if metric == "l2":
        scores = 2.0 * scores - jnp.sum(vectors * vectors, axis=1)
    ok = (scalars >= lo) & (scalars <= hi) | ~active.astype(bool)
    ok = jnp.all(ok, axis=1) & (jnp.arange(n) < n_rows)
    masked = jnp.where(ok, scores, NEG)
    # stable tie-break by row id: sort by (-score, id)
    order = jnp.lexsort((jnp.arange(n), -masked))
    ids = order[:k]
    top = masked[ids]
    return top, jnp.where(top > NEG / 2, ids, -1).astype(jnp.int32)


def int8_topk_ref(q, vec_i8, scales, scalars, lo, hi, active, n_rows, *, k: int):
    """Oracle for the quantized scan (dequantize then exact top-k)."""
    deq = vec_i8.astype(jnp.float32) * scales[:, None]
    return masked_topk_ref(q, deq, scalars, lo, hi, active, n_rows, k=k,
                           metric="dot")
