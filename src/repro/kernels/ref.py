"""Pure-jnp oracles for the Pallas kernels (the correctness contract)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.shapes import NEG


def masked_topk_ref(q, vectors, scalars, lo, hi, active, n_rows, *, k: int,
                    metric: str = "dot"):
    """Exact filtered top-k. Tie-break: smaller row id first (kernel parity).

    Returns (scores (k,), ids (k,)); empty slots score NEG / id -1."""
    n = vectors.shape[0]
    scores = vectors @ q
    if metric == "l2":
        scores = 2.0 * scores - jnp.sum(vectors * vectors, axis=1)
    ok = (scalars >= lo) & (scalars <= hi) | ~active.astype(bool)
    ok = jnp.all(ok, axis=1) & (jnp.arange(n) < n_rows)
    masked = jnp.where(ok, scores, NEG)
    # stable tie-break by row id: sort by (-score, id)
    order = jnp.lexsort((jnp.arange(n), -masked))
    ids = order[:k]
    top = masked[ids]
    return top, jnp.where(top > NEG / 2, ids, -1).astype(jnp.int32)


def int8_topk_ref(q, vec_i8, scales, scalars, lo, hi, active, n_rows, *, k: int):
    """Oracle for the quantized scan (dequantize then exact top-k)."""
    deq = vec_i8.astype(jnp.float32) * scales[:, None]
    return masked_topk_ref(q, deq, scalars, lo, hi, active, n_rows, k=k,
                           metric="dot")


def gather_score_ref(cand, vectors, qs, weights, scalars, lo, hi, active,
                     clause_valid, *, k: int, metric: str = "dot",
                     apply_pred: bool = True, scales=None):
    """Reference for the candidate-local gather+score kernel — and the
    executor's actual scoring path off-TPU (``gather_score_topk`` routes
    here unless a TPU backend is present).

    Same contract as ``gather_score.gather_score_topk`` after predicate
    normalization: cand (B, S) i32 rows (-1 = padding, duplicates allowed),
    vectors/qs per-column tuples, weights (B, n_vec), DNF fields (B, C, M)
    + (B, C). With ``scales`` (per-column (n,) f32) the vectors are int8
    replicas, dequantized per gathered row — the quantized-tier reference.
    -> (ids (B, k), scores (B, k), n_qualified (B,)); duplicate
    ids are suppressed and ties break by smaller row id."""
    from repro.kernels.gather_score import merge_topk_unique

    n = scalars.shape[0]
    b, s_tot = cand.shape
    if s_tot < k:  # selection needs at least k slots
        cand = jnp.pad(cand, ((0, 0), (0, k - s_tot)), constant_values=-1)
    cand = cand.astype(jnp.int32)
    idc = jnp.clip(cand, 0, n - 1)
    valid = cand >= 0
    total = jnp.zeros(cand.shape, jnp.float32)
    for i, (v, q) in enumerate(zip(vectors, qs)):
        g = v[idc]  # (B, S, d) — int8 when quantized: 4× fewer bytes moved
        if scales is not None:
            # per-row scale folds into the SCORE, like the kernel:
            # score(s·v) = s·score(v) for dot; l2 norms rescale by s² —
            # never materialize a second (B, S, d) dequantized tile
            gf = g.astype(jnp.float32)
            sc = scales[i][idc]  # (B, S)
            s = jnp.einsum("bsd,bd->bs", gf, q) * sc
            if metric == "l2":
                s = (2.0 * s - sc * sc * jnp.sum(gf * gf, axis=-1)
                     - jnp.sum(q * q, axis=-1)[:, None])
        else:
            s = jnp.einsum("bsd,bd->bs", g, q)
            if metric == "l2":
                s = (2.0 * s - jnp.sum(g * g, axis=-1)
                     - jnp.sum(q * q, axis=-1)[:, None])
        total = total + weights[:, i:i + 1] * s
    if apply_pred:
        st = scalars[idc]  # (B, S, M)
        ok_cm = ((st[:, :, None, :] >= lo[:, None])
                 & (st[:, :, None, :] <= hi[:, None])) \
            | (active[:, None] < 0.5)  # (B, S, C, M)
        clause = jnp.all(ok_cm, axis=-1) & (clause_valid[:, None, :] > 0.5)
        ok = jnp.any(clause, axis=-1)
    else:
        ok = jnp.ones(cand.shape, bool)
    qual = ok & valid
    masked = jnp.where(qual, total, NEG)
    ids, scores = merge_topk_unique(cand, masked, k)
    return ids, scores, jnp.sum(qual, axis=1)
