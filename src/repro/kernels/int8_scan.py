"""int8-quantized filtered scan (beyond-paper memory-bound optimization).

The full-scan strategy is HBM-bandwidth-bound: every query reads N·D·4
bytes. Block-wise int8 quantization of the DB (per-row absmax scale) cuts
that 4× — scores are computed on the int8 tile (dequantized in VMEM after
the MXU dot, not in HBM) and rescaled per row, then masked/top-k'd exactly
like masked_topk. The ref.py oracle bounds the quantization error; tests
assert recall@k parity within tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.shapes import ID_SENTINEL, NEG, SCAN_BLOCK_ROWS


def quantize_rows(vectors: jax.Array):
    """Per-row absmax int8 quantization. -> (q (N,D) int8, scale (N,) f32)."""
    scale = jnp.maximum(jnp.max(jnp.abs(vectors), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(vectors / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _kernel(q_ref, vec_ref, scale_ref, scal_ref, lo_ref, hi_ref, act_ref,
            nrows_ref, out_s_ref, out_i_ref, *, k: int, block_rows: int):
    i = pl.program_id(0)
    v = vec_ref[...].astype(jnp.float32)  # int8 tile -> f32 in VMEM
    q = q_ref[...]  # (1, D) f32
    scores = jnp.dot(v, q.T, preferred_element_type=jnp.float32)  # (BN, 1)
    scores = scores * scale_ref[...]  # per-row dequant
    sc = scal_ref[...]
    ok = (sc >= lo_ref[...]) & (sc <= hi_ref[...]) | (act_ref[...] < 0.5)
    ok = jnp.all(ok, axis=1, keepdims=True)
    row = jax.lax.broadcasted_iota(jnp.int32, (block_rows, 1), 0)
    gid = i * block_rows + row
    valid = gid < nrows_ref[0, 0]
    s = jnp.where(ok & valid, scores, NEG)
    for j in range(k):
        m = jnp.max(s)
        is_max = (s >= m) & (s > NEG / 2)
        first = jnp.min(jnp.where(is_max, gid, jnp.int32(ID_SENTINEL)))
        out_s_ref[0, j] = m
        out_i_ref[0, j] = jnp.where(m > NEG / 2, first, -1)
        s = jnp.where(gid == first, NEG, s)


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def int8_topk_blocks(q, vec_i8, scales, scalars, lo, hi, active, n_rows, *,
                     k: int, block_rows: int = SCAN_BLOCK_ROWS,
                     interpret: bool = True):
    n, d = vec_i8.shape
    m = scalars.shape[1]
    assert n % block_rows == 0
    nb = n // block_rows
    kern = functools.partial(_kernel, k=k, block_rows=block_rows)
    out_s, out_i = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, k), jnp.float32),
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
        ],
        interpret=interpret,
    )(q[None, :], vec_i8, scales[:, None], scalars, lo[None, :], hi[None, :],
      active[None, :].astype(jnp.float32),
      jnp.asarray(n_rows, jnp.int32).reshape(1, 1))
    return out_s, out_i
