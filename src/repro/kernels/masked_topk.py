"""Fused filtered-scan kernel: score ⊙ predicate-mask → per-block top-k.

The paper's hot loop (§3.4 execution) is "score rows, drop rows failing
Q_S, keep the best k". On TPU we tile the DB into (block_rows × dim) VMEM
blocks; each grid step runs one MXU matvec (scores), evaluates the
conjunctive range predicate on the block's scalars, masks, and selects the
block-local top-K by K rounds of max+knockout (K is static and small, so
this stays fully vectorized — no sort, which Mosaic lowers poorly).
Per-block candidates go back to HBM; the cross-block merge is a single
O(nb·K) ``lax.top_k`` in the caller (ops.py).

Grid is 1-D over row blocks; the query and predicate vectors stay resident
(their index_map pins block (0, …)). VMEM per step ≈ block_rows·(dim + M)·4B
— block_rows=1024, dim=768, M=8 ⇒ ~3.2 MB, comfortably inside 16 MB VMEM,
with dims aligned to the 128-lane MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.shapes import ID_SENTINEL, NEG, SCAN_BLOCK_ROWS


def _kernel(q_ref, vec_ref, scal_ref, lo_ref, hi_ref, act_ref, nrows_ref,
            out_s_ref, out_i_ref, *, k: int, block_rows: int, metric: str):
    i = pl.program_id(0)
    v = vec_ref[...]  # (BN, D)
    q = q_ref[...]  # (1, D)
    scores = jnp.dot(v, q.T, preferred_element_type=jnp.float32)  # (BN, 1)
    if metric == "l2":  # -||v - q||² up to the constant ||q||²
        scores = 2.0 * scores - jnp.sum(v * v, axis=1, keepdims=True)
    sc = scal_ref[...]  # (BN, M)
    ok = (sc >= lo_ref[...]) & (sc <= hi_ref[...]) | (act_ref[...] < 0.5)
    ok = jnp.all(ok, axis=1, keepdims=True)  # (BN, 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (block_rows, 1), 0)
    gid = i * block_rows + row
    valid = gid < nrows_ref[0, 0]
    s = jnp.where(ok & valid, scores, NEG)  # (BN, 1)

    # K rounds of (max, knockout) — static K keeps everything vectorized
    for j in range(k):
        m = jnp.max(s)
        # first row achieving the max (tie-break by smallest row id)
        is_max = (s >= m) & (s > NEG / 2)
        first = jnp.min(jnp.where(is_max, gid, jnp.int32(ID_SENTINEL)))
        out_s_ref[0, j] = m
        out_i_ref[0, j] = jnp.where(m > NEG / 2, first, -1)
        s = jnp.where(gid == first, NEG, s)


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "metric",
                                             "interpret"))
def masked_topk_blocks(q, vectors, scalars, lo, hi, active, n_rows, *,
                       k: int, block_rows: int = SCAN_BLOCK_ROWS,
                       metric: str = "dot", interpret: bool = True):
    """-> (block_scores (nb, k), block_ids (nb, k)). Inputs must be padded to
    a multiple of block_rows (ops.py handles padding + the final merge)."""
    n, d = vectors.shape
    m = scalars.shape[1]
    assert n % block_rows == 0, (n, block_rows)
    nb = n // block_rows
    kern = functools.partial(_kernel, k=k, block_rows=block_rows, metric=metric)
    out_s, out_i = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),  # q — resident
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),  # vectors tile
            pl.BlockSpec((block_rows, m), lambda i: (i, 0)),  # scalars tile
            pl.BlockSpec((1, m), lambda i: (0, 0)),  # lo
            pl.BlockSpec((1, m), lambda i: (0, 0)),  # hi
            pl.BlockSpec((1, m), lambda i: (0, 0)),  # active
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # n_rows
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, k), jnp.float32),
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
        ],
        interpret=interpret,
    )(q[None, :], vectors, scalars, lo[None, :], hi[None, :],
      active[None, :].astype(jnp.float32),
      jnp.asarray(n_rows, jnp.int32).reshape(1, 1))
    return out_s, out_i
