"""Minimal pure-JAX neural-net primitives (init/apply style, plain-dict params).

No flax/haiku in this environment — every layer is a pair of functions:
``*_init(key, ...) -> params`` and ``*_apply(params, x, ...) -> y``.
Params are nested dicts of jnp arrays so they stack cleanly for
``jax.lax.scan`` over homogeneous layer stacks.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def trunc_normal(key, shape, std, dtype=jnp.float32):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def lecun_normal(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    return trunc_normal(key, shape, 1.0 / math.sqrt(max(1, fan_in)), dtype)


# ---------------------------------------------------------------------------
# linear / mlp
# ---------------------------------------------------------------------------

def linear_init(key, in_dim, out_dim, *, bias=False, dtype=jnp.float32, std=None):
    wk, bk = jax.random.split(key)
    std = std if std is not None else 1.0 / math.sqrt(max(1, in_dim))
    p = {"w": trunc_normal(wk, (in_dim, out_dim), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def linear_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def mlp_init(key, dims: Sequence[int], *, bias=True, dtype=jnp.float32):
    """A plain ReLU MLP used by the BoomHQ encoder/rewriter heads."""
    keys = jax.random.split(key, len(dims) - 1)
    return {f"l{i}": linear_init(keys[i], dims[i], dims[i + 1], bias=bias, dtype=dtype)
            for i in range(len(dims) - 1)}


def mlp_apply(p, x, *, final_activation=False):
    n = len(p)
    for i in range(n):
        x = linear_apply(p[f"l{i}"], x)
        if i < n - 1 or final_activation:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p, x, *, eps=1e-6, zero_centered=False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = p["scale"].astype(jnp.float32)
    if zero_centered:  # gemma-style (1 + scale)
        scale = 1.0 + scale
    return (x * scale).astype(dt)


def layernorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p, x, *, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embedding_init(key, vocab, dim, dtype=jnp.float32):
    return {"table": trunc_normal(key, (vocab, dim), 1.0, dtype)}


def embedding_apply(p, ids):
    return p["table"][ids]


def embedding_attend(p, x):
    """Tied-weights logit projection."""
    return x @ p["table"].T


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {
        "relu": jax.nn.relu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "gelu_exact": lambda x: jax.nn.gelu(x, approximate=False),
        "silu": jax.nn.silu,
    }[name]
