"""Pytree helpers shared across the framework."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_map(fn: Callable, *trees: PyTree) -> PyTree:
    return jax.tree.map(fn, *trees)


def tree_paths(tree: PyTree) -> list[tuple[str, Any]]:
    """Flatten a pytree into (dotted-path, leaf) pairs."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        out.append((path_str(path), leaf))
    return out


def path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:  # pragma: no cover - defensive
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: PyTree) -> PyTree:
    """Map ``fn(path, leaf)`` over ``tree`` keeping structure."""

    def _fn(path, leaf):
        return fn(path_str(path), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


def param_count(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def param_bytes(tree: PyTree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize for x in jax.tree.leaves(tree)
    )


def cast_tree(tree: PyTree, dtype) -> PyTree:
    """Cast floating leaves to ``dtype``; leave integer leaves untouched."""

    def _cast(x):
        if jnp.issubdtype(jnp.asarray(x).dtype if not hasattr(x, "dtype") else x.dtype, jnp.floating):
            return x.astype(dtype) if hasattr(x, "astype") else jnp.asarray(x, dtype)
        return x

    return jax.tree.map(_cast, tree)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_zeros_like(tree: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def stack_trees(trees: list[PyTree]) -> PyTree:
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def check_finite(tree: PyTree) -> jax.Array:
    """True iff every floating leaf is finite."""
    oks = [
        jnp.all(jnp.isfinite(x))
        for x in jax.tree.leaves(tree)
        if jnp.issubdtype(x.dtype, jnp.floating)
    ]
    if not oks:
        return jnp.asarray(True)
    return jnp.stack(oks).all()
