"""jax version compatibility shims.

The repo targets the modern public APIs (``jax.shard_map``,
``jax.sharding.AxisType``) but must also run on jax 0.4.x, where shard_map
still lives in ``jax.experimental`` (with ``check_rep`` instead of
``check_vma``) and mesh axis types don't exist yet.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` with fallback to the 0.4.x experimental API."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  **kwargs)
    from jax.experimental.shard_map import shard_map as legacy_sm
    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kwargs)
