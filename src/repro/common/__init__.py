from repro.common import nn, pytree  # noqa: F401
