"""Deterministic, shardable, resumable data pipeline.

Requirements at pod scale:
  * every host derives its own batch shard purely from (seed, step, host) — no
    coordinator traffic, no file-offset state to lose on preemption;
  * a replacement host (straggler swap / elastic reshard) reproduces the
    exact stream the failed host would have produced;
  * resume-from-checkpoint only needs the integer ``step`` cursor.

Two sources:
  * ``SyntheticLM``: Zipf-ish token stream (smoke tests, dry-runs, examples).
  * ``PackedCorpus``: document packing from an in-memory token array with
    deterministic shuffling — the real-data path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class BatchSpec:
    global_batch: int
    seq_len: int
    vocab: int
    num_hosts: int = 1
    host_index: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticLM:
    """Deterministic synthetic LM batches: tokens + next-token labels."""

    def __init__(self, spec: BatchSpec, seed: int = 0):
        self.spec = spec
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        s = self.spec
        gen = np.random.default_rng([self.seed, step, s.host_index, 0x0B00])
        # Zipf-flavoured marginal so the loss curve is non-trivial
        z = gen.zipf(1.3, size=(s.host_batch, s.seq_len + 1))
        tokens = np.minimum(z - 1, s.vocab - 1).astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PackedCorpus:
    """Pack documents into fixed-length sequences, deterministic per step."""

    def __init__(self, docs: list[np.ndarray], spec: BatchSpec, seed: int = 0,
                 eos_id: int = 0):
        self.spec = spec
        self.seed = seed
        stream = []
        for d in docs:
            stream.append(np.asarray(d, np.int32))
            stream.append(np.array([eos_id], np.int32))
        self.stream = np.concatenate(stream) if stream else np.zeros((1,), np.int32)

    def batch_at(self, step: int) -> dict:
        s = self.spec
        need = s.host_batch * (s.seq_len + 1)
        rng = np.random.default_rng([self.seed, step, s.host_index, 1])
        # deterministic random window offsets into the packed stream
        offs = rng.integers(0, max(1, len(self.stream) - s.seq_len - 1), size=s.host_batch)
        rows = np.stack(
            [np.take(self.stream, np.arange(o, o + s.seq_len + 1), mode="wrap") for o in offs]
        )
        assert rows.size == need
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_source(kind: str, spec: BatchSpec, seed: int = 0, docs=None):
    if kind == "synthetic":
        return SyntheticLM(spec, seed)
    if kind == "packed":
        return PackedCorpus(docs or [], spec, seed)
    raise ValueError(f"unknown data source {kind!r}")
