from repro.data.pipeline import BatchSpec, SyntheticLM, PackedCorpus, make_source  # noqa: F401
