"""Fault tolerance for pod-scale training (DESIGN.md §6).

Pieces, all host-side and engine-agnostic:
  * ``StepWatchdog``     — rolling p50 step time; flags hosts whose steps
                           exceed ``straggler_factor × p50`` for ``patience``
                           consecutive steps (straggler mitigation = report
                           to the coordinator, checkpoint, restart without
                           the slow host — exercised in tests with a fake
                           clock).
  * ``PreemptionGuard``  — SIGTERM/SIGINT handler that requests a final
                           synchronous checkpoint before exit (TPU-pod
                           maintenance events deliver SIGTERM).
  * ``Heartbeat``        — tiny file-based liveness protocol: every host
                           touches ``<dir>/host_<i>`` each step; a
                           coordinator scanning mtimes finds dead hosts.
                           (On real pods this is the job orchestrator's
                           role; the file protocol makes it testable.)
  * ``run_resilient``    — drives a train loop with periodic checkpoints,
                           auto-resume from the newest valid manifest and
                           checkpoint-on-preemption.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Callable, Optional

import numpy as np


class StepWatchdog:
    def __init__(self, straggler_factor: float = 2.0, patience: int = 3,
                 window: int = 50):
        self.factor = straggler_factor
        self.patience = patience
        self.window = window
        self.times: list[float] = []
        self.strikes = 0
        self.flagged = False

    def record(self, step_seconds: float) -> bool:
        """Record one step; returns True if this host is now flagged."""
        self.times.append(step_seconds)
        hist = self.times[-self.window:]
        if len(hist) >= 5:
            p50 = float(np.median(hist))
            if step_seconds > self.factor * p50:
                self.strikes += 1
            else:
                self.strikes = 0
            if self.strikes >= self.patience:
                self.flagged = True
        return self.flagged

    def p50(self) -> float:
        return float(np.median(self.times[-self.window:])) if self.times else 0.0


class PreemptionGuard:
    """Installs SIGTERM/SIGINT handlers that set a flag; the train loop
    checks ``should_checkpoint`` each step and exits cleanly."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.should_checkpoint = False
        self._prev = {}
        for s in signals:
            self._prev[s] = signal.signal(s, self._handler)

    def _handler(self, signum, frame):  # noqa: ARG002
        self.should_checkpoint = True

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class Heartbeat:
    def __init__(self, directory: str, host_index: int):
        self.dir = directory
        self.host = host_index
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, f"host_{host_index:05d}")

    def beat(self):
        with open(self.path, "w") as f:
            f.write(str(time.time()))

    @staticmethod
    def dead_hosts(directory: str, timeout_s: float, now: Optional[float] = None):
        now = now if now is not None else time.time()
        dead = []
        if not os.path.isdir(directory):
            return dead
        for name in sorted(os.listdir(directory)):
            if not name.startswith("host_"):
                continue
            mtime = os.path.getmtime(os.path.join(directory, name))
            if now - mtime > timeout_s:
                dead.append(int(name.split("_")[1]))
        return dead


@dataclasses.dataclass
class ResilientReport:
    start_step: int
    end_step: int
    checkpoints: list[int]
    preempted: bool
    straggler_flagged: bool


def run_resilient(step_fn: Callable[[int, dict], dict], state: dict, *,
                  ckpt_dir: str, total_steps: int, ckpt_every: int = 100,
                  watchdog: Optional[StepWatchdog] = None,
                  guard: Optional[PreemptionGuard] = None,
                  save_fn=None, restore_fn=None) -> ResilientReport:
    """Generic resilient loop: auto-resume + periodic/preemption checkpoints.

    ``save_fn(dir, step, state)`` / ``restore_fn(dir) -> (step, state)`` default
    to repro.checkpoint.ckpt.
    """
    from repro.checkpoint import ckpt

    save_fn = save_fn or (lambda d, s, st: ckpt.save(d, s, st))
    if restore_fn is None:
        def restore_fn(d):
            step = ckpt.latest_step(d)
            if step is None:
                return 0, None
            s, tree, _ = ckpt.restore(d, like=state)
            return s, tree

    start, restored = restore_fn(ckpt_dir)
    if restored is not None:
        state = restored
    watchdog = watchdog or StepWatchdog()
    saved = []
    preempted = False
    step = start
    while step < total_steps:
        t0 = time.perf_counter()
        state = step_fn(step, state)
        watchdog.record(time.perf_counter() - t0)
        step += 1
        if guard is not None and guard.should_checkpoint:
            save_fn(ckpt_dir, step, state)
            saved.append(step)
            preempted = True
            break
        if step % ckpt_every == 0 or step == total_steps:
            save_fn(ckpt_dir, step, state)
            saved.append(step)
    return ResilientReport(start_step=start, end_step=step, checkpoints=saved,
                           preempted=preempted,
                           straggler_flagged=watchdog.flagged)
