"""Elastic scaling: reshard a checkpointed train state onto a new mesh.

Checkpoints are stored mesh-agnostic (repro.checkpoint saves full arrays +
partition specs in the manifest), so scale-up/down/axis-reshape is just a
restore with new shardings. ``replan`` recomputes per-arch shardings for the
new mesh and validates divisibility, reporting which axes changed.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import sharding as shd


@dataclasses.dataclass
class ReshardReport:
    old_mesh: tuple
    new_mesh: tuple
    n_params: int
    changed_axes: list


def replan(cfg: ModelConfig, params_shape, old_mesh, new_mesh, *,
           fsdp: bool = False) -> tuple:
    """-> (new sharding tree, report). Raises if a sharded dim no longer
    divides the new mesh axis size."""
    spec = shd.param_specs(cfg, params_shape, fsdp=fsdp)
    flat_specs = jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, P))
    flat_shapes = jax.tree.leaves(params_shape)
    changed = []
    for s, leaf in zip(flat_specs, flat_shapes):
        for dim, ax in enumerate(tuple(s)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= dict(zip(new_mesh.axis_names, new_mesh.axis_sizes
                                 if hasattr(new_mesh, "axis_sizes")
                                 else new_mesh.devices.shape))[a]
            if leaf.shape[dim] % size != 0:
                raise ValueError(
                    f"elastic reshard: dim {dim} of {leaf.shape} not divisible "
                    f"by new axis {axes}={size}")
    if tuple(old_mesh.devices.shape) != tuple(new_mesh.devices.shape):
        changed = [
            (a, o, n) for a, o, n in zip(
                new_mesh.axis_names, old_mesh.devices.shape,
                new_mesh.devices.shape) if o != n
        ]
    ns = jax.tree.map(lambda s: NamedSharding(new_mesh, s), spec,
                      is_leaf=lambda x: isinstance(x, P))
    report = ReshardReport(
        old_mesh=tuple(old_mesh.devices.shape),
        new_mesh=tuple(new_mesh.devices.shape),
        n_params=len(flat_shapes), changed_axes=changed)
    return ns, report
