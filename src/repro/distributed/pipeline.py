"""Microbatched pipeline parallelism over a mesh axis (GPipe-style).

The production mesh has no dedicated pipeline axis (DESIGN.md §5) — PP is
provided as an option for meshes that do (e.g. repurposing `pod`). Stages
are laid out over ``axis``; the schedule is the classic fill-drain loop
expressed in shard_map: each stage applies its layer block to the current
microbatch and ``ppermute``s activations to the next stage. Bubble fraction
= (S-1)/(M+S-1) for S stages / M microbatches, surfaced by ``bubble()``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.common import compat


def bubble(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def pipeline_apply(mesh: Mesh, axis: str, stage_fn, n_microbatches: int):
    """Build fn(stage_params, x) running a stage-partitioned pipeline.

    ``stage_params`` leaves carry a leading stage dim sharded over ``axis``;
    ``x`` is (n_microbatches, mb, ...) with microbatches entering stage 0.
    Returns outputs (n_microbatches, mb, ...) from the LAST stage (gathered).
    """
    n_stages = mesh.shape[axis]

    def local(params, x):
        # params: this stage's block params (leading dim 1) ; x: all mbs
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        mb = x[0]
        zero = jnp.zeros_like(mb)
        n_ticks = n_microbatches + n_stages - 1
        outs = jnp.zeros((n_microbatches,) + mb.shape, mb.dtype)

        def tick(t, carry):
            inflight, outs = carry
            # stage 0 injects microbatch t (if any); others use the permuted
            mb_idx = jnp.clip(t, 0, n_microbatches - 1)
            inject = jax.lax.dynamic_index_in_dim(x, mb_idx, 0, keepdims=False)
            cur = jnp.where(stage == 0, inject, inflight)
            active = (t - stage >= 0) & (t - stage < n_microbatches)
            y = stage_fn(params, cur)
            y = jnp.where(active, y, zero)
            # last stage emits its finished microbatch
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_microbatches - 1)
            emit = (stage == n_stages - 1) & active
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, y, outs[out_idx]), out_idx, 0)
            # forward activations to the next stage
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return nxt, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (zero, outs))
        # bring the last stage's outputs to every stage (replicated out)
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    shard = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
    return shard
