"""Inline suppressions and the checked-in baseline.

Inline syntax (same line as the finding, or a standalone comment on the
line(s) above it)::

    x = int(n_qual)  # boomlint: ignore[HS001] one sync per round is the contract

    # boomlint: ignore[HS001,RC001] reason may span
    # further plain comment lines
    x = int(n_qual)

A standalone suppression comment applies to the next non-comment,
non-blank line. The baseline is a JSON file of finding keys
(rule, path, stripped source line) so entries survive unrelated line
drift; matched entries are consumed (multiset semantics).
"""
from __future__ import annotations

import json
import re

from repro.analysis.findings import Finding

SUPPRESS_RE = re.compile(
    r"#\s*boomlint:\s*ignore\[([A-Za-z0-9_,\s]+)\]\s*(.*)")


def parse_suppressions(source: str) -> dict:
    """-> {line_number: set(rule_ids)} of suppressed lines (1-indexed)."""
    out: dict = {}
    lines = source.splitlines()
    for i, line in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        out.setdefault(i, set()).update(rules)
        if line.strip().startswith("#"):
            # standalone comment: also covers the next code line
            j = i
            while j < len(lines) and (not lines[j].strip()
                                      or lines[j].strip().startswith("#")):
                j += 1
            if j < len(lines):
                out.setdefault(j + 1, set()).update(rules)
    return out


def split_suppressed(findings: list, suppressions_by_path: dict) -> tuple:
    """-> (active, suppressed) given {path: {line: rules}} maps."""
    active, suppressed = [], []
    for f in findings:
        rules = suppressions_by_path.get(f.path, {}).get(f.line, set())
        (suppressed if f.rule in rules else active).append(f)
    return active, suppressed


class Baseline:
    def __init__(self, entries: list | None = None):
        self.entries = list(entries or [])

    @classmethod
    def load(cls, path) -> "Baseline":
        with open(path) as fh:
            data = json.load(fh)
        return cls([(e["rule"], e["path"], e.get("context", ""))
                    for e in data.get("entries", [])])

    @classmethod
    def from_findings(cls, findings: list) -> "Baseline":
        return cls([f.key() for f in findings])

    def save(self, path) -> None:
        entries = [{"rule": r, "path": p, "context": c}
                   for (r, p, c) in sorted(self.entries)]
        with open(path, "w") as fh:
            json.dump({"version": 1, "entries": entries}, fh, indent=2,
                      sort_keys=True)
            fh.write("\n")

    def filter(self, findings: list) -> list:
        """Drop findings matching a baseline entry (each entry consumes at
        most one finding)."""
        budget: dict = {}
        for key in self.entries:
            budget[key] = budget.get(key, 0) + 1
        out = []
        for f in findings:
            k = f.key()
            if budget.get(k, 0) > 0:
                budget[k] -= 1
            else:
                out.append(f)
        return out


def _self_test_finding() -> Finding:  # pragma: no cover - debugging helper
    return Finding("HS001", "x.py", 1, "m", context="int(x)")
