"""Machine-readable findings: (rule, file:line, message, severity)."""
from __future__ import annotations

import dataclasses
import json

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str  # e.g. "HS001"
    path: str  # repo-relative posix path
    line: int  # 1-indexed
    message: str
    severity: str = ERROR
    # the stripped source line (or a stable label for trace-level findings):
    # baselines key on it so entries survive unrelated line drift
    context: str = ""

    def key(self) -> tuple:
        return (self.rule, self.path, self.context)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.severity}: " \
               f"{self.message}"


def to_json(findings: list) -> str:
    return json.dumps([f.to_dict() for f in findings], indent=2,
                      sort_keys=True)


def sort_findings(findings: list) -> list:
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule, f.message))
