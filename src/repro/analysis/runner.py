"""Scan orchestration: file collection, cross-module jit registry,
suppressions, baseline."""
from __future__ import annotations

import os

from repro.analysis import astpass
from repro.analysis.config import LintConfig
from repro.analysis.findings import sort_findings
from repro.analysis.suppressions import (
    Baseline, parse_suppressions, split_suppressed,
)


def collect_files(paths: list) -> list:
    files = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d not in ("__pycache__", ".git"))
            files.extend(os.path.join(root, n) for n in sorted(names)
                         if n.endswith(".py"))
    return files


def _relpath(path: str) -> str:
    return os.path.relpath(path).replace(os.sep, "/")


def run_paths(paths: list, cfg: LintConfig | None = None,
              baseline: Baseline | None = None) -> dict:
    """Run level 1 (and level 2 when ``cfg.trace``) over ``paths``.

    Returns ``{"active": [...], "suppressed": [...], "baselined": n}`` —
    ``active`` is what should gate CI."""
    cfg = cfg or LintConfig()
    files = collect_files(paths)
    sources = {}
    for f in files:
        with open(f, encoding="utf-8") as fh:
            sources[f] = fh.read()

    # pass 1: every module's decorated jit entries feed the RC001 registry,
    # so cross-module call sites (serve -> vectordb) are checked too
    entries: dict = {}
    for f, src in sources.items():
        try:
            lint = astpass.ModuleLint(f, src, cfg, relpath=_relpath(f))
            entries.update(lint.collect_jit_entries())
        except SyntaxError:
            continue
    astpass.ModuleLint.reset_jit_entries()
    astpass.ModuleLint.register_jit_entries(entries)

    findings = []
    suppress_maps: dict = {}
    for f, src in sources.items():
        rel = _relpath(f)
        try:
            findings.extend(astpass.lint_source(f, src, cfg, relpath=rel))
        except SyntaxError as e:
            from repro.analysis.findings import Finding
            findings.append(Finding("XX000", rel, e.lineno or 1,
                                    f"syntax error: {e.msg}"))
        suppress_maps[rel] = parse_suppressions(src)

    if cfg.trace:
        from repro.analysis import tracepass
        findings.extend(tracepass.run_trace_checks(cfg))

    findings = sort_findings(findings)
    if cfg.ignore_suppressions:
        active, suppressed = findings, []
    else:
        active, suppressed = split_suppressed(findings, suppress_maps)
    baselined = 0
    if baseline is not None:
        before = len(active)
        active = baseline.filter(active)
        baselined = before - len(active)
    return {"active": active, "suppressed": suppressed,
            "baselined": baselined}
