"""Level-1 AST rules: HS001, RC001, SM001, PL001 (literal shapes), EP001.

The pass builds a per-module picture of which functions run under a JAX
trace (decorated with jit/vmap, wrapped at a call site, passed to
``shard_map``/``pallas_call``/``lax`` control flow, or nested inside any
of those) and runs a forward taint analysis over each: parameters that
are not static argnames are *traced values*, and anything that would
force one to the host mid-trace is a finding. Host functions on the
serving hot path get the complementary check: device→host coercions
inside loops (a sync per iteration) and repeated transfers of the same
expression (the PR 1 bug class).

The scope detection and taint rules are deliberately calibrated against
this repo's idioms — ``functools.partial(kern, **static)`` bodies handed
to ``pallas_call``, ``compat.shard_map(local, ...)`` closures over static
config, ``.shape``/``len()`` reads that are static under trace — so the
repo lints clean without blanket suppressions.
"""
from __future__ import annotations

import ast
import fnmatch

from repro.analysis.config import LintConfig
from repro.analysis.findings import Finding

JIT_NAMES = {"jax.jit", "jit", "pjit", "jax.pjit"}
VMAP_NAMES = {"jax.vmap", "vmap"}
PARTIAL_NAMES = {"partial", "functools.partial"}
NP_ALIASES = {"np", "numpy", "onp"}
# jax.lax control-flow wrappers whose callable args trace
LAX_CALLEES = {"scan", "fori_loop", "while_loop", "cond", "switch", "map",
               "associative_scan", "custom_root"}
# attributes that read static metadata off a traced value; n_clauses is
# this repo's shape-derived clause count (predicates.PredicateSet.n_clauses
# returns int(active.shape[-2]) — static at trace time by construction)
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval",
                "n_clauses"}
UNTAINTING_CALLS = {"len", "range", "isinstance", "hasattr", "type"}
# builtins whose result is a host scalar (SM001 scalar inference)
SCALAR_CALLS = {"max", "min", "len", "int", "float", "round", "abs", "bool"}
COERCERS = {"int", "float", "bool", "complex"}
# SM001: (callee tail -> positions that consume arrays)
ARRAY_CONSUMERS = {
    "similarity": (0, 1), "eval_mask": (1,), "gather_score_topk": (0, 4),
    "search_local_batch": (1, 2), "filter_first_local_batch": (0, 1),
    "dot": (0, 1), "matmul": (0, 1), "einsum": (1, 2), "take": (0,),
    "sum": (0,), "mean": (0,), "top_k": (0,), "where": (0, 1, 2),
}
DTYPE_BYTES = {"float32": 4, "int32": 4, "uint32": 4, "float64": 8,
               "bfloat16": 2, "float16": 2, "int16": 2, "int8": 1,
               "uint8": 1, "bool_": 1}


def dotted(node) -> str | None:
    """'jax.jit' for Attribute/Name chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _tail(name: str | None) -> str:
    return name.rsplit(".", 1)[-1] if name else ""


def _annotate_parents(tree) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._bl_parent = node


def _scope_of(node):
    """Nearest enclosing FunctionDef/Module of a node (excluding itself)."""
    cur = getattr(node, "_bl_parent", None)
    while cur is not None and not isinstance(
            cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)):
        cur = getattr(cur, "_bl_parent", None)
    return cur


def _qualname(fn) -> str:
    parts = [fn.name]
    cur = getattr(fn, "_bl_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            parts.append(cur.name)
        cur = getattr(cur, "_bl_parent", None)
    return ".".join(reversed(parts))


def _param_names(fn) -> list:
    a = fn.args
    params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg.arg)
    if a.kwarg:
        params.append(a.kwarg.arg)
    return params


def _static_from_keywords(keywords, fn=None) -> set:
    """static_argnames/static_argnums keyword values -> param-name set."""
    static: set = set()
    for kw in keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                static.add(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                static.update(e.value for e in v.elts
                              if isinstance(e, ast.Constant)
                              and isinstance(e.value, str))
        elif kw.arg == "static_argnums" and fn is not None:
            nums = []
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                nums = [v.value]
            elif isinstance(v, (ast.Tuple, ast.List)):
                nums = [e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, int)]
            pos = fn.args.posonlyargs + fn.args.args
            for i in nums:
                if 0 <= i < len(pos):
                    static.add(pos[i].arg)
    return static


class ModuleLint:
    """One source file through every level-1 rule."""

    def __init__(self, path: str, source: str, cfg: LintConfig,
                 relpath: str | None = None):
        self.path = relpath if relpath is not None else path
        self.source = source
        self.cfg = cfg
        self.findings: list = []
        self.tree = ast.parse(source, filename=path)
        _annotate_parents(self.tree)
        self.lines = source.splitlines()
        self._module_names: set = set()
        self._defs: dict = {}  # (id(scope), name) -> FunctionDef
        self._partials: dict = {}  # (id(scope), var) -> (fndef, static set)
        self._shard_map_calls: list = []  # (call node, body def)
        self._jit_entries: dict = {}  # name -> static arg-name set
        self._analyzed: set = set()

    # -- driver -------------------------------------------------------------

    def run(self) -> list:
        self._collect()
        self._mark_traced()
        for fn in self._all_defs():
            if getattr(fn, "_bl_traced", False) and not getattr(
                    _scope_of(fn), "_bl_traced", False):
                self._scan_traced(fn, inherited=frozenset())
            elif not getattr(fn, "_bl_traced", False) and self._is_hot(fn):
                self._scan_hot(fn)
            if self._is_hot(fn):
                self._check_ep001(fn)
                self._check_ep002(fn)
        self._check_rc001()
        for call, body in self._shard_map_calls:
            self._check_sm001(call, body)
        self._check_pl001()
        return self.findings

    def _emit(self, rule, node, message, severity="error"):
        line = getattr(node, "lineno", 1)
        ctx = self.lines[line - 1].strip() if line - 1 < len(self.lines) \
            else ""
        self.findings.append(Finding(rule, self.path, line, message,
                                     severity, ctx))

    # -- collection ---------------------------------------------------------

    def _all_defs(self):
        return [n for n in ast.walk(self.tree)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]

    def _collect(self):
        for node in self.tree.body:
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                for a in node.names:
                    self._module_names.add(a.asname or a.name.split(".")[0])
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                self._module_names.add(node.name)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            self._module_names.add(n.id)
            elif isinstance(node, ast.AnnAssign) and isinstance(
                    node.target, ast.Name):
                self._module_names.add(node.target.id)
        for fn in self._all_defs():
            scope = _scope_of(fn)
            self._defs[(id(scope), fn.name)] = fn

    def _resolve(self, name: str, from_node):
        cur = _scope_of(from_node)
        while cur is not None:
            fn = self._defs.get((id(cur), name))
            if fn is not None:
                return fn
            cur = _scope_of(cur) if not isinstance(cur, ast.Module) else None
        return None

    def _mark(self, fn, static: set, reason: str):
        fn._bl_traced = True
        fn._bl_static = getattr(fn, "_bl_static", set()) | set(static)
        fn._bl_reason = getattr(fn, "_bl_reason", reason)

    def _mark_callable(self, arg, at_node, static=(), reason="wrapped"):
        """Mark the function a wrapper call-arg refers to as traced."""
        if isinstance(arg, ast.Name):
            fn = self._resolve(arg.id, at_node)
            if fn is None:
                # maybe a partial var: partial(kern, **static) -> pallas_call
                rec = self._lookup_partial(arg.id, at_node)
                if rec is not None:
                    self._mark(rec[0], set(static) | rec[1], reason)
                return
            self._mark(fn, static, reason)
        elif isinstance(arg, ast.Call):
            fd = dotted(arg.func)
            if fd in PARTIAL_NAMES and arg.args:
                kw_static = {k.arg for k in arg.keywords if k.arg}
                self._mark_callable(arg.args[0], at_node,
                                    set(static) | kw_static, reason)
            elif fd in JIT_NAMES or fd in VMAP_NAMES or (
                    fd and _tail(fd) in LAX_CALLEES):
                for sub in arg.args:
                    self._mark_callable(sub, at_node, static, reason)
        elif isinstance(arg, ast.Lambda):
            arg._bl_traced = True
            arg._bl_static = set(static)

    def _lookup_partial(self, name, from_node):
        cur = _scope_of(from_node)
        while cur is not None:
            rec = self._partials.get((id(cur), name))
            if rec is not None:
                return rec
            cur = _scope_of(cur) if not isinstance(cur, ast.Module) else None
        return None

    def _mark_traced(self):
        # decorators
        for fn in self._all_defs():
            for dec in fn.decorator_list:
                d = dotted(dec)
                if d in JIT_NAMES:
                    self._mark(fn, set(), "jit")
                    self._jit_entries.setdefault(fn.name, set())
                elif d in VMAP_NAMES:
                    self._mark(fn, set(), "vmap")
                elif isinstance(dec, ast.Call):
                    fd = dotted(dec.func)
                    if fd in PARTIAL_NAMES and dec.args and (
                            dotted(dec.args[0]) in JIT_NAMES):
                        static = _static_from_keywords(dec.keywords, fn)
                        self._mark(fn, static, "jit")
                        self._jit_entries[fn.name] = static
                    elif fd in PARTIAL_NAMES and dec.args and (
                            dotted(dec.args[0]) in VMAP_NAMES):
                        self._mark(fn, set(), "vmap")
                    elif fd in JIT_NAMES:
                        static = _static_from_keywords(dec.keywords, fn)
                        self._mark(fn, static, "jit")
                        self._jit_entries[fn.name] = static
                    elif fd in VMAP_NAMES:
                        self._mark(fn, set(), "vmap")
        # partial assignments + wrapper call sites
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call):
                fd = dotted(node.value.func)
                if fd in PARTIAL_NAMES and node.value.args and isinstance(
                        node.value.args[0], ast.Name):
                    body = self._resolve(node.value.args[0].id, node)
                    if body is not None and len(node.targets) == 1 and \
                            isinstance(node.targets[0], ast.Name):
                        kw_static = {k.arg for k in node.value.keywords
                                     if k.arg}
                        scope = _scope_of(node)
                        self._partials[(id(scope), node.targets[0].id)] = \
                            (body, kw_static)
            if not isinstance(node, ast.Call):
                continue
            fd = dotted(node.func)
            tail = _tail(fd)
            if fd in JIT_NAMES or fd in VMAP_NAMES:
                static = _static_from_keywords(node.keywords)
                for a in node.args:
                    self._mark_callable(a, node, static, "wrapped")
            elif tail == "shard_map":
                if node.args and isinstance(node.args[0], ast.Name):
                    body = self._resolve(node.args[0].id, node)
                    if body is not None:
                        self._mark(body, set(), "shard_map")
                        self._shard_map_calls.append((node, body))
                elif node.args:
                    self._mark_callable(node.args[0], node, (), "shard_map")
            elif tail == "pallas_call":
                if node.args:
                    self._mark_callable(node.args[0], node, (),
                                        "pallas_call")
            elif tail in LAX_CALLEES and fd and fd not in ("map",):
                for a in node.args:
                    if isinstance(a, (ast.Name, ast.Lambda)) or (
                            isinstance(a, ast.Call)
                            and dotted(a.func) in PARTIAL_NAMES):
                        self._mark_callable(a, node, (), "lax")

    # -- HS001 scope A: traced functions ------------------------------------

    def _scan_traced(self, fn, inherited):
        if id(fn) in self._analyzed:
            return
        self._analyzed.add(id(fn))
        params = set(_param_names(fn))
        static = getattr(fn, "_bl_static", set())
        tainted = (params - set(static)) | set(inherited)
        # pass 1 builds the taint environment, pass 2 emits findings —
        # handles names first used above their (re)binding site
        self._walk_traced_body(fn.body, tainted, emit=False)
        self._walk_traced_body(fn.body, set(tainted), emit=True)

    def _walk_traced_body(self, stmts, tainted, emit):
        for st in stmts:
            self._walk_traced_stmt(st, tainted, emit)

    def _walk_traced_stmt(self, st, tainted, emit):
        t = self._taint  # shorthand
        if isinstance(st, ast.Assign):
            val = t(st.value, tainted, emit)
            for tgt in st.targets:
                self._bind(tgt, val, tainted)
        elif isinstance(st, ast.AugAssign):
            val = t(st.value, tainted, emit) or t(st.target, tainted, False)
            self._bind(st.target, val, tainted)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self._bind(st.target, t(st.value, tainted, emit), tainted)
        elif isinstance(st, (ast.If, ast.While)):
            if t(st.test, tainted, emit) and emit:
                kind = "while" if isinstance(st, ast.While) else "if"
                self._emit(
                    "HS001", st.test,
                    f"data-dependent `{kind}` on a traced value forces a "
                    f"host sync (TracerBoolConversionError under jit; a "
                    f"silent device round-trip otherwise) — use lax.cond/"
                    f"jnp.where or hoist the decision")
            self._walk_traced_body(st.body, tainted, emit)
            self._walk_traced_body(st.orelse, tainted, emit)
        elif isinstance(st, ast.For):
            val = t(st.iter, tainted, emit)
            self._bind(st.target, val, tainted)
            self._walk_traced_body(st.body, tainted, emit)
            self._walk_traced_body(st.orelse, tainted, emit)
        elif isinstance(st, ast.Assert):
            if t(st.test, tainted, emit) and emit:
                self._emit(
                    "HS001", st.test,
                    "assert on a traced value forces a host sync — assert "
                    "on static shapes or use checkify")
        elif isinstance(st, (ast.Return, ast.Expr)):
            if st.value is not None:
                t(st.value, tainted, emit)
        elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            free = self._free_names(st)
            self._scan_traced(st, inherited=frozenset(tainted & free))
        elif isinstance(st, ast.With):
            for item in st.items:
                t(item.context_expr, tainted, emit)
            self._walk_traced_body(st.body, tainted, emit)
        elif isinstance(st, ast.Try):
            self._walk_traced_body(st.body, tainted, emit)
            for h in st.handlers:
                self._walk_traced_body(h.body, tainted, emit)
            self._walk_traced_body(st.orelse, tainted, emit)
            self._walk_traced_body(st.finalbody, tainted, emit)
        elif isinstance(st, (ast.Raise, ast.Delete, ast.Pass, ast.Break,
                             ast.Continue, ast.Global, ast.Nonlocal,
                             ast.Import, ast.ImportFrom, ast.ClassDef)):
            pass
        else:  # anything exotic: evaluate child expressions for taint flags
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.expr):
                    t(child, tainted, emit)

    def _bind(self, target, val: bool, tainted):
        for n in ast.walk(target):
            if isinstance(n, ast.Name):
                if val:
                    tainted.add(n.id)
                else:
                    tainted.discard(n.id)

    def _taint(self, e, tainted, emit) -> bool:
        """Taint of an expression; emits HS001 findings when `emit`."""
        if e is None or isinstance(e, ast.Constant):
            return False
        if isinstance(e, ast.Name):
            return e.id in tainted
        if isinstance(e, ast.Attribute):
            if e.attr in STATIC_ATTRS:
                self._taint(e.value, tainted, emit)
                return False
            return self._taint(e.value, tainted, emit)
        if isinstance(e, ast.Subscript):
            v = self._taint(e.value, tainted, emit)
            s = self._taint(e.slice, tainted, emit)
            return v or s
        if isinstance(e, ast.Call):
            return self._taint_call(e, tainted, emit)
        if isinstance(e, ast.Compare):
            left = self._taint(e.left, tainted, emit)
            base = False
            for op, cmp in zip(e.ops, e.comparators):
                ct = self._taint(cmp, tainted, emit)
                if isinstance(op, (ast.Is, ast.IsNot)):
                    continue  # `x is None` stays a static decision — the
                    # identity test resolves at trace time even when x is a
                    # tracer, so the left operand's taint must not leak out
                base = base or left or ct
            return base
        if isinstance(e, ast.IfExp):
            if self._taint(e.test, tainted, emit) and emit:
                self._emit(
                    "HS001", e.test,
                    "conditional expression on a traced value forces a host "
                    "sync — use jnp.where")
            a = self._taint(e.body, tainted, emit)
            b = self._taint(e.orelse, tainted, emit)
            return a or b
        if isinstance(e, ast.Lambda):
            params = {p.arg for p in e.args.args + e.args.kwonlyargs}
            sub = set(tainted) | params
            self._taint(e.body, sub, emit)
            return False
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                          ast.DictComp)):
            sub = set(tainted)
            for gen in e.generators:
                it = self._taint(gen.iter, sub, emit)
                self._bind(gen.target, it, sub)
                for cond in gen.ifs:
                    self._taint(cond, sub, emit)
            if isinstance(e, ast.DictComp):
                return self._taint(e.key, sub, emit) | \
                    self._taint(e.value, sub, emit)
            return self._taint(e.elt, sub, emit)
        # generic containers / operators: tainted if any child is
        out = False
        for child in ast.iter_child_nodes(e):
            if isinstance(child, ast.expr):
                out = self._taint(child, tainted, emit) or out
            elif isinstance(child, ast.keyword):
                out = self._taint(child.value, tainted, emit) or out
        return out

    def _taint_call(self, e, tainted, emit) -> bool:
        fd = dotted(e.func)
        arg_taints = [self._taint(a, tainted, emit) for a in e.args]
        kw_taints = [self._taint(k.value, tainted, emit)
                     for k in e.keywords]
        any_arg = any(arg_taints) or any(kw_taints)
        recv = False
        if isinstance(e.func, ast.Attribute):
            recv = self._taint(e.func.value, tainted, emit)
            if e.func.attr in ("item", "tolist") and recv:
                if emit:
                    self._emit(
                        "HS001", e,
                        f"`.{e.func.attr}()` on a traced value is a "
                        f"device->host sync inside a traced function")
                return False
        if fd in COERCERS and any_arg:
            if emit:
                self._emit(
                    "HS001", e,
                    f"`{fd}()` coercion of a traced value forces a host "
                    f"sync (ConcretizationTypeError under jit)")
            return False
        if fd and fd.split(".")[0] in NP_ALIASES and any_arg:
            if emit:
                self._emit(
                    "HS001", e,
                    f"`{fd}(...)` pulls a traced value through NumPy — a "
                    f"device->host transfer inside a traced function; use "
                    f"the jnp equivalent")
            return True
        if fd in ("jax.device_get",) and any_arg:
            if emit:
                self._emit("HS001", e,
                           "`jax.device_get` inside a traced function")
            return False
        if fd in UNTAINTING_CALLS:
            return False
        return any_arg or recv

    def _free_names(self, fn) -> frozenset:
        bound = set(_param_names(fn))
        loads = set()
        for n in ast.walk(fn):
            if isinstance(n, ast.Name):
                if isinstance(n.ctx, (ast.Store, ast.Del)):
                    bound.add(n.id)
                else:
                    loads.add(n.id)
            elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and n is not fn:
                bound.add(n.name)
            elif isinstance(n, (ast.Import, ast.ImportFrom)):
                for a in n.names:
                    bound.add(a.asname or a.name.split(".")[0])
        return frozenset(loads - bound)

    # -- HS001 scope B: hot host functions ----------------------------------

    def _is_hot(self, fn) -> bool:
        qn = _qualname(fn)
        for path_suffix, pattern in self.cfg.hot_functions:
            if self.path.endswith(path_suffix) and fnmatch.fnmatch(
                    qn, pattern):
                return True
        return False

    def _scan_hot(self, fn):
        transfers: dict = {}  # unparsed arg -> [nodes]
        own_nodes = [n for n in ast.walk(fn)
                     if self._owner_fn(n) is fn]
        for node in own_nodes:
            if not isinstance(node, ast.Call):
                continue
            fd = dotted(node.func)
            is_np_transfer = fd and fd.split(".")[0] in NP_ALIASES and \
                _tail(fd) in ("asarray", "array")
            is_item = isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("item", "tolist")
            is_get = fd == "jax.device_get"
            is_coerce = fd in COERCERS and node.args and not isinstance(
                node.args[0], ast.Constant)
            if is_np_transfer or is_item:
                arg = node.func.value if is_item else (
                    node.args[0] if node.args else None)
                if arg is not None and not isinstance(arg, ast.Constant):
                    transfers.setdefault(ast.unparse(arg),
                                         []).append((node, arg))
            if (is_np_transfer or is_item or is_get or is_coerce) and \
                    self._loop_depth(node, fn) > 0:
                label = f"`.{node.func.attr}()`" if is_item else f"`{fd}()`"
                self._emit(
                    "HS001", node,
                    f"{label} inside a loop of hot function "
                    f"`{_qualname(fn)}` — a device->host sync per "
                    f"iteration; hoist to one transfer per batch/round")
        # duplicate-transfer grouping: two same-text transfers count only
        # when (a) both can execute in one pass (no mutually exclusive `if`
        # arms between them) and (b) no name the expression reads is
        # reassigned between the two sites (a rebound `ids` is a new value)
        stores = sorted(
            (n.lineno, n.id) for n in own_nodes
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store))
        for src, sites in transfers.items():
            if len(sites) < 2:
                continue
            sites.sort(key=lambda p: p[0].lineno)
            done = False
            for i in range(1, len(sites)):
                cur, arg = sites[i]
                roots = {nm.id for nm in ast.walk(arg)
                         if isinstance(nm, ast.Name)}
                sig_cur = self._branch_sig(cur, fn)
                for prev, _a in sites[:i]:
                    sig_prev = self._branch_sig(prev, fn)
                    if any(sig_cur.get(key, arm) != arm
                           for key, arm in sig_prev.items()):
                        continue  # mutually exclusive branches
                    if any(prev.lineno < ln < cur.lineno and nm in roots
                           for ln, nm in stores):
                        continue  # rebound between the sites
                    if self._assign_targets(prev) & self._none_guards(
                            cur, fn):
                        continue  # lazy-memo idiom: `if x is None: x = ...`
                    self._emit(
                        "HS001", cur,
                        f"repeated host transfer of `{src}` in hot "
                        f"function `{_qualname(fn)}` ({len(sites)} sites) "
                        f"— transfer once and reuse the host value")
                    done = True
                    break
                if done:
                    break

    # -- EP001: epoch-consistency of tiered reads ---------------------------

    def _check_ep001(self, fn):
        """Serving hot paths must read tiered ingest state through ONE
        ``snapshot()`` taken at batch-formation time. A direct read of a
        mutable ``TieredTable`` field (``_hot``/``_cold``/``_sealing``/...)
        can observe a DIFFERENT epoch than the rest of the batch when a
        background compaction swaps mid-flight — mixed-epoch row ids are
        silently wrong, not crashes. The detector is textual by design: any
        attribute access whose base expression mentions ``tiered`` and
        whose attr is a registered mutable field."""
        banned = set(self.cfg.tiered_mutable_fields)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Attribute) or \
                    self._owner_fn(node) is not fn:
                continue
            if node.attr not in banned:
                continue
            base = ast.unparse(node.value)
            if "tiered" not in base:
                continue
            self._emit(
                "EP001", node,
                f"hot function `{_qualname(fn)}` reads mutable tiered "
                f"state `{base}.{node.attr}` directly — a background "
                f"compaction can swap the epoch mid-batch and mix row-id "
                f"spaces; take one `tiered.snapshot()` at batch formation "
                f"and read `(epoch, cold, hot_views)` from it")

    # -- EP002: freshness of semantic-cache reads ----------------------------

    def _check_ep002(self, fn):
        """Serving hot paths must not read semantic-cache entry payloads
        (``ids``/``scores``/``centroids``) without a freshness check: a raw
        entry read can serve a result computed under a PREVIOUS epoch —
        resurrecting pre-compaction row ids — or one that predates a
        hot-tier insert. The sanctioned read is ``SemanticCache.lookup()``
        (it enforces the ``(epoch, n_rows)`` token internally); a function
        that compares an entry's ``token``/``epoch`` explicitly also
        qualifies. Textual like EP001: attribute reads whose base mentions
        ``cache`` or ``entry``."""
        if self._has_freshness_check(fn):
            return
        banned = set(self.cfg.cache_entry_fields)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Attribute) or \
                    self._owner_fn(node) is not fn:
                continue
            if node.attr not in banned:
                continue
            base = ast.unparse(node.value).lower()
            if "cache" not in base and "entry" not in base:
                continue
            self._emit(
                "EP002", node,
                f"hot function `{_qualname(fn)}` reads cache-entry payload "
                f"`{base}.{node.attr}` without a freshness check — a stale "
                f"entry can resurrect pre-compaction results; go through "
                f"`SemanticCache.lookup()` (token-checked) or compare the "
                f"entry's token against the current `(epoch, n_rows)` first")

    def _has_freshness_check(self, fn) -> bool:
        """True when fn reads the cache through lookup() or explicitly
        compares a token/epoch attribute (either side of any comparison)."""
        for node in ast.walk(fn):
            if self._owner_fn(node) is not fn:
                continue
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "lookup":
                return True
            if isinstance(node, ast.Compare):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Attribute) and \
                            sub.attr in ("token", "epoch"):
                        return True
        return False

    @staticmethod
    def _assign_targets(node) -> set:
        """Names the nearest enclosing Assign binds (node on its RHS)."""
        prev, cur = node, getattr(node, "_bl_parent", None)
        while cur is not None and not isinstance(cur, ast.stmt):
            prev, cur = cur, getattr(cur, "_bl_parent", None)
        if isinstance(cur, ast.Assign) and prev is cur.value:
            return {n.id for t in cur.targets for n in ast.walk(t)
                    if isinstance(n, ast.Name)}
        return set()

    def _none_guards(self, node, fn) -> set:
        """Names N where node sits in the body of `if N is None:`."""
        guards: set = set()
        prev, cur = node, getattr(node, "_bl_parent", None)
        while cur is not None and cur is not fn:
            if isinstance(cur, ast.If) and any(prev is s for s in cur.body):
                t = cur.test
                if isinstance(t, ast.Compare) and len(t.ops) == 1 and \
                        isinstance(t.ops[0], ast.Is) and \
                        isinstance(t.left, ast.Name) and isinstance(
                            t.comparators[0], ast.Constant) and \
                        t.comparators[0].value is None:
                    guards.add(t.left.id)
            prev, cur = cur, getattr(cur, "_bl_parent", None)
        return guards

    def _branch_sig(self, node, fn) -> dict:
        """{id(if-node): arm} for every `if` between node and fn — two
        nodes with the same if on different arms never co-execute."""
        sig = {}
        prev, cur = node, getattr(node, "_bl_parent", None)
        while cur is not None and cur is not fn:
            if isinstance(cur, ast.If) and prev is not cur.test:
                in_body = any(prev is s for s in cur.body)
                sig[id(cur)] = "body" if in_body else "orelse"
            prev, cur = cur, getattr(cur, "_bl_parent", None)
        return sig

    def _owner_fn(self, node):
        cur = getattr(node, "_bl_parent", None)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return cur
            cur = getattr(cur, "_bl_parent", None)
        return None

    def _loop_depth(self, node, fn) -> int:
        depth = 0
        prev = node
        cur = getattr(node, "_bl_parent", None)
        while cur is not None and cur is not fn:
            if isinstance(cur, (ast.For, ast.While)):
                depth += 1
            elif isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                break
            prev, cur = cur, getattr(cur, "_bl_parent", None)
        # a While's test runs every iteration too
        if isinstance(cur, ast.While) and prev is cur.test:
            depth += 1
        return depth

    # -- RC001: recompile hazards -------------------------------------------

    def _check_rc001(self):
        # static_argnames naming a parameter that does not exist
        for fn in self._all_defs():
            static = getattr(fn, "_bl_static", set())
            if not static:
                continue
            params = set(_param_names(fn))
            for s in sorted(static - params):
                self._emit(
                    "RC001", fn,
                    f"static_argnames entry '{s}' does not match any "
                    f"parameter of `{fn.name}` — jit will raise (or worse, "
                    f"silently trace the argument)")
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            entry = self._jit_entry_for(node)
            if entry is None:
                continue
            name, static = entry
            for kw in node.keywords:
                if kw.arg not in static:
                    continue
                v = kw.value
                if isinstance(v, (ast.List, ast.Set, ast.Dict)):
                    self._emit(
                        "RC001", v,
                        f"unhashable {type(v).__name__.lower()} literal "
                        f"passed to static arg '{kw.arg}' of jitted "
                        f"`{name}` — static args must be hashable")
                elif isinstance(v, ast.Constant) and isinstance(
                        v.value, int) and not isinstance(v.value, bool):
                    if not self.cfg.allowed_shape_literal(v.value):
                        self._emit(
                            "RC001", v,
                            f"shape-bearing literal {v.value} passed to "
                            f"static arg '{kw.arg}' of jitted `{name}` is "
                            f"not a registered grid value or pow2 bucket — "
                            f"every novel value is a recompile; draw it "
                            f"from SHAPE_GRIDS / next_bucket "
                            f"(serve/batch.py)")

    def _jit_entry_for(self, call):
        """(name, static set) if the call targets a known jitted entry."""
        fd = dotted(call.func)
        if not fd:
            return None
        tail = _tail(fd)
        if isinstance(call.func, ast.Name):
            fn = self._resolve(tail, call)
            if fn is not None and getattr(fn, "_bl_traced", False):
                static = getattr(fn, "_bl_static", set())
                return (tail, static) if static else None
        if tail in self._cross_module_jits():
            return (tail, self._cross_module_jits()[tail])
        return None

    _XMOD_CACHE: dict = {}

    @classmethod
    def register_jit_entries(cls, entries: dict):
        """Feed jitted-entry signatures collected from other modules (the
        runner collects the whole scan set first, then lints)."""
        cls._XMOD_CACHE.update(entries)

    @classmethod
    def reset_jit_entries(cls):
        cls._XMOD_CACHE.clear()

    def _cross_module_jits(self) -> dict:
        return self._XMOD_CACHE

    def collect_jit_entries(self) -> dict:
        """name -> static names, for decorated jits in this module."""
        self._collect()
        self._mark_traced()
        return dict(self._jit_entries)

    # -- SM001: shard_map closure capture -----------------------------------

    def _check_sm001(self, call, body):
        free = self._free_names(body)
        enclosing_bound: set = set()
        cur = _scope_of(body)
        while cur is not None and not isinstance(cur, ast.Module):
            enclosing_bound.update(_param_names(cur))
            for n in ast.walk(cur):
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store) \
                        and self._owner_fn(n) is cur:
                    enclosing_bound.add(n.id)
            cur = _scope_of(cur)
        candidates = (free & enclosing_bound) - self._module_names
        # host scalars (shape arithmetic, config fields, max/min/len) are
        # broadcast-free closures — only array-like captures replicate
        candidates = {c for c in candidates
                      if not self._scalar_like(c, body)}
        if not candidates:
            return
        flagged = set()
        for n in ast.walk(body):
            if isinstance(n, ast.Subscript) and isinstance(
                    n.value, ast.Name) and n.value.id in candidates:
                flagged.add((n.value.id, n))
            elif isinstance(n, ast.BinOp) and isinstance(n.op, ast.MatMult):
                for side in (n.left, n.right):
                    while isinstance(side, ast.Attribute):
                        side = side.value  # unwrap table.T / x.real / ...
                    if isinstance(side, ast.Name) and side.id in candidates:
                        flagged.add((side.id, n))
            elif isinstance(n, ast.Call):
                tail = _tail(dotted(n.func))
                positions = ARRAY_CONSUMERS.get(tail)
                if positions is None:
                    continue
                for i, a in enumerate(n.args):
                    if i in positions and isinstance(a, ast.Name) and \
                            a.id in candidates:
                        flagged.add((a.id, n))
        for name, node in sorted(flagged, key=lambda x: (x[0],
                                                         x[1].lineno)):
            self._emit(
                "SM001", node,
                f"shard_map body `{body.name}` closes over `{name}` and "
                f"uses it as an array — closed-over arrays replicate to "
                f"every device; pass it through in_specs with a sharded "
                f"PartitionSpec instead")

    # -- SM001 scalar inference ---------------------------------------------

    def _scalar_like(self, name: str, body) -> bool:
        """True when a name free in a shard_map body is provably a host
        scalar in the enclosing scope chain (shape arithmetic, `*Config`
        attribute reads, max/min/len results)."""
        bindings, config_params = self._enclosing_bindings(body)
        return self._expr_scalar(ast.Name(id=name, ctx=ast.Load()),
                                 bindings, config_params, set())

    def _enclosing_bindings(self, body):
        bindings: dict = {}  # name -> [value exprs | True (shape dim)]
        config_params: set = set()
        cur = _scope_of(body)
        while cur is not None and not isinstance(cur, ast.Module):
            a = cur.args
            for arg in (*a.posonlyargs, *a.args, *a.kwonlyargs):
                ann = arg.annotation
                if isinstance(ann, ast.Name) and ann.id.endswith("Config"):
                    config_params.add(arg.arg)
            for n in ast.walk(cur):
                if self._owner_fn(n) is not cur:
                    continue
                if isinstance(n, ast.Assign):
                    for tgt in n.targets:
                        self._record_binding(tgt, n.value, bindings)
                elif isinstance(n, (ast.AugAssign, ast.AnnAssign)) and \
                        isinstance(n.target, ast.Name) and \
                        n.value is not None:
                    bindings.setdefault(n.target.id, []).append(n.value)
            cur = _scope_of(cur)
        return bindings, config_params

    def _record_binding(self, tgt, value, bindings):
        if isinstance(tgt, ast.Name):
            bindings.setdefault(tgt.id, []).append(value)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            if isinstance(value, (ast.Tuple, ast.List)) and \
                    len(value.elts) == len(tgt.elts):
                for t, v in zip(tgt.elts, value.elts):
                    self._record_binding(t, v, bindings)
            elif isinstance(value, ast.Attribute) and \
                    value.attr == "shape":
                for t in tgt.elts:  # b, s, d = x.shape — each dim an int
                    if isinstance(t, ast.Name):
                        bindings.setdefault(t.id, []).append(True)

    def _expr_scalar(self, e, bindings, config_params, seen) -> bool:
        if e is True:
            return True
        if isinstance(e, ast.Constant):
            return True
        if isinstance(e, ast.Name):
            if e.id in seen:
                return True  # cycle (x *= ...): other bindings decide
            bound = bindings.get(e.id)
            if not bound:
                return False
            seen = seen | {e.id}
            return all(self._expr_scalar(b, bindings, config_params, seen)
                       for b in bound)
        if isinstance(e, ast.BinOp):
            return not isinstance(e.op, ast.MatMult) and \
                self._expr_scalar(e.left, bindings, config_params, seen) \
                and self._expr_scalar(e.right, bindings, config_params,
                                      seen)
        if isinstance(e, ast.UnaryOp):
            return self._expr_scalar(e.operand, bindings, config_params,
                                     seen)
        if isinstance(e, ast.IfExp):
            return self._expr_scalar(e.body, bindings, config_params,
                                     seen) and \
                self._expr_scalar(e.orelse, bindings, config_params, seen)
        if isinstance(e, ast.Compare):
            return True
        if isinstance(e, ast.Call):
            fd = dotted(e.func)
            return (isinstance(e.func, ast.Name)
                    and e.func.id in SCALAR_CALLS) or \
                (fd or "").startswith("math.") or _tail(fd) == "item"
        if isinstance(e, ast.Subscript):
            return isinstance(e.value, ast.Attribute) and \
                e.value.attr == "shape"
        if isinstance(e, ast.Attribute):
            if e.attr in STATIC_ATTRS:
                return True  # static metadata reads (shape/ndim/size/...)
            return isinstance(e.value, ast.Name) and \
                e.value.id in config_params
        return False

    # -- PL001 (AST level): literal Pallas shapes ---------------------------

    def _check_pl001(self):
        budget = self.cfg.budget()
        per_fn: dict = {}
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            tail = _tail(dotted(node.func))
            size = 0
            if tail == "BlockSpec" and node.args and isinstance(
                    node.args[0], ast.Tuple):
                size = self._literal_bytes(node.args[0], 4)
            elif tail == "VMEM" and node.args and isinstance(
                    node.args[0], ast.Tuple):
                itemsize = 4
                if len(node.args) > 1:
                    itemsize = DTYPE_BYTES.get(
                        _tail(dotted(node.args[1])), 4)
                size = self._literal_bytes(node.args[0], itemsize)
            if size:
                owner = self._owner_fn(node) or self.tree
                rec = per_fn.setdefault(id(owner), [owner, 0, node])
                rec[1] += size
        for owner, total, first in per_fn.values():
            if total > budget:
                name = getattr(owner, "name", "<module>")
                self._emit(
                    "PL001", first,
                    f"literal Pallas block shapes in `{name}` sum to "
                    f"{total / 2**20:.1f} MiB of VMEM — over the "
                    f"{budget / 2**20:.0f} MiB budget; shrink the tile or "
                    f"grid it (kernels/shapes.py holds the supported "
                    f"envelope)")

    @staticmethod
    def _literal_bytes(tup, itemsize) -> int:
        total = itemsize
        for e in tup.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                total *= e.value
            else:
                return 0  # symbolic dim: the trace-level estimator owns it
        return total


def lint_source(path: str, source: str, cfg: LintConfig | None = None,
                relpath: str | None = None) -> list:
    """Lint one module's source. Returns raw findings (suppressions are
    applied by the runner)."""
    return ModuleLint(path, source, cfg or LintConfig(), relpath).run()
