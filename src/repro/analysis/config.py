"""boomlint configuration: rule knobs, hot-path registry, grid registry."""
from __future__ import annotations

import dataclasses

# Host functions on the serving hot path (scope B of HS001): host-side
# coercions inside their loops are per-iteration syncs, and repeated
# transfers of the same value are duplicate round-trips. Offline code
# (fit/build/bench) is deliberately NOT here — np.asarray is free there.
DEFAULT_HOT_FUNCTIONS = (
    ("serve/batch.py", "BatchedHybridExecutor.*"),
    ("serve/batch.py", "ServingEngine.*"),
    ("serve/queue.py", "AsyncServingEngine.*"),
    ("serve/queue.py", "BatchFormer.*"),
    ("core/executor.py", "HybridExecutor.execute"),
    ("core/executor.py", "HybridExecutor._subquery"),
    ("core/boomhq.py", "BoomHQ.execute"),
    ("core/boomhq.py", "BoomHQ.execute_batch"),
    ("core/boomhq.py", "BoomHQ.optimize"),
    ("core/boomhq.py", "BoomHQ.optimize_batch"),
    ("core/boomhq.py", "BoomHQ._merge_hot"),
    ("core/boomhq.py", "BoomHQ._execute_batch_sharded"),
)

# EP001: TieredTable fields that hold the MUTABLE ingest state. Serving hot
# paths must never read these directly — every epoch-consistent view comes
# from ONE tiered.snapshot() call taken at batch-formation time.
DEFAULT_TIERED_MUTABLE_FIELDS = (
    "_hot", "_cold", "_sealing", "_snap", "_epoch", "_compacting",
)

# EP002: payload fields of a semantic-cache entry (serve/semcache.py
# CacheEntry). Serving hot paths must never read these directly — the
# sanctioned read is SemanticCache.lookup(), which enforces the
# (epoch, n_rows) freshness token; a raw entry read can resurrect
# pre-compaction results. `token` itself is NOT banned: comparing it IS
# the freshness check.
DEFAULT_CACHE_ENTRY_FIELDS = (
    "ids", "scores", "centroids",
)

# Fallback shape vocabulary used only when the live registries cannot be
# imported (e.g. linting a checkout without jax). registered_shape_values()
# prefers the single-source-of-truth exports.
_FALLBACK_GRID_VALUES = frozenset(
    {1, 2, 4}  # CLAUSE_GRID
    | {1, 2, 4, 8, 16, 32}  # NPROBE_GRID
    | {2048, 8192, 32768, 131072}  # MAX_SCAN_GRID
    | {1, 2, 4, 8}  # KMULT_GRID
    | {16, 64, 256, 1024}  # floors + kernel tiles
)


def registered_shape_values() -> frozenset:
    """Every non-pow2-exempt static shape value the serving stack is allowed
    to use at a jitted call site: the registered grids (serve/batch.py
    ``SHAPE_GRIDS``), the padding floors, and the kernel tile constants
    (kernels/shapes.py)."""
    try:
        from repro.kernels.shapes import GATHER_BLOCK_S, SCAN_BLOCK_ROWS
        from repro.serve.batch import (
            CANDIDATE_PAD_FLOOR, K_BUCKET_FLOOR, SHAPE_GRIDS,
        )
    except Exception:  # pragma: no cover - jax-less checkout
        return _FALLBACK_GRID_VALUES
    vals = {K_BUCKET_FLOOR, CANDIDATE_PAD_FLOOR, SCAN_BLOCK_ROWS,
            GATHER_BLOCK_S}
    for grid in SHAPE_GRIDS.values():
        vals.update(int(v) for v in grid)
    return frozenset(vals)


def is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


@dataclasses.dataclass
class LintConfig:
    """Knobs for one analyzer run (tests construct these; the CLI maps
    flags onto them)."""

    # AST-level PL001: literal BlockSpec/VMEM shapes per function must sum
    # under this. Trace-level PL001 checks the kernels/shapes.py envelope
    # against the same budget.
    vmem_budget: int = 0  # 0 -> use kernels.shapes.DEFAULT_VMEM_BUDGET
    # CM001: all-gathers allowed per serving kernel (ids + scores of the
    # O(shards·k) merge).
    max_all_gathers: int = 2
    # hot host functions for HS001 scope B: (path suffix, qualname glob)
    hot_functions: tuple = DEFAULT_HOT_FUNCTIONS
    # EP001: mutable TieredTable fields banned from hot-path reads
    tiered_mutable_fields: tuple = DEFAULT_TIERED_MUTABLE_FIELDS
    # EP002: cache-entry payload fields banned from hot-path reads without
    # a freshness (token/epoch) check
    cache_entry_fields: tuple = DEFAULT_CACHE_ENTRY_FIELDS
    # run the level-2 trace checks (CLI --no-trace disables)
    trace: bool = True
    # report suppressed findings too (debugging)
    ignore_suppressions: bool = False
    # explicit grid override for tests; None -> registered_shape_values()
    shape_values: frozenset | None = None

    def budget(self) -> int:
        if self.vmem_budget:
            return self.vmem_budget
        try:
            from repro.kernels.shapes import DEFAULT_VMEM_BUDGET
        except Exception:  # pragma: no cover
            return 12 * 2**20
        return DEFAULT_VMEM_BUDGET

    def grid_values(self) -> frozenset:
        if self.shape_values is not None:
            return self.shape_values
        return registered_shape_values()

    def allowed_shape_literal(self, v: int) -> bool:
        return is_pow2(v) or v in self.grid_values()
