"""Runtime recompile counting via ``jax_log_compiles``.

JAX logs per-compilation records when ``jax_log_compiles`` is on.
``CompileCounter`` attaches a counting handler to the ``jax`` ancestor
logger for the duration of a ``with`` block — the serving-path regression
tests use it to pin "a warmed engine never recompiles":

    with CompileCounter() as cc:
        engine.execute_batch(queries)
    assert cc.count == 0

One compilation can emit BOTH marker styles ("Finished XLA compilation of
<name>" from the dispatch path and "Compiling <name> with global shapes"
from pxla), so the two are counted separately and ``count`` is their max.
Counting is support-probed (``supported()``): if a jax version moves the
log messages, dependent tests skip instead of passing vacuously.
"""
from __future__ import annotations

import logging

_FINISHED = "Finished XLA compilation"
_COMPILING = "Compiling "


class _CountingHandler(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.finished = 0
        self.compiling = 0
        self.names: list = []

    def emit(self, record):
        msg = record.getMessage()
        if _FINISHED in msg:
            self.finished += 1
            self.names.append(msg.split(" in ")[0])
        elif msg.startswith(_COMPILING):
            self.compiling += 1
            self.names.append(msg.split(" with ")[0])


class CompileCounter:
    """Count XLA compilations inside a ``with`` block."""

    def __init__(self):
        self._handler = _CountingHandler()
        self._saved = None

    def __enter__(self):
        import jax

        self._ctx = jax.log_compiles(True)
        self._ctx.__enter__()
        # the ancestor logger sees every jax._src.* record via propagation;
        # propagate=False keeps the WARNING-level compile log spam off the
        # root handlers while counting
        logger = logging.getLogger("jax")
        self._saved = (logger, logger.propagate)
        logger.addHandler(self._handler)
        logger.propagate = False
        return self

    def __exit__(self, *exc):
        logger, propagate = self._saved
        logger.removeHandler(self._handler)
        logger.propagate = propagate
        self._saved = None
        self._ctx.__exit__(*exc)
        return False

    @property
    def count(self) -> int:
        return max(self._handler.finished, self._handler.compiling)

    @property
    def names(self) -> list:
        return list(self._handler.names)


def supported() -> bool:
    """Probe: does this jax emit countable compile logs?

    Compiles a trivial jitted function with a fresh shape under a counter
    and checks the count moved. Tests skip (not pass) when this is False.
    """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def probe(x):
        return x * 2 + 1

    with CompileCounter() as cc:
        probe(jnp.ones((3, 7), jnp.float32)).block_until_ready()
    return cc.count >= 1
