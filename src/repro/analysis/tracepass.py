"""Level-2 checks: trace the real serving kernels and inspect jaxpr/HLO.

AST rules see what the source *says*; this pass checks what the compiler
actually *builds*. A tiny synthetic table (256 rows) is pushed through the
serving kernels — the candidate-local gather+score path, the batched
filter-first and IVF probes, and both sharded top-k merges — and each
jaxpr/HLO is walked for:

* **CM001** — host callbacks (``pure_callback``/``io_callback``/
  ``debug_callback``: a device->host round-trip per call), collectives
  beyond the O(shards·k) merge contract (at most ``max_all_gathers``
  all-gathers per kernel, nothing else), and host-transfer instructions in
  the compiled HLO (``launch.hlo_analysis.host_transfers``).
* **PL001** — the Pallas VMEM envelope: the tile estimators in
  ``kernels/shapes.py`` (the same constants the kernels launch with),
  evaluated at the declared support envelope, must fit the budget.

Shapes here are deliberately minuscule — the checks are structural
(primitive counts), not performance measurements.
"""
from __future__ import annotations

from repro.analysis.config import LintConfig
from repro.analysis.findings import Finding

CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback",
                  "callback"}
COLLECTIVE_PRIMS = {"all_gather", "all_gather_invariant", "psum", "pmax",
                    "pmin", "all_to_all", "ppermute", "reduce_scatter",
                    "psum_scatter", "pgather"}


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            yield from _iter_sub(v)


def _iter_sub(v):
    if hasattr(v, "eqns"):  # Jaxpr
        yield from _iter_eqns(v)
    elif hasattr(v, "jaxpr") and hasattr(getattr(v, "jaxpr"), "eqns"):
        yield from _iter_eqns(v.jaxpr)  # ClosedJaxpr
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from _iter_sub(item)


def prim_counts(jaxpr) -> dict:
    counts: dict = {}
    for eqn in _iter_eqns(jaxpr):
        name = eqn.primitive.name
        counts[name] = counts.get(name, 0) + 1
    return counts


def _check_jaxpr(findings, label, path, counts, cfg: LintConfig,
                 *, allow_gathers: int | None = None):
    gathers = allow_gathers if allow_gathers is not None \
        else cfg.max_all_gathers
    for prim in sorted(set(counts) & CALLBACK_PRIMS):
        findings.append(Finding(
            "CM001", path, 1,
            f"{label}: jaxpr contains host callback `{prim}` "
            f"(×{counts[prim]}) — a device->host round-trip inside the "
            f"kernel", context=f"trace:{label}:callback:{prim}"))
    n_ag = counts.get("all_gather", 0) + counts.get("all_gather_invariant", 0)
    if n_ag > gathers:
        findings.append(Finding(
            "CM001", path, 1,
            f"{label}: {n_ag} all-gathers in the traced kernel — the merge "
            f"contract is at most {gathers} (scores + ids, O(shards·k))",
            context=f"trace:{label}:all_gather"))
    others = sorted((set(counts) & COLLECTIVE_PRIMS)
                    - {"all_gather", "all_gather_invariant"})
    for prim in others:
        findings.append(Finding(
            "CM001", path, 1,
            f"{label}: unexpected collective `{prim}` (×{counts[prim]}) — "
            f"serving kernels communicate only through the O(shards·k) "
            f"candidate merge", context=f"trace:{label}:{prim}"))


def check_vmem_envelope(cfg: LintConfig) -> list:
    """PL001 at the declared kernel envelope (kernels/shapes.py)."""
    from repro.kernels import shapes

    budget = cfg.budget()
    findings: list = []
    envelope = [
        ("masked_topk", "src/repro/kernels/masked_topk.py",
         shapes.scan_tile_bytes(shapes.MAX_COL_DIM, shapes.MAX_SCALARS)),
        ("int8_scan", "src/repro/kernels/int8_scan.py",
         shapes.int8_scan_tile_bytes(shapes.MAX_COL_DIM,
                                     shapes.MAX_SCALARS)),
        ("gather_score", "src/repro/kernels/gather_score.py",
         shapes.gather_tile_bytes(
             (shapes.MAX_COL_DIM,) * shapes.MAX_VEC_COLS,
             shapes.MAX_SCALARS, 4)),
        ("int8_gather_score", "src/repro/kernels/gather_score.py",
         shapes.int8_gather_tile_bytes(
             (shapes.MAX_COL_DIM,) * shapes.MAX_VEC_COLS,
             shapes.MAX_SCALARS, 4)),
        ("beam_search", "src/repro/kernels/beam_search.py",
         shapes.beam_tile_bytes(shapes.MAX_COL_DIM, shapes.MAX_SCALARS, 4)),
    ]
    for label, path, est in envelope:
        if est > budget:
            findings.append(Finding(
                "PL001", path, 1,
                f"{label}: VMEM estimate at the declared envelope is "
                f"{est / 2**20:.1f} MiB > budget {budget / 2**20:.0f} MiB "
                f"— shrink the tile constants in kernels/shapes.py or "
                f"raise the budget deliberately",
                context=f"trace:vmem:{label}"))
    return findings


def _fixture():
    import jax.numpy as jnp
    import numpy as np

    from repro.vectordb.predicates import Predicates, stack

    rng = np.random.default_rng(0)
    n, d, m, b = 256, 16, 4, 4
    vectors = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    scalars = jnp.asarray(rng.uniform(size=(n, m)), jnp.float32)
    q_b = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    pred_b = stack([Predicates.from_conditions(m, {0: (0.2, 0.9)})
                    for _ in range(b)])
    w_b = jnp.ones((b, 1), jnp.float32)
    return vectors, scalars, q_b, pred_b, w_b


def run_trace_checks(cfg: LintConfig) -> list:
    findings = check_vmem_envelope(cfg)
    try:
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh
    except Exception:  # pragma: no cover - jax-less checkout
        return findings

    from repro.kernels.gather_score import gather_score_topk
    from repro.launch import hlo_analysis
    from repro.vectordb import flat, ivf
    from repro.vectordb.distributed import (
        build_sharded_ivf, sharded_batch_topk, sharded_ivf_topk,
    )

    vectors, scalars, q_b, pred_b, w_b = _fixture()
    k = 8

    # gather_score: reference path (the off-TPU executor scoring path) and
    # the Pallas kernel body (interpret mode traces the same kernel jaxpr)
    cand = jnp.tile(jnp.arange(64, dtype=jnp.int32)[None], (q_b.shape[0], 1))
    for label, use_kernel in (("gather_score_ref", False),
                              ("gather_score_kernel", True)):
        jaxpr = jax.make_jaxpr(
            lambda c, v, s, q, w, p: gather_score_topk(
                c, (v,), (q,), w, s, p, k=k, use_kernel=use_kernel,
                interpret=True))(cand, vectors, scalars, q_b, w_b, pred_b)
        _check_jaxpr(findings, label, "src/repro/kernels/gather_score.py",
                     prim_counts(jaxpr.jaxpr), cfg, allow_gathers=0)

    # batched filter-first (candidate-local, no dense matrix)
    jaxpr = jax.make_jaxpr(
        lambda v, s, p, q, w: flat.filter_first_local_batch(
            (v,), s, p, (q,), w, k=k, max_candidates=64, n_vec=1))(
        vectors, scalars, pred_b, q_b, w_b)
    _check_jaxpr(findings, "filter_first_local_batch",
                 "src/repro/vectordb/flat.py", prim_counts(jaxpr.jaxpr),
                 cfg, allow_gathers=0)

    # plan-driven IVF probing (single-index batched path)
    index = ivf.build(vectors, 8, seed=0)
    jaxpr = jax.make_jaxpr(
        lambda v, s, p, q: ivf.search_local_batch(
            index, v, s, p, q, nprobe=2, max_scan=64, k=k))(
        vectors, scalars, pred_b, q_b)
    _check_jaxpr(findings, "search_local_batch",
                 "src/repro/vectordb/ivf.py", prim_counts(jaxpr.jaxpr),
                 cfg, allow_gathers=0)

    # sharded exact merge under shard_map: the all-gather budget is the
    # whole point — 2 gathers (scores + ids) of O(shards·k), nothing else
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    fn = sharded_batch_topk(mesh, ("data",), k=k)
    w_scores = jnp.einsum("nd,qd->qn", vectors, q_b)
    jaxpr = jax.make_jaxpr(fn)(w_scores, scalars, pred_b)
    counts = prim_counts(jaxpr.jaxpr)
    _check_jaxpr(findings, "sharded_batch_topk",
                 "src/repro/vectordb/distributed.py", counts, cfg)
    if counts.get("all_gather", 0) == 0:  # the merge must actually exist
        findings.append(Finding(
            "CM001", "src/repro/vectordb/distributed.py", 1,
            "sharded_batch_topk: expected the O(shards·k) candidate merge "
            "(2 all-gathers) in the shard_map body, found none — the merge "
            "contract changed", context="trace:sharded_batch_topk:missing"))

    # compiled HLO of the same kernel: no device->host transfers allowed
    hlo = jax.jit(fn).lower(w_scores, scalars, pred_b).compile().as_text()
    report = hlo_analysis.comm_report(hlo,
                                      max_all_gathers=cfg.max_all_gathers)
    if report["host"]["count"] > 0:
        findings.append(Finding(
            "CM001", "src/repro/vectordb/distributed.py", 1,
            f"sharded_batch_topk: compiled HLO contains "
            f"{report['host']['count']} device<->host transfer(s): "
            f"{report['host']['ops']}",
            context="trace:sharded_batch_topk:host_transfer"))

    # plan-driven per-shard IVF probing, logical-shard path (vmap): must be
    # collective- and callback-free
    sivf = build_sharded_ivf(vectors, 2, n_clusters=8)
    sfn = sharded_ivf_topk(2, None, subs=((0, 8, 16, 2, 64),), k=k,
                           n_cols=1, metric="dot", pad_total=64)
    jaxpr = jax.make_jaxpr(
        lambda c, r, o, v, s, p, q, w: sfn((c,), (r,), (o,), (v,), s, p,
                                           (q,), w))(
        sivf.centroids, sivf.sorted_rows, sivf.offsets, vectors, scalars,
        pred_b, q_b, w_b)
    _check_jaxpr(findings, "sharded_ivf_topk",
                 "src/repro/vectordb/distributed.py",
                 prim_counts(jaxpr.jaxpr), cfg, allow_gathers=0)
    return findings
