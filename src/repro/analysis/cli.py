"""boomlint CLI.

    PYTHONPATH=src python -m repro.analysis.cli src/repro

Exit code 0 iff no unsuppressed, unbaselined findings. ``--json`` emits
machine-readable findings; ``--write-baseline`` snapshots current findings
so pre-existing debt can be ratcheted down without blocking CI.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.config import LintConfig
from repro.analysis.findings import to_json
from repro.analysis.runner import run_paths
from repro.analysis.suppressions import Baseline


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="boomlint",
        description="trace-safety & recompile-hazard lint for the serving "
                    "stack (AST + jaxpr/HLO)")
    p.add_argument("paths", nargs="+", help="files or directories to scan")
    p.add_argument("--json", action="store_true",
                   help="emit findings as JSON on stdout")
    p.add_argument("--baseline", default=None,
                   help="baseline file of accepted findings (JSON)")
    p.add_argument("--write-baseline", default=None, metavar="PATH",
                   help="write current active findings to PATH and exit 0")
    p.add_argument("--no-trace", action="store_true",
                   help="skip level-2 jaxpr/HLO checks (AST only; fast)")
    p.add_argument("--vmem-budget", type=int, default=0, metavar="BYTES",
                   help="per-kernel VMEM budget for PL001 "
                        "(default: kernels.shapes.DEFAULT_VMEM_BUDGET)")
    p.add_argument("--max-all-gathers", type=int, default=2,
                   help="CM001 all-gather budget per kernel (default 2)")
    p.add_argument("--ignore-suppressions", action="store_true",
                   help="report suppressed findings too (audit mode)")
    p.add_argument("--show-suppressed", action="store_true",
                   help="list suppressed findings after the active ones")
    return p


def main(argv: list | None = None) -> int:
    args = build_parser().parse_args(argv)
    cfg = LintConfig(
        vmem_budget=args.vmem_budget,
        max_all_gathers=args.max_all_gathers,
        trace=not args.no_trace,
        ignore_suppressions=args.ignore_suppressions,
    )
    baseline = None
    if args.baseline and not args.write_baseline:
        baseline = Baseline.load(args.baseline)

    result = run_paths(args.paths, cfg, baseline=baseline)
    active = result["active"]

    if args.write_baseline:
        Baseline.from_findings(active).save(args.write_baseline)
        print(f"boomlint: wrote {len(active)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    if args.json:
        print(to_json(active))
    else:
        for f in active:
            print(f.render())
        if args.show_suppressed and result["suppressed"]:
            print("# suppressed:")
            for f in result["suppressed"]:
                print(f"#   {f.render()}")
        tail = []
        if result["suppressed"]:
            tail.append(f"{len(result['suppressed'])} suppressed")
        if result["baselined"]:
            tail.append(f"{result['baselined']} baselined")
        status = f"boomlint: {len(active)} finding(s)"
        if tail:
            status += " (" + ", ".join(tail) + ")"
        print(status, file=sys.stderr)
    return 1 if active else 0


if __name__ == "__main__":
    raise SystemExit(main())
