"""boomlint: trace-safety & recompile-hazard static analysis.

Two levels, one finding stream:

* **Level 1 (AST)** — :mod:`repro.analysis.astpass` walks the source and
  flags host-sync hazards in traced/hot functions (HS001), shape-bearing
  literals at jitted entry points that are off the registered grids
  (RC001), ``shard_map`` bodies closing over full-table arrays (SM001),
  and literal Pallas block shapes that blow the VMEM budget (PL001).
* **Level 2 (jaxpr/HLO)** — :mod:`repro.analysis.tracepass` traces the
  real serving kernels and checks the jaxpr/HLO for host callbacks,
  collectives beyond the O(shards·k) merge (CM001), and the per-kernel
  VMEM envelope from :mod:`repro.kernels.shapes` (PL001).

Findings support inline suppression (``# boomlint: ignore[HS001] reason``)
and a checked-in baseline; the CLI (``python -m repro.analysis.cli``)
gates CI on zero unsuppressed findings. Rule catalog: ``docs/analysis.md``.
"""
from repro.analysis.config import LintConfig
from repro.analysis.findings import Finding
from repro.analysis.runner import run_paths

__all__ = ["Finding", "LintConfig", "run_paths"]
