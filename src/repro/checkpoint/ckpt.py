"""Fault-tolerant sharded checkpointing with elastic resharding.

Layout of a checkpoint directory::

    <root>/step_<N>/
        manifest.msgpack      # treedef paths, shapes, dtypes, crc32 per leaf,
                              # mesh shape/axes + partition specs, data cursor
        shard_p<proc>.npz     # leaves owned by process <proc> (single-host: p0)
        COMMIT                # written last (atomic rename) — validity marker

Design points for 1000+ node deployments (documented + exercised in tests):
  * atomic commit: writers stage into ``.tmp-step_<N>`` and ``os.replace`` it
    into place after fsync; readers ignore dirs without COMMIT so a
    preempted/half-written checkpoint is never restored.
  * crc32 per leaf: bit-rot / truncation is detected at restore.
  * elastic restore: arrays are saved unsharded (per-process shards are
    concatenated at save on multi-host); at restore we ``jax.device_put`` to
    whatever mesh/sharding the *new* job passes in — scale-up, scale-down and
    axis-reshape all work without a conversion step.
  * the data-pipeline cursor + rng state ride in the manifest so a restarted
    job reproduces the exact batch stream.
"""
from __future__ import annotations

import os
import re
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.common import pytree

PyTree = Any


def _leaf_key(i: int, path: str) -> str:
    return f"{i:05d}__{path.replace('/', '.')}"


def save(root: str, step: int, tree: PyTree, *, meta: Optional[dict] = None) -> str:
    """Checkpoint ``tree`` (any pytree of arrays) at ``step``."""
    meta = dict(meta or {})
    final = os.path.join(root, f"step_{step:08d}")
    tmp = os.path.join(root, f".tmp-step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)

    flat = pytree.tree_paths(tree)
    arrays = {}
    manifest_leaves = []
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical == "bfloat16":  # npz cannot round-trip ml_dtypes — view
            arr = arr.view(np.uint16)
        key = _leaf_key(i, path)
        arrays[key] = arr
        manifest_leaves.append(
            {
                "path": path,
                "key": key,
                "shape": list(arr.shape),
                "dtype": logical,
                "crc32": zlib.crc32(arr.tobytes()),
            }
        )

    shard_path = os.path.join(tmp, "shard_p0.npz")
    np.savez(shard_path, **arrays)
    manifest = {"step": step, "leaves": manifest_leaves, "meta": meta}
    man_path = os.path.join(tmp, "manifest.msgpack")
    with open(man_path, "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):  # overwrite-in-place restart of the same step
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(root: str) -> Optional[int]:
    """Newest *valid* (committed) checkpoint step under ``root``."""
    if not os.path.isdir(root):
        return None
    best = None
    for name in os.listdir(root):
        m = re.fullmatch(r"step_(\d+)", name)
        if not m:
            continue
        if not os.path.exists(os.path.join(root, name, "COMMIT")):
            continue  # half-written (preemption mid-save) — skip
        s = int(m.group(1))
        best = s if best is None or s > best else best
    return best


def restore(
    root: str,
    step: Optional[int] = None,
    *,
    like: Optional[PyTree] = None,
    shardings: Optional[PyTree] = None,
) -> tuple[int, PyTree, dict]:
    """Restore. ``like`` gives the target structure; ``shardings`` (same
    structure, NamedSharding leaves) triggers elastic resharding onto the
    current mesh."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(d, "shard_p0.npz"))

    by_path = {}
    for leaf in manifest["leaves"]:
        arr = data[leaf["key"]]
        if zlib.crc32(arr.tobytes()) != leaf["crc32"]:
            raise IOError(f"checksum mismatch for {leaf['path']} in {d}")
        if leaf["dtype"] == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        by_path[leaf["path"]] = arr

    if like is None:
        # return a flat dict keyed by path
        return step, by_path, manifest.get("meta", {})

    flat = pytree.tree_paths(like)
    leaves = []
    for path, ref in flat:
        if path not in by_path:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = by_path[path]
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"shape mismatch for {path}: ckpt {arr.shape} vs {ref.shape}")
        leaves.append(arr)
    treedef = jax.tree.structure(like)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        flat_s = jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        tree = jax.tree.unflatten(
            treedef,
            [jax.device_put(a, s) for a, s in zip(jax.tree.leaves(tree), flat_s)],
        )
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return step, tree, manifest.get("meta", {})
