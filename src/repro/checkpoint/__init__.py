from repro.checkpoint.ckpt import save, restore, latest_step  # noqa: F401
