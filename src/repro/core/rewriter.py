"""MHQ Rewriter: predicted execution strategies and parameters (paper §3.4).

Phase 1 — strategy head: X_in -> {filter_first, index_scan, single_index}.
Phase 2 — parameter heads: per vector column, classification over the
  nprobe / max_scan / k_mult grids + a Bernoulli head for iterative_scan.

Self-supervised training exactly as the paper prescribes: execute each
workload query under a grid of candidate configurations, measure (latency,
recall), and label with the cheapest configuration that meets the query's
recall target. A per-column greedy trim pass differentiates k_i/nprobe_i
across columns (the weight-adaptive behaviour of Fig. 5).
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import nn
from repro.core.executor import HybridExecutor, recall_at_k
from repro.core.query import (
    BEAM_GRID, ExecutionPlan, HOP_GRID, KMULT_GRID, MAX_SCAN_GRID, MHQ,
    NPROBE_GRID, PRECISION_GRID, STRATEGIES, SubqueryParams,
)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

N_NP, N_MS, N_KM = len(NPROBE_GRID), len(MAX_SCAN_GRID), len(KMULT_GRID)
N_BEAM, N_HOP = len(BEAM_GRID), len(HOP_GRID)
PER_COL = N_NP + N_MS + N_KM + 1


@dataclasses.dataclass(frozen=True)
class RewriterConfig:
    hidden: int = 96
    lr: float = 2e-3
    steps: int = 800
    batch: int = 64
    seed: int = 0
    refine_columns: bool = True  # per-column greedy trim of the best plan


@dataclasses.dataclass
class PlanLabel:
    strategy: int
    nprobe_idx: np.ndarray  # (N,)
    max_scan_idx: np.ndarray  # (N,)
    k_mult_idx: np.ndarray  # (N,)
    iterative: np.ndarray  # (N,) {0,1}
    latency: float
    recall: float
    precision: int = 0  # PRECISION_GRID index of the candidate-tier dtype
    beam_idx: int = 1  # BEAM_GRID index (graph strategy only)
    hop_idx: int = 1  # HOP_GRID index (graph strategy only)


class MHQRewriter:
    def __init__(self, in_dim: int, n_vec: int, cfg: RewriterConfig):
        self.cfg = cfg
        self.n_vec = n_vec
        self.in_dim = in_dim
        k = jax.random.PRNGKey(cfg.seed)
        k1, k2, k3, k4, k5 = jax.random.split(k, 5)
        h = cfg.hidden
        self.params = {
            "trunk": nn.mlp_init(k1, [in_dim, h, h]),
            "strategy": nn.mlp_init(k2, [h, len(STRATEGIES)]),
            "per_col": nn.mlp_init(k3, [h, n_vec * PER_COL]),
            "precision": nn.mlp_init(k4, [h, len(PRECISION_GRID)]),
            # graph-strategy knobs: beam-width and hop-count grids, one
            # shared head (the walk is per-query, not per-column)
            "graph": nn.mlp_init(k5, [h, N_BEAM + N_HOP]),
        }

    # -- forward -------------------------------------------------------------

    def _heads(self, params, x):
        z = nn.mlp_apply(params["trunk"], x, final_activation=True)
        strat = nn.mlp_apply(params["strategy"], z)
        per_col = nn.mlp_apply(params["per_col"], z)
        per_col = per_col.reshape(*per_col.shape[:-1], self.n_vec, PER_COL)
        prec = nn.mlp_apply(params["precision"], z)
        gr = nn.mlp_apply(params["graph"], z)
        return strat, per_col, prec, gr

    def plan_codes(self, params, x):
        """Jit-friendly head evaluation: -> int32 codes
        [strategy, np_idx×N, ms_idx×N, km_idx×N, iter×N, precision,
        beam_idx, hop_idx]."""
        strat, per_col, prec, gr = self._heads(params, x)
        s_idx = jnp.argmax(strat)[None]
        np_i = jnp.argmax(per_col[..., :N_NP], axis=-1)
        ms_i = jnp.argmax(per_col[..., N_NP:N_NP + N_MS], axis=-1)
        km_i = jnp.argmax(per_col[..., N_NP + N_MS:N_NP + N_MS + N_KM], axis=-1)
        it = (per_col[..., -1] > 0.0).astype(jnp.int32)
        p_idx = jnp.argmax(prec)[None]
        b_idx = jnp.argmax(gr[..., :N_BEAM])[None]
        h_idx = jnp.argmax(gr[..., N_BEAM:])[None]
        return jnp.concatenate(
            [s_idx, np_i, ms_i, km_i, it, p_idx, b_idx, h_idx]
        ).astype(jnp.int32)

    def plan_from_codes(self, codes: np.ndarray) -> ExecutionPlan:
        n = self.n_vec
        s_idx = int(codes[0])
        np_i, ms_i, km_i = (codes[1:1 + n], codes[1 + n:1 + 2 * n],
                            codes[1 + 2 * n:1 + 3 * n])
        it = codes[1 + 3 * n:1 + 4 * n]
        # precision + graph knobs ride as trailing codes; decode stays
        # compatible with shorter code vectors (older checkpoints/tests)
        prec = PRECISION_GRID[int(codes[1 + 4 * n])] \
            if codes.shape[0] > 1 + 4 * n else "fp32"
        beam = BEAM_GRID[int(codes[2 + 4 * n])] \
            if codes.shape[0] > 2 + 4 * n else ExecutionPlan.beam_width
        hops = HOP_GRID[int(codes[3 + 4 * n])] \
            if codes.shape[0] > 3 + 4 * n else ExecutionPlan.n_hops
        subs = tuple(
            SubqueryParams(k_mult=KMULT_GRID[km_i[i]], nprobe=NPROBE_GRID[np_i[i]],
                           max_scan=MAX_SCAN_GRID[ms_i[i]], iterative=bool(it[i]))
            for i in range(n))
        return ExecutionPlan(strategy=STRATEGIES[s_idx], subqueries=subs,
                             precision=prec, beam_width=beam, n_hops=hops)

    def predict(self, x: np.ndarray, *, k: int = 10) -> ExecutionPlan:
        """Single-query convenience wrapper over the canonical decode path
        (plan_codes -> plan_from_codes), so the two can never drift.

        Dominant column for single_index: the largest-weight feature is
        embedded in x; the caller picks it at plan-build time."""
        if not hasattr(self, "_codes_jit") or self._codes_jit is None:
            self._codes_jit = jax.jit(self.plan_codes)
        codes = np.asarray(self._codes_jit(self.params, jnp.asarray(x)))
        return self.plan_from_codes(codes)

    # -- training --------------------------------------------------------------

    def fit(self, X: np.ndarray, labels: list[PlanLabel]) -> dict:
        cfg = self.cfg
        n = X.shape[0]
        y_strat = jnp.asarray([l.strategy for l in labels])
        y_np = jnp.asarray(np.stack([l.nprobe_idx for l in labels]))
        y_ms = jnp.asarray(np.stack([l.max_scan_idx for l in labels]))
        y_km = jnp.asarray(np.stack([l.k_mult_idx for l in labels]))
        y_it = jnp.asarray(np.stack([l.iterative for l in labels]), jnp.float32)
        y_prec = jnp.asarray([l.precision for l in labels])
        y_beam = jnp.asarray([l.beam_idx for l in labels])
        y_hop = jnp.asarray([l.hop_idx for l in labels])
        # parameter losses only matter for index-scan-family labels
        par_mask = jnp.asarray([1.0 if l.strategy != 0 else 0.0 for l in labels])
        gr_idx = STRATEGIES.index("graph")
        gr_mask = jnp.asarray(
            [1.0 if l.strategy == gr_idx else 0.0 for l in labels])
        Xj = jnp.asarray(X)

        def loss_fn(params, idx):
            x = Xj[idx]
            strat, per_col, prec, gr = self._heads(params, x)
            ls = -jnp.mean(jnp.take_along_axis(
                jax.nn.log_softmax(strat), y_strat[idx][:, None], 1))
            # precision head: like the strategy head but masked to the
            # index family (filter_first is always fp32 post-legalization)
            lprec = -jnp.mean(jnp.take_along_axis(
                jax.nn.log_softmax(prec), y_prec[idx][:, None], 1)[..., 0]
                * par_mask[idx])
            # graph knob heads: only graph-strategy labels carry a
            # meaningful beam/hop choice
            lgr = -jnp.mean(
                (jnp.take_along_axis(
                    jax.nn.log_softmax(gr[..., :N_BEAM]),
                    y_beam[idx][:, None], 1)[..., 0]
                 + jnp.take_along_axis(
                    jax.nn.log_softmax(gr[..., N_BEAM:]),
                    y_hop[idx][:, None], 1)[..., 0]) * gr_mask[idx])
            ls = ls + lprec + lgr

            def head_ce(sl, y):
                logp = jax.nn.log_softmax(per_col[..., sl], axis=-1)
                ce = -jnp.take_along_axis(logp, y[idx][..., None], -1)[..., 0]
                return jnp.mean(ce * par_mask[idx][:, None])

            lp = head_ce(slice(0, N_NP), y_np)
            lp += head_ce(slice(N_NP, N_NP + N_MS), y_ms)
            lp += head_ce(slice(N_NP + N_MS, N_NP + N_MS + N_KM), y_km)
            logit_it = per_col[..., -1]
            bce = jnp.mean(
                (jax.nn.softplus(logit_it) - y_it[idx] * logit_it)
                * par_mask[idx][:, None])
            return ls + lp + bce

        opt_cfg = AdamWConfig(lr=cfg.lr, weight_decay=1e-4, grad_clip_norm=1.0)
        st = adamw_init(self.params, opt_cfg)
        grad = jax.jit(jax.value_and_grad(loss_fn))
        rng = np.random.default_rng(cfg.seed)
        l = jnp.zeros(())
        for step in range(cfg.steps):
            idx = jnp.asarray(rng.integers(0, n, min(cfg.batch, n)))
            l, g = grad(self.params, idx)
            self.params, st = adamw_update(g, st, self.params, opt_cfg)
        # training accuracy
        strat, _, _, _ = self._heads(self.params, Xj)
        acc = float(jnp.mean(jnp.argmax(strat, -1) == y_strat))
        return {"rewriter_loss": float(l), "strategy_acc": acc}


# ---------------------------------------------------------------------------
# self-supervised label generation (grid execution)
# ---------------------------------------------------------------------------

def candidate_plans(n_vec: int, weights=None, *,
                    graphs: bool = False) -> list[ExecutionPlan]:
    """The exploration grid (coarse; per-column trim refines it afterwards).

    ``graphs``: offer graph-strategy configurations — only meaningful when
    the labeling executor has a graph tier bound (otherwise legalization
    rewrites them to index_scan and the label would be mis-attributed)."""
    plans = [ExecutionPlan("filter_first",
                           tuple(SubqueryParams() for _ in range(n_vec)))]
    for npb, km, ms in itertools.product((2, 8, 32), (2, 8), (8192, 131072)):
        subs = tuple(SubqueryParams(k_mult=km, nprobe=npb, max_scan=ms,
                                    iterative=True) for _ in range(n_vec))
        plans.append(ExecutionPlan("index_scan", subs))
    if graphs:
        # the beam/hop product spans cheap walks (short, narrow — the
        # selective-predicate sweet spot) through deep wide walks that
        # rival exhaustive probing on recall
        for bw, nh, km in ((4, 2, 2), (8, 4, 2), (8, 4, 8), (16, 8, 8)):
            subs = tuple(SubqueryParams(k_mult=km, iterative=False)
                         for _ in range(n_vec))
            plans.append(ExecutionPlan("graph", subs, beam_width=bw,
                                       n_hops=nh))
    # quantized-tier twins of the deep-scan configs: int8 candidate scoring
    # + exact fp32 rerank only pays off where the scan budget is large, so
    # the exploration grid offers it exactly there — label generation then
    # measures whether the two-stage path is actually cheaper at target
    for npb, km in itertools.product((8, 32), (2, 8)):
        subs = tuple(SubqueryParams(k_mult=km, nprobe=npb, max_scan=131072,
                                    iterative=True) for _ in range(n_vec))
        plans.append(ExecutionPlan("index_scan", subs, precision="int8"))
    if n_vec > 1 and weights is not None:
        dom = int(np.argmax(weights))
        for npb in (8, 32):
            subs = tuple(SubqueryParams(k_mult=8, nprobe=npb, max_scan=32768,
                                        iterative=True) for _ in range(n_vec))
            plans.append(ExecutionPlan("single_index", subs, dominant=dom))
    return plans


def _grid_index(grid, value) -> int:
    return min(range(len(grid)), key=lambda i: abs(grid[i] - value))


def plan_to_label(plan: ExecutionPlan, latency: float, recall: float) -> PlanLabel:
    return PlanLabel(
        strategy=STRATEGIES.index(plan.strategy),
        nprobe_idx=np.asarray([_grid_index(NPROBE_GRID, s.nprobe)
                               for s in plan.subqueries]),
        max_scan_idx=np.asarray([_grid_index(MAX_SCAN_GRID, s.max_scan)
                                 for s in plan.subqueries]),
        k_mult_idx=np.asarray([_grid_index(KMULT_GRID, s.k_mult)
                               for s in plan.subqueries]),
        iterative=np.asarray([1.0 if s.iterative else 0.0
                              for s in plan.subqueries], np.float32),
        latency=latency, recall=recall,
        precision=PRECISION_GRID.index(plan.precision),
        beam_idx=_grid_index(BEAM_GRID, plan.beam_width),
        hop_idx=_grid_index(HOP_GRID, plan.n_hops))


LABEL_RECALL_MARGIN = 0.05  # train to a margin above E_rec: the learned
# heads generalize imperfectly, so labels aim slightly high to keep the
# SERVED recall at/above the user threshold


def generate_label(executor: HybridExecutor, q: MHQ, gt_ids,
                   *, refine_columns: bool = True) -> PlanLabel:
    """Execute the candidate grid; label = cheapest plan meeting the target
    (+ margin). If nothing meets it, fall back to the highest-recall plan
    (the engine cannot do better within its own search space)."""
    target = min(1.0, q.recall_target + LABEL_RECALL_MARGIN)
    best, best_any = None, None
    has_graphs = getattr(executor, "graphs", None) is not None
    for plan in candidate_plans(q.n_vec, q.weights, graphs=has_graphs):
        ids, _, dt = executor.execute_timed(q, plan)
        rec = recall_at_k(ids, gt_ids)
        entry = (dt, rec, plan)
        if best_any is None or rec > best_any[1] + 1e-9 or \
                (abs(rec - best_any[1]) < 1e-9 and dt < best_any[0]):
            best_any = entry
        if rec >= target and (best is None or dt < best[0]):
            best = entry
    if best is None:
        best = best_any
    dt, rec, plan = best

    # per-column greedy trim: shrink k_mult / nprobe of each column while the
    # recall target still holds — differentiates columns by weight (Fig. 5)
    if refine_columns and plan.strategy != "filter_first" and q.n_vec > 1:
        # graph walks ignore nprobe — trimming it would loop to the grid
        # floor on no-op re-executions
        attrs = (("k_mult", KMULT_GRID),) if plan.strategy == "graph" else \
            (("k_mult", KMULT_GRID), ("nprobe", NPROBE_GRID))
        subs = list(plan.subqueries)
        for i in range(q.n_vec):
            for attr, grid in attrs:
                while True:
                    cur = getattr(subs[i], attr)
                    gi = _grid_index(grid, cur)
                    if gi == 0:
                        break
                    trial = dataclasses.replace(subs[i], **{attr: grid[gi - 1]})
                    trial_plan = dataclasses.replace(
                        plan, subqueries=tuple(subs[:i] + [trial] + subs[i + 1:]))
                    ids, _, dt_t = executor.execute_timed(q, trial_plan)
                    if recall_at_k(ids, gt_ids) >= target:
                        subs[i] = trial
                        plan, dt, rec = trial_plan, dt_t, recall_at_k(ids, gt_ids)
                    else:
                        break
        plan = dataclasses.replace(plan, subqueries=tuple(subs))

    return plan_to_label(plan, dt, rec)
