"""Correlation-Aware Vector–Scalar Data Encoder (paper §3.2).

Per vector column i:
  * M **frozen** MLPs ``f_frozen[i,j]`` — each trained to predict scalar j
    (binned, cross-entropy) from vector i, then frozen. Their softmax outputs
    embed scalar-relevant structure into the vector representation.
  * one **trainable** MLP ``f_trainable[i]`` — trained end-to-end with the
    autoencoder.

``E_i = [‖_j f_frozen[i,j](v_i) ; f_trainable[i](v_i) ; E_s]`` feeds a shared
autoencoder trained on reconstruction MSE. At query time the reconstruction
error of the (query-vector, predicate-encoding) pairing is the anomaly score
ε_recon_i consumed by the rewriter.

Incremental updates (paper §3.2 'Incremental Model Updates'): ``update()``
fine-tunes on the inserted rows only — frozen nets get a short refresh, the
AE continues training; no full retraining pass.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import nn
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.vectordb.predicates import PredicateLike, soft_encode, value_encode
from repro.vectordb.table import Table


@dataclasses.dataclass(frozen=True)
class DataEncoderConfig:
    n_bins: int = 16  # one-hot bins per scalar (encoder-side)
    frozen_hidden: int = 32
    trainable_dim: int = 16
    ae_hidden: int = 64
    ae_latent: int = 24
    lr: float = 2e-3
    frozen_steps: int = 200
    ae_steps: int = 400
    update_steps: int = 80  # incremental fine-tune budget
    batch: int = 512
    sample: int = 8192  # sampled subset for initial training (paper §3.5)
    seed: int = 0


def _quantile_edges(scalars: np.ndarray, n_bins: int) -> np.ndarray:
    """(n, M) -> (M, B+1) quantile bin edges (robust to skewed marginals)."""
    qs = np.linspace(0.0, 1.0, n_bins + 1)
    edges = np.quantile(scalars, qs, axis=0).T.astype(np.float32)
    # ensure strictly increasing edges
    eps = 1e-6 * (1.0 + np.abs(edges))
    edges = np.maximum.accumulate(edges + eps * np.arange(n_bins + 1)[None, :], axis=1)
    return edges


# ---------------------------------------------------------------------------
# stacked frozen predictors (per vector column: M nets, vmapped over j)
# ---------------------------------------------------------------------------

def _frozen_init(key, d_in: int, m: int, cfg: DataEncoderConfig):
    k1, k2 = jax.random.split(key)
    h, b = cfg.frozen_hidden, cfg.n_bins
    return {
        "w0": nn.trunc_normal(k1, (m, d_in, h), 1.0 / np.sqrt(d_in)),
        "b0": jnp.zeros((m, h)),
        "w1": nn.trunc_normal(k2, (m, h, b), 1.0 / np.sqrt(h)),
        "b1": jnp.zeros((m, b)),
    }


def _frozen_apply(p, v):
    """v: (..., d) -> (..., M, B) softmax probabilities."""
    h = jax.nn.relu(jnp.einsum("...d,mdh->...mh", v, p["w0"]) + p["b0"])
    logits = jnp.einsum("...mh,mhb->...mb", h, p["w1"]) + p["b1"]
    return jax.nn.softmax(logits, axis=-1)


def _frozen_logits(p, v):
    h = jax.nn.relu(jnp.einsum("...d,mdh->...mh", v, p["w0"]) + p["b0"])
    return jnp.einsum("...mh,mhb->...mb", h, p["w1"]) + p["b1"]


# ---------------------------------------------------------------------------
# the encoder
# ---------------------------------------------------------------------------

class DataEncoder:
    """Holds params + bin edges; provides fit / update / recon_error."""

    def __init__(self, vec_dims: list[int], n_scalars: int, cfg: DataEncoderConfig):
        self.cfg = cfg
        self.vec_dims = list(vec_dims)
        self.m = n_scalars
        self.edges: Optional[jnp.ndarray] = None  # (M, B+1)
        self.params: dict = {}
        b, t = cfg.n_bins, cfg.trainable_dim
        self.embed_dim = self.m * b + t + self.m * b  # E_vi ; E_s

    # -- embeddings ---------------------------------------------------------

    def _evec(self, params, i: int, v: jax.Array) -> jax.Array:
        """E_vi = [frozen probs (M·B) ; trainable (T)] for column i."""
        fr = _frozen_apply(params["frozen"][i], v)  # (..., M, B)
        fr = fr.reshape(*fr.shape[:-2], -1)
        tr = nn.mlp_apply(params["trainable"][i], v)
        return jnp.concatenate([fr, tr], axis=-1)

    def _ae(self, params, e: jax.Array) -> jax.Array:
        z = nn.mlp_apply(params["ae_enc"], e)
        return nn.mlp_apply(params["ae_dec"], z)

    def embed_rows(self, i: int, vecs: jax.Array, scalars: jax.Array) -> jax.Array:
        es = jax.vmap(lambda s: value_encode(s, self.edges).reshape(-1))(scalars)
        ev = self._evec(self.params, i, vecs)
        return jnp.concatenate([ev, es], axis=-1)

    # -- training -----------------------------------------------------------

    def fit(self, table: Table) -> dict:
        cfg = self.cfg
        key = jax.random.PRNGKey(cfg.seed)
        n = table.n_rows
        sub = np.random.default_rng(cfg.seed).choice(n, min(cfg.sample, n), replace=False)
        scal_np = np.asarray(table.scalars)[sub]
        self.edges = jnp.asarray(_quantile_edges(np.asarray(table.scalars), cfg.n_bins))
        # bin labels for frozen training
        labels = np.stack(
            [
                np.clip(
                    np.searchsorted(np.asarray(self.edges)[j], scal_np[:, j], side="right") - 1,
                    0,
                    cfg.n_bins - 1,
                )
                for j in range(self.m)
            ],
            axis=1,
        )  # (S, M)
        labels = jnp.asarray(labels)

        keys = jax.random.split(key, 2 * len(self.vec_dims) + 2)
        params = {
            "frozen": [
                _frozen_init(keys[i], d, self.m, cfg) for i, d in enumerate(self.vec_dims)
            ],
            "trainable": [
                nn.mlp_init(keys[len(self.vec_dims) + i], [d, cfg.frozen_hidden, cfg.trainable_dim])
                for i, d in enumerate(self.vec_dims)
            ],
            "ae_enc": nn.mlp_init(keys[-2], [self.embed_dim, cfg.ae_hidden, cfg.ae_latent]),
            "ae_dec": nn.mlp_init(keys[-1], [cfg.ae_latent, cfg.ae_hidden, self.embed_dim]),
        }

        # ---- stage 1: frozen predictors (per vector column) ----
        opt_cfg = AdamWConfig(lr=cfg.lr, weight_decay=1e-4, grad_clip_norm=1.0)

        @jax.jit
        def frozen_loss(fp, v, lab):
            logits = _frozen_logits(fp, v)  # (B, M, bins)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, lab[:, :, None], axis=-1))

        metrics = {}
        rng = np.random.default_rng(cfg.seed + 1)
        for i in range(len(self.vec_dims)):
            vecs = jnp.asarray(np.asarray(table.vectors[i])[sub])
            fp = params["frozen"][i]
            st = adamw_init(fp, opt_cfg)
            grad_fn = jax.jit(jax.value_and_grad(frozen_loss))
            for step in range(cfg.frozen_steps):
                bidx = rng.integers(0, vecs.shape[0], cfg.batch)
                l, g = grad_fn(fp, vecs[bidx], labels[bidx])
                fp, st = adamw_update(g, st, fp, opt_cfg)
            params["frozen"][i] = fp
            metrics[f"frozen_loss_col{i}"] = float(l)

        # ---- stage 2: trainable + AE (frozen nets held fixed) ----
        es_all = jax.vmap(lambda s: value_encode(s, self.edges).reshape(-1))(
            jnp.asarray(scal_np)
        )
        vec_subs = [jnp.asarray(np.asarray(table.vectors[i])[sub]) for i in range(len(self.vec_dims))]

        def ae_loss(train_params, batch_idx):
            p = {**params, "trainable": train_params["trainable"],
                 "ae_enc": train_params["ae_enc"], "ae_dec": train_params["ae_dec"]}
            loss = 0.0
            for i in range(len(self.vec_dims)):
                ev = self._evec(p, i, vec_subs[i][batch_idx])
                e = jnp.concatenate([ev, es_all[batch_idx]], axis=-1)
                rec = self._ae(p, e)
                loss = loss + jnp.mean(jnp.square(rec - e))
            return loss / len(self.vec_dims)

        tp = {"trainable": params["trainable"], "ae_enc": params["ae_enc"], "ae_dec": params["ae_dec"]}
        st = adamw_init(tp, opt_cfg)
        grad_fn = jax.jit(jax.value_and_grad(ae_loss))
        for step in range(cfg.ae_steps):
            bidx = jnp.asarray(rng.integers(0, len(sub), cfg.batch))
            l, g = grad_fn(tp, bidx)
            tp, st = adamw_update(g, st, tp, opt_cfg)
        params.update(tp)
        metrics["ae_loss"] = float(l)
        self.params = params
        return metrics

    def update(self, table: Table, new_rows: np.ndarray) -> dict:
        """Incremental fine-tune on inserted rows only (paper: O(c·M̃))."""
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + 2)
        scal_new = jnp.asarray(np.asarray(table.scalars)[new_rows])
        es_new = jax.vmap(lambda s: value_encode(s, self.edges).reshape(-1))(scal_new)
        vec_new = [jnp.asarray(np.asarray(table.vectors[i])[new_rows]) for i in range(len(self.vec_dims))]
        params = self.params

        def ae_loss(train_params, batch_idx):
            p = {**params, "trainable": train_params["trainable"],
                 "ae_enc": train_params["ae_enc"], "ae_dec": train_params["ae_dec"]}
            loss = 0.0
            for i in range(len(self.vec_dims)):
                ev = self._evec(p, i, vec_new[i][batch_idx])
                e = jnp.concatenate([ev, es_new[batch_idx]], axis=-1)
                rec = self._ae(p, e)
                loss = loss + jnp.mean(jnp.square(rec - e))
            return loss / len(self.vec_dims)

        tp = {"trainable": params["trainable"], "ae_enc": params["ae_enc"], "ae_dec": params["ae_dec"]}
        opt_cfg = AdamWConfig(lr=cfg.lr * 0.5, weight_decay=1e-4)
        st = adamw_init(tp, opt_cfg)
        grad_fn = jax.jit(jax.value_and_grad(ae_loss))
        nb = scal_new.shape[0]
        l = jnp.zeros(())
        for step in range(cfg.update_steps):
            bidx = jnp.asarray(rng.integers(0, nb, min(cfg.batch, nb)))
            l, g = grad_fn(tp, bidx)
            tp, st = adamw_update(g, st, tp, opt_cfg)
        self.params = {**params, **tp}
        return {"ae_update_loss": float(l)}

    # -- query phase --------------------------------------------------------

    def recon_errors(self, query_vectors: list[jax.Array], pred: PredicateLike) -> jax.Array:
        """ε_recon per vector column for a query (paper 'Query Phase')."""
        if not hasattr(self, "_recon_jit") or self._recon_jit is None:
            def _fn(params, edges, qs, pred):
                es = soft_encode(pred, edges).reshape(-1)
                errs = []
                for i, q in enumerate(qs):
                    ev = self._evec(params, i, q)
                    e = jnp.concatenate([ev, es], axis=-1)
                    rec = self._ae(params, e)
                    errs.append(jnp.mean(jnp.square(rec - e)))
                return jnp.stack(errs)

            self._recon_jit = jax.jit(_fn)
        return self._recon_jit(self.params, self.edges, tuple(query_vectors), pred)
