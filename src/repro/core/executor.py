"""MHQ execution engine: the three strategies + two-phase multi-vector flow.

Strategies (paper §3.4, TPU-adapted per DESIGN.md §2):
  * filter_first  — evaluate Q_S over all rows, gather ≤ max_candidates
                    qualifying rows, score only those (scalar-index path);
  * index_scan    — rewrite the MHQ into one single-vector filtered IVF
                    subquery per column (k_i, nprobe, max_scan, iterative),
                    merge the candidates, re-rank by the full weighted score;
  * single_index  — heavily skewed weights: search only the dominant column,
                    re-rank by the full score.

``iterative`` implements pgvector's iterative_scan as nprobe doubling while
the filtered result underfills k (bounded by the engine's nprobe cap).

Engine personalities (§5.4): Milvus/OpenSearch expose no max_scan_tuples /
iterative_scan, so those knobs pin to engine defaults — the learned
optimizer is constrained to each engine's search space.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.query import ExecutionPlan, MHQ, SubqueryParams
from repro.vectordb import flat, ivf
from repro.vectordb.table import Table, similarity

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class EngineCaps:
    """What the underlying engine exposes (paper §5 setup / §5.4)."""
    name: str
    max_scan_tuples: bool = True
    iterative_scan: bool = True
    per_column_params: bool = True  # can k_i / nprobe differ per column?
    nprobe_cap: int = 64
    default_max_scan: int = 32768


PGVECTOR = EngineCaps("pgvector")
MILVUS = EngineCaps("milvus", max_scan_tuples=False, iterative_scan=False)
OPENSEARCH = EngineCaps("opensearch", max_scan_tuples=False, iterative_scan=False)
ENGINES = {e.name: e for e in (PGVECTOR, MILVUS, OPENSEARCH)}


def _dedup_topk(rows, score, *, k, total):
    """Top-k over candidate scores with duplicate row ids suppressed by
    keeping only the first occurrence (sort-based). rows: (total,), -1 =
    empty slot."""
    valid = rows >= 0
    order = jnp.argsort(rows)
    sorted_rows = rows[order]
    first = jnp.concatenate([jnp.ones((1,), bool),
                             sorted_rows[1:] != sorted_rows[:-1]])
    keep = jnp.zeros((total,), bool).at[order].set(first) & valid
    masked = jnp.where(keep, score, NEG)
    top_s, top_i = jax.lax.top_k(masked, k)
    ids = jnp.where(top_s > NEG / 2, rows[top_i], -1)
    return ids, top_s


@partial(jax.jit, static_argnames=("k", "n_vec", "metric", "total"))
def _rerank(vectors, pred_mask_rows, rows, qs, w, *, k, n_vec, metric, total):
    """Re-rank the union of candidate rows by the full weighted score.

    rows: (total,) candidate ids, -1 = empty."""
    n = vectors[0].shape[0]
    rows_c = jnp.clip(rows, 0, n - 1)
    score = jnp.zeros((total,), jnp.float32)
    for i in range(n_vec):
        score = score + w[i] * similarity(qs[i], vectors[i][rows_c], metric)
    return _dedup_topk(rows, score, k=k, total=total)


@partial(jax.jit, static_argnames=("k", "total"))
def rerank_scored(row_scores, rows, *, k, total):
    """``_rerank`` with the full weighted row scores precomputed (the
    batched path's per-column GEMMs already hold every candidate's score)."""
    n = row_scores.shape[0]
    score = row_scores[jnp.clip(rows, 0, n - 1)]
    return _dedup_topk(rows, score, k=k, total=total)


def legalize_for_shard(k_i: int, nprobe: int, max_scan: int, *,
                       n_shards: int, shard_len: int,
                       n_clusters: int) -> tuple[int, int, int]:
    """Split one subquery's GLOBAL probing budget across ``n_shards``.

    The learned plan's knobs describe a whole-table search; under the
    per-shard IVF path every shard probes its own (smaller) index, so the
    scan budget is divided across shards (ceil, floored at the per-shard
    candidate count so a shard can always fill its slice of the merge) and
    nprobe is clamped to the per-shard cluster count. Returns the per-shard
    ``(k_i, nprobe, max_scan)`` — all static, so they join the group key and
    the jit cache stays bounded the same way the single-device grids do."""
    ms = min(shard_len, max(1, min(k_i, shard_len), -(-max_scan // n_shards)))
    return min(k_i, ms), max(1, min(nprobe, n_clusters)), ms


def plan_columns(q: MHQ, plan: ExecutionPlan) -> tuple:
    """Vector columns a plan actually searches (shared by the sequential and
    batched executors so candidate generation can never drift)."""
    if plan.strategy == "single_index":
        return (plan.dominant,)
    return tuple(i for i in range(q.n_vec) if q.weights[i] > 0.0)


class HybridExecutor:
    """Binds a table + per-column IVF indexes + an engine personality."""

    def __init__(self, table: Table, indexes: list, engine: EngineCaps = PGVECTOR):
        self.table = table
        self.indexes = indexes
        self.engine = engine

    # -- plan legalization ---------------------------------------------------

    def legalize(self, plan: ExecutionPlan) -> ExecutionPlan:
        """Clamp a plan to what the engine personality supports, and every
        candidate budget to the table — the legalized ``max_scan`` /
        ``max_candidates`` are what the batched executor's scoring
        dispatcher weighs against ``n_rows``."""
        e = self.engine
        subs = []
        base = plan.subqueries[0]
        for s in plan.subqueries:
            if not e.per_column_params:
                s = dataclasses.replace(s, k_mult=base.k_mult, nprobe=base.nprobe)
            if not e.max_scan_tuples:
                s = dataclasses.replace(s, max_scan=e.default_max_scan)
            if not e.iterative_scan:
                s = dataclasses.replace(s, iterative=False)
            s = dataclasses.replace(s, nprobe=min(s.nprobe, e.nprobe_cap))
            subs.append(s)
        return dataclasses.replace(
            plan, subqueries=tuple(subs),
            max_candidates=min(plan.max_candidates, self.table.n_rows))

    # -- execution -------------------------------------------------------------

    def execute(self, q: MHQ, plan: ExecutionPlan):
        """-> (ids (k,), scores (k,)) numpy arrays."""
        plan = self.legalize(plan)
        t = self.table
        w = jnp.asarray(q.weights, jnp.float32)
        if plan.strategy == "filter_first":
            ids, scores, _, _ = flat.filter_first(
                tuple(t.vectors), t.scalars, q.predicates,
                tuple(q.query_vectors), w, t.schema.metric,
                k=q.k, max_candidates=plan.max_candidates, n_vec=q.n_vec)
            return ids, scores

        cols = plan_columns(q, plan)

        cand = []
        for i in cols:
            sp = plan.subqueries[i]
            k_i = min(sp.k_mult * q.k, t.n_rows)
            ids_i = self._subquery(i, q, k_i, sp)
            cand.append(ids_i)
        rows = jnp.concatenate(cand)
        total = int(rows.shape[0])
        return _rerank(tuple(t.vectors), None, rows, tuple(q.query_vectors), w,
                       k=q.k, n_vec=q.n_vec, metric=t.schema.metric, total=total)

    def _subquery(self, i: int, q: MHQ, k_i: int, sp: SubqueryParams):
        """One single-vector filtered subquery, with iterative re-expansion."""
        t = self.table
        nprobe = sp.nprobe
        while True:
            nprobe = min(nprobe, self.indexes[i].n_clusters, self.engine.nprobe_cap)
            max_scan = min(sp.max_scan, t.n_rows)
            ids, scores, n_scored, n_qual = ivf.search(
                self.indexes[i], t.vectors[i], t.scalars, q.predicates,
                q.query_vectors[i], nprobe=nprobe, max_scan=max_scan, k=k_i)
            if not sp.iterative:
                return ids
            # boomlint: ignore[HS001] one sync per re-expansion round is the
            # sequential iterative_scan contract (the batched path amortizes
            # it per group — serve/batch._batched_subquery)
            if int(n_qual) >= k_i or nprobe >= min(self.indexes[i].n_clusters,
                                                   self.engine.nprobe_cap):
                return ids
            nprobe *= 2  # iterative_scan: relaxed re-expansion

    # -- measured execution ----------------------------------------------------

    def execute_timed(self, q: MHQ, plan: ExecutionPlan, *, repeats: int = 1):
        """Returns (ids, scores, seconds). Call once to warm the jit cache
        before timing loops."""
        ids, scores = self.execute(q, plan)  # warm + result
        jax.block_until_ready(scores)
        t0 = time.perf_counter()
        for _ in range(repeats):
            ids, scores = self.execute(q, plan)
            jax.block_until_ready(scores)
        dt = (time.perf_counter() - t0) / repeats
        return np.asarray(ids), np.asarray(scores), dt


def recall_at_k(ids, gt_ids) -> float:
    got = set(int(i) for i in np.asarray(ids) if i >= 0)
    gt = [int(i) for i in np.asarray(gt_ids) if i >= 0]
    if not gt:
        return 1.0
    return len(got.intersection(gt)) / len(gt)
