"""MHQ execution engine: the three strategies + two-phase multi-vector flow.

Strategies (paper §3.4, TPU-adapted per DESIGN.md §2):
  * filter_first  — evaluate Q_S over all rows, gather ≤ max_candidates
                    qualifying rows, score only those (scalar-index path);
  * index_scan    — rewrite the MHQ into one single-vector filtered IVF
                    subquery per column (k_i, nprobe, max_scan, iterative),
                    merge the candidates, re-rank by the full weighted score;
  * single_index  — heavily skewed weights: search only the dominant column,
                    re-rank by the full score.

``iterative`` implements pgvector's iterative_scan as nprobe doubling while
the filtered result underfills k (bounded by the engine's nprobe cap).

Engine personalities (§5.4): Milvus/OpenSearch expose no max_scan_tuples /
iterative_scan, so those knobs pin to engine defaults — the learned
optimizer is constrained to each engine's search space.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.query import (
    BEAM_GRID, ExecutionPlan, HOP_GRID, MHQ, PRECISION_GRID, SubqueryParams,
)
from repro.vectordb import flat, graph, ivf, predicates
from repro.vectordb.table import Table, similarity

NEG = -1e30

# Shape-bucketing primitives. These live here (not serve/batch) because the
# candidate-union width vocabulary is part of PLAN SEMANTICS shared by the
# sequential and batched executors — both must build the same union for the
# parity contract to hold. serve/batch re-exports them unchanged.
K_BUCKET_FLOOR = 16  # smallest padded top-k bucket
CANDIDATE_PAD_FLOOR = 64  # smallest padded candidate-slot bucket


def next_bucket(n: int, floor: int = 1) -> int:
    """Smallest power-of-two bucket ≥ n (≥ floor)."""
    b = floor
    while b < n:
        b <<= 1
    return b


def pow2_at_most(n: int) -> int:
    b = 1
    while b * 2 <= n:
        b <<= 1
    return b


@dataclasses.dataclass(frozen=True)
class EngineCaps:
    """What the underlying engine exposes (paper §5 setup / §5.4)."""
    name: str
    max_scan_tuples: bool = True
    iterative_scan: bool = True
    per_column_params: bool = True  # can k_i / nprobe differ per column?
    nprobe_cap: int = 64
    default_max_scan: int = 32768


PGVECTOR = EngineCaps("pgvector")
MILVUS = EngineCaps("milvus", max_scan_tuples=False, iterative_scan=False)
OPENSEARCH = EngineCaps("opensearch", max_scan_tuples=False, iterative_scan=False)
ENGINES = {e.name: e for e in (PGVECTOR, MILVUS, OPENSEARCH)}


def _dedup_topk(rows, score, *, k, total):
    """Top-k over candidate scores with duplicate row ids suppressed by
    keeping only the first occurrence (sort-based). rows: (total,), -1 =
    empty slot."""
    valid = rows >= 0
    order = jnp.argsort(rows)
    sorted_rows = rows[order]
    first = jnp.concatenate([jnp.ones((1,), bool),
                             sorted_rows[1:] != sorted_rows[:-1]])
    keep = jnp.zeros((total,), bool).at[order].set(first) & valid
    masked = jnp.where(keep, score, NEG)
    top_s, top_i = jax.lax.top_k(masked, k)
    ids = jnp.where(top_s > NEG / 2, rows[top_i], -1)
    return ids, top_s


@partial(jax.jit, static_argnames=("k", "n_vec", "metric", "total"))
def _rerank(vectors, pred_mask_rows, rows, qs, w, *, k, n_vec, metric, total):
    """Re-rank the union of candidate rows by the full weighted score.

    rows: (total,) candidate ids, -1 = empty."""
    n = vectors[0].shape[0]
    rows_c = jnp.clip(rows, 0, n - 1)
    score = jnp.zeros((total,), jnp.float32)
    for i in range(n_vec):
        score = score + w[i] * similarity(qs[i], vectors[i][rows_c], metric)
    return _dedup_topk(rows, score, k=k, total=total)


@partial(jax.jit, static_argnames=("k", "total"))
def rerank_scored(row_scores, rows, *, k, total):
    """``_rerank`` with the full weighted row scores precomputed (the
    batched path's per-column GEMMs already hold every candidate's score)."""
    n = row_scores.shape[0]
    score = row_scores[jnp.clip(rows, 0, n - 1)]
    return _dedup_topk(rows, score, k=k, total=total)


# Reciprocal-rank fusion across per-column candidate lists (multi-column
# index_scan unions). Truncating each column at its top-k_i loses rows that
# rank just below k_i in EVERY column yet carry the best weighted score on
# weight-skewed queries — and the subquery probes already ranked a wider
# list (the padded top-k bucket), whose tail was previously discarded. The
# union therefore keeps the exact per-column top-k_i block (the engine
# contract) and fills its pad bucket with the rows the combined column
# rankings like best: score(row) = Σ_cols 1/(RRF_K + rank_col(row)).
RRF_K = 60  # standard reciprocal-rank-fusion constant
RRF_MIN_EXTRA = 16  # fused-extra slots guaranteed per multi-column union


def rrf_union_total(sum_ki: int) -> int:
    """Static union width for a multi-column candidate union: the exact
    per-column top-k_i block plus ≥ RRF_MIN_EXTRA fused-extra slots,
    power-of-two bucketed so the width vocabulary stays finite."""
    return next_bucket(sum_ki + RRF_MIN_EXTRA, CANDIDATE_PAD_FLOOR)


def subquery_width(k_i: int, max_scan: int) -> int:
    """Probe width of one column's subquery: the padded top-k bucket, so
    the list carries a ranked tail beyond k_i for RRF fusion to draw from.
    One formula for both executors — the fused extras must be computed
    from identical lists for batched/sequential parity."""
    return min(next_bucket(k_i, K_BUCKET_FLOOR), max_scan)


@partial(jax.jit, static_argnames=("kis", "n_extra", "rrf_k"))
def rrf_extras(lists, *, kis, n_extra, rrf_k=RRF_K):
    """Top-``n_extra`` candidates by reciprocal-rank fusion of the columns'
    ranked tails, excluding rows already in some column's top-k_i block.

    ``lists``: per-column (B, ks_i) ranked candidate ids, -1 = empty slot
    (each column's FULL probed ranking, top-k_i prefix included so a row's
    fused score sees all of its ranks). ``kis``: static per-column included
    widths. Returns (B, n_extra) ids, -1 padded, best-fused first.

    Cross-column dedup sums every occurrence's contribution: sort slots by
    row id, segmented cumulative sums (cum/cumi are nondecreasing along the
    row, so a running max of each segment-start value carries every slot
    its own segment base), then read each run at its last slot."""
    sc_parts, inc_parts = [], []
    for lst, ki in zip(lists, kis):
        valid = lst >= 0
        contrib = 1.0 / (rrf_k + 1.0
                         + jnp.arange(lst.shape[1], dtype=jnp.float32))
        sc_parts.append(jnp.where(valid, contrib[None, :], 0.0))
        inc_parts.append(valid & (jnp.arange(lst.shape[1]) < ki)[None, :])
    rows = jnp.concatenate(list(lists), axis=1)
    sc = jnp.concatenate(sc_parts, axis=1)
    inc = jnp.concatenate(inc_parts, axis=1).astype(jnp.int32)
    order = jnp.argsort(rows, axis=1)
    rs = jnp.take_along_axis(rows, order, axis=1)
    cs = jnp.take_along_axis(sc, order, axis=1)
    ins = jnp.take_along_axis(inc, order, axis=1)
    cum = jnp.cumsum(cs, axis=1)
    cumi = jnp.cumsum(ins, axis=1)
    b = rs.shape[0]
    seg_start = jnp.concatenate(
        [jnp.ones((b, 1), bool), rs[:, 1:] != rs[:, :-1]], axis=1)
    is_last = jnp.concatenate(
        [rs[:, 1:] != rs[:, :-1], jnp.ones((b, 1), bool)], axis=1)
    base = jax.lax.cummax(jnp.where(seg_start, cum - cs, -1.0), axis=1)
    basei = jax.lax.cummax(jnp.where(seg_start, cumi - ins, -1), axis=1)
    fused = jnp.where(is_last & (rs >= 0) & (cumi - basei == 0),
                      cum - base, -1.0)
    ne = min(n_extra, fused.shape[1])
    top_s, top_j = jax.lax.top_k(fused, ne)
    out = jnp.where(top_s > 0.0, jnp.take_along_axis(rs, top_j, axis=1), -1)
    if ne < n_extra:
        out = jnp.pad(out, ((0, 0), (0, n_extra - ne)), constant_values=-1)
    return out.astype(jnp.int32)


def legalize_for_shard(k_i: int, nprobe: int, max_scan: int, *,
                       n_shards: int, shard_len: int,
                       n_clusters: int) -> tuple[int, int, int]:
    """Split one subquery's GLOBAL probing budget across ``n_shards``.

    The learned plan's knobs describe a whole-table search; under the
    per-shard IVF path every shard probes its own (smaller) index, so the
    scan budget is divided across shards (ceil, floored at the per-shard
    candidate count so a shard can always fill its slice of the merge) and
    nprobe is clamped to the per-shard cluster count. Returns the per-shard
    ``(k_i, nprobe, max_scan)`` — all static, so they join the group key and
    the jit cache stays bounded the same way the single-device grids do."""
    ms = min(shard_len, max(1, min(k_i, shard_len), -(-max_scan // n_shards)))
    return min(k_i, ms), max(1, min(nprobe, n_clusters)), ms


def plan_columns(q: MHQ, plan: ExecutionPlan) -> tuple:
    """Vector columns a plan actually searches (shared by the sequential and
    batched executors so candidate generation can never drift)."""
    if plan.strategy == "single_index":
        return (plan.dominant,)
    return tuple(i for i in range(q.n_vec) if q.weights[i] > 0.0)


def legal_knob(grid: tuple, value: int) -> int:
    """Smallest grid entry ≥ value (grid max when none) — how the graph
    beam/hop knobs snap onto their static grids at legalization time."""
    for g in grid:
        if g >= value:
            return g
    return grid[-1]


class HybridExecutor:
    """Binds a table + per-column IVF indexes + an engine personality.

    ``graphs``: optional per-column ``vectordb.graph.GraphIndex`` tuple —
    when bound, plans may pick the third ("graph") strategy; when absent,
    legalization rewrites graph plans to index_scan so a plan learned
    against a graph-bearing deployment stays executable everywhere."""

    def __init__(self, table: Table, indexes: list,
                 engine: EngineCaps = PGVECTOR, *, graphs=None):
        self.table = table
        self.indexes = indexes
        self.engine = engine
        self.graphs = tuple(graphs) if graphs is not None else None

    # -- plan legalization ---------------------------------------------------

    def legalize(self, plan: ExecutionPlan) -> ExecutionPlan:
        """Clamp a plan to what the engine personality supports, and every
        candidate budget to the table — the legalized ``max_scan`` /
        ``max_candidates`` are what the batched executor's scoring
        dispatcher weighs against ``n_rows``."""
        e = self.engine
        subs = []
        base = plan.subqueries[0]
        for s in plan.subqueries:
            if not e.per_column_params:
                s = dataclasses.replace(s, k_mult=base.k_mult, nprobe=base.nprobe)
            if not e.max_scan_tuples:
                s = dataclasses.replace(s, max_scan=e.default_max_scan)
            if not e.iterative_scan:
                s = dataclasses.replace(s, iterative=False)
            s = dataclasses.replace(s, nprobe=min(s.nprobe, e.nprobe_cap))
            subs.append(s)
        # precision legalization: unknown values pin to fp32, and
        # filter_first always scores fp32 (its gather is the plan — there
        # is no candidate tier for the int8 replica to accelerate), so the
        # batched group keys never split on a precision that can't act.
        prec = plan.precision if plan.precision in PRECISION_GRID else "fp32"
        if plan.strategy == "filter_first":
            prec = "fp32"
        strategy = plan.strategy
        beam, hops = plan.beam_width, plan.n_hops
        if strategy == "graph":
            if self.graphs is None:
                # no graph tier bound: the nearest executable strategy is
                # the per-column probe union the graph plan approximates
                strategy = "index_scan"
            else:
                # graph candidates come from the fp32 routing walk + one
                # fused extraction — there is no int8 candidate tier
                prec = "fp32"
                beam = legal_knob(BEAM_GRID, beam)
                hops = legal_knob(HOP_GRID, hops)
        return dataclasses.replace(
            plan, strategy=strategy, subqueries=tuple(subs), precision=prec,
            beam_width=beam, n_hops=hops,
            max_candidates=min(plan.max_candidates, self.table.n_rows))

    # -- execution -------------------------------------------------------------

    def execute(self, q: MHQ, plan: ExecutionPlan):
        """-> (ids (k,), scores (k,)) numpy arrays."""
        plan = self.legalize(plan)
        t = self.table
        w = jnp.asarray(q.weights, jnp.float32)
        if plan.strategy == "filter_first":
            ids, scores, _, _ = flat.filter_first(
                tuple(t.vectors), t.scalars, q.predicates,
                tuple(q.query_vectors), w, t.schema.metric,
                k=q.k, max_candidates=plan.max_candidates, n_vec=q.n_vec)
            return ids, scores

        cols = plan_columns(q, plan)

        cand, wide = [], []
        for i in cols:
            sp = plan.subqueries[i]
            k_i = min(sp.k_mult * q.k, t.n_rows)
            ks = subquery_width(k_i, min(sp.max_scan, t.n_rows)) \
                if len(cols) > 1 else k_i
            if plan.strategy == "graph":
                # predicate-aware beam walk over the column's proximity
                # graph; the returned list is already filtered + ranked,
                # so it slots into the same RRF union + rerank as IVF
                ids_i, _, _, _ = graph.search(
                    self.graphs[i], t.vectors[i], t.scalars, q.predicates,
                    q.query_vectors[i], beam_width=plan.beam_width,
                    n_hops=plan.n_hops, k=ks)
            else:
                ids_i = self._subquery(i, q, k_i, sp,
                                       precision=plan.precision, width=ks)
            wide.append(ids_i)
            cand.append(ids_i[:k_i])
        rows = jnp.concatenate(cand)
        if len(cols) > 1:
            # multi-column union: RRF-fused extras from the probed tails
            # (identical construction to serve/batch._union_candidates, so
            # batched/sequential parity is preserved by both improving)
            kis = tuple(int(c.shape[0]) for c in cand)
            total = rrf_union_total(int(rows.shape[0]))
            extras = rrf_extras(tuple(wd[None, :] for wd in wide), kis=kis,
                                n_extra=total - int(rows.shape[0]))
            rows = jnp.concatenate([rows, extras[0]])
        total = int(rows.shape[0])
        return _rerank(tuple(t.vectors), None, rows, tuple(q.query_vectors), w,
                       k=q.k, n_vec=q.n_vec, metric=t.schema.metric, total=total)

    def _subquery(self, i: int, q: MHQ, k_i: int, sp: SubqueryParams,
                  precision: str = "fp32", width: int | None = None):
        """One single-vector filtered subquery, with iterative re-expansion.

        ``width`` (≥ k_i) widens the returned ranked list — top-k is
        prefix-consistent, so slots beyond k_i are the column's ranked tail
        for RRF fusion; underfill and re-expansion still key on k_i.

        ``precision == "int8"`` probes the same slots but scores them from
        the column's int8 replica, exact-reranking the top-α·k survivors in
        fp32 (``ivf.search_local_batch_int8`` at batch 1). The qualified
        count driving re-expansion comes from the exact fp32 scalar
        predicates either way, so the doubling ladder is precision-blind."""
        t = self.table
        kw = width or k_i
        nprobe = sp.nprobe
        while True:
            nprobe = min(nprobe, self.indexes[i].n_clusters, self.engine.nprobe_cap)
            max_scan = min(sp.max_scan, t.n_rows)
            if precision == "int8":
                vq, sc = t.quantized(i)
                ids_b, _, _, nq_b = ivf.search_local_batch_int8(
                    self.indexes[i], t.vectors[i], vq, sc, t.scalars,
                    predicates.stack([q.predicates]),
                    q.query_vectors[i][None, :],
                    nprobe=nprobe, max_scan=max_scan, k=kw)
                ids, n_qual = ids_b[0], nq_b[0]
            else:
                ids, scores, n_scored, n_qual = ivf.search(
                    self.indexes[i], t.vectors[i], t.scalars, q.predicates,
                    q.query_vectors[i], nprobe=nprobe, max_scan=max_scan, k=kw)
            if not sp.iterative:
                return ids
            # boomlint: ignore[HS001] one sync per re-expansion round is the
            # sequential iterative_scan contract (the batched path amortizes
            # it per group — serve/batch._batched_subquery)
            if int(n_qual) >= k_i or nprobe >= min(self.indexes[i].n_clusters,
                                                   self.engine.nprobe_cap):
                return ids
            nprobe *= 2  # iterative_scan: relaxed re-expansion

    # -- measured execution ----------------------------------------------------

    def execute_timed(self, q: MHQ, plan: ExecutionPlan, *, repeats: int = 1):
        """Returns (ids, scores, seconds). Call once to warm the jit cache
        before timing loops."""
        ids, scores = self.execute(q, plan)  # warm + result
        jax.block_until_ready(scores)
        t0 = time.perf_counter()
        for _ in range(repeats):
            ids, scores = self.execute(q, plan)
            jax.block_until_ready(scores)
        dt = (time.perf_counter() - t0) / repeats
        return np.asarray(ids), np.asarray(scores), dt


def recall_at_k(ids, gt_ids) -> float:
    got = set(int(i) for i in np.asarray(ids) if i >= 0)
    gt = [int(i) for i in np.asarray(gt_ids) if i >= 0]
    if not gt:
        return 1.0
    return len(got.intersection(gt)) / len(gt)
