"""Neighborhood-Selectivity-Enhanced Query Representation (paper §3.3).

Three feature groups, exactly as the paper prescribes:
  (1) statistics      — weights W_V, k, the user's recall target E_rec;
  (2) local           — neighborhood pre-probing: a cheap *unfiltered* ANN
                        probe per vector column, then the fraction of probed
                        neighbors satisfying Q_S (the local satisfaction
                        rate), plus the probe's mean similarity;
  (3) global          — histogram selectivity estimate σ_est (prefix-sum
                        lookups, independence across conjuncts).

Plus the data-encoder's reconstruction errors ε_recon (§3.2 query phase).
``encode()`` assembles X_in = [‖ᵢ ε_recon_i ; S_enc ; E_rec ; R_probe ; σ_est]
for the rewriter heads.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.data_encoder import DataEncoder
from repro.core.query import MHQ
from repro.vectordb import histogram, ivf
from repro.vectordb.predicates import active_any, soft_encode
from repro.vectordb.table import Table

S_ENC_BINS = 8  # compact predicate encoding for X_in


@dataclasses.dataclass
class QueryFeatures:
    recon_errors: np.ndarray  # (N,)
    local_rates: np.ndarray  # (N,) pre-probe satisfaction rate per column
    probe_scores: np.ndarray  # (N,) mean top-score of the unfiltered probe
    selectivity: float  # σ_est
    weights: np.ndarray  # (N,)
    k: int
    recall_target: float
    s_enc: np.ndarray  # flattened predicate encoding

    def x_in(self) -> np.ndarray:
        return np.concatenate([
            self.recon_errors,
            self.local_rates,
            self.probe_scores,
            [self.selectivity, np.log1p(1.0 / max(self.selectivity, 1e-6))],
            self.weights,
            [np.log(self.k), self.recall_target],
            self.s_enc,
        ]).astype(np.float32)


def feature_dim(n_vec: int, n_scalar: int) -> int:
    return 3 * n_vec + 2 + n_vec + 2 + n_scalar * (S_ENC_BINS + 1)


class QueryEncoder:
    """Holds the per-column IVF indexes (for pre-probing), the histograms
    (for GSE) and the data encoder (for ε_recon)."""

    def __init__(self, table: Table, indexes: list, hists: histogram.Histograms,
                 data_encoder: Optional[DataEncoder], *, probe_k: int = 32,
                 probe_nprobe: int = 1):
        self.table = table
        self.indexes = indexes
        self.hists = hists
        self.data_encoder = data_encoder
        self.probe_k = probe_k
        self.probe_nprobe = probe_nprobe
        # compact bin edges for S_enc
        scal = np.asarray(table.scalars)
        lo, hi = scal.min(axis=0), scal.max(axis=0)
        span = np.maximum(hi - lo, 1e-9)
        self._edges = jnp.asarray(
            lo[:, None] + span[:, None]
            * np.linspace(0.0, 1.0 + 1e-6, S_ENC_BINS + 1)[None, :],
            jnp.float32)

    # -- single-feature probes (exposed for ablations) ----------------------

    def global_selectivity(self, q: MHQ) -> float:
        return float(histogram.estimate_selectivity(self.hists, q.predicates))

    def local_probe(self, q: MHQ) -> tuple[np.ndarray, np.ndarray]:
        rates, scores = [], []
        for i, qv in enumerate(q.query_vectors):
            rate, ms = ivf.preprobe(
                self.indexes[i], self.table.vectors[i], self.table.scalars,
                q.predicates, qv, nprobe=self.probe_nprobe, probe_k=self.probe_k)
            rates.append(float(rate))
            scores.append(float(ms))
        return np.asarray(rates, np.float32), np.asarray(scores, np.float32)

    def recon_errors(self, q: MHQ) -> np.ndarray:
        if self.data_encoder is None:
            return np.zeros((q.n_vec,), np.float32)
        errs = self.data_encoder.recon_errors(list(q.query_vectors), q.predicates)
        return np.asarray(errs, np.float32)

    # -- full feature assembly ----------------------------------------------

    def encode(self, q: MHQ, *, use_de=True, use_stats=True, use_gse=True,
               use_lnp=True) -> QueryFeatures:
        """Feature flags support the paper's ablations (§5.5)."""
        n = q.n_vec
        recon = self.recon_errors(q) if use_de else np.zeros((n,), np.float32)
        if use_lnp:
            rates, scores = self.local_probe(q)
        else:
            rates = np.full((n,), 0.5, np.float32)
            scores = np.zeros((n,), np.float32)
        sel = self.global_selectivity(q) if use_gse else 0.5
        if not hasattr(self, "_senc_jit") or self._senc_jit is None:
            self._senc_jit = jax.jit(soft_encode)
        enc = np.asarray(self._senc_jit(q.predicates, self._edges), np.float32)
        # DNF predicates fold to the same (M, B) mass + a per-column
        # any-clause activity flag, so the feature width is clause-free
        active = np.asarray(active_any(q.predicates), np.float32)[:, None]
        s_enc = np.concatenate([enc, active], axis=1).reshape(-1)
        if use_stats:
            weights = np.asarray(q.weights, np.float32)
            k, rec = q.k, q.recall_target
        else:
            weights = np.full((n,), 1.0 / n, np.float32)
            k, rec = 10, 0.9
        return QueryFeatures(
            recon_errors=recon, local_rates=rates, probe_scores=scores,
            selectivity=float(sel), weights=weights, k=k, recall_target=rec,
            s_enc=s_enc)
