"""MHQ query and execution-plan types (paper §1 definition, §3.4 search space).

A Multiple Hybrid Query Q = ⟨Q_S, Q_V, W_V⟩: scalar predicates, one query
vector per vector column, and per-column weights. ``ExecutionPlan`` is the
rewriter's output — the strategy plus per-subquery parameters, i.e. exactly
the knobs the paper tunes (ef_search→nprobe, max_scan_tuples,
iterative_scan, per-column candidate count k_i).

Parameters live on small discrete grids so the learned heads are
classification tasks and the jit cache stays bounded.
"""
from __future__ import annotations

import dataclasses

from repro.vectordb.predicates import PredicateLike

STRATEGIES = ("filter_first", "index_scan", "single_index", "graph")

# parameter grids (ef_search analogue etc.) — §3.4 search space
NPROBE_GRID = (1, 2, 4, 8, 16, 32)
MAX_SCAN_GRID = (2048, 8192, 32768, 131072)
KMULT_GRID = (1, 2, 4, 8)  # k_i = mult · k
# graph-strategy knobs: beam width and hop count of the predicate-aware
# proximity-graph walk (kernels.beam_search). The grids bound the static
# candidate-pool shapes, so the jit cache is keyed by at most
# |BEAM_GRID|·|HOP_GRID| routing traces per column.
BEAM_GRID = (4, 8, 16)
HOP_GRID = (2, 4, 8)
# scoring precision of the candidate tier: exact fp32, or the symmetric
# int8 replica with an exact fp32 rerank of the top-α·k survivors
# (kernels.gather_score.gather_score_topk_int8). Scalar predicates stay
# fp32 either way, so filtering is bit-identical across precisions.
PRECISION_GRID = ("fp32", "int8")


@dataclasses.dataclass(frozen=True)
class MHQ:
    query_vectors: tuple  # one (d_i,) jnp array per vector column
    weights: tuple  # one float per vector column
    predicates: PredicateLike  # conjunctive Predicates or DNF PredicateSet
    k: int = 10
    recall_target: float = 0.9
    # namespace: folds to an implicit `tenant_col == tenant_id` conjunct in
    # every DNF clause (BoomHQ.resolve_tenant) — no new kernel surface
    tenant_id: int | None = None

    @property
    def n_vec(self) -> int:
        return len(self.query_vectors)


@dataclasses.dataclass(frozen=True)
class SubqueryParams:
    k_mult: int = 2  # k_i = k_mult · k
    nprobe: int = 8  # ef_search analogue
    max_scan: int = 8192  # max_scan_tuples analogue
    iterative: bool = True  # iterative_scan: re-expand nprobe on underfill


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    strategy: str  # one of STRATEGIES
    subqueries: tuple  # one SubqueryParams per vector column
    dominant: int = 0  # column searched when strategy == "single_index"
    max_candidates: int = 16384  # filter-first gather cap
    precision: str = "fp32"  # PRECISION_GRID: candidate-tier scoring dtype
    # graph-strategy knobs (ignored by the other strategies): beam width and
    # hop count of the predicate-aware proximity-graph walk
    beam_width: int = 8  # BEAM_GRID
    n_hops: int = 4  # HOP_GRID

    def describe(self) -> str:
        subs = ", ".join(
            f"col{i}(k×{s.k_mult},np{s.nprobe},ms{s.max_scan}"
            f"{',iter' if s.iterative else ''})"
            for i, s in enumerate(self.subqueries))
        prec = "" if self.precision == "fp32" else f"@{self.precision}"
        knobs = f"(bw{self.beam_width},h{self.n_hops})" \
            if self.strategy == "graph" else ""
        return f"{self.strategy}{prec}{knobs}[{subs}]"


def default_plan(n_vec: int, engine_caps=None) -> ExecutionPlan:
    """A robust one-size-fits-all plan (also the underfill-escalation
    fallback): wide probes + a deep scan cap.

    ``engine_caps`` (an ``executor.EngineCaps``-shaped object, duck-typed to
    avoid a circular import) clamps the knobs to what the engine
    personality exposes: nprobe to ``nprobe_cap``, max_scan to the engine
    default when ``max_scan_tuples`` is absent, and iterative_scan off when
    unsupported."""
    nprobe, max_scan, iterative = 16, 131072, True
    if engine_caps is not None:
        nprobe = min(nprobe, engine_caps.nprobe_cap)
        if not engine_caps.max_scan_tuples:
            max_scan = engine_caps.default_max_scan
        iterative = iterative and engine_caps.iterative_scan
    return ExecutionPlan(
        strategy="index_scan",
        subqueries=tuple(SubqueryParams(k_mult=4, nprobe=nprobe,
                                        max_scan=max_scan,
                                        iterative=iterative)
                         for _ in range(n_vec)),
    )
