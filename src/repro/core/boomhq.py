"""BoomHQ façade: the full learned optimizer wired end-to-end (paper Fig. 2).

  fit():      build per-column IVF indexes + histograms, train the
              correlation-aware data encoder, generate self-supervised plan
              labels over the training workload, train the rewriter heads.
  optimize(): query encoder -> X_in -> predicted ExecutionPlan.
  execute():  optimize + run on the bound engine personality.
  insert():   buffer-style data updates — extend indexes/histograms and
              incrementally fine-tune the data encoder (paper §3.2, §5.3).

Ablation switches (use_de / use_stats / use_gse / use_lnp) zero out the
corresponding X_in feature groups — BoomHQ w.o. DE / QE-Stats / QE-GSE /
QE-LNP in the paper's §5.5 naming.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.data_encoder import DataEncoder, DataEncoderConfig
from repro.core.executor import EngineCaps, HybridExecutor, PGVECTOR
from repro.core.query import ExecutionPlan, MHQ, SubqueryParams, default_plan
from repro.core.query_encoder import QueryEncoder
from repro.core.rewriter import MHQRewriter, RewriterConfig, generate_label
from repro.vectordb import flat, graph, histogram, ivf
from repro.vectordb.table import Table


def _n_valid(ids) -> int:
    return int(np.sum(np.asarray(ids) >= 0))


@dataclasses.dataclass(frozen=True)
class BoomHQConfig:
    n_clusters: int = 64
    hist_bins: int = 64
    # per-column proximity graphs (the third "graph" strategy —
    # vectordb.graph): fixed out-degree of the sealed Vamana-style graph;
    # 0 disables the tier (plans legalize graph -> index_scan)
    graph_degree: int = 16
    encoder: DataEncoderConfig = dataclasses.field(default_factory=DataEncoderConfig)
    rewriter: RewriterConfig = dataclasses.field(default_factory=RewriterConfig)
    # ablations (§5.5)
    use_de: bool = True
    use_stats: bool = True
    use_gse: bool = True
    use_lnp: bool = True


class BoomHQ:
    def __init__(self, table: Table, cfg: BoomHQConfig = BoomHQConfig(),
                 engine: EngineCaps = PGVECTOR):
        self.table = table
        self.cfg = cfg
        self.engine = engine
        self.indexes = [
            ivf.build(v, min(cfg.n_clusters, max(2, table.n_rows // 8)),
                      seed=i, metric=table.schema.metric)
            for i, v in enumerate(table.vectors)
        ]
        self.hists = histogram.build(table.scalars, cfg.hist_bins)
        self.graphs = None
        if cfg.graph_degree:
            self.graphs = tuple(
                graph.build(v, cfg.graph_degree, metric=table.schema.metric)
                for v in table.vectors)
        self.executor = HybridExecutor(table, self.indexes, engine,
                                       graphs=self.graphs)
        self.data_encoder: Optional[DataEncoder] = None
        if cfg.use_de:
            self.data_encoder = DataEncoder(
                [v.shape[1] for v in table.vectors], table.schema.n_scalar,
                cfg.encoder)
        self.qenc: Optional[QueryEncoder] = None
        self.rewriter: Optional[MHQRewriter] = None
        self._fitted = False
        self.n_shards = 1  # cross-shard serving config (bind_shards)
        self.shard_mesh = None
        self.cost_model = None  # scoring-dispatch override (bind_cost_model)
        self.tiered = None  # streaming-ingest config (bind_tiered)
        self.tenant_col = None  # namespace column index (bind_tenants)
        self._compactor = None  # background scheduler (serve attaches one)
        self._tiered_finetune = True
        # recent served queries, retained so compaction can pre-warm the
        # post-swap jit shapes with REAL traffic before the epoch publish
        self._recent: deque = deque(maxlen=64)
        self._last_batch = 1

    # -- offline -------------------------------------------------------------

    def fit(self, workload: list[MHQ], *, verbose: bool = False) -> dict:
        metrics = {}
        t0 = time.perf_counter()
        if self.data_encoder is not None:
            metrics.update(self.data_encoder.fit(self.table))
        self.qenc = QueryEncoder(self.table, self.indexes, self.hists,
                                 self.data_encoder)
        feats, labels = [], []
        for qi, q in enumerate(workload):
            gt_ids, _ = flat.ground_truth(
                self.table, list(q.query_vectors), list(q.weights),
                q.predicates, q.k)
            x = self._features(q)
            lab = generate_label(self.executor, q, gt_ids,
                                 refine_columns=self.cfg.rewriter.refine_columns)
            feats.append(x)
            labels.append(lab)
            if verbose and (qi + 1) % 50 == 0:
                print(f"  labeled {qi + 1}/{len(workload)} queries")
        X = np.stack(feats)
        n_vec = workload[0].n_vec
        self.rewriter = MHQRewriter(X.shape[1], n_vec, self.cfg.rewriter)
        metrics.update(self.rewriter.fit(X, labels))
        metrics["fit_seconds"] = time.perf_counter() - t0
        self._fitted = True
        return metrics

    def _features(self, q: MHQ) -> np.ndarray:
        """X_in for one query, via a single fused jitted pipeline (the
        unfused per-feature path in QueryEncoder.encode is kept for tests
        and ablations of individual probes)."""
        if getattr(self, "_fused_x", None) is None:
            self._fused_x = self._build_fused_features()
        de = self.data_encoder
        de_args = (de.params, de.edges) if (self.cfg.use_de and de is not None) \
            else (None, None)
        x = self._fused_x(
            de_args, self.qenc._edges, self.hists,
            tuple(self.indexes), tuple(self.table.vectors), self.table.scalars,
            tuple(q.query_vectors), q.predicates,
            jnp.asarray(q.weights, jnp.float32),
            jnp.asarray(float(np.log(q.k)), jnp.float32),
            jnp.asarray(q.recall_target, jnp.float32))
        return np.asarray(x)

    def _build_fused_features(self, scored: bool = False):
        """One jitted function assembling X_in exactly like
        QueryFeatures.x_in(): [ε_recon; rates; probe_scores; σ, log1p(1/σ);
        weights; log k, E_rec; S_enc].

        ``scored=True`` builds the batched variant: it takes one extra
        ``row_scores`` arg (a per-column tuple of (n,) similarities,
        precomputed by a whole-batch GEMM) and pre-probes by gathering f32
        scores instead of vectors — the vmapped vector gather is the
        dominant batched-optimizer cost on CPU."""
        from functools import partial

        from repro.core.query_encoder import S_ENC_BINS  # noqa: F401
        from repro.vectordb import ivf as _ivf
        from repro.vectordb.predicates import active_any as _active_any
        from repro.vectordb.predicates import soft_encode as _soft

        cfg = self.cfg
        use_de = cfg.use_de and self.data_encoder is not None
        de = self.data_encoder
        probe_k, probe_np = self.qenc.probe_k, self.qenc.probe_nprobe
        n_vec = self.table.schema.n_vec

        @partial(jax.jit, static_argnums=())
        def fused(de_args, senc_edges, hists, indexes, vectors, scalars,
                  qs, pred, weights, logk, rec, row_scores=()):
            de_params, de_edges = de_args
            if use_de:
                es = _soft(pred, de_edges).reshape(-1)
                recon = []
                for i in range(n_vec):
                    ev = de._evec(de_params, i, qs[i])
                    e = jnp.concatenate([ev, es], axis=-1)
                    recon.append(jnp.mean(jnp.square(de._ae(de_params, e) - e)))
                recon = jnp.stack(recon)
            else:
                recon = jnp.zeros((n_vec,), jnp.float32)
            if cfg.use_lnp:
                rates, scores = [], []
                for i in range(n_vec):
                    if scored:
                        r, s = _ivf.preprobe_scored(
                            indexes[i], row_scores[i], scalars, pred, qs[i],
                            nprobe=probe_np, probe_k=probe_k)
                    else:
                        r, s = _ivf.preprobe(
                            indexes[i], vectors[i], scalars, pred, qs[i],
                            nprobe=probe_np, probe_k=probe_k)
                    rates.append(r)
                    scores.append(s)
                rates, scores = jnp.stack(rates), jnp.stack(scores)
            else:
                rates = jnp.full((n_vec,), 0.5)
                scores = jnp.zeros((n_vec,))
            if cfg.use_gse:
                from repro.vectordb import histogram as _h
                sel = _h.estimate_selectivity(hists, pred)
            else:
                sel = jnp.asarray(0.5)
            enc = _soft(pred, senc_edges)
            s_enc = jnp.concatenate(
                [enc, _active_any(pred).astype(jnp.float32)[:, None]],
                axis=1).reshape(-1)
            if not cfg.use_stats:
                weights = jnp.full((n_vec,), 1.0 / n_vec)
                logk = jnp.asarray(np.log(10.0), jnp.float32)
                rec = jnp.asarray(0.9, jnp.float32)
            return jnp.concatenate([
                recon, rates, scores,
                jnp.stack([sel, jnp.log1p(1.0 / jnp.maximum(sel, 1e-6))]),
                weights, jnp.stack([logk, rec]), s_enc,
            ]).astype(jnp.float32)

        return fused

    # -- online ----------------------------------------------------------------

    SINGLE_INDEX_MIN_SKEW = 0.85  # paper: single-index only for skewed weights

    def optimize(self, q: MHQ) -> ExecutionPlan:
        """ONE fused jit call (features + heads + argmax) and ONE host sync
        per query — the optimizer's serving overhead is dispatch-dominated
        on small tables, so everything lives in a single graph."""
        if not self._fitted:
            return default_plan(q.n_vec, self.engine)
        if getattr(self, "_plan_jit", None) is None:
            self._build_plan_jit()
        de = self.data_encoder
        de_args = (de.params, de.edges) if (self.cfg.use_de and de is not None) \
            else (None, None)
        codes = np.asarray(self._plan_jit(
            self.rewriter.params, de_args, self.qenc._edges, self.hists,
            tuple(self.indexes), tuple(self.table.vectors), self.table.scalars,
            tuple(q.query_vectors), q.predicates,
            jnp.asarray(q.weights, jnp.float32),
            jnp.asarray(float(np.log(q.k)), jnp.float32),
            jnp.asarray(q.recall_target, jnp.float32)))
        return self._apply_skew_guard(self.rewriter.plan_from_codes(codes), q)

    def _apply_skew_guard(self, plan: ExecutionPlan, q: MHQ) -> ExecutionPlan:
        if plan.strategy == "single_index":
            wmax = float(np.max(q.weights))
            if wmax >= self.SINGLE_INDEX_MIN_SKEW:
                plan = dataclasses.replace(plan, dominant=int(np.argmax(q.weights)))
            else:  # guard: not skewed enough — fall back to per-column scans
                plan = dataclasses.replace(plan, strategy="index_scan")
        return plan

    def _plan_local(self, b: int, cold=None) -> bool:
        """Should batch planning skip the dense score GEMMs?

        The batched optimizer's only dense-score consumer is the pre-probe
        feature; its candidate budget is the probe scan (``probe_k·4`` or
        ``nprobe·4·n/C`` rows per query per column). The same cost model
        that dispatches execution groups weighs that budget against the
        table: when candidate-local wins, planning runs the unscored
        pre-probe (vector gathers on the small probe tiles) and the GEMMs
        are never built unless an execution group later asks for them."""
        from repro.serve.batch import CANDIDATE_LOCAL, CostModel, next_bucket
        cm = self.cost_model if self.cost_model is not None else CostModel()
        t = self.table if cold is None else cold.table
        idxs = self.indexes if cold is None else cold.indexes
        n = t.n_rows
        scan = 0
        for idx in idxs:
            if self.qenc is not None:
                scan += ivf.probe_scan_budget(
                    idx.n_clusters, n, nprobe=self.qenc.probe_nprobe,
                    probe_k=self.qenc.probe_k)
            else:
                scan += min(n, self.engine.default_max_scan)
        return cm.choose(batch=next_bucket(max(1, b)), scan=max(1, scan),
                         n_rows=n * max(1, len(idxs))) \
            == CANDIDATE_LOCAL

    def optimize_batch(self, qs: list[MHQ], *,
                       scores_b: Optional[tuple] = None,
                       dense: Optional[bool] = None,
                       cold=None) -> list[ExecutionPlan]:
        """Plan a whole batch with ONE fused jit call and ONE host sync:
        the per-query feature + head pipeline vmapped over the query axis
        (batch padded to a power-of-two bucket so the jit cache stays
        bounded). ``scores_b`` — per-column (B_bucket, n) dense similarity
        matrices from ``compute_batch_scores`` — feeds the pre-probe
        features; pass the same tuple to the batched executor so the GEMMs
        run once per batch. ``dense=None`` auto-picks: when the scoring
        cost model says the table is past the dense crossover (and no
        matrices were passed in), planning runs the UNSCORED pre-probe
        pipeline instead and no (B, n) matrix is ever built.

        ``cold`` — an optional epoch's ``tiered.ColdState``: planning reads
        THAT epoch's table/indexes/histograms (the snapshot a formed batch
        carries) instead of the façade's fields, so plans stay consistent
        with the data the batch will actually execute against."""
        if not qs:
            return []
        if not self._fitted:
            return [default_plan(q.n_vec, self.engine) for q in qs]
        t = self.table if cold is None else cold.table
        idxs = self.indexes if cold is None else list(cold.indexes)
        hs = self.hists if cold is None else cold.hists
        if dense is None:
            dense = scores_b is not None or not self._plan_local(
                len(qs), cold)
        if dense:
            if getattr(self, "_plan_batch_jit", None) is None:
                self._build_plan_batch_jit()
            from repro.serve.batch import compute_batch_scores
            if scores_b is None:
                scores_b = compute_batch_scores(t, qs)
        elif getattr(self, "_plan_batch_local_jit", None) is None:
            self._build_plan_batch_jit(scored=False)
        from repro.serve.batch import next_bucket
        b = len(qs)
        qpad = list(qs) + [qs[0]] * (next_bucket(b) - b)
        de = self.data_encoder
        de_args = (de.params, de.edges) if (self.cfg.use_de and de is not None) \
            else (None, None)
        from repro.vectordb import predicates
        pred_b = predicates.stack([q.predicates for q in qpad])
        qv_b = tuple(jnp.stack([q.query_vectors[i] for q in qpad])
                     for i in range(t.schema.n_vec))
        args = (
            self.rewriter.params, de_args, self.qenc._edges, hs,
            tuple(idxs), tuple(t.vectors), t.scalars,
            qv_b, pred_b,
            jnp.asarray([q.weights for q in qpad], jnp.float32),
            jnp.asarray([float(np.log(q.k)) for q in qpad], jnp.float32),
            jnp.asarray([q.recall_target for q in qpad], jnp.float32))
        codes = np.asarray(
            self._plan_batch_jit(*args, scores_b) if dense
            else self._plan_batch_local_jit(*args))
        return [self._apply_skew_guard(self.rewriter.plan_from_codes(c), q)
                for q, c in zip(qs, codes[:b])]

    def _build_plan_jit(self):
        fused = self._fused_x if getattr(self, "_fused_x", None) is not None \
            else self._build_fused_features()
        self._fused_x = fused
        rew = self.rewriter

        @jax.jit
        def plan_jit(rw_params, de_args, senc_edges, hists, indexes, vectors,
                     scalars, qs, pred, weights, logk, rec):
            x = fused(de_args, senc_edges, hists, indexes, vectors, scalars,
                      qs, pred, weights, logk, rec)  # nested jit inlines
            return rew.plan_codes(rw_params, x)

        self._plan_jit = plan_jit

    def _build_plan_batch_jit(self, scored: bool = True):
        fused = self._build_fused_features(scored=scored)
        rew = self.rewriter

        if scored:
            def one(rw_params, de_args, senc_edges, hists, indexes, vectors,
                    scalars, qs, pred, weights, logk, rec, row_scores):
                x = fused(de_args, senc_edges, hists, indexes, vectors,
                          scalars, qs, pred, weights, logk, rec, row_scores)
                return rew.plan_codes(rw_params, x)

            self._plan_batch_jit = jax.jit(jax.vmap(
                one,
                in_axes=(None, None, None, None, None, None, None,
                         0, 0, 0, 0, 0, 0)))
        else:
            def one(rw_params, de_args, senc_edges, hists, indexes, vectors,
                    scalars, qs, pred, weights, logk, rec):
                x = fused(de_args, senc_edges, hists, indexes, vectors,
                          scalars, qs, pred, weights, logk, rec)
                return rew.plan_codes(rw_params, x)

            self._plan_batch_local_jit = jax.jit(jax.vmap(
                one,
                in_axes=(None, None, None, None, None, None, None,
                         0, 0, 0, 0, 0)))

    def execute(self, q: MHQ):
        q = self.resolve_tenant(q)
        if self.tiered is not None:
            # tiered serving is snapshot-based and batch-shaped; a single
            # query rides a one-element batch against one snapshot
            return self.execute_batch([q])[0]
        ids, scores = self.executor.execute(q, self.optimize(q))
        # underfill safeguard: if the plan found fewer than k qualifying rows
        # (severe mis-prediction), escalate once to the robust default plan.
        # One transfer per result decides it (HS001: ids used to round-trip
        # the device twice more in the comparison below).
        nv = _n_valid(ids)
        if nv < q.k:
            ids2, scores2 = self.executor.execute(
                q, default_plan(q.n_vec, self.engine))
            if _n_valid(ids2) > nv:
                return ids2, scores2
        return ids, scores

    def bind_shards(self, n_shards: int = 1, *, mesh=None,
                    shard_axes=("data",)) -> "BoomHQ":
        """Serve over a SHARDED table: subsequent ``execute_batch`` calls
        plan the batch with the learned optimizer and fan each execution
        group out over contiguous table shards
        (``serve.batch.BatchedHybridExecutor.execute_batch_sharded``).
        Index-strategy groups are cost-model routed three ways: plan-driven
        per-shard IVF probing (each shard probes its own ``ShardedIVF``
        with the group's shard-legalized knobs and reranks candidate-
        locally inside the shard — the learned nprobe/max_scan finally
        operative at shard scale), the exact per-shard dense scan, or the
        plain single-device path when shards are too small to amortize the
        fan-out; filter_first groups keep the exact sharded scan. With a
        ``mesh`` the fan-out runs under shard_map over its data axes;
        without one, logical shards on the local device keep identical
        semantics. ``bind_shards()`` (defaults) restores single-shard
        serving."""
        self.n_shards = max(1, int(n_shards))
        self.shard_mesh = mesh
        self.shard_axes = shard_axes
        self._batched = None  # rebind the executor with the new shard config
        return self

    def bind_tiered(self, hot_capacity: int = 1024, *,
                    rebuild_every: int = 0,
                    finetune: bool = True) -> "BoomHQ":
        """Serve over a TIERED hot/cold table: subsequent ``insert`` calls
        append to a bounded writable hot segment (scored exactly,
        candidate-locally, as one extra merge source on every query) and
        background compaction folds full segments into the cold IVF state
        under an epoch-swapped snapshot — streaming ingest with zero
        serving pauses (``vectordb.tiered``, docs/tiered_ingest.md).
        Composes with ``bind_shards``/``bind_cost_model``: the cold tier
        keeps the existing plan-driven (possibly sharded) probing paths.
        ``rebuild_every=N`` makes every Nth compaction a full re-cluster
        (the sealing step); ``finetune`` keeps the data encoder updating on
        compacted rows. ``unbind_tiered()`` restores build-once serving."""
        from repro.vectordb.tiered import TieredTable
        self._tiered_finetune = finetune
        self.tiered = TieredTable(
            self.table, self.indexes, self.hists,
            hot_capacity=hot_capacity, rebuild_every=rebuild_every,
            finetune_cb=self._on_compaction, graphs=self.graphs)
        return self

    def unbind_tiered(self) -> "BoomHQ":
        """Back to build-once serving. The façade's table/index fields were
        kept in sync at every compaction, so the latest cold epoch stays
        the serving state; un-compacted hot rows (if any) are folded in
        through the legacy eager insert."""
        t = self.tiered
        self.tiered = None
        self._compactor = None
        if t is not None:
            snap = t.snapshot()
            for view in snap.hot_views:
                if view.count:
                    self.insert(
                        [v[: view.count] for v in view.np_vectors],
                        view.np_scalars[: view.count],
                        finetune=self._tiered_finetune)
        return self

    def _on_compaction(self, cold, first_new: int, n_new: int) -> None:
        """Compaction-thread callback (runs BEFORE the epoch publish):
        finetune the data encoder on the newly cold rows, refresh the query
        encoder, keep the façade's offline fields tracking the latest
        epoch, and PRE-WARM the post-swap jit shapes. Serving never reads
        these mutable fields (EP001) — batches in flight keep their
        snapshot."""
        if self.data_encoder is not None and self._tiered_finetune:
            self.data_encoder.update(
                cold.table, np.arange(first_new, first_new + n_new))
        if self.qenc is not None:
            self.qenc = QueryEncoder(cold.table, list(cold.indexes),
                                     cold.hists, self.data_encoder)
        self.table = cold.table
        self.indexes = list(cold.indexes)
        self.hists = cold.hists
        self.graphs = cold.graphs
        self.executor = HybridExecutor(cold.table, list(cold.indexes),
                                       self.engine, graphs=cold.graphs)
        self._prewarm_cold(cold)

    def _prewarm_cold(self, cold) -> None:
        """Compile the post-swap serving shapes BEFORE the epoch publish.

        Compaction grows the cold table, and the new row count is a new
        static shape for every serving jit (dense GEMMs, probe kernels,
        the fused batched optimizer) — the first post-swap batch used to
        pay the whole compile ladder inside its measured latency
        (benchmarks/results/data_updates.json: p99 ≈ 3× p50 with exactly
        one compaction in the window). Re-running a window of retained
        recent queries against the new cold state on THIS (compaction)
        thread populates the jit caches through the same code path serving
        will take, so the epoch bump lands on a warm engine; the built
        executor is published for the first post-swap batch to reuse."""
        qs = list(self._recent)[-max(1, self._last_batch):]
        if not qs:
            return
        from repro.serve.batch import warm_bucket_ladder
        from repro.vectordb.tiered import TieredSnapshot
        # a synthetic pre-publish snapshot of the new cold state (no hot
        # views: compaction just drained them). Warming goes through the
        # REAL serving entry so every branch the first post-swap batch can
        # take — planning, grouped execution, underfill escalation — is
        # compiled by the same code path that will serve it. The snapshot
        # also suppresses _recent re-recording (sub-batch guard).
        snap = TieredSnapshot(epoch=-1, cold=cold, hot_views=())
        warm_bucket_ladder(
            lambda batch: self.execute_batch(batch, snapshot=snap),
            qs, len(qs))

    def bind_tenants(self, column: int | str = "tenant") -> "BoomHQ":
        """Serve MULTI-TENANT: queries carrying ``MHQ.tenant_id`` are scoped
        to rows whose ``column`` equals that id. The namespace compiles to
        an implicit ``tenant == id`` conjunct folded into every DNF clause
        of the query's predicate (``predicates.fold_conjunct``) — the clause
        bucket, C-grid legalization and every kernel stay untouched.
        ``unbind_tenants()`` restores shared serving."""
        if isinstance(column, str):
            names = {sc.name: i for i, sc in
                     enumerate(self.table.schema.scalar_cols)}
            if column not in names:
                raise KeyError(f"unknown scalar column {column!r}")
            self.tenant_col = names[column]
        else:
            if not 0 <= int(column) < self.table.schema.n_scalar:
                raise IndexError(f"scalar column {column} out of range")
            self.tenant_col = int(column)
        return self

    def unbind_tenants(self) -> "BoomHQ":
        self.tenant_col = None
        return self

    def resolve_tenant(self, q: MHQ) -> MHQ:
        """Fold the query's tenant namespace into its predicate. No-op for
        untenanted queries or unbound engines; idempotent, so front-ends
        (the serving engine folds before its cache lookup) and the execute
        paths may both resolve."""
        if q.tenant_id is None or self.tenant_col is None:
            return q
        from repro.vectordb.predicates import fold_conjunct
        t = float(int(q.tenant_id))
        return dataclasses.replace(
            q, predicates=fold_conjunct(q.predicates, self.tenant_col, t, t))

    def bind_cost_model(self, cost_model=None) -> "BoomHQ":
        """Override the scoring dispatcher's cost model (a
        ``serve.batch.CostModel`` — crossover ratio and/or a forced path)
        for subsequent batched execution. ``bind_cost_model()`` restores the
        calibrated default."""
        self.cost_model = cost_model
        self._batched = None  # rebind the executor with the new model
        return self

    @property
    def _sharded(self) -> bool:
        return self.n_shards > 1 or self.shard_mesh is not None

    def execute_batch(self, queries: list[MHQ], *, snapshot=None) -> list:
        """Batched analogue of execute(): one fused optimizer dispatch for
        the whole batch, grouped vmapped execution, then one batched
        underfill-escalation pass. Returns [(ids, scores)] per query.

        Over a sharded table (``bind_shards``) execution instead fans the
        learned plans out across the shards: each index-strategy group is
        cost-model routed to per-shard IVF probing (the plans' knobs drive
        each shard's own index), the exact per-shard dense scan, or the
        single-device path, with per-shard underfill escalation inside the
        probing route and the global cross-check of
        ``_execute_batch_sharded`` on top.

        Over a TIERED table (``bind_tiered``) the whole batch executes
        against ONE immutable ``(epoch, hot_view, cold_shards)`` snapshot —
        ``snapshot`` when the batch former stamped one at cut time, else
        taken here — so an epoch swap mid-batch can never mix states: the
        cold side runs the unchanged plan-driven paths against the
        snapshot's epoch and the hot segment merges in as one extra exact
        candidate source (``_merge_hot``)."""
        if not queries:
            return []
        from repro.serve.batch import (
            MAX_BATCH_KERNEL, SLOT_BUDGET, compute_batch_scores, pow2_at_most,
        )
        queries = [self.resolve_tenant(q) for q in queries]
        if snapshot is None:  # outer call, not a size-limit sub-batch
            self._recent.extend(queries)
            self._last_batch = len(queries)
        snap = None
        if self.tiered is not None:
            snap = snapshot if snapshot is not None else \
                self.tiered.snapshot()
        cold = snap.cold if snap is not None else None
        t = self.table if cold is None else cold.table
        # bound the dense-score working set (batch · n_rows per column) the
        # same way the executor chunks do — large tables get sub-batches
        limit = pow2_at_most(max(1, min(
            MAX_BATCH_KERNEL, SLOT_BUDGET // max(t.n_rows, 1))))
        if len(queries) > limit:
            out = []
            for s in range(0, len(queries), limit):
                out.extend(self.execute_batch(queries[s: s + limit],
                                              snapshot=snap))
            return out
        # past the dense crossover the (B, n) similarity matrices are never
        # built: planning runs the unscored pre-probe pipeline and execution
        # groups gather only their candidate budgets (per-group dispatch can
        # still fall back to a per-chunk GEMM when a group wants dense)
        plan_local = self._plan_local(len(queries), cold)
        scores_b = None if plan_local \
            else compute_batch_scores(t, queries)
        bx = self._batched_executor(cold)
        if self._sharded:
            results = self._execute_batch_sharded(queries, bx, scores_b,
                                                  cold=cold)
        else:
            plans = self.optimize_batch(queries, scores_b=scores_b,
                                        dense=not plan_local, cold=cold)
            results = bx.execute_batch(queries, plans, scores_b=scores_b)

            under = [j for j, (ids, _) in enumerate(results)
                     if _n_valid(ids) < queries[j].k]
            if under:
                sub = np.asarray(under)
                retry = bx.execute_batch(
                    [queries[j] for j in under],
                    [default_plan(queries[j].n_vec, self.engine)
                     for j in under],
                    scores_b=tuple(s[sub] for s in scores_b)
                    if scores_b is not None else None)
                for j, (ids2, s2) in zip(under, retry):
                    if _n_valid(ids2) > _n_valid(results[j][0]):
                        results[j] = (ids2, s2)
        if snap is not None and snap.hot_views:
            results = self._merge_hot(results, queries, snap)
        return results

    def _merge_hot(self, results, queries: list[MHQ], snap) -> list:
        """Fold the snapshot's hot views into the cold results: ONE fused
        exact gather-score over each bounded hot view plus ONE pass of the
        existing O(shards·k) dedup merge (``merge_topk_unique``) — the hot
        segment is just one more candidate source, with globally disjoint
        row ids, so escalation and recall contracts survive unchanged. An
        empty hot segment never reaches here (bit-for-bit cold parity)."""
        from repro.kernels.shapes import NEG
        from repro.serve.batch import K_BUCKET_FLOOR, next_bucket
        from repro.vectordb import predicates, tiered
        b = len(queries)
        k_pad = next_bucket(max(K_BUCKET_FLOOR,
                                max(q.k for q in queries)))
        b_pad = next_bucket(b)
        qpad = list(queries) + [queries[0]] * (b_pad - b)
        n_vec = snap.cold.table.schema.n_vec
        pred_b = predicates.stack([q.predicates for q in qpad])
        qv_b = tuple(jnp.stack([q.query_vectors[i] for q in qpad])
                     for i in range(n_vec))
        w_b = jnp.asarray([q.weights for q in qpad], jnp.float32)
        ids_np = [np.asarray(r[0], np.int32).ravel() for r in results]
        sc_np = [np.asarray(r[1], np.float32).ravel() for r in results]
        cold_ids = np.full((b_pad, k_pad), -1, np.int32)
        cold_scores = np.full((b_pad, k_pad), np.float32(NEG), np.float32)
        for j in range(b):
            kk = min(ids_np[j].shape[0], k_pad)
            cold_ids[j, :kk] = ids_np[j][:kk]
            cold_scores[j, :kk] = sc_np[j][:kk]
        views = tuple(tiered.view_args(v) for v in snap.hot_views)
        m_ids, m_scores = tiered.merge_hot_batch(
            jnp.asarray(cold_ids), jnp.asarray(cold_scores), views,
            qv_b, w_b, pred_b, k=k_pad, metric=snap.cold.table.schema.metric)
        m_ids = np.asarray(m_ids)
        m_scores = np.asarray(m_scores)
        return [(m_ids[j, : q.k], m_scores[j, : q.k])
                for j, q in enumerate(queries)]

    def _execute_batch_sharded(self, queries: list[MHQ], bx,
                               scores_b: tuple, cold=None) -> list:
        """Plan-driven cross-shard execution + underfill escalation.

        The batch is planned by the learned optimizer exactly like the
        single-shard path, then fanned out: the executor routes every
        index-strategy group through the cost model (per-shard IVF probing
        / exact per-shard dense scan / single-device), with PER-SHARD
        underfill escalation inside the probing path (exact retry only on
        the underfilled shard-subset). This global cross-check remains on
        top: any query still returning fewer than k valid ids re-runs
        through the single-shard exact filter-first (one extra grouped pass
        over only that subset) and the better-filled result wins — the
        same recall contract the single-shard learned path keeps."""
        t = self.table if cold is None else cold.table
        plans = self.optimize_batch(queries, scores_b=scores_b, cold=cold)
        results = bx.execute_batch_sharded(queries, plans,
                                           scores_b=scores_b)
        under = [j for j, (ids, _) in enumerate(results)
                 if _n_valid(ids) < queries[j].k]
        if under:
            sub = np.asarray(under)
            exact = [ExecutionPlan(
                "filter_first",
                tuple(SubqueryParams() for _ in range(queries[j].n_vec)),
                max_candidates=t.n_rows) for j in under]
            retry = bx.execute_batch(
                [queries[j] for j in under], exact,
                scores_b=tuple(s[sub] for s in scores_b)
                if scores_b is not None else None)
            for j, (ids2, s2) in zip(under, retry):
                if _n_valid(ids2) > _n_valid(results[j][0]):
                    results[j] = (ids2, s2)
        return results

    def _batched_executor(self, cold=None):
        """Executor bound to the serving state — the façade's fields, or a
        snapshot's cold epoch when one is passed. Single-slot cache keyed
        on table identity: batches execute in formation order, so an epoch
        swap rebuilds once at the first post-swap batch and never
        thrashes."""
        from repro.serve.batch import BatchedHybridExecutor
        t = self.table if cold is None else cold.table
        idxs = self.indexes if cold is None else list(cold.indexes)
        hs = self.hists if cold is None else cold.hists
        grs = self.graphs if cold is None else cold.graphs
        if getattr(self, "_batched", None) is None \
                or self._batched.table is not t:
            self._batched = BatchedHybridExecutor(
                t, idxs, self.engine,
                n_shards=self.n_shards, mesh=self.shard_mesh,
                shard_axes=getattr(self, "shard_axes", ("data",)),
                cost_model=self.cost_model, hists=hs, graphs=grs)
        return self._batched

    def execute_timed(self, q: MHQ, *, repeats: int = 1):
        """(ids, scores, seconds) — optimizer overhead INCLUDED (the paper
        counts pre-probing and inference in the measured latency)."""
        ids, scores = self.execute(q)  # warm (jit caches)
        jnp.asarray(scores).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(repeats):
            ids, scores = self.execute(q)
            jnp.asarray(scores).block_until_ready()
        dt = (time.perf_counter() - t0) / repeats
        return np.asarray(ids), np.asarray(scores), dt

    # -- updates (paper §3.2 incremental, §5.3) ---------------------------------

    def insert(self, vectors: list[np.ndarray], scalars: np.ndarray,
               *, finetune: bool = True) -> dict:
        """Data updates. Tiered (``bind_tiered``): rows append to the hot
        segment — visible to the next formed batch, exact-scored, never a
        serving pause — and compaction (background when a scheduler is
        attached, else deferred to the next ``compact()``) folds them cold,
        finetuning the encoder per ``finetune``. Untiered: the legacy eager
        path — extend indexes/histograms and rebuild the executor now."""
        if self.tiered is not None:
            self._tiered_finetune = finetune
            stats = self.tiered.insert(vectors, scalars)
            if stats["needs_compaction"] and self._compactor is not None:
                self._compactor.maybe_schedule()
            return stats
        first_new = self.table.n_rows
        self.table = self.table.append(vectors, scalars)
        self.indexes = [
            ivf.extend(idx, jnp.asarray(v, jnp.float32), first_new)
            for idx, v in zip(self.indexes, vectors)
        ]
        self.hists = histogram.update(self.hists, jnp.asarray(scalars, jnp.float32))
        if self.graphs is not None:
            # graph.extend reads the FULL post-append column (the graph
            # stores no vectors), so this must follow the table append
            self.graphs = tuple(
                graph.extend(g, v, first_new)
                for g, v in zip(self.graphs, self.table.vectors))
        self.executor = HybridExecutor(self.table, self.indexes, self.engine,
                                       graphs=self.graphs)
        self._batched = None  # rebind the batched executor to the new table
        out = {}
        if self.data_encoder is not None and finetune:
            new_rows = np.arange(first_new, self.table.n_rows)
            out = self.data_encoder.update(self.table, new_rows)
        if self.qenc is not None:
            self.qenc = QueryEncoder(self.table, self.indexes, self.hists,
                                     self.data_encoder)
        return out
