from repro.bench import augment, datasets, queries  # noqa: F401
