"""Benchmark construction (paper §4): correlated scalar/vector augmentation.

Vector → scalar (for ann-benchmark-style datasets):
  * cluster IDs        — k-means cluster of each vector (categorical);
  * hyperplane codes   — side-of-random-hyperplane bit strings (categorical);
  * reference distance — Σ distances to random reference points (continuous).

Scalar → vector (for IMDb/TPC-H-style tables): the paper embeds text columns
with language models. Offline we provide two embedders with the same key
property (vectors CORRELATED with the scalars):
  * "hash"  — deterministic random-feature projection of the scalar row
              through a fixed tanh network + Gaussian noise (fast; default);
  * "lm"    — tokens derived from the row are run through a configured
              assigned-architecture LM (repro.models.lm) and mean-pooled —
              the framework's own models as embedding producers (DESIGN §4).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# vector -> scalar
# ---------------------------------------------------------------------------

def cluster_labels(vectors: np.ndarray, n_clusters: int = 16, seed: int = 0,
                   iters: int = 8) -> np.ndarray:
    from repro.vectordb.ivf import _kmeans

    _, assign = _kmeans(jnp.asarray(vectors, jnp.float32),
                        jax.random.PRNGKey(seed), n_clusters, iters)
    return np.asarray(assign, np.float32)


def hyperplane_codes(vectors: np.ndarray, n_planes: int = 4, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    planes = rng.normal(size=(vectors.shape[1], n_planes)).astype(np.float32)
    bits = (vectors @ planes > 0).astype(np.int64)
    code = np.zeros(vectors.shape[0], np.int64)
    for j in range(n_planes):
        code = code * 2 + bits[:, j]
    return code.astype(np.float32)


def refpoint_distance_sum(vectors: np.ndarray, n_refs: int = 4, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed + 7)
    lo, hi = vectors.min(axis=0), vectors.max(axis=0)
    refs = rng.uniform(lo, hi, size=(n_refs, vectors.shape[1])).astype(np.float32)
    d = np.sqrt(((vectors[:, None, :] - refs[None]) ** 2).sum(-1))
    return d.sum(axis=1).astype(np.float32)


def augment_with_scalars(vectors: np.ndarray, *, n_clusters: int = 16,
                         n_planes: int = 4, n_refs: int = 4, seed: int = 0):
    """-> (scalars (n, 3), column specs) via the three §4 constructions."""
    from repro.vectordb.table import ScalarCol

    cols = [
        ScalarCol("cluster_id", "cat", n_clusters),
        ScalarCol("hplane_code", "cat", 2 ** n_planes),
        ScalarCol("ref_dist_sum", "num"),
    ]
    scalars = np.stack([
        cluster_labels(vectors, n_clusters, seed),
        hyperplane_codes(vectors, n_planes, seed),
        refpoint_distance_sum(vectors, n_refs, seed),
    ], axis=1)
    return scalars, cols


# ---------------------------------------------------------------------------
# scalar -> vector
# ---------------------------------------------------------------------------

def hash_embed(scalars: np.ndarray, dim: int, *, seed: int = 0,
               noise: float = 0.25) -> np.ndarray:
    """Deterministic 'semantic' embedding of scalar rows: a fixed random
    2-layer tanh feature map + noise, L2-normalized. Nearby scalar rows map
    to nearby vectors — the correlation §4 requires."""
    rng = np.random.default_rng(seed)
    m = scalars.shape[1]
    mu, sd = scalars.mean(axis=0), scalars.std(axis=0) + 1e-6
    z = (scalars - mu) / sd
    w1 = rng.normal(size=(m, 4 * m + 8)).astype(np.float32)
    w2 = rng.normal(size=(4 * m + 8, dim)).astype(np.float32) / np.sqrt(4 * m + 8)
    h = np.tanh(z @ w1)
    v = np.tanh(h @ w2) + noise * rng.normal(size=(len(scalars), dim))
    v = v.astype(np.float32)
    return v / (np.linalg.norm(v, axis=1, keepdims=True) + 1e-9)


def lm_embed(scalars: np.ndarray, dim: int, *, arch: str = "stablelm-1.6b",
             smoke: bool = True, seed: int = 0, seq: int = 16,
             batch: int = 256) -> np.ndarray:
    """Embed rows with one of the assigned-architecture LMs: rows are hashed
    to token sequences, run through ``lm.hidden``, mean-pooled, projected."""
    from repro import configs
    from repro.models import lm

    cfg = configs.get_config(arch, smoke=smoke)
    params = lm.init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    # deterministic row -> token hash
    mu, sd = scalars.mean(axis=0), scalars.std(axis=0) + 1e-6
    z = ((scalars - mu) / sd * 37.0).astype(np.int64)
    toks = np.zeros((len(scalars), seq), np.int64)
    for j in range(seq):
        toks = toks * 31 + np.roll(z, j, axis=1).sum(axis=1, keepdims=True) + j
        toks[:, j] = np.abs(toks[:, j]) % cfg.vocab
    proj = rng.normal(size=(cfg.d_model, dim)).astype(np.float32) / np.sqrt(cfg.d_model)

    @jax.jit
    def embed(tok_batch):
        h, _ = lm.hidden(params, cfg, {"tokens": tok_batch})
        return jnp.mean(h, axis=1) @ proj

    outs = []
    for i in range(0, len(scalars), batch):
        outs.append(np.asarray(embed(jnp.asarray(toks[i:i + batch], jnp.int32))))
    v = np.concatenate(outs).astype(np.float32)
    return v / (np.linalg.norm(v, axis=1, keepdims=True) + 1e-9)
