"""Query workload generation (paper §4 'Query Generation').

Per table: hybrid queries with (a) query vectors uniformly sampled within
each dimension's data range, (b) predicates over a random subset of scalar
columns (equality for categoricals, ranges for numerics), with (c) the
SELECTIVITY of the predicate set stratified ~uniformly over [0, 1] by
oversample-then-flatten (the paper regenerates queries when a selectivity
sub-interval overfills), and (d) w₁ ~ U[0,1], w₂ = 1 − w₁ for two-vector
MHQs.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.query import MHQ
from repro.vectordb.predicates import Predicates, eval_mask
from repro.vectordb.table import Table


def _random_predicates(table: Table, rng) -> Predicates:
    m = table.schema.n_scalar
    scal = np.asarray(table.scalars)
    n_active = rng.integers(1, m + 1)
    cols = rng.choice(m, size=n_active, replace=False)
    conds = {}
    for c in cols:
        col = table.schema.scalar_cols[c]
        if col.kind == "cat":
            v = float(rng.choice(scal[:, c]))
            conds[int(c)] = (v, v)  # equality
        else:
            lo, hi = scal[:, c].min(), scal[:, c].max()
            a, b = sorted(rng.uniform(lo, hi, size=2))
            kind = rng.integers(0, 3)
            if kind == 0:
                conds[int(c)] = (float(a), float(b))  # closed range
            elif kind == 1:
                conds[int(c)] = (-np.inf, float(b))  # x < b
            else:
                conds[int(c)] = (float(a), np.inf)  # x > a
    return Predicates.from_conditions(m, conds)


def _query_vectors(table: Table, rng) -> tuple:
    qs = []
    for i, vcol in enumerate(table.schema.vector_cols):
        v = np.asarray(table.vectors[i])
        lo, hi = v.min(axis=0), v.max(axis=0)
        qs.append(jnp.asarray(rng.uniform(lo, hi).astype(np.float32)))
    return tuple(qs)


def gen_workload(table: Table, n_queries: int, *, n_vec_used: int = 1,
                 k: int = 10, recall_target: float = 0.9, seed: int = 0,
                 stratify_bins: int = 10, oversample: int = 6) -> list[MHQ]:
    """Selectivity-stratified workload. ``n_vec_used`` ∈ {1, 2}."""
    rng = np.random.default_rng(seed)
    n_vec = table.schema.n_vec
    pool = []
    for _ in range(n_queries * oversample):
        pred = _random_predicates(table, rng)
        sel = float(jnp.mean(eval_mask(pred, table.scalars)))
        pool.append((sel, pred))
    # flatten the selectivity histogram (paper: uniform over sub-intervals)
    bins = [[] for _ in range(stratify_bins)]
    for sel, pred in pool:
        b = min(int(sel * stratify_bins), stratify_bins - 1)
        bins[b].append((sel, pred))
    cap = max(1, n_queries // stratify_bins)
    chosen, chosen_ids = [], set()
    for b in bins:
        for item in b[:cap]:
            chosen.append(item)
            chosen_ids.add(id(item))
    for b in bins:  # round-robin fill from the remainder
        for item in b[cap:]:
            if len(chosen) >= n_queries:
                break
            if id(item) not in chosen_ids:
                chosen.append(item)
                chosen_ids.add(id(item))
    chosen = chosen[:n_queries]

    out = []
    for sel, pred in chosen:
        qs = _query_vectors(table, rng)
        if n_vec_used == 1 or n_vec == 1:
            weights = tuple(1.0 if i == 0 else 0.0 for i in range(n_vec))
        else:
            w1 = float(rng.uniform(0.0, 1.0))
            weights = (w1, 1.0 - w1) + tuple(0.0 for _ in range(n_vec - 2))
        out.append(MHQ(query_vectors=qs, weights=weights, predicates=pred,
                       k=k, recall_target=recall_target))
    return out


def workload_selectivities(table: Table, workload) -> np.ndarray:
    return np.asarray([
        float(jnp.mean(eval_mask(q.predicates, table.scalars))) for q in workload
    ])
