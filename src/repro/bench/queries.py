"""Query workload generation (paper §4 'Query Generation').

Per table: hybrid queries with (a) query vectors uniformly sampled within
each dimension's data range, (b) predicates over a random subset of scalar
columns (equality for categoricals, ranges for numerics), with (c) the
SELECTIVITY of the predicate set stratified ~uniformly over [0, 1] by
oversample-then-flatten (the paper regenerates queries when a selectivity
sub-interval overfills), and (d) w₁ ~ U[0,1], w₂ = 1 − w₁ for two-vector
MHQs.

``gen_dnf_workload`` extends the generator past single conjunctions: it
emits OR-of-ranges and IN-list predicates through the builder algebra
(:mod:`repro.vectordb.algebra`) with a controllable DNF clause count, then
applies the same selectivity stratification — the workload CHASE-style
hybrid planners are stressed with.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.query import MHQ
from repro.vectordb.algebra import col
from repro.vectordb.predicates import Predicates, eval_mask
from repro.vectordb.table import Table


def _random_predicates(table: Table, rng) -> Predicates:
    m = table.schema.n_scalar
    scal = np.asarray(table.scalars)
    n_active = rng.integers(1, m + 1)
    cols = rng.choice(m, size=n_active, replace=False)
    conds = {}
    for c in cols:
        column = table.schema.scalar_cols[c]
        if column.kind == "cat":
            v = float(rng.choice(scal[:, c]))
            conds[int(c)] = (v, v)  # equality
        else:
            lo, hi = scal[:, c].min(), scal[:, c].max()
            a, b = sorted(rng.uniform(lo, hi, size=2))
            kind = rng.integers(0, 3)
            if kind == 0:
                conds[int(c)] = (float(a), float(b))  # closed range
            elif kind == 1:
                conds[int(c)] = (-np.inf, float(b))  # x < b
            else:
                conds[int(c)] = (float(a), np.inf)  # x > a
    return Predicates.from_conditions(m, conds)


def _random_range(scal, c, rng):
    lo, hi = scal[:, c].min(), scal[:, c].max()
    a, b = sorted(rng.uniform(lo, hi, size=2))
    return col(int(c)).between(float(a), float(b))


def _random_dnf_expr(table: Table, rng, *, n_clauses: int):
    """A random builder expression whose DNF has ~``n_clauses`` clauses.

    Shapes drawn (mirroring the disjunctive/IN-list workloads of the
    filtered-ANN literature):
      * IN-list on a categorical column (one clause per member),
      * OR of ``n_clauses`` numeric ranges (same or different columns),
      * (IN-list ∧ range): the range merges into every clause,
      * NOT of a range (complement → up to 2 clauses).
    Each optionally AND-ed with one extra conjunctive range condition.
    """
    m = table.schema.n_scalar
    scal = np.asarray(table.scalars)
    cats = [i for i in range(m) if table.schema.scalar_cols[i].kind == "cat"]
    nums = [i for i in range(m) if table.schema.scalar_cols[i].kind == "num"]

    def in_list(size):
        c = int(rng.choice(cats))
        vals = np.unique(scal[:, c])
        pick = rng.choice(vals, size=min(size, len(vals)), replace=False)
        return col(c).isin([float(v) for v in pick])

    shape = rng.integers(0, 4)
    if shape == 0 and cats:  # plain IN-list
        expr = in_list(n_clauses)
    elif shape == 1 and nums:  # OR of ranges
        parts = [_random_range(scal, int(rng.choice(nums)), rng)
                 for _ in range(n_clauses)]
        expr = parts[0]
        for p in parts[1:]:
            expr = expr | p
    elif shape == 2 and cats and nums:  # IN-list ∧ range (clauses preserved)
        expr = in_list(n_clauses) & _random_range(scal, int(rng.choice(nums)), rng)
    else:  # NOT of a range (≤ 2 clauses), widened toward n_clauses by ORs
        c = int(rng.choice(nums)) if nums else 0
        expr = ~_random_range(scal, c, rng)
        if n_clauses > 2 and nums:
            expr = expr | _random_range(scal, int(rng.choice(nums)), rng)
    if rng.random() < 0.5 and nums:  # extra conjunct: intersects every clause
        expr = expr & _random_range(scal, int(rng.choice(nums)), rng)
    return expr


def _query_vectors(table: Table, rng) -> tuple:
    qs = []
    for i, vcol in enumerate(table.schema.vector_cols):
        v = np.asarray(table.vectors[i])
        lo, hi = v.min(axis=0), v.max(axis=0)
        qs.append(jnp.asarray(rng.uniform(lo, hi).astype(np.float32)))
    return tuple(qs)


def _stratify(pool: list, n_queries: int, stratify_bins: int) -> list:
    """Flatten the selectivity histogram of (sel, pred) pairs (paper:
    uniform over sub-intervals), then round-robin fill from the rest."""
    bins = [[] for _ in range(stratify_bins)]
    for sel, pred in pool:
        b = min(int(sel * stratify_bins), stratify_bins - 1)
        bins[b].append((sel, pred))
    cap = max(1, n_queries // stratify_bins)
    chosen, chosen_ids = [], set()
    for b in bins:
        for item in b[:cap]:
            chosen.append(item)
            chosen_ids.add(id(item))
    for b in bins:  # round-robin fill from the remainder
        for item in b[cap:]:
            if len(chosen) >= n_queries:
                break
            if id(item) not in chosen_ids:
                chosen.append(item)
                chosen_ids.add(id(item))
    return chosen[:n_queries]


def _attach_vectors(table: Table, chosen: list, rng, *, n_vec_used: int,
                    k: int, recall_target: float) -> list[MHQ]:
    n_vec = table.schema.n_vec
    out = []
    for sel, pred in chosen:
        qs = _query_vectors(table, rng)
        if n_vec_used == 1 or n_vec == 1:
            weights = tuple(1.0 if i == 0 else 0.0 for i in range(n_vec))
        else:
            w1 = float(rng.uniform(0.0, 1.0))
            weights = (w1, 1.0 - w1) + tuple(0.0 for _ in range(n_vec - 2))
        out.append(MHQ(query_vectors=qs, weights=weights, predicates=pred,
                       k=k, recall_target=recall_target))
    return out


def gen_workload(table: Table, n_queries: int, *, n_vec_used: int = 1,
                 k: int = 10, recall_target: float = 0.9, seed: int = 0,
                 stratify_bins: int = 10, oversample: int = 6) -> list[MHQ]:
    """Selectivity-stratified conjunctive workload. ``n_vec_used`` ∈ {1, 2}."""
    rng = np.random.default_rng(seed)
    pool = []
    for _ in range(n_queries * oversample):
        pred = _random_predicates(table, rng)
        sel = float(jnp.mean(eval_mask(pred, table.scalars)))
        pool.append((sel, pred))
    chosen = _stratify(pool, n_queries, stratify_bins)
    return _attach_vectors(table, chosen, rng, n_vec_used=n_vec_used, k=k,
                           recall_target=recall_target)


def gen_dnf_workload(table: Table, n_queries: int, *, n_vec_used: int = 1,
                     k: int = 10, recall_target: float = 0.9, seed: int = 0,
                     clause_counts=(2, 3, 4), stratify_bins: int = 10,
                     oversample: int = 6) -> list[MHQ]:
    """Selectivity-stratified DNF workload (OR-of-ranges, IN-lists, NOTs).

    ``clause_counts``: target clause counts sampled per query (the compiled
    count may land lower after intersection/dedup and is then padded onto
    CLAUSE_GRID). Selectivity is measured exactly on the table and
    stratified like :func:`gen_workload`."""
    rng = np.random.default_rng(seed)
    pool = []
    while len(pool) < n_queries * oversample:
        nc = int(rng.choice(clause_counts))
        expr = _random_dnf_expr(table, rng, n_clauses=nc)
        try:
            pred = expr.compile(table.schema)
        except ValueError:  # blew the clause grid — resample
            continue
        sel = float(jnp.mean(eval_mask(pred, table.scalars)))
        pool.append((sel, pred))
    chosen = _stratify(pool, n_queries, stratify_bins)
    return _attach_vectors(table, chosen, rng, n_vec_used=n_vec_used, k=k,
                           recall_target=recall_target)


def workload_selectivities(table: Table, workload) -> np.ndarray:
    return np.asarray([
        float(jnp.mean(eval_mask(q.predicates, table.scalars))) for q in workload
    ])
