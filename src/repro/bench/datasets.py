"""The 11-dataset benchmark registry (paper Table 1), offline-reproducible.

SIFT/GloVe/Deep1B/IMDb/TPC-H are not redistributable in this environment, so
each entry ships a generator that reproduces its SHAPE (dims preserved, row
counts CLI-scalable from the paper's figures) and its CHARACTER:
  * v+s / v→s sets: Gaussian-mixture vectors (clusterable, like real
    embeddings) + the paper's three correlated-scalar constructions;
  * s→v sets: realistic scalar marginals (Zipf categoricals, lognormal
    numerics, TPC-H-style uniform prices) + correlated embeddings of the
    'semantically rich' columns (hash_embed, or the LM path in augment.py).

``make(name, rows=...)`` returns a fully-built ``Table``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.bench.augment import augment_with_scalars, hash_embed
from repro.vectordb.table import ScalarCol, Table, TableSchema, VectorCol


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    kind: str  # "v+s" | "v->s" | "s->v"
    paper_rows: int
    dims: tuple  # one entry per vector column
    n_vec_queries: int = 1  # 2 for Part / Aka_title (multi-vector MHQs)


SPECS: dict[str, DatasetSpec] = {
    "fungis": DatasetSpec("fungis", "v+s", 295_938, (768,)),
    "sift": DatasetSpec("sift", "v->s", 1_000_000, (128,)),
    "glove": DatasetSpec("glove", "v->s", 1_183_514, (100,)),
    "deep1b": DatasetSpec("deep1b", "v->s", 9_990_000, (96,)),
    "aka_title": DatasetSpec("aka_title", "s->v", 361_472, (768, 768), 2),
    "title": DatasetSpec("title", "s->v", 2_528_312, (768,)),
    "aka_name": DatasetSpec("aka_name", "s->v", 901_343, (768,)),
    "part": DatasetSpec("part", "s->v", 200_000, (768, 768), 2),
    "partsupp": DatasetSpec("partsupp", "s->v", 800_000, (768,)),
    "orders": DatasetSpec("orders", "s->v", 1_500_000, (768,)),
    "lineitem": DatasetSpec("lineitem", "s->v", 6_000_000, (768,)),
}


def _mixture_vectors(n: int, dim: int, *, n_comp: int = 24, seed: int = 0,
                     spread: float = 0.35) -> np.ndarray:
    rng = np.random.default_rng(seed)
    mus = rng.normal(size=(n_comp, dim)).astype(np.float32)
    comp = rng.integers(0, n_comp, n)
    v = mus[comp] + spread * rng.normal(size=(n, dim)).astype(np.float32)
    return v.astype(np.float32)


def _scalar_table(n: int, seed: int) -> tuple[np.ndarray, list[ScalarCol]]:
    """TPC-H/IMDb-flavoured scalar columns: two Zipf categoricals, a
    lognormal 'size' and a uniform 'price'."""
    rng = np.random.default_rng(seed)
    cat1 = np.minimum(rng.zipf(1.5, n) - 1, 24).astype(np.float32)
    cat2 = np.minimum(rng.zipf(1.3, n) - 1, 49).astype(np.float32)
    size = rng.lognormal(1.0, 0.6, n).astype(np.float32)
    price = rng.uniform(1.0, 1000.0, n).astype(np.float32)
    cols = [ScalarCol("category", "cat", 25), ScalarCol("brand", "cat", 50),
            ScalarCol("size", "num"), ScalarCol("price", "num")]
    return np.stack([cat1, cat2, size, price], axis=1), cols


def make(name: str, *, rows: int = 20_000, seed: int = 0,
         metric: str = "dot") -> Table:
    spec = SPECS[name]
    n = min(rows, spec.paper_rows)
    if spec.kind in ("v+s", "v->s"):
        vectors = [_mixture_vectors(n, d, seed=seed + i)
                   for i, d in enumerate(spec.dims)]
        scalars, cols = augment_with_scalars(vectors[0], seed=seed)
        if spec.kind == "v+s":  # fungis: extra native metadata column
            rng = np.random.default_rng(seed + 3)
            extra = (scalars[:, 0] * 2.0 + rng.normal(0, 1.0, n)).astype(np.float32)
            scalars = np.concatenate([scalars, extra[:, None]], axis=1)
            cols = cols + [ScalarCol("obs_count", "num")]
    else:  # s->v
        scalars, cols = _scalar_table(n, seed)
        vectors = [hash_embed(scalars, d, seed=seed + 11 * (i + 1),
                              noise=0.25 + 0.1 * i)
                   for i, d in enumerate(spec.dims)]
    schema = TableSchema(
        vector_cols=tuple(VectorCol(f"vec{i}", d) for i, d in enumerate(spec.dims)),
        scalar_cols=tuple(cols),
        metric=metric,
    )
    return Table.from_numpy(schema, vectors, scalars)


def table_row(name: str) -> dict:
    s = SPECS[name]
    return {"Benchmark": name, "Type": s.kind, "Rows": s.paper_rows,
            "Dimension": "/".join(str(d) for d in s.dims)}
