"""Error-feedback gradient compression for cross-pod data parallelism.

At pod scale the gradient all-reduce crosses the (slow) inter-pod links.
We compress each gradient tensor to int8 with a per-tensor scale before the
cross-pod reduction and carry the quantization error in an fp32 residual
(error feedback, à la 1-bit Adam / EF-SGD), which keeps SGD convergence
unbiased in the long run.

Two entry points:
  * ``compress``/``decompress`` — the quantizer itself (unit-testable).
  * ``ef_allreduce`` — shard_map-compatible: quantize -> psum over the given
    axis -> dequantize, with residual update. Inside pjit'd code the psum is
    whatever collective XLA chooses for the mesh axis.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def compress(x: jax.Array):
    """Per-tensor absmax int8 quantization. Returns (q, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads: PyTree, residual: PyTree):
    """Quantize grads+residual; return (quantized tree, new residual)."""

    def _one(g, r):
        val = g.astype(jnp.float32) + r
        q, s = compress(val)
        back = decompress(q, s)
        return (q, s), val - back

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [_one(g, r) for g, r in zip(flat_g, flat_r)]
    qtree = treedef.unflatten([o[0] for o in outs])
    new_res = treedef.unflatten([o[1] for o in outs])
    return qtree, new_res


def decompress_tree(qtree: PyTree, dtype=jnp.float32) -> PyTree:
    return jax.tree.map(
        lambda leaf: decompress(*leaf, dtype=dtype),
        qtree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
    )


def ef_allreduce(grads: PyTree, residual: PyTree, axis_name: str, *, mean=True):
    """Error-feedback compressed all-reduce over ``axis_name`` (shard_map ctx)."""
    qtree, new_res = compress_tree(grads, residual)

    def _reduce(leaf):
        q, s = leaf
        # reduce in f32 to avoid int overflow across many participants
        summed = jax.lax.psum(decompress(q, s), axis_name)
        if mean:
            summed = summed / jax.lax.psum(1.0, axis_name)
        return summed

    reduced = jax.tree.map(
        _reduce, qtree, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
    )
    return reduced, new_res


def init_residual(grads_shape: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)
