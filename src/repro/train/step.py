"""Training-step builder: remat + microbatch gradient accumulation + optimizer.

``make_train_step`` returns a pure ``(params, opt_state, batch) -> (params,
opt_state, metrics)`` suitable for ``jax.jit`` with in/out shardings. The
microbatch loop is a ``lax.scan`` so grad-accumulation buffers inherit the
parameter sharding (ZeRO-sharded accumulation when FSDP is on).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.train.optimizer import (
    AdafactorConfig, AdamWConfig, adafactor_init, adafactor_update,
    adamw_init, adamw_update, cosine_schedule,
)


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    """Per-arch execution plan (launch/plans.py owns the per-arch table)."""
    microbatches: int = 1
    remat: bool = True
    optimizer: str = "adamw"  # "adamw" | "adafactor"
    state_dtype: str = "float32"  # adamw moment dtype ("int8" = 8-bit adam)
    param_dtype: str = "float32"
    lr: float = 3e-4
    weight_decay: float = 0.1
    warmup: int = 100
    total_steps: int = 10_000
    grad_clip: Optional[float] = 1.0
    fsdp: bool = False  # shard big weights over data too (ZeRO-3)
    seq_shard_acts: bool = False  # SP: shard the residual carry over `model`
    grad_accum_dtype: str = "float32"


def _opt(plan: TrainPlan):
    sched = cosine_schedule(plan.lr, plan.warmup, plan.total_steps)
    if plan.optimizer == "adafactor":
        cfg = AdafactorConfig(lr=sched, weight_decay=plan.weight_decay)
        return cfg, adafactor_init, adafactor_update
    cfg = AdamWConfig(lr=sched, weight_decay=plan.weight_decay,
                      grad_clip_norm=plan.grad_clip, state_dtype=plan.state_dtype)
    return cfg, adamw_init, adamw_update


def init_state(key, cfg: ModelConfig, plan: TrainPlan):
    """(params, opt_state) — traceable (usable under jax.eval_shape)."""
    params = lm.init(key, cfg)
    if plan.param_dtype != "float32":
        dt = jnp.dtype(plan.param_dtype)
        params = jax.tree.map(
            lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x,
            params)
    ocfg, oinit, _ = _opt(plan)
    return params, oinit(params, ocfg)


def make_train_step(cfg: ModelConfig, plan: TrainPlan, act_spec=None,
                    batch_axes=None, grad_specs=None):
    """``grad_specs`` (a PartitionSpec tree matching params) pins the
    microbatch grad-accumulation buffers to the parameter sharding —
    without it SPMD can leave the accumulator replicated and the gradient
    sync degenerates to full all-reduces instead of sharded accumulation."""
    ocfg, _, oupdate = _opt(plan)

    def loss(params, batch):
        return lm.loss_fn(params, cfg, batch, remat=plan.remat, act_spec=act_spec)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(params, opt_state, batch):
        n = plan.microbatches
        if n == 1:
            (l, metrics), grads = grad_fn(params, batch)
        else:
            bax = (tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]) \
                if batch_axes else None

            def split(x):
                b = x.shape[0]
                assert b % n == 0, (b, n)
                y = x.reshape(n, b // n, *x.shape[1:])
                if bax is not None:
                    # keep the LOOP dim unsharded; shard only the batch dim —
                    # otherwise SPMD factors the data axis across both and
                    # every device redundantly processes extra microbatches
                    from jax.sharding import PartitionSpec as P
                    y = jax.lax.with_sharding_constraint(
                        y, P(None, bax, *([None] * (x.ndim - 1))))
                return y

            mbatches = jax.tree.map(split, batch)
            acc_dt = jnp.dtype(plan.grad_accum_dtype)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            if grad_specs is not None:
                constrain = lambda t: jax.tree.map(  # noqa: E731
                    lambda a, s: jax.lax.with_sharding_constraint(a, s), t,
                    grad_specs)
                zero = constrain(zero)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(acc_dt), acc, g)
                if grad_specs is not None:
                    acc = constrain(acc)
                return acc, (l, m)

            grads, (ls, ms) = jax.lax.scan(body, zero, mbatches)
            grads = jax.tree.map(lambda g: (g / n).astype(jnp.float32), grads)
            l = jnp.mean(ls)
            metrics = jax.tree.map(jnp.mean, ms)

        new_params, new_opt = oupdate(grads, opt_state, params, ocfg)
        metrics = dict(metrics)
        metrics["loss"] = l
        return new_params, new_opt, metrics

    return train_step
