"""Optimizers in pure JAX: AdamW with optional int8-quantized moments.

The int8 state (block-wise absmax scaling, like 8-bit Adam) is a
distributed-optimization feature: it cuts optimizer-state HBM from 8 to 2
bytes/param, which is what lets the 671B/1T MoE configs fit a single
16GB-HBM v5e pod (see EXPERIMENTS.md §Dry-run memory table).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.common import pytree

PyTree = Any

_QBLOCK = 256  # elements per quantization block


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"  # "float32" | "bfloat16" | "int8"


# ---------------------------------------------------------------------------
# int8 block-quantized tensors
# ---------------------------------------------------------------------------

def _quantize_i8(x: jax.Array) -> dict:
    """Per-row (last-dim) absmax int8 quantization.

    STRUCTURE-PRESERVING on purpose: ``q`` keeps the parameter's exact shape
    (int8) and ``scale`` is (..., 1), so both inherit the parameter's
    PartitionSpec unchanged and the dequantize fuses elementwise into the
    update — a flat block layout forces resharding/replication of f32
    moment temporaries (observed: +30 GiB/device on the 7B dense cells)."""
    if x.ndim == 0:
        scale = jnp.maximum(jnp.abs(x), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32)}
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequantize_i8(qt: dict, shape, dtype=jnp.float32) -> jax.Array:
    x = qt["q"].astype(jnp.float32) * qt["scale"]
    return x.reshape(shape).astype(dtype)


def _make_moment(x: jax.Array, state_dtype: str):
    if state_dtype == "int8":
        return _quantize_i8(jnp.zeros_like(x, dtype=jnp.float32))
    return jnp.zeros(x.shape, jnp.dtype(state_dtype))


def _read_moment(m, shape, state_dtype: str) -> jax.Array:
    if state_dtype == "int8":
        return _dequantize_i8(m, shape)
    return m.astype(jnp.float32)


def _write_moment(val: jax.Array, state_dtype: str):
    if state_dtype == "int8":
        return _quantize_i8(val)
    return val.astype(jnp.dtype(state_dtype))


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params: PyTree, cfg: AdamWConfig) -> dict:
    # "int8" quantizes the FIRST moment only; the second moment uses bf16 —
    # linear int8 zeros out small v entries and 1/sqrt(v) then explodes
    # (classic 8-bit-Adam failure; bnb solves it with nonlinear quantiles,
    # we solve it with bf16's wide exponent). 3 bytes/param total.
    mk = partial(_make_moment, state_dtype=cfg.state_dtype)
    vk = partial(_make_moment,
                 state_dtype="bfloat16" if cfg.state_dtype == "int8"
                 else cfg.state_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(mk, params),
        "v": jax.tree.map(vk, params),
    }


def adamw_update(grads: PyTree, state: dict, params: PyTree, cfg: AdamWConfig):
    """Returns (new_params, new_state). Grad clip + decoupled weight decay."""
    step = state["step"] + 1
    if cfg.grad_clip_norm is not None:
        gnorm = pytree.global_norm(grads)
        clip = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * clip, grads)

    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    v_dtype = "bfloat16" if cfg.state_dtype == "int8" else cfg.state_dtype

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = _read_moment(m, g.shape, cfg.state_dtype)
        v32 = _read_moment(v, g.shape, v_dtype)
        m32 = cfg.b1 * m32 + (1.0 - cfg.b1) * g32
        v32 = cfg.b2 * v32 + (1.0 - cfg.b2) * jnp.square(g32)
        mh = m32 / c1
        vh = v32 / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, _write_moment(m32, cfg.state_dtype), _write_moment(v32, v_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    outs = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    return new_p, {"step": step, "m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return sched


def constant_schedule(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment) — the memory saver for the MoE giants:
# optimizer state is O(rows + cols) per matrix instead of O(rows·cols),
# which is what lets deepseek-v3/kimi-k2 train states fit 16GB/chip
# (EXPERIMENTS.md §Dry-run memory table).
# ---------------------------------------------------------------------------

import dataclasses as _dc


@_dc.dataclass(frozen=True)
class AdafactorConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 1e-2
    decay: float = 0.8  # beta2_t = 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_size_to_factor: int = 64


def _factored(shape, cfg: AdafactorConfig) -> bool:
    return (len(shape) >= 2 and shape[-1] >= cfg.min_dim_size_to_factor
            and shape[-2] >= cfg.min_dim_size_to_factor)


def adafactor_init(params: PyTree, cfg: AdafactorConfig) -> dict:
    def mk(p):
        if _factored(p.shape, cfg):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col stats
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"step": jnp.zeros((), jnp.int32),
            "v": jax.tree.map(mk, params, is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(grads: PyTree, state: dict, params: PyTree, cfg: AdafactorConfig):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay)
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr

    def upd(g, v, p):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + cfg.eps
        if "vr" in v:
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            r_factor = jax.lax.rsqrt(
                vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), cfg.eps))
            c_factor = jax.lax.rsqrt(vc)
            u = g32 * r_factor[..., None] * c_factor[..., None, :]
            newv = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            u = g32 * jax.lax.rsqrt(vv)
            newv = {"v": vv}
        # update clipping by RMS
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms_u / cfg.clip_threshold)
        newp = p.astype(jnp.float32) - lr * u
        if cfg.weight_decay:
            newp = newp - lr * cfg.weight_decay * p.astype(jnp.float32)
        return newp.astype(p.dtype), newv

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_v = treedef.unflatten([o[1] for o in outs])
    return new_p, {"step": step, "v": new_v}
