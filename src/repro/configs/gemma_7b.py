"""gemma-7b [dense] — 28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000.

GeGLU, head_dim=256, tied embeddings scaled by sqrt(d_model), zero-centered
RMSNorm (1+scale). [arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, vocab=256000,
        n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, ffn_act="gelu",
        rope_theta=10000.0,
        tie_embeddings=True, embed_scale=True, zero_centered_norm=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b-smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=128, ffn_act="gelu",
        tie_embeddings=True, embed_scale=True, zero_centered_norm=True,
        dtype="float32", attn_chunk_q=16,
    )


register("gemma-7b", full, smoke)
