"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.

RoPE + SwiGLU, MHA (kv == heads), head_dim=96. [arXiv:2404.14219; unverified]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, vocab=32064,
        n_heads=32, n_kv_heads=32, head_dim=96,
        d_ff=8192, ffn_act="silu",
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, ffn_act="silu",
        dtype="float32", attn_chunk_q=16,
    )


register("phi3-mini-3.8b", full, smoke)
