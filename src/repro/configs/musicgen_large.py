"""musicgen-large [audio] — 48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048.

Decoder-only over EnCodec tokens. The codec frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings (the sum of the 4
delayed codebook embeddings); sinusoidal positions; ungated GELU FFN;
LayerNorm. Text cross-attention conditioning is omitted (stub prefix) —
noted in DESIGN.md §4. [arXiv:2306.05284; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="dense", modality="audio",
        n_layers=48, d_model=2048, vocab=2048,
        n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, ffn_act="gelu_mlp",
        norm="layernorm", norm_eps=1e-5,
        pos_embed="sinusoidal",
        inputs_are_embeds=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="dense", modality="audio",
        n_layers=2, d_model=64, vocab=64,
        n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, ffn_act="gelu_mlp",
        norm="layernorm", norm_eps=1e-5,
        pos_embed="sinusoidal", inputs_are_embeds=True,
        dtype="float32", attn_chunk_q=16,
    )


register("musicgen-large", full, smoke)
