"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) vocab=163840.

Trillion-parameter MoE: 384 experts, top-8, d_ff=2048/expert, 1 shared
expert, first layer dense (d_ff=18432). Per the assignment table this uses
plain GQA attention (head_dim=128), unlike deepseek-v3's MLA.
[arXiv:2501.kimi2; unverified — paper-table entry]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, vocab=163840,
        n_heads=64, n_kv_heads=8, head_dim=128,
        ffn_act="silu",
        n_experts=384, n_experts_per_tok=8, n_shared_experts=1,
        moe_d_ff=2048, first_k_dense=1, dense_d_ff=18432,
        rope_theta=50000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke", family="moe",
        n_layers=3, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16,
        ffn_act="silu",
        n_experts=8, n_experts_per_tok=2, n_shared_experts=1,
        moe_d_ff=32, first_k_dense=1, dense_d_ff=128,
        dtype="float32", attn_chunk_q=16,
    )


register("kimi-k2-1t-a32b", full, smoke)
