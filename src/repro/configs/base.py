"""Model/arch configuration schema + the assigned-architecture registry.

Every assigned architecture is a ``ModelConfig`` constructed by a function in
its own ``configs/<id>.py`` file (exact published hyper-parameters), plus a
``smoke()`` variant — same family/wiring, tiny widths — used by the CPU smoke
tests. The FULL configs are only ever lowered via ShapeDtypeStruct in the
dry-run (never allocated).

``ShapeSpec`` captures the assigned input-shape grid (train_4k / prefill_32k /
decode_32k / long_500k) and which step each shape lowers (train vs serve).
"""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "dense" | "moe" | "ssm" | "hybrid"
    n_layers: int
    d_model: int
    vocab: int
    modality: str = "text"  # "text" | "vlm" | "audio"

    # ---- attention ----
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0  # fraction of head_dim that rotates (stablelm: 0.25)
    attn_logit_softcap: float = 0.0
    norm: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-6
    zero_centered_norm: bool = False  # gemma-style (1 + scale)
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d_model)
    pos_embed: str = "rope"  # "rope" | "sinusoidal" | "none"
    tie_embeddings: bool = False
    attn_chunk_q: int = 512  # q-block size for the chunked attention
    # flash attention: online-softmax over kv blocks — intermediates shrink
    # from (B,H,cq,S) to (B,H,cq,ckv). §Perf hillclimb knob; the naive
    # q-chunked implementation is the recorded baseline.
    flash_attention: bool = False
    attn_chunk_kv: int = 1024

    # ---- ffn ----
    d_ff: int = 0
    ffn_act: str = "silu"  # gated: "silu"=SwiGLU, "gelu"=GeGLU; "gelu_mlp"=ungated

    # ---- MoE ----
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0
    dense_d_ff: int = 0  # d_ff of the dense prefix layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 1e-3
    # routing-group size in tokens (0 = one group per batch row). Smaller
    # groups shrink the GShard dispatch tensors (B,S,E,C) and the dispatch
    # einsum FLOPs linearly — §Perf hillclimb knob for the MoE giants.
    moe_group_tokens: int = 0
    # "einsum" = paper-faithful GShard dispatch; "sharded" = scatter-based
    # shard_map expert parallelism (§Perf B7) — requires an active mesh.
    moe_impl: str = "einsum"

    # ---- MLA (deepseek-v3) ----
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- MTP (deepseek-v3) ----
    mtp_depth: int = 0

    # ---- SSM (mamba2 / SSD) ----
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_n_groups: int = 1

    # ---- hybrid (zamba2) ----
    hybrid_attn_every: int = 0  # shared attention block after every k-th mamba layer

    # ---- modality stubs ----
    n_prefix_embeds: int = 0  # vlm: precomputed patch-embedding prefix length
    inputs_are_embeds: bool = False  # audio: precomputed frame embeddings replace tokens

    # ---- numerics ----
    dtype: str = "bfloat16"  # activation/computation dtype
    param_dtype: str = "float32"
    vocab_pad_multiple: int = 0  # pad embedding rows for TP divisibility

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        if not m:
            return self.vocab
        return -(-self.vocab // m) * m

    @property
    def qk_head_dim(self) -> int:
        if self.use_mla:
            return self.qk_nope_head_dim + self.qk_rope_head_dim
        return self.head_dim

    @property
    def v_dim(self) -> int:
        return self.v_head_dim if self.use_mla else self.head_dim

    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_n_groups * self.ssm_state

    @property
    def has_attention(self) -> bool:
        return self.family in ("dense", "moe") or self.hybrid_attn_every > 0

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d = self.d_model
        n = 0
        if not self.inputs_are_embeds:
            n += self.vocab * d
        if not self.tie_embeddings:
            n += self.vocab * d
        if self.family in ("dense", "moe"):
            for layer in range(self.n_layers):
                n += self._attn_params()
                n += self._ffn_params(layer)
                n += 2 * d  # 2 norms (scale only; bias ignored for estimate)
        elif self.family == "ssm":
            n += self.n_layers * (self._mamba_params() + d)
        elif self.family == "hybrid":
            n += self.n_layers * (self._mamba_params() + d)
            if self.hybrid_attn_every:
                n += self._attn_params() + self._dense_ffn_params(self.d_ff) + 2 * d
        n += d  # final norm
        if self.mtp_depth:
            n += self.mtp_depth * (self._attn_params() + self._ffn_params(self.n_layers - 1)
                                   + 2 * d * self.d_model + 4 * d)
        return n

    def n_active_params(self) -> int:
        """Per-token active parameters (= n_params for non-MoE)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        for layer in range(self.n_layers):
            n += self._attn_params() + 2 * d
            if layer < self.first_k_dense:
                n += self._dense_ffn_params(self.dense_d_ff)
            else:
                n += self.n_experts_per_tok * self._dense_ffn_params(self.moe_d_ff)
                n += self.n_shared_experts * self._dense_ffn_params(self.moe_d_ff)
                n += d * self.n_experts  # router
        n += d
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.use_mla:
            qk, v = self.qk_head_dim, self.v_head_dim
            n = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qk
            n += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            n += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + v)
            n += self.n_heads * v * d
            n += self.q_lora_rank + self.kv_lora_rank  # lora norms
            return n
        hd = self.head_dim
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

    def _dense_ffn_params(self, f: int) -> int:
        gated = self.ffn_act in ("silu", "gelu")
        return (3 if gated else 2) * self.d_model * f

    def _ffn_params(self, layer: int) -> int:
        if self.family == "moe" and layer >= self.first_k_dense:
            n = self.n_experts * self._dense_ffn_params(self.moe_d_ff)
            n += self.n_shared_experts * self._dense_ffn_params(self.moe_d_ff)
            n += self.d_model * self.n_experts
            return n
        f = self.dense_d_ff if (self.family == "moe" and self.dense_d_ff) else self.d_ff
        return self._dense_ffn_params(f)

    def _mamba_params(self) -> int:
        d, di = self.d_model, self.d_inner
        gn = self.ssm_n_groups * self.ssm_state
        n = d * (2 * di + 2 * gn + self.ssm_heads)  # in_proj
        n += self.ssm_conv * self.conv_dim + self.conv_dim  # conv1d
        n += 3 * self.ssm_heads  # A_log, D, dt_bias
        n += di  # gated norm
        n += di * d  # out_proj
        return n


# ---------------------------------------------------------------------------
# input-shape grid (assigned shapes; identical for every LM arch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(applicable, reason-if-not). long_500k needs sub-quadratic attention."""
    if shape == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "full quadratic attention — long_500k skipped (DESIGN.md §4)"
    return True, ""


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, dict[str, Callable[[], ModelConfig]]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]):
    _REGISTRY[arch_id] = {"full": full, "smoke": smoke}


def get_config(arch_id: str, *, smoke: bool = False) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]["smoke" if smoke else "full"]()


def list_archs() -> list[str]:
    return sorted(_REGISTRY)
