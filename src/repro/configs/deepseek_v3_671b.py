"""deepseek-v3-671b [moe] — 61L d_model=7168 128H MLA vocab=129280.

MLA (q_lora=1536, kv_lora=512, rope=64 + nope=128, v=128); 1 shared + 256
routed experts top-8 (d_ff=2048/expert); first 3 layers dense (d_ff=18432);
MTP head depth 1. Decode caches the compressed (c_kv, k_rope) stream only.
[arXiv:2412.19437; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, vocab=129280,
        n_heads=128,
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        ffn_act="silu",
        n_experts=256, n_experts_per_tok=8, n_shared_experts=1,
        moe_d_ff=2048, first_k_dense=3, dense_d_ff=18432,
        mtp_depth=1,
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe",
        n_layers=3, d_model=64, vocab=256,
        n_heads=4,
        use_mla=True, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        ffn_act="silu",
        n_experts=8, n_experts_per_tok=2, n_shared_experts=1,
        moe_d_ff=32, first_k_dense=1, dense_d_ff=128,
        mtp_depth=1,
        dtype="float32", attn_chunk_q=16,
    )


register("deepseek-v3-671b", full, smoke)
