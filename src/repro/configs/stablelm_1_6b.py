"""stablelm-1.6b [dense] — 24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.

LayerNorm, partial rotary (25% of head_dim), SwiGLU, tied embeddings.
[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense",
        n_layers=24, d_model=2048, vocab=100352,
        n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=5632, ffn_act="silu",
        norm="layernorm", norm_eps=1e-5,
        rotary_pct=0.25, rope_theta=10000.0,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, ffn_act="silu",
        norm="layernorm", norm_eps=1e-5, rotary_pct=0.25,
        tie_embeddings=True,
        dtype="float32", attn_chunk_q=16,
    )


register("stablelm-1.6b", full, smoke)
