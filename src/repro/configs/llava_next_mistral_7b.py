"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

Mistral-7B backbone; the anyres vision tower is a STUB — ``input_specs()``
feeds 576 precomputed patch embeddings (one base 24×24 CLIP grid) which are
projected and prepended to the token embeddings (DESIGN.md §4).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.configs.base import ModelConfig, register

N_PATCHES = 576


def full() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="dense", modality="vlm",
        n_layers=32, d_model=4096, vocab=32000,
        n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, ffn_act="silu",
        rope_theta=1_000_000.0,
        n_prefix_embeds=N_PATCHES,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava-next-smoke", family="dense", modality="vlm",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, ffn_act="silu",
        n_prefix_embeds=8,
        dtype="float32", attn_chunk_q=16,
    )


register("llava-next-mistral-7b", full, smoke)
