"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240, ssm_state=64.

Mamba2 backbone with one SHARED attention+FFN block applied after every 6th
mamba layer (shared weights; the per-invocation LoRA deltas of the released
model are dropped — simplification noted in DESIGN.md §4). Sub-quadratic ⇒
runs long_500k. [arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, vocab=32000,
        n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=10240, ffn_act="gelu",
        ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
        ssm_chunk=128, ssm_n_groups=1,
        hybrid_attn_every=6,
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, ffn_act="gelu",
        ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=32,
        ssm_chunk=16, ssm_n_groups=1,
        hybrid_attn_every=2,
        dtype="float32", attn_chunk_q=16,
    )


register("zamba2-2.7b", full, smoke)
