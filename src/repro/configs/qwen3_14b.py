"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.

Per-head RMS qk-norm, SwiGLU, head_dim=128. [hf:Qwen/Qwen3-14B; hf]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        n_layers=40, d_model=5120, vocab=151936,
        n_heads=40, n_kv_heads=8, head_dim=128, qk_norm=True,
        d_ff=17408, ffn_act="silu",
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16, qk_norm=True,
        d_ff=128, ffn_act="silu",
        dtype="float32", attn_chunk_q=16,
    )


register("qwen3-14b", full, smoke)
