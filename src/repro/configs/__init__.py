"""Architecture registry — importing this package registers every assigned arch."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeSpec, SHAPES, get_config, list_archs, register,
    shape_applicable,
)

# one module per assigned architecture (registration happens at import)
from repro.configs import (  # noqa: F401
    gemma_7b,
    qwen3_14b,
    phi3_mini_3_8b,
    stablelm_1_6b,
    llava_next_mistral_7b,
    musicgen_large,
    zamba2_2_7b,
    kimi_k2_1t_a32b,
    deepseek_v3_671b,
    mamba2_370m,
)

ARCHS = list_archs()
