"""mamba2-370m [ssm] — 48L d_model=1024 (attention-free) vocab=50280, ssm_state=128.

SSD (state-space duality) chunked scan; d_inner = 2×1024 = 2048, head_dim 64
⇒ 32 SSM heads. Tied embeddings. Runs long_500k (O(1)/token decode).
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, vocab=50280,
        ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
        ssm_chunk=256, ssm_n_groups=1,
        tie_embeddings=True, pos_embed="none",
        # 50280 is not divisible by the 16-way model axis; pad the embedding
        # rows to 50288 (= 16·3143) — the padded logits are masked in the loss.
        vocab_pad_multiple=16,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, vocab=256,
        ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_head_dim=32,
        ssm_chunk=16, ssm_n_groups=1,
        tie_embeddings=True, pos_embed="none",
        dtype="float32",
    )


register("mamba2-370m", full, smoke)
