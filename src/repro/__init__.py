"""repro — BoomHQ (learned hybrid-query optimization) on a multi-pod JAX stack."""

__version__ = "0.1.0"
