"""End-to-end driver: a hybrid-query SERVICE with batched + async requests.

Simulates the deployment the paper targets: a fitted BoomHQ instance serving
a stream of mixed MHQ requests (different weights, predicates, k and recall
targets) through the batched ``ServingEngine`` — one fused optimizer
dispatch + grouped vmapped execution per batch instead of a host sync per
query — with running QPS/recall accounting and a mid-stream data insert
(the paper's update scenario). The first batch is also served through the
old per-query loop so the dispatch win is visible.

Later stages switch to LIVE traffic: the table is sharded
(``bind_shards``) and a Poisson request stream flows through the async
deadline-aware engine — requests queue, batches cut when full or when the
oldest request ages out, each batch fans out across the shards, and every
request resolves with an ok/timed-out disposition plus its latency.

The final stage is STREAMING INGEST (``bind_tiered``,
docs/tiered_ingest.md): inserts land in a bounded writable hot segment in
front of the sealed cold IVF state, queries merge both tiers under one
epoch-swapped snapshot, and a background compaction folds hot rows cold
mid-stream with zero serving pauses.

  PYTHONPATH=src python examples/hybrid_serving.py
"""
import asyncio
import time

import numpy as np

from repro.bench import datasets, queries
from repro.core.boomhq import BoomHQ, BoomHQConfig
from repro.core.data_encoder import DataEncoderConfig
from repro.core.executor import recall_at_k
from repro.core.rewriter import RewriterConfig
from repro.serve.batch import ServingEngine, warm_bucket_ladder
from repro.serve.queue import AsyncServingEngine, serve_stream
from repro.vectordb import flat


def ground_truths(table, reqs):
    return [np.asarray(flat.ground_truth(table, list(q.query_vectors),
                                         list(q.weights), q.predicates,
                                         q.k)[0]) for q in reqs]


def main():
    table = datasets.make("aka_title", rows=6000, seed=0)
    train = queries.gen_workload(table, 40, n_vec_used=2, seed=1)
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=32,
        encoder=DataEncoderConfig(frozen_steps=40, ae_steps=80, sample=2048),
        rewriter=RewriterConfig(steps=250)))
    bq.fit(train)
    engine = ServingEngine(bq, batch_size=24)
    print("service ready")

    stream = queries.gen_workload(table, 48, n_vec_used=2, seed=2)
    engine.warmup(stream)

    # sequential reference on the first batch (the pre-batching hot path);
    # warm its jit specializations untimed so both columns are steady-state
    reqs = stream[:24]
    gts = ground_truths(bq.table, reqs)
    for q in reqs:
        bq.execute(q)
    recs, t0 = [], time.perf_counter()
    for q, gt in zip(reqs, gts):
        ids, _ = bq.execute(q)
        recs.append(recall_at_k(ids, gt))
    dt = time.perf_counter() - t0
    print(f"  [sequential] {len(reqs)} requests in {dt:.2f}s "
          f"({len(reqs)/dt:.1f} QPS), mean recall {np.mean(recs):.3f}")

    _, rep = engine.serve(reqs, gt_ids=gts)
    print(f"  [batch-1]    {rep.describe()}")

    # live data insert (buffered update + incremental encoder fine-tune)
    rng = np.random.default_rng(3)
    n_new = 600
    vecs = [np.asarray(v[:n_new]) + 0.05 * rng.normal(
        size=(n_new, v.shape[1])).astype(np.float32) for v in table.vectors]
    scal = np.asarray(table.scalars[:n_new])
    bq.insert(vecs, scal, finetune=True)
    print(f"inserted {n_new} rows -> {bq.table.n_rows} total")

    reqs2 = stream[24:]
    gts2 = ground_truths(bq.table, reqs2)
    _, rep2 = engine.serve(reqs2, gt_ids=gts2)
    print(f"  [batch-2 (post-insert)] {rep2.describe()}")

    # -- the scoring-dispatch knob ----------------------------------------
    # Each execution group picks its scoring path per batch: DENSE (one
    # GEMM over all rows per vector column) or CANDIDATE_LOCAL (fused
    # gather+score over only the plan's candidate budget). The default
    # CostModel routes a group candidate-local when
    # batch·scan <= crossover·n_rows (crossover calibrated by
    # `python -m benchmarks.serving --crossover`); `bind_cost_model`
    # overrides it — move the threshold, or pin every group to one path.
    # ServeReport.path_counts / describe() show what served the traffic.
    from repro.serve.batch import CANDIDATE_LOCAL, DENSE, CostModel
    bq.bind_cost_model(CostModel(force=CANDIDATE_LOCAL))
    _, rep_local = engine.serve(reqs2, gt_ids=gts2)
    print(f"  [candidate-local forced] {rep_local.describe()}")
    bq.bind_cost_model()  # restore the calibrated crossover

    # -- live traffic: async deadline-aware serving over a sharded table --
    # Deadline-critical serving pins the EXACT sharded scan: one kernel
    # shape per (clause bucket, k) keeps mid-stream jit compiles out of
    # the latency budget. (The default cost model would plan each batch
    # and route per group — richer, but its plan-keyed group shapes can
    # cold-compile mid-stream; the learned sharded route is demonstrated
    # on the batch engine below, where no deadline is at stake.)
    n_shards = 3  # 6600 post-insert rows -> three 2200-row shards
    assert bq.table.n_rows % n_shards == 0
    bq.bind_shards(n_shards).bind_cost_model(CostModel(force=DENSE))
    live = queries.gen_workload(bq.table, 36, n_vec_used=2, seed=5)
    warm_bucket_ladder(bq.execute_batch, live, batch_size=12)
    rng = np.random.default_rng(6)
    gaps = rng.exponential(1.0 / 150.0, len(live) - 1).tolist()  # Poisson
    aeng = AsyncServingEngine(bq, batch_size=12, max_wait=0.02,
                              default_timeout=2.0)
    reqs = asyncio.run(serve_stream(aeng, live, arrival_gaps=gaps))
    gts = {r.seq: g for r, g in zip(reqs, ground_truths(bq.table, live))}
    rep3 = aeng.report(gt_ids=gts)
    print(f"  [async, {n_shards} shards] {rep3.describe()}")
    assert rep3.n_timed_out == 0, "deadline budget was generous"

    # -- the sharded-IVF LEARNED path -------------------------------------
    # With shards bound, index-strategy groups are cost-model routed three
    # ways: plan-driven per-shard IVF probing (each shard probes its OWN
    # index with the learned plan's shard-legalized nprobe/max_scan and
    # reranks candidate-locally inside the shard — the learned knobs stay
    # operative at the scale where the dense GEMM becomes the wall), the
    # exact per-shard dense scan, or single-device when shards are too
    # small to amortize the O(shards·k) merge. This table IS that small,
    # so the default model routes single-device; forcing SHARDED_LOCAL
    # demonstrates the probing fan-out (per-shard underfill escalation
    # keeps the recall contract). ServeReport.path_counts shows the route.
    from repro.serve.batch import SHARDED_LOCAL
    gt_live = ground_truths(bq.table, live)
    bq.bind_cost_model(CostModel(force=SHARDED_LOCAL))
    seng = ServingEngine(bq, batch_size=12)
    seng.warmup(live)
    _, rep4 = seng.serve(live, gt_ids=gt_live)
    print(f"  [sharded-IVF learned, {n_shards} shards] {rep4.describe()}")
    assert rep4.path_counts and "sharded_local" in rep4.path_counts
    bq.bind_cost_model()  # restore the calibrated three-way routing

    # -- streaming ingest: the tiered hot/cold table ----------------------
    # The inserts above were the legacy EAGER path: every insert regrouped
    # the indexes and rebuilt the executor before returning. bind_tiered
    # switches to the LSM-style tiered table (docs/tiered_ingest.md):
    # inserts append to a bounded writable hot segment — visible to the
    # very next batch, scored exactly, candidate-locally — and a full
    # segment is folded into the cold IVF state by a BACKGROUND compaction
    # that publishes via an epoch-swapped snapshot. Serving never pauses:
    # every batch executes against the immutable snapshot stamped on it at
    # cut time, so an epoch swap mid-flight cannot mix row-id spaces.
    bq.bind_shards(1).bind_cost_model()
    bq.bind_tiered(hot_capacity=512)
    rng = np.random.default_rng(9)
    n_live = 700  # > hot capacity: forces a mid-stream background compaction
    lvecs = [np.asarray(v[:n_live]) + 0.05 * rng.normal(
        size=(n_live, v.shape[1])).astype(np.float32)
        for v in bq.table.vectors]
    lscal = np.asarray(bq.table.scalars[:n_live])

    async def ingest_while_serving():
        eng = AsyncServingEngine(bq, batch_size=12, max_wait=0.02)
        async with eng:
            tasks = [asyncio.ensure_future(eng.submit(q)) for q in live]
            # mid-stream: fills the hot segment; the engine's
            # CompactionScheduler folds it cold on its own worker thread
            await asyncio.get_running_loop().run_in_executor(
                None, bq.insert, lvecs, lscal)
            await asyncio.gather(*tasks)
        return eng

    eng5 = asyncio.run(ingest_while_serving())
    rep5 = eng5.report()
    print(f"  [tiered streaming ingest] {rep5.describe()}")
    assert rep5.n_compactions >= 1 and rep5.n_timed_out == 0
    snap = bq.tiered.snapshot()
    print(f"  epoch {snap.epoch}: {snap.cold.table.n_rows} cold + "
          f"{snap.n_hot} hot rows, "
          f"encoder staleness {bq.tiered.encoder_staleness():.3f}")
    bq.unbind_tiered()  # folds any remaining hot rows, back to build-once


if __name__ == "__main__":
    main()
