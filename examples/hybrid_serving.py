"""End-to-end driver: a hybrid-query SERVICE with batched requests.

Simulates the deployment the paper targets: a fitted BoomHQ instance serving
a stream of mixed MHQ requests (different weights, predicates, k and recall
targets), with running QPS/recall accounting and a mid-stream data insert
(the paper's update scenario).

  PYTHONPATH=src python examples/hybrid_serving.py
"""
import time

import numpy as np

from repro.bench import datasets, queries
from repro.core.boomhq import BoomHQ, BoomHQConfig
from repro.core.data_encoder import DataEncoderConfig
from repro.core.executor import recall_at_k
from repro.core.rewriter import RewriterConfig
from repro.vectordb import flat


def main():
    table = datasets.make("aka_title", rows=6000, seed=0)
    train = queries.gen_workload(table, 40, n_vec_used=2, seed=1)
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=32,
        encoder=DataEncoderConfig(frozen_steps=40, ae_steps=80, sample=2048),
        rewriter=RewriterConfig(steps=250)))
    bq.fit(train)
    print("service ready")

    def serve_batch(reqs, tag):
        recs, t0 = [], time.perf_counter()
        for q in reqs:
            ids, _ = bq.execute(q)
            gt, _ = flat.ground_truth(bq.table, list(q.query_vectors),
                                      list(q.weights), q.predicates, q.k)
            recs.append(recall_at_k(ids, gt))
        dt = time.perf_counter() - t0
        print(f"  [{tag}] {len(reqs)} requests in {dt:.2f}s "
              f"({len(reqs)/dt:.1f} QPS), mean recall {np.mean(recs):.3f}")

    stream = queries.gen_workload(table, 48, n_vec_used=2, seed=2)
    serve_batch(stream[:24], "batch-1")

    # live data insert (buffered update + incremental encoder fine-tune)
    rng = np.random.default_rng(3)
    n_new = 600
    vecs = [np.asarray(v[:n_new]) + 0.05 * rng.normal(
        size=(n_new, v.shape[1])).astype(np.float32) for v in table.vectors]
    scal = np.asarray(table.scalars[:n_new])
    bq.insert(vecs, scal, finetune=True)
    print(f"inserted {n_new} rows -> {bq.table.n_rows} total")

    serve_batch(stream[24:], "batch-2 (post-insert)")


if __name__ == "__main__":
    main()
