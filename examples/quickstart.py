"""Quickstart: build a hybrid table, fit BoomHQ, run optimized MHQs —
including DNF predicates written with the builder algebra.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.bench import datasets, queries
from repro.core.boomhq import BoomHQ, BoomHQConfig
from repro.core.data_encoder import DataEncoderConfig
from repro.core.executor import recall_at_k
from repro.core.query import MHQ
from repro.core.rewriter import RewriterConfig
from repro.vectordb import flat
from repro.vectordb.algebra import col


def main():
    # 1. a table with two vector columns + four scalar columns (TPC-H Part
    #    shape, §4 benchmark construction)
    table = datasets.make("part", rows=4000, seed=0)
    print(f"table: {table.n_rows} rows, {table.schema.n_vec} vector cols, "
          f"{table.schema.n_scalar} scalar cols")

    # 2. a stratified MHQ workload (weighted two-vector queries) — half
    #    conjunctive, half DNF (OR-of-ranges / IN-lists via the builder)
    workload = queries.gen_workload(table, 24, n_vec_used=2, seed=1) + \
        queries.gen_dnf_workload(table, 16, n_vec_used=2, seed=2)

    # 3. fit the learned optimizer (data encoder + self-supervised rewriter)
    bq = BoomHQ(table, BoomHQConfig(
        n_clusters=32,
        encoder=DataEncoderConfig(frozen_steps=40, ae_steps=80, sample=1024),
        rewriter=RewriterConfig(steps=200)))
    metrics = bq.fit(workload[:30])
    print(f"fit done: strategy_acc={metrics['strategy_acc']:.2f} "
          f"({metrics['fit_seconds']:.0f}s)")

    # 4. optimized execution on unseen queries
    for q in workload[30:36]:
        plan = bq.optimize(q)
        ids, scores = bq.execute(q)
        gt, _ = flat.ground_truth(table, list(q.query_vectors),
                                  list(q.weights), q.predicates, q.k)
        print(f"  w={tuple(round(w, 2) for w in q.weights)} "
              f"plan={plan.strategy:12s} recall={recall_at_k(ids, gt):.2f} "
              f"top-id={int(np.asarray(ids)[0])}")

    # 5. hand-written DNF predicate through the builder algebra: mid-range
    #    prices OR a specific brand excluding the smallest sizes. compile()
    #    resolves names against the schema and legalizes the clause count
    #    onto the (1, 2, 4) grid.
    expr = col("price").between(100, 400) | \
        (col("brand") == 3) & ~col("size").below(2.0)
    pred = expr.compile(table.schema)
    q0 = workload[30]
    q = MHQ(query_vectors=q0.query_vectors, weights=q0.weights,
            predicates=pred, k=10)
    ids, _ = bq.execute(q)
    gt, _ = flat.ground_truth(table, list(q.query_vectors), list(q.weights),
                              pred, q.k)
    print(f"  DNF (C={pred.n_clauses}) plan={bq.optimize(q).strategy:12s} "
          f"recall={recall_at_k(ids, gt):.2f}")


if __name__ == "__main__":
    main()
