"""End-to-end LM training driver: train a ~100M-parameter qwen3-family model
for a few hundred steps on the synthetic pipeline, with checkpoints and the
fault-tolerance rig.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import BatchSpec, make_source


def config_100m() -> ModelConfig:
    """~100M params: a scaled qwen3 family member."""
    return ModelConfig(
        name="qwen3-100m", family="dense",
        n_layers=8, d_model=512, vocab=32000,
        n_heads=8, n_kv_heads=4, head_dim=64, qk_norm=True,
        d_ff=1536, ffn_act="silu", dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = config_100m()
    print(f"model: {cfg.name} ~{cfg.n_params()/1e6:.0f}M params")

    from repro.train.step import TrainPlan, init_state, make_train_step
    from repro.checkpoint import ckpt as ckpt_lib
    from repro.distributed.fault_tolerance import StepWatchdog

    plan = TrainPlan(microbatches=2, lr=6e-4, warmup=30,
                     total_steps=args.steps, state_dtype="int8")
    params, opt = init_state(jax.random.PRNGKey(0), cfg, plan)
    step_fn = jax.jit(make_train_step(cfg, plan))
    src = make_source("synthetic", BatchSpec(8, 256, cfg.vocab), seed=0)
    wd = StepWatchdog()

    import time
    losses = []
    for step in range(args.steps):
        b = src.batch_at(step)
        t0 = time.perf_counter()
        params, opt, m = step_fn(params, opt,
                                 {"tokens": b["tokens"], "labels": b["labels"]})
        wd.record(time.perf_counter() - t0)
        losses.append(float(m["loss"]))
        if step % 25 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"p50 {wd.p50()*1e3:.0f}ms")
        if (step + 1) % 100 == 0:
            ckpt_lib.save(args.ckpt, step + 1, {"params": params, "opt": opt})
    print(f"done: loss {np.mean(losses[:20]):.3f} -> {np.mean(losses[-20:]):.3f}"
          f" (ckpts in {args.ckpt})")
    assert np.mean(losses[-20:]) < np.mean(losses[:20])


if __name__ == "__main__":
    main()
