"""Lower + compile one (arch × shape) cell on the production mesh and print
its roofline terms — the single-cell version of the multi-pod dry-run.

  PYTHONPATH=src python examples/dryrun_one_cell.py --arch gemma-7b \
      --shape decode_32k [--multi-pod]

(Must run as its own process: the dry-run forces 512 host devices.)
"""
import subprocess
import sys


def main():
    args = sys.argv[1:] or ["--arch", "gemma-7b", "--shape", "decode_32k"]
    cmd = [sys.executable, "-m", "repro.launch.dryrun"] + args
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
