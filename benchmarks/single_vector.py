"""Fig. 3 — single-vector-column hybrid query QPS vs recall threshold.

BoomHQ vs the grid-searched static pgvector configuration, per dataset and
recall threshold. The paper reports ~20% average QPS improvement (8–32%).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common

DATASETS = ("fungis", "sift", "glove", "part", "aka_title", "orders")
THRESHOLDS = (0.8, 0.9, 0.95, 0.99)


def run(sizes=common.FAST, datasets=DATASETS, thresholds=THRESHOLDS,
        seed: int = 0) -> dict:
    out = {"figure": "fig3_single_vector", "rows": []}
    gains = []
    for ds in datasets:
        suite = common.build_suite(ds, n_vec_used=1, seed=seed, sizes=sizes)
        profile = common.grid_profile(
            suite.executor, suite.train[: min(16, len(suite.train))], suite.gts)
        for thr in thresholds:
            plan, _ = common.pick_static(profile, thr)
            base = common.eval_static(suite, plan, thr,
                                      repeats=sizes["repeats"])
            ours = common.eval_boomhq(suite, thr, repeats=sizes["repeats"])
            gain = ours["qps"] / base["qps"] - 1.0
            gains.append(gain)
            row = {"dataset": ds, "recall_thr": thr,
                   "boomhq_qps": round(ours["qps"], 1),
                   "boomhq_recall": round(ours["recall"], 3),
                   "static_qps": round(base["qps"], 1),
                   "static_recall": round(base["recall"], 3),
                   "qps_gain_pct": round(100 * gain, 1)}
            out["rows"].append(row)
            print(f"  fig3 {ds:10s} thr={thr:.2f} "
                  f"BoomHQ {ours['qps']:8.1f} qps (r={ours['recall']:.3f})  "
                  f"static {base['qps']:8.1f} qps (r={base['recall']:.3f})  "
                  f"gain {100*gain:+.1f}%")
    out["avg_qps_gain_pct"] = round(100 * float(np.mean(gains)), 1)
    print(f"fig3 AVG QPS gain: {out['avg_qps_gain_pct']}% "
          f"(paper: ~20%, range 8-32%)")
    return out


if __name__ == "__main__":
    run()
