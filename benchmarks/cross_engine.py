"""§5.4 — black-box portability: Milvus and OpenSearch personalities.

The 'original' system uses one grid-searched (λ, nprobe) applied uniformly
to all vector columns (k' = λ·k) — exactly the paper's §5.4 setup. BoomHQ
recommends per-column parameters within each engine's capability set.
Paper: +71–93% QPS on Milvus, +85–141% on OpenSearch.
"""
from __future__ import annotations


from benchmarks import common
from repro.core.executor import ENGINES

DATASETS = ("part", "aka_title")
ENGINE_NAMES = ("milvus", "opensearch")


def run(sizes=common.FAST, datasets=DATASETS, seed: int = 0,
        thr: float = 0.9) -> dict:
    out = {"figure": "sec54_cross_engine", "rows": []}
    for engine_name in ENGINE_NAMES:
        engine = ENGINES[engine_name]
        for ds in datasets:
            suite = common.build_suite(ds, n_vec_used=2, seed=seed,
                                       sizes=sizes, engine=engine)
            plan, _ = common.grid_search_static(
                suite.executor, suite.train[: min(16, len(suite.train))],
                suite.gts, thr)
            base = common.eval_static(suite, plan, thr, repeats=sizes["repeats"])
            ours = common.eval_boomhq(suite, thr, repeats=sizes["repeats"])
            gain = ours["qps"] / base["qps"] - 1.0
            out["rows"].append({
                "engine": engine_name, "dataset": ds,
                "boomhq_qps": round(ours["qps"], 1),
                "boomhq_recall": round(ours["recall"], 3),
                "original_qps": round(base["qps"], 1),
                "original_recall": round(base["recall"], 3),
                "qps_gain_pct": round(100 * gain, 1)})
            print(f"  §5.4 {engine_name:10s} {ds:10s} gain {100*gain:+.1f}% "
                  f"(BoomHQ r={ours['recall']:.3f})")
    return out


if __name__ == "__main__":
    run()
