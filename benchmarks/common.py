"""Shared benchmark harness: suites, baselines, QPS-at-recall evaluation.

The paper's metric (§5.1) is the maximum achievable QPS at a fixed recall
threshold. Baselines are *static* configurations chosen by grid search on a
validation workload — the best single plan whose mean recall meets the
threshold (exactly how §5.4 configures the original systems). BoomHQ picks
per-query plans; its optimizer overhead (probes + inference) is included in
the measured latency.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

from repro.bench import datasets, queries
from repro.core.boomhq import BoomHQ, BoomHQConfig
from repro.core.data_encoder import DataEncoderConfig
from repro.core.executor import (
    EngineCaps, HybridExecutor, PGVECTOR, recall_at_k,
)
from repro.core.query import ExecutionPlan, SubqueryParams
from repro.core.rewriter import RewriterConfig
from repro.vectordb import flat

# Row counts sized so filtered-query execution (≥ a few ms on the IVF path)
# dominates the per-query optimizer overhead (~2 ms) — the paper's regime.
FAST = dict(rows=60_000, n_train=24, n_test=16, frozen_steps=40, ae_steps=60,
            rw_steps=200, repeats=2, n_clusters=64)
FULL = dict(rows=250_000, n_train=96, n_test=48, frozen_steps=120, ae_steps=240,
            rw_steps=600, repeats=3, n_clusters=128)


@dataclasses.dataclass
class Suite:
    name: str
    table: object
    train: list
    test: list
    gts: dict  # id(query) -> ground-truth ids
    bq: BoomHQ
    executor: HybridExecutor  # baseline executor (same engine caps)


def ground_truths(table, workload):
    gts = {}
    for q in workload:
        ids, _ = flat.ground_truth(table, list(q.query_vectors),
                                   list(q.weights), q.predicates, q.k)
        gts[id(q)] = np.asarray(ids)
    return gts


def build_suite(dataset: str, *, n_vec_used: int = 1, seed: int = 0,
                engine: EngineCaps = PGVECTOR, sizes: dict = FAST,
                recall_targets=(0.8, 0.9, 0.95, 0.99),
                boomhq_overrides: Optional[dict] = None) -> Suite:
    table = datasets.make(dataset, rows=sizes["rows"], seed=seed)
    n = sizes["n_train"] + sizes["n_test"]
    wl = queries.gen_workload(table, n, n_vec_used=n_vec_used, seed=seed + 1)
    # mixed recall targets in training so E_rec is a live feature
    rng = np.random.default_rng(seed + 2)
    wl = [dataclasses.replace(q, recall_target=float(rng.choice(recall_targets)))
          for q in wl]
    train, test = wl[: sizes["n_train"]], wl[sizes["n_train"]:]
    cfg = BoomHQConfig(
        n_clusters=sizes["n_clusters"],
        encoder=DataEncoderConfig(frozen_steps=sizes["frozen_steps"],
                                  ae_steps=sizes["ae_steps"], sample=4096),
        rewriter=RewriterConfig(steps=sizes["rw_steps"]),
        **(boomhq_overrides or {}),
    )
    bq = BoomHQ(table, cfg, engine=engine)
    bq.fit(train)
    return Suite(name=dataset, table=table, train=train, test=test,
                 gts=ground_truths(table, wl), bq=bq, executor=bq.executor)


# ---------------------------------------------------------------------------
# static baselines (grid-searched per engine personality)
# ---------------------------------------------------------------------------

def static_plan_grid(n_vec: int, engine: EngineCaps) -> list[ExecutionPlan]:
    plans = []
    nprobes = (2, 4, 8, 16, 32)
    kms = (1, 2, 4, 8)
    scans = (8192, 131072) if engine.max_scan_tuples else (engine.default_max_scan,)
    for npb, km, ms in itertools.product(nprobes, kms, scans):
        subs = tuple(SubqueryParams(
            k_mult=km, nprobe=npb, max_scan=ms,
            iterative=engine.iterative_scan) for _ in range(n_vec))
        plans.append(ExecutionPlan("index_scan", subs))
    return plans


def grid_profile(executor: HybridExecutor, workload, gts) -> list:
    """Run every static plan once over the validation workload.
    -> [(plan, mean_recall, mean_latency)] — thresholds pick from this."""
    n_vec = workload[0].n_vec
    out = []
    for plan in static_plan_grid(n_vec, executor.engine):
        recs, lats = [], []
        for q0 in workload:
            ids, _, dt = executor.execute_timed(q0, plan)
            recs.append(recall_at_k(ids, gts[id(q0)]))
            lats.append(dt)
        out.append((plan, float(np.mean(recs)), float(np.mean(lats))))
    return out


def pick_static(profile: list, recall_thr: float) -> tuple[ExecutionPlan, float]:
    """Cheapest profiled static plan meeting the threshold (else best recall)."""
    ok = [p for p in profile if p[1] >= recall_thr]
    if ok:
        plan, mr, _ = min(ok, key=lambda p: p[2])
    else:
        plan, mr, _ = max(profile, key=lambda p: p[1])
    return plan, mr


def grid_search_static(executor: HybridExecutor, workload, gts,
                       recall_thr: float) -> tuple[ExecutionPlan, float]:
    """Best static plan: max QPS subject to mean recall >= threshold."""
    return pick_static(grid_profile(executor, workload, gts), recall_thr)


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def eval_boomhq(suite: Suite, recall_thr: float, *, repeats: int = 2) -> dict:
    recs, lats = [], []
    for q0 in suite.test:
        q = dataclasses.replace(q0, recall_target=recall_thr)
        ids, _, dt = suite.bq.execute_timed(q, repeats=repeats)
        recs.append(recall_at_k(ids, suite.gts[id(q0)]))
        lats.append(dt)
    return _summ(recs, lats)


def eval_static(suite: Suite, plan: ExecutionPlan, recall_thr: float,
                *, repeats: int = 2) -> dict:
    recs, lats = [], []
    for q0 in suite.test:
        q = dataclasses.replace(q0, recall_target=recall_thr)
        ids, _, dt = suite.executor.execute_timed(q, plan, repeats=repeats)
        recs.append(recall_at_k(ids, suite.gts[id(q0)]))
        lats.append(dt)
    return _summ(recs, lats)


def _summ(recs, lats) -> dict:
    lats = np.asarray(lats)
    return {
        "recall": float(np.mean(recs)),
        "lat_ms": float(lats.mean() * 1e3),
        "qps": float(1.0 / lats.mean()),
        "lats": lats.tolist(),
    }


def speedups(base_lats, new_lats) -> dict:
    b, n = np.asarray(base_lats), np.asarray(new_lats)
    per_q = b / np.maximum(n, 1e-9)
    return {"avg_speedup": float(b.mean() / n.mean()),
            "peak_speedup": float(per_q.max()),
            "n_over_2x": int((per_q > 2.0).sum()),
            "n_over_25x": int((per_q > 25.0).sum())}
