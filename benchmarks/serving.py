"""MHQ serving throughput: batched vs sequential, and async over shards.

Two measurements on one fitted suite:

  * ``run_sync_compare`` — the original figure: the sequential per-query
    loop vs ``ServingEngine`` -> ``BoomHQ.execute_batch`` (one fused
    optimizer dispatch + grouped vmapped execution per batch). Per-query
    results match up to float reduction order, so the recall columns must
    match and the QPS column is pure dispatch/batching win.
  * ``run_async_shards`` — the live-traffic figure: Poisson (open-loop)
    arrivals into the deadline-aware ``AsyncServingEngine``, served over
    1 / 2 / 4 table shards. The single-shard row is the plan-driven batched
    path; multi-shard rows fan every formed batch out across the shards
    (per-shard mask + local top-k on the dense score matrices, one
    O(shards·k) merge). Reports QPS, p50/p99 latency, timed-out count
    (zero at the default deadline) and oracle recall per shard count.

  PYTHONPATH=src python -m benchmarks.serving            # FAST suite
  PYTHONPATH=src python -m benchmarks.serving --smoke    # tiny, seconds

Run as a script the process forces 4 host devices, so the 2/4-shard rows
execute under shard_map on a real device mesh; under ``benchmarks.run``
(single-device process) they use logical shards with identical semantics.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

DEFAULT_SHARDS = (1, 2, 4)
DEFAULT_DEADLINE = 5.0  # seconds — generous; the report must show 0 timeouts
DEFAULT_RATE = 100.0  # Poisson arrivals per second


def _smoke_sizes():
    from benchmarks import common

    return dict(common.FAST, rows=4000, n_train=16, n_test=8, frozen_steps=25,
                ae_steps=40, rw_steps=100, n_clusters=16)


def _stream_and_gts(suite, n_stream: int, seed: int):
    import numpy as np

    from benchmarks import common
    from repro.bench import queries

    stream = queries.gen_workload(suite.table, n_stream, n_vec_used=2,
                                  seed=seed + 100)
    gts = [np.asarray(common.flat.ground_truth(
        suite.table, list(q.query_vectors), list(q.weights), q.predicates,
        q.k)[0]) for q in stream]
    return stream, gts


def run_sync_compare(suite, stream, gts, *, batch_size: int = 32) -> dict:
    """Sequential per-query loop vs the batched ServingEngine."""
    import numpy as np

    from repro.core.executor import recall_at_k
    from repro.serve.batch import ServingEngine

    bq = suite.bq
    engine = ServingEngine(bq, batch_size=batch_size)
    # steady-state measurement: ONE untimed pass per path populates every
    # jit specialization (a long-running service reuses a bounded kernel
    # cache; cold-compile cost is amortized away in both columns)
    engine.serve(stream)
    for q in stream:
        bq.execute(q)

    seq_recs = []
    t0 = time.perf_counter()
    for q, gt in zip(stream, gts):
        ids, _ = bq.execute(q)
        seq_recs.append(recall_at_k(ids, gt))
    seq_s = time.perf_counter() - t0
    seq_qps = len(stream) / seq_s

    _, rep = engine.serve(stream, gt_ids=gts)
    speedup = rep.qps / seq_qps
    print(f"  serving sync: sequential {seq_qps:.1f} QPS "
          f"(recall {np.mean(seq_recs):.3f}) vs batched {rep.qps:.1f} QPS "
          f"(recall {rep.mean_recall:.3f}) -> {speedup:.2f}x")
    return {
        "sequential_qps": round(seq_qps, 1),
        "sequential_recall": round(float(np.mean(seq_recs)), 3),
        "batched_qps": round(rep.qps, 1),
        "batched_recall": round(rep.mean_recall, 3),
        "batched_speedup": round(speedup, 2),
    }


def run_async_shards(suite, stream, gts, *, batch_size: int = 32,
                     shards=DEFAULT_SHARDS, rate: float = DEFAULT_RATE,
                     max_wait: float = 0.01,
                     deadline: float = DEFAULT_DEADLINE, seed: int = 0
                     ) -> list[dict]:
    """Poisson open-loop arrivals into AsyncServingEngine per shard count."""
    import numpy as np

    import jax

    from repro.serve.batch import warm_bucket_ladder
    from repro.serve.queue import AsyncServingEngine, serve_stream

    bq = suite.bq
    rng = np.random.default_rng(seed + 7)
    gaps = rng.exponential(1.0 / rate, len(stream) - 1).tolist()
    rows = []
    try:
        for s in shards:
            mesh = None
            if s > 1:
                if jax.device_count() >= s and suite.table.n_rows % s == 0:
                    from jax.sharding import Mesh
                    mesh = Mesh(np.array(jax.devices()[:s]), ("data",))
                    bq.bind_shards(mesh=mesh)
                else:
                    bq.bind_shards(s)  # logical shards, same semantics
            else:
                bq.bind_shards()  # plan-driven single-shard baseline
            warm_bucket_ladder(bq.execute_batch, stream, batch_size)
            engine = AsyncServingEngine(bq, batch_size=batch_size,
                                        max_wait=max_wait,
                                        default_timeout=deadline)
            reqs = asyncio.run(serve_stream(engine, stream,
                                            arrival_gaps=gaps))
            rep = engine.report(
                gt_ids={r.seq: gts[i] for i, r in enumerate(reqs)})
            row = {
                "shards": s,
                "mesh": mesh is not None,
                "qps": round(rep.qps, 1),
                "p50_ms": round(rep.p50_ms, 2),
                "p99_ms": round(rep.p99_ms, 2),
                "timed_out": rep.n_timed_out,
                "recall": round(rep.mean_recall, 3),
            }
            rows.append(row)
            print(f"  serving async shards={s}{' (mesh)' if row['mesh'] else ''}: "
                  f"{row['qps']} QPS, p50 {row['p50_ms']}ms, "
                  f"p99 {row['p99_ms']}ms, {row['timed_out']} timed out, "
                  f"recall {row['recall']}")
    finally:
        bq.bind_shards()  # leave the suite single-shard
    return rows


# dense-vs-candidate-local acceptance sweep: (dataset, rows, batch sizes).
# part = 2×768-dim columns (the multi-vector MHQ shape); sift = 1×128-dim at
# half a million rows (the scale where the dense GEMM becomes the wall).
CROSSOVER_TABLES = (("part", 60_000, (8, 32)), ("sift", 500_000, (8, 32)))


def run_crossover(tables=CROSSOVER_TABLES, *, n_stream: int = 64,
                  max_scan: int = 2048, nprobe: int = 16, k_mult: int = 4,
                  seed: int = 0) -> list[dict]:
    """Dense vs candidate-local batched executor QPS at a fixed plan.

    Both paths run the SAME legalized plan (index_scan, the smallest
    ``MAX_SCAN_GRID`` budget — the regime learned plans put large tables
    in), so they probe identical candidate slots and their oracle recall
    must agree to float ties; the QPS difference is purely the scoring
    path. The executor is driven directly (fixed plans, no optimizer) so
    the table isolates scoring; ``auto_path`` reports what the calibrated
    ``CostModel`` would pick for each group."""
    import numpy as np

    from repro.bench import datasets, queries
    from repro.core.executor import recall_at_k
    from repro.core.query import ExecutionPlan, SubqueryParams
    from repro.serve.batch import (
        BatchedHybridExecutor, CANDIDATE_LOCAL, DENSE, CostModel, next_bucket,
    )
    from repro.vectordb import flat, ivf

    rows_out = []
    for dataset, rows, batch_sizes in tables:
        table = datasets.make(dataset, rows=rows, seed=seed)
        n_vec = table.schema.n_vec
        nc = max(64, min(512, table.n_rows // 2000))
        idx = [ivf.build(v, nc, seed=i, metric=table.schema.metric)
               for i, v in enumerate(table.vectors)]
        stream = queries.gen_workload(table, n_stream,
                                      n_vec_used=min(2, n_vec),
                                      seed=seed + 100)
        gts = [np.asarray(flat.ground_truth(
            table, list(q.query_vectors), list(q.weights), q.predicates,
            q.k)[0]) for q in stream]
        plan = ExecutionPlan("index_scan", tuple(
            SubqueryParams(k_mult=k_mult, nprobe=nprobe, max_scan=max_scan,
                           iterative=True) for _ in range(n_vec)))
        plans = [plan] * len(stream)
        for bs in batch_sizes:
            row = {"dataset": dataset, "rows": table.n_rows, "batch": bs,
                   "max_scan": max_scan}
            scan_budget = max_scan * len([w for w in stream[0].weights
                                          if w > 0])
            row["auto_path"] = CostModel().choose(
                batch=next_bucket(bs), scan=scan_budget, n_rows=table.n_rows)
            for label, force in (("dense", DENSE),
                                 ("local", CANDIDATE_LOCAL)):
                bx = BatchedHybridExecutor(
                    table, idx, cost_model=CostModel(force=force))
                bx.execute_batch(stream[:bs], plans[:bs])  # warm jit
                t0 = time.perf_counter()
                results = []
                for s in range(0, len(stream), bs):
                    results.extend(
                        bx.execute_batch(stream[s: s + bs],
                                         plans[s: s + bs]))
                dt = time.perf_counter() - t0
                row[f"{label}_qps"] = round(len(stream) / dt, 1)
                row[f"{label}_recall"] = round(float(np.mean(
                    [recall_at_k(ids, gt)
                     for (ids, _), gt in zip(results, gts)])), 3)
            row["speedup"] = round(row["local_qps"] / row["dense_qps"], 2)
            row["recall_delta"] = round(
                abs(row["local_recall"] - row["dense_recall"]), 4)
            rows_out.append(row)
            print(f"  crossover {dataset} rows={row['rows']} B={bs}: "
                  f"dense {row['dense_qps']} QPS (recall "
                  f"{row['dense_recall']}) vs candidate-local "
                  f"{row['local_qps']} QPS (recall {row['local_recall']}) "
                  f"-> {row['speedup']}x, auto={row['auto_path']}")
    return rows_out


def run(sizes=None, dataset: str = "part", *, n_stream: int = 64,
        batch_size: int = 32, seed: int = 0, shards=DEFAULT_SHARDS,
        rate: float = DEFAULT_RATE, deadline: float = DEFAULT_DEADLINE
        ) -> dict:
    from benchmarks import common

    sizes = common.FAST if sizes is None else sizes
    suite = common.build_suite(dataset, n_vec_used=2, seed=seed, sizes=sizes)
    stream, gts = _stream_and_gts(suite, n_stream, seed)
    out = {
        "figure": "serving_batched_and_async_sharded",
        "dataset": dataset, "rows": suite.table.n_rows,
        "n_stream": n_stream, "batch_size": batch_size,
        "poisson_rate": rate, "deadline_s": deadline,
    }
    out.update(run_sync_compare(suite, stream, gts, batch_size=batch_size))
    out["async_shards"] = run_async_shards(
        suite, stream, gts, batch_size=batch_size, shards=shards, rate=rate,
        deadline=deadline, seed=seed)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="part")
    ap.add_argument("--n-stream", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--rate", type=float, default=DEFAULT_RATE,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--deadline", type=float, default=DEFAULT_DEADLINE,
                    help="per-request deadline (s)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny table for a seconds-long sanity run")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--crossover", action="store_true",
                    help="dense vs candidate-local acceptance sweep "
                         "(60k and 500k-row tables) instead of the suite")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.crossover:
        res = {"figure": "serving_scoring_crossover",
               "table": run_crossover(n_stream=args.n_stream)}
        if args.out:
            with open(args.out, "w") as f:
                json.dump(res, f, indent=2)
        return

    # force a 4-device host platform BEFORE jax initializes so the 2/4-shard
    # rows run under shard_map on a real mesh (imports below are lazy for
    # exactly this reason; benchmarks.run imports this module with jax
    # already single-device and gets logical shards instead)
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count="
            f"{max(DEFAULT_SHARDS)}").strip()

    from benchmarks import common

    sizes = _smoke_sizes() if args.smoke \
        else (common.FULL if args.full else common.FAST)
    res = run(sizes, args.dataset, n_stream=args.n_stream,
              batch_size=args.batch_size, rate=args.rate,
              deadline=args.deadline)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)


if __name__ == "__main__":
    main()
